//! City-hotspot scenario: the GIScience workload the paper's introduction
//! motivates — find activity hotspots in a city-scale point set (POIs /
//! check-ins / incident reports) that contains GPS-glitch outliers, and
//! show why K-Medoids (not K-Means) is the right tool.
//!
//! Compares, on the same data and same simulated cluster:
//!   - parallel K-Medoids++ (the paper's method)
//!   - parallel k-means     (the paper's Ref. 6 baseline)
//! reporting hotspot-coverage error and robustness to the outliers.

use kmedoids_mr::clustering::kmeans::ParallelKMeans;
use kmedoids_mr::clustering::parallel::ParallelKMedoids;
use kmedoids_mr::clustering::{Init, IterParams, UpdateStrategy};
use kmedoids_mr::config::ClusterConfig;
use kmedoids_mr::driver::setup_cluster;
use kmedoids_mr::geo::datasets::{generate, SpatialSpec};
use kmedoids_mr::geo::Point;
use kmedoids_mr::runtime::{load_backend, BackendKind};

fn coverage(truth: &[Point], fitted: &[Point]) -> f64 {
    truth
        .iter()
        .map(|t| fitted.iter().map(|c| t.dist2(c).sqrt()).fold(f64::INFINITY, f64::min))
        .sum::<f64>()
        / truth.len() as f64
}

fn main() -> anyhow::Result<()> {
    // A "city": 9 dense activity hotspots, 5% diffuse background, and a
    // visible rate of bad geocodes far outside town.
    let mut spec = SpatialSpec::new(200_000, 9, 7);
    spec.outlier_frac = 0.01;
    let dataset = generate(&spec);
    println!(
        "city dataset: {} points, {} hotspots, {:.1}% outliers",
        dataset.points.len(),
        dataset.centers.len(),
        spec.outlier_frac * 100.0
    );

    let cfg = ClusterConfig::paper_cluster(); // all 7 nodes
    let backend = load_backend(BackendKind::Auto, 2048)?;
    println!("backend: {}", backend.name());

    // Parallel K-Medoids++ (random init for the robustness comparison —
    // both methods get identical initialization).
    let (mut c1, input1, points1) = setup_cluster(&cfg, &dataset, 7);
    let mut kmed = ParallelKMedoids::new(backend.clone(), IterParams::new(9, 7));
    kmed.init = Init::Random;
    kmed.update = UpdateStrategy::Sampled { candidates: 256, member_sample: 8192 };
    let kmed_out = kmed.run(&mut c1, &input1, &points1);

    // Parallel k-means, same init.
    let (mut c2, input2, points2) = setup_cluster(&cfg, &dataset, 7);
    let km = ParallelKMeans {
        backend: backend.clone(),
        init: Init::Random,
        params: IterParams::new(9, 7),
    };
    let km_out = km.run(&mut c2, &input2, &points2);

    let kmed_cov = coverage(&dataset.centers, &kmed_out.medoids);
    let km_cov = coverage(&dataset.centers, &km_out.medoids);

    println!("\n{:<22}{:>14}{:>14}{:>14}", "method", "iterations", "sim time", "hotspot err");
    println!(
        "{:<22}{:>14}{:>13.1}s{:>13.1}m",
        "k-medoids++ (MR)", kmed_out.iterations, kmed_out.sim_seconds, kmed_cov
    );
    println!(
        "{:<22}{:>14}{:>13.1}s{:>13.1}m",
        "k-means (MR)", km_out.iterations, km_out.sim_seconds, km_cov
    );

    // Medoids are data points: every reported hotspot is a real location.
    for m in &kmed_out.medoids {
        anyhow::ensure!(
            points1.iter().any(|p| p.x == m.x && p.y == m.y),
            "every medoid must be an actual observed location"
        );
    }
    println!("\nall k-medoid hotspots are observed data points (k-means centroids are not)");
    println!("city_hotspots OK");
    Ok(())
}
