//! City-hotspot scenario: the GIScience workload the paper's introduction
//! motivates — find activity hotspots in a city-scale point set (POIs /
//! check-ins / incident reports) that contains GPS-glitch outliers, and
//! show why K-Medoids (not K-Means) is the right tool.
//!
//! Session showcase: the city is ingested **once**, then both solvers run
//! against the same `DatasetHandle` on the same simulated cluster:
//!   - parallel K-Medoids++ (the paper's method)
//!   - parallel k-means     (the paper's Ref. 6 baseline)
//! reporting hotspot-coverage error and robustness to the outliers.

use kmedoids_mr::prelude::*;

fn coverage(truth: &[Point], fitted: &[Point]) -> f64 {
    truth
        .iter()
        .map(|t| fitted.iter().map(|c| t.dist2(c).sqrt()).fold(f64::INFINITY, f64::min))
        .sum::<f64>()
        / truth.len() as f64
}

fn main() -> anyhow::Result<()> {
    // A "city": 9 dense activity hotspots, 5% diffuse background, and a
    // visible rate of bad geocodes far outside town.
    let mut spec = SpatialSpec::new(200_000, 9, 7);
    spec.outlier_frac = 0.01;
    let dataset = generate(&spec);
    println!(
        "city dataset: {} points, {} hotspots, {:.1}% outliers",
        dataset.points.len(),
        dataset.centers.len(),
        spec.outlier_frac * 100.0
    );

    // One session: the paper's full 7-node cluster, city ingested once.
    let mut session = ClusterSession::builder()
        .cluster(ClusterConfig::paper_cluster())
        .backend_kind(BackendKind::Auto)
        .seed(7)
        .build()?;
    println!("backend: {}", session.backend().name());
    let city = session.ingest("city", &dataset);

    // Parallel K-Medoids++ (random init for the robustness comparison —
    // both methods get identical initialization).
    let kmed = KMedoids::mapreduce()
        .random_init()
        .k(9)
        .seed(7)
        .update(UpdateStrategy::Sampled { candidates: 256, member_sample: 8192 })
        .build();
    let kmed_out = kmed.fit(&mut session, &city)?;

    // Parallel k-means, same init, same ingested data.
    let km = KMeans::mapreduce().random_init().k(9).seed(7).build();
    let km_out = km.fit(&mut session, &city)?;

    let kmed_cov = coverage(&dataset.centers, &kmed_out.medoids);
    let km_cov = coverage(&dataset.centers, &km_out.medoids);

    println!("\n{:<22}{:>14}{:>14}{:>14}", "method", "iterations", "sim time", "hotspot err");
    println!(
        "{:<22}{:>14}{:>13.1}s{:>13.1}m",
        "k-medoids++ (MR)", kmed_out.iterations, kmed_out.sim_seconds, kmed_cov
    );
    println!(
        "{:<22}{:>14}{:>13.1}s{:>13.1}m",
        "k-means (MR)", km_out.iterations, km_out.sim_seconds, km_cov
    );
    println!(
        "\nsession accounting: {} MR jobs, {:.1} simulated seconds total",
        session.jobs_run(),
        session.now_s()
    );

    // Medoids are data points: every reported hotspot is a real location.
    let points = session.dataset_points(&city);
    for m in &kmed_out.medoids {
        anyhow::ensure!(
            points.iter().any(|p| p == m),
            "every medoid must be an actual observed location"
        );
    }
    println!("all k-medoid hotspots are observed data points (k-means centroids are not)");
    println!("city_hotspots OK");
    Ok(())
}
