//! Quickstart: cluster a synthetic spatial dataset with the paper's
//! parallel K-Medoids++ on a simulated 4-node Hadoop cluster.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use kmedoids_mr::clustering::metrics::{adjusted_rand_index, silhouette_sampled};
use kmedoids_mr::clustering::parallel::ParallelKMedoids;
use kmedoids_mr::clustering::{Init, IterParams, UpdateStrategy};
use kmedoids_mr::config::ClusterConfig;
use kmedoids_mr::driver::setup_cluster;
use kmedoids_mr::geo::datasets::{generate, SpatialSpec};
use kmedoids_mr::runtime::{load_backend, BackendKind};

fn main() -> anyhow::Result<()> {
    // 1. A small spatial dataset: 30k points around 6 hotspots + noise.
    let mut spec = SpatialSpec::new(30_000, 6, 42);
    spec.outlier_frac = 0.0;
    let dataset = generate(&spec);
    println!("generated {} points around {} hotspots", dataset.points.len(), 6);

    // 2. A 4-node simulated cluster with the data ingested into HBase.
    let cfg = ClusterConfig::paper_cluster().cluster_subset(4);
    let (mut cluster, input, points) = setup_cluster(&cfg, &dataset, 42);
    println!(
        "cluster: {} nodes, {} map slots, {} HBase regions",
        cfg.nodes.len(),
        cfg.total_map_slots(),
        input.splits().len()
    );

    // 3. The compute backend: PJRT (AOT JAX/Pallas artifacts) when built,
    //    native Rust otherwise.
    let backend = load_backend(BackendKind::Auto, 2048)?;
    println!("backend: {}", backend.name());

    // 4. Parallel K-Medoids++ (the paper's §3).
    let mut driver = ParallelKMedoids::new(backend, IterParams::new(6, 42));
    driver.init = Init::PlusPlus;
    driver.update = UpdateStrategy::Exact;
    driver.label_pass = true;
    let out = driver.run(&mut cluster, &input, &points);

    println!("\nresults:");
    println!("  iterations      : {}", out.iterations);
    println!("  total cost E    : {:.4e}", out.cost);
    println!("  simulated time  : {:.1} s (on the 2012-era 4-node cluster)", out.sim_seconds);
    println!("  distance evals  : {}", out.dist_evals);
    for (i, m) in out.medoids.iter().enumerate() {
        println!("  medoid {i}: ({:.1}, {:.1})", m.x, m.y);
    }

    let labels = out.labels.as_ref().unwrap();
    let ari = adjusted_rand_index(labels, &dataset.truth);
    let sil = silhouette_sampled(&points, labels, 6, 500, 42);
    println!("  ARI vs truth    : {ari:.4}");
    println!("  silhouette (est): {sil:.4}");
    anyhow::ensure!(ari > 0.8, "clustering should recover the planted hotspots");
    println!("\nquickstart OK");
    Ok(())
}
