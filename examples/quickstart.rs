//! Quickstart: cluster a synthetic spatial dataset with the paper's
//! parallel K-Medoids++ through the session API — build the simulated
//! 4-node Hadoop cluster once, ingest once, fit through the
//! `SpatialClusterer` trait with live iteration streaming.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use kmedoids_mr::clustering::metrics::{adjusted_rand_index, silhouette_sampled};
use kmedoids_mr::prelude::*;
use kmedoids_mr::report;

fn main() -> anyhow::Result<()> {
    // 1. A small spatial dataset: 30k points around 6 hotspots + noise.
    let mut spec = SpatialSpec::new(30_000, 6, 42);
    spec.outlier_frac = 0.0;

    // 2. A session: 4-node simulated cluster + compute backend (PJRT
    //    when AOT artifacts are built, native Rust otherwise).
    let mut session = ClusterSession::builder()
        .cluster(ClusterConfig::paper_cluster())
        .nodes(4)
        .backend_kind(BackendKind::Auto)
        .seed(42)
        .build()?;
    let data = session.ingest_spec("quickstart", &spec);
    println!(
        "session: {} nodes, {} HBase splits, backend {}",
        session.config().nodes.len(),
        session.dataset_input(&data).splits().len(),
        session.backend().name()
    );

    // 3. Observers: record the iteration stream (and print it live).
    let log = IterationLog::new();
    session.add_observer(Box::new(log.clone()));
    session.add_observer(Box::new(StderrProgress::new()));

    // 4. Parallel K-Medoids++ (the paper's §3) via the fluent builder.
    let solver = KMedoids::mapreduce()
        .plus_plus()
        .k(6)
        .seed(42)
        .update(UpdateStrategy::Exact)
        .with_labels()
        .build();
    let out = solver.fit(&mut session, &data)?;

    println!("\niteration trace:\n{}", report::iteration_trace(&log.events()));
    println!("results:");
    println!("  iterations      : {}", out.iterations);
    println!("  total cost E    : {:.4e}", out.cost);
    println!("  simulated time  : {:.1} s (on the 2012-era 4-node cluster)", out.sim_seconds);
    println!("  distance evals  : {}", out.dist_evals);
    println!("  MR jobs run     : {}", session.jobs_run());
    for (i, m) in out.medoids.iter().enumerate() {
        println!("  medoid {i}: ({:.1}, {:.1})", m.x(), m.y());
    }

    let points = session.dataset_points(&data);
    let truth = session.dataset_truth(&data).expect("ingest_spec keeps ground truth");
    let labels = out.labels.as_ref().unwrap();
    let ari = adjusted_rand_index(labels, truth);
    let sil = silhouette_sampled(&points, labels, 6, 500, 42);
    println!("  ARI vs truth    : {ari:.4}");
    println!("  silhouette (est): {sil:.4}");
    anyhow::ensure!(ari > 0.8, "clustering should recover the planted hotspots");
    anyhow::ensure!(log.len() == out.iterations, "one event per iteration");
    println!("\nquickstart OK");
    Ok(())
}
