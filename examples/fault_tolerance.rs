//! Fault-tolerance demo: kill a slave node mid-job — plus a transient
//! per-attempt task failure rate — and watch the MapReduce runtime
//! recover: task retry up to `max_attempts`, map-output re-execution,
//! DFS re-replication, HBase region failover — with the clustering
//! result bit-identical to the healthy run (the Hadoop property the
//! paper's §2.1–2.2 leans on: "automatically handle the hardware
//! failure").
//!
//! Faults are injected as a [`FaultPlan`] on the session builder; the
//! per-job history exposes how many attempts the faults killed.

use kmedoids_mr::prelude::*;

fn main() -> anyhow::Result<()> {
    let mut spec = SpatialSpec::new(500_000, 6, 11);
    spec.outlier_frac = 0.0;
    let dataset = generate(&spec);
    let backend = load_backend(BackendKind::Auto, 2048)?;

    let run = |fail: bool| -> anyhow::Result<(ClusterOutcome, usize, usize)> {
        let mut builder = ClusterSession::builder()
            .cluster(ClusterConfig::paper_cluster())
            .nodes(5)
            .backend(backend.clone())
            .seed(11);
        if fail {
            // Kill slave01 (node index 1) mid-iteration — it runs map
            // tasks and reducers — bring it back two jobs later, and make
            // 5% of all task attempts die partway through.
            builder = builder.faults(FaultPlan {
                node_failures: vec![(85.0, 1)],
                node_recoveries: vec![(150.0, 1)],
                task_fail_rate: 0.05,
                seed: 11,
            });
        }
        let mut session = builder.build()?;
        let data = session.ingest("points", &dataset);
        let solver = KMedoids::mapreduce()
            .plus_plus()
            .k(6)
            .seed(11)
            .update(UpdateStrategy::SampledAdaptive {
                candidates: 128,
                frac_div: 4,
                min_sample: 8192,
            })
            .build();
        let out = solver.fit(&mut session, &data)?;
        let failed_attempts: usize =
            session.history().iter().map(|j| j.n_failed_attempts).sum();
        Ok((out, failed_attempts, session.dataset_n_points(&data)))
    };

    println!("healthy run:");
    let (ok, _, n) = run(false)?;
    println!(
        "  {} points, {} iterations, cost {:.4e}, sim {:.1}s",
        n, ok.iterations, ok.cost, ok.sim_seconds
    );

    println!("\nrun with slave01 failing at t=85s (recovering at t=150s):");
    let (faulty, failed_attempts, _) = run(true)?;
    println!(
        "  {} iterations, cost {:.4e}, sim {:.1}s, {} attempts killed by the failure",
        faulty.iterations, faulty.cost, faulty.sim_seconds, failed_attempts
    );

    anyhow::ensure!(ok.medoids == faulty.medoids, "results must be identical despite the failure");
    anyhow::ensure!(
        faulty.sim_seconds >= ok.sim_seconds,
        "the failure should not make the job faster"
    );
    println!(
        "\nresult identical to the healthy run; recovery cost {:.1}s of simulated time",
        faulty.sim_seconds - ok.sim_seconds
    );
    println!("fault_tolerance OK");
    Ok(())
}
