//! Fault-tolerance demo: kill a slave node mid-job and watch the
//! MapReduce runtime recover — task retry, map-output re-execution, DFS
//! re-replication, HBase region failover — with the clustering result
//! bit-identical to the healthy run (the Hadoop property the paper's
//! §2.1–2.2 leans on: "automatically handle the hardware failure").

use kmedoids_mr::clustering::parallel::ParallelKMedoids;
use kmedoids_mr::clustering::{Init, IterParams, UpdateStrategy};
use kmedoids_mr::config::ClusterConfig;
use kmedoids_mr::driver::setup_cluster;
use kmedoids_mr::geo::datasets::{generate, SpatialSpec};
use kmedoids_mr::runtime::{load_backend, BackendKind};

fn main() -> anyhow::Result<()> {
    let mut spec = SpatialSpec::new(500_000, 6, 11);
    spec.outlier_frac = 0.0;
    let dataset = generate(&spec);
    let cfg = ClusterConfig::paper_cluster().cluster_subset(5);
    let backend = load_backend(BackendKind::Auto, 2048)?;

    let run = |fail: bool| {
        let (mut cluster, input, points) = setup_cluster(&cfg, &dataset, 11);
        if fail {
            // Kill slave01 (node index 1) mid-iteration — it runs map
            // tasks and reducers — and bring it back two jobs later.
            cluster.plan_failure(85.0, 1);
            cluster.plan_recovery(150.0, 1);
        }
        let mut drv = ParallelKMedoids::new(backend.clone(), IterParams::new(6, 11));
        drv.init = Init::PlusPlus;
        drv.update = UpdateStrategy::SampledAdaptive { candidates: 128, frac_div: 4, min_sample: 8192 };
        let out = drv.run(&mut cluster, &input, &points);
        let failed_attempts: usize =
            cluster.history.iter().map(|j| j.n_failed_attempts).sum();
        let lost_outputs: u64 = 0; // counted per job in counters
        let _ = lost_outputs;
        (out, failed_attempts, points.len())
    };

    println!("healthy run:");
    let (ok, _, n) = run(false);
    println!("  {} points, {} iterations, cost {:.4e}, sim {:.1}s", n, ok.iterations, ok.cost, ok.sim_seconds);

    println!("\nrun with slave01 failing at t=85s (recovering at t=150s):");
    let (faulty, failed_attempts, _) = run(true);
    println!(
        "  {} iterations, cost {:.4e}, sim {:.1}s, {} attempts killed by the failure",
        faulty.iterations, faulty.cost, faulty.sim_seconds, failed_attempts
    );

    anyhow::ensure!(ok.medoids == faulty.medoids, "results must be identical despite the failure");
    anyhow::ensure!(
        faulty.sim_seconds >= ok.sim_seconds,
        "the failure should not make the job faster"
    );
    println!("\nresult identical to the healthy run; recovery cost {:.1}s of simulated time", faulty.sim_seconds - ok.sim_seconds);
    println!("fault_tolerance OK");
    Ok(())
}
