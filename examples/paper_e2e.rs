//! End-to-end driver: regenerates the paper's entire evaluation section
//! on one machine, through all three layers (Pallas/JAX AOT kernels via
//! PJRT on the hot path, MapReduce runtime on the simulated Table 3
//! cluster), driven by the session-based suites.
//!
//! By default runs at 1/10 of Table 5's dataset sizes so the whole thing
//! finishes in a few minutes; set `KMR_SCALE=1` for the full-scale run
//! recorded in EXPERIMENTS.md (sim times are work-proportional either
//! way; the backend env `KMR_E2E_BACKEND=native|pjrt|auto` picks the
//! kernel path, and `KMR_TRACE=1` streams live per-iteration events).
//!
//! ```bash
//! make artifacts && cargo run --release --example paper_e2e
//! ```

use kmedoids_mr::driver::suites::{ablation_suite, fig5_suite, table6_suite, SuiteOpts};
use kmedoids_mr::report;
use kmedoids_mr::runtime::{load_backend, BackendKind};

fn main() -> anyhow::Result<()> {
    let scale: usize = std::env::var("KMR_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
    let backend_kind = std::env::var("KMR_E2E_BACKEND")
        .ok()
        .and_then(|s| BackendKind::parse(&s))
        .unwrap_or(BackendKind::Auto);
    let seed: u64 = std::env::var("KMR_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let trace =
        std::env::var("KMR_TRACE").map_or(false, |v| !matches!(v.as_str(), "" | "0" | "false"));
    let backend = load_backend(backend_kind, 2048)?;
    let opts = SuiteOpts::new(scale, seed).with_trace(trace);
    println!(
        "paper end-to-end reproduction — scale 1/{scale}, backend {}, seed {seed}\n",
        backend.name()
    );

    println!("== Table 6 / Fig 3: execution time, 4–7 nodes x 3 datasets ==");
    let t6 = table6_suite(&backend, &opts);
    println!("\n{}", report::table6(&t6));

    println!("== Fig 4: speedup ==");
    println!("\n{}", report::fig4_speedup(&t6));

    println!("== Fig 5: comparative algorithms ==");
    let f5 = fig5_suite(&backend, &opts);
    println!("\n{}", report::fig5_comparative(&f5));

    println!("== §3.1 ablation: seeding strategy ==");
    let ab = ablation_suite(&backend, &opts);
    println!();
    println!("{:<18}{:>8}{:>12}{:>16}", "variant", "iters", "time(ms)", "cost");
    for r in &ab {
        println!("{:<18}{:>8}{:>12}{:>16.4e}", r.algorithm, r.iterations, r.time_ms, r.cost);
    }

    // Sanity assertions on the paper's qualitative claims.
    for ds in [t6[0].n_points, t6[4].n_points, t6[8].n_points] {
        let times: Vec<u64> =
            t6.iter().filter(|r| r.n_points == ds).map(|r| r.time_ms).collect();
        anyhow::ensure!(
            times.windows(2).all(|w| w[1] <= w[0]),
            "time must decrease with nodes: {times:?}"
        );
    }
    let pp_iters: usize = ab[0].iterations;
    let rand_iters: usize = ab[1].iterations;
    anyhow::ensure!(
        pp_iters <= rand_iters,
        "++ seeding should not need more iterations ({pp_iters} vs {rand_iters})"
    );

    println!("\nCSV (all cells):\n{}", report::to_csv(&t6));
    println!("paper_e2e OK");
    Ok(())
}
