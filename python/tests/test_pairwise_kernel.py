"""Pallas pairwise-cost kernel vs oracle + composition invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pairwise, ref


def make_case(seed, b, n_members, spread=50.0):
    rng = np.random.default_rng(seed)
    cand = (rng.normal(size=(b, 2)) * spread).astype(np.float32)
    memb = (rng.normal(size=(b, 2)) * spread).astype(np.float32)
    mask = (np.arange(b) < n_members).astype(np.float32)
    return jnp.array(cand), jnp.array(memb), jnp.array(mask)


def test_matches_ref():
    cand, memb, mask = make_case(0, 256, 256)
    got = pairwise.pairwise_cost_block(cand, memb, mask, tile=64)
    want = ref.pairwise_cost(cand, memb, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


def test_masked_members_ignored():
    cand, memb, mask = make_case(1, 128, 60)
    got = pairwise.pairwise_cost_block(cand, memb, mask, tile=64)
    # Recompute with garbage in the masked tail: result must be identical.
    memb2 = memb.at[60:].set(12345.0)
    got2 = pairwise.pairwise_cost_block(cand, memb2, mask, tile=64)
    np.testing.assert_allclose(got, got2, rtol=1e-5)


def test_zero_members_zero_cost():
    cand, memb, mask = make_case(2, 128, 0)
    got = pairwise.pairwise_cost_block(cand, memb, mask, tile=64)
    assert float(jnp.max(jnp.abs(got))) == 0.0


def test_self_distance_excluded_is_callers_job():
    # The kernel includes d(c,c)=0 when the candidate is in the member
    # block -- the sum is unchanged, which is exactly PAM's objective.
    cand, _, _ = make_case(3, 128, 128)
    mask = jnp.ones(128, jnp.float32)
    got = pairwise.pairwise_cost_block(cand, cand, mask, tile=64)
    want = ref.pairwise_cost(cand, cand, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


def test_block_composition():
    """Costs over a big member set == sum of per-block partials."""
    rng = np.random.default_rng(4)
    cand = jnp.array((rng.normal(size=(128, 2)) * 10).astype(np.float32))
    members = (rng.normal(size=(3, 128, 2)) * 10).astype(np.float32)
    mask = jnp.ones(128, jnp.float32)
    total = sum(
        pairwise.pairwise_cost_block(cand, jnp.array(mb), mask, tile=64)
        for mb in members
    )
    flat = jnp.array(members.reshape(-1, 2))
    want = ref.sq_distances(cand, flat).sum(axis=1)
    np.testing.assert_allclose(total, want, rtol=1e-4, atol=1e-1)


@pytest.mark.parametrize("tile", [32, 64, 128])
def test_tile_invariance(tile):
    cand, memb, mask = make_case(5, 128, 100)
    got = pairwise.pairwise_cost_block(cand, memb, mask, tile=tile)
    base = pairwise.pairwise_cost_block(cand, memb, mask, tile=128)
    np.testing.assert_allclose(got, base, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_members=st.integers(0, 128),
    spread=st.sampled_from([0.5, 10.0, 1e3]),
)
def test_hypothesis_matches_ref(seed, n_members, spread):
    cand, memb, mask = make_case(seed, 128, n_members, spread)
    got = pairwise.pairwise_cost_block(cand, memb, mask, tile=64)
    want = ref.pairwise_cost(cand, memb, mask)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=spread * spread * 1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_argmin_is_true_medoid(seed):
    """The argmin of kernel costs is the brute-force 1-medoid of the set."""
    rng = np.random.default_rng(seed)
    pts_np = (rng.normal(size=(128, 2)) * 5).astype(np.float32)
    pts = jnp.array(pts_np)
    mask = jnp.ones(128, jnp.float32)
    costs = np.array(pairwise.pairwise_cost_block(pts, pts, mask, tile=64))
    d = ((pts_np[:, None, :] - pts_np[None, :, :]) ** 2).sum(-1)
    brute = d.sum(1)
    assert np.isclose(costs[np.argmin(costs)], brute.min(), rtol=1e-3, atol=1e-1)
