"""L2 model graphs + AOT lowering: shapes, manifest, cache idempotence."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_assign_step_shapes():
    b, k = 256, 16
    out = model.assign_step(
        jnp.zeros((b, 2), jnp.float32),
        jnp.ones((b,), jnp.float32),
        jnp.full((k, 2), ref.PAD_COORD, jnp.float32).at[0].set(0.0),
    )
    labels, mind, ccost, ccnt = out
    assert labels.shape == (b,) and labels.dtype == jnp.int32
    assert mind.shape == (b,) and mind.dtype == jnp.float32
    assert ccost.shape == (k,) and ccnt.shape == (k,)


def test_seed_step_monotone_shrink():
    rng = np.random.default_rng(0)
    b, k = 256, 16
    pts = jnp.array(rng.normal(size=(b, 2)).astype(np.float32))
    mask = jnp.ones((b,), jnp.float32)
    med = np.full((k, 2), ref.PAD_COORD, np.float32)
    med[0] = [0.0, 0.0]
    cur = jnp.array(rng.uniform(0, 0.5, size=(b,)).astype(np.float32))
    new, s = model.seed_mindist_step(pts, mask, jnp.array(med), cur)
    assert bool(jnp.all(new <= cur + 1e-6))
    np.testing.assert_allclose(float(s[0]), float(jnp.sum(new)), rtol=1e-5)


def test_make_example_args_kinds():
    for kind in ("assign", "pairwise", "seed"):
        args = model.make_example_args(kind, 64, 8)
        assert all(a.dtype == jnp.float32 for a in args)
    with pytest.raises(ValueError):
        model.make_example_args("bogus", 64, 8)


def test_unit_names():
    assert aot.unit_name("assign", 2048, 64) == "assign_b2048_k64"
    assert aot.unit_name("pairwise", 2048, 64) == "pairwise_b2048"


def test_build_and_cache(tmp_path):
    out = str(tmp_path / "arts")
    m1 = aot.build(out, [{"block": 64, "kpad": 8}])
    assert len(m1["units"]) == 3
    for u in m1["units"]:
        p = os.path.join(out, u["file"])
        assert os.path.exists(p)
        text = open(p).read()
        assert text.startswith("HloModule"), "artifact must be HLO text"
        assert u["pad_coord"] == ref.PAD_COORD
    # Second build is a cache no-op producing an identical manifest.
    m2 = aot.build(out, [{"block": 64, "kpad": 8}])
    assert json.dumps(m1, sort_keys=True) == json.dumps(m2, sort_keys=True)


def test_repo_manifest_consistent():
    """If `make artifacts` has run, the checked manifest must be valid."""
    here = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    man = os.path.join(here, "artifacts", "manifest.json")
    if not os.path.exists(man):
        pytest.skip("artifacts not built")
    units = json.load(open(man))["units"]
    names = {u["name"] for u in units}
    assert "assign_b2048_k64" in names
    assert "pairwise_b2048" in names
    for u in units:
        assert os.path.exists(os.path.join(os.path.dirname(man), u["file"]))
