"""Pallas assign kernel vs pure-jnp oracle (the core L1 correctness signal)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import assign, ref


def make_case(seed, b, k, n_valid, n_medoids, spread=100.0):
    rng = np.random.default_rng(seed)
    pts = (rng.normal(size=(b, 2)) * spread).astype(np.float32)
    mask = (np.arange(b) < n_valid).astype(np.float32)
    med = np.full((k, 2), ref.PAD_COORD, dtype=np.float32)
    med[:n_medoids] = (rng.normal(size=(n_medoids, 2)) * spread).astype(np.float32)
    return jnp.array(pts), jnp.array(mask), jnp.array(med)


def check_against_ref(pts, mask, med, n_valid, tile=64, spread=100.0):
    labels, mind, ccost, ccnt = assign.assign_block(pts, mask, med, tile=tile)
    rl, rm, rc, rn = ref.assign(pts, mask, med)
    # Labels must agree exactly on valid rows (ties broken identically:
    # both use argmin over the same distance expression).
    np.testing.assert_array_equal(np.array(labels)[:n_valid], np.array(rl)[:n_valid])
    # Distances scale like spread^2; use scale-aware absolute tolerance.
    atol = max(spread * spread, 1.0) * 1e-5
    np.testing.assert_allclose(mind, rm, rtol=1e-4, atol=atol)
    np.testing.assert_allclose(ccost, rc, rtol=1e-3, atol=atol * pts.shape[0])
    np.testing.assert_allclose(ccnt, rn, rtol=0, atol=0)


def test_basic_block():
    pts, mask, med = make_case(0, 256, 16, 256, 8)
    check_against_ref(pts, mask, med, 256)


def test_padded_points():
    pts, mask, med = make_case(1, 256, 16, 100, 5)
    check_against_ref(pts, mask, med, 100)


def test_single_medoid():
    pts, mask, med = make_case(2, 128, 16, 128, 1)
    labels, mind, ccost, ccnt = assign.assign_block(pts, mask, med, tile=64)
    assert (np.array(labels) == 0).all()
    assert np.isclose(float(ccnt[0]), 128)


def test_all_points_padded():
    pts, mask, med = make_case(3, 128, 16, 0, 4)
    _, mind, ccost, ccnt = assign.assign_block(pts, mask, med, tile=64)
    assert float(jnp.sum(mind)) == 0.0
    assert float(jnp.sum(ccost)) == 0.0
    assert float(jnp.sum(ccnt)) == 0.0


def test_counts_sum_to_valid():
    pts, mask, med = make_case(4, 512, 16, 300, 7)
    _, _, _, ccnt = assign.assign_block(pts, mask, med, tile=128)
    assert float(jnp.sum(ccnt)) == 300.0


def test_cost_matches_mindist_sum():
    pts, mask, med = make_case(5, 256, 16, 256, 9)
    _, mind, ccost, _ = assign.assign_block(pts, mask, med, tile=64)
    np.testing.assert_allclose(float(jnp.sum(ccost)), float(jnp.sum(mind)), rtol=1e-5)


def test_point_on_medoid_has_zero_dist():
    pts, mask, med = make_case(6, 128, 16, 128, 4)
    pts = pts.at[7].set(med[2])
    labels, mind, _, _ = assign.assign_block(pts, mask, med, tile=64)
    assert int(labels[7]) == 2
    assert float(mind[7]) <= 1e-3


def test_pad_medoids_never_win():
    # Even extreme real coordinates lose to the PAD sentinel by orders of
    # magnitude, so labels stay < n_medoids.
    pts, mask, med = make_case(7, 256, 16, 256, 3, spread=1e5)
    labels, _, _, _ = assign.assign_block(pts, mask, med, tile=64)
    assert int(np.array(labels).max()) < 3


@pytest.mark.parametrize("tile", [32, 64, 128, 256])
def test_tile_invariance(tile):
    pts, mask, med = make_case(8, 256, 16, 200, 6)
    out = assign.assign_block(pts, mask, med, tile=tile)
    base = assign.assign_block(pts, mask, med, tile=256)
    for a, b in zip(out, base):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)


def test_indivisible_tile_raises():
    pts, mask, med = make_case(9, 250, 16, 250, 4)
    with pytest.raises(ValueError):
        assign.assign_block(pts, mask, med, tile=64)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_valid=st.integers(0, 256),
    n_medoids=st.integers(1, 15),
    spread=st.sampled_from([0.1, 1.0, 100.0, 1e4]),
)
def test_hypothesis_matches_ref(seed, n_valid, n_medoids, spread):
    pts, mask, med = make_case(seed, 256, 16, n_valid, n_medoids, spread)
    check_against_ref(pts, mask, med, n_valid, spread=spread)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_duplicate_points(seed):
    rng = np.random.default_rng(seed)
    base = (rng.normal(size=(4, 2)) * 10).astype(np.float32)
    pts = jnp.array(base[rng.integers(0, 4, size=256)])
    mask = jnp.ones(256, jnp.float32)
    med = np.full((16, 2), ref.PAD_COORD, np.float32)
    med[:4] = base
    med = jnp.array(med)
    labels, mind, _, _ = assign.assign_block(pts, mask, med, tile=64)
    assert float(jnp.max(mind)) <= 1e-3  # every point sits on a medoid
