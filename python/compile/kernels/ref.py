"""Pure-jnp oracles for the Pallas kernels.

These are the *specification*: small, obviously-correct jnp implementations
of the two hot-path computations. The pytest suite asserts the Pallas
kernels (and, transitively, the AOT artifacts the Rust runtime executes)
match these to float32 tolerance.
"""

import jax.numpy as jnp

# Coordinate used to pad unused medoid slots. Distances to a padded medoid
# are ~1e18 and can never win the argmin against any real point (real
# coordinates are bounded by the dataset bbox, |coord| < 1e6 by contract).
PAD_COORD = 1e9


def sq_distances(points, medoids):
    """All-pairs squared Euclidean distances.

    points: (B, 2) f32, medoids: (K, 2) f32 -> (B, K) f32.

    Uses the expanded form ||p||^2 - 2 p.m + ||m||^2 (same decomposition
    the kernel uses so rounding behaviour matches).
    """
    p2 = jnp.sum(points * points, axis=1, keepdims=True)  # (B, 1)
    m2 = jnp.sum(medoids * medoids, axis=1)[None, :]  # (1, K)
    cross = points @ medoids.T  # (B, K)
    d = p2 - 2.0 * cross + m2
    return jnp.maximum(d, 0.0)  # clamp tiny negative rounding


def assign(points, mask, medoids):
    """Nearest-medoid assignment over one block.

    points: (B, 2) f32 -- block of spatial points (padded rows arbitrary)
    mask:   (B,)  f32 -- 1.0 for valid rows, 0.0 for padding
    medoids:(K, 2) f32 -- padded with PAD_COORD rows beyond k

    Returns (labels, mindists, cluster_cost, cluster_count):
      labels        (B,) i32 -- argmin cluster id (garbage where mask==0)
      mindists      (B,) f32 -- squared distance to nearest medoid, masked
      cluster_cost  (K,) f32 -- sum of mindists per cluster (masked)
      cluster_count (K,) f32 -- number of valid points per cluster
    """
    d = sq_distances(points, medoids)  # (B, K)
    labels = jnp.argmin(d, axis=1).astype(jnp.int32)
    mindists = jnp.min(d, axis=1) * mask
    onehot = (labels[:, None] == jnp.arange(medoids.shape[0])[None, :]).astype(
        jnp.float32
    ) * mask[:, None]
    cluster_cost = jnp.sum(onehot * mindists[:, None], axis=0)
    cluster_count = jnp.sum(onehot, axis=0)
    return labels, mindists, cluster_cost, cluster_count


def pairwise_cost(candidates, members, member_mask):
    """Partial medoid-update costs over one (candidate-block, member-block).

    candidates:  (B, 2) f32 -- candidate medoid positions
    members:     (B, 2) f32 -- cluster member block (padded)
    member_mask: (B,)  f32 -- 1.0 for valid members

    Returns (B,) f32: partial_cost[i] = sum_j mask[j] * ||c_i - p_j||^2.
    The exact PAM update for a cluster of any size is the elementwise sum
    of these partials over all member blocks.
    """
    d = sq_distances(candidates, members)  # (B, B)
    return jnp.sum(d * member_mask[None, :], axis=1)
