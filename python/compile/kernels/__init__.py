"""Layer-1 Pallas kernels for the K-Medoids++ hot paths.

Two kernels cover every distance computation in the system:

- :mod:`assign` -- tiled point->nearest-medoid assignment (mapper hot path
  and the D(p) pass of the ++ seeding).
- :mod:`pairwise` -- tiled pairwise-cost partials (reducer hot path: exact
  PAM-style medoid update composed over fixed-size blocks).

Both are lowered with ``interpret=True`` (the CPU PJRT plugin cannot run
Mosaic custom-calls) and are validated against the pure-jnp oracle in
:mod:`ref` by the pytest suite.
"""

from . import assign, pairwise, ref  # noqa: F401
