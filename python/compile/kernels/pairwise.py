"""Pallas kernel: tiled pairwise-cost partials (the reducer hot path).

The exact PAM-style medoid update for a cluster asks, for every candidate
point ``c_i``, the total cost ``sum_j ||c_i - p_j||^2`` over the cluster
members. This kernel computes that sum for one (candidate-block,
member-block) pair; the Rust reducer composes arbitrary cluster sizes by
summing the partial vectors over member blocks and taking the global argmin
over candidate blocks.

Tiling: grid over the candidate axis; each step holds a ``(TILE, B)``
distance block in VMEM, with the member block resident across steps. The
cross term is one ``(TILE,2) x (2,B)`` MXU matmul.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 256


def _pairwise_kernel(cand_ref, memb_ref, mask_ref, cost_ref):
    c = cand_ref[...]  # (T, 2)
    p = memb_ref[...]  # (B, 2)
    mask = mask_ref[...]  # (B,)

    c2 = jnp.sum(c * c, axis=1, keepdims=True)  # (T, 1)
    p2 = jnp.sum(p * p, axis=1)[None, :]  # (1, B)
    cross = jnp.dot(c, p.T, preferred_element_type=jnp.float32)  # (T, B)
    d = jnp.maximum(c2 - 2.0 * cross + p2, 0.0)
    cost_ref[...] = jnp.sum(d * mask[None, :], axis=1)


@functools.partial(jax.jit, static_argnames=("tile",))
def pairwise_cost_block(candidates, members, member_mask, *, tile=None):
    """Partial medoid-update costs for one candidate/member block pair.

    candidates (B,2) f32, members (B,2) f32, member_mask (B,) f32.
    Returns (B,) f32 partial costs. Matches ref.pairwise_cost.
    """
    b, _ = candidates.shape
    if tile is None:
        tile = min(DEFAULT_TILE, b)
    if b % tile != 0:
        raise ValueError(f"block size {b} not divisible by tile {tile}")
    grid = (b // tile,)
    return pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, 2), lambda i: (i, 0)),
            pl.BlockSpec((b, 2), lambda i: (0, 0)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(candidates, members, member_mask)
