"""Pallas kernel: tiled nearest-medoid assignment (the mapper hot path).

One ``pallas_call`` processes a block of ``B`` points against ``K`` padded
medoid slots and emits, per point, the nearest medoid id and squared
distance, plus per-cluster partial cost/count sums (what the paper's
combiner would aggregate before the shuffle).

TPU shaping (see DESIGN.md #Hardware-Adaptation): the grid walks the point
axis in ``TILE``-row tiles so a ``(TILE, K)`` distance block lives in VMEM;
the distance uses the ``||p||^2 - 2 p.m + ||m||^2`` decomposition so the
cross term is a single ``(TILE,2) x (2,K)`` matmul that the MXU executes;
the per-cluster sums accumulate into a ``(K,)`` output block that every grid
step revisits (classic Pallas reduction-output pattern).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and the artifact must run inside the Rust coordinator.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 512 rows/tile: ~8% fewer interpret-mode grid steps than 256 with the
# (TILE, K) distance block still far under VMEM budget (512x64 f32 = 128KB).
DEFAULT_TILE = 512


def _assign_kernel(points_ref, mask_ref, medoids_ref, labels_ref, mindist_ref, ccost_ref, ccount_ref):
    """One grid step: TILE points vs all K medoid slots."""
    p = points_ref[...]  # (T, 2)
    mask = mask_ref[...]  # (T,)
    m = medoids_ref[...]  # (K, 2)

    # ||p - m||^2 = ||p||^2 - 2 p.m + ||m||^2 ; cross term is the matmul.
    p2 = jnp.sum(p * p, axis=1, keepdims=True)  # (T, 1)
    m2 = jnp.sum(m * m, axis=1)[None, :]  # (1, K)
    cross = jnp.dot(p, m.T, preferred_element_type=jnp.float32)  # (T, K)
    d = jnp.maximum(p2 - 2.0 * cross + m2, 0.0)

    labels = jnp.argmin(d, axis=1).astype(jnp.int32)
    mind = jnp.min(d, axis=1) * mask

    labels_ref[...] = labels
    mindist_ref[...] = mind

    k = m.shape[0]
    onehot = (labels[:, None] == jax.lax.iota(jnp.int32, k)[None, :]).astype(jnp.float32)
    onehot = onehot * mask[:, None]
    partial_cost = jnp.sum(onehot * mind[:, None], axis=0)  # (K,)
    partial_count = jnp.sum(onehot, axis=0)  # (K,)

    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        ccost_ref[...] = jnp.zeros_like(ccost_ref)
        ccount_ref[...] = jnp.zeros_like(ccount_ref)

    ccost_ref[...] += partial_cost
    ccount_ref[...] += partial_count


@functools.partial(jax.jit, static_argnames=("tile",))
def assign_block(points, mask, medoids, *, tile=None):
    """Assign a padded block of points to their nearest medoids.

    points (B,2) f32, mask (B,) f32, medoids (K,2) f32 (padded with
    ref.PAD_COORD). Returns (labels (B,) i32, mindists (B,) f32,
    cluster_cost (K,) f32, cluster_count (K,) f32). Matches ref.assign.
    """
    b, _ = points.shape
    k = medoids.shape[0]
    if tile is None:
        tile = min(DEFAULT_TILE, b)
    if b % tile != 0:
        raise ValueError(f"block size {b} not divisible by tile {tile}")
    grid = (b // tile,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, 2), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((k, 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(points, mask, medoids)
