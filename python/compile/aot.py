"""AOT lowering: JAX (L2+L1) -> HLO text artifacts for the Rust runtime.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the published xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Usage (from ``python/``)::

    python -m compile.aot --out-dir ../artifacts [--block 2048] [--kpad 64]

Writes one ``<name>.hlo.txt`` per AOT unit x block-variant plus
``manifest.json`` describing shapes, which the Rust loader validates at
startup. Running twice with unchanged inputs is a no-op (content hash).
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# Block variants compiled by default. The big variants are the production
# hot path (kpad=16 covers the paper's k=9 with 7x less padded work than
# kpad=64 — see EXPERIMENTS.md §Perf); the small one keeps unit tests and
# the quickstart example snappy (PJRT compile time scales with block size
# in interpret mode).
DEFAULT_VARIANTS = [
    {"block": 2048, "kpad": 16},
    {"block": 2048, "kpad": 64},
    {"block": 256, "kpad": 16},
]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_unit(kind: str, block: int, kpad: int) -> str:
    fn = model.AOT_UNITS[kind]
    args = model.make_example_args(kind, block, kpad)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def unit_name(kind: str, block: int, kpad: int) -> str:
    if kind == "pairwise":  # no medoid axis
        return f"{kind}_b{block}"
    return f"{kind}_b{block}_k{kpad}"


def build(out_dir: str, variants, force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    old = {}
    if os.path.exists(manifest_path) and not force:
        try:
            with open(manifest_path) as f:
                old = {u["name"]: u for u in json.load(f)["units"]}
        except (json.JSONDecodeError, KeyError):
            old = {}

    units = []
    seen = set()
    for v in variants:
        block, kpad = v["block"], v["kpad"]
        for kind in model.AOT_UNITS:
            name = unit_name(kind, block, kpad)
            if name in seen:  # pairwise has no medoid axis -> kpad variants collide
                continue
            seen.add(name)
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            prev = old.get(name)
            if prev and os.path.exists(path) and not force:
                with open(path, "rb") as f:
                    if hashlib.sha256(f.read()).hexdigest() == prev["sha256"]:
                        units.append(prev)
                        print(f"  [cached] {name}")
                        continue
            text = lower_unit(kind, block, kpad)
            with open(path, "w") as f:
                f.write(text)
            units.append(
                {
                    "name": name,
                    "kind": kind,
                    "block": block,
                    "kpad": kpad,
                    "file": os.path.basename(path),
                    "pad_coord": model.PAD_COORD,
                    "sha256": hashlib.sha256(text.encode()).hexdigest(),
                    "bytes": len(text),
                }
            )
            print(f"  [lowered] {name} -> {path} ({len(text)} chars)")

    manifest = {"format": 1, "units": units}
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {manifest_path} ({len(units)} units)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--block", type=int, default=None, help="extra block variant")
    ap.add_argument("--kpad", type=int, default=64)
    ap.add_argument("--force", action="store_true", help="rebuild even if cached")
    args = ap.parse_args()
    variants = list(DEFAULT_VARIANTS)
    if args.block is not None:
        variants.append({"block": args.block, "kpad": args.kpad})
    build(args.out_dir, variants, force=args.force)


if __name__ == "__main__":
    main()
