"""Build-time compile path: JAX model (L2) + Pallas kernels (L1) -> HLO text.

Nothing in this package is imported at serving time; the Rust coordinator
only consumes the AOT artifacts written by :mod:`compile.aot`.
"""
