"""Layer-2 JAX compute graphs for the MapReduce K-Medoids++ hot paths.

Each public function here is one AOT unit: it is jitted, lowered to HLO
text by :mod:`compile.aot`, and executed from the Rust coordinator via
PJRT. Shapes are static (see DESIGN.md padding contract).

The graphs are thin on purpose -- the Pallas kernels carry the compute and
XLA fuses the rest -- but they are the *only* numeric code on the request
path, so everything the mapper/reducer needs per block is produced in a
single executable call (no Python, no multiple dispatches).
"""

import jax
import jax.numpy as jnp

from .kernels import assign as assign_kernel
from .kernels import pairwise as pairwise_kernel
from .kernels.ref import PAD_COORD

__all__ = [
    "assign_step",
    "pairwise_cost_step",
    "seed_mindist_step",
    "PAD_COORD",
]


def assign_step(points, mask, medoids):
    """Mapper step: labels + mindists + per-cluster partial (cost, count).

    One call = one input block. The per-cluster partials are the combiner
    output the paper's mapper would emit alongside the (clusterId, point)
    pairs, letting the driver track total cost E (Eq. 1) per iteration
    without a second pass.
    """
    labels, mindists, ccost, ccount = assign_kernel.assign_block(points, mask, medoids)
    return labels, mindists, ccost, ccount


def pairwise_cost_step(candidates, members, member_mask):
    """Reducer step: partial PAM-update costs for a block pair."""
    return (pairwise_kernel.pairwise_cost_block(candidates, members, member_mask),)


def seed_mindist_step(points, mask, medoids, current_mindist):
    """K-Medoids++ seeding D(p) maintenance.

    After a new medoid is appended, D(p) only shrinks:
    ``D'(p) = min(D(p), ||p - new||^2)``. We reuse the assign kernel over
    the padded medoid set and fold in the running minimum, returning the
    per-block sum S that the weighted draw needs.
    """
    _, mindists, _, _ = assign_kernel.assign_block(points, mask, medoids)
    new_min = jnp.minimum(current_mindist, mindists) * mask
    block_sum = jnp.sum(new_min)
    return new_min, block_sum.reshape((1,))


def make_example_args(kind, b, k):
    """ShapeDtypeStructs for lowering each AOT unit."""
    f32 = jnp.float32
    pt = jax.ShapeDtypeStruct((b, 2), f32)
    vec = jax.ShapeDtypeStruct((b,), f32)
    med = jax.ShapeDtypeStruct((k, 2), f32)
    if kind == "assign":
        return (pt, vec, med)
    if kind == "pairwise":
        return (pt, pt, vec)
    if kind == "seed":
        return (pt, vec, med, vec)
    raise ValueError(f"unknown AOT unit kind: {kind}")


AOT_UNITS = {
    "assign": assign_step,
    "pairwise": pairwise_cost_step,
    "seed": seed_mindist_step,
}
