//! Offline stand-in for the `anyhow` crate (the build image has no
//! crates.io registry). Implements the subset this workspace uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error state is a flattened
//! message chain (outermost context first, root cause last) — enough for
//! `{}` / `{:#}` / `{:?}` to render like the real crate — plus the typed
//! root cause when one was supplied, so `downcast_ref` works.

use std::error::Error as StdError;
use std::fmt;

/// A context-carrying error. The rendered message chain drives display;
/// when the error was built from a typed `std::error::Error` the live
/// value rides along so callers can recover it with
/// [`Error::downcast_ref`] (the one piece of real-anyhow behaviour the
/// typed spec errors depend on).
pub struct Error {
    /// Outermost context first; the root cause is the last entry.
    chain: Vec<String>,
    /// The typed root cause, when the error came from one.
    payload: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], payload: None }
    }

    /// Create an error from a standard error, capturing its source chain
    /// (for display) and the value itself (for downcasting).
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        let mut chain = vec![error.to_string()];
        let mut src = error.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain, payload: Some(Box::new(error)) }
    }

    /// Wrap with an outer context message (like `anyhow::Error::context`).
    /// The typed root cause, if any, is preserved.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The typed root cause, if this error carries one of type `E`.
    /// Context wrapping does not hide it.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        self.payload.as_deref().and_then(|p| p.downcast_ref::<E>())
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        self.chain.first().map(|s| s.as_str()).unwrap_or("")
    }

    /// The root cause message (innermost).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, colon-separated (anyhow semantics).
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.to_string_outer())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_string_outer())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain.iter().skip(1).enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`;
// that is what makes the blanket `From` below coherent (same trick as the
// real anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `anyhow::Result<T>`: `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Autoref-specialization support for the single-expression `anyhow!` /
/// `bail!` arm (the real crate's "kind" trick): an expression that is
/// already convertible to [`Error`] — any typed `std::error::Error` —
/// converts via `From`, keeping its payload downcastable; anything
/// merely displayable falls back to a rendered message. Implementation
/// detail of the macros, not public API.
#[doc(hidden)]
pub mod kind {
    use super::Error;
    use std::fmt;

    pub struct Trait;
    pub trait TraitKind: Sized {
        #[inline]
        fn anyhow_kind(&self) -> Trait {
            Trait
        }
    }
    impl<E: Into<Error>> TraitKind for E {}
    impl Trait {
        pub fn wrap(self, error: impl Into<Error>) -> Error {
            error.into()
        }
    }

    pub struct Adhoc;
    pub trait AdhocKind: Sized {
        #[inline]
        fn anyhow_kind(&self) -> Adhoc {
            Adhoc
        }
    }
    impl<T: fmt::Display + Send + Sync + 'static + ?Sized> AdhocKind for &T {}
    impl Adhoc {
        pub fn wrap<M: fmt::Display>(self, message: M) -> Error {
            Error::msg(message)
        }
    }
}

/// Build an [`Error`] from a message, format string, or typed error
/// value (the latter stays downcastable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {{
        use $crate::kind::{AdhocKind as _, TraitKind as _};
        let error = $err;
        (&error).anyhow_kind().wrap(error)
    }};
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn context_chain_renders() {
        let r: Result<()> = Err(io_err()).with_context(|| "opening config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing thing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(e.to_string_outer(), "no value");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert!(f(12).unwrap_err().to_string_outer().contains("12"));
        assert!(f(3).unwrap_err().to_string_outer().contains("unlucky 3"));
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn question_mark_conversion() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn downcast_survives_every_typed_path() {
        // `?` conversion.
        fn via_question_mark() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = via_question_mark().unwrap_err();
        assert_eq!(
            e.downcast_ref::<std::io::Error>().unwrap().kind(),
            std::io::ErrorKind::NotFound
        );

        // Single-expression `bail!` of a typed error.
        fn via_bail() -> Result<()> {
            bail!(io_err());
        }
        assert!(via_bail().unwrap_err().downcast_ref::<std::io::Error>().is_some());

        // Context wrapping keeps the payload reachable.
        let e = via_question_mark().context("outer").unwrap_err();
        assert!(e.downcast_ref::<std::io::Error>().is_some());
        assert_eq!(format!("{e}"), "outer");

        // Adhoc messages carry no payload and say so.
        assert!(anyhow!("just text").downcast_ref::<std::io::Error>().is_none());
        let s = String::from("dynamic");
        assert!(anyhow!(s).downcast_ref::<std::io::Error>().is_none());
    }
}
