//! Offline stand-in for the `anyhow` crate (the build image has no
//! crates.io registry). Implements the subset this workspace uses:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error state is a flattened
//! message chain (outermost context first, root cause last) — enough for
//! `{}` / `{:#}` / `{:?}` to render like the real crate.

use std::error::Error as StdError;
use std::fmt;

/// A context-carrying error. Unlike the real crate this stores the
/// rendered message chain, not the live source error; that is all the
/// callers here need (display + propagation).
pub struct Error {
    /// Outermost context first; the root cause is the last entry.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Create an error from a standard error, capturing its source chain.
    pub fn new<E: StdError>(error: E) -> Error {
        let mut chain = vec![error.to_string()];
        let mut src = error.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context message (like `anyhow::Error::context`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        self.chain.first().map(|s| s.as_str()).unwrap_or("")
    }

    /// The root cause message (innermost).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, colon-separated (anyhow semantics).
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.to_string_outer())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.to_string_outer())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain.iter().skip(1).enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`;
// that is what makes the blanket `From` below coherent (same trick as the
// real anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `anyhow::Result<T>`: `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn context_chain_renders() {
        let r: Result<()> = Err(io_err()).with_context(|| "opening config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing thing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(e.to_string_outer(), "no value");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert!(f(12).unwrap_err().to_string_outer().contains("12"));
        assert!(f(3).unwrap_err().to_string_outer().contains("unlucky 3"));
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn question_mark_conversion() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
