//! API-compatible **stub** of the `xla` PJRT bindings used by
//! `kmedoids_mr::runtime::pjrt`.
//!
//! The build image has no crates.io registry and no `xla_extension`
//! shared library, so this crate provides just enough surface for the
//! PJRT backend to compile. [`PjRtClient::cpu`] always returns an error,
//! which makes `runtime::load_backend` fall back to the native Rust
//! kernels; the PJRT unit/integration tests already self-skip when no AOT
//! artifacts are present. To run the real PJRT path, point the `xla` path
//! dependency in the workspace `Cargo.toml` at a checkout of the actual
//! bindings — the types and signatures here mirror theirs.

use std::error::Error as StdError;
use std::fmt;

/// Error type matching the bindings' `Error` (a displayable status).
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}
impl StdError for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn stub_unavailable<T>() -> Result<T> {
    Err(XlaError(
        "xla_extension bindings not present in this build (offline stub); \
         use the native backend or vendor the real `xla` crate"
            .to_string(),
    ))
}

/// A host literal (dense array) — stub carries f32 storage only.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }
    pub fn reshape(mut self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(XlaError(format!(
                "reshape {:?} incompatible with {} elements",
                dims,
                self.data.len()
            )));
        }
        self.dims = dims.to_vec();
        Ok(self)
    }
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        stub_unavailable()
    }
    pub fn to_tuple1(self) -> Result<Literal> {
        stub_unavailable()
    }
    pub fn to_vec<T: FromLiteralElem>(&self) -> Result<Vec<T>> {
        stub_unavailable()
    }
}

/// Element types extractable from a [`Literal`] (sealed in the stub).
pub trait FromLiteralElem: Sized {}
impl FromLiteralElem for f32 {}
impl FromLiteralElem for i32 {}
impl FromLiteralElem for i64 {}

/// Parsed HLO module (stub holds nothing).
#[derive(Debug, Clone, Default)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub_unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone, Default)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by execution.
#[derive(Debug, Clone, Default)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_unavailable()
    }
}

/// Loaded executable handle.
#[derive(Debug, Default)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_unavailable()
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the stub.
#[derive(Debug, Default)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_unavailable()
    }
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline stub"));
    }

    #[test]
    fn literal_reshape_checks_shape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(l.clone().reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
    }
}
