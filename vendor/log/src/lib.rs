//! Offline stand-in for the `log` facade (no crates.io in the build
//! image). The five level macros format straight to stderr with a level
//! prefix — no registration, no filtering.

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { eprintln!("[ERROR] {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { eprintln!("[WARN] {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { eprintln!("[INFO] {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { eprintln!("[DEBUG] {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { eprintln!("[TRACE] {}", format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_expand() {
        crate::warn!("w {}", 1);
        crate::info!("i {}", 2);
        crate::error!("e");
        crate::debug!("d");
        crate::trace!("t");
    }
}
