//! Concurrency property tests for the serving epoch swap: readers that
//! hammer [`ModelHandle::load`] while a writer publishes snapshots must
//! only ever observe *complete* models (every probe answers exactly as
//! that epoch's reference model does — never a mix of two epochs) and a
//! non-decreasing epoch sequence; once the writer is done, the next read
//! sees the final epoch.

use kmedoids_mr::prelude::*;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const EPOCHS: u64 = 6;
const K: usize = 3;
const READERS: usize = 3;

/// Medoids for a given epoch, far enough apart that every epoch gives a
/// distinct (label, distance) answer on every probe point.
fn medoids_for(epoch: u64) -> Vec<Point> {
    let off = (epoch as f32) * 4096.0;
    (0..K)
        .map(|i| Point::new(off + (i as f32) * 512.0, off + (i as f32) * 256.0))
        .collect()
}

fn probes() -> Vec<Point> {
    let mut ps = Vec::new();
    for i in 0..24 {
        let t = i as f32;
        ps.push(Point::new(t * 913.0 - 3000.0, t * 377.0 + 150.0));
    }
    ps
}

#[test]
fn concurrent_readers_see_consistent_monotone_epochs() {
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(256, 16));
    let probes = probes();

    // Reference answer table: expected[e - 1][p] = (label, dist bits)
    // from a private model built with epoch e's medoids. A published
    // snapshot must match one row *exactly* — a torn read that mixed
    // medoid sets across epochs would straddle rows.
    let expected: Vec<Vec<(u32, u32)>> = (1..=EPOCHS)
        .map(|e| {
            let model = ClusterModel::new(backend.clone(), medoids_for(e), Metric::SqEuclidean);
            probes.iter().map(|p| model.assign(p)).map(|(l, d)| (l, d.to_bits())).collect()
        })
        .collect();

    let first = ClusterModel::new(backend.clone(), medoids_for(1), Metric::SqEuclidean);
    let handle = Arc::new(ModelHandle::new(first));
    let done = Arc::new(AtomicBool::new(false));

    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for r in 0..READERS {
            let handle = handle.clone();
            let done = done.clone();
            let probes = &probes;
            let expected = &expected;
            joins.push(scope.spawn(move || {
                let mut last_epoch = 0u64;
                let mut observed = 0usize;
                loop {
                    // Read the flag *before* loading: if the writer is
                    // already done, the Acquire pair guarantees this
                    // load sees the final publish.
                    let finished = done.load(Ordering::Acquire);
                    let model = handle.load();
                    let e = model.epoch();
                    assert!(
                        (1..=EPOCHS).contains(&e),
                        "reader {r} saw out-of-range epoch {e}"
                    );
                    assert!(
                        e >= last_epoch,
                        "reader {r} saw epoch regress {last_epoch} -> {e}"
                    );
                    last_epoch = e;
                    let row = &expected[(e - 1) as usize];
                    for (p, want) in probes.iter().zip(row) {
                        let (l, d) = model.assign(p);
                        assert_eq!(
                            (l, d.to_bits()),
                            *want,
                            "reader {r}: torn snapshot at epoch {e}"
                        );
                    }
                    observed += 1;
                    if finished {
                        break;
                    }
                }
                (last_epoch, observed)
            }));
        }

        for e in 2..=EPOCHS {
            std::thread::sleep(std::time::Duration::from_millis(2));
            let model = ClusterModel::new(backend.clone(), medoids_for(e), Metric::SqEuclidean);
            let stamped = handle.publish(model);
            assert_eq!(stamped, e, "publish must stamp consecutive epochs");
        }
        done.store(true, Ordering::Release);

        for join in joins {
            let (last, observed) = join.join().expect("reader panicked");
            assert_eq!(
                last, EPOCHS,
                "a reader's post-done load must see the final epoch"
            );
            assert!(observed > 0);
        }
    });

    assert_eq!(handle.epochs_published(), EPOCHS as usize);
    assert_eq!(handle.epoch(), EPOCHS);
}

#[test]
fn serve_session_updates_swap_epochs_under_concurrent_readers() {
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(256, 16));

    // A small explicit weighted coreset: 40 unit-weight representatives
    // on a deterministic lattice, three of them doubling as medoids.
    let reps: Vec<Point> = (0..40)
        .map(|i| {
            let t = i as f32;
            Point::new((t % 8.0) * 700.0, (t / 8.0).floor() * 900.0)
        })
        .collect();
    let weights = vec![1.0f64; reps.len()];
    let medoids = vec![reps[0], reps[17], reps[33]];

    let cfg = ServeConfig { batch_size: 16, refine_iters: 1, coreset_size: Some(40) };
    let mut serve = ServeSession::from_coreset(
        backend,
        Metric::SqEuclidean,
        99,
        cfg,
        medoids,
        reps,
        weights,
    )
    .expect("from_coreset");
    assert_eq!(serve.model().epoch(), 1);
    assert_eq!(serve.k(), 3);

    let handle = serve.handle();
    let done = Arc::new(AtomicBool::new(false));
    const BATCHES: usize = 5;

    std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for r in 0..2 {
            let handle = handle.clone();
            let done = done.clone();
            joins.push(scope.spawn(move || {
                let probe = Point::new(1100.0 + (r as f32) * 53.0, 1900.0);
                let mut last_epoch = 0u64;
                loop {
                    let finished = done.load(Ordering::Acquire);
                    let model = handle.load();
                    let e = model.epoch();
                    assert!((1..=(BATCHES as u64 + 1)).contains(&e));
                    assert!(e >= last_epoch, "epoch regressed {last_epoch} -> {e}");
                    last_epoch = e;
                    assert_eq!(model.k(), 3);
                    assert_eq!(model.dims(), 2);
                    let (label, dist) = model.assign(&probe);
                    assert!((label as usize) < model.k());
                    assert!(dist.is_finite() && dist >= 0.0);
                    if finished {
                        break;
                    }
                }
                last_epoch
            }));
        }

        // Single writer: five full mini-batches, one epoch swap each,
        // while the readers above spin on the shared handle.
        for b in 0..BATCHES {
            let deltas: Vec<Point> = (0..16)
                .map(|i| {
                    let t = (b * 16 + i) as f32;
                    Point::new(1000.0 + t * 3.0, 2000.0 - t * 2.0)
                })
                .collect();
            let flushed = serve.ingest(&deltas).expect("ingest");
            assert_eq!(flushed, 1, "a full batch must flush exactly once");
            let rep = serve.last_update().expect("flush leaves a report");
            assert_eq!(rep.batch, 16);
            assert!(
                rep.cost_after <= rep.cost_before * (1.0 + 1e-6),
                "refinement increased weighted cost: {} -> {}",
                rep.cost_before,
                rep.cost_after
            );
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        done.store(true, Ordering::Release);

        for join in joins {
            let last = join.join().expect("reader panicked");
            assert_eq!(last, BATCHES as u64 + 1, "post-done read sees final epoch");
        }
    });

    assert_eq!(serve.updates(), BATCHES);
    assert_eq!(serve.pending(), 0);
    assert_eq!(serve.model().epoch(), BATCHES as u64 + 1);
    assert_eq!(handle.epochs_published(), BATCHES + 1);
}
