//! Crash-recovery chaos harness (the durability subsystem's headline
//! proof).
//!
//! The whole engine is deterministic — same seed ⇒ byte-identical
//! medoids, costs, and labels at any thread count — so recovery can be
//! *proved*, not sampled: this harness "kills" runs at every durable
//! boundary and asserts the recovered run is bitwise-indistinguishable
//! from one that was never interrupted.
//!
//! - **Fit side**: every MR k-medoids algorithm × metric fits once with
//!   a keep-everything [`CheckpointSink`], then re-fits from *every*
//!   snapshot it left behind; labels, cost bits, medoids, iteration and
//!   distance-evaluation counters must all match the uninterrupted run.
//! - **Serve side**: a durable [`ServeSession`]'s directory is copied
//!   after every ingest round (the copy is exactly what a crashed
//!   process leaves) and restored; epoch, medoids, pending buffer, and
//!   query answers must match the still-running writer — and continued
//!   ingestion must stay identical from there on.
//! - **Corruption**: every damaged-file shape yields its exact typed
//!   [`PersistError`] through the store, and the store falls back to the
//!   last good snapshot.
//! - **Golden layout**: the on-disk byte layout is pinned field by
//!   field, so any format change must bump `FORMAT_VERSION` on purpose.

use std::path::Path;
use std::sync::Arc;

use kmedoids_mr::persist::{crc32, FORMAT_VERSION, HEADER_LEN, MAGIC};
use kmedoids_mr::prelude::*;
use kmedoids_mr::util::rng::Rng;
use kmedoids_mr::util::tempdir::TempDir;

const K: usize = 3;

/// Planted dataset matched to the metric: haversine needs (lat, lon)
/// degree pairs, the others use the planar map-unit cloud.
fn spec_for(metric: Metric, seed: u64) -> SpatialSpec {
    let mut spec = if metric == Metric::Haversine {
        SpatialSpec::latlon(900, K, seed)
    } else {
        SpatialSpec::new(900, K, seed)
    };
    spec.outlier_frac = 0.0;
    spec
}

/// Builder for one cell of the chaos matrix, labels on so resumed runs
/// can be compared point by point.
fn solver(algo: &str, metric: Metric, seed: u64) -> KMedoidsBuilder {
    let b = match algo {
        "kmedoids-mr" => KMedoids::mapreduce().random_init(),
        "kmedoids++-mr" => KMedoids::mapreduce().plus_plus(),
        "kmedoids-coreset-mr" => KMedoids::coreset(),
        other => panic!("no such algorithm {other}"),
    };
    b.k(K).seed(seed).metric(metric).with_labels()
}

fn fresh_session(seed: u64) -> ClusterSession {
    ClusterSession::builder().test(4).seed(seed).build().unwrap()
}

#[test]
fn every_fit_boundary_resumes_byte_identically() {
    let seed = 4242;
    // Controlled iterations pin the boundary count, so the matrix kills
    // the run at early, middle, and final snapshots for every cell.
    let iters = 4;
    for metric in [Metric::SqEuclidean, Metric::Haversine] {
        for algo in ["kmedoids-mr", "kmedoids++-mr", "kmedoids-coreset-mr"] {
            let spec = spec_for(metric, seed);

            // The uninterrupted run, snapshotting every boundary.
            let tmp = TempDir::new("chaos-fit");
            let store = CheckpointStore::open(tmp.path()).unwrap().keep_all(true);
            let mut session = fresh_session(seed);
            session.add_observer(Box::new(CheckpointSink::new(store.clone())));
            let data = session.ingest_spec("pts", &spec);
            let full = solver(algo, metric, seed)
                .fixed_iters(iters)
                .build()
                .fit(&mut session, &data)
                .unwrap();

            let snapshots = store.files().unwrap();
            assert_eq!(
                snapshots.len(),
                iters,
                "{algo}/{}: one snapshot per controlled iteration",
                metric.name()
            );

            // Kill at every boundary: the resumed fit must replay the
            // exact trajectory of the uninterrupted one.
            for snap in &snapshots {
                let ck = CheckpointStore::load(snap).unwrap();
                assert_eq!(ck.algorithm, algo);
                let mut session = fresh_session(seed);
                let data = session.ingest_spec("pts", &spec);
                let resumed = solver(algo, metric, seed)
                    .fixed_iters(iters)
                    .resume(ck.to_resume())
                    .build()
                    .fit(&mut session, &data)
                    .unwrap();
                let at = format!("{algo}/{} killed after iter {}", metric.name(), ck.iteration);
                assert_eq!(resumed.medoids, full.medoids, "{at}: medoids diverged");
                assert_eq!(resumed.labels, full.labels, "{at}: labels diverged");
                assert_eq!(resumed.cost.to_bits(), full.cost.to_bits(), "{at}: cost bits");
                assert_eq!(resumed.iterations, full.iterations, "{at}: iteration count");
                assert_eq!(resumed.dist_evals, full.dist_evals, "{at}: eval accounting");
            }
        }
    }
}

#[test]
fn resuming_the_converged_snapshot_runs_zero_further_iterations() {
    let seed = 4711;
    let metric = Metric::SqEuclidean;
    let spec = spec_for(metric, seed);

    let tmp = TempDir::new("chaos-converged");
    let store = CheckpointStore::open(tmp.path()).unwrap().keep_all(true);
    let mut session = fresh_session(seed);
    session.add_observer(Box::new(CheckpointSink::new(store.clone())));
    let data = session.ingest_spec("pts", &spec);
    let full = solver("kmedoids++-mr", metric, seed).build().fit(&mut session, &data).unwrap();

    let (_, last) = store.latest().unwrap();
    assert!(last.converged, "planted clusters must converge within the default iteration cap");
    assert_eq!(last.iteration as usize, full.iterations);

    // Had the snapshot dropped the converged flag, the resumed run would
    // execute one more cost-flat iteration and move the medoids again.
    let mut session = fresh_session(seed);
    let data = session.ingest_spec("pts", &spec);
    let resumed = solver("kmedoids++-mr", metric, seed)
        .resume(last.to_resume())
        .build()
        .fit(&mut session, &data)
        .unwrap();
    assert_eq!(resumed.iterations, full.iterations, "converged resume must not re-iterate");
    assert_eq!(resumed.medoids, full.medoids);
    assert_eq!(resumed.labels, full.labels);
    assert_eq!(resumed.cost.to_bits(), full.cost.to_bits());
    assert_eq!(resumed.dist_evals, full.dist_evals);
}

#[test]
fn mismatched_resume_state_is_refused_not_replayed() {
    let seed = 99;
    let spec = spec_for(Metric::SqEuclidean, seed);
    let tmp = TempDir::new("chaos-mismatch");
    let store = CheckpointStore::open(tmp.path()).unwrap();
    let mut session = fresh_session(seed);
    session.add_observer(Box::new(CheckpointSink::new(store.clone())));
    let data = session.ingest_spec("pts", &spec);
    solver("kmedoids++-mr", Metric::SqEuclidean, seed).build().fit(&mut session, &data).unwrap();
    let (_, ck) = store.latest().unwrap();

    // Same checkpoint, wrong algorithm / metric / seed: each must refuse
    // up front instead of silently producing a different trajectory.
    let cases: [(&str, Metric, u64, &str); 3] = [
        ("kmedoids-mr", Metric::SqEuclidean, seed, "written by 'kmedoids++-mr'"),
        ("kmedoids++-mr", Metric::Manhattan, seed, "metric"),
        ("kmedoids++-mr", Metric::SqEuclidean, seed + 1, "seed"),
    ];
    for (algo, metric, fit_seed, needle) in cases {
        let mut session = fresh_session(seed);
        let data = session.ingest_spec("pts", &spec);
        let err = solver(algo, metric, fit_seed)
            .resume(ck.to_resume())
            .build()
            .fit(&mut session, &data)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(needle), "{algo}/{}/{fit_seed}: {msg}", metric.name());
    }
}

/// What a crash leaves behind: a point-in-time copy of the durable dir.
fn snapshot_dir(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let path = entry.unwrap().path();
        if path.is_file() {
            std::fs::copy(&path, dst.join(path.file_name().unwrap())).unwrap();
        }
    }
}

#[test]
fn serve_restore_matches_the_uninterrupted_writer_at_every_kill_point() {
    let seed = 77;
    // Explicit coreset budget: restore needs the same recompression
    // threshold as the crashed writer to replay byte-identically.
    let cfg = ServeConfig { batch_size: 64, refine_iters: 2, coreset_size: Some(48) };
    let spec = spec_for(Metric::SqEuclidean, seed);
    let dataset = generate(&spec);
    let mut session = fresh_session(seed);
    let data = session.ingest("pts", &dataset);
    let out = solver("kmedoids-coreset-mr", Metric::SqEuclidean, seed)
        .build()
        .fit(&mut session, &data)
        .unwrap();
    let mut live = ServeSession::from_fit(&session, &data, &out, Metric::SqEuclidean, cfg).unwrap();

    let dir = TempDir::new("chaos-serve");
    live.attach_persistence(dir.path()).unwrap();
    assert!(live.is_durable());

    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(256, 16));
    let snaps = TempDir::new("chaos-serve-snaps");
    let mut rng = Rng::new(seed);
    let mut jittered = |n: usize, dx: f32, dy: f32| -> Vec<Point> {
        (0..n)
            .map(|_| {
                let p = dataset.points[rng.below(dataset.points.len())];
                Point::new(p.x() + dx, p.y() + dy)
            })
            .collect()
    };

    // Six rounds of 40 deltas against a batch size of 64: rounds
    // alternate between buffering only (state lives in the WAL) and
    // triggering a flush (state lives in a fresh snapshot), so the kill
    // points cover both halves of the checkpoint-then-truncate protocol.
    let probes = jittered(16, 1.5, -1.5);
    for round in 0..6u64 {
        let deltas = jittered(40, 40.0 * round as f32, -25.0);
        live.ingest(&deltas).unwrap();

        // "Crash": all the dead writer leaves is the directory contents.
        let snap = snaps.join(&format!("kill-{round}"));
        snapshot_dir(dir.path(), &snap);
        let restored = ServeSession::restore(backend.clone(), cfg, &snap).unwrap();

        assert_eq!(restored.model().epoch(), live.model().epoch(), "round {round}: epoch");
        assert_eq!(restored.model().medoids(), live.model().medoids(), "round {round}: medoids");
        assert_eq!(restored.pending(), live.pending(), "round {round}: pending deltas");
        assert_eq!(restored.updates(), live.updates(), "round {round}: flush count");
        assert_eq!(restored.coreset_len(), live.coreset_len(), "round {round}: pool size");
        for p in &probes {
            assert_eq!(
                restored.model().assign(p).0,
                live.model().assign(p).0,
                "round {round}: query answers diverged"
            );
        }
    }

    // The restored writer must also *continue* identically — matching at
    // the instant of the crash is necessary but not sufficient.
    let mut restored = ServeSession::restore(backend, cfg, &snaps.join("kill-5")).unwrap();
    let deltas = jittered(2 * 64, -70.0, 70.0);
    assert_eq!(live.ingest(&deltas).unwrap(), restored.ingest(&deltas).unwrap());
    assert_eq!(restored.model().epoch(), live.model().epoch());
    assert_eq!(restored.model().medoids(), live.model().medoids());
    assert_eq!(restored.updates(), live.updates());
}

/// A small but fully populated checkpoint for the corruption fixtures.
fn fixture_checkpoint(iteration: u64) -> Checkpoint {
    Checkpoint {
        algorithm: "kmedoids++-mr".into(),
        metric: Metric::Manhattan,
        dims: 2,
        k: 2,
        iteration,
        sim_seconds: 12.5,
        rng: [1234, 0, 0, 0],
        converged: false,
        cost: 1.0 / (iteration + 1) as f64,
        dist_evals: 5000 * iteration,
        epoch: 2,
        wal_seq: 9,
        medoids: vec![Point::new(0.5, -0.5), Point::new(8.0, 8.0)],
        coreset: Some((vec![Point::new(1.0, 1.0), Point::new(2.0, 2.0)], vec![3.0, 4.0])),
        pending: vec![Point::new(9.0, -9.0)],
    }
}

#[test]
fn every_corruption_shape_is_a_typed_error_through_the_store() {
    let tmp = TempDir::new("chaos-corrupt");
    let store = CheckpointStore::open(tmp.path()).unwrap().keep_all(true);
    let path = store.save(&fixture_checkpoint(7)).unwrap();
    let good = std::fs::read(&path).unwrap();

    // File cut off inside the header.
    std::fs::write(&path, &good[..HEADER_LEN - 2]).unwrap();
    let err = CheckpointStore::load(&path).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<PersistError>(),
            Some(PersistError::Truncated { need: HEADER_LEN, have: 18 })
        ),
        "{err:#}"
    );

    // File cut off inside the payload (header promises more bytes).
    std::fs::write(&path, &good[..good.len() - 5]).unwrap();
    let err = CheckpointStore::load(&path).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<PersistError>(),
            Some(PersistError::Truncated { need, have })
                if *need == good.len() && *have == good.len() - 5
        ),
        "{err:#}"
    );

    // Foreign magic: some other file format dropped into the directory.
    let mut bad = good.clone();
    bad[0..4].copy_from_slice(b"\x7fELF");
    std::fs::write(&path, &bad).unwrap();
    let err = CheckpointStore::load(&path).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<PersistError>(),
            Some(PersistError::BadMagic { found }) if found == b"\x7fELF"
        ),
        "{err:#}"
    );

    // A future format version this build cannot read.
    let mut bad = good.clone();
    bad[4..8].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
    std::fs::write(&path, &bad).unwrap();
    let err = CheckpointStore::load(&path).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<PersistError>(),
            Some(PersistError::UnsupportedVersion { found, supported })
                if *found == FORMAT_VERSION + 1 && *supported == FORMAT_VERSION
        ),
        "{err:#}"
    );

    // One flipped payload bit: the CRC must catch it, and the error must
    // carry both the stored and the recomputed checksum.
    let mut bad = good.clone();
    let last = bad.len() - 1;
    bad[last] ^= 0x40;
    std::fs::write(&path, &bad).unwrap();
    let err = CheckpointStore::load(&path).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<PersistError>(),
            Some(PersistError::BadCrc { stored, computed })
                if *stored == crc32(&good[HEADER_LEN..]) && *computed == crc32(&bad[HEADER_LEN..])
        ),
        "{err:#}"
    );

    // With only the corrupt file present, `latest` surfaces its typed
    // error instead of inventing an empty state...
    let err = store.latest().unwrap_err();
    assert!(matches!(err.downcast_ref::<PersistError>(), Some(PersistError::BadCrc { .. })));

    // ...and once an older good snapshot exists, it falls back to it.
    let older = store.save(&fixture_checkpoint(3)).unwrap();
    let (found, ck) = store.latest().unwrap();
    assert_eq!(found, older);
    assert_eq!(ck, fixture_checkpoint(3));

    // Undamaged bytes still load exactly, so the fixtures above failed
    // for the injected reasons and not some accident of the setup.
    std::fs::write(&path, &good).unwrap();
    assert_eq!(CheckpointStore::load(&path).unwrap(), fixture_checkpoint(7));
}

#[test]
fn on_disk_byte_layout_is_golden() {
    let ck = Checkpoint {
        algorithm: "kmedoids-mr".into(),
        metric: Metric::Haversine,
        dims: 2,
        k: 2,
        iteration: 7,
        sim_seconds: 1.5,
        rng: [42, 0, 0, 0],
        converged: true,
        cost: 8.25,
        dist_evals: 999,
        epoch: 3,
        wal_seq: 5,
        medoids: vec![Point::new(1.0, 2.0), Point::new(-3.5, 4.25)],
        coreset: None,
        pending: Vec::new(),
    };
    let bytes = ck.encode();

    // Header: magic, version, payload length, payload CRC — 20 bytes.
    assert_eq!(bytes[0..4], MAGIC);
    assert_eq!(&bytes[0..4], b"KMDC");
    assert_eq!(bytes[4..8], FORMAT_VERSION.to_le_bytes());
    assert_eq!(bytes[4..8], 1u32.to_le_bytes(), "a version bump must be deliberate");
    let payload = &bytes[HEADER_LEN..];
    assert_eq!(u64::from_le_bytes(bytes[8..16].try_into().unwrap()), payload.len() as u64);
    assert_eq!(u32::from_le_bytes(bytes[16..20].try_into().unwrap()), crc32(payload));

    // Payload, field by field, all little-endian at fixed offsets.
    assert_eq!(payload[0..2], 11u16.to_le_bytes(), "algorithm name length");
    assert_eq!(&payload[2..13], b"kmedoids-mr");
    assert_eq!(payload[13], 2, "haversine metric code");
    assert_eq!(payload[14], 2, "dims");
    assert_eq!(payload[15..19], 2u32.to_le_bytes(), "k");
    assert_eq!(payload[19..27], 7u64.to_le_bytes(), "iteration");
    assert_eq!(payload[27..35], 1.5f64.to_le_bytes(), "sim clock");
    assert_eq!(payload[35..43], 42u64.to_le_bytes(), "rng word 0 (base seed)");
    assert_eq!(payload[43..67], [0u8; 24], "rng words 1-3 (reserved)");
    assert_eq!(payload[67], 1, "converged flag");
    assert_eq!(payload[68..76], 8.25f64.to_le_bytes(), "cost");
    assert_eq!(payload[76..84], 999u64.to_le_bytes(), "dist evals");
    assert_eq!(payload[84..92], 3u64.to_le_bytes(), "epoch");
    assert_eq!(payload[92..100], 5u64.to_le_bytes(), "wal seq");
    // Medoids: u32 count, then dims × f32 coordinates per point.
    assert_eq!(payload[100..104], 2u32.to_le_bytes(), "medoid count");
    assert_eq!(payload[104..108], 1.0f32.to_le_bytes());
    assert_eq!(payload[108..112], 2.0f32.to_le_bytes());
    assert_eq!(payload[112..116], (-3.5f32).to_le_bytes());
    assert_eq!(payload[116..120], 4.25f32.to_le_bytes());
    // Tail: no-coreset flag, empty pending list — and nothing after.
    assert_eq!(payload[120], 0, "coreset flag");
    assert_eq!(payload[121..125], 0u32.to_le_bytes(), "pending count");
    assert_eq!(payload.len(), 125, "payload layout changed — bump FORMAT_VERSION");

    // The pinned frame decodes back to the identical checkpoint.
    assert_eq!(Checkpoint::decode(&bytes).unwrap(), ck);
}
