//! Integration tests over the public API: the full pipeline from dataset
//! generation through HBase ingest, MapReduce execution, and clustering —
//! including the PJRT artifact path when artifacts are built.

use kmedoids_mr::clustering::metrics::{adjusted_rand_index, total_cost};
use kmedoids_mr::clustering::parallel::ParallelKMedoids;
use kmedoids_mr::clustering::{Init, IterParams, UpdateStrategy};
use kmedoids_mr::config::ClusterConfig;
use kmedoids_mr::driver::{run_experiment, setup_cluster, Algorithm, Experiment};
use kmedoids_mr::geo::datasets::{generate, SpatialSpec};
use kmedoids_mr::runtime::{
    default_artifacts_dir, load_backend, BackendKind, ComputeBackend, Manifest, NativeBackend,
    PjrtBackend,
};
use std::sync::Arc;

fn clean_spec(n: usize, k: usize, seed: u64) -> SpatialSpec {
    let mut s = SpatialSpec::new(n, k, seed);
    s.outlier_frac = 0.0;
    s
}

#[test]
fn full_pipeline_native_backend() {
    // Seed 10 converges to the global basin (alternating K-Medoids is a
    // local-optimum method; see the seed sweep note in EXPERIMENTS.md).
    let dataset = generate(&clean_spec(20_000, 6, 10));
    let cfg = ClusterConfig::paper_cluster().cluster_subset(5);
    let (mut cluster, input, points) = setup_cluster(&cfg, &dataset, 10);

    // The ingest actually landed in both storage layers.
    assert!(cluster.hmaster.table("points").is_some());
    assert!(cluster.namenode.file("hbase/points").is_some());

    let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(512, 16));
    let mut drv = ParallelKMedoids::new(be, IterParams::new(6, 10));
    drv.init = Init::PlusPlus;
    drv.update = UpdateStrategy::Exact;
    drv.label_pass = true;
    let out = drv.run(&mut cluster, &input, &points);

    let ari = adjusted_rand_index(out.labels.as_ref().unwrap(), &dataset.truth);
    assert!(ari > 0.85, "ARI {ari}");
    // Counter-reported cost equals brute-force Eq. 1 cost.
    let brute = total_cost(&points, &out.medoids);
    assert!((out.cost - brute).abs() / brute < 0.01);
    // MR machinery really ran: one job per seeding round + iteration + labels.
    assert!(cluster.history.len() >= out.iterations + 5);
}

#[test]
fn full_pipeline_pjrt_backend_if_built() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let be: Arc<dyn ComputeBackend> = Arc::new(PjrtBackend::load(&manifest, 256).unwrap());

    let dataset = generate(&clean_spec(8_000, 5, 9));
    let cfg = ClusterConfig::paper_cluster().cluster_subset(4);
    let (mut cluster, input, points) = setup_cluster(&cfg, &dataset, 9);
    let mut drv = ParallelKMedoids::new(be.clone(), IterParams::new(5, 9));
    drv.update = UpdateStrategy::Exact;
    drv.label_pass = true;
    let out = drv.run(&mut cluster, &input, &points);
    let ari = adjusted_rand_index(out.labels.as_ref().unwrap(), &dataset.truth);
    assert!(ari > 0.85, "ARI {ari} (pjrt backend)");

    // PJRT and native agree bit-for-bit on labels (same argmin over the
    // same f32 expression).
    let nat: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(256, 16));
    let (mut c2, input2, points2) = setup_cluster(&cfg, &dataset, 9);
    let mut drv2 = ParallelKMedoids::new(nat, IterParams::new(5, 9));
    drv2.update = UpdateStrategy::Exact;
    drv2.label_pass = true;
    let out2 = drv2.run(&mut c2, &input2, &points2);
    assert_eq!(out.medoids, out2.medoids, "backends must agree on the trajectory");
    let _ = (input2, points2);
}

#[test]
fn auto_backend_loads() {
    let be = load_backend(BackendKind::Auto, 256).unwrap();
    assert!(be.block() >= 256);
}

#[test]
fn experiment_grid_cell_serial_vs_parallel_speedup() {
    // The core value proposition: at the paper's full Dataset-1 scale the
    // MR version on 7 nodes beats the serial version on one node. (At
    // 1/20 scale the fixed Hadoop overheads dominate and serial wins —
    // that crossover is real and documented in EXPERIMENTS.md.)
    let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(2048, 64));
    // Full scale in release; 1/4 scale keeps debug `cargo test` quick
    // (the crossover already favours parallel at ~330k points).
    let scale = if cfg!(debug_assertions) { 4 } else { 1 };
    let par = Experiment::paper_cell(Algorithm::KMedoidsPlusPlusMR, 7, 0, 31).scaled(scale);
    let ser = Experiment::paper_cell(Algorithm::KMedoidsSerial, 7, 0, 31).scaled(scale);
    let rp = run_experiment(&par, &be);
    let rs = run_experiment(&ser, &be);
    assert!(
        rp.time_ms < rs.time_ms,
        "parallel {}ms should beat serial {}ms",
        rp.time_ms,
        rs.time_ms
    );
}

#[test]
fn failure_mid_clustering_preserves_result() {
    let dataset = generate(&clean_spec(15_000, 5, 13));
    let cfg = ClusterConfig::paper_cluster().cluster_subset(5);
    let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(512, 16));

    let run = |fail: bool| {
        let (mut cluster, input, points) = setup_cluster(&cfg, &dataset, 13);
        if fail {
            cluster.plan_failure(30.0, 3);
        }
        let mut drv = ParallelKMedoids::new(be.clone(), IterParams::new(5, 13));
        drv.update = UpdateStrategy::Exact;
        (drv.run(&mut cluster, &input, &points), cluster.n_alive())
    };
    let (healthy, alive_h) = run(false);
    let (faulty, alive_f) = run(true);
    assert_eq!(alive_h, 5);
    assert_eq!(alive_f, 4);
    assert_eq!(healthy.medoids, faulty.medoids, "failure must not change the answer");
    assert!(faulty.sim_seconds >= healthy.sim_seconds);
}

#[test]
fn determinism_across_full_pipeline() {
    let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(512, 16));
    let mut exp = Experiment::paper_cell(Algorithm::KMedoidsPlusPlusMR, 6, 1, 99).scaled(50);
    exp.fixed_iters = Some(4);
    let a = run_experiment(&exp, &be);
    let b = run_experiment(&exp, &be);
    assert_eq!(a.time_ms, b.time_ms);
    assert_eq!(a.cost, b.cost);
    assert_eq!(a.dist_evals, b.dist_evals);
}
