//! Integration tests over the public API: the full pipeline from dataset
//! generation through session ingest (HBase + HDFS), MapReduce execution
//! via the `SpatialClusterer` trait, and streaming observers — including
//! the PJRT artifact path when artifacts are built.

use kmedoids_mr::clustering::metrics::{adjusted_rand_index, total_cost};
use kmedoids_mr::driver::{run_experiment, Algorithm, Experiment};
use kmedoids_mr::prelude::*;
use kmedoids_mr::runtime::{default_artifacts_dir, Manifest, PjrtBackend};
use std::sync::Arc;

fn clean_spec(n: usize, k: usize, seed: u64) -> SpatialSpec {
    let mut s = SpatialSpec::new(n, k, seed);
    s.outlier_frac = 0.0;
    s
}

fn session_with(
    n_nodes: usize,
    backend: Arc<dyn ComputeBackend>,
    seed: u64,
) -> ClusterSession {
    ClusterSession::builder()
        .cluster(ClusterConfig::paper_cluster())
        .nodes(n_nodes)
        .backend(backend)
        .seed(seed)
        .build()
        .unwrap()
}

#[test]
fn full_pipeline_native_backend() {
    // Seed 10 converges to the global basin (alternating K-Medoids is a
    // local-optimum method; see the seed sweep note in EXPERIMENTS.md).
    let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(512, 16));
    let mut session = session_with(5, be, 10);
    let data = session.ingest_spec("points", &clean_spec(20_000, 6, 10));

    // The ingest actually landed in both storage layers.
    assert!(session.cluster().hmaster.table("points").is_some());
    assert!(session.cluster().namenode.file("hbase/points").is_some());

    let log = IterationLog::new();
    session.add_observer(Box::new(log.clone()));
    let solver = KMedoids::mapreduce()
        .plus_plus()
        .k(6)
        .seed(10)
        .update(UpdateStrategy::Exact)
        .with_labels()
        .build();
    let out = solver.fit(&mut session, &data).unwrap();

    let truth = session.dataset_truth(&data).unwrap();
    let ari = adjusted_rand_index(out.labels.as_ref().unwrap(), truth);
    assert!(ari > 0.85, "ARI {ari}");
    // Counter-reported cost equals brute-force Eq. 1 cost.
    let points = session.dataset_points(&data);
    let brute = total_cost(&points, &out.medoids);
    assert!((out.cost - brute).abs() / brute < 0.01);
    // MR machinery really ran: one job per seeding round + iteration + labels.
    assert!(session.history().len() >= out.iterations + 5);
    assert_eq!(session.jobs_run(), session.history().len());
    // Observer stream is one event per iteration with matching totals.
    assert_eq!(log.len(), out.iterations);
    let last = log.last().unwrap();
    assert_eq!(last.cost, out.cost);
    assert_eq!(last.dist_evals, out.dist_evals);
    assert!(last.sim_seconds <= out.sim_seconds, "label pass runs after the last iteration");
}

#[test]
fn full_pipeline_pjrt_backend_if_built() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let be: Arc<dyn ComputeBackend> = Arc::new(PjrtBackend::load(&manifest, 256).unwrap());

    let spec = clean_spec(8_000, 5, 9);
    let fit = |backend: Arc<dyn ComputeBackend>| {
        let mut session = session_with(4, backend, 9);
        let data = session.ingest_spec("points", &spec);
        KMedoids::mapreduce()
            .plus_plus()
            .k(5)
            .seed(9)
            .update(UpdateStrategy::Exact)
            .with_labels()
            .build()
            .fit(&mut session, &data)
            .unwrap()
    };

    let out = fit(be);
    let truth = generate(&spec).truth;
    let ari = adjusted_rand_index(out.labels.as_ref().unwrap(), &truth);
    assert!(ari > 0.85, "ARI {ari} (pjrt backend)");

    // PJRT and native agree bit-for-bit on the trajectory (same argmin
    // over the same f32 expression).
    let out2 = fit(Arc::new(NativeBackend::new(256, 16)));
    assert_eq!(out.medoids, out2.medoids, "backends must agree on the trajectory");
}

#[test]
fn auto_backend_loads() {
    let be = load_backend(BackendKind::Auto, 256).unwrap();
    assert!(be.block() >= 256);
}

#[test]
fn experiment_grid_cell_serial_vs_parallel_speedup() {
    // The core value proposition: at the paper's full Dataset-1 scale the
    // MR version on 7 nodes beats the serial version on one node. (At
    // 1/20 scale the fixed Hadoop overheads dominate and serial wins —
    // that crossover is real and documented in EXPERIMENTS.md.)
    let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(2048, 64));
    // Full scale in release; 1/4 scale keeps debug `cargo test` quick
    // (the crossover already favours parallel at ~330k points).
    let scale = if cfg!(debug_assertions) { 4 } else { 1 };
    let par = Experiment::paper_cell(Algorithm::KMedoidsPlusPlusMR, 7, 0, 31).scaled(scale);
    let ser = Experiment::paper_cell(Algorithm::KMedoidsSerial, 7, 0, 31).scaled(scale);
    let rp = run_experiment(&par, &be);
    let rs = run_experiment(&ser, &be);
    assert!(
        rp.time_ms < rs.time_ms,
        "parallel {}ms should beat serial {}ms",
        rp.time_ms,
        rs.time_ms
    );
}

#[test]
fn failure_mid_clustering_preserves_result() {
    let dataset = generate(&clean_spec(15_000, 5, 13));
    let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(512, 16));

    let run = |fail: bool| {
        let mut session = session_with(5, be.clone(), 13);
        let data = session.ingest("points", &dataset);
        if fail {
            session.plan_failure(30.0, 3);
        }
        let out = KMedoids::mapreduce()
            .plus_plus()
            .k(5)
            .seed(13)
            .update(UpdateStrategy::Exact)
            .build()
            .fit(&mut session, &data)
            .unwrap();
        (out, session.n_alive())
    };
    let (healthy, alive_h) = run(false);
    let (faulty, alive_f) = run(true);
    assert_eq!(alive_h, 5);
    assert_eq!(alive_f, 4);
    assert_eq!(healthy.medoids, faulty.medoids, "failure must not change the answer");
    assert!(faulty.sim_seconds >= healthy.sim_seconds);
}

#[test]
fn determinism_across_full_pipeline() {
    let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(512, 16));
    let mut exp = Experiment::paper_cell(Algorithm::KMedoidsPlusPlusMR, 6, 1, 99).scaled(50);
    exp.fixed_iters = Some(4);
    let a = run_experiment(&exp, &be);
    let b = run_experiment(&exp, &be);
    assert_eq!(a.time_ms, b.time_ms);
    assert_eq!(a.cost, b.cost);
    assert_eq!(a.dist_evals, b.dist_evals);
}

#[test]
fn session_reuse_matches_fresh_sessions() {
    // Running two MR fits back-to-back on one session must produce the
    // same simulated results as two single-use sessions: per-fit sim
    // time is relative, table placement is per-table deterministic.
    let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(512, 16));
    let spec = clean_spec(12_000, 5, 21);

    let mut shared = session_with(5, be.clone(), 21);
    let data = shared.ingest_spec("points", &spec);
    let solver = KMedoids::mapreduce().plus_plus().k(5).seed(21).build();
    let first = solver.fit(&mut shared, &data).unwrap();
    let second = solver.fit(&mut shared, &data).unwrap();
    assert_eq!(first.medoids, second.medoids, "same solver, same data, same result");
    // Clock-relative sim time; the nonzero start only leaves float dust.
    assert!(
        (first.sim_seconds - second.sim_seconds).abs() < 1e-6,
        "per-fit sim time is clock-relative: {} vs {}",
        first.sim_seconds,
        second.sim_seconds
    );

    let mut fresh = session_with(5, be, 21);
    let fresh_data = fresh.ingest_spec("points", &spec);
    let fresh_out = solver.fit(&mut fresh, &fresh_data).unwrap();
    assert_eq!(fresh_out.medoids, first.medoids);
    assert_eq!(fresh_out.sim_seconds, first.sim_seconds);
    // The shared session's clock accumulated both fits.
    assert!(shared.now_s() > fresh.now_s());
}

#[test]
fn metric_generic_pipeline_end_to_end() {
    // The metric/dims matrix through the full public API: ingest once per
    // dataset shape, fit through the trait, verify costs against the
    // brute-force oracle under the same metric, and check byte-identity
    // across compute thread counts.
    use kmedoids_mr::clustering::metrics::total_cost_metric;
    let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(256, 16));
    let cells: [(SpatialSpec, Metric); 3] = [
        (clean_spec(4_000, 4, 15).with_dims(3), Metric::Manhattan),
        (clean_spec(4_000, 4, 15).with_dims(8), Metric::SqEuclidean),
        (SpatialSpec::latlon(4_000, 4, 15), Metric::Haversine),
    ];
    for (spec, metric) in cells {
        let fit = |threads: usize| {
            let mut session = ClusterSession::builder()
                .cluster(ClusterConfig::paper_cluster())
                .nodes(5)
                .backend(be.clone())
                .seed(15)
                .threads(threads)
                .build()
                .unwrap();
            let data = session.ingest_spec("points", &spec);
            assert_eq!(session.dataset_dims(&data), spec.dims);
            let out = KMedoids::mapreduce()
                .plus_plus()
                .k(4)
                .seed(15)
                .metric(metric)
                .update(UpdateStrategy::Exact)
                .with_labels()
                .build()
                .fit(&mut session, &data)
                .unwrap();
            (out, session.dataset_points(&data))
        };
        let (out, points) = fit(1);
        // Counter-reported cost equals the brute-force objective under
        // the fit's own metric.
        let brute = total_cost_metric(&points, &out.medoids, metric);
        assert!(
            (out.cost - brute).abs() / brute.max(1.0) < 0.01,
            "{metric:?} d={}: counter {} vs brute {brute}",
            spec.dims,
            out.cost
        );
        // Medoids are data points of the right dimensionality.
        assert!(out.medoids.iter().all(|m| m.dims() == spec.dims));
        for m in &out.medoids {
            assert!(points.iter().any(|p| p == m), "{metric:?}: medoid not a data point");
        }
        // Thread counts change only the wall clock.
        let (out4, _) = fit(4);
        assert_eq!(out.medoids, out4.medoids, "{metric:?}: threads diverged");
        assert_eq!(out.cost, out4.cost);
        assert_eq!(out.sim_seconds, out4.sim_seconds);
        assert_eq!(out.dist_evals, out4.dist_evals);
        assert_eq!(out.labels, out4.labels);
    }
}

#[test]
fn scalable_seeding_end_to_end() {
    // kmedoids-scalable-mr (k-means||-style seeding) through the public
    // API: converges, recovers structure, and is deterministic.
    let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(256, 16));
    let spec = clean_spec(8_000, 5, 77);
    let fit = || {
        let mut session = session_with(5, be.clone(), 77);
        let data = session.ingest_spec("points", &spec);
        KMedoids::mapreduce()
            .oversample(10, 4)
            .k(5)
            .seed(77)
            .update(UpdateStrategy::Exact)
            .with_labels()
            .build()
            .fit(&mut session, &data)
            .unwrap()
    };
    let out = fit();
    let truth = generate(&spec).truth;
    let ari = adjusted_rand_index(out.labels.as_ref().unwrap(), &truth);
    assert!(ari > 0.85, "ARI {ari} (scalable seeding)");
    assert_eq!(out.medoids, fit().medoids, "deterministic");
}

#[test]
fn all_algorithms_share_one_session_with_observers() {
    let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(256, 16));
    let mut session = session_with(4, be, 33);
    let data = session.ingest_spec("points", &clean_spec(5_000, 4, 33));
    let log = IterationLog::new();
    session.add_observer(Box::new(log.clone()));

    let solvers: Vec<Box<dyn SpatialClusterer>> = vec![
        Box::new(KMedoids::mapreduce().plus_plus().k(4).seed(33).build()),
        Box::new(KMedoids::mapreduce().random_init().k(4).seed(33).build()),
        Box::new(KMedoids::serial().k(4).seed(33).build()),
        Box::new(Clarans::serial().k(4).seed(33).build()),
        Box::new(KMeans::mapreduce().k(4).seed(33).build()),
    ];
    let mut total_events = 0usize;
    for solver in &solvers {
        let before = log.len();
        let out = solver.fit(&mut session, &data).unwrap();
        let events = log.len() - before;
        assert_eq!(events, out.iterations, "{}: one event per iteration", solver.name());
        assert!(out.cost > 0.0, "{}", solver.name());
        assert_eq!(out.medoids.len(), 4, "{}", solver.name());
        total_events += events;
    }
    assert_eq!(log.len(), total_events);
    // The stream carries each solver's name.
    let names: Vec<&str> = log.events().iter().map(|e| e.algorithm).collect();
    for expect in ["kmedoids++-mr", "kmedoids-mr", "kmedoids-serial", "clarans", "kmeans-mr"] {
        assert!(names.contains(&expect), "missing events for {expect}");
    }
}

#[test]
fn file_ingest_binary_csv_and_generator_fit_identically() {
    // The same points driven through all three ingest doors — in-memory
    // generation, a CSV file, a binary dataset file — must produce
    // byte-identical fits: same medoids, same labels, same cost bits,
    // same eval counts. This is the contract the CI file-ingest step
    // re-checks end-to-end through the CLI.
    use kmedoids_mr::geo::binfmt;
    use kmedoids_mr::geo::io::write_csv;
    use kmedoids_mr::util::json::{obj, Json};
    use kmedoids_mr::util::tempdir::TempDir;

    let spec = clean_spec(4_000, 5, 11);
    let tmp = TempDir::new("file-ingest-identity");
    let points = generate(&spec).points;
    let csv = tmp.join("pts.csv");
    let bin = tmp.join("pts.bin");
    write_csv(&csv, &points).unwrap();
    binfmt::write_file(&bin, &points, None).unwrap();

    let be = || -> Arc<dyn ComputeBackend> { Arc::new(NativeBackend::new(512, 16)) };
    let solver = || {
        KMedoids::mapreduce()
            .plus_plus()
            .k(5)
            .seed(11)
            .update(UpdateStrategy::Exact)
            .with_labels()
            .build()
    };

    let mut s_gen = session_with(5, be(), 11);
    let d_gen = s_gen.ingest_spec("points", &spec);
    let out_gen = solver().fit(&mut s_gen, &d_gen).unwrap();

    let mut s_csv = session_with(5, be(), 11);
    let d_csv = s_csv.ingest_file("points", &csv).unwrap();
    let out_csv = solver().fit(&mut s_csv, &d_csv).unwrap();

    let mut s_bin = session_with(5, be(), 11);
    let d_bin = s_bin.ingest_file("points", &bin).unwrap();
    let out_bin = solver().fit(&mut s_bin, &d_bin).unwrap();

    for (tag, out) in [("csv", &out_csv), ("binary", &out_bin)] {
        assert_eq!(out.medoids, out_gen.medoids, "{tag}: medoids diverged");
        assert_eq!(out.labels, out_gen.labels, "{tag}: labels diverged");
        assert_eq!(out.cost.to_bits(), out_gen.cost.to_bits(), "{tag}: cost bits diverged");
        assert_eq!(out.iterations, out_gen.iterations, "{tag}: iteration count diverged");
        assert_eq!(out.dist_evals, out_gen.dist_evals, "{tag}: eval count diverged");
    }

    // The manifest workflow closes over both formats: emit, then verify
    // against the bytes on disk.
    let prov = || obj(vec![("source", Json::Str("integration test".into()))]);
    for path in [&csv, &bin] {
        binfmt::emit_manifest("pts", path, prov()).unwrap();
        let m = binfmt::verify_manifest(path).unwrap();
        assert_eq!(m.count, 4_000);
        assert_eq!(m.dims, 2);
    }
}
