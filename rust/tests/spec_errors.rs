//! Regression: run-spec parse failures are typed [`SpecError`]s naming
//! the offending key. The fixture pins a catalogue of broken specs; a
//! decoder refactor that loses the type or misattributes the key fails
//! here, not in a user's tooling.

use kmedoids_mr::driver::spec::{experiments_from_str, SpecError};
use kmedoids_mr::util::json::Json;

#[test]
fn bad_spec_fixture_yields_typed_keyed_errors() {
    let src = include_str!("fixtures/bad_spec.json");
    let cases = Json::parse(src).expect("fixture must be valid JSON");
    let cases = cases.as_arr().expect("fixture is an array of cases");
    assert!(cases.len() >= 20, "the catalogue should stay comprehensive");
    for case in cases {
        let expect =
            case.get("expect_key").and_then(|k| k.as_str()).expect("case needs expect_key");
        let cell = case.get("cell").expect("case needs cell");
        let err = experiments_from_str(&cell.to_string())
            .expect_err(&format!("cell must be rejected: {cell}"));
        let spec_err = err
            .downcast_ref::<SpecError>()
            .unwrap_or_else(|| panic!("not a typed SpecError for {cell}: {err:#}"));
        assert_eq!(spec_err.key(), expect, "wrong key for {cell}: {spec_err}");
        // Every rendered message names its key — the greppable contract
        // the typed form exists to guarantee.
        assert!(
            spec_err.to_string().contains(expect),
            "message must name the key: {spec_err}"
        );
    }
}

#[test]
fn good_cells_in_the_same_shapes_still_parse() {
    // The fixture's cases are minimal mutations of valid cells; make
    // sure the unmutated shapes parse, so the catalogue can't silently
    // pass by rejecting everything.
    for good in [
        r#"{"algorithm": "clarans", "dataset": {"n_points": 10}}"#,
        r#"{"dataset": {"paper_dataset": 2, "scale_div": 100}}"#,
        r#"{"update": {"kind": "sampled", "candidates": 8, "member_sample": 64},
            "dataset": {"n_points": 10}}"#,
        r#"{"algorithm": "kmedoids-scalable-mr", "oversample": {"l": 18, "rounds": 5},
            "dataset": {"n_points": 10}}"#,
        r#"{"lane": "spark", "dataset": {"n_points": 10}}"#,
        r#"{"lane": "hadoop-mr", "max_attempts": 6, "dataset": {"n_points": 10}}"#,
    ] {
        experiments_from_str(good).unwrap_or_else(|e| panic!("should parse {good}: {e:#}"));
    }
}
