//! Cross-algorithm conformance harness: one declarative matrix runs
//! **every** [`Algorithm`] against the same oracles, over every supported
//! `(Metric, dims)` combination at two compute-thread widths, and asserts
//! three contracts per cell:
//!
//! (a) **Thread identity** — medoids, cost, iterations, simulated time,
//!     distance evaluations, and labels are byte-identical at 1 and 4
//!     compute threads (the worker pool only changes wall clock).
//! (b) **Cost** — the brute-force oracle cost of the fitted medoids
//!     ([`total_cost_metric`]) is within the algorithm's *declared
//!     factor* of the best oracle cost any algorithm achieved in the
//!     cell, and the algorithm's *reported* cost agrees with the oracle
//!     cost of its own medoids.
//! (c) **Labels** — when a fit emits labels, every point's assigned
//!     medoid is as near as the brute-force label's medoid
//!     ([`brute_labels_metric`]), up to f32-kernel tie tolerance.
//! (d) **ARI floor** — the Adjusted Rand Index of the fitted medoids'
//!     brute-force labels against the generator's ground truth clears
//!     the row's declared floor. The floors are deliberately loose
//!     breakage bounds, not quality targets (K < hotspots caps the
//!     achievable ARI by construction; a broken kernel scores ~0).
//! (e) **Serving identity** — a [`ClusterModel`] published from the
//!     fit's medoids answers `assign`/`assign_batch` byte-identically
//!     to a fresh batch assign pass over the same medoids.
//! (f) **Pruned-lane identity** — the default fit (`PruningMode::Auto`
//!     resolves to the pruned triangle-inequality lane here: no
//!     durability) matches a dense-forced (`PruningMode::Off`) twin on
//!     medoids, cost bits, iteration count, and labels, while never
//!     evaluating more distances. Cost bits seal the per-point f32
//!     min-distances: the lanes fold them block-by-block in the same
//!     order, so any mindist bit flip lands in the cost bits.
//! (g) **Execution-lane identity** — for the MR-engine algorithms, an
//!     in-memory-DAG-lane twin of the default Hadoop-lane fit matches
//!     on medoids, cost bits, iteration count, labels, and exact
//!     distance-eval counts, while finishing strictly cheaper on
//!     simulated time (the DAG lane drops JVM launch, input re-parse,
//!     and shuffle-spill costs — never compute).
//!
//! Adding an algorithm = adding one row to [`MATRIX`] (the coreset
//! pipeline entered exactly that way). The declared factors document
//! expected quality: seeded variants (++ / scalable / coreset / kmeans)
//! are tight; random-init variants are deliberately loose because a
//! random draw can deterministically land in a merged-cluster local
//! optimum — the harness still catches kernel/pipeline breakage, which
//! shows up orders of magnitude beyond any local optimum.
//!
//! CI runs the smoke subset (dims 2 and 3) on every PR; the full matrix
//! (dims 8 included) runs under `CONFORMANCE_FULL=1` via the manual
//! workflow-dispatch job.

use kmedoids_mr::clustering::metrics::{
    adjusted_rand_index, brute_labels_metric, total_cost_metric,
};
use kmedoids_mr::driver::{Algorithm, Experiment};
use kmedoids_mr::mapreduce::Lane;
use kmedoids_mr::prelude::*;
use kmedoids_mr::runtime::assign_points;
use std::sync::Arc;

/// One row of the conformance matrix: an algorithm plus its declared
/// worst-case factor over the best oracle cost in the cell and its
/// ground-truth ARI floor.
struct Row {
    algorithm: Algorithm,
    cost_factor: f64,
    /// Minimum Adjusted Rand Index vs. generator truth. With K=4 over 8
    /// hotspots the *ceiling* for a clean pairwise merge is ~0.6, so
    /// these floors are breakage detectors (broken kernels score ~0),
    /// calibrated loose like `cost_factor`, not quality targets.
    ari_floor: f64,
}

/// The declarative matrix — every algorithm must have a row.
const MATRIX: &[Row] = &[
    Row { algorithm: Algorithm::KMedoidsPlusPlusMR, cost_factor: 3.0, ari_floor: 0.2 },
    Row { algorithm: Algorithm::KMedoidsScalableMR, cost_factor: 3.0, ari_floor: 0.2 },
    Row { algorithm: Algorithm::KMedoidsCoresetMR, cost_factor: 3.0, ari_floor: 0.2 },
    Row { algorithm: Algorithm::KMeansMR, cost_factor: 3.0, ari_floor: 0.2 },
    Row { algorithm: Algorithm::Clarans, cost_factor: 6.0, ari_floor: 0.15 },
    // Random-init variants: a random draw can land in a worse basin
    // deterministically; the looser bounds still reject broken kernels
    // (which miss by orders of magnitude on cost and sit at ~0 ARI).
    Row { algorithm: Algorithm::KMedoidsRandomMR, cost_factor: 8.0, ari_floor: 0.05 },
    Row { algorithm: Algorithm::KMedoidsSerial, cost_factor: 8.0, ari_floor: 0.05 },
];

/// Full matrix (dims 8) only under `CONFORMANCE_FULL=1` — the PR smoke
/// subset keeps tier-1 fast.
fn full_matrix() -> bool {
    std::env::var("CONFORMANCE_FULL").map_or(false, |v| !v.is_empty() && v != "0")
}

fn planar_dims() -> Vec<usize> {
    if full_matrix() {
        vec![2, 3, 8]
    } else {
        vec![2, 3]
    }
}

const THREADS: [usize; 2] = [1, 4];
const N: usize = 800;
const K: usize = 4;
/// More hotspots than k flattens the local-optimum landscape, so the
/// declared factors stay meaningful for the random-init variants too
/// (with hotspots == k a random draw that merges two blobs would be an
/// arbitrarily deep basin, forcing useless factors).
const HOTSPOTS: usize = 2 * K;

/// Everything one fit contributes to the cell's cross-checks.
struct Fit {
    medoids: Vec<Point>,
    cost: f64,
    iterations: usize,
    sim_seconds: f64,
    dist_evals: u64,
    labels: Option<Vec<u32>>,
}

#[allow(clippy::too_many_arguments)]
fn fit_once(
    algorithm: Algorithm,
    dataset: &SpatialDataset,
    spec: &SpatialSpec,
    metric: Metric,
    threads: usize,
    seed: u64,
    pruning: PruningMode,
    lane: Lane,
) -> Fit {
    let mut session =
        ClusterSession::builder().test(4).seed(seed).threads(threads).build().unwrap();
    let data = session.ingest("pts", dataset);
    let mut exp = Experiment::paper_cell(algorithm, 4, 0, seed);
    exp.spec = spec.clone();
    exp.k = K;
    exp.metric = metric;
    exp.update = UpdateStrategy::Exact;
    exp.pruning = pruning;
    exp.lane = lane;
    exp.with_quality = true; // label_pass where the solver supports it
    let out = exp
        .clusterer()
        .fit(&mut session, &data)
        .unwrap_or_else(|e| panic!("{} failed under {metric:?}: {e:#}", algorithm.name()));
    Fit {
        medoids: out.medoids,
        cost: out.cost,
        iterations: out.iterations,
        sim_seconds: out.sim_seconds,
        dist_evals: out.dist_evals,
        labels: out.labels,
    }
}

/// Run the full matrix for one `(metric, spec)` cell and enforce the
/// three contracts.
fn run_cell_matrix(metric: Metric, spec: &SpatialSpec) {
    assert_eq!(MATRIX.len(), Algorithm::ALL.len(), "every algorithm needs a matrix row");
    let seed = 0x5EED ^ spec.dims as u64 ^ ((metric as u64) << 8);
    let mut spec = spec.clone();
    spec.seed = seed;
    spec.outlier_frac = 0.0;
    let dataset = generate(&spec);
    let points = &dataset.points;
    let cell = format!("{} d={}", metric.name(), spec.dims);

    let mut oracle_costs: Vec<(Algorithm, f64, f64)> = Vec::new();
    for row in MATRIX {
        // (a) identity across compute-thread widths.
        let base = fit_once(
            row.algorithm,
            &dataset,
            &spec,
            metric,
            THREADS[0],
            seed,
            PruningMode::Auto,
            Lane::HadoopMr,
        );
        for &t in &THREADS[1..] {
            let other = fit_once(
                row.algorithm,
                &dataset,
                &spec,
                metric,
                t,
                seed,
                PruningMode::Auto,
                Lane::HadoopMr,
            );
            let name = row.algorithm.name();
            assert_eq!(base.medoids, other.medoids, "[{cell}] {name}: medoids diverged at t={t}");
            assert_eq!(base.cost, other.cost, "[{cell}] {name}: cost diverged at t={t}");
            assert_eq!(
                base.iterations, other.iterations,
                "[{cell}] {name}: iterations diverged at t={t}"
            );
            assert_eq!(
                base.sim_seconds, other.sim_seconds,
                "[{cell}] {name}: sim clock diverged at t={t}"
            );
            assert_eq!(
                base.dist_evals, other.dist_evals,
                "[{cell}] {name}: dist evals diverged at t={t}"
            );
            assert_eq!(base.labels, other.labels, "[{cell}] {name}: labels diverged at t={t}");
        }

        // (f) pruned vs dense lane byte-identity. `base` already runs the
        // pruned lane (Auto, no durability); the Off twin forces the dense
        // kernels. The lanes must agree exactly — and pruning must never
        // add evaluations. (sim clock and eval counts legitimately differ:
        // skipped work is skipped simulated work.)
        let dense = fit_once(
            row.algorithm,
            &dataset,
            &spec,
            metric,
            THREADS[0],
            seed,
            PruningMode::Off,
            Lane::HadoopMr,
        );
        let name = row.algorithm.name();
        assert_eq!(base.medoids, dense.medoids, "[{cell}] {name}: pruned medoids diverged");
        assert_eq!(
            base.cost.to_bits(),
            dense.cost.to_bits(),
            "[{cell}] {name}: pruned cost bits diverged ({} vs {})",
            base.cost,
            dense.cost
        );
        assert_eq!(
            base.iterations, dense.iterations,
            "[{cell}] {name}: pruned iteration count diverged"
        );
        assert_eq!(base.labels, dense.labels, "[{cell}] {name}: pruned labels diverged");
        assert!(
            base.dist_evals <= dense.dist_evals,
            "[{cell}] {name}: pruned lane evaluated MORE distances ({} vs {})",
            base.dist_evals,
            dense.dist_evals
        );

        // (g) execution-lane identity: the DAG lane reuses the exact
        // map/reduce compute functions, so for every MR-engine
        // algorithm an in-memory-DAG twin must match the Hadoop-lane
        // fit byte-for-byte — and finish strictly cheaper on simulated
        // time (no JVM launch, no input re-parse, push shuffle). The
        // serial engines never submit jobs and refuse lane overrides.
        let uses_lane = matches!(
            row.algorithm,
            Algorithm::KMedoidsPlusPlusMR
                | Algorithm::KMedoidsRandomMR
                | Algorithm::KMedoidsScalableMR
                | Algorithm::KMedoidsCoresetMR
                | Algorithm::KMeansMR
        );
        if uses_lane {
            let dag = fit_once(
                row.algorithm,
                &dataset,
                &spec,
                metric,
                THREADS[0],
                seed,
                PruningMode::Auto,
                Lane::InMemoryDag,
            );
            assert_eq!(base.medoids, dag.medoids, "[{cell}] {name}: dag medoids diverged");
            assert_eq!(
                base.cost.to_bits(),
                dag.cost.to_bits(),
                "[{cell}] {name}: dag cost bits diverged ({} vs {})",
                base.cost,
                dag.cost
            );
            assert_eq!(
                base.iterations, dag.iterations,
                "[{cell}] {name}: dag iteration count diverged"
            );
            assert_eq!(base.labels, dag.labels, "[{cell}] {name}: dag labels diverged");
            assert_eq!(
                base.dist_evals, dag.dist_evals,
                "[{cell}] {name}: dag dist evals diverged"
            );
            assert!(
                dag.sim_seconds < base.sim_seconds,
                "[{cell}] {name}: dag lane not strictly cheaper ({} vs {})",
                dag.sim_seconds,
                base.sim_seconds
            );
        }

        // (b) reported cost agrees with the oracle cost of its own medoids.
        assert_eq!(base.medoids.len(), K, "[{cell}] {}", row.algorithm.name());
        let oracle = total_cost_metric(points, &base.medoids, metric);
        assert!(
            (base.cost - oracle).abs() <= 0.05 * oracle.max(1.0),
            "[{cell}] {}: reported cost {} vs oracle {oracle}",
            row.algorithm.name(),
            base.cost
        );

        // (c) labels consistent with the brute-force oracle, up to
        // f32-kernel near-ties (compare by distance, not index). The
        // absolute slack is metric-scaled: the squared-Euclidean fast
        // path's expanded-norm form can mis-rank medoids whose squared
        // distances differ by ~1e-6 of the coordinate magnitude squared.
        let slack = match metric {
            Metric::SqEuclidean => 100.0, // coords ±1e4 -> d² up to ~1e8
            Metric::Manhattan => 0.1,
            Metric::Haversine => 1.0, // km; f32 trig error ~0.5 km
        };
        if let Some(labels) = &base.labels {
            assert_eq!(labels.len(), points.len());
            let brute = brute_labels_metric(points, &base.medoids, metric);
            for (i, (&got, &want)) in labels.iter().zip(&brute).enumerate() {
                let got_d = metric.distance(&points[i], &base.medoids[got as usize]);
                let want_d = metric.distance(&points[i], &base.medoids[want as usize]);
                assert!(
                    got_d <= want_d * 1.001 + slack,
                    "[{cell}] {}: point {i} labeled {got} (d {got_d}) vs brute {want} (d {want_d})",
                    row.algorithm.name()
                );
            }
        }
        // (d) ARI floor vs. generator truth, on the fitted medoids'
        // brute-force labels (uniform across algorithms whether or not
        // the fit emitted its own label pass).
        let brute = brute_labels_metric(points, &base.medoids, metric);
        let ari = adjusted_rand_index(&brute, &dataset.truth);
        assert!(
            ari >= row.ari_floor,
            "[{cell}] {}: ARI {ari:.3} below declared floor {}",
            row.algorithm.name(),
            row.ari_floor
        );

        // (e) serving identity: a model published from this fit answers
        // byte-identically to a fresh batch assign pass over the same
        // medoids — labels AND f32 mindists, single-point and batched.
        // (Compared against a fresh pass rather than `base.labels`:
        // iterative PAM exits can leave fit labels one medoid-update
        // stale, which contract (c) already tolerates by distance.)
        let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(256, 16));
        let model = ClusterModel::new(be.clone(), base.medoids.clone(), metric);
        let (mlabels, mdists) = model.assign_batch(points.as_slice());
        let oracle_assign =
            assign_points(be.as_ref(), points, &base.medoids, metric).expect("assign pass");
        assert_eq!(
            mlabels,
            oracle_assign.labels,
            "[{cell}] {}: serve labels diverged from the batch assign pass",
            row.algorithm.name()
        );
        assert_eq!(mdists.len(), oracle_assign.mindists.len());
        for (i, (a, b)) in mdists.iter().zip(&oracle_assign.mindists).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "[{cell}] {}: serve mindist {i} not bitwise-identical",
                row.algorithm.name()
            );
        }
        for i in (0..points.len()).step_by(97) {
            let (l, d) = model.assign(&points[i]);
            assert_eq!(
                (l, d.to_bits()),
                (mlabels[i], mdists[i].to_bits()),
                "[{cell}] {}: single-point assign diverged from batch at {i}",
                row.algorithm.name()
            );
        }

        oracle_costs.push((row.algorithm, oracle, row.cost_factor));
    }

    // (b) every algorithm within its declared factor of the best oracle
    // cost any of them achieved in this cell.
    let best = oracle_costs.iter().map(|&(_, c, _)| c).fold(f64::INFINITY, f64::min);
    assert!(best.is_finite() && best > 0.0, "[{cell}] degenerate best cost {best}");
    for (algorithm, cost, factor) in oracle_costs {
        assert!(
            cost <= best * factor,
            "[{cell}] {}: oracle cost {cost} exceeds {factor}x best {best}",
            algorithm.name()
        );
    }
}

#[test]
fn conformance_sq_euclidean() {
    for dims in planar_dims() {
        run_cell_matrix(Metric::SqEuclidean, &SpatialSpec::new(N, HOTSPOTS, 1).with_dims(dims));
    }
}

#[test]
fn conformance_manhattan() {
    for dims in planar_dims() {
        run_cell_matrix(Metric::Manhattan, &SpatialSpec::new(N, HOTSPOTS, 1).with_dims(dims));
    }
}

#[test]
fn conformance_haversine() {
    // Haversine is dims-2 only, over (lat, lon) city clouds.
    run_cell_matrix(Metric::Haversine, &SpatialSpec::latlon(N, HOTSPOTS, 1));
}

#[test]
fn matrix_covers_every_algorithm_exactly_once() {
    assert_eq!(MATRIX.len(), Algorithm::ALL.len());
    for a in Algorithm::ALL {
        let rows = MATRIX.iter().filter(|r| r.algorithm == a).count();
        assert_eq!(rows, 1, "{} must have exactly one matrix row", a.name());
    }
    // Declared factors are sane (>= 1; the harness is a ceiling, not a
    // target), and ARI floors sit strictly below the ~0.6 construction
    // ceiling so they stay breakage bounds.
    assert!(MATRIX.iter().all(|r| r.cost_factor >= 1.0));
    assert!(MATRIX.iter().all(|r| r.ari_floor > 0.0 && r.ari_floor < 0.6));
}

/// The coreset pipeline's headline property, checked inside the shared
/// harness context: at equal k it runs strictly fewer MR jobs than the
/// iterative random-init driver on the same ingested data.
#[test]
fn coreset_runs_fewer_jobs_than_iterative_mr_in_harness_setup() {
    let mut spec = SpatialSpec::new(N, HOTSPOTS, 7);
    spec.outlier_frac = 0.0;
    let dataset = generate(&spec);
    let jobs_of = |algorithm: Algorithm| {
        let mut session = ClusterSession::builder().test(4).seed(7).build().unwrap();
        let data = session.ingest("pts", &dataset);
        let mut exp = Experiment::paper_cell(algorithm, 4, 0, 7);
        exp.spec = spec.clone();
        exp.k = K;
        exp.update = UpdateStrategy::Exact;
        // Pinned iterations (as in `bench scale`): the comparison must
        // not hinge on convergence luck.
        exp.fixed_iters = Some(4);
        exp.clusterer().fit(&mut session, &data).unwrap();
        session.jobs_run()
    };
    let coreset = jobs_of(Algorithm::KMedoidsCoresetMR);
    let iterative = jobs_of(Algorithm::KMedoidsRandomMR);
    assert_eq!(coreset, 2, "coreset merge job + exact cost pass");
    assert!(coreset < iterative, "coreset {coreset} jobs vs kmedoids-mr {iterative}");
}

/// The pruned lane's headline property (the same floor `bench perf`
/// gates in CI): on clustered data the cached triangle-inequality bounds
/// cut the exact distance-eval count at least 3x, with byte-identical
/// output. Iterations are pinned and the centroid-nearest update keeps
/// the reduce side cheap, so the assignment passes — the lane under
/// test — dominate the count.
#[test]
fn pruned_lane_cuts_dist_evals_at_least_3x_on_clustered_data() {
    let mut spec = SpatialSpec::new(4_000, 9, 11);
    spec.outlier_frac = 0.0;
    let dataset = generate(&spec);
    let fit_lane = |mode: PruningMode| {
        let mut session = ClusterSession::builder().test(4).seed(11).build().unwrap();
        let data = session.ingest("pts", &dataset);
        let mut exp = Experiment::paper_cell(Algorithm::KMedoidsPlusPlusMR, 4, 0, 11);
        exp.spec = spec.clone();
        exp.k = 12;
        exp.update = UpdateStrategy::CentroidNearest;
        exp.fixed_iters = Some(8);
        exp.with_quality = true;
        exp.pruning = mode;
        exp.clusterer().fit(&mut session, &data).unwrap()
    };
    let dense = fit_lane(PruningMode::Off);
    let pruned = fit_lane(PruningMode::On);
    assert_eq!(pruned.medoids, dense.medoids, "pruned medoids diverged");
    assert_eq!(pruned.cost.to_bits(), dense.cost.to_bits(), "pruned cost bits diverged");
    assert_eq!(pruned.labels, dense.labels, "pruned labels diverged");
    let reduction = dense.dist_evals as f64 / pruned.dist_evals.max(1) as f64;
    assert!(
        reduction >= 3.0,
        "dense {} vs pruned {} evals: {reduction:.2}x reduction below the 3x floor",
        dense.dist_evals,
        pruned.dist_evals
    );
}
