//! Bench: Fig. 4 — speedup curves for the three datasets, including the
//! paper's qualitative claim that *larger datasets speed up better*.
//!
//! Shares the Table 6 grid (same cells), then derives speedups relative
//! to the 4-node cluster and checks the Fig. 4 shapes.

use kmedoids_mr::driver::suites::{table6_suite, SuiteOpts};
use kmedoids_mr::report;
use kmedoids_mr::runtime::{load_backend, BackendKind};

fn main() {
    let scale: usize =
        std::env::var("KMR_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1);
    let kind = std::env::var("KMR_BENCH_BACKEND")
        .ok()
        .and_then(|s| BackendKind::parse(&s))
        .unwrap_or(BackendKind::Native);
    let backend = load_backend(kind, 2048).expect("backend");
    println!("== Fig 4: speedup (scale 1/{scale}, backend {}) ==", backend.name());
    let trace =
        std::env::var("KMR_TRACE").map_or(false, |v| !matches!(v.as_str(), "" | "0" | "false"));
    let opts = SuiteOpts::new(scale, 42).with_trace(trace);
    let results = table6_suite(&backend, &opts);
    println!("\n{}", report::fig4_speedup(&results));

    // Shape checks: speedup >= 1 at every size, below linear, and the
    // biggest dataset's 7-node speedup is at least the smallest's.
    let mut datasets: Vec<usize> = results.iter().map(|r| r.n_points).collect();
    datasets.sort_unstable();
    datasets.dedup();
    let speedup = |ds: usize, n: usize| -> f64 {
        let base = results.iter().find(|r| r.n_points == ds && r.n_nodes == 4).unwrap();
        let cur = results.iter().find(|r| r.n_points == ds && r.n_nodes == n).unwrap();
        base.time_ms as f64 / cur.time_ms as f64
    };
    let mut ok = true;
    for &ds in &datasets {
        for n in 4..=7 {
            let s = speedup(ds, n);
            if s < 0.999 || s > n as f64 / 4.0 + 0.25 {
                println!("SHAPE VIOLATION: speedup({ds}, {n}) = {s:.2}");
                ok = false;
            }
        }
    }
    let s_small = speedup(datasets[0], 7);
    let s_big = speedup(datasets[2], 7);
    println!(
        "7-node speedup: smallest dataset {:.3}x, largest {:.3}x ({})",
        s_small,
        s_big,
        if s_big >= s_small * 0.95 {
            "larger scales at least as well — Fig 4 shape"
        } else {
            "UNEXPECTED"
        }
    );
    if s_big < s_small * 0.95 {
        ok = false;
    }
    println!("paper-shape check: {}", if ok { "PASS" } else { "FAIL" });
}
