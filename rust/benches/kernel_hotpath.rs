//! Bench: the L1/L2 hot path — PJRT (AOT Pallas/JAX artifact) vs the
//! native Rust oracle on the two block kernels, plus the end-to-end
//! assignment throughput the mapper sees.
//!
//! This is the §Perf microbenchmark: distance-evaluations per second per
//! backend, block-size sensitivity, and executor lock overhead.

use kmedoids_mr::geo::{Metric, Point};
use kmedoids_mr::runtime::{
    assign_points, default_artifacts_dir, pairwise_costs, ComputeBackend, Manifest, NativeBackend,
    PjrtBackend,
};
use kmedoids_mr::util::bench::{bench, fmt_rate, header, BenchOpts};
use kmedoids_mr::util::rng::Rng;

fn mk_points(n: usize, seed: u64) -> Vec<Point> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| Point::new((rng.f64() * 2e4 - 1e4) as f32, (rng.f64() * 2e4 - 1e4) as f32))
        .collect()
}

fn bench_backend(name: &str, be: &dyn ComputeBackend, n: usize, k: usize) {
    let points = mk_points(n, 1);
    let medoids = mk_points(k, 2);
    let opts = BenchOpts { warmup_iters: 1, iters: 5 };
    let s = bench(&format!("{name}: assign {n} pts x {k} medoids"), &opts, || {
        assign_points(be, &points, &medoids, Metric::SqEuclidean).unwrap().labels.len()
    });
    println!(
        "    -> {} dist-evals/s (block={})",
        fmt_rate((n * k) as f64, s.median_s),
        be.block()
    );

    let cands = mk_points(1024, 3);
    let members = mk_points(16 * 1024, 4);
    let s = bench(&format!("{name}: pairwise 1024 cands x 16k members"), &opts, || {
        pairwise_costs(be, &cands, &members, Metric::SqEuclidean).unwrap().len()
    });
    println!("    -> {} dist-evals/s", fmt_rate((1024 * 16 * 1024) as f64, s.median_s));
}

fn main() {
    let n: usize =
        std::env::var("KMR_BENCH_N").ok().and_then(|s| s.parse().ok()).unwrap_or(262_144);
    let k = 9;
    header("kernel hot path: native vs PJRT (AOT Pallas/JAX)");

    let native = NativeBackend::new(2048, 64);
    bench_backend("native/b2048", &native, n, k);

    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        let manifest = Manifest::load(&dir).expect("manifest");
        let pjrt = PjrtBackend::load(&manifest, 2048).expect("pjrt backend");
        bench_backend("pjrt/b2048", &pjrt, n, k);
        let pjrt_small = PjrtBackend::load(&manifest, 256).expect("pjrt small");
        bench_backend("pjrt/b256", &pjrt_small, n.min(32_768), k);
    } else {
        println!("(artifacts not built; PJRT benches skipped — run `make artifacts`)");
    }

    // Native block-size sensitivity (structure mirror of the Pallas tile
    // sweep in python).
    header("native block-size sweep");
    for b in [256usize, 1024, 2048, 8192] {
        let be = NativeBackend::new(b, 64);
        let points = mk_points(n, 1);
        let medoids = mk_points(k, 2);
        let s = bench(
            &format!("native/b{b}: assign {n} pts"),
            &BenchOpts { warmup_iters: 1, iters: 3 },
            || assign_points(&be, &points, &medoids, Metric::SqEuclidean).unwrap().labels.len(),
        );
        println!("    -> {}", fmt_rate((n * k) as f64, s.median_s));
    }

    // Generic metric path: d-dim Manhattan through the unrolled kernel
    // (no norm-trick SoA staging — tracks the non-Euclidean throughput).
    header("generic kernel path (d=3, manhattan)");
    let be = NativeBackend::new(2048, 64);
    let points3 = mk_points_d(n, 1, 3);
    let medoids3 = mk_points_d(k, 2, 3);
    let s = bench(
        &format!("native/b2048: assign {n} pts [d=3 manhattan]"),
        &BenchOpts { warmup_iters: 1, iters: 3 },
        || assign_points(&be, &points3, &medoids3, Metric::Manhattan).unwrap().labels.len(),
    );
    println!("    -> {}", fmt_rate((n * k) as f64, s.median_s));
}

fn mk_points_d(n: usize, seed: u64, dims: usize) -> Vec<Point> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let coords: Vec<f32> = (0..dims).map(|_| (rng.f64() * 2e4 - 1e4) as f32).collect();
            Point::from_slice(&coords)
        })
        .collect()
}
