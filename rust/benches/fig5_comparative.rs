//! Bench: Fig. 5 — comparative execution time of the proposed parallel
//! K-Medoids++ against traditional K-Medoids and CLARANS across the three
//! dataset sizes, plus the §3.1 seeding ablation.

use kmedoids_mr::driver::suites::{ablation_suite, fig5_suite, SuiteOpts};
use kmedoids_mr::report;
use kmedoids_mr::runtime::{load_backend, BackendKind};

fn main() {
    let scale: usize =
        std::env::var("KMR_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1);
    let kind = std::env::var("KMR_BENCH_BACKEND")
        .ok()
        .and_then(|s| BackendKind::parse(&s))
        .unwrap_or(BackendKind::Native);
    let backend = load_backend(kind, 2048).expect("backend");
    println!("== Fig 5: comparative algorithms (scale 1/{scale}, backend {}) ==", backend.name());
    let trace =
        std::env::var("KMR_TRACE").map_or(false, |v| !matches!(v.as_str(), "" | "0" | "false"));
    let opts = SuiteOpts::new(scale, 42).with_trace(trace);
    let results = fig5_suite(&backend, &opts);
    println!("\n{}", report::fig5_comparative(&results));
    println!("CSV:\n{}", report::to_csv(&results));

    // Shape: proposed <= traditional <= clarans at every dataset size,
    // with the gap widening as data grows.
    let mut datasets: Vec<usize> = results.iter().map(|r| r.n_points).collect();
    datasets.sort_unstable();
    datasets.dedup();
    let t = |algo: &str, ds: usize| -> u64 {
        results.iter().find(|r| r.algorithm == algo && r.n_points == ds).unwrap().time_ms
    };
    let mut ok = true;
    for &ds in &datasets {
        let pp = t("kmedoids++-mr", ds);
        let trad = t("kmedoids-serial", ds);
        let cl = t("clarans", ds);
        println!("n={ds}: kmedoids++ {pp}ms | traditional {trad}ms | clarans {cl}ms");
        if !(pp <= trad && trad <= cl) {
            println!("SHAPE VIOLATION at n={ds}");
            ok = false;
        }
    }
    println!("\n== §3.1 ablation: seeding and update strategies (dataset 1) ==\n");
    let ab = ablation_suite(&backend, &opts);
    println!("{:<18}{:>8}{:>12}{:>16}", "variant", "iters", "time(ms)", "cost");
    for r in &ab {
        println!("{:<18}{:>8}{:>12}{:>16.4e}", r.algorithm, r.iterations, r.time_ms, r.cost);
    }
    if ab[0].iterations > ab[1].iterations {
        println!("SHAPE VIOLATION: ++ seeding used more iterations than random init");
        ok = false;
    }
    println!("paper-shape check: {}", if ok { "PASS" } else { "FAIL" });
}
