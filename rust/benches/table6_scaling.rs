//! Bench: Table 6 / Fig. 3 — execution time of parallel K-Medoids++ over
//! 4–7 node clusters × the three Table 5 datasets.
//!
//! The reported quantity is the *simulated* execution time (ms) on the
//! Table 3 cluster — the paper's metric. Wallclock of the real compute is
//! printed alongside. `KMR_SCALE` divides the dataset sizes (default 1 =
//! full Table 5 scale); `KMR_BENCH_BACKEND` picks the kernel path
//! (default native — simulated times are backend-independent, see
//! EXPERIMENTS.md §Method).

use kmedoids_mr::driver::suites::{table6_suite, SuiteOpts};
use kmedoids_mr::report;
use kmedoids_mr::runtime::{load_backend, BackendKind};

fn main() {
    let scale: usize =
        std::env::var("KMR_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1);
    let kind = std::env::var("KMR_BENCH_BACKEND")
        .ok()
        .and_then(|s| BackendKind::parse(&s))
        .unwrap_or(BackendKind::Native);
    let backend = load_backend(kind, 2048).expect("backend");
    println!(
        "== Table 6 / Fig 3: K-Medoids++ MR execution time (scale 1/{scale}, backend {}) ==",
        backend.name()
    );
    // KMR_TRACE=1 streams live per-iteration events from every cell.
    let trace =
        std::env::var("KMR_TRACE").map_or(false, |v| !matches!(v.as_str(), "" | "0" | "false"));
    let opts = SuiteOpts::new(scale, 42).with_trace(trace);
    let results = table6_suite(&backend, &opts);
    println!("\nTable 6 — execution time (ms):\n\n{}", report::table6(&results));
    println!("Fig. 4 — speedup vs 4-node cluster:\n\n{}", report::fig4_speedup(&results));
    println!("CSV:\n{}", report::to_csv(&results));

    // Paper-shape checks (who wins, monotonicity).
    let mut ok = true;
    for ds in [results[0].n_points, results[4].n_points, results[8].n_points] {
        let times: Vec<u64> =
            results.iter().filter(|r| r.n_points == ds).map(|r| r.time_ms).collect();
        if !times.windows(2).all(|w| w[1] <= w[0]) {
            println!("SHAPE VIOLATION: time not monotone in nodes for dataset {ds}: {times:?}");
            ok = false;
        }
    }
    println!("paper-shape check: {}", if ok { "PASS" } else { "FAIL" });
}
