//! Versioned little-endian binary dataset format + content-addressed
//! manifests — the at-scale twin of the CSV interchange path.
//!
//! ## Why a binary format
//!
//! CSV parse cost is the declared scale ceiling for multi-million-point
//! runs (ROADMAP; ~65k rows/s in the cost model, real parse cost in wall
//! clock). This format stores the coordinate plane as raw little-endian
//! `f32`s so a reader can hand out the existing
//! [`PackedPoints`]/[`crate::geo::PointSource`] zero-copy views straight
//! off the file bytes via [`crate::util::codec::f32s_view`] — ingest
//! becomes a bounds-checked pointer cast plus a CRC pass, with an owned
//! decode fallback when the buffer is misaligned (or the target is
//! big-endian).
//!
//! ## Layout (`KMDS` version 1)
//!
//! All integers and floats little-endian. The header is exactly
//! [`HEADER_LEN`] = 32 bytes, so the payload starts 8-byte aligned
//! whenever the backing buffer is (every practical allocator) and the
//! zero-copy view applies:
//!
//! | offset | size | field |
//! |-------:|-----:|-------|
//! | 0      | 4    | magic [`MAGIC`] = `"KMDS"` |
//! | 4      | 4    | format version u32 = [`VERSION`] |
//! | 8      | 4    | dims u32 (1..=[`MAX_DIMS`]) |
//! | 12     | 8    | point count u64 |
//! | 20     | 4    | flags u32 ([`FLAG_WEIGHTS`] = weight plane present) |
//! | 24     | 4    | CRC-32 (IEEE) of the payload |
//! | 28     | 4    | reserved, must be 0 |
//! | 32     | …    | payload: `count·dims` coord f32s, then `count` weight f32s if flagged |
//!
//! The payload is exactly the engine's weighted-run wire layout
//! (`[coords][weights]`, see [`crate::util::codec`]), so
//! [`DatasetFile::packed`] is a direct [`PackedPoints`] construction
//! over the file bytes — no translation layer.
//!
//! ## Discipline
//!
//! Mirrors [`crate::persist::format`]/[`crate::persist::store`]: strict
//! decoding where truncation, a foreign magic, a future version, a CRC
//! mismatch, or structural garbage each yield their own typed
//! [`DatasetError`] variant (never a silent partial load), and writes go
//! tmp-file → `fsync` → rename so a crash mid-write can never leave a
//! half dataset under the final name. Non-finite coordinates are refused
//! on *both* sides with the same typed [`NonFiniteCoord`] as the CSV
//! path, and heterogeneous dims with the shared typed [`MixedDims`].
//!
//! ## Manifests
//!
//! Every dataset file gets a JSON [`Manifest`] sibling
//! (`<file>.manifest.json`): name, format, dims, count, weights flag,
//! CRC-32 checksum, and provenance (the generator spec or the source
//! file it was converted from). Bench artifacts embed the manifest
//! record, making every published number content-addressed: the
//! checksum in the artifact is verifiable against the dataset bytes
//! with [`verify_manifest`].

use super::io::{read_csv, MixedDims, NonFiniteCoord};
use super::{Point, MAX_DIMS};
use crate::persist::crc32;
use crate::util::codec::{floats_of, PackedPoints};
use crate::util::json::{obj, Json};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// First four bytes of every binary dataset file (`KMDS` = K-Medoids
/// DataSet; distinct from the checkpoint magic `KMDC`).
pub const MAGIC: [u8; 4] = *b"KMDS";

/// Format version this build reads and writes.
pub const VERSION: u32 = 1;

/// Fixed header size in bytes; the payload starts here, keeping the
/// coordinate plane 8-byte aligned relative to the buffer start.
pub const HEADER_LEN: usize = 32;

/// Header flag bit: a weight plane (`count` f32s) follows the
/// coordinate plane.
pub const FLAG_WEIGHTS: u32 = 1;

/// Suffix appended to a dataset path to name its manifest sibling.
pub const MANIFEST_SUFFIX: &str = ".manifest.json";

/// Format label recorded in manifests for binary datasets.
pub const FORMAT_BINARY: &str = "kmds-v1";

/// Format label recorded in manifests for CSV datasets.
pub const FORMAT_CSV: &str = "csv";

/// Typed failure modes of the binary dataset decoder, mirroring
/// [`crate::persist::PersistError`] variant-for-variant. Carried inside
/// [`anyhow::Error`] chains; recover with
/// `err.downcast_ref::<DatasetError>()`.
#[derive(Debug, Clone, PartialEq)]
pub enum DatasetError {
    /// The file ended before a complete header + payload could be read.
    Truncated {
        /// Bytes the decoder needed.
        need: usize,
        /// Bytes actually available.
        have: usize,
    },
    /// The first four bytes are not [`MAGIC`] — not a dataset file.
    BadMagic {
        /// The four bytes actually found.
        found: [u8; 4],
    },
    /// The file declares a format version this build cannot read.
    UnsupportedVersion {
        /// Version stamped in the file.
        found: u32,
        /// Highest version this build supports ([`VERSION`]).
        supported: u32,
    },
    /// The payload checksum does not match the header — bit rot or a
    /// partially overwritten file.
    BadCrc {
        /// CRC stored in the header.
        stored: u32,
        /// CRC computed over the payload actually read.
        computed: u32,
    },
    /// Structurally invalid content (impossible dims, unknown flag bits,
    /// trailing garbage, …).
    Malformed(String),
}

impl std::fmt::Display for DatasetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DatasetError::Truncated { need, have } => {
                write!(f, "dataset truncated: needed {need} bytes, have {have}")
            }
            DatasetError::BadMagic { found } => {
                write!(f, "not a binary dataset file: bad magic {found:02x?}")
            }
            DatasetError::UnsupportedVersion { found, supported } => write!(
                f,
                "dataset format version {found} not supported (this build reads <= {supported})"
            ),
            DatasetError::BadCrc { stored, computed } => write!(
                f,
                "dataset CRC mismatch: header {stored:#010x} vs payload {computed:#010x}"
            ),
            DatasetError::Malformed(what) => write!(f, "malformed dataset: {what}"),
        }
    }
}

impl std::error::Error for DatasetError {}

/// Encode points (and an optional parallel weight plane) as one binary
/// dataset buffer. Refuses empty input, heterogeneous dims (typed
/// [`MixedDims`]), and non-finite coordinates or weights (typed
/// [`NonFiniteCoord`]) — the writer can never emit a file its own
/// reader rejects.
pub fn encode(points: &[Point], weights: Option<&[f32]>) -> Result<Vec<u8>> {
    let Some(first) = points.first() else {
        bail!("cannot encode an empty dataset");
    };
    let dims = first.dims();
    if let Some(ws) = weights {
        if ws.len() != points.len() {
            bail!("{} weights for {} points (must be one per point)", ws.len(), points.len());
        }
    }
    let n_weights = weights.map_or(0, <[f32]>::len);
    let mut buf = Vec::with_capacity(HEADER_LEN + 4 * (points.len() * dims + n_weights));
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(dims as u32).to_le_bytes());
    buf.extend_from_slice(&(points.len() as u64).to_le_bytes());
    let flags = if weights.is_some() { FLAG_WEIGHTS } else { 0 };
    buf.extend_from_slice(&flags.to_le_bytes());
    buf.extend_from_slice(&0u32.to_le_bytes()); // CRC placeholder, patched below
    buf.extend_from_slice(&0u32.to_le_bytes()); // reserved
    debug_assert_eq!(buf.len(), HEADER_LEN);
    for (row, p) in points.iter().enumerate() {
        if p.dims() != dims {
            let e = MixedDims { line: row, got: p.dims(), expected: dims };
            return Err(anyhow::Error::new(e));
        }
        for (i, c) in p.coords().iter().enumerate() {
            if !c.is_finite() {
                let e = NonFiniteCoord { index: i, token: c.to_string() };
                return Err(anyhow::Error::new(e).context(format!("point {row}")));
            }
            buf.extend_from_slice(&c.to_le_bytes());
        }
    }
    if let Some(ws) = weights {
        for (row, w) in ws.iter().enumerate() {
            if !w.is_finite() {
                let e = NonFiniteCoord { index: 0, token: w.to_string() };
                return Err(anyhow::Error::new(e).context(format!("weight {row}")));
            }
            buf.extend_from_slice(&w.to_le_bytes());
        }
    }
    let crc = crc32(&buf[HEADER_LEN..]);
    buf[24..28].copy_from_slice(&crc.to_le_bytes());
    Ok(buf)
}

/// Write a binary dataset with tmp-file → `fsync` → rename discipline
/// (same as [`crate::persist::CheckpointStore`]): a crash mid-write can
/// never leave a torn file under `path`. Returns bytes written.
pub fn write_file(path: &Path, points: &[Point], weights: Option<&[f32]>) -> Result<u64> {
    let bytes = encode(points, weights).with_context(|| format!("encode {path:?}"))?;
    write_atomic(path, &bytes)?;
    Ok(bytes.len() as u64)
}

/// Atomic byte write used for datasets and their manifests.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty()).unwrap_or(Path::new("."));
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .with_context(|| format!("dataset path {path:?} has no file name"))?;
    let tmp = dir.join(format!(".tmp-{name}"));
    let mut f = std::fs::File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
    f.write_all(bytes).with_context(|| format!("write {tmp:?}"))?;
    f.sync_all().with_context(|| format!("fsync {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    // Durability of the rename itself is best-effort, exactly as in the
    // checkpoint store: failing to fsync the directory does not un-write
    // the data.
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// A decoded binary dataset: owns the file bytes and hands out typed
/// views into them. The coordinate plane is *not* copied at decode time;
/// [`DatasetFile::packed`] borrows it zero-copy through
/// [`crate::util::codec::f32s_view`] (owned fallback on misalignment).
pub struct DatasetFile {
    buf: Vec<u8>,
    dims: usize,
    count: usize,
    weighted: bool,
    crc: u32,
}

impl DatasetFile {
    /// Strict decode of a complete file image. Error order mirrors the
    /// checkpoint decoder: truncation → magic → version → structure →
    /// CRC, each a typed [`DatasetError`]; non-finite payload
    /// coordinates are refused with the CSV path's typed
    /// [`NonFiniteCoord`].
    pub fn decode(buf: Vec<u8>) -> Result<DatasetFile> {
        if buf.len() < HEADER_LEN {
            return Err(DatasetError::Truncated { need: HEADER_LEN, have: buf.len() }.into());
        }
        let found: [u8; 4] = buf[0..4].try_into().expect("4-byte slice");
        if found != MAGIC {
            return Err(DatasetError::BadMagic { found }.into());
        }
        let version = u32::from_le_bytes(buf[4..8].try_into().expect("4-byte slice"));
        if version != VERSION {
            return Err(
                DatasetError::UnsupportedVersion { found: version, supported: VERSION }.into()
            );
        }
        let dims = u32::from_le_bytes(buf[8..12].try_into().expect("4-byte slice")) as usize;
        if !(1..=MAX_DIMS).contains(&dims) {
            return Err(
                DatasetError::Malformed(format!("dims {dims} out of range 1..={MAX_DIMS}")).into()
            );
        }
        let count64 = u64::from_le_bytes(buf[12..20].try_into().expect("8-byte slice"));
        let count = usize::try_from(count64)
            .map_err(|_| DatasetError::Malformed(format!("count {count64} overflows usize")))?;
        let flags = u32::from_le_bytes(buf[20..24].try_into().expect("4-byte slice"));
        if flags & !FLAG_WEIGHTS != 0 {
            return Err(DatasetError::Malformed(format!("unknown flag bits {flags:#x}")).into());
        }
        let weighted = flags & FLAG_WEIGHTS != 0;
        let stored = u32::from_le_bytes(buf[24..28].try_into().expect("4-byte slice"));
        let reserved = u32::from_le_bytes(buf[28..32].try_into().expect("4-byte slice"));
        if reserved != 0 {
            return Err(DatasetError::Malformed(format!("reserved field is {reserved}")).into());
        }
        let floats = count
            .checked_mul(dims)
            .and_then(|c| c.checked_add(if weighted { count } else { 0 }))
            .ok_or_else(|| DatasetError::Malformed(format!("count {count} overflows")))?;
        let need = HEADER_LEN + 4 * floats;
        if buf.len() < need {
            return Err(DatasetError::Truncated { need, have: buf.len() }.into());
        }
        if buf.len() > need {
            return Err(
                DatasetError::Malformed(format!("{} trailing bytes", buf.len() - need)).into()
            );
        }
        let computed = crc32(&buf[HEADER_LEN..]);
        if computed != stored {
            return Err(DatasetError::BadCrc { stored, computed }.into());
        }
        let df = DatasetFile { buf, dims, count, weighted, crc: stored };
        // Same no-poison invariant as the CSV reader: a NaN/inf that
        // reached the file (foreign writer, bit flip that kept the CRC —
        // or just a file we did not write) must not sail into the
        // distance kernels.
        for (i, c) in floats_of(df.coord_bytes()).iter().enumerate() {
            if !c.is_finite() {
                let e = NonFiniteCoord { index: i % df.dims, token: c.to_string() };
                return Err(anyhow::Error::new(e).context(format!("point {}", i / df.dims)));
            }
        }
        Ok(df)
    }

    /// Read and strictly decode a dataset file from disk.
    pub fn read(path: &Path) -> Result<DatasetFile> {
        let buf = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
        DatasetFile::decode(buf).with_context(|| format!("decode {path:?}"))
    }

    /// Dimensionality of every point.
    pub fn dims(&self) -> usize {
        self.dims
    }

    /// Number of points.
    pub fn len(&self) -> usize {
        self.count
    }

    /// Whether the file holds zero points (unreachable via [`encode`],
    /// which refuses empty datasets, but decodable in principle).
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Whether a weight plane is present.
    pub fn weighted(&self) -> bool {
        self.weighted
    }

    /// The payload CRC-32 from the (verified) header — the dataset's
    /// content address, as recorded in manifests.
    pub fn crc32(&self) -> u32 {
        self.crc
    }

    /// The raw little-endian coordinate plane (`len·dims` f32s).
    pub fn coord_bytes(&self) -> &[u8] {
        &self.buf[HEADER_LEN..HEADER_LEN + 4 * self.count * self.dims]
    }

    /// The raw little-endian weight plane, when present.
    pub fn weight_bytes(&self) -> Option<&[u8]> {
        self.weighted.then(|| &self.buf[HEADER_LEN + 4 * self.count * self.dims..])
    }

    /// Zero-copy [`PackedPoints`] view over the file bytes: borrowed
    /// `&[f32]` planes when the buffer is aligned (the normal case —
    /// the header is 32 bytes, so payload alignment follows buffer
    /// alignment), an owned decode otherwise. Weighted files surface
    /// their weight plane through the same view.
    pub fn packed(&self) -> PackedPoints<'_> {
        let payload = &self.buf[HEADER_LEN..];
        if self.weighted {
            PackedPoints::weighted(self.dims, std::iter::once(payload))
        } else {
            PackedPoints::new(self.dims, std::iter::once(payload))
        }
    }

    /// Materialize the coordinate plane as owned [`Point`]s (the session
    /// ingest path, which shares points across cells via `Arc`).
    pub fn points(&self) -> Vec<Point> {
        floats_of(self.coord_bytes()).chunks_exact(self.dims).map(Point::from_slice).collect()
    }

    /// Materialize the weight plane, when present.
    pub fn weights(&self) -> Option<Vec<f32>> {
        self.weight_bytes().map(|b| floats_of(b).into_owned())
    }
}

/// Whether `path` starts with the binary dataset [`MAGIC`] (the sniff
/// used by every format-agnostic ingest surface: [`read_any`],
/// `ClusterSession::ingest_file`, the CLI `convert` subcommand).
pub fn is_binary(path: &Path) -> Result<bool> {
    use std::io::Read;
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut head = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        let n = f.read(&mut head[got..]).with_context(|| format!("read {path:?}"))?;
        if n == 0 {
            return Ok(false); // shorter than a magic: not binary
        }
        got += n;
    }
    Ok(head == MAGIC)
}

/// Read a dataset file in either format, sniffed by magic: binary files
/// decode through [`DatasetFile`], anything else parses as CSV.
pub fn read_any(path: &Path) -> Result<Vec<Point>> {
    if is_binary(path)? {
        Ok(DatasetFile::read(path)?.points())
    } else {
        read_csv(path)
    }
}

/// On-disk facts about a dataset file in either format, as recorded in
/// its manifest. For binary files the checksum is the header's payload
/// CRC; for CSV it is the CRC-32 of the raw file bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct FileSummary {
    /// [`FORMAT_BINARY`] or [`FORMAT_CSV`].
    pub format: &'static str,
    /// Dimensionality of every point.
    pub dims: usize,
    /// Number of points.
    pub count: usize,
    /// Whether a weight plane is present (always false for CSV).
    pub weights: bool,
    /// Content checksum (see above).
    pub crc32: u32,
}

/// Summarize a dataset file (either format) for manifest purposes.
/// Fully validates the file on the way: a corrupt binary file or a
/// malformed CSV is an error here, not at fit time.
pub fn summarize(path: &Path) -> Result<FileSummary> {
    if is_binary(path)? {
        let df = DatasetFile::read(path)?;
        Ok(FileSummary {
            format: FORMAT_BINARY,
            dims: df.dims(),
            count: df.len(),
            weights: df.weighted(),
            crc32: df.crc32(),
        })
    } else {
        let bytes = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
        let points = read_csv(path)?;
        let Some(first) = points.first() else {
            bail!("{path:?}: empty dataset");
        };
        Ok(FileSummary {
            format: FORMAT_CSV,
            dims: first.dims(),
            count: points.len(),
            weights: false,
            crc32: crc32(&bytes),
        })
    }
}

/// The manifest sibling path of a dataset file
/// (`points.bin` → `points.bin.manifest.json`).
pub fn manifest_path(dataset: &Path) -> PathBuf {
    let mut name = dataset.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(MANIFEST_SUFFIX);
    dataset.with_file_name(name)
}

/// Content-addressed dataset manifest: the JSON record written next to
/// every dataset file and embedded in bench artifacts, so every
/// published number names the exact bytes it was computed from.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Logical dataset name.
    pub name: String,
    /// Dataset file name (no directory — manifests travel with files).
    pub file: String,
    /// [`FORMAT_BINARY`] or [`FORMAT_CSV`].
    pub format: String,
    /// Dimensionality of every point.
    pub dims: usize,
    /// Number of points.
    pub count: usize,
    /// Whether a weight plane is present.
    pub weights: bool,
    /// Content checksum ([`FileSummary::crc32`] semantics).
    pub crc32: u32,
    /// Where the data came from: `{"generator": <spec>}` for synthetic
    /// datasets, `{"source": <path>}` for conversions.
    pub provenance: Json,
}

impl Manifest {
    /// Build a manifest for `dataset` from its on-disk [`FileSummary`].
    pub fn new(name: &str, dataset: &Path, summary: &FileSummary, provenance: Json) -> Manifest {
        let file = dataset
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or_default()
            .to_string();
        Manifest {
            name: name.to_string(),
            file,
            format: summary.format.to_string(),
            dims: summary.dims,
            count: summary.count,
            weights: summary.weights,
            crc32: summary.crc32,
            provenance,
        }
    }

    /// The manifest as a JSON object (the golden-tested key set).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("file", Json::Str(self.file.clone())),
            ("format", Json::Str(self.format.clone())),
            ("dims", Json::Num(self.dims as f64)),
            ("count", Json::Num(self.count as f64)),
            ("weights", Json::Bool(self.weights)),
            ("crc32", Json::Num(self.crc32 as f64)),
            ("provenance", self.provenance.clone()),
        ])
    }

    /// Parse a manifest back from its JSON record.
    pub fn from_json(j: &Json) -> Result<Manifest> {
        let str_field = |key: &str| -> Result<String> {
            j.get(key)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .with_context(|| format!("manifest: missing string {key:?}"))
        };
        let num_field = |key: &str| -> Result<f64> {
            j.get(key)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("manifest: missing number {key:?}"))
        };
        Ok(Manifest {
            name: str_field("name")?,
            file: str_field("file")?,
            format: str_field("format")?,
            dims: num_field("dims")? as usize,
            count: num_field("count")? as usize,
            weights: j
                .get("weights")
                .and_then(|v| v.as_bool())
                .context("manifest: missing bool \"weights\"")?,
            crc32: num_field("crc32")? as u32,
            provenance: j.get("provenance").context("manifest: missing \"provenance\"")?.clone(),
        })
    }

    /// Write this manifest next to `dataset` (atomic, like the dataset
    /// itself). Returns the manifest path.
    pub fn write(&self, dataset: &Path) -> Result<PathBuf> {
        let path = manifest_path(dataset);
        let mut body = self.to_json().to_string();
        body.push('\n');
        write_atomic(&path, body.as_bytes())
            .with_context(|| format!("write manifest {path:?}"))?;
        Ok(path)
    }
}

/// Summarize a dataset, write its manifest sibling, and return the
/// manifest — the one-call path every dataset-producing surface
/// (`generate --out`, `convert`) uses.
pub fn emit_manifest(name: &str, dataset: &Path, provenance: Json) -> Result<Manifest> {
    let summary = summarize(dataset)?;
    let m = Manifest::new(name, dataset, &summary, provenance);
    m.write(dataset)?;
    Ok(m)
}

/// Verify a dataset against its manifest sibling: re-summarize the
/// bytes on disk and check format, dims, count, weights flag, and
/// checksum. Returns the verified manifest; any drift is an error
/// naming the mismatched field.
pub fn verify_manifest(dataset: &Path) -> Result<Manifest> {
    let mpath = manifest_path(dataset);
    let text = std::fs::read_to_string(&mpath).with_context(|| format!("read {mpath:?}"))?;
    let j = Json::parse(&text).with_context(|| format!("parse {mpath:?}"))?;
    let m = Manifest::from_json(&j).with_context(|| format!("decode {mpath:?}"))?;
    let s = summarize(dataset)?;
    if s.format != m.format {
        bail!("{dataset:?}: format {:?} but manifest says {:?}", s.format, m.format);
    }
    if s.dims != m.dims {
        bail!("{dataset:?}: {} dims but manifest says {}", s.dims, m.dims);
    }
    if s.count != m.count {
        bail!("{dataset:?}: {} points but manifest says {}", s.count, m.count);
    }
    if s.weights != m.weights {
        bail!("{dataset:?}: weights={} but manifest says {}", s.weights, m.weights);
    }
    if s.crc32 != m.crc32 {
        bail!(
            "{dataset:?}: checksum {:#010x} but manifest says {:#010x} — dataset bytes drifted",
            s.crc32,
            m.crc32
        );
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geo::{PointSource as _, WeightedSource as _};
    use crate::util::codec::f32s_view;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("kmr_binfmt_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample(dims: usize, n: usize) -> Vec<Point> {
        (0..n)
            .map(|i| {
                let coords: Vec<f32> =
                    (0..dims).map(|d| (i * dims + d) as f32 * 0.5 - 3.0).collect();
                Point::from_slice(&coords)
            })
            .collect()
    }

    #[test]
    fn golden_byte_layout() {
        // Pin the exact v1 layout: any byte-level drift must fail here.
        let pts = vec![Point::new(1.0, 2.0), Point::new(3.0, 4.0)];
        let buf = encode(&pts, None).unwrap();
        let mut want = Vec::new();
        want.extend_from_slice(b"KMDS"); // magic
        want.extend_from_slice(&1u32.to_le_bytes()); // version
        want.extend_from_slice(&2u32.to_le_bytes()); // dims
        want.extend_from_slice(&2u64.to_le_bytes()); // count
        want.extend_from_slice(&0u32.to_le_bytes()); // flags
        let mut payload = Vec::new();
        for c in [1f32, 2.0, 3.0, 4.0] {
            payload.extend_from_slice(&c.to_le_bytes());
        }
        want.extend_from_slice(&crc32(&payload).to_le_bytes()); // crc
        want.extend_from_slice(&0u32.to_le_bytes()); // reserved
        want.extend_from_slice(&payload);
        assert_eq!(buf, want, "v1 byte layout drifted");
        assert_eq!(HEADER_LEN, 32);
        assert_eq!(HEADER_LEN % 8, 0, "payload must stay 8-byte aligned");
    }

    #[test]
    fn roundtrip_property_csv_binary_packed() {
        // Property: any finite point set (dims 2/3/8, weighted or not)
        // round-trips byte-exact through the binary format, and the
        // PackedPoints view agrees with the materialized points. The CSV
        // twin round-trips through write_csv/read_csv (shortest-roundtrip
        // float formatting makes that exact too).
        let dir = tmp_dir("prop");
        crate::util::proptest::for_all(25, 0xB1AF, |rng| {
            let dims = [2usize, 3, 8][rng.below(3)];
            let n = 1 + rng.below(60);
            let pts: Vec<Point> = (0..n)
                .map(|_| {
                    let coords: Vec<f32> =
                        (0..dims).map(|_| rng.range_f64(-1000.0, 1000.0) as f32).collect();
                    Point::from_slice(&coords)
                })
                .collect();
            let weighted = rng.below(2) == 1;
            let ws: Option<Vec<f32>> =
                weighted.then(|| (0..n).map(|_| rng.range_f64(0.001, 50.0) as f32).collect());

            // Binary round trip.
            let bin = dir.join("prop.bin");
            write_file(&bin, &pts, ws.as_deref()).unwrap();
            let df = DatasetFile::read(&bin).unwrap();
            assert_eq!(df.dims(), dims);
            assert_eq!(df.len(), n);
            assert_eq!(df.weighted(), weighted);
            assert_eq!(df.points(), pts);
            assert_eq!(df.weights(), ws);

            // PackedPoints view agrees point-for-point (and weight-for-
            // weight) with the materialized vector.
            let packed = df.packed();
            assert_eq!(packed.len(), n);
            assert_eq!(packed.dims(), dims);
            for i in 0..n {
                assert_eq!(packed.get(i), pts[i], "point {i}");
                let want_w = ws.as_ref().map_or(1.0, |w| w[i]);
                assert_eq!(packed.weight(i), want_w, "weight {i}");
            }

            // CSV twin: unweighted only (CSV has no weight plane).
            let csv = dir.join("prop.csv");
            crate::geo::io::write_csv(&csv, &pts).unwrap();
            assert_eq!(read_csv(&csv).unwrap(), pts, "CSV round trip must be exact");
            assert_eq!(read_any(&csv).unwrap(), read_any(&bin).unwrap(), "sniffed readers agree");
        });
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn zero_copy_view_applies_to_the_payload() {
        // The whole point of the 32-byte header: the coordinate plane of
        // a freshly read file reinterprets in place on little-endian
        // targets (Vec allocations are at least 8-byte aligned).
        let pts = sample(3, 10);
        let buf = encode(&pts, None).unwrap();
        let df = DatasetFile::decode(buf).unwrap();
        if cfg!(target_endian = "little") {
            let view = f32s_view(df.coord_bytes()).expect("aligned payload must view in place");
            let expect: Vec<f32> = (0..30).map(|j| j as f32 * 0.5 - 3.0).collect();
            assert_eq!(view, &expect[..]);
        }
    }

    #[test]
    fn misaligned_buffer_takes_the_owned_fallback() {
        // Shift the encoded image by one byte: f32s_view must refuse the
        // view and the owned decode fallback must produce identical
        // points through the same PackedPoints surface.
        let pts = sample(2, 7);
        let buf = encode(&pts, None).unwrap();
        let mut shifted = vec![0u8];
        shifted.extend_from_slice(&buf);
        let payload = &shifted[1 + HEADER_LEN..];
        assert!(f32s_view(payload).is_none(), "odd offset cannot alias f32s");
        let packed = PackedPoints::new(2, std::iter::once(payload));
        assert_eq!(packed.len(), 7);
        for (i, p) in pts.iter().enumerate() {
            assert_eq!(packed.get(i), *p, "fallback point {i}");
        }
    }

    #[test]
    fn truncation_detected_at_every_length() {
        let buf = encode(&sample(2, 4), None).unwrap();
        for cut in 0..buf.len() {
            let e = DatasetFile::decode(buf[..cut].to_vec()).unwrap_err();
            assert!(
                matches!(e.downcast_ref::<DatasetError>(), Some(DatasetError::Truncated { .. })),
                "cut at {cut}: {e:#}"
            );
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = encode(&sample(2, 2), None).unwrap();
        buf[0..4].copy_from_slice(b"KMDC"); // the *checkpoint* magic
        let e = DatasetFile::decode(buf).unwrap_err();
        assert_eq!(
            e.downcast_ref::<DatasetError>(),
            Some(&DatasetError::BadMagic { found: *b"KMDC" }),
            "{e:#}"
        );
    }

    #[test]
    fn future_version_rejected() {
        let mut buf = encode(&sample(2, 2), None).unwrap();
        buf[4..8].copy_from_slice(&(VERSION + 1).to_le_bytes());
        let e = DatasetFile::decode(buf).unwrap_err();
        assert_eq!(
            e.downcast_ref::<DatasetError>(),
            Some(&DatasetError::UnsupportedVersion { found: VERSION + 1, supported: VERSION }),
            "{e:#}"
        );
    }

    #[test]
    fn flipped_payload_bit_fails_crc() {
        let mut buf = encode(&sample(2, 3), None).unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x40;
        let e = DatasetFile::decode(buf).unwrap_err();
        assert!(
            matches!(e.downcast_ref::<DatasetError>(), Some(DatasetError::BadCrc { .. })),
            "{e:#}"
        );
    }

    #[test]
    fn structural_garbage_is_malformed() {
        // Trailing bytes after the declared payload.
        let mut buf = encode(&sample(2, 2), None).unwrap();
        buf.push(0);
        buf.push(0);
        buf.push(0);
        buf.push(0);
        let e = DatasetFile::decode(buf).unwrap_err();
        assert_eq!(
            e.downcast_ref::<DatasetError>(),
            Some(&DatasetError::Malformed("4 trailing bytes".into())),
            "{e:#}"
        );
        // Impossible dims (0 and > MAX_DIMS).
        for bad_dims in [0u32, MAX_DIMS as u32 + 1] {
            let mut buf = encode(&sample(2, 2), None).unwrap();
            buf[8..12].copy_from_slice(&bad_dims.to_le_bytes());
            let e = DatasetFile::decode(buf).unwrap_err();
            assert!(
                matches!(e.downcast_ref::<DatasetError>(), Some(DatasetError::Malformed(_))),
                "dims={bad_dims}: {e:#}"
            );
        }
        // Unknown flag bits.
        let mut buf = encode(&sample(2, 2), None).unwrap();
        buf[20..24].copy_from_slice(&0x8000_0002u32.to_le_bytes());
        let e = DatasetFile::decode(buf).unwrap_err();
        assert!(
            matches!(e.downcast_ref::<DatasetError>(), Some(DatasetError::Malformed(_))),
            "{e:#}"
        );
    }

    #[test]
    fn writer_refuses_what_readers_refuse() {
        // Non-finite coordinate: same typed error as the CSV writer.
        let pts = vec![Point::new(1.0, f32::NAN)];
        let e = encode(&pts, None).unwrap_err();
        assert!(e.downcast_ref::<NonFiniteCoord>().is_some(), "{e:#}");
        // Mixed dims: the shared typed MixedDims.
        let pts = vec![Point::new(1.0, 2.0), Point::from_slice(&[1.0, 2.0, 3.0])];
        let e = encode(&pts, None).unwrap_err();
        assert_eq!(
            e.downcast_ref::<MixedDims>(),
            Some(&MixedDims { line: 1, got: 3, expected: 2 }),
            "{e:#}"
        );
        // Weight count mismatch and empty input are refused outright.
        assert!(encode(&sample(2, 3), Some(&[1.0])).is_err());
        assert!(encode(&[], None).is_err());
    }

    #[test]
    fn non_finite_payload_rejected_on_read() {
        // Bit-exact NaN in the payload with a *valid* CRC (a foreign
        // writer): the reader must still refuse it, typed.
        let mut buf = encode(&sample(2, 2), None).unwrap();
        buf[HEADER_LEN..HEADER_LEN + 4].copy_from_slice(&f32::NAN.to_le_bytes());
        let crc = crc32(&buf[HEADER_LEN..]);
        buf[24..28].copy_from_slice(&crc.to_le_bytes());
        let e = DatasetFile::decode(buf).unwrap_err();
        assert_eq!(
            e.downcast_ref::<NonFiniteCoord>(),
            Some(&NonFiniteCoord { index: 0, token: "NaN".into() }),
            "{e:#}"
        );
    }

    #[test]
    fn manifest_golden_key_set_and_verify() {
        let dir = tmp_dir("manifest");
        let bin = dir.join("pts.bin");
        write_file(&bin, &sample(3, 5), None).unwrap();
        let provenance = obj(vec![("source", Json::Str("pts.csv".into()))]);
        let m = emit_manifest("pts", &bin, provenance).unwrap();
        let j = m.to_json();
        // Golden key set: artifact consumers depend on these exact keys.
        let keys: Vec<&str> =
            j.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
        assert_eq!(
            keys,
            vec!["count", "crc32", "dims", "file", "format", "name", "provenance", "weights"],
            "manifest key set drifted"
        );
        // The sibling file parses back to the same record and verifies.
        let mpath = manifest_path(&bin);
        assert!(mpath.ends_with("pts.bin.manifest.json"), "{mpath:?}");
        let parsed = Json::parse(&std::fs::read_to_string(&mpath).unwrap()).unwrap();
        assert_eq!(Manifest::from_json(&parsed).unwrap(), m);
        assert_eq!(verify_manifest(&bin).unwrap(), m);
        // Flip a payload byte (keeping the CRC valid in the *file*
        // header would be a different failure); rewriting the dataset
        // with different contents must fail checksum verification.
        write_file(&bin, &sample(3, 5), Some(&[1.0; 5])).unwrap();
        let e = verify_manifest(&bin).unwrap_err();
        assert!(format!("{e:#}").contains("weights"), "{e:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_manifests_checksum_the_file_bytes() {
        let dir = tmp_dir("csvman");
        let csv = dir.join("pts.csv");
        crate::geo::io::write_csv(&csv, &sample(2, 4)).unwrap();
        let m = emit_manifest("pts", &csv, Json::Null).unwrap();
        assert_eq!(m.format, FORMAT_CSV);
        assert_eq!(m.count, 4);
        assert_eq!(m.dims, 2);
        assert_eq!(m.crc32, crc32(&std::fs::read(&csv).unwrap()));
        assert_eq!(verify_manifest(&csv).unwrap(), m);
        // Appending a row drifts the checksum.
        let mut f = std::fs::OpenOptions::new().append(true).open(&csv).unwrap();
        writeln!(f, "9,9").unwrap();
        drop(f);
        assert!(verify_manifest(&csv).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_is_atomic_no_tmp_residue() {
        let dir = tmp_dir("atomic");
        let bin = dir.join("a.bin");
        write_file(&bin, &sample(2, 3), None).unwrap();
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert!(names.iter().all(|n| !n.starts_with(".tmp-")), "{names:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sniffing_distinguishes_formats() {
        let dir = tmp_dir("sniff");
        let bin = dir.join("b.bin");
        let csv = dir.join("c.csv");
        write_file(&bin, &sample(2, 2), None).unwrap();
        crate::geo::io::write_csv(&csv, &sample(2, 2)).unwrap();
        assert!(is_binary(&bin).unwrap());
        assert!(!is_binary(&csv).unwrap());
        // Shorter than a magic: CSV by definition.
        let tiny = dir.join("tiny");
        std::fs::write(&tiny, "1,2").unwrap();
        assert!(!is_binary(&tiny).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }
}
