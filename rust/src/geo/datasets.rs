//! Synthetic spatial dataset generators.
//!
//! The paper's three datasets are real GIS point sets of 1 316 792 /
//! 2 449 101 / 3 220 460 points (Table 5) whose provenance is not given.
//! We substitute synthetic spatial data with the same cardinalities and
//! clusterable structure: Gaussian "hotspots" (cities) of varying density
//! + uniform background noise + far outliers (the outliers are the whole
//! point of K-Medoids over K-Means, §1–2 of the paper).

use super::Point;
use crate::util::rng::Rng;

/// Paper Table 5 cardinalities.
pub const PAPER_DATASET_POINTS: [usize; 3] = [1_316_792, 2_449_101, 3_220_460];
/// Paper Table 5 sizes in MB (text encoding on HDFS). Implied row size
/// ≈ 410 bytes/row (GIS attribute columns beside the coordinate).
pub const PAPER_DATASET_MB: [usize; 3] = [515, 958, 1259];

/// Average encoded row size implied by Table 5 (bytes/row).
pub fn paper_row_bytes() -> u64 {
    // 515 MB / 1.316M rows ≈ 410 B; use the mean implied by all three.
    let total_mb: usize = PAPER_DATASET_MB.iter().sum();
    let total_pts: usize = PAPER_DATASET_POINTS.iter().sum();
    ((total_mb as u64) << 20) / total_pts as u64
}

/// Generation spec for a synthetic spatial dataset.
#[derive(Debug, Clone, PartialEq)]
pub struct SpatialSpec {
    pub n_points: usize,
    /// Number of Gaussian hotspots (true clusters).
    pub n_hotspots: usize,
    /// Coordinate domain half-width (map units).
    pub extent: f32,
    /// Hotspot standard deviation as a fraction of the extent.
    pub sigma_frac: f32,
    /// Fraction of points drawn uniformly over the domain (background).
    pub noise_frac: f32,
    /// Fraction of extreme outliers (far outside the domain).
    pub outlier_frac: f32,
    pub seed: u64,
}

impl SpatialSpec {
    pub fn new(n_points: usize, n_hotspots: usize, seed: u64) -> SpatialSpec {
        SpatialSpec {
            n_points,
            n_hotspots,
            extent: 10_000.0,
            sigma_frac: 0.03,
            noise_frac: 0.05,
            outlier_frac: 0.002,
            seed,
        }
    }

    /// The paper's dataset `i` (0..3) with k=9 hotspots (the paper does
    /// not state k; 9 true clusters keeps reduce keys < nodes·slots).
    pub fn paper_dataset(i: usize, seed: u64) -> SpatialSpec {
        SpatialSpec::new(PAPER_DATASET_POINTS[i], 9, seed ^ (i as u64))
    }

    /// A laptop-friendly scaled version (same structure, fewer points).
    pub fn paper_dataset_scaled(i: usize, scale_div: usize, seed: u64) -> SpatialSpec {
        let mut s = Self::paper_dataset(i, seed);
        s.n_points = (s.n_points / scale_div).max(1000);
        s
    }
}

/// Generated dataset with ground truth for quality metrics.
pub struct SpatialDataset {
    pub points: Vec<Point>,
    /// Ground-truth hotspot id per point; `None` for noise/outliers.
    pub truth: Vec<Option<u32>>,
    pub centers: Vec<Point>,
}

/// Generate a dataset from a spec. Deterministic in the seed.
pub fn generate(spec: &SpatialSpec) -> SpatialDataset {
    assert!(spec.n_hotspots > 0);
    let mut rng = Rng::new(spec.seed);
    let e = spec.extent as f64;
    let sigma = (spec.extent * spec.sigma_frac) as f64;

    // Hotspot centers: spread over the domain, min-distance rejection so
    // clusters are resolvable (8σ keeps neighboring hotspots separable).
    let mut centers: Vec<Point> = Vec::with_capacity(spec.n_hotspots);
    let min_sep = 8.0 * sigma;
    let mut guard = 0;
    while centers.len() < spec.n_hotspots {
        let c = Point::new(rng.range_f64(-e, e) as f32, rng.range_f64(-e, e) as f32);
        if centers.iter().all(|o| o.dist2(&c).sqrt() > min_sep) || guard > 10_000 {
            centers.push(c);
        }
        guard += 1;
    }

    // Unequal hotspot weights (real cities are not equal-sized).
    let weights: Vec<f64> = (0..spec.n_hotspots).map(|_| 0.3 + rng.f64()).collect();

    let mut points = Vec::with_capacity(spec.n_points);
    let mut truth = Vec::with_capacity(spec.n_points);
    for _ in 0..spec.n_points {
        let u = rng.f64();
        if u < spec.outlier_frac as f64 {
            // Far outliers: 1.5–3 extents outside the populated domain
            // (GPS glitches / bad geocodes, not absurd coordinates — the
            // squared-distance ++ seeding weight must not be dominated by
            // a handful of points).
            let r = e * rng.range_f64(1.5, 3.0);
            let th = rng.range_f64(0.0, std::f64::consts::TAU);
            points.push(Point::new((r * th.cos()) as f32, (r * th.sin()) as f32));
            truth.push(None);
        } else if u < (spec.outlier_frac + spec.noise_frac) as f64 {
            points.push(Point::new(rng.range_f64(-e, e) as f32, rng.range_f64(-e, e) as f32));
            truth.push(None);
        } else {
            let h = rng.weighted(&weights);
            let c = centers[h];
            points.push(Point::new(
                (c.x as f64 + rng.normal() * sigma) as f32,
                (c.y as f64 + rng.normal() * sigma) as f32,
            ));
            truth.push(Some(h as u32));
        }
    }
    SpatialDataset { points, truth, centers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geo::BBox;
    use crate::util::proptest::for_all;

    #[test]
    fn deterministic_in_seed() {
        let s = SpatialSpec::new(2000, 4, 42);
        let a = generate(&s);
        let b = generate(&s);
        assert_eq!(a.points, b.points);
        let mut s2 = s.clone();
        s2.seed = 43;
        let c = generate(&s2);
        assert_ne!(a.points, c.points);
    }

    #[test]
    fn cardinality_and_truth_len() {
        let d = generate(&SpatialSpec::new(5000, 6, 1));
        assert_eq!(d.points.len(), 5000);
        assert_eq!(d.truth.len(), 5000);
        assert_eq!(d.centers.len(), 6);
    }

    #[test]
    fn hotspot_points_near_centers() {
        let s = SpatialSpec::new(20_000, 5, 7);
        let d = generate(&s);
        let sigma = (s.extent * s.sigma_frac) as f64;
        for (p, t) in d.points.iter().zip(&d.truth) {
            if let Some(h) = t {
                let dist = p.dist2(&d.centers[*h as usize]).sqrt();
                assert!(dist < 6.0 * sigma, "point {dist} sigma {sigma}");
            }
        }
    }

    #[test]
    fn outliers_exist_and_are_far() {
        let s = SpatialSpec::new(50_000, 4, 3);
        let d = generate(&s);
        let core: Vec<_> =
            d.points.iter().zip(&d.truth).filter(|(_, t)| t.is_some()).map(|(p, _)| *p).collect();
        let bb = BBox::of(&core).unwrap();
        let far = d.points.iter().filter(|p| !bb.contains(p)).count();
        assert!(far > 0, "expected some outliers outside the core bbox");
    }

    #[test]
    fn noise_fraction_roughly_respected() {
        let s = SpatialSpec::new(100_000, 4, 9);
        let d = generate(&s);
        let noise = d.truth.iter().filter(|t| t.is_none()).count() as f64 / 100_000.0;
        let expected = (s.noise_frac + s.outlier_frac) as f64;
        assert!((noise - expected).abs() < 0.01, "noise {noise} vs {expected}");
    }

    #[test]
    fn paper_specs_have_table5_cardinalities() {
        for i in 0..3 {
            let s = SpatialSpec::paper_dataset(i, 0);
            assert_eq!(s.n_points, PAPER_DATASET_POINTS[i]);
        }
        assert!(paper_row_bytes() > 300 && paper_row_bytes() < 500);
    }

    #[test]
    fn scaled_preserves_structure() {
        let s = SpatialSpec::paper_dataset_scaled(0, 100, 0);
        assert_eq!(s.n_points, 13_167);
        assert_eq!(s.n_hotspots, 9);
    }

    #[test]
    fn centers_separated() {
        for_all(10, 0x9E0, |rng| {
            let d = generate(&SpatialSpec::new(100, 8, rng.next_u64()));
            for i in 0..d.centers.len() {
                for j in 0..i {
                    assert!(d.centers[i].dist2(&d.centers[j]) > 0.0);
                }
            }
        });
    }
}
