//! Synthetic spatial dataset generators.
//!
//! The paper's three datasets are real GIS point sets of 1 316 792 /
//! 2 449 101 / 3 220 460 points (Table 5) whose provenance is not given.
//! We substitute synthetic spatial data with the same cardinalities and
//! clusterable structure: Gaussian "hotspots" (cities) of varying density
//! + uniform background noise + far outliers (the outliers are the whole
//! point of K-Medoids over K-Means, §1–2 of the paper).
//!
//! Three generator families share one [`SpatialSpec`]:
//!
//! - **Planar 2-D** (`dims == 2`, the default): the paper's workload.
//!   This path reproduces the historical RNG draw sequence exactly, so
//!   2-D datasets are byte-identical across releases.
//! - **d-dim Gaussian mixtures** (`dims > 2`): hotspot centers in the
//!   d-cube, isotropic Gaussian clouds, uniform noise, and radial far
//!   outliers — the feature-vector workload for the metric-generic core.
//! - **Lat/lon GIS clouds** (`latlon == true`, `dims == 2`): city-like
//!   clusters on the sphere, coordinates in `(lat, lon)` degrees, built
//!   for [`crate::geo::Metric::Haversine`] runs.

use super::{Metric, Point};
use crate::util::rng::Rng;

/// Paper Table 5 cardinalities.
pub const PAPER_DATASET_POINTS: [usize; 3] = [1_316_792, 2_449_101, 3_220_460];
/// Paper Table 5 sizes in MB (text encoding on HDFS). Implied row size
/// ≈ 410 bytes/row (GIS attribute columns beside the coordinate).
pub const PAPER_DATASET_MB: [usize; 3] = [515, 958, 1259];

/// Average encoded row size implied by Table 5 (bytes/row).
pub fn paper_row_bytes() -> u64 {
    // 515 MB / 1.316M rows ≈ 410 B; use the mean implied by all three.
    let total_mb: usize = PAPER_DATASET_MB.iter().sum();
    let total_pts: usize = PAPER_DATASET_POINTS.iter().sum();
    ((total_mb as u64) << 20) / total_pts as u64
}

/// Generation spec for a synthetic spatial dataset.
#[derive(Debug, Clone, PartialEq)]
pub struct SpatialSpec {
    pub n_points: usize,
    /// Number of Gaussian hotspots (true clusters).
    pub n_hotspots: usize,
    /// Coordinate domain half-width (map units; planar/d-dim families).
    pub extent: f32,
    /// Hotspot standard deviation as a fraction of the extent (for the
    /// lat/lon family: as a fraction of 90°).
    pub sigma_frac: f32,
    /// Fraction of points drawn uniformly over the domain (background).
    pub noise_frac: f32,
    /// Fraction of extreme outliers (far outside the domain; for the
    /// lat/lon family these are globally-uniform mislocated points).
    pub outlier_frac: f32,
    /// Point dimensionality (2 = the paper's planar GIS case).
    pub dims: usize,
    /// Generate city-like `(lat, lon)` degree clouds on the sphere
    /// (requires `dims == 2`); built for haversine runs.
    pub latlon: bool,
    pub seed: u64,
}

impl SpatialSpec {
    pub fn new(n_points: usize, n_hotspots: usize, seed: u64) -> SpatialSpec {
        SpatialSpec {
            n_points,
            n_hotspots,
            extent: 10_000.0,
            sigma_frac: 0.03,
            noise_frac: 0.05,
            outlier_frac: 0.002,
            dims: 2,
            latlon: false,
            seed,
        }
    }

    /// Same spec at dimensionality `dims` (a d-dim Gaussian mixture).
    pub fn with_dims(mut self, dims: usize) -> SpatialSpec {
        self.dims = dims;
        self
    }

    /// A lat/lon GIS cloud spec: `n_cities` clusters on the sphere,
    /// coordinates in `(lat, lon)` degrees — pair with
    /// [`Metric::Haversine`].
    pub fn latlon(n_points: usize, n_cities: usize, seed: u64) -> SpatialSpec {
        let mut s = SpatialSpec::new(n_points, n_cities, seed);
        s.latlon = true;
        s
    }

    /// The paper's dataset `i` (0..3) with k=9 hotspots (the paper does
    /// not state k; 9 true clusters keeps reduce keys < nodes·slots).
    pub fn paper_dataset(i: usize, seed: u64) -> SpatialSpec {
        SpatialSpec::new(PAPER_DATASET_POINTS[i], 9, seed ^ (i as u64))
    }

    /// A laptop-friendly scaled version (same structure, fewer points).
    pub fn paper_dataset_scaled(i: usize, scale_div: usize, seed: u64) -> SpatialSpec {
        let mut s = Self::paper_dataset(i, seed);
        s.n_points = (s.n_points / scale_div).max(1000);
        s
    }
}

/// Generated dataset with ground truth for quality metrics.
pub struct SpatialDataset {
    pub points: Vec<Point>,
    /// Ground-truth hotspot id per point; `None` for noise/outliers.
    pub truth: Vec<Option<u32>>,
    pub centers: Vec<Point>,
    /// Whether the coordinates are `(lat, lon)` degree pairs (the
    /// generator knows; carried so ingest keeps the provenance for the
    /// haversine misuse guard).
    pub latlon: bool,
}

/// Generate a dataset from a spec. Deterministic in the seed; the 2-D
/// planar family reproduces the historical draw sequence exactly.
pub fn generate(spec: &SpatialSpec) -> SpatialDataset {
    assert!(spec.n_hotspots > 0);
    assert!(
        spec.dims >= 2 && spec.dims <= super::MAX_DIMS,
        "dims must be in 2..={}, got {}",
        super::MAX_DIMS,
        spec.dims
    );
    if spec.latlon {
        assert!(spec.dims == 2, "lat/lon clouds are (lat, lon) pairs: dims must be 2");
        return generate_latlon(spec);
    }
    if spec.dims == 2 {
        generate_planar_2d(spec)
    } else {
        generate_ndim(spec)
    }
}

/// The historical planar 2-D generator, draw-for-draw identical to the
/// pre-metric-generic releases (2-D datasets are byte-stable in the seed).
fn generate_planar_2d(spec: &SpatialSpec) -> SpatialDataset {
    let mut rng = Rng::new(spec.seed);
    let e = spec.extent as f64;
    let sigma = (spec.extent * spec.sigma_frac) as f64;

    // Hotspot centers: spread over the domain, min-distance rejection so
    // clusters are resolvable (8σ keeps neighboring hotspots separable).
    let mut centers: Vec<Point> = Vec::with_capacity(spec.n_hotspots);
    let min_sep = 8.0 * sigma;
    let mut guard = 0;
    while centers.len() < spec.n_hotspots {
        let c = Point::new(rng.range_f64(-e, e) as f32, rng.range_f64(-e, e) as f32);
        if centers.iter().all(|o| o.dist2(&c).sqrt() > min_sep) || guard > 10_000 {
            centers.push(c);
        }
        guard += 1;
    }

    // Unequal hotspot weights (real cities are not equal-sized).
    let weights: Vec<f64> = (0..spec.n_hotspots).map(|_| 0.3 + rng.f64()).collect();

    let mut points = Vec::with_capacity(spec.n_points);
    let mut truth = Vec::with_capacity(spec.n_points);
    for _ in 0..spec.n_points {
        let u = rng.f64();
        if u < spec.outlier_frac as f64 {
            // Far outliers: 1.5–3 extents outside the populated domain
            // (GPS glitches / bad geocodes, not absurd coordinates — the
            // squared-distance ++ seeding weight must not be dominated by
            // a handful of points).
            let r = e * rng.range_f64(1.5, 3.0);
            let th = rng.range_f64(0.0, std::f64::consts::TAU);
            points.push(Point::new((r * th.cos()) as f32, (r * th.sin()) as f32));
            truth.push(None);
        } else if u < (spec.outlier_frac + spec.noise_frac) as f64 {
            points.push(Point::new(rng.range_f64(-e, e) as f32, rng.range_f64(-e, e) as f32));
            truth.push(None);
        } else {
            let h = rng.weighted(&weights);
            let c = centers[h];
            points.push(Point::new(
                (c.x() as f64 + rng.normal() * sigma) as f32,
                (c.y() as f64 + rng.normal() * sigma) as f32,
            ));
            truth.push(Some(h as u32));
        }
    }
    SpatialDataset { points, truth, centers, latlon: spec.latlon }
}

/// d-dimensional Gaussian mixture (dims > 2): same structure as the
/// planar family — hotspot clouds + cube noise + radial far outliers.
fn generate_ndim(spec: &SpatialSpec) -> SpatialDataset {
    let d = spec.dims;
    let mut rng = Rng::new(spec.seed);
    let e = spec.extent as f64;
    let sigma = (spec.extent * spec.sigma_frac) as f64;

    let mut centers: Vec<Point> = Vec::with_capacity(spec.n_hotspots);
    let min_sep = 8.0 * sigma;
    let mut guard = 0;
    let mut coords = vec![0f32; d];
    while centers.len() < spec.n_hotspots {
        for slot in coords.iter_mut() {
            *slot = rng.range_f64(-e, e) as f32;
        }
        let c = Point::from_slice(&coords);
        if centers.iter().all(|o| o.dist2(&c).sqrt() > min_sep) || guard > 10_000 {
            centers.push(c);
        }
        guard += 1;
    }

    let weights: Vec<f64> = (0..spec.n_hotspots).map(|_| 0.3 + rng.f64()).collect();

    let mut points = Vec::with_capacity(spec.n_points);
    let mut truth = Vec::with_capacity(spec.n_points);
    for _ in 0..spec.n_points {
        let u = rng.f64();
        if u < spec.outlier_frac as f64 {
            // Radial far outlier: random direction, 1.5–3 extents out.
            let r = e * rng.range_f64(1.5, 3.0);
            let dir: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let norm = dir.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
            for (slot, v) in coords.iter_mut().zip(&dir) {
                *slot = (r * v / norm) as f32;
            }
            points.push(Point::from_slice(&coords));
            truth.push(None);
        } else if u < (spec.outlier_frac + spec.noise_frac) as f64 {
            for slot in coords.iter_mut() {
                *slot = rng.range_f64(-e, e) as f32;
            }
            points.push(Point::from_slice(&coords));
            truth.push(None);
        } else {
            let h = rng.weighted(&weights);
            let c = centers[h];
            for (i, slot) in coords.iter_mut().enumerate() {
                *slot = (c.coord(i) as f64 + rng.normal() * sigma) as f32;
            }
            points.push(Point::from_slice(&coords));
            truth.push(Some(h as u32));
        }
    }
    SpatialDataset { points, truth, centers, latlon: spec.latlon }
}

/// City-like clusters on the sphere: `(lat, lon)` degree pairs, built
/// for [`Metric::Haversine`] runs. Cluster spread is `sigma_frac · 90°`
/// of latitude (longitude widened by `1 / cos(lat)` so clouds are
/// roughly isotropic on the ground).
fn generate_latlon(spec: &SpatialSpec) -> SpatialDataset {
    let mut rng = Rng::new(spec.seed);
    let sigma_deg = (90.0 * spec.sigma_frac) as f64;
    // Degrees → km at the equator; separation is measured properly via
    // haversine so polar longitude compression cannot merge cities.
    let min_sep_km = 8.0 * sigma_deg * 111.2;

    let mut centers: Vec<Point> = Vec::with_capacity(spec.n_hotspots);
    let mut guard = 0;
    while centers.len() < spec.n_hotspots {
        let c = Point::new(rng.range_f64(-60.0, 60.0) as f32, rng.range_f64(-175.0, 175.0) as f32);
        if centers.iter().all(|o| Metric::Haversine.distance(o, &c) > min_sep_km) || guard > 10_000
        {
            centers.push(c);
        }
        guard += 1;
    }

    let weights: Vec<f64> = (0..spec.n_hotspots).map(|_| 0.3 + rng.f64()).collect();

    let mut points = Vec::with_capacity(spec.n_points);
    let mut truth = Vec::with_capacity(spec.n_points);
    for _ in 0..spec.n_points {
        let u = rng.f64();
        if u < (spec.outlier_frac + spec.noise_frac) as f64 {
            // Background + mislocated points: uniform over the globe.
            points.push(Point::new(
                rng.range_f64(-85.0, 85.0) as f32,
                rng.range_f64(-180.0, 180.0) as f32,
            ));
            truth.push(None);
        } else {
            let h = rng.weighted(&weights);
            let c = centers[h];
            let lat = (c.x() as f64 + rng.normal() * sigma_deg).clamp(-89.9, 89.9);
            let lon_spread = sigma_deg / (c.x() as f64).to_radians().cos().max(0.2);
            let mut lon = c.y() as f64 + rng.normal() * lon_spread;
            // Wrap into [-180, 180).
            lon = (lon + 180.0).rem_euclid(360.0) - 180.0;
            points.push(Point::new(lat as f32, lon as f32));
            truth.push(Some(h as u32));
        }
    }
    SpatialDataset { points, truth, centers, latlon: spec.latlon }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geo::BBox;
    use crate::util::proptest::for_all;

    #[test]
    fn deterministic_in_seed() {
        let s = SpatialSpec::new(2000, 4, 42);
        let a = generate(&s);
        let b = generate(&s);
        assert_eq!(a.points, b.points);
        let mut s2 = s.clone();
        s2.seed = 43;
        let c = generate(&s2);
        assert_ne!(a.points, c.points);
    }

    #[test]
    fn cardinality_and_truth_len() {
        let d = generate(&SpatialSpec::new(5000, 6, 1));
        assert_eq!(d.points.len(), 5000);
        assert_eq!(d.truth.len(), 5000);
        assert_eq!(d.centers.len(), 6);
    }

    #[test]
    fn hotspot_points_near_centers() {
        let s = SpatialSpec::new(20_000, 5, 7);
        let d = generate(&s);
        let sigma = (s.extent * s.sigma_frac) as f64;
        for (p, t) in d.points.iter().zip(&d.truth) {
            if let Some(h) = t {
                let dist = p.dist2(&d.centers[*h as usize]).sqrt();
                assert!(dist < 6.0 * sigma, "point {dist} sigma {sigma}");
            }
        }
    }

    #[test]
    fn outliers_exist_and_are_far() {
        let s = SpatialSpec::new(50_000, 4, 3);
        let d = generate(&s);
        let core: Vec<_> =
            d.points.iter().zip(&d.truth).filter(|(_, t)| t.is_some()).map(|(p, _)| *p).collect();
        let bb = BBox::of(&core).unwrap();
        let far = d.points.iter().filter(|p| !bb.contains(p)).count();
        assert!(far > 0, "expected some outliers outside the core bbox");
    }

    #[test]
    fn noise_fraction_roughly_respected() {
        let s = SpatialSpec::new(100_000, 4, 9);
        let d = generate(&s);
        let noise = d.truth.iter().filter(|t| t.is_none()).count() as f64 / 100_000.0;
        let expected = (s.noise_frac + s.outlier_frac) as f64;
        assert!((noise - expected).abs() < 0.01, "noise {noise} vs {expected}");
    }

    #[test]
    fn paper_specs_have_table5_cardinalities() {
        for i in 0..3 {
            let s = SpatialSpec::paper_dataset(i, 0);
            assert_eq!(s.n_points, PAPER_DATASET_POINTS[i]);
        }
        assert!(paper_row_bytes() > 300 && paper_row_bytes() < 500);
    }

    #[test]
    fn scaled_preserves_structure() {
        let s = SpatialSpec::paper_dataset_scaled(0, 100, 0);
        assert_eq!(s.n_points, 13_167);
        assert_eq!(s.n_hotspots, 9);
    }

    #[test]
    fn centers_separated() {
        for_all(10, 0x9E0, |rng| {
            let d = generate(&SpatialSpec::new(100, 8, rng.next_u64()));
            for i in 0..d.centers.len() {
                for j in 0..i {
                    assert!(d.centers[i].dist2(&d.centers[j]) > 0.0);
                }
            }
        });
    }

    #[test]
    fn ndim_mixture_has_dims_and_structure() {
        for dims in [3usize, 5, 8] {
            let s = SpatialSpec::new(8000, 4, 17).with_dims(dims);
            let d = generate(&s);
            assert_eq!(d.points.len(), 8000);
            assert!(d.points.iter().all(|p| p.dims() == dims));
            assert!(d.centers.iter().all(|c| c.dims() == dims));
            // Hotspot members stay near their center (isotropic Gaussian:
            // the radius concentrates around sigma·sqrt(d)).
            let sigma = (s.extent * s.sigma_frac) as f64;
            let bound = (6.0 + 2.0 * (dims as f64).sqrt()) * sigma;
            for (p, t) in d.points.iter().zip(&d.truth) {
                if let Some(h) = t {
                    let dist = p.dist2(&d.centers[*h as usize]).sqrt();
                    assert!(dist < bound, "dist {dist} bound {bound} (d={dims})");
                }
            }
            // Deterministic in the seed.
            assert_eq!(generate(&s).points, d.points);
        }
    }

    #[test]
    fn latlon_clouds_are_valid_coordinates() {
        let s = SpatialSpec::latlon(10_000, 5, 23);
        let d = generate(&s);
        assert_eq!(d.points.len(), 10_000);
        for p in &d.points {
            assert!((-90.0..=90.0).contains(&p.x()), "lat {}", p.x());
            assert!((-180.0..=180.0).contains(&p.y()), "lon {}", p.y());
        }
        // City members are within a few hundred km of their city.
        let sigma_km = 90.0 * s.sigma_frac as f64 * 111.2;
        for (p, t) in d.points.iter().zip(&d.truth) {
            if let Some(h) = t {
                let dist = Metric::Haversine.distance(p, &d.centers[*h as usize]);
                assert!(dist < 8.0 * sigma_km, "{dist} km from city (σ {sigma_km} km)");
            }
        }
        // Cities resolvable under haversine.
        for i in 0..d.centers.len() {
            for j in 0..i {
                assert!(Metric::Haversine.distance(&d.centers[i], &d.centers[j]) > 4.0 * sigma_km);
            }
        }
        assert_eq!(generate(&s).points, d.points, "deterministic in seed");
    }

    #[test]
    fn two_d_path_is_draw_stable() {
        // The 2-D planar family must keep its historical draw sequence:
        // replicate the exact draw order inline (one center, one weight,
        // then per point: branch draw + the outlier's r/θ pair) and
        // assert the generator matches. Routing 2-D through the generic
        // d-dim path — whose outliers consume direction *normals* instead
        // of a single θ — would change the stream and fail here loudly
        // instead of silently altering every historical 2-D dataset.
        let mut spec = SpatialSpec::new(3, 1, 7);
        spec.outlier_frac = 1.0; // every point takes the outlier branch
        spec.noise_frac = 0.0;
        let d = generate(&spec);

        let mut rng = Rng::new(7);
        let e = spec.extent as f64;
        // Center draw (first candidate is always accepted) + its weight.
        let _cx = rng.range_f64(-e, e);
        let _cy = rng.range_f64(-e, e);
        let _w = 0.3 + rng.f64();
        let want: Vec<Point> = (0..3)
            .map(|_| {
                let _u = rng.f64(); // branch selector (< outlier_frac)
                let r = e * rng.range_f64(1.5, 3.0);
                let th = rng.range_f64(0.0, std::f64::consts::TAU);
                Point::new((r * th.cos()) as f32, (r * th.sin()) as f32)
            })
            .collect();
        assert_eq!(d.points, want, "2-D draw sequence must stay byte-stable");
    }
}
