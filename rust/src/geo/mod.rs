//! Spatial primitives, synthetic dataset generation, and CSV I/O.
//!
//! ## Points, dimensions, metrics
//!
//! [`Point`] is a small-vector of up to [`MAX_DIMS`] `f32` coordinates
//! stored inline (no heap indirection), so a point stays `Copy` and the
//! paper's 2-D GIS workload keeps its dense, allocation-free layout.
//! 2-D construction goes through [`Point::new`]; higher-dimensional
//! points through [`Point::from_slice`].
//!
//! [`Metric`] is the pluggable dissimilarity every layer dispatches on:
//! squared Euclidean (the paper's Eq. 1 cost term), Manhattan, and
//! haversine great-circle distance over `(lat, lon)` degree pairs. The
//! kernel layer ([`crate::runtime`]) keeps a precomputed-norm SoA fast
//! path for the 2-D squared-Euclidean case and routes every other
//! `(dims, metric)` combination through a generic unrolled path, so the
//! paper's workload does not regress while general-metric K-Medoids
//! (Mazzetto et al.; Bahmani et al.) becomes expressible.

pub mod binfmt;
pub mod datasets;
pub mod index;
pub mod io;

/// Maximum inline dimensionality of a [`Point`].
pub const MAX_DIMS: usize = 8;

/// A spatial point: up to [`MAX_DIMS`] coordinates stored inline.
///
/// The paper clusters two-dimensional GIS points; [`Point::new`] builds
/// that fast common case. Trailing unused slots are always zero so the
/// derived `PartialEq` compares logical coordinates only.
///
/// Deliberate trade-off: the inline array makes every `Point` 36 bytes
/// regardless of `dims` (vs 8 for the old `{x, y}` struct), buying
/// `Copy`, heap-free N-dim points, and zero API churn per dimension.
/// The kernel hot loops are unaffected (they run on staged flat `f32`
/// slabs, and the `PackedPoints` shuffle views stay `dims · 4` bytes
/// per point on the wire); the cost lands on `Vec<Point>` residency and
/// sequential staging scans, which `bench perf` tracks.
#[derive(Clone, Copy, PartialEq)]
pub struct Point {
    dims: u8,
    c: [f32; MAX_DIMS],
}

impl Point {
    /// 2-D constructor (the paper's GIS case).
    pub fn new(x: f32, y: f32) -> Point {
        let mut c = [0f32; MAX_DIMS];
        c[0] = x;
        c[1] = y;
        Point { dims: 2, c }
    }

    /// N-D constructor from a coordinate slice (1 ..= [`MAX_DIMS`] dims).
    pub fn from_slice(coords: &[f32]) -> Point {
        assert!(
            !coords.is_empty() && coords.len() <= MAX_DIMS,
            "point dims must be in 1..={MAX_DIMS}, got {}",
            coords.len()
        );
        let mut c = [0f32; MAX_DIMS];
        c[..coords.len()].copy_from_slice(coords);
        Point { dims: coords.len() as u8, c }
    }

    /// Origin of the given dimensionality.
    pub fn zero(dims: usize) -> Point {
        assert!((1..=MAX_DIMS).contains(&dims));
        Point { dims: dims as u8, c: [0f32; MAX_DIMS] }
    }

    #[inline]
    pub fn dims(&self) -> usize {
        self.dims as usize
    }

    /// The logical coordinates (`dims()` values).
    #[inline]
    pub fn coords(&self) -> &[f32] {
        &self.c[..self.dims as usize]
    }

    /// Coordinate `i` (`i < dims()`).
    #[inline]
    pub fn coord(&self, i: usize) -> f32 {
        self.c[i]
    }

    /// First coordinate (x, or latitude for lat/lon points).
    #[inline]
    pub fn x(&self) -> f32 {
        self.c[0]
    }

    /// Second coordinate (y, or longitude for lat/lon points).
    #[inline]
    pub fn y(&self) -> f32 {
        self.c[1]
    }

    /// Squared Euclidean distance (the paper's Eq. 1 cost term). The 2-D
    /// case keeps the exact historical expression (and therefore exact
    /// historical rounding); higher dims accumulate per-coordinate in
    /// fixed order, so results are deterministic everywhere.
    #[inline]
    pub fn dist2(&self, other: &Point) -> f64 {
        debug_assert_eq!(self.dims, other.dims, "dims mismatch in dist2");
        if self.dims == 2 {
            let dx = (self.c[0] - other.c[0]) as f64;
            let dy = (self.c[1] - other.c[1]) as f64;
            return dx * dx + dy * dy;
        }
        let mut acc = 0f64;
        for i in 0..self.dims as usize {
            let d = (self.c[i] - other.c[i]) as f64;
            acc += d * d;
        }
        acc
    }
}

impl Default for Point {
    /// 2-D origin (the historical `Point::default()`).
    fn default() -> Point {
        Point::new(0.0, 0.0)
    }
}

impl std::fmt::Debug for Point {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Point(")?;
        for (i, v) in self.coords().iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v}")?;
        }
        write!(f, ")")
    }
}

/// Mean Earth radius in kilometers (IUGG R1), used by [`Metric::Haversine`].
pub const EARTH_RADIUS_KM: f64 = 6371.0088;

/// Pluggable point-to-point dissimilarity, dispatched through every
/// layer: kernels ([`crate::runtime`]), MapReduce mappers/reducers, and
/// all five solvers.
///
/// | Metric | Coordinates | Value |
/// |---|---|---|
/// | `SqEuclidean` | any dims | squared L2 (paper Eq. 1; *not* a metric — no triangle inequality) |
/// | `Manhattan` | any dims | L1 distance (a true metric) |
/// | `Haversine` | `(lat, lon)` degrees, dims = 2 | great-circle distance in km (a true metric) |
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Metric {
    /// Squared Euclidean — the paper's cost term; kernels keep the
    /// precomputed-norm fast path for the 2-D case.
    #[default]
    SqEuclidean,
    /// L1 / city-block distance.
    Manhattan,
    /// Great-circle distance over `(lat, lon)` degree pairs, in
    /// kilometers. Requires `dims == 2`.
    Haversine,
}

impl Metric {
    pub const ALL: [Metric; 3] = [Metric::SqEuclidean, Metric::Manhattan, Metric::Haversine];

    pub fn name(&self) -> &'static str {
        match self {
            Metric::SqEuclidean => "sq_euclidean",
            Metric::Manhattan => "manhattan",
            Metric::Haversine => "haversine",
        }
    }

    pub fn parse(s: &str) -> Option<Metric> {
        match s {
            "sq_euclidean" | "sqeuclidean" | "euclidean" | "l2sq" => Some(Metric::SqEuclidean),
            "manhattan" | "l1" | "cityblock" => Some(Metric::Manhattan),
            "haversine" | "greatcircle" => Some(Metric::Haversine),
            _ => None,
        }
    }

    /// Does this metric accept `dims`-dimensional points?
    pub fn supports_dims(&self, dims: usize) -> bool {
        match self {
            Metric::Haversine => dims == 2,
            _ => (1..=MAX_DIMS).contains(&dims),
        }
    }

    /// True when the arithmetic mean minimizes the within-cluster cost —
    /// i.e. when the k-means mean-update is valid. Only squared Euclidean
    /// qualifies; for every other metric k-means must fall back to a
    /// medoid update.
    pub fn mean_is_minimizer(&self) -> bool {
        matches!(self, Metric::SqEuclidean)
    }

    /// Dissimilarity in `f64` — the serial/oracle path. For
    /// `SqEuclidean` this is exactly [`Point::dist2`] (same rounding).
    pub fn distance(&self, a: &Point, b: &Point) -> f64 {
        match self {
            Metric::SqEuclidean => a.dist2(b),
            Metric::Manhattan => {
                debug_assert_eq!(a.dims(), b.dims());
                let mut acc = 0f64;
                for i in 0..a.dims() {
                    acc += ((a.coord(i) - b.coord(i)) as f64).abs();
                }
                acc
            }
            Metric::Haversine => haversine_f64(
                a.coord(0) as f64,
                a.coord(1) as f64,
                b.coord(0) as f64,
                b.coord(1) as f64,
            ),
        }
    }

    /// Dissimilarity in `f32` over raw coordinate slices — the kernel
    /// form used by the generic block paths in [`crate::runtime`].
    /// Deterministic fixed-order accumulation; never NaN for finite
    /// inputs (the haversine argument is clamped to `[0, 1]`).
    #[inline]
    pub fn distance_f32(&self, dims: usize, a: &[f32], b: &[f32]) -> f32 {
        match self {
            Metric::SqEuclidean => {
                let mut acc = 0f32;
                for i in 0..dims {
                    let d = a[i] - b[i];
                    acc += d * d;
                }
                acc
            }
            Metric::Manhattan => {
                let mut acc = 0f32;
                for i in 0..dims {
                    acc += (a[i] - b[i]).abs();
                }
                acc
            }
            Metric::Haversine => {
                haversine_f64(a[0] as f64, a[1] as f64, b[0] as f64, b[1] as f64) as f32
            }
        }
    }

    /// How far a medoid "moved" between iterations, for observer
    /// telemetry: the metric's own distance, except squared Euclidean
    /// reports the (historical) plain Euclidean displacement.
    pub fn displacement(&self, a: &Point, b: &Point) -> f64 {
        match self {
            Metric::SqEuclidean => a.dist2(b).sqrt(),
            _ => self.distance(a, b),
        }
    }
}

/// Great-circle distance between `(lat1, lon1)` and `(lat2, lon2)` in
/// degrees, in kilometers. The half-angle argument is clamped to `[0, 1]`
/// so padded/garbage coordinates can never produce NaN.
fn haversine_f64(lat1: f64, lon1: f64, lat2: f64, lon2: f64) -> f64 {
    let p1 = lat1.to_radians();
    let p2 = lat2.to_radians();
    let dp = (lat2 - lat1).to_radians();
    let dl = (lon2 - lon1).to_radians();
    let sp = (dp / 2.0).sin();
    let sl = (dl / 2.0).sin();
    let h = (sp * sp + p1.cos() * p2.cos() * sl * sl).clamp(0.0, 1.0);
    2.0 * EARTH_RADIUS_KM * h.sqrt().asin()
}

/// A readable sequence of points, abstracting over the storage layout:
/// an owned/borrowed `[Point]` slice, or zero-copy `&[f32]` views over
/// MapReduce shuffle bytes ([`crate::util::codec::PackedPoints`]). The
/// kernel block-packing ops ([`crate::runtime::ops`]) and the
/// medoid-update step consume this trait so the reduce side never has to
/// materialize a `Vec<Point>`.
pub trait PointSource {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Dimensionality of the stored points (0 for an empty source).
    fn dims(&self) -> usize;
    /// Point at index `i` (`i < len()`).
    fn get(&self, i: usize) -> Point;
    /// Write points `start..start + n` as interleaved coordinate runs
    /// (`dims()` f32s per point) into `dst[..dims() * n]`.
    /// Implementations may override with bulk copies.
    fn fill_coords(&self, start: usize, n: usize, dst: &mut [f32]) {
        let d = self.dims();
        for i in 0..n {
            let p = self.get(start + i);
            dst[d * i..d * (i + 1)].copy_from_slice(p.coords());
        }
    }
}

impl PointSource for [Point] {
    fn len(&self) -> usize {
        <[Point]>::len(self)
    }
    fn dims(&self) -> usize {
        self.first().map(|p| p.dims()).unwrap_or(0)
    }
    fn get(&self, i: usize) -> Point {
        self[i]
    }
}

/// A [`PointSource`] whose points carry non-negative f32 weights — the
/// first-class representation behind the weighted-coreset pipeline
/// ([`crate::clustering::coreset`]): a coreset point of weight `w` stands
/// for `w` original points, so every weighted cost is
/// `Σ w_i · d(p_i, ·)`. A weight of exactly 1.0 for every point reduces
/// every weighted op to its unweighted twin (asserted by tests).
pub trait WeightedSource: PointSource {
    /// Weight of point `i` (`i < len()`).
    fn weight(&self, i: usize) -> f32;
    /// Write weights `start..start + n` into `dst[..n]`. Implementations
    /// with contiguous weight storage override with bulk copies.
    fn fill_weights(&self, start: usize, n: usize, dst: &mut [f32]) {
        for (j, slot) in dst.iter_mut().enumerate().take(n) {
            *slot = self.weight(start + j);
        }
    }
    /// Total weight (`Σ w_i`, the weighted analogue of `len()`).
    fn total_weight(&self) -> f64 {
        (0..self.len()).map(|i| self.weight(i) as f64).sum()
    }
}

/// Zero-copy weighted view pairing any [`PointSource`] with a parallel
/// weight slice — `Weighted<[Point]>` is the in-memory `Weighted<Point>`
/// sequence the coreset driver and the weighted update kernels consume.
pub struct Weighted<'a, S: PointSource + ?Sized> {
    source: &'a S,
    weights: &'a [f32],
}

impl<'a, S: PointSource + ?Sized> Weighted<'a, S> {
    /// Pair `source` with per-point `weights` (lengths must match).
    pub fn new(source: &'a S, weights: &'a [f32]) -> Weighted<'a, S> {
        assert_eq!(
            source.len(),
            weights.len(),
            "weighted view needs one weight per point"
        );
        Weighted { source, weights }
    }
    pub fn weights(&self) -> &[f32] {
        self.weights
    }
}

impl<S: PointSource + ?Sized> PointSource for Weighted<'_, S> {
    fn len(&self) -> usize {
        self.source.len()
    }
    fn dims(&self) -> usize {
        self.source.dims()
    }
    fn get(&self, i: usize) -> Point {
        self.source.get(i)
    }
    fn fill_coords(&self, start: usize, n: usize, dst: &mut [f32]) {
        self.source.fill_coords(start, n, dst)
    }
}

impl<S: PointSource + ?Sized> WeightedSource for Weighted<'_, S> {
    fn weight(&self, i: usize) -> f32 {
        self.weights[i]
    }
    fn fill_weights(&self, start: usize, n: usize, dst: &mut [f32]) {
        dst[..n].copy_from_slice(&self.weights[start..start + n]);
    }
}

/// Axis-aligned 2-D bounding box (diagnostics over the paper's planar
/// GIS datasets; not used by the N-dimensional solver paths).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BBox {
    pub min_x: f32,
    pub min_y: f32,
    pub max_x: f32,
    pub max_y: f32,
}

impl BBox {
    pub fn of(points: &[Point]) -> Option<BBox> {
        let first = points.first()?;
        let mut b =
            BBox { min_x: first.x(), min_y: first.y(), max_x: first.x(), max_y: first.y() };
        for p in points {
            b.min_x = b.min_x.min(p.x());
            b.min_y = b.min_y.min(p.y());
            b.max_x = b.max_x.max(p.x());
            b.max_y = b.max_y.max(p.y());
        }
        Some(b)
    }

    pub fn contains(&self, p: &Point) -> bool {
        p.x() >= self.min_x && p.x() <= self.max_x && p.y() >= self.min_y && p.y() <= self.max_y
    }

    pub fn width(&self) -> f32 {
        self.max_x - self.min_x
    }
    pub fn height(&self) -> f32 {
        self.max_y - self.min_y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    #[test]
    fn dist2_basic() {
        let a = Point::new(0.0, 0.0);
        let b = Point::new(3.0, 4.0);
        assert_eq!(a.dist2(&b), 25.0);
        assert_eq!(a.dist2(&a), 0.0);
    }

    #[test]
    fn ndim_point_construction_and_accessors() {
        let p = Point::from_slice(&[1.0, 2.0, 3.0]);
        assert_eq!(p.dims(), 3);
        assert_eq!(p.coords(), &[1.0, 2.0, 3.0]);
        assert_eq!((p.x(), p.y(), p.coord(2)), (1.0, 2.0, 3.0));
        // 2-D constructor and from_slice agree (incl. equality).
        assert_eq!(Point::new(5.0, -1.0), Point::from_slice(&[5.0, -1.0]));
        assert_eq!(Point::zero(4).coords(), &[0.0; 4]);
        // dist2 generalizes: 1² x 8 = 8.
        let a = Point::zero(8);
        let b = Point::from_slice(&[1.0; 8]);
        assert_eq!(a.dist2(&b), 8.0);
    }

    #[test]
    #[should_panic(expected = "dims must be")]
    fn oversized_point_rejected() {
        let _ = Point::from_slice(&[0.0; MAX_DIMS + 1]);
    }

    #[test]
    fn metric_parse_roundtrip_and_support() {
        for m in Metric::ALL {
            assert_eq!(Metric::parse(m.name()), Some(m));
        }
        assert_eq!(Metric::parse("nope"), None);
        assert!(Metric::SqEuclidean.supports_dims(8));
        assert!(Metric::Manhattan.supports_dims(3));
        assert!(Metric::Haversine.supports_dims(2));
        assert!(!Metric::Haversine.supports_dims(3));
        assert!(!Metric::SqEuclidean.supports_dims(MAX_DIMS + 1));
        assert!(Metric::SqEuclidean.mean_is_minimizer());
        assert!(!Metric::Manhattan.mean_is_minimizer());
        assert!(!Metric::Haversine.mean_is_minimizer());
    }

    #[test]
    fn sq_euclidean_distance_is_dist2() {
        for_all(30, 0xD157, |rng| {
            let a = Point::new(rng.f64() as f32 * 10.0, rng.f64() as f32 * 10.0);
            let b = Point::new(rng.f64() as f32 * 10.0, rng.f64() as f32 * 10.0);
            assert_eq!(Metric::SqEuclidean.distance(&a, &b), a.dist2(&b));
        });
    }

    #[test]
    fn manhattan_known_values() {
        let a = Point::from_slice(&[0.0, 0.0, 0.0]);
        let b = Point::from_slice(&[1.0, -2.0, 3.0]);
        assert_eq!(Metric::Manhattan.distance(&a, &b), 6.0);
        assert_eq!(Metric::Manhattan.distance_f32(3, a.coords(), b.coords()), 6.0);
    }

    #[test]
    fn haversine_city_spot_checks() {
        // Known great-circle distances (km), ±1% tolerance: the classic
        // sanity anchors for a haversine implementation.
        let cases: [((f32, f32), (f32, f32), f64); 3] = [
            // Paris (48.8566, 2.3522) — London (51.5074, -0.1278): ~344 km
            ((48.8566, 2.3522), (51.5074, -0.1278), 343.5),
            // New York (40.7128, -74.0060) — Los Angeles (34.0522, -118.2437): ~3936 km
            ((40.7128, -74.0060), (34.0522, -118.2437), 3935.7),
            // Sydney (-33.8688, 151.2093) — Melbourne (-37.8136, 144.9631): ~713 km
            ((-33.8688, 151.2093), (-37.8136, 144.9631), 713.4),
        ];
        for ((la1, lo1), (la2, lo2), want) in cases {
            let a = Point::new(la1, lo1);
            let b = Point::new(la2, lo2);
            let got = Metric::Haversine.distance(&a, &b);
            assert!((got - want).abs() < 0.01 * want, "{got} vs {want}");
            // f32 kernel form agrees to f32 precision.
            let got32 = Metric::Haversine.distance_f32(2, a.coords(), b.coords()) as f64;
            assert!((got32 - want).abs() < 0.02 * want, "{got32} vs {want}");
        }
        // Antipodal clamp: no NaN, ~half the circumference.
        let d = Metric::Haversine.distance(&Point::new(0.0, 0.0), &Point::new(0.0, 180.0));
        assert!((d - std::f64::consts::PI * EARTH_RADIUS_KM).abs() < 1.0, "{d}");
    }

    /// Random point with coordinates suited to the metric.
    fn rand_point(rng: &mut Rng, dims: usize, metric: Metric) -> Point {
        let coords: Vec<f32> = (0..dims)
            .map(|i| match metric {
                Metric::Haversine if i == 0 => rng.range_f64(-89.0, 89.0) as f32,
                Metric::Haversine => rng.range_f64(-179.0, 179.0) as f32,
                _ => rng.range_f64(-100.0, 100.0) as f32,
            })
            .collect();
        Point::from_slice(&coords)
    }

    #[test]
    fn metric_axioms_identity_symmetry_nonnegativity() {
        for metric in Metric::ALL {
            for dims in [2usize, 3, 8] {
                if !metric.supports_dims(dims) {
                    continue;
                }
                for_all(40, 0xA10 ^ dims as u64, |rng| {
                    let a = rand_point(rng, dims, metric);
                    let b = rand_point(rng, dims, metric);
                    let dab = metric.distance(&a, &b);
                    assert!(dab >= 0.0, "{metric:?} nonnegativity");
                    assert!(metric.distance(&a, &a) == 0.0, "{metric:?} identity");
                    let dba = metric.distance(&b, &a);
                    assert!(
                        (dab - dba).abs() <= 1e-9 * dab.max(1.0),
                        "{metric:?} symmetry: {dab} vs {dba}"
                    );
                });
            }
        }
    }

    #[test]
    fn metric_axiom_triangle_inequality_for_true_metrics() {
        // SqEuclidean is deliberately excluded: squared distances violate
        // the triangle inequality (that is why it is "sq_", not a metric).
        for (metric, dims_list) in
            [(Metric::Manhattan, &[2usize, 3, 8][..]), (Metric::Haversine, &[2][..])]
        {
            for &dims in dims_list {
                for_all(60, 0x7121 ^ dims as u64, |rng| {
                    let a = rand_point(rng, dims, metric);
                    let b = rand_point(rng, dims, metric);
                    let c = rand_point(rng, dims, metric);
                    let ab = metric.distance(&a, &b);
                    let bc = metric.distance(&b, &c);
                    let ac = metric.distance(&a, &c);
                    assert!(
                        ac <= ab + bc + 1e-6 * (ab + bc).max(1.0),
                        "{metric:?} d={dims}: {ac} > {ab} + {bc}"
                    );
                });
            }
        }
    }

    #[test]
    fn point_source_slice_impl() {
        let pts = vec![Point::new(1.0, 2.0), Point::new(3.0, 4.0), Point::new(5.0, 6.0)];
        let src: &[Point] = &pts;
        assert_eq!(PointSource::len(src), 3);
        assert_eq!(PointSource::dims(src), 2);
        assert!(!PointSource::is_empty(src));
        assert_eq!(PointSource::get(src, 1), Point::new(3.0, 4.0));
        let mut buf = [0f32; 4];
        src.fill_coords(1, 2, &mut buf);
        assert_eq!(buf, [3.0, 4.0, 5.0, 6.0]);
        // 3-D fill interleaves dims-wide.
        let pts3 = vec![Point::from_slice(&[1.0, 2.0, 3.0]), Point::from_slice(&[4.0, 5.0, 6.0])];
        let src3: &[Point] = &pts3;
        assert_eq!(PointSource::dims(src3), 3);
        let mut buf3 = [0f32; 6];
        src3.fill_coords(0, 2, &mut buf3);
        assert_eq!(buf3, [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn weighted_view_passes_points_through_and_serves_weights() {
        let pts = vec![Point::new(1.0, 2.0), Point::new(3.0, 4.0), Point::new(5.0, 6.0)];
        let ws = [2.0f32, 0.5, 1.0];
        let view = Weighted::new(pts.as_slice(), &ws);
        assert_eq!(PointSource::len(&view), 3);
        assert_eq!(PointSource::dims(&view), 2);
        assert_eq!(view.get(1), Point::new(3.0, 4.0));
        assert_eq!(view.weight(0), 2.0);
        assert_eq!(view.total_weight(), 3.5);
        let mut wbuf = [0f32; 2];
        view.fill_weights(1, 2, &mut wbuf);
        assert_eq!(wbuf, [0.5, 1.0]);
        let mut cbuf = [0f32; 4];
        view.fill_coords(1, 2, &mut cbuf);
        assert_eq!(cbuf, [3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    #[should_panic(expected = "one weight per point")]
    fn weighted_view_length_mismatch_rejected() {
        let pts = vec![Point::new(0.0, 0.0)];
        let _ = Weighted::new(pts.as_slice(), &[1.0, 2.0]);
    }

    #[test]
    fn bbox_bounds_all() {
        let pts = vec![Point::new(1.0, 5.0), Point::new(-2.0, 3.0), Point::new(0.5, -1.0)];
        let b = BBox::of(&pts).unwrap();
        assert_eq!(b.min_x, -2.0);
        assert_eq!(b.max_y, 5.0);
        assert!(pts.iter().all(|p| b.contains(p)));
        assert!(BBox::of(&[]).is_none());
    }
}
