//! Spatial primitives, synthetic dataset generation, and CSV I/O.

pub mod datasets;
pub mod io;

/// A 2-D spatial point (the paper clusters two-dimensional GIS points).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Point {
    pub x: f32,
    pub y: f32,
}

impl Point {
    pub fn new(x: f32, y: f32) -> Point {
        Point { x, y }
    }

    /// Squared Euclidean distance (the paper's Eq. 1 cost term).
    #[inline]
    pub fn dist2(&self, other: &Point) -> f64 {
        let dx = (self.x - other.x) as f64;
        let dy = (self.y - other.y) as f64;
        dx * dx + dy * dy
    }
}

/// A readable sequence of 2-D points, abstracting over the storage
/// layout: an owned/borrowed `[Point]` slice, or zero-copy `&[f32]`
/// views over MapReduce shuffle bytes
/// ([`crate::util::codec::PackedPoints`]). The kernel block-packing ops
/// ([`crate::runtime::ops`]) and the medoid-update step consume this
/// trait so the reduce side never has to materialize a `Vec<Point>`.
pub trait PointSource {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Point at index `i` (`i < len()`).
    fn get(&self, i: usize) -> Point;
    /// Write points `start..start + n` as interleaved `x, y` f32 pairs
    /// into `dst[..2 * n]`. Implementations may override with bulk copies.
    fn fill_coords(&self, start: usize, n: usize, dst: &mut [f32]) {
        for i in 0..n {
            let p = self.get(start + i);
            dst[2 * i] = p.x;
            dst[2 * i + 1] = p.y;
        }
    }
}

impl PointSource for [Point] {
    fn len(&self) -> usize {
        <[Point]>::len(self)
    }
    fn get(&self, i: usize) -> Point {
        self[i]
    }
}

/// Axis-aligned bounding box.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BBox {
    pub min_x: f32,
    pub min_y: f32,
    pub max_x: f32,
    pub max_y: f32,
}

impl BBox {
    pub fn of(points: &[Point]) -> Option<BBox> {
        let first = points.first()?;
        let mut b = BBox { min_x: first.x, min_y: first.y, max_x: first.x, max_y: first.y };
        for p in points {
            b.min_x = b.min_x.min(p.x);
            b.min_y = b.min_y.min(p.y);
            b.max_x = b.max_x.max(p.x);
            b.max_y = b.max_y.max(p.y);
        }
        Some(b)
    }

    pub fn contains(&self, p: &Point) -> bool {
        p.x >= self.min_x && p.x <= self.max_x && p.y >= self.min_y && p.y <= self.max_y
    }

    pub fn width(&self) -> f32 {
        self.max_x - self.min_x
    }
    pub fn height(&self) -> f32 {
        self.max_y - self.min_y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist2_basic() {
        let a = Point::new(0.0, 0.0);
        let b = Point::new(3.0, 4.0);
        assert_eq!(a.dist2(&b), 25.0);
        assert_eq!(a.dist2(&a), 0.0);
    }

    #[test]
    fn point_source_slice_impl() {
        let pts = vec![Point::new(1.0, 2.0), Point::new(3.0, 4.0), Point::new(5.0, 6.0)];
        let src: &[Point] = &pts;
        assert_eq!(PointSource::len(src), 3);
        assert!(!PointSource::is_empty(src));
        assert_eq!(PointSource::get(src, 1), Point::new(3.0, 4.0));
        let mut buf = [0f32; 4];
        src.fill_coords(1, 2, &mut buf);
        assert_eq!(buf, [3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn bbox_bounds_all() {
        let pts = vec![Point::new(1.0, 5.0), Point::new(-2.0, 3.0), Point::new(0.5, -1.0)];
        let b = BBox::of(&pts).unwrap();
        assert_eq!(b.min_x, -2.0);
        assert_eq!(b.max_y, 5.0);
        assert!(pts.iter().all(|p| b.contains(p)));
        assert!(BBox::of(&[]).is_none());
    }
}
