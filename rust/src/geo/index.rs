//! Shared conservative spatial index over a medoid set.
//!
//! One implementation serves every candidate-pruning consumer in the
//! repo: [`crate::serve::ClusterModel`] queries, the batch label pass,
//! and the triangle-inequality pruned assignment lane in
//! [`crate::runtime::pruned`]. It generalizes the 2-D squared-Euclidean
//! grid that used to live privately in `serve/model.rs`:
//!
//! - **2-D** (both squared-Euclidean and Manhattan): a `g × g` uniform
//!   grid over the padded medoid bounding box, `g = ⌈√(4k)⌉` clamped to
//!   `[4, 32]` — byte-for-byte the legacy serve geometry.
//! - **3 ≤ d ≤ 8**: a conservative rect-bound variant — a uniform
//!   `g^d` grid (a k-d bisection of fixed depth per axis) with `g`
//!   chosen so the cell count stays ≤ 4096. Coarser per axis as `d`
//!   grows, but every bound is still exact rectangle geometry, so the
//!   pruning guarantee is unchanged.
//! - **Haversine** has no index (no axis-aligned rect bounds on the
//!   sphere); `build` returns `None` and callers fall back to the full
//!   medoid slab.
//!
//! Correctness contract (the reason every consumer can share this): a
//! cell keeps medoid `m` iff the *minimum* rect-to-`m` dissimilarity is
//! within `slack` of the best medoid's *maximum* over the rect, where
//! `slack` is 1e-3 of the largest coordinate-norm scale in play — more
//! than three orders of magnitude above the f32 kernel error. A pruned
//! medoid therefore can never be the f32 kernel's argmin (not even via
//! a tie) for any query inside the cell, so candidate-restricted scans
//! return the dense answer bit-for-bit. Queries outside the padded box
//! return `None` and must take the full-slab path.
//!
//! Each cell additionally records [`IndexCell::excluded_floor`]: a true
//! lower bound (in *metric* space — square roots for squared Euclidean)
//! on the distance from anywhere in the cell to the nearest *excluded*
//! medoid. The pruned assignment lane uses it to keep its per-point
//! lower bounds sound when a resolve only scanned the candidate list.

use crate::geo::{BBox, Metric, Point, MAX_DIMS};

/// One grid cell: ascending candidate medoid indices (ascending order
/// preserves the dense kernel's first-wins tie policy) plus the
/// metric-space floor to the nearest excluded medoid (`INFINITY` when
/// nothing was excluded).
pub struct IndexCell {
    pub cands: Vec<u32>,
    pub excluded_floor: f64,
}

/// Conservative per-cell candidate lists over a medoid set. See the
/// module docs for the geometry and the pruning guarantee.
pub struct SpatialIndex {
    dims: usize,
    lo: [f64; MAX_DIMS],
    cell: [f64; MAX_DIMS],
    g: usize,
    k: usize,
    cells: Vec<IndexCell>,
}

impl SpatialIndex {
    /// Build an index over `medoids`, or `None` when no index applies
    /// (fewer than two medoids, Haversine, or non-finite geometry).
    pub fn build(medoids: &[Point], metric: Metric) -> Option<SpatialIndex> {
        if medoids.len() < 2 || metric == Metric::Haversine {
            return None;
        }
        let dims = medoids[0].dims();
        debug_assert!(medoids.iter().all(|m| m.dims() == dims));
        let mut lo = [f64::INFINITY; MAX_DIMS];
        let mut hi = [f64::NEG_INFINITY; MAX_DIMS];
        if dims == 2 {
            // Legacy serve geometry, kept byte-identical: pad by half
            // the larger f32 extent (floored at 1) so typical queries
            // near the hull still hit a cell.
            let bbox = BBox::of(medoids)?;
            let pad = 0.5 * f32::max(bbox.width(), bbox.height()).max(1.0) as f64;
            lo[0] = bbox.min_x as f64 - pad;
            lo[1] = bbox.min_y as f64 - pad;
            hi[0] = bbox.max_x as f64 + pad;
            hi[1] = bbox.max_y as f64 + pad;
        } else {
            for m in medoids {
                for (d, &c) in m.coords().iter().enumerate() {
                    lo[d] = lo[d].min(c as f64);
                    hi[d] = hi[d].max(c as f64);
                }
            }
            let extent =
                (0..dims).map(|d| hi[d] - lo[d]).fold(0.0f64, f64::max).max(1.0);
            let pad = 0.5 * extent;
            for d in 0..dims {
                lo[d] -= pad;
                hi[d] += pad;
            }
        }
        if (0..dims).any(|d| !(lo[d].is_finite() && hi[d].is_finite())) {
            return None;
        }
        let g = if dims == 2 {
            (((4 * medoids.len()) as f64).sqrt().ceil() as usize).clamp(4, 32)
        } else {
            // Keep the total cell count ≤ 4096 (≈ 4096^(1/d) per axis).
            ((4096f64).powf(1.0 / dims as f64).floor() as usize).clamp(2, 16)
        };
        let mut cell = [0.0f64; MAX_DIMS];
        for d in 0..dims {
            cell[d] = (hi[d] - lo[d]) / g as f64;
        }

        // Pruning slack: 1e-3 of the largest coordinate-norm scale among
        // the medoids and the padded box corners, floored at 1 — the
        // same margin the serve grid has always used, generalized per
        // metric (squared norm for sq-Euclidean, L1 norm for Manhattan).
        let mut scale: f64 = 1.0;
        for m in medoids {
            scale = scale.max(norm_scale(metric, m.coords()));
        }
        scale = scale.max(corner_norm_scale(metric, dims, &lo, &hi));
        let slack = 1e-3 * scale;

        let n_cells = g.pow(dims as u32);
        let mut cells = Vec::with_capacity(n_cells);
        let mut idx = [0usize; MAX_DIMS];
        for _ in 0..n_cells {
            let mut r_lo = [0.0f64; MAX_DIMS];
            let mut r_hi = [0.0f64; MAX_DIMS];
            for d in 0..dims {
                r_lo[d] = lo[d] + idx[d] as f64 * cell[d];
                r_hi[d] = r_lo[d] + cell[d];
            }
            let ub = medoids
                .iter()
                .map(|m| rect_max(metric, dims, &r_lo, &r_hi, m))
                .fold(f64::INFINITY, f64::min);
            let mut cands = Vec::new();
            let mut excluded_floor = f64::INFINITY;
            for (j, m) in medoids.iter().enumerate() {
                let min_d = rect_min(metric, dims, &r_lo, &r_hi, m);
                if min_d <= ub + slack {
                    cands.push(j as u32);
                } else {
                    // Metric-space floor: √ for squared Euclidean.
                    let floor = match metric {
                        Metric::SqEuclidean => min_d.sqrt(),
                        _ => min_d,
                    };
                    excluded_floor = excluded_floor.min(floor);
                }
            }
            debug_assert!(!cands.is_empty());
            cells.push(IndexCell { cands, excluded_floor });
            // Row-major increment, last dim fastest.
            for d in (0..dims).rev() {
                idx[d] += 1;
                if idx[d] < g {
                    break;
                }
                idx[d] = 0;
            }
        }
        Some(SpatialIndex { dims, lo, cell, g, k: medoids.len(), cells })
    }

    /// Number of medoids indexed.
    pub fn k(&self) -> usize {
        self.k
    }

    pub fn dims(&self) -> usize {
        self.dims
    }

    /// The cell covering `p`, or `None` when `p` falls outside the
    /// padded box (callers must then scan the full medoid slab).
    pub fn cell(&self, p: &Point) -> Option<&IndexCell> {
        let mut at = 0usize;
        for d in 0..self.dims {
            let f = (p.coord(d) as f64 - self.lo[d]) / self.cell[d];
            if !(0.0..=self.g as f64).contains(&f) {
                return None;
            }
            at = at * self.g + (f as usize).min(self.g - 1);
        }
        Some(&self.cells[at])
    }
}

/// Squared norm (sq-Euclidean) or L1 norm (Manhattan) of a coordinate
/// vector — the scale whose 1e-3 multiple dominates f32 kernel error.
fn norm_scale(metric: Metric, c: &[f32]) -> f64 {
    match metric {
        Metric::SqEuclidean => c.iter().map(|&v| (v as f64) * (v as f64)).sum(),
        _ => c.iter().map(|&v| (v as f64).abs()).sum(),
    }
}

/// Largest norm over the 2^d box corners, computed per-axis (the
/// maximizing corner takes the larger |coordinate| on every axis).
fn corner_norm_scale(metric: Metric, dims: usize, lo: &[f64], hi: &[f64]) -> f64 {
    let mut acc = 0.0f64;
    for d in 0..dims {
        let a = lo[d].abs().max(hi[d].abs());
        acc += match metric {
            Metric::SqEuclidean => a * a,
            _ => a,
        };
    }
    acc
}

/// Minimum dissimilarity from anywhere in the rect to `m` (0 inside).
fn rect_min(metric: Metric, dims: usize, lo: &[f64], hi: &[f64], m: &Point) -> f64 {
    let mut acc = 0.0f64;
    for d in 0..dims {
        let c = m.coord(d) as f64;
        let gap = (lo[d] - c).max(0.0).max(c - hi[d]);
        acc += match metric {
            Metric::SqEuclidean => gap * gap,
            _ => gap,
        };
    }
    acc
}

/// Maximum dissimilarity from anywhere in the rect to `m`.
fn rect_max(metric: Metric, dims: usize, lo: &[f64], hi: &[f64], m: &Point) -> f64 {
    let mut acc = 0.0f64;
    for d in 0..dims {
        let c = m.coord(d) as f64;
        let far = (c - lo[d]).abs().max((c - hi[d]).abs());
        acc += match metric {
            Metric::SqEuclidean => far * far,
            _ => far,
        };
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    fn rand_points(rng: &mut Rng, n: usize, dims: usize, spread: f64) -> Vec<Point> {
        (0..n)
            .map(|_| {
                let c: Vec<f32> = (0..dims)
                    .map(|_| (rng.f64() * spread - spread / 2.0) as f32)
                    .collect();
                Point::from_slice(&c)
            })
            .collect()
    }

    fn brute_argmin(metric: Metric, p: &Point, medoids: &[Point]) -> usize {
        let mut best = f64::INFINITY;
        let mut at = 0;
        for (j, m) in medoids.iter().enumerate() {
            let d = metric.distance(p, m);
            if d < best {
                best = d;
                at = j;
            }
        }
        at
    }

    /// The argmin medoid is always in the candidate list, and the
    /// excluded floor never exceeds the true distance to any excluded
    /// medoid — for every metric/dims combination that builds an index.
    #[test]
    fn candidates_contain_argmin_and_floors_are_sound() {
        for &(metric, dims) in &[
            (Metric::SqEuclidean, 2usize),
            (Metric::SqEuclidean, 3),
            (Metric::SqEuclidean, 8),
            (Metric::Manhattan, 2),
            (Metric::Manhattan, 5),
        ] {
            for_all(10, 0x1D3 ^ dims as u64, |rng| {
                let k = 2 + rng.below(10);
                let medoids = rand_points(rng, k, dims, 2e4);
                let ix = SpatialIndex::build(&medoids, metric).expect("index builds");
                assert_eq!(ix.k(), k);
                for p in rand_points(rng, 100, dims, 5e4) {
                    let Some(cell) = ix.cell(&p) else { continue };
                    let best = brute_argmin(metric, &p, &medoids) as u32;
                    assert!(
                        cell.cands.contains(&best),
                        "{metric:?} d={dims}: argmin {best} pruned from {:?}",
                        cell.cands
                    );
                    assert!(cell.cands.windows(2).all(|w| w[0] < w[1]), "cands not ascending");
                    for j in 0..k as u32 {
                        if !cell.cands.contains(&j) {
                            let d = metric.distance(&p, &medoids[j as usize]);
                            let d_metric =
                                if metric == Metric::SqEuclidean { d.sqrt() } else { d };
                            assert!(
                                cell.excluded_floor <= d_metric + 1e-9,
                                "floor {} above excluded medoid {j} at {d_metric}",
                                cell.excluded_floor
                            );
                        }
                    }
                }
            });
        }
    }

    #[test]
    fn haversine_and_degenerate_sets_have_no_index() {
        let two = vec![Point::new(0.0, 0.0), Point::new(1.0, 1.0)];
        assert!(SpatialIndex::build(&two, Metric::Haversine).is_none());
        assert!(SpatialIndex::build(&two[..1], Metric::SqEuclidean).is_none());
        assert!(SpatialIndex::build(&two, Metric::SqEuclidean).is_some());
    }

    /// Queries far outside the padded box take the `None` (full-slab)
    /// path instead of a wrong cell.
    #[test]
    fn out_of_box_queries_return_none() {
        let medoids = vec![Point::new(0.0, 0.0), Point::new(10.0, 10.0)];
        let ix = SpatialIndex::build(&medoids, Metric::SqEuclidean).unwrap();
        assert!(ix.cell(&Point::new(1e6, 1e6)).is_none());
        assert!(ix.cell(&Point::new(5.0, 5.0)).is_some());
    }
}
