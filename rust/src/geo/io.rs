//! CSV import/export for spatial points (the interchange format the
//! paper's HDFS ingest would use: one coordinate row per line —
//! `x,y` for the planar GIS case, `c0,c1,...,cd-1` for d-dim data).

use super::{Point, MAX_DIMS};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Typed rejection for a NaN/infinite coordinate in a CSV row. `"nan"`
/// and `"inf"` parse as valid `f32`s, so without this check they would
/// sail through ingest and poison every distance kernel downstream.
/// [`read_csv`] wraps it with `file:line` context; recover the variant
/// from the `anyhow` chain with `err.downcast_ref::<NonFiniteCoord>()`.
#[derive(Debug, Clone, PartialEq)]
pub struct NonFiniteCoord {
    /// 0-based coordinate index within the row.
    pub index: usize,
    /// The offending token as written in the file.
    pub token: String,
}

impl std::fmt::Display for NonFiniteCoord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "coordinate {} ({:?}) is not finite", self.index, self.token)
    }
}

impl std::error::Error for NonFiniteCoord {}

/// Typed rejection for a row whose dimensionality disagrees with the
/// rows before it. Every dataset surface (CSV, the binary format in
/// [`crate::geo::binfmt`], the in-memory ingest asserts) requires one
/// uniform dimensionality; recover the variant from the `anyhow` chain
/// with `err.downcast_ref::<MixedDims>()`.
#[derive(Debug, Clone, PartialEq)]
pub struct MixedDims {
    /// 1-based line (CSV) or 0-based point index (in-memory slices).
    pub line: usize,
    /// Dimensionality of the offending row.
    pub got: usize,
    /// Dimensionality established by the earlier rows.
    pub expected: usize,
}

impl std::fmt::Display for MixedDims {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "row {} has {} coordinates but earlier rows have {}",
            self.line, self.got, self.expected
        )
    }
}

impl std::error::Error for MixedDims {}

/// Write points as comma-separated coordinate lines. Returns bytes written.
///
/// Non-finite coordinates are refused with the same typed
/// [`NonFiniteCoord`] that [`read_csv`] raises, so a write-then-read
/// round trip either succeeds or fails symmetrically — `write_csv` can
/// never emit a file its own reader rejects.
pub fn write_csv(path: &Path, points: &[Point]) -> Result<u64> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    let mut bytes = 0u64;
    let mut line = String::new();
    for (row, p) in points.iter().enumerate() {
        line.clear();
        for (i, c) in p.coords().iter().enumerate() {
            if !c.is_finite() {
                let e = NonFiniteCoord { index: i, token: c.to_string() };
                return Err(anyhow::Error::new(e).context(format!("{path:?}: point {row}")));
            }
            if i > 0 {
                line.push(',');
            }
            line.push_str(&c.to_string());
        }
        line.push('\n');
        w.write_all(line.as_bytes())?;
        bytes += line.len() as u64;
    }
    w.flush()?;
    Ok(bytes)
}

/// Read coordinate lines; blank lines and `#` comments are skipped.
/// All rows must share one dimensionality.
pub fn read_csv(path: &Path) -> Result<Vec<Point>> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let r = std::io::BufReader::new(f);
    let mut out: Vec<Point> = Vec::new();
    for (i, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let p = parse_line(t).with_context(|| format!("{path:?}:{}", i + 1))?;
        if let Some(first) = out.first() {
            if first.dims() != p.dims() {
                let e = MixedDims { line: i + 1, got: p.dims(), expected: first.dims() };
                return Err(anyhow::Error::new(e).context(format!("{path:?}:{}", i + 1)));
            }
        }
        out.push(p);
    }
    Ok(out)
}

/// Parse one coordinate row: 2 to [`MAX_DIMS`] comma/tab/space-separated
/// *finite* floats (NaN/inf rows are refused with a typed
/// [`NonFiniteCoord`]).
pub fn parse_line(t: &str) -> Result<Point> {
    let mut coords: Vec<f32> = Vec::with_capacity(2);
    for s in t.split(&[',', '\t', ' '][..]).filter(|s| !s.is_empty()) {
        if coords.len() == MAX_DIMS {
            bail!("more than {MAX_DIMS} coordinates in {t:?}");
        }
        let v: f32 = s.trim().parse().with_context(|| format!("bad coordinate {s:?}"))?;
        if !v.is_finite() {
            let e = NonFiniteCoord { index: coords.len(), token: s.trim().to_string() };
            return Err(e.into());
        }
        coords.push(v);
    }
    if coords.len() < 2 {
        bail!("expected at least 'x,y', got {t:?}");
    }
    Ok(Point::from_slice(&coords))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("kmr_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pts.csv");
        let pts = vec![Point::new(1.5, -2.25), Point::new(0.0, 9.0)];
        write_csv(&path, &pts).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back, pts);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_ndim() {
        let dir = std::env::temp_dir().join("kmr_io_test_nd");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pts3.csv");
        let pts = vec![
            Point::from_slice(&[1.0, 2.0, 3.0]),
            Point::from_slice(&[-4.5, 5.25, 6.0]),
        ];
        write_csv(&path, &pts).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back, pts);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mixed_dims_rejected() {
        let dir = std::env::temp_dir().join("kmr_io_test_mixed");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mixed.csv");
        std::fs::write(&path, "1,2\n1,2,3\n").unwrap();
        let e = read_csv(&path).unwrap_err();
        assert!(format!("{e:#}").contains("coordinates"), "{e:#}");
        // The rejection is a typed error, not a stringly bail: the line,
        // found dims, and expected dims are all recoverable.
        assert_eq!(
            e.downcast_ref::<MixedDims>(),
            Some(&MixedDims { line: 2, got: 3, expected: 2 }),
            "{e:#}"
        );
        assert!(format!("{e:#}").contains(":2"), "context must name line 2: {e:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_csv_rejects_non_finite_coordinates() {
        let dir = std::env::temp_dir().join("kmr_io_test_wnf");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("nf.csv");
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let pts = vec![Point::new(1.0, 2.0), Point::new(bad, 4.0)];
            let e = write_csv(&path, &pts).unwrap_err();
            let t = e.downcast_ref::<NonFiniteCoord>().expect("typed NonFiniteCoord");
            assert_eq!(t.index, 0, "{e:#}");
            assert!(format!("{e:#}").contains("point 1"), "{e:#}");
        }
        // Symmetry: whatever write_csv accepts, read_csv accepts back.
        let good = vec![Point::new(1.0, 2.0), Point::new(3.0, 4.0)];
        write_csv(&path, &good).unwrap();
        assert_eq!(read_csv(&path).unwrap(), good);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_csv_byte_count_matches_file_size() {
        let dir = std::env::temp_dir().join("kmr_io_test_bytes");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sized.csv");
        let pts = vec![Point::new(1.5, -2.25), Point::from_slice(&[0.125, 9.0])];
        let n = write_csv(&path, &pts).unwrap();
        assert_eq!(n, std::fs::metadata(&path).unwrap().len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn parse_variants() {
        assert_eq!(parse_line("1,2").unwrap(), Point::new(1.0, 2.0));
        assert_eq!(parse_line("1.5\t-2").unwrap(), Point::new(1.5, -2.0));
        assert_eq!(parse_line("3 4").unwrap(), Point::new(3.0, 4.0));
        assert_eq!(parse_line("1,2,3,4").unwrap(), Point::from_slice(&[1.0, 2.0, 3.0, 4.0]));
        assert!(parse_line("nope").is_err());
        assert!(parse_line("1,abc").is_err());
        assert!(parse_line("1").is_err(), "single coordinate rejected");
        assert!(parse_line("1,2,3,4,5,6,7,8,9").is_err(), "more than MAX_DIMS rejected");
    }

    #[test]
    fn non_finite_coordinates_are_typed_errors() {
        for (row, index, token) in
            [("nan,1", 0, "nan"), ("1,inf", 1, "inf"), ("0,-inf", 1, "-inf"), ("1,2,NaN", 2, "NaN")]
        {
            let e = parse_line(row).unwrap_err();
            assert_eq!(
                e.downcast_ref::<NonFiniteCoord>(),
                Some(&NonFiniteCoord { index, token: token.to_string() }),
                "row {row:?}: {e:#}"
            );
        }
    }

    #[test]
    fn read_csv_reports_the_offending_line_for_non_finite_rows() {
        let dir = std::env::temp_dir().join("kmr_io_test_nonfinite");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "1,2\n# comment\n3,nan\n5,6\n").unwrap();
        let e = read_csv(&path).unwrap_err();
        assert!(format!("{e:#}").contains(":3"), "must name line 3: {e:#}");
        assert_eq!(
            e.downcast_ref::<NonFiniteCoord>(),
            Some(&NonFiniteCoord { index: 1, token: "nan".to_string() }),
            "{e:#}"
        );
        std::fs::remove_file(&path).ok();
    }
}
