//! CSV import/export for spatial points (the interchange format the
//! paper's HDFS ingest would use: one `x,y` coordinate row per line).

use super::Point;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Write points as `x,y` lines. Returns bytes written.
pub fn write_csv(path: &Path, points: &[Point]) -> Result<u64> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    let mut bytes = 0u64;
    for p in points {
        let line = format!("{},{}\n", p.x, p.y);
        bytes += line.len() as u64;
        w.write_all(line.as_bytes())?;
    }
    w.flush()?;
    Ok(bytes)
}

/// Read `x,y` lines; blank lines and `#` comments are skipped.
pub fn read_csv(path: &Path) -> Result<Vec<Point>> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let r = std::io::BufReader::new(f);
    let mut out = Vec::new();
    for (i, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        out.push(parse_line(t).with_context(|| format!("{path:?}:{}", i + 1))?);
    }
    Ok(out)
}

pub fn parse_line(t: &str) -> Result<Point> {
    let mut it = t.split(&[',', '\t', ' '][..]).filter(|s| !s.is_empty());
    let (Some(xs), Some(ys)) = (it.next(), it.next()) else {
        bail!("expected 'x,y', got {t:?}");
    };
    let x: f32 = xs.trim().parse().with_context(|| format!("bad x {xs:?}"))?;
    let y: f32 = ys.trim().parse().with_context(|| format!("bad y {ys:?}"))?;
    Ok(Point::new(x, y))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("kmr_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pts.csv");
        let pts = vec![Point::new(1.5, -2.25), Point::new(0.0, 9.0)];
        write_csv(&path, &pts).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back, pts);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn parse_variants() {
        assert_eq!(parse_line("1,2").unwrap(), Point::new(1.0, 2.0));
        assert_eq!(parse_line("1.5\t-2").unwrap(), Point::new(1.5, -2.0));
        assert_eq!(parse_line("3 4").unwrap(), Point::new(3.0, 4.0));
        assert!(parse_line("nope").is_err());
        assert!(parse_line("1,abc").is_err());
    }
}
