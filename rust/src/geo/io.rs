//! CSV import/export for spatial points (the interchange format the
//! paper's HDFS ingest would use: one coordinate row per line —
//! `x,y` for the planar GIS case, `c0,c1,...,cd-1` for d-dim data).

use super::{Point, MAX_DIMS};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Write points as comma-separated coordinate lines. Returns bytes written.
pub fn write_csv(path: &Path, points: &[Point]) -> Result<u64> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    let mut bytes = 0u64;
    let mut line = String::new();
    for p in points {
        line.clear();
        for (i, c) in p.coords().iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            line.push_str(&c.to_string());
        }
        line.push('\n');
        bytes += line.len() as u64;
        w.write_all(line.as_bytes())?;
    }
    w.flush()?;
    Ok(bytes)
}

/// Read coordinate lines; blank lines and `#` comments are skipped.
/// All rows must share one dimensionality.
pub fn read_csv(path: &Path) -> Result<Vec<Point>> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let r = std::io::BufReader::new(f);
    let mut out: Vec<Point> = Vec::new();
    for (i, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let p = parse_line(t).with_context(|| format!("{path:?}:{}", i + 1))?;
        if let Some(first) = out.first() {
            if first.dims() != p.dims() {
                bail!(
                    "{path:?}:{}: row has {} coordinates but earlier rows have {}",
                    i + 1,
                    p.dims(),
                    first.dims()
                );
            }
        }
        out.push(p);
    }
    Ok(out)
}

/// Parse one coordinate row: 2 to [`MAX_DIMS`] comma/tab/space-separated
/// floats.
pub fn parse_line(t: &str) -> Result<Point> {
    let mut coords: Vec<f32> = Vec::with_capacity(2);
    for s in t.split(&[',', '\t', ' '][..]).filter(|s| !s.is_empty()) {
        if coords.len() == MAX_DIMS {
            bail!("more than {MAX_DIMS} coordinates in {t:?}");
        }
        let v: f32 = s.trim().parse().with_context(|| format!("bad coordinate {s:?}"))?;
        coords.push(v);
    }
    if coords.len() < 2 {
        bail!("expected at least 'x,y', got {t:?}");
    }
    Ok(Point::from_slice(&coords))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("kmr_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pts.csv");
        let pts = vec![Point::new(1.5, -2.25), Point::new(0.0, 9.0)];
        write_csv(&path, &pts).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back, pts);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_ndim() {
        let dir = std::env::temp_dir().join("kmr_io_test_nd");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pts3.csv");
        let pts = vec![
            Point::from_slice(&[1.0, 2.0, 3.0]),
            Point::from_slice(&[-4.5, 5.25, 6.0]),
        ];
        write_csv(&path, &pts).unwrap();
        let back = read_csv(&path).unwrap();
        assert_eq!(back, pts);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mixed_dims_rejected() {
        let dir = std::env::temp_dir().join("kmr_io_test_mixed");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mixed.csv");
        std::fs::write(&path, "1,2\n1,2,3\n").unwrap();
        let e = read_csv(&path).unwrap_err();
        assert!(format!("{e:#}").contains("coordinates"), "{e:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn parse_variants() {
        assert_eq!(parse_line("1,2").unwrap(), Point::new(1.0, 2.0));
        assert_eq!(parse_line("1.5\t-2").unwrap(), Point::new(1.5, -2.0));
        assert_eq!(parse_line("3 4").unwrap(), Point::new(3.0, 4.0));
        assert_eq!(parse_line("1,2,3,4").unwrap(), Point::from_slice(&[1.0, 2.0, 3.0, 4.0]));
        assert!(parse_line("nope").is_err());
        assert!(parse_line("1,abc").is_err());
        assert!(parse_line("1").is_err(), "single coordinate rejected");
        assert!(parse_line("1,2,3,4,5,6,7,8,9").is_err(), "more than MAX_DIMS rejected");
    }
}
