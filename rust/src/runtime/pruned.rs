//! Triangle-inequality pruned assignment lane (Elkan/Hamerly-style),
//! byte-identical to the dense kernels by construction.
//!
//! ## What it does
//!
//! The dense assignment path evaluates every `point × medoid` distance
//! each iteration. But medoids barely move between iterations, so for
//! most points the nearest medoid *provably* cannot have changed. This
//! lane caches per-point bounds across iterations and skips every point
//! whose bounds certify the cached label, falling back to the dense
//! kernel arithmetic (which remains the oracle) only for points whose
//! bounds overlap:
//!
//! - `ub[i]` — upper bound on the **true** distance from point `i` to
//!   its cached nearest medoid.
//! - `lb[i]` — lower bound on the true distance from point `i` to every
//!   *other* medoid (Hamerly's single global bound).
//!
//! At the start of an epoch (one [`PrunedAssigner::begin_epoch`] per
//! iteration), each medoid's drift — `Metric::displacement` between its
//! old and new position, inflated by 1e-9 for f64 rounding — feeds the
//! bound maintenance exactly as the `IterationEvent::medoid_drift`
//! telemetry defines it: `ub += drift[label]`, `lb −= max drift over
//! the other medoids` (triangle inequality both ways). Then per point:
//!
//! 1. **Skip test**: if `lb` and `ub` are separated by more than the
//!    kernel-error margin (below), the cached label is certified. If the
//!    label's medoid did not move at all, even the cached f32 distance
//!    is still bitwise-valid — zero evaluations.
//! 2. **Tighten** (1 evaluation): recompute the distance to the cached
//!    label with kernel-identical arithmetic, shrink `ub`, re-test.
//! 3. **Resolve**: scan the medoids — restricted to the shared
//!    [`SpatialIndex`] cell candidates when the index applies — with
//!    kernel-identical arithmetic, tracking best and second-best. The
//!    second-best distance (and the cell's excluded-medoid floor)
//!    rebuild `lb`; the best rebuilds `ub` and the cached label.
//!
//! ## Why outputs are byte-identical
//!
//! Each point's scalar arithmetic replicates the dense kernel exactly:
//! the 2-D squared-Euclidean fast path uses the same expanded
//! `‖p‖² − 2p·m + ‖m‖²` f32 form (same precomputed `‖m‖²`, clamped at
//! 0), every other `(dims, metric)` uses `Metric::distance_f32`, ties
//! break first-wins with strict `<` like the kernels, and the per-block
//! f32 cost/count accumulation (block size [`ComputeBackend::block`],
//! point order, f64 fold per block) mirrors `ops::assign_points`. The
//! only question is whether the *argmin* matches, and that is what the
//! bounds certify: the skip test demands separation `> 2·s` where `s`
//! is a slack that dominates the worst-case f32 kernel error by more
//! than two orders of magnitude (1e-4 of the squared/L1 coordinate
//! scale, 0.5 km for haversine — same style of margin the spatial
//! index has always used). Squared Euclidean is not a metric, so its
//! bounds are maintained in square-root (true Euclidean) space — where
//! the triangle inequality holds — and the skip test compares back in
//! squared space: skip iff `lb² − ub² > 2·s`.
//!
//! The conformance matrix asserts the resulting labels, `f32::to_bits`
//! min-distances, and cost bits against the dense oracle in every
//! `Algorithm × Metric × dims × threads` cell.
//!
//! ## Determinism & MR safety
//!
//! Per-split state is keyed by the split's `row_start`: the MR engine
//! computes every map task exactly once per job (fanned over the worker
//! pool, cached across attempts), so each split advances exactly one
//! epoch per job regardless of thread count, faults, or speculation —
//! labels, cost bits, *and* evaluation counts are thread-count- and
//! fault-invariant. Interior mutability (mutexes around the epoch data
//! and the split map) makes the assigner shareable from `&self` mapper
//! methods; contention is one brief lock per split per epoch.

use super::backend::ComputeBackend;
use super::ops::AssignResult;
use crate::geo::index::SpatialIndex;
use crate::geo::{Metric, Point};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// CLI/spec toggle for the pruned lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PruningMode {
    /// Always prune (eval counts differ from a dense run when resuming
    /// from a checkpoint, because bounds are not persisted).
    On,
    /// Always run the dense kernels.
    Off,
    /// Prune unless the fit writes checkpoints or resumes from one —
    /// bounds are not persisted, so a resumed run would re-resolve
    /// everything once and its `dist_evals` would diverge from the
    /// uninterrupted run's, breaking crash-recovery byte-identity.
    Auto,
}

impl Default for PruningMode {
    fn default() -> PruningMode {
        PruningMode::Auto
    }
}

impl PruningMode {
    pub fn parse(s: &str) -> Option<PruningMode> {
        match s {
            "on" => Some(PruningMode::On),
            "off" => Some(PruningMode::Off),
            "auto" => Some(PruningMode::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PruningMode::On => "on",
            PruningMode::Off => "off",
            PruningMode::Auto => "auto",
        }
    }

    /// Resolve the mode against the fit's durability configuration.
    pub fn enabled(&self, wants_checkpoints: bool, resuming: bool) -> bool {
        match self {
            PruningMode::On => true,
            PruningMode::Off => false,
            PruningMode::Auto => !wants_checkpoints && !resuming,
        }
    }
}

/// One epoch's shared data: the medoid set, per-medoid drift since the
/// previous epoch, the spatial index, and the fast-path norms.
struct EpochData {
    epoch: u64,
    medoids: Vec<Point>,
    dims: usize,
    /// Precomputed `‖m‖²` in f32 — the fast path's exact staging values.
    m2: Vec<f32>,
    /// Inflated true-metric displacement of each medoid vs. last epoch.
    drift: Vec<f64>,
    drift_max: f64,
    drift_max_idx: usize,
    drift_second: f64,
    index: Option<SpatialIndex>,
    /// Largest medoid norm scale (squared norm / L1 norm) for the slack.
    med_scale: f64,
}

impl EpochData {
    /// Max drift over every medoid except `j`.
    fn drift_excl(&self, j: usize) -> f64 {
        if j == self.drift_max_idx {
            self.drift_second
        } else {
            self.drift_max
        }
    }
}

/// Cross-epoch bound state for one split.
struct SplitState {
    /// Epoch this state was last advanced at.
    epoch: u64,
    label: Vec<u32>,
    /// Cached kernel mindist (bitwise what the dense kernel emitted).
    md: Vec<f32>,
    /// Upper bound on the true distance to the labeled medoid
    /// (metric space; square-root space for squared Euclidean).
    ub: Vec<f64>,
    /// Lower bound on the true distance to every other medoid.
    lb: Vec<f64>,
    /// Largest point norm scale in the split (constant across epochs).
    p_scale: f64,
}

impl SplitState {
    fn fresh(n: usize, metric: Metric, points: &[Point]) -> SplitState {
        let p_scale = points
            .iter()
            .map(|p| norm_scale(metric, p))
            .fold(0.0f64, f64::max);
        SplitState {
            epoch: 0,
            label: vec![0; n],
            md: vec![0.0; n],
            ub: vec![0.0; n],
            lb: vec![0.0; n],
            p_scale,
        }
    }
}

fn norm_scale(metric: Metric, p: &Point) -> f64 {
    match metric {
        Metric::SqEuclidean => {
            p.coords().iter().map(|&c| (c as f64) * (c as f64)).sum()
        }
        Metric::Manhattan => p.coords().iter().map(|&c| (c as f64).abs()).sum(),
        Metric::Haversine => 0.0,
    }
}

/// Kernel-identical scalar distance from `p` to medoid `j` — bitwise
/// the value the dense block kernels compute for the same pair.
#[inline]
fn kernel_dist(
    metric: Metric,
    dims: usize,
    fast2d: bool,
    m2: &[f32],
    medoids: &[Point],
    p: &Point,
    j: usize,
) -> f32 {
    if fast2d {
        let (px, py) = (p.x(), p.y());
        let p2 = px * px + py * py;
        let m = &medoids[j];
        let cross = px * m.x() + py * m.y();
        (p2 - 2.0 * cross + m2[j]).max(0.0)
    } else {
        metric.distance_f32(dims, p.coords(), medoids[j].coords())
    }
}

/// Upper bound on the true metric distance given the kernel value `d`
/// and the kernel-error slack `s` (both in kernel comparison space).
#[inline]
fn upper_bound(metric: Metric, d: f32, s: f64) -> f64 {
    match metric {
        Metric::SqEuclidean => (d as f64 + s).max(0.0).sqrt(),
        _ => d as f64 + s,
    }
}

/// Lower bound on the true metric distance given the kernel value `d`.
#[inline]
fn lower_bound(metric: Metric, d: f32, s: f64) -> f64 {
    match metric {
        Metric::SqEuclidean => (d as f64 - s).max(0.0).sqrt(),
        _ => (d as f64 - s).max(0.0),
    }
}

/// The skip test: do `lb`/`ub` separate by more than twice the kernel
/// slack in comparison space? (Squared space for squared Euclidean.)
#[inline]
fn bounds_separate(metric: Metric, lb: f64, ub: f64, s: f64) -> bool {
    match metric {
        Metric::SqEuclidean => lb * lb - ub * ub > 2.0 * s,
        _ => lb - ub > 2.0 * s,
    }
}

/// The pruned assignment lane. One instance lives for one fit; the
/// driver calls [`PrunedAssigner::begin_epoch`] with the iteration's
/// medoids before each assignment job, and mappers call
/// [`PrunedAssigner::assign_split`] once per split per epoch.
pub struct PrunedAssigner {
    metric: Metric,
    epoch: Mutex<Arc<EpochData>>,
    splits: Mutex<HashMap<u64, SplitState>>,
}

impl PrunedAssigner {
    pub fn new(metric: Metric) -> PrunedAssigner {
        PrunedAssigner {
            metric,
            epoch: Mutex::new(Arc::new(EpochData {
                epoch: 0,
                medoids: Vec::new(),
                dims: 0,
                m2: Vec::new(),
                drift: Vec::new(),
                drift_max: 0.0,
                drift_max_idx: usize::MAX,
                drift_second: 0.0,
                index: None,
                med_scale: 0.0,
            })),
            splits: Mutex::new(HashMap::new()),
        }
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }

    /// Start a new epoch over `medoids`: compute per-medoid drift vs.
    /// the previous epoch's medoids, rebuild the spatial index, and
    /// precompute the fast-path norms. If the medoid set's structure
    /// changed (k or dims), all cached split bounds are discarded.
    pub fn begin_epoch(&self, medoids: &[Point]) {
        assert!(!medoids.is_empty(), "begin_epoch with no medoids");
        let dims = medoids[0].dims();
        let mut guard = self.epoch.lock().unwrap();
        let prev = guard.clone();
        let structure_ok =
            prev.epoch > 0 && prev.medoids.len() == medoids.len() && prev.dims == dims;
        let drift: Vec<f64> = if structure_ok {
            medoids
                .iter()
                .zip(&prev.medoids)
                .map(|(new, old)| {
                    // Inflate for f64 rounding so the stored drift can
                    // never undershoot the true displacement.
                    self.metric.displacement(old, new) * (1.0 + 1e-9)
                })
                .collect()
        } else {
            vec![0.0; medoids.len()]
        };
        let (mut dmax, mut didx, mut dsecond) = (0.0f64, usize::MAX, 0.0f64);
        for (j, &d) in drift.iter().enumerate() {
            if d > dmax {
                dsecond = dmax;
                dmax = d;
                didx = j;
            } else if d > dsecond {
                dsecond = d;
            }
        }
        let fast2d = dims == 2 && self.metric == Metric::SqEuclidean;
        let m2: Vec<f32> = if fast2d {
            medoids.iter().map(|m| m.x() * m.x() + m.y() * m.y()).collect()
        } else {
            Vec::new()
        };
        let med_scale = medoids
            .iter()
            .map(|m| norm_scale(self.metric, m))
            .fold(0.0f64, f64::max);
        *guard = Arc::new(EpochData {
            epoch: prev.epoch + 1,
            medoids: medoids.to_vec(),
            dims,
            m2,
            drift,
            drift_max: dmax,
            drift_max_idx: didx,
            drift_second: dsecond,
            index: SpatialIndex::build(medoids, self.metric),
            med_scale,
        });
        drop(guard);
        if !structure_ok {
            self.splits.lock().unwrap().clear();
        }
    }

    /// Assign one split's points for the current epoch. `split_key` must
    /// be stable across epochs for the same point range (the MR drivers
    /// use the split's `row_start`). Returns the same labels, f32
    /// min-distance bits, and per-cluster cost/count bits as
    /// [`super::ops::assign_points`] over the same inputs, with
    /// `dist_evals` counting the evaluations actually performed.
    pub fn assign_split(
        &self,
        be: &dyn ComputeBackend,
        split_key: u64,
        points: &[Point],
        medoids: &[Point],
    ) -> Result<AssignResult> {
        let ep = self.epoch.lock().unwrap().clone();
        if ep.epoch == 0 {
            bail!("PrunedAssigner::assign_split before begin_epoch");
        }
        debug_assert_eq!(
            ep.medoids, medoids,
            "assign_split medoids differ from the current epoch's"
        );
        let _ = medoids;
        let metric = self.metric;
        let k = ep.medoids.len();
        let n = points.len();
        let fast2d = ep.dims == 2 && metric == Metric::SqEuclidean;
        let b = be.block().max(1);

        let taken = self.splits.lock().unwrap().remove(&split_key);
        let (mut st, fresh) = match taken {
            Some(s) if s.epoch + 1 == ep.epoch && s.label.len() == n => (s, false),
            _ => (SplitState::fresh(n, metric, points), true),
        };

        // Kernel-error slack in comparison space (squared space for
        // squared Euclidean): 1e-4 of the coordinate scale dominates
        // the f32 kernel error by > 100x; 0.5 km dwarfs the f64→f32
        // haversine rounding (~1e-3 km).
        let s = match metric {
            Metric::Haversine => 0.5,
            _ => 1e-4 * (ep.med_scale + st.p_scale).max(1.0),
        };

        let mut labels = Vec::with_capacity(n);
        let mut mindists = Vec::with_capacity(n);
        let mut cost = vec![0f64; k];
        let mut count = vec![0u64; k];
        let mut evals: u64 = 0;
        // Per-block f32 accumulators, folded to f64 per block — the
        // exact accumulation granularity of the dense blocking loop.
        let mut bcost = vec![0f32; k];
        let mut bcount = vec![0f32; k];

        let mut start = 0usize;
        while start < n {
            let len = (n - start).min(b);
            bcost.iter_mut().for_each(|v| *v = 0.0);
            bcount.iter_mut().for_each(|v| *v = 0.0);
            for i in start..start + len {
                let p = &points[i];
                let mut need_resolve = fresh;
                if !fresh {
                    let lab = st.label[i] as usize;
                    let dr = ep.drift[lab];
                    st.ub[i] += dr;
                    st.lb[i] = (st.lb[i] - ep.drift_excl(lab)).max(0.0);
                    if bounds_separate(metric, st.lb[i], st.ub[i], s) {
                        if dr != 0.0 {
                            // Label certified but its medoid moved:
                            // refresh the cached kernel distance.
                            let d = kernel_dist(
                                metric, ep.dims, fast2d, &ep.m2, &ep.medoids, p, lab,
                            );
                            evals += 1;
                            st.md[i] = d;
                            st.ub[i] = upper_bound(metric, d, s).min(st.ub[i]);
                        }
                    } else {
                        // Hamerly tighten: one exact evaluation of the
                        // cached label, then re-test.
                        let d =
                            kernel_dist(metric, ep.dims, fast2d, &ep.m2, &ep.medoids, p, lab);
                        evals += 1;
                        st.md[i] = d;
                        st.ub[i] = upper_bound(metric, d, s).min(st.ub[i]);
                        if !bounds_separate(metric, st.lb[i], st.ub[i], s) {
                            need_resolve = true;
                        }
                    }
                }
                if need_resolve {
                    resolve_point(metric, &ep, fast2d, s, p, &mut st, i, &mut evals);
                }
                let lab = st.label[i] as usize;
                let md = st.md[i];
                labels.push(st.label[i]);
                mindists.push(md);
                bcost[lab] += md;
                bcount[lab] += 1.0;
            }
            for j in 0..k {
                cost[j] += bcost[j] as f64;
                count[j] += bcount[j] as u64;
            }
            start += len;
        }

        st.epoch = ep.epoch;
        self.splits.lock().unwrap().insert(split_key, st);
        Ok(AssignResult {
            labels,
            mindists,
            cluster_cost: cost,
            cluster_count: count,
            dist_evals: evals,
        })
    }
}

/// Full resolve of one point: scan the spatial-index candidates (or all
/// medoids) with kernel-identical arithmetic, tracking best and
/// second-best; rebuild label, cached distance, and both bounds.
#[allow(clippy::too_many_arguments)]
fn resolve_point(
    metric: Metric,
    ep: &EpochData,
    fast2d: bool,
    s: f64,
    p: &Point,
    st: &mut SplitState,
    i: usize,
    evals: &mut u64,
) {
    let k = ep.medoids.len();
    let mut best = f32::INFINITY;
    let mut best_j = 0usize;
    let mut second = f32::INFINITY;
    let mut floor = f64::INFINITY;
    let cell = ep.index.as_ref().and_then(|ix| ix.cell(p));
    match cell {
        Some(cell) => {
            for &ju in &cell.cands {
                let j = ju as usize;
                let d = kernel_dist(metric, ep.dims, fast2d, &ep.m2, &ep.medoids, p, j);
                if d < best {
                    second = best;
                    best = d;
                    best_j = j;
                } else if d < second {
                    second = d;
                }
            }
            *evals += cell.cands.len() as u64;
            floor = cell.excluded_floor;
        }
        None => {
            for j in 0..k {
                let d = kernel_dist(metric, ep.dims, fast2d, &ep.m2, &ep.medoids, p, j);
                if d < best {
                    second = best;
                    best = d;
                    best_j = j;
                } else if d < second {
                    second = d;
                }
            }
            *evals += k as u64;
        }
    }
    st.label[i] = best_j as u32;
    st.md[i] = best;
    st.ub[i] = upper_bound(metric, best, s);
    let second_lb = if second.is_finite() {
        lower_bound(metric, second, s)
    } else {
        f64::INFINITY
    };
    st.lb[i] = second_lb.min(floor).max(0.0);
}

#[cfg(test)]
mod tests {
    use super::super::backend::NativeBackend;
    use super::super::ops::assign_points;
    use super::*;
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    fn be() -> NativeBackend {
        NativeBackend::new(64, 16)
    }

    fn rand_points_d(rng: &mut Rng, n: usize, spread: f64, dims: usize) -> Vec<Point> {
        (0..n)
            .map(|_| {
                let c: Vec<f32> =
                    (0..dims).map(|_| (rng.f64() * spread - spread / 2.0) as f32).collect();
                Point::from_slice(&c)
            })
            .collect()
    }

    fn latlon_points(rng: &mut Rng, n: usize) -> Vec<Point> {
        (0..n)
            .map(|_| {
                Point::new(
                    rng.range_f64(-75.0, 75.0) as f32,
                    rng.range_f64(-170.0, 170.0) as f32,
                )
            })
            .collect()
    }

    /// Jitter medoids slightly, as converging iterations do; leave a
    /// random subset exactly in place (drift == 0, the cached-distance
    /// fast case).
    fn jitter(rng: &mut Rng, medoids: &mut [Point], step: f64) {
        for m in medoids.iter_mut() {
            if rng.below(4) == 0 {
                continue;
            }
            let dims = m.dims();
            let c: Vec<f32> = (0..dims)
                .map(|d| m.coord(d) + (rng.f64() * step - step / 2.0) as f32)
                .collect();
            *m = Point::from_slice(&c);
        }
    }

    fn assert_identical(
        pruned: &AssignResult,
        dense: &AssignResult,
        ctx: &str,
    ) {
        assert_eq!(pruned.labels, dense.labels, "{ctx}: labels diverged");
        assert_eq!(pruned.mindists.len(), dense.mindists.len());
        for (i, (a, b)) in pruned.mindists.iter().zip(&dense.mindists).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: mindist {i} not bitwise-identical");
        }
        for (j, (a, b)) in
            pruned.cluster_cost.iter().zip(&dense.cluster_cost).enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: cluster cost {j} bits diverged");
        }
        assert_eq!(pruned.cluster_count, dense.cluster_count, "{ctx}: counts diverged");
    }

    /// Core identity property: over multiple epochs of drifting medoids
    /// and multiple splits, the pruned lane is bitwise-identical to the
    /// dense oracle (labels, mindist bits, cost bits, counts) for every
    /// supported `(metric, dims)` combination — while evaluating fewer
    /// distances once bounds are warm.
    #[test]
    fn pruned_lane_is_byte_identical_to_dense_across_epochs() {
        let combos: &[(Metric, usize, f64)] = &[
            (Metric::SqEuclidean, 2, 2e4),
            (Metric::SqEuclidean, 3, 2e4),
            (Metric::SqEuclidean, 8, 2e4),
            (Metric::Manhattan, 2, 2e4),
            (Metric::Manhattan, 3, 2e4),
            (Metric::Manhattan, 8, 2e4),
        ];
        for &(metric, dims, spread) in combos {
            for_all(4, 0x9F2 ^ (dims as u64) ^ ((metric as u64) << 4), |rng| {
                let n = 300 + rng.below(200);
                let k = 2 + rng.below(8);
                let pts = rand_points_d(rng, n, spread, dims);
                let mut medoids = rand_points_d(rng, k, spread, dims);
                let be = be();
                let pa = PrunedAssigner::new(metric);
                let split_at = n / 2;
                let mut pruned_evals = 0u64;
                let mut dense_evals = 0u64;
                for epoch in 0..6 {
                    pa.begin_epoch(&medoids);
                    for (key, range) in
                        [(0u64, 0..split_at), (split_at as u64, split_at..n)]
                    {
                        let slice = &pts[range];
                        let got = pa.assign_split(&be, key, slice, &medoids).unwrap();
                        let want = assign_points(&be, slice, &medoids, metric).unwrap();
                        assert_identical(
                            &got,
                            &want,
                            &format!("{metric:?} d={dims} epoch {epoch} split {key}"),
                        );
                        pruned_evals += got.dist_evals;
                        dense_evals += want.dist_evals;
                    }
                    jitter(rng, &mut medoids, spread * 1e-4);
                }
                assert!(
                    pruned_evals < dense_evals,
                    "{metric:?} d={dims}: pruned {pruned_evals} >= dense {dense_evals}"
                );
            });
        }
    }

    #[test]
    fn pruned_lane_is_byte_identical_for_haversine() {
        for_all(4, 0x9A7, |rng| {
            let n = 250 + rng.below(150);
            let k = 2 + rng.below(6);
            let pts = latlon_points(rng, n);
            let mut medoids = latlon_points(rng, k);
            let be = be();
            let pa = PrunedAssigner::new(Metric::Haversine);
            for epoch in 0..5 {
                pa.begin_epoch(&medoids);
                let got = pa.assign_split(&be, 0, &pts, &medoids).unwrap();
                let want = assign_points(&be, &pts, &medoids, Metric::Haversine).unwrap();
                assert_identical(&got, &want, &format!("haversine epoch {epoch}"));
                jitter(rng, &mut medoids, 0.01);
            }
        });
    }

    /// On clustered data with converging (small-drift) medoids, warm
    /// bounds skip the vast majority of points: total evaluations drop
    /// well past the 3x reduction floor the CI gate enforces.
    #[test]
    fn warm_bounds_cut_evals_at_least_3x_on_clustered_data() {
        let mut rng = Rng::new(0xC1D);
        let k = 8usize;
        let per = 150usize;
        let centers = rand_points_d(&mut rng, k, 4e4, 2);
        let mut pts = Vec::new();
        for c in &centers {
            for _ in 0..per {
                pts.push(Point::new(
                    c.x() + (rng.f64() * 200.0 - 100.0) as f32,
                    c.y() + (rng.f64() * 200.0 - 100.0) as f32,
                ));
            }
        }
        let mut medoids = centers.clone();
        let be = be();
        let pa = PrunedAssigner::new(Metric::SqEuclidean);
        let mut pruned_evals = 0u64;
        let mut dense_evals = 0u64;
        for _ in 0..10 {
            pa.begin_epoch(&medoids);
            let got = pa.assign_split(&be, 0, &pts, &medoids).unwrap();
            let want = assign_points(&be, &pts, &medoids, Metric::SqEuclidean).unwrap();
            assert_eq!(got.labels, want.labels);
            pruned_evals += got.dist_evals;
            dense_evals += want.dist_evals;
            jitter(&mut rng, &mut medoids, 2.0);
        }
        assert!(
            pruned_evals * 3 <= dense_evals,
            "pruned {pruned_evals} vs dense {dense_evals}: reduction below 3x"
        );
    }

    /// Changing k (or dims) between epochs discards stale bounds
    /// instead of applying them to the wrong medoid set.
    #[test]
    fn structure_change_resets_bounds() {
        let mut rng = Rng::new(0x57A);
        let pts = rand_points_d(&mut rng, 200, 1e3, 2);
        let be = be();
        let pa = PrunedAssigner::new(Metric::SqEuclidean);
        for k in [4usize, 6, 3] {
            let medoids = rand_points_d(&mut rng, k, 1e3, 2);
            pa.begin_epoch(&medoids);
            let got = pa.assign_split(&be, 0, &pts, &medoids).unwrap();
            let want = assign_points(&be, &pts, &medoids, Metric::SqEuclidean).unwrap();
            assert_identical(&got, &want, &format!("k={k}"));
            // Fresh structure = full resolves; with the index the count
            // may undercut n×k but never exceed it.
            assert!(got.dist_evals <= want.dist_evals);
        }
    }

    #[test]
    fn mode_resolution_honors_durability() {
        assert!(PruningMode::On.enabled(true, true));
        assert!(!PruningMode::Off.enabled(false, false));
        assert!(PruningMode::Auto.enabled(false, false));
        assert!(!PruningMode::Auto.enabled(true, false));
        assert!(!PruningMode::Auto.enabled(false, true));
        assert_eq!(PruningMode::parse("auto"), Some(PruningMode::Auto));
        assert_eq!(PruningMode::parse("bogus"), None);
        assert_eq!(PruningMode::default(), PruningMode::Auto);
    }
}
