//! Compute backend abstraction + the pure-Rust native implementation.
//!
//! The hot-path numeric ops (block assignment, block pairwise cost) have
//! two interchangeable implementations:
//! - [`super::pjrt::PjrtBackend`] — the production path: AOT HLO
//!   artifacts (JAX/Pallas) executed via the PJRT CPU client.
//! - [`NativeBackend`] — a pure-Rust oracle used for cross-checking the
//!   artifacts at startup, for tests without artifacts, and as the
//!   baseline in the kernel benchmark.

use anyhow::Result;
use std::cell::RefCell;

/// Thread-local SoA staging for the native pairwise kernel: member
/// coordinates deinterleaved into `xs`/`ys` plus their precomputed
/// squared norms `p2`, shared across all candidates of one block call
/// (§Perf: the old loop recomputed `px² + py²` once per candidate per
/// member). Fully overwritten on every call, so reuse is state-free.
#[derive(Default)]
struct PwScratch {
    xs: Vec<f32>,
    ys: Vec<f32>,
    p2: Vec<f32>,
}

thread_local! {
    static PW_SCRATCH: RefCell<PwScratch> = RefCell::new(PwScratch::default());
}

/// Result of one assign block call (matches `ref.assign` in python).
#[derive(Debug, Clone)]
pub struct AssignOut {
    pub labels: Vec<i32>,
    pub mindists: Vec<f32>,
    pub cluster_cost: Vec<f32>,
    pub cluster_count: Vec<f32>,
}

/// Fixed-shape block compute. Inputs are flat row-major f32 slices:
/// points `(B,2)`, mask `(B,)`, medoids `(K,2)` padded with `pad_coord`.
pub trait ComputeBackend: Send + Sync {
    /// Block size B (points per call).
    fn block(&self) -> usize;
    /// Padded medoid capacity K.
    fn kpad(&self) -> usize;
    /// Padding coordinate for unused medoid slots.
    fn pad_coord(&self) -> f32;
    fn name(&self) -> &str;

    /// Nearest-medoid assignment for one block.
    fn assign_block(&self, points: &[f32], mask: &[f32], medoids: &[f32]) -> Result<AssignOut>;

    /// Partial PAM-update costs: for each candidate i,
    /// `sum_j mask[j] * ||c_i - p_j||^2` over the member block.
    fn pairwise_block(&self, cand: &[f32], members: &[f32], mask: &[f32]) -> Result<Vec<f32>>;

    /// Like [`Self::pairwise_block`] but only the first `n_cand`
    /// candidates are meaningful; backends that can skip the padded tail
    /// (native) override this (§Perf: the reducer typically fills an
    /// eighth of the candidate block). The PJRT executable has a fixed
    /// shape, so its default just runs the full block.
    fn pairwise_block_partial(
        &self,
        cand: &[f32],
        members: &[f32],
        mask: &[f32],
        n_cand: usize,
    ) -> Result<Vec<f32>> {
        let _ = n_cand;
        self.pairwise_block(cand, members, mask)
    }
}

/// Pure-Rust reference backend (no artifacts needed).
pub struct NativeBackend {
    pub block_size: usize,
    pub kpad_size: usize,
}

impl NativeBackend {
    pub fn new(block: usize, kpad: usize) -> NativeBackend {
        NativeBackend { block_size: block, kpad_size: kpad }
    }
}

impl ComputeBackend for NativeBackend {
    fn block(&self) -> usize {
        self.block_size
    }
    fn kpad(&self) -> usize {
        self.kpad_size
    }
    fn pad_coord(&self) -> f32 {
        1e9
    }
    fn name(&self) -> &str {
        "native"
    }

    fn assign_block(&self, points: &[f32], mask: &[f32], medoids: &[f32]) -> Result<AssignOut> {
        let b = self.block_size;
        let k = self.kpad_size;
        assert_eq!(points.len(), 2 * b);
        assert_eq!(mask.len(), b);
        assert_eq!(medoids.len(), 2 * k);
        let mut labels = vec![0i32; b];
        let mut mindists = vec![0f32; b];
        let mut cost = vec![0f32; k];
        let mut count = vec![0f32; k];
        // Padded medoid slots (trailing PAD_COORD rows) can never win the
        // argmin — skip them instead of evaluating 64 slots for k=9.
        // (§Perf: 7x fewer distance evals on the assignment hot path.)
        let pad = self.pad_coord();
        let k_eff = (0..k)
            .rposition(|j| medoids[2 * j] != pad || medoids[2 * j + 1] != pad)
            .map(|j| j + 1)
            .unwrap_or(k);
        // Same expanded form as the Pallas kernel so rounding matches:
        // ||p-m||^2 = ||p||^2 - 2 p.m + ||m||^2.
        let m2: Vec<f32> = (0..k_eff)
            .map(|j| medoids[2 * j] * medoids[2 * j] + medoids[2 * j + 1] * medoids[2 * j + 1])
            .collect();
        for i in 0..b {
            let (px, py) = (points[2 * i], points[2 * i + 1]);
            let p2 = px * px + py * py;
            let mut best = f32::INFINITY;
            let mut best_j = 0usize;
            for j in 0..k_eff {
                let cross = px * medoids[2 * j] + py * medoids[2 * j + 1];
                let d = (p2 - 2.0 * cross + m2[j]).max(0.0);
                if d < best {
                    best = d;
                    best_j = j;
                }
            }
            labels[i] = best_j as i32;
            let md = best * mask[i];
            mindists[i] = md;
            cost[best_j] += md;
            count[best_j] += mask[i];
        }
        Ok(AssignOut { labels, mindists, cluster_cost: cost, cluster_count: count })
    }

    fn pairwise_block(&self, cand: &[f32], members: &[f32], mask: &[f32]) -> Result<Vec<f32>> {
        self.pairwise_block_partial(cand, members, mask, self.block_size)
    }

    fn pairwise_block_partial(
        &self,
        cand: &[f32],
        members: &[f32],
        mask: &[f32],
        n_cand: usize,
    ) -> Result<Vec<f32>> {
        let b = self.block_size;
        assert_eq!(cand.len(), 2 * b);
        assert_eq!(members.len(), 2 * b);
        assert_eq!(mask.len(), b);
        let mut out = vec![0f32; b];
        PW_SCRATCH.with(|scratch| {
            let mut guard = scratch.borrow_mut();
            let PwScratch { xs, ys, p2 } = &mut *guard;
            // SoA staging pass, shared by every candidate: deinterleave
            // member coordinates and precompute the squared norms once.
            xs.clear();
            ys.clear();
            p2.clear();
            xs.reserve(b);
            ys.reserve(b);
            p2.reserve(b);
            for j in 0..b {
                let (px, py) = (members[2 * j], members[2 * j + 1]);
                xs.push(px);
                ys.push(py);
                p2.push(px * px + py * py);
            }
            // Same expanded form as the Pallas kernel:
            // ||c-p||² = ||c||² - 2 c·p + ||p||², clamped at 0, masked.
            // Masked-multiply instead of a branch + 4-wide unrolled
            // accumulators keep the inner loop branch-free and
            // vectorizable; the reduction order is fixed, so results are
            // deterministic across runs and thread counts.
            let tail_start = b - b % 4;
            for i in 0..n_cand.min(b) {
                let (cx, cy) = (cand[2 * i], cand[2 * i + 1]);
                let c2 = cx * cx + cy * cy;
                let term = |j: usize| -> f32 {
                    mask[j] * (c2 - 2.0 * (cx * xs[j] + cy * ys[j]) + p2[j]).max(0.0)
                };
                let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
                let mut j = 0usize;
                while j < tail_start {
                    a0 += term(j);
                    a1 += term(j + 1);
                    a2 += term(j + 2);
                    a3 += term(j + 3);
                    j += 4;
                }
                let mut rem = 0f32;
                while j < b {
                    rem += term(j);
                    j += 1;
                }
                out[i] = ((a0 + a1) + (a2 + a3)) + rem;
            }
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple_setup() -> (NativeBackend, Vec<f32>, Vec<f32>, Vec<f32>) {
        let be = NativeBackend::new(4, 3);
        // 4 points: two near (0,0), two near (10,10); medoids at both, one pad.
        let points = vec![0.1, 0.0, 0.0, 0.2, 10.0, 9.9, 10.1, 10.0];
        let mask = vec![1.0, 1.0, 1.0, 1.0];
        let medoids = vec![0.0, 0.0, 10.0, 10.0, 1e9, 1e9];
        (be, points, mask, medoids)
    }

    #[test]
    fn assign_matches_intuition() {
        let (be, points, mask, medoids) = simple_setup();
        let out = be.assign_block(&points, &mask, &medoids).unwrap();
        assert_eq!(out.labels, vec![0, 0, 1, 1]);
        assert_eq!(out.cluster_count, vec![2.0, 2.0, 0.0]);
        assert!(out.cluster_cost[2] == 0.0);
        assert!((out.mindists[0] - 0.01).abs() < 1e-6);
    }

    #[test]
    fn masked_points_do_not_count() {
        let (be, points, _, medoids) = simple_setup();
        let mask = vec![1.0, 0.0, 1.0, 0.0];
        let out = be.assign_block(&points, &mask, &medoids).unwrap();
        assert_eq!(out.cluster_count, vec![1.0, 1.0, 0.0]);
        assert_eq!(out.mindists[1], 0.0);
    }

    #[test]
    fn pairwise_cost_sums() {
        let be = NativeBackend::new(2, 2);
        let cand = vec![0.0, 0.0, 1.0, 0.0];
        let members = vec![0.0, 0.0, 2.0, 0.0];
        let mask = vec![1.0, 1.0];
        let out = be.pairwise_block(&cand, &members, &mask).unwrap();
        assert_eq!(out, vec![4.0, 2.0]); // c0: 0+4 ; c1: 1+1
    }

    #[test]
    fn pad_medoids_never_selected() {
        let (be, points, mask, medoids) = simple_setup();
        let out = be.assign_block(&points, &mask, &medoids).unwrap();
        assert!(out.labels.iter().all(|&l| l < 2));
    }
}
