//! Compute backend abstraction + the pure-Rust native implementation.
//!
//! The hot-path numeric ops (block assignment, block pairwise cost) have
//! two interchangeable implementations:
//! - [`super::pjrt::PjrtBackend`] — the production path: AOT HLO
//!   artifacts (JAX/Pallas) executed via the PJRT CPU client.
//! - [`NativeBackend`] — a pure-Rust oracle used for cross-checking the
//!   artifacts at startup, for tests without artifacts, and as the
//!   baseline in the kernel benchmark.
//!
//! ## Metric dispatch
//!
//! The fixed-shape `assign_block` / `pairwise_block*` methods are the
//! 2-D squared-Euclidean **fast path** (the paper's workload): SoA
//! staging, precomputed `‖p‖²` norms, and the expanded
//! `‖p−m‖² = ‖p‖² − 2p·m + ‖m‖²` form that matches the Pallas kernel
//! bit-for-bit. Every other `(dims, metric)` combination goes through
//! the `*_metric` trait methods, whose default implementations run the
//! generic unrolled native kernels below — fixed accumulation order, so
//! results stay byte-identical across runs and thread counts. Backends
//! with metric-specialized hardware kernels can override them; the PJRT
//! backend inherits the native generic path (its AOT artifacts only
//! cover the 2-D squared-Euclidean blocks).

use crate::geo::Metric;
use anyhow::Result;
use std::cell::RefCell;

/// Thread-local SoA staging for the native pairwise kernel: member
/// coordinates deinterleaved into `xs`/`ys` plus their precomputed
/// squared norms `p2`, shared across all candidates of one block call
/// (§Perf: the old loop recomputed `px² + py²` once per candidate per
/// member). Fully overwritten on every call, so reuse is state-free.
#[derive(Default)]
struct PwScratch {
    xs: Vec<f32>,
    ys: Vec<f32>,
    p2: Vec<f32>,
}

thread_local! {
    static PW_SCRATCH: RefCell<PwScratch> = RefCell::new(PwScratch::default());
}

/// Result of one assign block call (matches `ref.assign` in python).
#[derive(Debug, Clone)]
pub struct AssignOut {
    pub labels: Vec<i32>,
    pub mindists: Vec<f32>,
    pub cluster_cost: Vec<f32>,
    pub cluster_count: Vec<f32>,
}

/// Fixed-shape block compute. Inputs are flat row-major f32 slices:
/// points `(B,2)`, mask `(B,)`, medoids `(K,2)` padded with `pad_coord`
/// for the 2-D fast-path methods; the `*_metric` methods take the same
/// layout at `dims` coordinates per row.
pub trait ComputeBackend: Send + Sync {
    /// Block size B (points per call).
    fn block(&self) -> usize;
    /// Padded medoid capacity K.
    fn kpad(&self) -> usize;
    /// Padding coordinate for unused medoid slots.
    fn pad_coord(&self) -> f32;
    fn name(&self) -> &str;

    /// Nearest-medoid assignment for one block (2-D squared Euclidean).
    fn assign_block(&self, points: &[f32], mask: &[f32], medoids: &[f32]) -> Result<AssignOut>;

    /// Partial PAM-update costs: for each candidate i,
    /// `sum_j mask[j] * ||c_i - p_j||^2` over the member block
    /// (2-D squared Euclidean).
    fn pairwise_block(&self, cand: &[f32], members: &[f32], mask: &[f32]) -> Result<Vec<f32>>;

    /// Like [`Self::pairwise_block`] but only the first `n_cand`
    /// candidates are meaningful; backends that can skip the padded tail
    /// (native) override this (§Perf: the reducer typically fills an
    /// eighth of the candidate block). The PJRT executable has a fixed
    /// shape, so its default just runs the full block.
    fn pairwise_block_partial(
        &self,
        cand: &[f32],
        members: &[f32],
        mask: &[f32],
        n_cand: usize,
    ) -> Result<Vec<f32>> {
        let _ = n_cand;
        self.pairwise_block(cand, members, mask)
    }

    /// Metric-generic nearest-medoid assignment: points `(B, dims)`,
    /// mask `(B,)`, medoids `(K, dims)` padded with `pad_coord` rows.
    /// Default: the generic unrolled native kernel (deterministic fixed
    /// accumulation order).
    fn assign_block_metric(
        &self,
        dims: usize,
        metric: Metric,
        points: &[f32],
        mask: &[f32],
        medoids: &[f32],
    ) -> Result<AssignOut> {
        native_assign_metric(
            self.block(),
            self.kpad(),
            self.pad_coord(),
            dims,
            metric,
            points,
            mask,
            medoids,
        )
    }

    /// Metric-generic partial pairwise costs: candidates `(B, dims)`,
    /// members `(B, dims)`, mask `(B,)`; only the first `n_cand`
    /// candidate outputs are meaningful. Default: the generic unrolled
    /// native kernel.
    fn pairwise_block_partial_metric(
        &self,
        dims: usize,
        metric: Metric,
        cand: &[f32],
        members: &[f32],
        mask: &[f32],
        n_cand: usize,
    ) -> Result<Vec<f32>> {
        native_pairwise_metric(self.block(), dims, metric, cand, members, mask, n_cand)
    }

    /// Weighted medoid-update kernel: for each of the first `n_cand`
    /// candidates, `Σ_j w_j · d(c_i, p_j)` over the member block. The
    /// weight slab *is* the mask slab generalized — an unweighted call is
    /// the weighted call with 0/1 weights (padding rows carry weight 0) —
    /// so the default routes the paper's 2-D squared-Euclidean case
    /// through the existing fast-path kernel with weights standing in for
    /// the mask, and every other `(dims, metric)` combination through the
    /// generic unrolled kernel. Same fixed accumulation order, same
    /// byte-identity across runs and thread counts.
    fn pairwise_block_weighted(
        &self,
        dims: usize,
        metric: Metric,
        cand: &[f32],
        members: &[f32],
        weights: &[f32],
        n_cand: usize,
    ) -> Result<Vec<f32>> {
        if dims == 2 && metric == Metric::SqEuclidean {
            self.pairwise_block_partial(cand, members, weights, n_cand)
        } else {
            native_pairwise_metric(self.block(), dims, metric, cand, members, weights, n_cand)
        }
    }

    /// Weighted nearest-medoid assignment: labels are the plain argmin
    /// (a point's nearest medoid does not depend on its weight), while
    /// `mindists` / `cluster_cost` are weight-scaled (`Σ w·d`) and
    /// `cluster_count` accumulates total member weight (`Σ w`) — the
    /// mask lane of [`Self::assign_block`] generalized from 0/1 to
    /// arbitrary non-negative weights.
    ///
    /// Deliberately NOT routed through the 2-D fast-path artifact: the
    /// Pallas reference folds the mask into both `mindists` and the
    /// one-hot matrix, so its `cluster_cost` is `Σ mask²·d` — identical
    /// for 0/1 masks, wrong for real-valued weights. The generic native
    /// kernel multiplies the weight exactly once; weighted assigns are
    /// coreset-sized, so skipping the fast path costs nothing.
    fn assign_block_weighted(
        &self,
        dims: usize,
        metric: Metric,
        points: &[f32],
        weights: &[f32],
        medoids: &[f32],
    ) -> Result<AssignOut> {
        native_assign_metric(
            self.block(),
            self.kpad(),
            self.pad_coord(),
            dims,
            metric,
            points,
            weights,
            medoids,
        )
    }
}

/// Generic-path assign kernel over any `(dims, metric)`: plain
/// per-coordinate distance, fixed evaluation order. Shared as the
/// default for every [`ComputeBackend`].
#[allow(clippy::too_many_arguments)]
pub fn native_assign_metric(
    b: usize,
    k: usize,
    pad: f32,
    dims: usize,
    metric: Metric,
    points: &[f32],
    mask: &[f32],
    medoids: &[f32],
) -> Result<AssignOut> {
    assert_eq!(points.len(), dims * b);
    assert_eq!(mask.len(), b);
    assert_eq!(medoids.len(), dims * k);
    let mut labels = vec![0i32; b];
    let mut mindists = vec![0f32; b];
    let mut cost = vec![0f32; k];
    let mut count = vec![0f32; k];
    // Skip trailing pad rows, as the fast path does.
    let k_eff = (0..k)
        .rposition(|j| medoids[dims * j..dims * (j + 1)].iter().any(|&v| v != pad))
        .map(|j| j + 1)
        .unwrap_or(k);
    for i in 0..b {
        let p = &points[dims * i..dims * (i + 1)];
        let mut best = f32::INFINITY;
        let mut best_j = 0usize;
        for j in 0..k_eff {
            let m = &medoids[dims * j..dims * (j + 1)];
            let d = metric.distance_f32(dims, p, m);
            if d < best {
                best = d;
                best_j = j;
            }
        }
        labels[i] = best_j as i32;
        let md = best * mask[i];
        mindists[i] = md;
        cost[best_j] += md;
        count[best_j] += mask[i];
    }
    Ok(AssignOut { labels, mindists, cluster_cost: cost, cluster_count: count })
}

/// Generic-path pairwise kernel over any `(dims, metric)`: 4-wide
/// unrolled masked accumulation in a fixed order (deterministic across
/// runs and thread counts), matching the fast path's reduction shape.
pub fn native_pairwise_metric(
    b: usize,
    dims: usize,
    metric: Metric,
    cand: &[f32],
    members: &[f32],
    mask: &[f32],
    n_cand: usize,
) -> Result<Vec<f32>> {
    assert_eq!(cand.len(), dims * b);
    assert_eq!(members.len(), dims * b);
    assert_eq!(mask.len(), b);
    let mut out = vec![0f32; b];
    let tail_start = b - b % 4;
    for i in 0..n_cand.min(b) {
        let c = &cand[dims * i..dims * (i + 1)];
        let term = |j: usize| -> f32 {
            mask[j] * metric.distance_f32(dims, c, &members[dims * j..dims * (j + 1)])
        };
        let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
        let mut j = 0usize;
        while j < tail_start {
            a0 += term(j);
            a1 += term(j + 1);
            a2 += term(j + 2);
            a3 += term(j + 3);
            j += 4;
        }
        let mut rem = 0f32;
        while j < b {
            rem += term(j);
            j += 1;
        }
        out[i] = ((a0 + a1) + (a2 + a3)) + rem;
    }
    Ok(out)
}

/// Pure-Rust reference backend (no artifacts needed).
pub struct NativeBackend {
    pub block_size: usize,
    pub kpad_size: usize,
}

impl NativeBackend {
    pub fn new(block: usize, kpad: usize) -> NativeBackend {
        NativeBackend { block_size: block, kpad_size: kpad }
    }
}

impl ComputeBackend for NativeBackend {
    fn block(&self) -> usize {
        self.block_size
    }
    fn kpad(&self) -> usize {
        self.kpad_size
    }
    fn pad_coord(&self) -> f32 {
        1e9
    }
    fn name(&self) -> &str {
        "native"
    }

    fn assign_block(&self, points: &[f32], mask: &[f32], medoids: &[f32]) -> Result<AssignOut> {
        let b = self.block_size;
        let k = self.kpad_size;
        assert_eq!(points.len(), 2 * b);
        assert_eq!(mask.len(), b);
        assert_eq!(medoids.len(), 2 * k);
        let mut labels = vec![0i32; b];
        let mut mindists = vec![0f32; b];
        let mut cost = vec![0f32; k];
        let mut count = vec![0f32; k];
        // Padded medoid slots (trailing PAD_COORD rows) can never win the
        // argmin — skip them instead of evaluating 64 slots for k=9.
        // (§Perf: 7x fewer distance evals on the assignment hot path.)
        let pad = self.pad_coord();
        let k_eff = (0..k)
            .rposition(|j| medoids[2 * j] != pad || medoids[2 * j + 1] != pad)
            .map(|j| j + 1)
            .unwrap_or(k);
        // Same expanded form as the Pallas kernel so rounding matches:
        // ||p-m||^2 = ||p||^2 - 2 p.m + ||m||^2.
        let m2: Vec<f32> = (0..k_eff)
            .map(|j| medoids[2 * j] * medoids[2 * j] + medoids[2 * j + 1] * medoids[2 * j + 1])
            .collect();
        for i in 0..b {
            let (px, py) = (points[2 * i], points[2 * i + 1]);
            let p2 = px * px + py * py;
            let mut best = f32::INFINITY;
            let mut best_j = 0usize;
            for j in 0..k_eff {
                let cross = px * medoids[2 * j] + py * medoids[2 * j + 1];
                let d = (p2 - 2.0 * cross + m2[j]).max(0.0);
                if d < best {
                    best = d;
                    best_j = j;
                }
            }
            labels[i] = best_j as i32;
            let md = best * mask[i];
            mindists[i] = md;
            cost[best_j] += md;
            count[best_j] += mask[i];
        }
        Ok(AssignOut { labels, mindists, cluster_cost: cost, cluster_count: count })
    }

    fn pairwise_block(&self, cand: &[f32], members: &[f32], mask: &[f32]) -> Result<Vec<f32>> {
        self.pairwise_block_partial(cand, members, mask, self.block_size)
    }

    fn pairwise_block_partial(
        &self,
        cand: &[f32],
        members: &[f32],
        mask: &[f32],
        n_cand: usize,
    ) -> Result<Vec<f32>> {
        let b = self.block_size;
        assert_eq!(cand.len(), 2 * b);
        assert_eq!(members.len(), 2 * b);
        assert_eq!(mask.len(), b);
        let mut out = vec![0f32; b];
        PW_SCRATCH.with(|scratch| {
            let mut guard = scratch.borrow_mut();
            let PwScratch { xs, ys, p2 } = &mut *guard;
            // SoA staging pass, shared by every candidate: deinterleave
            // member coordinates and precompute the squared norms once.
            xs.clear();
            ys.clear();
            p2.clear();
            xs.reserve(b);
            ys.reserve(b);
            p2.reserve(b);
            for j in 0..b {
                let (px, py) = (members[2 * j], members[2 * j + 1]);
                xs.push(px);
                ys.push(py);
                p2.push(px * px + py * py);
            }
            // Same expanded form as the Pallas kernel:
            // ||c-p||² = ||c||² - 2 c·p + ||p||², clamped at 0, masked.
            // Masked-multiply instead of a branch + 4-wide unrolled
            // accumulators keep the inner loop branch-free and
            // vectorizable; the reduction order is fixed, so results are
            // deterministic across runs and thread counts.
            let tail_start = b - b % 4;
            for i in 0..n_cand.min(b) {
                let (cx, cy) = (cand[2 * i], cand[2 * i + 1]);
                let c2 = cx * cx + cy * cy;
                let term = |j: usize| -> f32 {
                    mask[j] * (c2 - 2.0 * (cx * xs[j] + cy * ys[j]) + p2[j]).max(0.0)
                };
                let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
                let mut j = 0usize;
                while j < tail_start {
                    a0 += term(j);
                    a1 += term(j + 1);
                    a2 += term(j + 2);
                    a3 += term(j + 3);
                    j += 4;
                }
                let mut rem = 0f32;
                while j < b {
                    rem += term(j);
                    j += 1;
                }
                out[i] = ((a0 + a1) + (a2 + a3)) + rem;
            }
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple_setup() -> (NativeBackend, Vec<f32>, Vec<f32>, Vec<f32>) {
        let be = NativeBackend::new(4, 3);
        // 4 points: two near (0,0), two near (10,10); medoids at both, one pad.
        let points = vec![0.1, 0.0, 0.0, 0.2, 10.0, 9.9, 10.1, 10.0];
        let mask = vec![1.0, 1.0, 1.0, 1.0];
        let medoids = vec![0.0, 0.0, 10.0, 10.0, 1e9, 1e9];
        (be, points, mask, medoids)
    }

    #[test]
    fn assign_matches_intuition() {
        let (be, points, mask, medoids) = simple_setup();
        let out = be.assign_block(&points, &mask, &medoids).unwrap();
        assert_eq!(out.labels, vec![0, 0, 1, 1]);
        assert_eq!(out.cluster_count, vec![2.0, 2.0, 0.0]);
        assert!(out.cluster_cost[2] == 0.0);
        assert!((out.mindists[0] - 0.01).abs() < 1e-6);
    }

    #[test]
    fn masked_points_do_not_count() {
        let (be, points, _, medoids) = simple_setup();
        let mask = vec![1.0, 0.0, 1.0, 0.0];
        let out = be.assign_block(&points, &mask, &medoids).unwrap();
        assert_eq!(out.cluster_count, vec![1.0, 1.0, 0.0]);
        assert_eq!(out.mindists[1], 0.0);
    }

    #[test]
    fn pairwise_cost_sums() {
        let be = NativeBackend::new(2, 2);
        let cand = vec![0.0, 0.0, 1.0, 0.0];
        let members = vec![0.0, 0.0, 2.0, 0.0];
        let mask = vec![1.0, 1.0];
        let out = be.pairwise_block(&cand, &members, &mask).unwrap();
        assert_eq!(out, vec![4.0, 2.0]); // c0: 0+4 ; c1: 1+1
    }

    #[test]
    fn pad_medoids_never_selected() {
        let (be, points, mask, medoids) = simple_setup();
        let out = be.assign_block(&points, &mask, &medoids).unwrap();
        assert!(out.labels.iter().all(|&l| l < 2));
    }

    #[test]
    fn generic_sq_euclidean_2d_agrees_with_fast_path_labels() {
        // Same argmin (labels/counts) as the norm-trick fast path; the
        // distances themselves may differ only in last-bit rounding.
        let (be, points, mask, medoids) = simple_setup();
        let fast = be.assign_block(&points, &mask, &medoids).unwrap();
        let generic = be
            .assign_block_metric(2, Metric::SqEuclidean, &points, &mask, &medoids)
            .unwrap();
        assert_eq!(fast.labels, generic.labels);
        assert_eq!(fast.cluster_count, generic.cluster_count);
        for (f, g) in fast.mindists.iter().zip(&generic.mindists) {
            assert!((f - g).abs() < 1e-4, "{f} vs {g}");
        }
    }

    #[test]
    fn generic_assign_manhattan_3d() {
        let be = NativeBackend::new(2, 2);
        // points: (0,0,0), (1,2,3); medoids: (0,0,0), (1,1,1)
        let points = vec![0.0, 0.0, 0.0, 1.0, 2.0, 3.0];
        let mask = vec![1.0, 1.0];
        let medoids = vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let out = be.assign_block_metric(3, Metric::Manhattan, &points, &mask, &medoids).unwrap();
        assert_eq!(out.labels, vec![0, 1]); // |1-1|+|2-1|+|3-1| = 3 < 6
        assert_eq!(out.mindists, vec![0.0, 3.0]);
        assert_eq!(out.cluster_count, vec![1.0, 1.0]);
    }

    #[test]
    fn generic_pairwise_manhattan() {
        let cand = vec![0.0, 0.0, 1.0, 0.0];
        let members = vec![0.0, 0.0, 2.0, 0.0];
        let mask = vec![1.0, 1.0];
        let out =
            native_pairwise_metric(2, 2, Metric::Manhattan, &cand, &members, &mask, 2).unwrap();
        assert_eq!(out, vec![2.0, 2.0]); // c0: 0+2 ; c1: 1+1
    }

    #[test]
    fn generic_pad_rows_skipped() {
        let be = NativeBackend::new(2, 3);
        let points = vec![0.0, 0.0, 0.0, 5.0, 5.0, 5.0];
        let mask = vec![1.0, 1.0];
        let medoids = vec![0.0, 0.0, 0.0, 5.0, 5.0, 5.0, 1e9, 1e9, 1e9];
        let out = be.assign_block_metric(3, Metric::Manhattan, &points, &mask, &medoids).unwrap();
        assert!(out.labels.iter().all(|&l| l < 2));
        assert_eq!(out.cluster_count, vec![1.0, 1.0, 0.0]);
    }

    #[test]
    fn weighted_pairwise_generalizes_the_mask() {
        let be = NativeBackend::new(4, 2);
        // Members at x = 0, 2, 4, 6 with weights 1, 2, 0, 0.5.
        let cand = vec![0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let members = vec![0.0, 0.0, 2.0, 0.0, 4.0, 0.0, 6.0, 0.0];
        let weights = vec![1.0, 2.0, 0.0, 0.5];
        let out = be
            .pairwise_block_weighted(2, Metric::SqEuclidean, &cand, &members, &weights, 2)
            .unwrap();
        // c0 = 1·0 + 2·4 + 0·16 + 0.5·36 = 26; c1 = 1·1 + 2·1 + 0·9 + 0.5·25 = 15.5
        assert_eq!(out[0], 26.0);
        assert_eq!(out[1], 15.5);
        // Unit weights reduce to the unweighted kernel exactly.
        let ones = vec![1.0; 4];
        let w = be
            .pairwise_block_weighted(2, Metric::SqEuclidean, &cand, &members, &ones, 2)
            .unwrap();
        let u = be.pairwise_block_partial(&cand, &members, &ones, 2).unwrap();
        assert_eq!(w, u);
        // Generic path (Manhattan) too: c0 = 1·2 + 2·4(?)... compute:
        // |0-0|=0·1, |0-2|=2·2, |0-4|=4·0, |0-6|=6·0.5 => 0 + 4 + 0 + 3 = 7.
        let m = be
            .pairwise_block_weighted(2, Metric::Manhattan, &cand, &members, &weights, 1)
            .unwrap();
        assert_eq!(m[0], 7.0);
    }

    #[test]
    fn weighted_assign_scales_cost_and_weight_not_labels() {
        let be = NativeBackend::new(4, 3);
        let points = vec![0.1, 0.0, 0.0, 0.2, 10.0, 9.9, 10.1, 10.0];
        let weights = vec![2.0, 1.0, 0.5, 3.0];
        let medoids = vec![0.0, 0.0, 10.0, 10.0, 1e9, 1e9];
        let out = be
            .assign_block_weighted(2, Metric::SqEuclidean, &points, &weights, &medoids)
            .unwrap();
        let plain =
            be.assign_block(&points, &[1.0, 1.0, 1.0, 1.0], &medoids).unwrap();
        assert_eq!(out.labels, plain.labels, "weights must not change the argmin");
        // cluster_count is total weight per cluster.
        assert_eq!(out.cluster_count, vec![3.0, 3.5, 0.0]);
        // Weighted cost = Σ w·d per cluster (1e-3 tolerance: the fast
        // path's expanded-norm form and the generic direct form round
        // differently at ~1e2 coordinate magnitudes).
        for j in 0..2 {
            let want: f32 = (0..4)
                .filter(|&i| plain.labels[i] == j as i32)
                .map(|i| weights[i] * plain.mindists[i])
                .sum();
            assert!(
                (out.cluster_cost[j] - want).abs() < 1e-3,
                "cluster {j}: {} vs {want}",
                out.cluster_cost[j]
            );
        }
    }

    #[test]
    fn generic_haversine_masked_padding_never_nan() {
        let be = NativeBackend::new(4, 2);
        // Two real member rows + two zeroed padding rows (mask 0).
        let cand = vec![48.85, 2.35, 51.5, -0.13, 0.0, 0.0, 0.0, 0.0];
        let members = vec![48.85, 2.35, 51.5, -0.13, 0.0, 0.0, 0.0, 0.0];
        let mask = vec![1.0, 1.0, 0.0, 0.0];
        let out =
            native_pairwise_metric(4, 2, Metric::Haversine, &cand, &members, &mask, 2).unwrap();
        assert!(out.iter().all(|v| v.is_finite()));
        // Self-distance is 0; cross distance ~343 km.
        assert!((out[0] - out[1]).abs() < 1.0);
        assert!(out[0] > 300.0 && out[0] < 400.0);
    }
}
