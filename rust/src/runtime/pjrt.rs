//! PJRT backend: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the PJRT CPU client,
//! and executes them on the map/reduce hot path.
//!
//! Interchange is HLO **text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use super::backend::{AssignOut, ComputeBackend};
use super::manifest::{Manifest, UnitKind, UnitMeta};
use anyhow::{bail, Context, Result};
use std::sync::Mutex;

/// One compiled executable guarded for shared use.
///
/// SAFETY: the PJRT CPU client is thread-safe for compilation and
/// execution; the raw pointers inside the `xla` wrappers carry no
/// thread-affinity. We still serialize calls through a `Mutex` so buffer
/// lifetimes never interleave.
struct Exe {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    meta: UnitMeta,
}
unsafe impl Send for Exe {}
unsafe impl Sync for Exe {}

/// The production compute backend: assign/pairwise/seed executables for
/// one (block, kpad) variant.
pub struct PjrtBackend {
    assign: Exe,
    pairwise: Exe,
    block: usize,
    kpad: usize,
    pad_coord: f32,
}

impl PjrtBackend {
    /// Load a variant with block >= `min_block` from `manifest`.
    pub fn load(manifest: &Manifest, min_block: usize) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let assign_meta = manifest
            .pick(UnitKind::Assign, min_block)
            .context("no assign artifact in manifest")?
            .clone();
        let pairwise_meta = manifest
            .pick(UnitKind::Pairwise, assign_meta.block)
            .context("no pairwise artifact in manifest")?
            .clone();
        if pairwise_meta.block != assign_meta.block {
            bail!(
                "artifact block mismatch: assign B={} pairwise B={}",
                assign_meta.block,
                pairwise_meta.block
            );
        }
        let compile = |meta: &UnitMeta| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                meta.path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO text {:?}", meta.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("PJRT compile {}", meta.name))
        };
        let assign = compile(&assign_meta)?;
        let pairwise = compile(&pairwise_meta)?;
        Ok(PjrtBackend {
            block: assign_meta.block,
            kpad: assign_meta.kpad,
            pad_coord: assign_meta.pad_coord,
            assign: Exe { exe: Mutex::new(assign), meta: assign_meta },
            pairwise: Exe { exe: Mutex::new(pairwise), meta: pairwise_meta },
        })
    }

    fn lit2(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }
    fn lit1(data: &[f32]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(data))
    }
}

impl ComputeBackend for PjrtBackend {
    fn block(&self) -> usize {
        self.block
    }
    fn kpad(&self) -> usize {
        self.kpad
    }
    fn pad_coord(&self) -> f32 {
        self.pad_coord
    }
    fn name(&self) -> &str {
        "pjrt"
    }

    fn assign_block(&self, points: &[f32], mask: &[f32], medoids: &[f32]) -> Result<AssignOut> {
        assert_eq!(points.len(), 2 * self.block);
        assert_eq!(mask.len(), self.block);
        assert_eq!(medoids.len(), 2 * self.kpad);
        let args = [
            Self::lit2(points, self.block, 2)?,
            Self::lit1(mask)?,
            Self::lit2(medoids, self.kpad, 2)?,
        ];
        let exe = self.assign.exe.lock().unwrap();
        let result = exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()
            .with_context(|| format!("execute {}", self.assign.meta.name))?;
        drop(exe);
        let parts = result.to_tuple()?;
        if parts.len() != 4 {
            bail!("assign artifact returned {} outputs, expected 4", parts.len());
        }
        let mut it = parts.into_iter();
        let labels = it.next().unwrap().to_vec::<i32>()?;
        let mindists = it.next().unwrap().to_vec::<f32>()?;
        let cluster_cost = it.next().unwrap().to_vec::<f32>()?;
        let cluster_count = it.next().unwrap().to_vec::<f32>()?;
        Ok(AssignOut { labels, mindists, cluster_cost, cluster_count })
    }

    fn pairwise_block(&self, cand: &[f32], members: &[f32], mask: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(cand.len(), 2 * self.block);
        assert_eq!(members.len(), 2 * self.block);
        assert_eq!(mask.len(), self.block);
        let args = [
            Self::lit2(cand, self.block, 2)?,
            Self::lit2(members, self.block, 2)?,
            Self::lit1(mask)?,
        ];
        let exe = self.pairwise.exe.lock().unwrap();
        let result = exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()
            .with_context(|| format!("execute {}", self.pairwise.meta.name))?;
        drop(exe);
        // Lowered with return_tuple=True -> 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::NativeBackend;
    use super::super::manifest::default_artifacts_dir;
    use super::*;
    use crate::util::rng::Rng;

    fn backend_or_skip(min_block: usize) -> Option<PjrtBackend> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (`make artifacts`)");
            return None;
        }
        Some(PjrtBackend::load(&Manifest::load(&dir).unwrap(), min_block).unwrap())
    }

    #[test]
    fn pjrt_matches_native_assign() {
        let Some(be) = backend_or_skip(256) else { return };
        let b = be.block();
        let k = be.kpad();
        let native = NativeBackend::new(b, k);
        let mut rng = Rng::new(99);
        let points: Vec<f32> = (0..2 * b).map(|_| (rng.f64() * 200.0 - 100.0) as f32).collect();
        let mut mask = vec![1.0f32; b];
        for m in mask.iter_mut().skip(b - 17) {
            *m = 0.0;
        }
        let mut medoids = vec![be.pad_coord(); 2 * k];
        for v in medoids.iter_mut().take(2 * 5) {
            *v = (rng.f64() * 200.0 - 100.0) as f32;
        }
        let got = be.assign_block(&points, &mask, &medoids).unwrap();
        let want = native.assign_block(&points, &mask, &medoids).unwrap();
        assert_eq!(got.labels[..b - 17], want.labels[..b - 17]);
        for (g, w) in got.mindists.iter().zip(&want.mindists) {
            assert!((g - w).abs() < 1e-2, "{g} vs {w}");
        }
        for (g, w) in got.cluster_count.iter().zip(&want.cluster_count) {
            assert_eq!(g, w);
        }
    }

    #[test]
    fn pjrt_matches_native_pairwise() {
        let Some(be) = backend_or_skip(256) else { return };
        let b = be.block();
        let native = NativeBackend::new(b, be.kpad());
        let mut rng = Rng::new(7);
        let cand: Vec<f32> = (0..2 * b).map(|_| (rng.f64() * 20.0 - 10.0) as f32).collect();
        let memb: Vec<f32> = (0..2 * b).map(|_| (rng.f64() * 20.0 - 10.0) as f32).collect();
        let mask: Vec<f32> = (0..b).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
        let got = be.pairwise_block(&cand, &memb, &mask).unwrap();
        let want = native.pairwise_block(&cand, &memb, &mask).unwrap();
        for (g, w) in got.iter().zip(&want) {
            let tol = 1e-3 * w.abs().max(1.0);
            assert!((g - w).abs() < tol, "{g} vs {w}");
        }
    }
}
