//! Backend-agnostic high-level ops: padding/chunking of arbitrary-size
//! point sets onto the fixed-shape block executables.
//!
//! §Perf: block staging buffers (padded point/mask/medoid slabs) are
//! **thread-local scratch**, reused across calls instead of freshly
//! allocated per call — the assignment mapper runs once per split per
//! iteration, and the old per-call `vec![0f32; 2 * b]` churn showed up as
//! allocator time at paper scale. Every scratch byte in the used range is
//! overwritten on every call, so reuse cannot leak state between calls
//! (or between the worker threads of the task pool, which each get their
//! own scratch).
//!
//! §Metric dispatch: every op takes the run's [`Metric`]. The 2-D
//! squared-Euclidean combination — the paper's workload — routes through
//! the backend's fixed-shape fast-path methods (`assign_block`,
//! `pairwise_block_partial`: SoA staging + precomputed norms, PJRT-able);
//! every other `(dims, metric)` combination routes through the
//! `*_metric` methods (generic unrolled native kernels by default). Both
//! paths use fixed accumulation orders, so outputs are byte-identical
//! across runs and thread counts for every `(dims, metric)` pair.

use super::backend::{AssignOut, ComputeBackend};
use crate::geo::{Metric, Point, PointSource, WeightedSource};
use anyhow::Result;
use std::cell::RefCell;

#[derive(Default)]
struct AssignScratch {
    pbuf: Vec<f32>,
    mask: Vec<f32>,
    med: Vec<f32>,
}

#[derive(Default)]
struct PairScratch {
    cbuf: Vec<f32>,
    mbuf: Vec<f32>,
    mmask: Vec<f32>,
}

thread_local! {
    static ASSIGN_SCRATCH: RefCell<AssignScratch> = RefCell::new(AssignScratch::default());
    static PAIR_SCRATCH: RefCell<PairScratch> = RefCell::new(PairScratch::default());
}

/// Grow (never shrink) a scratch vector so `buf[..len]` is addressable.
fn grow(buf: &mut Vec<f32>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

/// Full assignment of `points` to `medoids` (k <= kpad-1) under `metric`.
///
/// Returns per-point labels and dissimilarities plus per-cluster
/// (cost, count) aggregates. Exactly what the paper's mapper + combiner
/// produce for one split. For `SqEuclidean` the reported dissimilarity is
/// the squared distance (Eq. 1); for other metrics it is the metric's
/// own distance.
pub struct AssignResult {
    pub labels: Vec<u32>,
    pub mindists: Vec<f32>,
    pub cluster_cost: Vec<f64>,
    pub cluster_count: Vec<u64>,
    /// Distance evaluations actually performed for real points against
    /// real medoids (padding rows/slots are fixed-shape artifacts, not
    /// algorithmic work, and are not counted). For the dense lane this
    /// equals `n × k` by construction; the pruned lane
    /// ([`super::pruned::PrunedAssigner`]) reports the smaller count it
    /// actually evaluated.
    pub dist_evals: u64,
}

pub fn assign_points(
    be: &dyn ComputeBackend,
    points: &[Point],
    medoids: &[Point],
    metric: Metric,
) -> Result<AssignResult> {
    let b = be.block();
    let k = be.kpad();
    assert!(
        medoids.len() <= k,
        "k={} exceeds backend capacity {k}",
        medoids.len()
    );
    assert!(!medoids.is_empty());
    let dims = medoids[0].dims();
    debug_assert!(medoids.iter().all(|m| m.dims() == dims), "mixed-dims medoids");
    debug_assert!(points.iter().all(|p| p.dims() == dims), "points/medoids dims mismatch");
    assert!(metric.supports_dims(dims), "{} does not support dims={dims}", metric.name());
    let fast_2d = dims == 2 && metric == Metric::SqEuclidean;

    let n = points.len();
    let mut labels = Vec::with_capacity(n);
    let mut mindists = Vec::with_capacity(n);
    let mut cost = vec![0f64; medoids.len()];
    let mut count = vec![0u64; medoids.len()];
    let mut evals = 0u64;

    ASSIGN_SCRATCH.with(|scratch| -> Result<()> {
        let mut guard = scratch.borrow_mut();
        let AssignScratch { pbuf, mask, med } = &mut *guard;
        grow(pbuf, dims * b);
        grow(mask, b);
        grow(med, dims * k);
        let pbuf = &mut pbuf[..dims * b];
        let mask = &mut mask[..b];
        let med = &mut med[..dims * k];

        // Stage the medoid slab once per call: real medoids, then padding.
        for (j, m) in medoids.iter().enumerate() {
            med[dims * j..dims * (j + 1)].copy_from_slice(m.coords());
        }
        let pad = be.pad_coord();
        for v in med[dims * medoids.len()..].iter_mut() {
            *v = pad;
        }

        let mut start = 0usize;
        while start < n {
            let len = (n - start).min(b);
            for i in 0..len {
                pbuf[dims * i..dims * (i + 1)].copy_from_slice(points[start + i].coords());
                mask[i] = 1.0;
            }
            for i in len..b {
                pbuf[dims * i..dims * (i + 1)].fill(0.0);
                mask[i] = 0.0;
            }
            let out: AssignOut = if fast_2d {
                be.assign_block(pbuf, mask, med)?
            } else {
                be.assign_block_metric(dims, metric, pbuf, mask, med)?
            };
            for i in 0..len {
                labels.push(out.labels[i] as u32);
                mindists.push(out.mindists[i]);
            }
            for j in 0..medoids.len() {
                cost[j] += out.cluster_cost[j] as f64;
                count[j] += out.cluster_count[j] as u64;
            }
            evals += (len * medoids.len()) as u64;
            start += len;
        }
        Ok(())
    })?;
    Ok(AssignResult {
        labels,
        mindists,
        cluster_cost: cost,
        cluster_count: count,
        dist_evals: evals,
    })
}

/// Exact PAM-update candidate costs: for every candidate, the summed
/// dissimilarity to all members under `metric`, composed over fixed-size
/// blocks. Thin `&[Point]` wrapper over [`pairwise_costs_src`] that
/// drops the evaluation count.
pub fn pairwise_costs(
    be: &dyn ComputeBackend,
    candidates: &[Point],
    members: &[Point],
    metric: Metric,
) -> Result<Vec<f64>> {
    Ok(pairwise_costs_src(be, candidates, members, metric)?.0)
}

/// [`pairwise_costs`] over any two [`PointSource`]s — block staging goes
/// through `fill_coords`, so packed shuffle-byte views feed the kernel
/// directly without materializing `Vec<Point>`s. Returns the per-candidate
/// costs plus the number of distance evaluations actually performed
/// (`n_candidates × n_members` by construction — padding is not counted).
pub fn pairwise_costs_src<C, M>(
    be: &dyn ComputeBackend,
    candidates: &C,
    members: &M,
    metric: Metric,
) -> Result<(Vec<f64>, u64)>
where
    C: PointSource + ?Sized,
    M: PointSource + ?Sized,
{
    let b = be.block();
    let nc = candidates.len();
    let nm = members.len();
    let mut out = vec![0f64; nc];
    let mut evals = 0u64;
    if nc == 0 || nm == 0 {
        return Ok((out, evals));
    }
    let dims = candidates.dims();
    assert_eq!(dims, members.dims(), "candidates/members dims mismatch");
    assert!(metric.supports_dims(dims), "{} does not support dims={dims}", metric.name());
    let fast_2d = dims == 2 && metric == Metric::SqEuclidean;

    PAIR_SCRATCH.with(|scratch| -> Result<()> {
        let mut guard = scratch.borrow_mut();
        let PairScratch { cbuf, mbuf, mmask } = &mut *guard;
        grow(cbuf, dims * b);
        grow(mbuf, dims * b);
        grow(mmask, b);
        let cbuf = &mut cbuf[..dims * b];
        let mbuf = &mut mbuf[..dims * b];
        let mmask = &mut mmask[..b];

        let mut cs = 0usize;
        while cs < nc {
            let clen = (nc - cs).min(b);
            candidates.fill_coords(cs, clen, &mut cbuf[..dims * clen]);
            // Padding candidates is harmless (their outputs are discarded);
            // zero them for reproducibility.
            cbuf[dims * clen..].fill(0.0);
            let mut ms = 0usize;
            while ms < nm {
                let mlen = (nm - ms).min(b);
                members.fill_coords(ms, mlen, &mut mbuf[..dims * mlen]);
                for j in 0..mlen {
                    mmask[j] = 1.0;
                }
                mbuf[dims * mlen..].fill(0.0);
                for j in mlen..b {
                    mmask[j] = 0.0;
                }
                let partial = if fast_2d {
                    be.pairwise_block_partial(cbuf, mbuf, mmask, clen)?
                } else {
                    be.pairwise_block_partial_metric(dims, metric, cbuf, mbuf, mmask, clen)?
                };
                for i in 0..clen {
                    out[cs + i] += partial[i] as f64;
                }
                evals += (clen * mlen) as u64;
                ms += mlen;
            }
            cs += clen;
        }
        Ok(())
    })?;
    Ok((out, evals))
}

/// Result of a weighted assignment: labels are the plain (unweighted)
/// argmin; costs and counts are weight-scaled.
pub struct WeightedAssignResult {
    pub labels: Vec<u32>,
    /// Per-point `w_i · d(p_i, nearest medoid)`.
    pub weighted_mindists: Vec<f32>,
    /// Per-cluster `Σ w·d` (the weighted Eq. 1 contribution).
    pub cluster_cost: Vec<f64>,
    /// Per-cluster `Σ w` (total member weight).
    pub cluster_weight: Vec<f64>,
    /// Distance evaluations actually performed (real rows × medoids).
    pub dist_evals: u64,
}

/// Weighted assignment of a [`WeightedSource`] to `medoids`
/// (k <= kpad) under `metric`: the weight slab rides in the mask lane
/// (padding rows weigh 0), so labels match the unweighted assignment
/// while costs/counts accumulate `Σ w·d` / `Σ w` — what the coreset
/// merge and the weighted recluster need from one kernel pass.
pub fn assign_weighted<S>(
    be: &dyn ComputeBackend,
    src: &S,
    medoids: &[Point],
    metric: Metric,
) -> Result<WeightedAssignResult>
where
    S: WeightedSource + ?Sized,
{
    let b = be.block();
    let k = be.kpad();
    assert!(medoids.len() <= k, "k={} exceeds backend capacity {k}", medoids.len());
    assert!(!medoids.is_empty());
    let dims = medoids[0].dims();
    assert!(metric.supports_dims(dims), "{} does not support dims={dims}", metric.name());
    assert!(src.is_empty() || src.dims() == dims, "points/medoids dims mismatch");

    let n = src.len();
    let mut labels = Vec::with_capacity(n);
    let mut mindists = Vec::with_capacity(n);
    let mut cost = vec![0f64; medoids.len()];
    let mut weight = vec![0f64; medoids.len()];
    let mut evals = 0u64;

    ASSIGN_SCRATCH.with(|scratch| -> Result<()> {
        let mut guard = scratch.borrow_mut();
        let AssignScratch { pbuf, mask, med } = &mut *guard;
        grow(pbuf, dims * b);
        grow(mask, b);
        grow(med, dims * k);
        let pbuf = &mut pbuf[..dims * b];
        let mask = &mut mask[..b];
        let med = &mut med[..dims * k];

        for (j, m) in medoids.iter().enumerate() {
            med[dims * j..dims * (j + 1)].copy_from_slice(m.coords());
        }
        let pad = be.pad_coord();
        for v in med[dims * medoids.len()..].iter_mut() {
            *v = pad;
        }

        let mut start = 0usize;
        while start < n {
            let len = (n - start).min(b);
            src.fill_coords(start, len, &mut pbuf[..dims * len]);
            src.fill_weights(start, len, &mut mask[..len]);
            pbuf[dims * len..].fill(0.0);
            mask[len..].fill(0.0);
            let out: AssignOut = be.assign_block_weighted(dims, metric, pbuf, mask, med)?;
            for i in 0..len {
                labels.push(out.labels[i] as u32);
                mindists.push(out.mindists[i]);
            }
            for j in 0..medoids.len() {
                cost[j] += out.cluster_cost[j] as f64;
                weight[j] += out.cluster_count[j] as f64;
            }
            evals += (len * medoids.len()) as u64;
            start += len;
        }
        Ok(())
    })?;
    Ok(WeightedAssignResult {
        labels,
        weighted_mindists: mindists,
        cluster_cost: cost,
        cluster_weight: weight,
        dist_evals: evals,
    })
}

/// Weighted PAM-update candidate costs: for every candidate, the
/// weight-scaled summed dissimilarity `Σ_j w_j · d(c_i, p_j)` over all
/// members, composed over fixed-size blocks. Same staging/chunking shape
/// as [`pairwise_costs_src`] with the member weights riding in the mask
/// lane — the weighted medoid-update step of the coreset pipeline.
///
/// Deliberately a twin of [`pairwise_costs_src`]'s blocking loop rather
/// than a delegation: the unweighted path must keep dispatching through
/// the *overridable* `pairwise_block_partial{,_metric}` backend methods
/// (the paper-workload hot path), while this one dispatches through
/// `pairwise_block_weighted`. Changes to the blocking/padding scheme
/// must be applied to both loops (the unit-weight-reduction test pins
/// them byte-identical).
pub fn weighted_pairwise_costs_src<C, M>(
    be: &dyn ComputeBackend,
    candidates: &C,
    members: &M,
    metric: Metric,
) -> Result<(Vec<f64>, u64)>
where
    C: PointSource + ?Sized,
    M: WeightedSource + ?Sized,
{
    let b = be.block();
    let nc = candidates.len();
    let nm = members.len();
    let mut out = vec![0f64; nc];
    let mut evals = 0u64;
    if nc == 0 || nm == 0 {
        return Ok((out, evals));
    }
    let dims = candidates.dims();
    assert_eq!(dims, members.dims(), "candidates/members dims mismatch");
    assert!(metric.supports_dims(dims), "{} does not support dims={dims}", metric.name());

    PAIR_SCRATCH.with(|scratch| -> Result<()> {
        let mut guard = scratch.borrow_mut();
        let PairScratch { cbuf, mbuf, mmask } = &mut *guard;
        grow(cbuf, dims * b);
        grow(mbuf, dims * b);
        grow(mmask, b);
        let cbuf = &mut cbuf[..dims * b];
        let mbuf = &mut mbuf[..dims * b];
        let mmask = &mut mmask[..b];

        let mut cs = 0usize;
        while cs < nc {
            let clen = (nc - cs).min(b);
            candidates.fill_coords(cs, clen, &mut cbuf[..dims * clen]);
            cbuf[dims * clen..].fill(0.0);
            let mut ms = 0usize;
            while ms < nm {
                let mlen = (nm - ms).min(b);
                members.fill_coords(ms, mlen, &mut mbuf[..dims * mlen]);
                members.fill_weights(ms, mlen, &mut mmask[..mlen]);
                mbuf[dims * mlen..].fill(0.0);
                mmask[mlen..].fill(0.0);
                let partial =
                    be.pairwise_block_weighted(dims, metric, cbuf, mbuf, mmask, clen)?;
                for i in 0..clen {
                    out[cs + i] += partial[i] as f64;
                }
                evals += (clen * mlen) as u64;
                ms += mlen;
            }
            cs += clen;
        }
        Ok(())
    })?;
    Ok((out, evals))
}

#[cfg(test)]
mod tests {
    use super::super::backend::NativeBackend;
    use super::*;
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    fn be() -> NativeBackend {
        NativeBackend::new(64, 8)
    }

    fn rand_points(rng: &mut Rng, n: usize, spread: f64) -> Vec<Point> {
        rand_points_d(rng, n, spread, 2)
    }

    fn rand_points_d(rng: &mut Rng, n: usize, spread: f64, dims: usize) -> Vec<Point> {
        (0..n)
            .map(|_| {
                let coords: Vec<f32> =
                    (0..dims).map(|_| (rng.f64() * spread - spread / 2.0) as f32).collect();
                Point::from_slice(&coords)
            })
            .collect()
    }

    fn brute_assign(points: &[Point], medoids: &[Point], metric: Metric) -> (Vec<u32>, Vec<f64>) {
        points
            .iter()
            .map(|p| {
                let (mut bj, mut bd) = (0u32, f64::INFINITY);
                for (j, m) in medoids.iter().enumerate() {
                    let d = metric.distance(p, m);
                    if d < bd {
                        bd = d;
                        bj = j as u32;
                    }
                }
                (bj, bd)
            })
            .unzip()
    }

    #[test]
    fn assign_points_matches_brute_force_any_n() {
        for_all(20, 0xA551, |rng| {
            let n = 1 + rng.below(300); // exercises partial last block
            let k = 1 + rng.below(7);
            let pts = rand_points(rng, n, 100.0);
            let med = rand_points(rng, k, 100.0);
            let got = assign_points(&be(), &pts, &med, Metric::SqEuclidean).unwrap();
            let (bl, bd) = brute_assign(&pts, &med, Metric::SqEuclidean);
            assert_eq!(got.labels, bl);
            for (g, w) in got.mindists.iter().zip(&bd) {
                assert!((*g as f64 - w).abs() < 1e-2, "{g} vs {w}");
            }
            // Aggregates consistent with labels.
            let mut cnt = vec![0u64; k];
            for &l in &got.labels {
                cnt[l as usize] += 1;
            }
            assert_eq!(got.cluster_count, cnt);
            let total_cost: f64 = got.cluster_cost.iter().sum();
            let brute_total: f64 = bd.iter().sum();
            assert!((total_cost - brute_total).abs() < 1e-1 * brute_total.max(1.0));
        });
    }

    #[test]
    fn assign_points_generic_matches_brute_force() {
        // The generic kernel path: every (dims, metric) beyond 2-D
        // squared Euclidean, against the f64 oracle.
        let combos: [(usize, Metric); 5] = [
            (3, Metric::SqEuclidean),
            (8, Metric::SqEuclidean),
            (2, Metric::Manhattan),
            (3, Metric::Manhattan),
            (8, Metric::Manhattan),
        ];
        for (dims, metric) in combos {
            for_all(8, 0xD0 ^ dims as u64, |rng| {
                let n = 1 + rng.below(200);
                let k = 1 + rng.below(7);
                let pts = rand_points_d(rng, n, 100.0, dims);
                let med = rand_points_d(rng, k, 100.0, dims);
                let got = assign_points(&be(), &pts, &med, metric).unwrap();
                let (bl, bd) = brute_assign(&pts, &med, metric);
                assert_eq!(got.labels, bl, "labels d={dims} {metric:?}");
                for (g, w) in got.mindists.iter().zip(&bd) {
                    assert!((*g as f64 - w).abs() < 1e-2 * w.max(1.0), "{g} vs {w}");
                }
                let mut cnt = vec![0u64; k];
                for &l in &got.labels {
                    cnt[l as usize] += 1;
                }
                assert_eq!(got.cluster_count, cnt);
            });
        }
    }

    #[test]
    fn assign_points_haversine_matches_brute_force() {
        for_all(10, 0x6E0, |rng| {
            let n = 1 + rng.below(150);
            let k = 1 + rng.below(5);
            let mk = |rng: &mut Rng, n: usize| -> Vec<Point> {
                (0..n)
                    .map(|_| {
                        Point::new(
                            rng.range_f64(-80.0, 80.0) as f32,
                            rng.range_f64(-179.0, 179.0) as f32,
                        )
                    })
                    .collect()
            };
            let pts = mk(rng, n);
            let med = mk(rng, k);
            let got = assign_points(&be(), &pts, &med, Metric::Haversine).unwrap();
            let (bl, bd) = brute_assign(&pts, &med, Metric::Haversine);
            // f32 trig can flip near-ties; check distances, not labels.
            for (i, (g, w)) in got.mindists.iter().zip(&bd).enumerate() {
                assert!(
                    (*g as f64 - w).abs() < 1e-3 * w.max(1.0) + 0.5,
                    "point {i}: {g} vs {w} (label {} vs {})",
                    got.labels[i],
                    bl[i]
                );
            }
        });
    }

    #[test]
    fn pairwise_costs_match_brute_force_any_sizes() {
        for_all(15, 0xBEEF, |rng| {
            let nc = 1 + rng.below(150);
            let nm = 1 + rng.below(200);
            let cands = rand_points(rng, nc, 50.0);
            let membs = rand_points(rng, nm, 50.0);
            let got = pairwise_costs(&be(), &cands, &membs, Metric::SqEuclidean).unwrap();
            for (i, c) in cands.iter().enumerate() {
                let want: f64 = membs.iter().map(|m| c.dist2(m)).sum();
                assert!(
                    (got[i] - want).abs() < 1e-4 * want.max(1.0),
                    "cand {i}: {} vs {want}",
                    got[i]
                );
            }
        });
    }

    #[test]
    fn pairwise_costs_generic_match_brute_force() {
        for (dims, metric) in [(3usize, Metric::Manhattan), (8, Metric::SqEuclidean)] {
            for_all(8, 0xFACE ^ dims as u64, |rng| {
                let nc = 1 + rng.below(90);
                let nm = 1 + rng.below(150);
                let cands = rand_points_d(rng, nc, 50.0, dims);
                let membs = rand_points_d(rng, nm, 50.0, dims);
                let got = pairwise_costs(&be(), &cands, &membs, metric).unwrap();
                for (i, c) in cands.iter().enumerate() {
                    let want: f64 = membs.iter().map(|m| metric.distance(c, m)).sum();
                    assert!(
                        (got[i] - want).abs() < 1e-3 * want.max(1.0),
                        "d={dims} {metric:?} cand {i}: {} vs {want}",
                        got[i]
                    );
                }
            });
        }
    }

    #[test]
    fn empty_members_zero_cost() {
        let got =
            pairwise_costs(&be(), &[Point::new(1.0, 1.0)], &[], Metric::SqEuclidean).unwrap();
        assert_eq!(got, vec![0.0]);
    }

    #[test]
    fn packed_members_match_slice_members() {
        use crate::util::codec::{Enc, PackedPoints};
        for dims in [2usize, 3] {
            for_all(8, 0xC0DE ^ dims as u64, |rng| {
                let nc = 1 + rng.below(40);
                let nm = 1 + rng.below(180);
                let cands = rand_points_d(rng, nc, 50.0, dims);
                let membs = rand_points_d(rng, nm, 50.0, dims);
                // Split members into a few packed byte runs, as the shuffle
                // delivers them (one run per map task).
                let n_runs = 1 + rng.below(4);
                let mut runs: Vec<Vec<u8>> = Vec::new();
                for c in membs.chunks(nm.div_ceil(n_runs)) {
                    let mut enc = Enc::with_capacity(4 * dims * c.len());
                    for p in c {
                        enc = enc.f32s(p.coords());
                    }
                    runs.push(enc.done());
                }
                let packed = PackedPoints::new(dims, runs.iter().map(|r| r.as_slice()));
                assert_eq!(packed.len(), nm);
                let metric = if dims == 2 { Metric::SqEuclidean } else { Metric::Manhattan };
                let via_slice = pairwise_costs(&be(), &cands, &membs, metric).unwrap();
                let (via_packed, evals) =
                    pairwise_costs_src(&be(), cands.as_slice(), &packed, metric).unwrap();
                assert_eq!(via_slice, via_packed, "packed view must be byte-identical");
                assert_eq!(evals, (nc * nm) as u64, "pairwise evals are counted exactly");
            });
        }
    }

    #[test]
    fn weighted_pairwise_matches_oracle_and_unit_weights_reduce() {
        use crate::geo::Weighted;
        for (dims, metric) in [(2usize, Metric::SqEuclidean), (3, Metric::Manhattan)] {
            for_all(10, 0x73D ^ dims as u64, |rng| {
                let nc = 1 + rng.below(70);
                let nm = 1 + rng.below(150);
                let cands = rand_points_d(rng, nc, 50.0, dims);
                let membs = rand_points_d(rng, nm, 50.0, dims);
                let ws: Vec<f32> = (0..nm).map(|_| rng.range_f64(0.0, 4.0) as f32).collect();
                let view = Weighted::new(membs.as_slice(), &ws);
                let (got, wev) =
                    weighted_pairwise_costs_src(&be(), cands.as_slice(), &view, metric).unwrap();
                assert_eq!(wev, (nc * nm) as u64);
                for (i, c) in cands.iter().enumerate() {
                    let want: f64 = membs
                        .iter()
                        .zip(&ws)
                        .map(|(m, &w)| w as f64 * metric.distance(c, m))
                        .sum();
                    assert!(
                        (got[i] - want).abs() < 1e-2 * want.max(1.0),
                        "d={dims} {metric:?} cand {i}: {} vs {want}",
                        got[i]
                    );
                }
                // Unit weights are byte-identical to the unweighted op.
                let ones = vec![1.0f32; nm];
                let unit = Weighted::new(membs.as_slice(), &ones);
                let (w1, _) =
                    weighted_pairwise_costs_src(&be(), cands.as_slice(), &unit, metric).unwrap();
                let u = pairwise_costs(&be(), &cands, &membs, metric).unwrap();
                assert_eq!(w1, u, "unit weights must reduce exactly");
            });
        }
    }

    #[test]
    fn assign_weighted_matches_oracle() {
        use crate::geo::Weighted;
        for_all(12, 0xA570, |rng| {
            let n = 1 + rng.below(200);
            let k = 1 + rng.below(6);
            let pts = rand_points(rng, n, 80.0);
            let med = rand_points(rng, k, 80.0);
            let ws: Vec<f32> = (0..n).map(|_| rng.range_f64(0.0, 5.0) as f32).collect();
            let view = Weighted::new(pts.as_slice(), &ws);
            let got = assign_weighted(&be(), &view, &med, Metric::SqEuclidean).unwrap();
            // Labels pick a (near-)nearest medoid: compare by f64
            // distance, not index (f32 kernels may flip exact ties).
            let mut cost = vec![0f64; k];
            let mut weight = vec![0f64; k];
            for i in 0..n {
                let got_d = pts[i].dist2(&med[got.labels[i] as usize]);
                let best = med.iter().map(|m| pts[i].dist2(m)).fold(f64::INFINITY, f64::min);
                assert!(
                    got_d <= best * (1.0 + 1e-3) + 1e-3,
                    "point {i}: labeled distance {got_d} vs best {best}"
                );
                cost[got.labels[i] as usize] += ws[i] as f64 * got_d;
                weight[got.labels[i] as usize] += ws[i] as f64;
            }
            for j in 0..k {
                assert!(
                    (got.cluster_cost[j] - cost[j]).abs() < 1e-2 * cost[j].max(1.0),
                    "cluster {j}: {} vs {}",
                    got.cluster_cost[j],
                    cost[j]
                );
                assert!((got.cluster_weight[j] - weight[j]).abs() < 1e-3, "weight {j}");
            }
        });
    }

    #[test]
    fn dense_lane_counts_exactly_n_times_k() {
        for_all(10, 0xE7A1, |rng| {
            let n = 1 + rng.below(300);
            let k = 1 + rng.below(7);
            let pts = rand_points(rng, n, 100.0);
            let med = rand_points(rng, k, 100.0);
            let got = assign_points(&be(), &pts, &med, Metric::SqEuclidean).unwrap();
            assert_eq!(got.dist_evals, (n * k) as u64);
        });
    }

    #[test]
    #[should_panic(expected = "exceeds backend capacity")]
    fn too_many_medoids_panics() {
        let pts = vec![Point::new(0.0, 0.0)];
        let med = vec![Point::new(0.0, 0.0); 9];
        let _ = assign_points(&be(), &pts, &med, Metric::SqEuclidean);
    }
}
