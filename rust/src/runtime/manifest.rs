//! AOT artifact manifest: what `python/compile/aot.py` produced.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, PartialEq)]
pub enum UnitKind {
    Assign,
    Pairwise,
    Seed,
}

impl UnitKind {
    fn parse(s: &str) -> Result<UnitKind> {
        Ok(match s {
            "assign" => UnitKind::Assign,
            "pairwise" => UnitKind::Pairwise,
            "seed" => UnitKind::Seed,
            other => bail!("unknown AOT unit kind {other:?}"),
        })
    }
}

/// One compiled executable variant.
#[derive(Debug, Clone)]
pub struct UnitMeta {
    pub name: String,
    pub kind: UnitKind,
    /// Points-block size B.
    pub block: usize,
    /// Padded medoid capacity K (assign/seed only; pairwise keeps the
    /// lowering-time value but does not use it).
    pub kpad: usize,
    pub path: PathBuf,
    /// Sentinel coordinate for padded medoid slots.
    pub pad_coord: f32,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub units: Vec<UnitMeta>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parse {path:?}"))?;
        let fmt = j.get("format").and_then(|f| f.as_u64()).unwrap_or(0);
        if fmt != 1 {
            bail!("unsupported manifest format {fmt}");
        }
        let mut units = Vec::new();
        for u in j.get("units").and_then(|u| u.as_arr()).context("manifest.units missing")? {
            let get_str =
                |k: &str| u.get(k).and_then(|v| v.as_str()).with_context(|| format!("unit.{k}"));
            let get_num =
                |k: &str| u.get(k).and_then(|v| v.as_f64()).with_context(|| format!("unit.{k}"));
            let file = get_str("file")?;
            let path = dir.join(file);
            if !path.exists() {
                bail!("artifact listed in manifest but missing on disk: {path:?}");
            }
            units.push(UnitMeta {
                name: get_str("name")?.to_string(),
                kind: UnitKind::parse(get_str("kind")?)?,
                block: get_num("block")? as usize,
                kpad: get_num("kpad")? as usize,
                path,
                pad_coord: get_num("pad_coord")? as f32,
            });
        }
        if units.is_empty() {
            bail!("manifest has no units");
        }
        Ok(Manifest { units, dir: dir.to_path_buf() })
    }

    /// Best unit of `kind` whose block is >= `min_block`: smallest such
    /// block, and among equal blocks the smallest medoid capacity that
    /// still holds `min_kpad` slots (padded slots are wasted work on the
    /// fixed-shape executable — §Perf). Falls back to the largest block
    /// available if none fits.
    pub fn pick(&self, kind: UnitKind, min_block: usize) -> Option<&UnitMeta> {
        self.pick_k(kind, min_block, 0)
    }

    pub fn pick_k(&self, kind: UnitKind, min_block: usize, min_kpad: usize) -> Option<&UnitMeta> {
        let mut of_kind: Vec<&UnitMeta> = self
            .units
            .iter()
            .filter(|u| u.kind == kind && u.kpad >= min_kpad)
            .collect();
        if of_kind.is_empty() {
            return None;
        }
        of_kind.sort_by_key(|u| (u.block, u.kpad));
        of_kind.iter().find(|u| u.block >= min_block).copied().or(of_kind.last().copied())
    }
}

/// Default artifact dir: `$KMR_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("KMR_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // Relative to the crate root (works for tests/benches/examples).
    let here = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    here.join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_repo_manifest_if_built() {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.units.iter().any(|u| u.kind == UnitKind::Assign && u.block == 2048));
        assert!(m.units.iter().all(|u| u.pad_coord == 1e9));
    }

    #[test]
    fn pick_prefers_smallest_sufficient() {
        let mk = |name: &str, kind: UnitKind, block: usize| UnitMeta {
            name: name.into(),
            kind,
            block,
            kpad: 16,
            path: PathBuf::new(),
            pad_coord: 1e9,
        };
        let m = Manifest {
            units: vec![
                mk("a", UnitKind::Assign, 2048),
                mk("b", UnitKind::Assign, 256),
                mk("c", UnitKind::Pairwise, 256),
            ],
            dir: PathBuf::new(),
        };
        assert_eq!(m.pick(UnitKind::Assign, 100).unwrap().block, 256);
        assert_eq!(m.pick(UnitKind::Assign, 1000).unwrap().block, 2048);
        assert_eq!(m.pick(UnitKind::Assign, 10_000).unwrap().block, 2048);
        assert!(m.pick(UnitKind::Seed, 1).is_none());
    }

    #[test]
    fn rejects_bad_manifest() {
        let dir = std::env::temp_dir().join("kmr_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"format":99,"units":[]}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::write(dir.join("manifest.json"), r#"{"format":1,"units":[]}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
