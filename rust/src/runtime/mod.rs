//! Runtime: AOT artifact loading + fixed-shape block execution.
//!
//! The production path is [`pjrt::PjrtBackend`] (HLO text → PJRT compile →
//! execute); [`backend::NativeBackend`] is the pure-Rust oracle and
//! ablation baseline. [`ops`] adapts arbitrary-size point sets onto the
//! fixed block shapes. [`load_default_backend`] picks PJRT when artifacts
//! exist and falls back to native (with a warning) otherwise.

pub mod backend;
pub mod manifest;
pub mod ops;
pub mod pjrt;
pub mod pruned;

pub use backend::{AssignOut, ComputeBackend, NativeBackend};
pub use manifest::{default_artifacts_dir, Manifest, UnitKind};
pub use ops::{
    assign_points, assign_weighted, pairwise_costs, pairwise_costs_src,
    weighted_pairwise_costs_src, AssignResult, WeightedAssignResult,
};
pub use pjrt::PjrtBackend;
pub use pruned::{PrunedAssigner, PruningMode};

use std::sync::Arc;

/// Backend selection for drivers/benches.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BackendKind {
    Pjrt,
    Native,
    /// PJRT if artifacts are present, else native.
    Auto,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "pjrt" => Some(BackendKind::Pjrt),
            "native" => Some(BackendKind::Native),
            "auto" => Some(BackendKind::Auto),
            _ => None,
        }
    }
}

/// Load a compute backend. `min_block` picks the artifact variant (use
/// 2048 for production workloads, 256 for tests/examples).
pub fn load_backend(
    kind: BackendKind,
    min_block: usize,
) -> anyhow::Result<Arc<dyn ComputeBackend>> {
    match kind {
        BackendKind::Native => Ok(Arc::new(NativeBackend::new(min_block, 64.min(min_block)))),
        BackendKind::Pjrt => {
            let m = Manifest::load(&default_artifacts_dir())?;
            Ok(Arc::new(PjrtBackend::load(&m, min_block)?))
        }
        BackendKind::Auto => {
            let dir = default_artifacts_dir();
            if dir.join("manifest.json").exists() {
                let m = Manifest::load(&dir)?;
                Ok(Arc::new(PjrtBackend::load(&m, min_block)?))
            } else {
                log::warn!("artifacts not built; falling back to native backend");
                Ok(Arc::new(NativeBackend::new(min_block, 64.min(min_block))))
            }
        }
    }
}
