//! HDFS-lite: an in-memory distributed file system model.
//!
//! Files are split into fixed-size blocks; each block is replicated onto
//! `replication` distinct nodes with a host-aware placement policy (first
//! replica "local", second on a different host, third anywhere else —
//! Hadoop's rack-aware policy with hosts standing in for racks). The
//! MapReduce engine asks the NameNode for block locations to schedule
//! data-local map tasks, exactly as the paper's JobTracker does.

use crate::config::ClusterConfig;
use crate::util::rng::Rng;
use std::collections::HashMap;

pub type BlockId = u64;

/// Failing a node would leave the DFS with no live DataNodes: nothing can
/// be re-replicated and every block is unreadable. Surfaced as a typed
/// error (rather than an assert) so the MapReduce scheduler can report a
/// cluster-dead job failure instead of panicking.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NoLiveDataNodes {
    /// The node whose loss emptied the cluster.
    pub failed: usize,
}

impl std::fmt::Display for NoLiveDataNodes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "DataNode {} was the last live node: the DFS has no replicas left to serve or re-replicate",
            self.failed
        )
    }
}

impl std::error::Error for NoLiveDataNodes {}

/// What re-replication after a DataNode loss actually moved: the NameNode
/// copies every under-replicated block from a surviving replica to a
/// fresh node, so `bytes` is real cross-node network traffic — the
/// MapReduce engine charges it to the simulated clock through
/// [`crate::sim::CostModel::rereplication_seconds`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ReplicationRepair {
    /// Blocks that got a fresh replica.
    pub blocks: usize,
    /// Bytes copied across the network to create those replicas.
    pub bytes: u64,
}

#[derive(Debug, Clone)]
pub struct Block {
    pub id: BlockId,
    pub bytes: u64,
    /// Nodes currently holding a replica. Invariant: distinct, non-empty
    /// unless every replica's node failed (then reads fail).
    pub replicas: Vec<usize>,
    /// Row range [start, end) of the file's logical records stored here.
    pub row_start: u64,
    pub row_end: u64,
}

#[derive(Debug, Clone)]
pub struct FileMeta {
    pub name: String,
    pub blocks: Vec<BlockId>,
    pub total_bytes: u64,
    pub total_rows: u64,
}

/// The NameNode: file → blocks → replica locations.
pub struct NameNode {
    files: HashMap<String, FileMeta>,
    blocks: HashMap<BlockId, Block>,
    next_block: BlockId,
    /// Bytes stored per node (placement balancing).
    pub node_usage: Vec<u64>,
    /// Nodes currently alive.
    alive: Vec<bool>,
    replication: usize,
    block_bytes: u64,
    hosts: Vec<usize>,
    rng: Rng,
}

impl NameNode {
    pub fn new(cluster: &ClusterConfig, seed: u64) -> NameNode {
        NameNode {
            files: HashMap::new(),
            blocks: HashMap::new(),
            next_block: 0,
            node_usage: vec![0; cluster.nodes.len()],
            alive: vec![true; cluster.nodes.len()],
            replication: cluster.dfs_replication.max(1),
            block_bytes: cluster.dfs_block_bytes,
            hosts: cluster.nodes.iter().map(|n| n.host).collect(),
            rng: Rng::new(seed ^ 0xD75),
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.alive.len()
    }

    /// Hadoop-style replica placement: least-used alive node first, then
    /// prefer a different host for the second replica, then fill.
    fn place_replicas(&mut self) -> Vec<usize> {
        let alive: Vec<usize> = (0..self.alive.len()).filter(|&n| self.alive[n]).collect();
        assert!(!alive.is_empty(), "no alive DataNodes");
        let r = self.replication.min(alive.len());
        let mut chosen: Vec<usize> = Vec::with_capacity(r);
        // First replica: least-used (random tie-break).
        let first = *alive
            .iter()
            .min_by_key(|&&n| (self.node_usage[n], self.rng.next_u64() & 0xff))
            .unwrap();
        chosen.push(first);
        // Second: different host if possible, least-used.
        while chosen.len() < r {
            let need_other_host = chosen.len() == 1;
            let candidates: Vec<usize> = alive
                .iter()
                .copied()
                .filter(|n| !chosen.contains(n))
                .filter(|&n| !need_other_host || self.hosts[n] != self.hosts[first] || {
                    // fall back if all remaining share the host
                    alive.iter().all(|&m| chosen.contains(&m) || self.hosts[m] == self.hosts[first])
                })
                .collect();
            let pick = *candidates
                .iter()
                .min_by_key(|&&n| (self.node_usage[n], self.rng.next_u64() & 0xff))
                .expect("placement candidates exhausted");
            chosen.push(pick);
        }
        chosen
    }

    /// Create a file of `total_rows` logical rows / `total_bytes` bytes,
    /// split into block-size chunks with replica placement. Returns meta.
    pub fn create_file(&mut self, name: &str, total_rows: u64, total_bytes: u64) -> &FileMeta {
        assert!(!self.files.contains_key(name), "file exists: {name}");
        let n_blocks = total_bytes.div_ceil(self.block_bytes).max(1);
        let mut ids = Vec::with_capacity(n_blocks as usize);
        for b in 0..n_blocks {
            let id = self.next_block;
            self.next_block += 1;
            let bytes = if b == n_blocks - 1 {
                total_bytes - self.block_bytes * (n_blocks - 1)
            } else {
                self.block_bytes
            };
            let row_start = total_rows * b / n_blocks;
            let row_end = total_rows * (b + 1) / n_blocks;
            let replicas = self.place_replicas();
            for &n in &replicas {
                self.node_usage[n] += bytes;
            }
            self.blocks.insert(id, Block { id, bytes, replicas, row_start, row_end });
            ids.push(id);
        }
        self.files.insert(
            name.to_string(),
            FileMeta { name: name.to_string(), blocks: ids, total_bytes, total_rows },
        );
        &self.files[name]
    }

    pub fn file(&self, name: &str) -> Option<&FileMeta> {
        self.files.get(name)
    }

    pub fn delete_file(&mut self, name: &str) {
        if let Some(meta) = self.files.remove(name) {
            for b in meta.blocks {
                if let Some(blk) = self.blocks.remove(&b) {
                    for &n in &blk.replicas {
                        self.node_usage[n] = self.node_usage[n].saturating_sub(blk.bytes);
                    }
                }
            }
        }
    }

    pub fn block(&self, id: BlockId) -> &Block {
        &self.blocks[&id]
    }

    /// Replica nodes for a block that are currently alive.
    pub fn locations(&self, id: BlockId) -> Vec<usize> {
        self.blocks[&id].replicas.iter().copied().filter(|&n| self.alive[n]).collect()
    }

    /// Fail-stop a DataNode; re-replicate every block it held (if enough
    /// alive nodes exist). Returns the [`ReplicationRepair`] traffic
    /// summary, or a typed [`NoLiveDataNodes`] error when this was the
    /// last live node (the node is still marked dead — fail-stop is a
    /// fact — but nothing can be re-replicated and reads will fail).
    pub fn fail_node(&mut self, node: usize) -> Result<ReplicationRepair, NoLiveDataNodes> {
        self.alive[node] = false;
        self.node_usage[node] = 0;
        if !self.alive.iter().any(|&a| a) {
            return Err(NoLiveDataNodes { failed: node });
        }
        let ids: Vec<BlockId> = self
            .blocks
            .values()
            .filter(|b| b.replicas.contains(&node))
            .map(|b| b.id)
            .collect();
        let mut repair = ReplicationRepair::default();
        for id in ids {
            // Remove the dead replica, then add a fresh one elsewhere.
            let (bytes, mut reps) = {
                let b = &self.blocks[&id];
                (b.bytes, b.replicas.clone())
            };
            reps.retain(|&n| n != node);
            let alive: Vec<usize> = (0..self.alive.len())
                .filter(|&n| self.alive[n] && !reps.contains(&n))
                .collect();
            if let Some(&new) = alive.iter().min_by_key(|&&n| (self.node_usage[n], n)) {
                reps.push(new);
                self.node_usage[new] += bytes;
                repair.blocks += 1;
                repair.bytes += bytes;
            }
            self.blocks.get_mut(&id).unwrap().replicas = reps;
        }
        Ok(repair)
    }

    pub fn recover_node(&mut self, node: usize) {
        self.alive[node] = true;
    }

    pub fn is_alive(&self, node: usize) -> bool {
        self.alive[node]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::for_all;

    fn nn(nodes: usize) -> NameNode {
        NameNode::new(&ClusterConfig::test_cluster(nodes), 1)
    }

    #[test]
    fn file_splits_into_blocks() {
        let mut n = nn(4);
        let meta = n.create_file("pts", 1000, 20 << 20).clone(); // 8MB blocks -> 3 blocks
        assert_eq!(meta.blocks.len(), 3);
        assert_eq!(meta.total_rows, 1000);
        let rows: u64 = meta
            .blocks
            .iter()
            .map(|&b| {
                let blk = n.block(b);
                blk.row_end - blk.row_start
            })
            .sum();
        assert_eq!(rows, 1000);
    }

    #[test]
    fn replicas_distinct_and_replicated() {
        let mut n = nn(4);
        let meta = n.create_file("pts", 100, 30 << 20);
        for &b in &meta.blocks.clone() {
            let blk = n.block(b);
            assert_eq!(blk.replicas.len(), 2); // test cluster replication=2
            let mut r = blk.replicas.clone();
            r.dedup();
            assert_eq!(r.len(), blk.replicas.len());
        }
    }

    #[test]
    fn second_replica_prefers_other_host() {
        let mut n = NameNode::new(&ClusterConfig::paper_cluster(), 7);
        let meta = n.create_file("pts", 100, 200 << 20);
        for &b in &meta.blocks.clone() {
            let blk = n.block(b);
            assert_eq!(blk.replicas.len(), 3);
            let hosts: std::collections::HashSet<usize> =
                blk.replicas.iter().map(|&r| n.hosts[r]).collect();
            assert!(hosts.len() >= 2, "replicas all on one host: {:?}", blk.replicas);
        }
    }

    #[test]
    fn failure_rereplicates() {
        let mut n = nn(4);
        n.create_file("pts", 100, 40 << 20);
        let victim = 0;
        let held: Vec<BlockId> =
            n.blocks.values().filter(|b| b.replicas.contains(&victim)).map(|b| b.id).collect();
        assert!(!held.is_empty());
        let repair = n.fail_node(victim).expect("3 nodes survive");
        assert_eq!(repair.blocks, held.len(), "every held block should be re-replicated");
        let held_bytes: u64 = held.iter().map(|&id| n.block(id).bytes).sum();
        assert_eq!(repair.bytes, held_bytes, "repair traffic is the held bytes");
        for id in held {
            let b = n.block(id);
            assert!(!b.replicas.contains(&victim));
            assert_eq!(b.replicas.len(), 2, "replication restored");
            assert!(b.replicas.iter().all(|&r| n.is_alive(r)));
        }
    }

    #[test]
    fn locations_exclude_dead_nodes() {
        let mut n = nn(2); // replication 2 on 2 nodes -> both hold each block
        let meta = n.create_file("pts", 10, 1 << 20);
        let b = meta.blocks[0];
        assert_eq!(n.locations(b).len(), 2);
        n.fail_node(1).unwrap();
        let locs = n.locations(b);
        assert_eq!(locs, vec![0]);
    }

    #[test]
    fn last_node_failure_is_a_typed_error_not_a_panic() {
        let mut n = nn(2);
        n.create_file("pts", 100, 4 << 20);
        n.fail_node(1).expect("one node still alive");
        let err = n.fail_node(0).expect_err("no live DataNodes remain");
        assert_eq!(err, NoLiveDataNodes { failed: 0 });
        assert!(err.to_string().contains("last live node"), "{err}");
        // Fail-stop is still a fact: the node is down and reads fail.
        assert!(!n.is_alive(0));
        let b = n.file("pts").unwrap().blocks[0];
        assert!(n.locations(b).is_empty());
        // Recovery brings the cluster back to a usable state.
        n.recover_node(0);
        assert!(!n.locations(b).is_empty());
    }

    #[test]
    fn delete_releases_usage() {
        let mut n = nn(4);
        n.create_file("pts", 100, 16 << 20);
        assert!(n.node_usage.iter().sum::<u64>() > 0);
        n.delete_file("pts");
        assert_eq!(n.node_usage.iter().sum::<u64>(), 0);
        assert!(n.file("pts").is_none());
    }

    #[test]
    fn placement_balances_usage() {
        for_all(5, 0xDF5, |rng| {
            let mut n = NameNode::new(&ClusterConfig::test_cluster(6), rng.next_u64());
            n.create_file("big", 10_000, 400 << 20); // 50 blocks x 8MB x2 replicas
            let max = *n.node_usage.iter().max().unwrap() as f64;
            let min = *n.node_usage.iter().min().unwrap() as f64;
            assert!(max / min.max(1.0) < 2.0, "unbalanced: {:?}", n.node_usage);
        });
    }
}
