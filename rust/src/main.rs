//! kmedoids-mr — CLI for the Parallel K-Medoids++ MapReduce reproduction.
//!
//! Subcommands (hand-rolled parsing; clap is not in the offline vendor
//! set):
//!
//! ```text
//! kmedoids-mr generate --points N --hotspots K --seed S --out file.csv
//! kmedoids-mr run      --algo kmedoids++-mr --nodes 7 --dataset 0 [--scale 10]
//! kmedoids-mr bench    table6|fig4|fig5|ablation [--scale 10]
//! kmedoids-mr inspect-artifacts
//! ```

use anyhow::{bail, Context, Result};
use kmedoids_mr::driver::{run_experiment, Algorithm, Experiment};
use kmedoids_mr::geo::datasets::{generate, SpatialSpec};
use kmedoids_mr::geo::io::write_csv;
use kmedoids_mr::report;
use kmedoids_mr::runtime::{self, BackendKind};
use std::collections::HashMap;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Args { flags, positional }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }
    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }
}

fn real_main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "generate" => cmd_generate(&args),
        "run" => cmd_run(&args),
        "bench" => cmd_bench(&args),
        "inspect-artifacts" => cmd_inspect(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `kmedoids-mr help`)"),
    }
}

fn print_help() {
    println!(
        "kmedoids-mr — Parallel K-Medoids++ spatial clustering on MapReduce

USAGE:
  kmedoids-mr generate --points N [--hotspots H] [--seed S] --out FILE.csv
  kmedoids-mr run   [--algo ALGO] [--nodes N] [--dataset 0|1|2] [--k K]
                    [--scale DIV] [--seed S] [--backend auto|pjrt|native]
                    [--quality]
  kmedoids-mr bench table6|fig4|fig5|ablation [--scale DIV] [--seed S]
  kmedoids-mr inspect-artifacts

ALGO: kmedoids++-mr | kmedoids-mr | kmedoids-serial | clarans | kmeans-mr
"
    );
}

fn backend_from(args: &Args, min_block: usize) -> Result<std::sync::Arc<dyn runtime::ComputeBackend>> {
    let kind = match args.get("backend") {
        Some(s) => BackendKind::parse(s).with_context(|| format!("bad --backend {s:?}"))?,
        None => BackendKind::Auto,
    };
    runtime::load_backend(kind, min_block)
}

fn cmd_generate(args: &Args) -> Result<()> {
    let n = args.get_usize("points", 100_000)?;
    let hotspots = args.get_usize("hotspots", 9)?;
    let seed = args.get_u64("seed", 42)?;
    let out = args.get("out").context("--out FILE.csv is required")?;
    let d = generate(&SpatialSpec::new(n, hotspots, seed));
    let bytes = write_csv(std::path::Path::new(out), &d.points)?;
    println!("wrote {n} points ({bytes} bytes) to {out}");
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let algo = match args.get("algo") {
        Some(s) => Algorithm::parse(s).with_context(|| format!("unknown --algo {s:?}"))?,
        None => Algorithm::KMedoidsPlusPlusMR,
    };
    let nodes = args.get_usize("nodes", 7)?;
    let dataset = args.get_usize("dataset", 0)?;
    if dataset > 2 {
        bail!("--dataset must be 0, 1 or 2 (Table 5)");
    }
    let scale = args.get_usize("scale", 1)?;
    let seed = args.get_u64("seed", 42)?;
    let k = args.get_usize("k", 9)?;
    let backend = backend_from(args, 2048)?;

    let mut exp = Experiment::paper_cell(algo, nodes, dataset, seed).scaled(scale.max(1));
    exp.k = k;
    exp.with_quality = args.get("quality").is_some();
    println!(
        "running {} on dataset {} ({} points) with {} nodes (backend: {})",
        algo.name(),
        dataset + 1,
        exp.spec.n_points,
        nodes,
        backend.name()
    );
    let r = run_experiment(&exp, &backend);
    println!("  simulated time : {} ms", r.time_ms);
    println!("  iterations     : {}", r.iterations);
    println!("  final cost E   : {:.4e}", r.cost);
    println!("  dist evals     : {}", r.dist_evals);
    if let Some(ari) = r.ari {
        println!("  ARI vs truth   : {ari:.4}");
    }
    println!("  wallclock      : {:.2} s", r.wall_s);
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("table6");
    let scale = args.get_usize("scale", 1)?;
    let seed = args.get_u64("seed", 42)?;
    let backend = backend_from(args, 2048)?;
    match which {
        "table6" | "fig3" => {
            let results = kmedoids_mr::driver::suites::table6_suite(&backend, scale, seed);
            println!("\nTable 6 — execution time (ms), K-Medoids++ MR:\n");
            print!("{}", report::table6(&results));
            println!("\nFig. 4 — speedup vs 4-node cluster:\n");
            print!("{}", report::fig4_speedup(&results));
            println!("\nCSV:\n{}", report::to_csv(&results));
        }
        "fig4" => {
            let results = kmedoids_mr::driver::suites::table6_suite(&backend, scale, seed);
            println!("\nFig. 4 — speedup vs 4-node cluster:\n");
            print!("{}", report::fig4_speedup(&results));
        }
        "fig5" => {
            let results = kmedoids_mr::driver::suites::fig5_suite(&backend, scale, seed);
            println!("\nFig. 5 — comparative execution time (ms), 7 nodes:\n");
            print!("{}", report::fig5_comparative(&results));
            println!("\nCSV:\n{}", report::to_csv(&results));
        }
        "ablation" => {
            let results = kmedoids_mr::driver::suites::ablation_suite(&backend, scale, seed);
            println!("\nAblation — init strategy & iterations (dataset 1):\n");
            println!(
                "{:<18}{:>8}{:>12}{:>16}",
                "variant", "iters", "time(ms)", "cost"
            );
            for r in &results {
                println!(
                    "{:<18}{:>8}{:>12}{:>16.4e}",
                    r.algorithm, r.iterations, r.time_ms, r.cost
                );
            }
        }
        other => bail!("unknown bench {other:?} (table6|fig4|fig5|ablation)"),
    }
    Ok(())
}

fn cmd_inspect() -> Result<()> {
    let dir = runtime::default_artifacts_dir();
    let m = runtime::Manifest::load(&dir)?;
    println!("artifacts at {:?}:", m.dir);
    for u in &m.units {
        println!(
            "  {:<22} kind={:<9} B={:<6} K={:<4} pad={:e}  {:?}",
            u.name,
            format!("{:?}", u.kind),
            u.block,
            u.kpad,
            u.pad_coord,
            u.path.file_name().unwrap()
        );
    }
    Ok(())
}
