//! kmedoids-mr — CLI for the Parallel K-Medoids++ MapReduce reproduction.
//!
//! Subcommands (hand-rolled parsing; clap is not in the offline vendor
//! set):
//!
//! ```text
//! kmedoids-mr generate --points N --hotspots K --seed S --out file.csv
//! kmedoids-mr run      --algo kmedoids++-mr --nodes 7 --dataset 0 [--scale 10]
//! kmedoids-mr run      --spec cells.json
//! kmedoids-mr bench    table6|fig4|fig5|ablation [--scale 10] [--trace]
//! kmedoids-mr inspect-artifacts
//! ```
//!
//! `run` drives a [`kmedoids_mr::session::ClusterSession`] directly:
//! build cluster → ingest → fit through the `SpatialClusterer` trait,
//! streaming live per-iteration progress (`--trace`) and printing the
//! recorded iteration trace. `--spec FILE.json` drives any cell grid
//! from a JSON run-spec (see `kmedoids_mr::driver::spec`).

use anyhow::{bail, Context, Result};
use kmedoids_mr::config::ClusterConfig;
use kmedoids_mr::driver::suites::{LanesOpts, ScaleOpts, ServeOpts, SuiteOpts};
use kmedoids_mr::driver::{run_cell, spec, Algorithm, Experiment, ExperimentResult};
use kmedoids_mr::geo::binfmt;
use kmedoids_mr::geo::datasets::{generate, SpatialSpec};
use kmedoids_mr::geo::io::{read_csv, write_csv};
use kmedoids_mr::geo::{Metric, MAX_DIMS};
use kmedoids_mr::mapreduce::Lane;
use kmedoids_mr::prelude::{ClusterSession, IterationLog, PruningMode, StderrProgress};
use kmedoids_mr::report;
use kmedoids_mr::runtime::{self, BackendKind};
use kmedoids_mr::util::json::{obj, Json};
use std::collections::HashMap;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Flags that never take a value; they must not swallow a following
/// positional (`bench --trace fig5` keeps `fig5` as the suite name).
const BOOL_FLAGS: &[&str] =
    &["quality", "trace", "smoke", "latlon", "no-faults", "no-speculation", "resume"];

/// Tiny flag parser: `--key value` pairs after the subcommand. Unknown
/// flags are rejected (with a did-you-mean suggestion) by
/// [`Args::check_known`].
struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                let takes_value = !BOOL_FLAGS.contains(&key)
                    && i + 1 < argv.len()
                    && !argv[i + 1].starts_with("--");
                if takes_value {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Args { flags, positional }
    }

    /// Reject flags the subcommand does not accept — a typo like
    /// `--node 7` must error, not be silently ignored.
    fn check_known(&self, cmd: &str, allowed: &[&str]) -> Result<()> {
        for key in self.flags.keys() {
            if !allowed.contains(&key.as_str()) {
                let hint = allowed
                    .iter()
                    .map(|a| (levenshtein(key, a), a))
                    .min()
                    .filter(|(d, _)| *d <= 2)
                    .map(|(_, a)| format!(" (did you mean --{a}?)"))
                    .unwrap_or_default();
                bail!(
                    "unknown flag --{key} for `{cmd}`{hint}; accepted flags: {}",
                    allowed.iter().map(|a| format!("--{a}")).collect::<Vec<_>>().join(" ")
                );
            }
        }
        Ok(())
    }

    /// Reject stray positional operands (`run table6` is a typo for
    /// `bench table6`, not a request to run the default cell).
    fn check_positionals(&self, cmd: &str, max: usize) -> Result<()> {
        if self.positional.len() > max {
            bail!(
                "unexpected argument{} {:?} for `{cmd}`{}",
                if self.positional.len() - max > 1 { "s" } else { "" },
                self.positional[max..].join(" "),
                if max == 0 { "" } else { " (it takes one operand)" }
            );
        }
        Ok(())
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }
    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer, got {v:?}")),
            None => Ok(default),
        }
    }
    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer, got {v:?}")),
            None => Ok(default),
        }
    }
}

/// Edit distance for the did-you-mean hint.
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

fn real_main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "generate" => cmd_generate(&args),
        "convert" => cmd_convert(&args),
        "run" => cmd_run(&args),
        "bench" => cmd_bench(&args),
        "inspect-artifacts" => cmd_inspect(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `kmedoids-mr help`)"),
    }
}

fn print_help() {
    println!(
        "kmedoids-mr — Parallel K-Medoids++ spatial clustering on MapReduce

USAGE:
  kmedoids-mr generate --points N [--hotspots H] [--dims D] [--latlon]
                    [--seed S] --out FILE (.csv extension writes CSV,
                    anything else the binary dataset format)
  kmedoids-mr convert IN OUT   (CSV <-> binary, direction sniffed from IN)
  kmedoids-mr run   [--algo ALGO] [--nodes N] [--dataset 0|1|2 | --data FILE]
                    [--k K]
                    [--metric METRIC] [--dims D] [--oversample L] [--rounds R]
                    [--coreset-size C] [--pruning on|off|auto]
                    [--lane hadoop-mr|in-memory-dag] [--max-attempts N]
                    [--checkpoint-dir DIR] [--resume]
                    [--scale DIV] [--seed S] [--backend auto|pjrt|native]
                    [--threads N] [--quality] [--trace]
  kmedoids-mr run   --spec CELLS.json [--backend auto|pjrt|native] [--trace]
  kmedoids-mr bench table6|fig4|fig5|ablation [--scale DIV] [--seed S]
                    [--threads N] [--trace]
  kmedoids-mr bench perf [--scale DIV] [--seed S] [--threads 1,2,4]
                    [--checkpoint-dir DIR] [--out BENCH_perf.json] [--smoke]
  kmedoids-mr bench scale [--nodes 1,2,4,8,16] [--scale DIV] [--seed S]
                    [--faults N] [--fail-rate X] [--no-faults]
                    [--no-speculation] [--threads N] [--smoke]
                    [--out BENCH_scale.json]
  kmedoids-mr bench scale --spec SCALE.json [--smoke] [--threads N]
                    [--out BENCH_scale.json]
  kmedoids-mr bench serve [--threads 1,4] [--queries N] [--update-frac X]
                    [--batch B] [--coreset-size C] [--scale DIV] [--seed S]
                    [--smoke] [--out BENCH_serve.json]
  kmedoids-mr bench serve --spec SERVE.json [--smoke] [--out BENCH_serve.json]
  kmedoids-mr bench lanes [--nodes 1,2,4,8] [--scale DIV] [--seed S]
                    [--threads N] [--smoke] [--out BENCH_lanes.json]
  kmedoids-mr inspect-artifacts

ALGO:   kmedoids++-mr | kmedoids-mr | kmedoids-scalable-mr
        | kmedoids-coreset-mr | kmedoids-serial | clarans | kmeans-mr
METRIC: sq_euclidean (default) | manhattan | haversine

--metric haversine clusters (lat, lon) degree pairs by great-circle
distance (the synthetic dataset becomes city clouds on the sphere);
--dims D > 2 generates a D-dimensional Gaussian mixture and runs the
generic metric kernels. --oversample/--rounds tune the k-means||-style
seeding of kmedoids-scalable-mr (defaults: l = 2k, 5 rounds).
--coreset-size tunes kmedoids-coreset-mr's weighted-representative
budget (default O(k log n)); the coreset pipeline runs a constant two
MR jobs regardless of iteration count.

--pruning selects the assignment lane for the MR drivers (see README
\"Sub-linear assignment\"): `on` caches triangle-inequality bounds and
skips points whose nearest medoid provably did not move, `off` forces
the dense kernels, and `auto` (the default) prunes except on
checkpointed or resumed fits, whose recorded eval counts must match a
dense replay. Labels, medoids and cost are byte-identical either way —
only `work.dist.evals` changes.

--lane selects the execution backend the MR jobs run through (see
README \"Execution lanes\"): `hadoop-mr` (the default) models the Hadoop
batch runtime — JVM task launch, per-job input parse, disk shuffle —
while `in-memory-dag` (aliases: dag, spark) models a Spark-style DAG
engine that caches input splits in executor memory across iterations,
launches tasks without JVM spin-up, and overlaps a push-based shuffle.
Labels, medoids, cost and dist-eval counts are byte-identical across
lanes; only simulated time differs. MR algorithms only. The DAG lane
does not model task failures, so it refuses fault plans and
--max-attempts (which sets the Hadoop lane's per-task retry budget).

`bench lanes` runs every MR algorithm x cluster size once per execution
lane on the same ingested dataset and writes the MR-vs-DAG sim-time
comparison to BENCH_lanes.json. The command exits non-zero unless the
DAG-lane fits are byte-identical to the Hadoop-lane fits and strictly
faster on simulated time in every cell — the blocking CI quality gates.

--checkpoint-dir DIR durably checkpoints every MR iteration (atomic
write-rename, CRC-checked; see README \"Durability & crash recovery\");
--resume continues the fit from the newest snapshot in DIR instead of
seeding fresh, reproducing the uninterrupted run's labels, medoids and
cost bit-for-bit. MR k-medoids algorithms only.

--threads N runs the map/reduce real compute on N worker threads
(wallclock only — results and simulated time are identical at any N).
`bench perf` sweeps a comma-separated thread list, verifies the outputs
are identical at every width, and writes the wall-clock trajectory to
BENCH_perf.json.

`bench scale` reproduces the paper's speedup/sizeup/scaleup experiments
for the four MR algorithms (including kmedoids-coreset-mr, whose cells
record constant job counts) on a commodity cluster with the
fault-tolerant scheduler (task retries, speculative twins, node loss +
DFS re-replication). Every cell also runs a fault-injected twin and the
command exits non-zero unless the clustering output is byte-identical
with faults on vs off. A --spec file accepts keys nodes_sweep /
speculation / faults / scale_div / seed.

`bench serve` drives the online serving subsystem with a mixed workload:
per sweep point, reader threads stream nearest-medoid queries through
lock-free epoch-swapped model snapshots while the driver ingests delta
mini-batches (fold -> coreset recompress -> weighted refine -> publish).
BENCH_serve.json records throughput and p50/p99/p999 assign latencies
per thread count. The command exits non-zero unless serving answers are
byte-identical to the batch assign pass and every online update kept the
weighted coreset cost monotone. A --spec file accepts keys threads /
queries / update_frac / batch / coreset_size / scale_div / seed.

Dataset files (see README \"Dataset files & manifests\"): `generate
--out` writes CSV when the extension is .csv and the zero-copy binary
dataset format otherwise; `convert` flips a file between the two
formats. Both commands write a content-addressed `*.manifest.json`
sibling (format, dims, count, CRC-32, provenance). `run --data FILE`
and a spec cell's `dataset: {{\"file\": ...}}` ingest either format,
sniffed by magic, and produce labels, medoids and cost bit-identical
to the in-memory generator path.

Run-spec JSON (one cell object or an array; see driver::spec docs):
  {{\"algorithm\": \"kmedoids++-mr\", \"nodes\": 7, \"k\": 9,
   \"dataset\": {{\"paper_dataset\": 0, \"scale_div\": 100}}}}
  — or point a cell at a file: \"dataset\": {{\"file\": \"points.bin\"}}
"
    );
}

fn backend_from(
    args: &Args,
    min_block: usize,
) -> Result<std::sync::Arc<dyn runtime::ComputeBackend>> {
    let kind = match args.get("backend") {
        Some(s) => BackendKind::parse(s).with_context(|| format!("bad --backend {s:?}"))?,
        None => BackendKind::Auto,
    };
    runtime::load_backend(kind, min_block)
}

fn cmd_generate(args: &Args) -> Result<()> {
    args.check_known("generate", &["points", "hotspots", "dims", "latlon", "seed", "out"])?;
    args.check_positionals("generate", 0)?;
    let n = args.get_usize("points", 100_000)?;
    let hotspots = args.get_usize("hotspots", 9)?;
    let dims = args.get_usize("dims", 2)?;
    if !(2..=MAX_DIMS).contains(&dims) {
        bail!("--dims must be in 2..={MAX_DIMS}");
    }
    let seed = args.get_u64("seed", 42)?;
    let out = args.get("out").context("--out FILE.csv is required")?;
    let mut spec = SpatialSpec::new(n, hotspots, seed).with_dims(dims);
    if args.has("latlon") {
        if dims != 2 {
            bail!("--latlon generates (lat, lon) pairs: drop --dims or use --dims 2");
        }
        spec.latlon = true;
    }
    let d = generate(&spec);
    let out_path = std::path::Path::new(out);
    let csv = out_path.extension().and_then(|e| e.to_str()) == Some("csv");
    let bytes = if csv {
        write_csv(out_path, &d.points)?
    } else {
        binfmt::write_file(out_path, &d.points, None)?
    };
    let name = out_path.file_stem().and_then(|s| s.to_str()).unwrap_or("dataset");
    let provenance = obj(vec![("generator", spec::spatial_spec_to_json(&spec))]);
    let m = binfmt::emit_manifest(name, out_path, provenance)?;
    println!("wrote {n} points ({bytes} bytes, {}) to {out}", m.format);
    println!("manifest: {} (crc32 {:08x})", binfmt::manifest_path(out_path).display(), m.crc32);
    Ok(())
}

/// `convert`: flip a dataset file between the CSV and binary formats
/// (direction sniffed from the input's magic), writing the output
/// atomically with a content-addressed manifest sibling that is
/// verified against the output bytes before the command reports success.
fn cmd_convert(args: &Args) -> Result<()> {
    args.check_known("convert", &[])?;
    args.check_positionals("convert", 2)?;
    let [input, output] = match args.positional.as_slice() {
        [i, o] => [i.as_str(), o.as_str()],
        _ => bail!("usage: kmedoids-mr convert IN OUT (CSV <-> binary, direction sniffed)"),
    };
    let (in_path, out_path) = (std::path::Path::new(input), std::path::Path::new(output));
    let to_csv = binfmt::is_binary(in_path)?;
    let bytes = if to_csv {
        let df = binfmt::DatasetFile::read(in_path)?;
        if df.weighted() {
            bail!("{input}: carries a weight plane, which CSV cannot represent; keep it binary");
        }
        write_csv(out_path, &df.points())?
    } else {
        let points = read_csv(in_path)?;
        binfmt::write_file(out_path, &points, None)?
    };
    let name = out_path.file_stem().and_then(|s| s.to_str()).unwrap_or("dataset");
    let provenance = obj(vec![("source", Json::Str(input.to_string()))]);
    let m = binfmt::emit_manifest(name, out_path, provenance)?;
    binfmt::verify_manifest(out_path)?;
    println!(
        "converted {input} ({}) -> {output} ({}, {bytes} bytes, {} points, crc32 {:08x})",
        if to_csv { binfmt::FORMAT_BINARY } else { binfmt::FORMAT_CSV },
        m.format,
        m.count,
        m.crc32,
    );
    println!("manifest: {}", binfmt::manifest_path(out_path).display());
    Ok(())
}

/// Run one experiment cell on its own session, streaming progress.
fn run_one_cell(
    exp: &Experiment,
    backend: &std::sync::Arc<dyn runtime::ComputeBackend>,
    trace: bool,
) -> Result<ExperimentResult> {
    let paper = ClusterConfig::paper_cluster();
    if exp.n_nodes < 1 || exp.n_nodes > paper.nodes.len() {
        bail!("nodes must be between 1 and {} (Table 3 cluster)", paper.nodes.len());
    }
    let mut builder = ClusterSession::builder()
        .cluster(paper)
        .nodes(exp.n_nodes)
        .backend(backend.clone())
        .seed(exp.seed)
        .threads(exp.threads);
    if let Some(dir) = &exp.checkpoint_dir {
        builder = builder.checkpoint_dir(dir.clone());
    }
    if let Some(n) = exp.max_attempts {
        builder = builder.max_attempts(n);
    }
    let mut session = builder.build()?;
    let log = IterationLog::new();
    session.add_observer(Box::new(log.clone()));
    if trace {
        session.add_observer(Box::new(StderrProgress::new()));
    }
    println!(
        "running {} on {} points (d={}, metric {}) with {} nodes (backend: {}, {} compute thread{})",
        exp.algorithm.name(),
        exp.spec.n_points,
        exp.spec.dims,
        exp.metric.name(),
        exp.n_nodes,
        backend.name(),
        session.compute_threads(),
        if session.compute_threads() == 1 { "" } else { "s" }
    );
    let data = match &exp.data_file {
        Some(path) => session.ingest_file("points", path)?,
        None => session.ingest_spec("points", &exp.spec),
    };
    let r = run_cell(&mut session, exp, &data)?;
    print!("\niterations:\n{}", report::iteration_trace(&log.events()));
    println!("\n  simulated time : {} ms", r.time_ms);
    println!("  iterations     : {}", r.iterations);
    println!("  final cost E   : {:.4e}", r.cost);
    println!("  dist evals     : {}", r.dist_evals);
    if let Some(ari) = r.ari {
        println!("  ARI vs truth   : {ari:.4}");
    }
    println!("  MR jobs run    : {}", session.jobs_run());
    println!("  wallclock      : {:.2} s", r.wall_s);
    Ok(r)
}

fn cmd_run(args: &Args) -> Result<()> {
    args.check_known(
        "run",
        &[
            "spec", "algo", "nodes", "dataset", "data", "k", "metric", "dims", "oversample",
            "rounds", "coreset-size", "pruning", "lane", "max-attempts", "checkpoint-dir",
            "resume", "scale", "seed", "backend", "threads", "quality", "trace",
        ],
    )?;
    args.check_positionals("run", 0)?;
    let trace = args.has("trace");

    // Spec-file mode: drive any cell grid from JSON.
    if let Some(path) = args.get("spec") {
        for flag in [
            "algo", "nodes", "dataset", "data", "k", "metric", "dims", "oversample", "rounds",
            "coreset-size", "pruning", "lane", "max-attempts", "checkpoint-dir", "resume",
            "scale", "seed", "quality", "threads",
        ] {
            if args.has(flag) {
                bail!("--{flag} conflicts with --spec (put it in the spec file)");
            }
        }
        let src = std::fs::read_to_string(path).with_context(|| format!("read spec {path:?}"))?;
        let cells = spec::experiments_from_str(&src)?;
        let backend = backend_from(args, 2048)?;
        println!("{} cell(s) from {path}", cells.len());
        let mut results = Vec::new();
        for (i, exp) in cells.iter().enumerate() {
            println!("\n== cell {} / {} ==", i + 1, cells.len());
            results.push(run_one_cell(exp, &backend, trace)?);
        }
        println!("\nCSV (all cells):\n{}", report::to_csv(&results));
        return Ok(());
    }

    let algo = match args.get("algo") {
        Some(s) => Algorithm::parse(s).with_context(|| format!("unknown --algo {s:?}"))?,
        None => Algorithm::KMedoidsPlusPlusMR,
    };
    let nodes = args.get_usize("nodes", 7)?;
    let dataset = args.get_usize("dataset", 0)?;
    if dataset > 2 {
        bail!("--dataset must be 0, 1 or 2 (Table 5)");
    }
    let scale = args.get_usize("scale", 1)?;
    let seed = args.get_u64("seed", 42)?;
    let k = args.get_usize("k", 9)?;
    let metric = match args.get("metric") {
        Some(s) => Metric::parse(s).with_context(|| {
            format!("unknown --metric {s:?} (sq_euclidean|manhattan|haversine)")
        })?,
        None => Metric::SqEuclidean,
    };
    let dims = args.get_usize("dims", 2)?;
    if !(2..=MAX_DIMS).contains(&dims) {
        bail!("--dims must be in 2..={MAX_DIMS}");
    }
    if !metric.supports_dims(dims) {
        bail!("--metric {} does not support --dims {dims}", metric.name());
    }
    let backend = backend_from(args, 2048)?;

    let mut exp = Experiment::paper_cell(algo, nodes, dataset, seed).scaled(scale.max(1));
    exp.k = k;
    exp.metric = metric;
    exp.spec.dims = dims;
    if metric == Metric::Haversine {
        // Haversine runs cluster city clouds on the sphere.
        exp.spec.latlon = true;
    }
    if let Some(file) = args.get("data") {
        for flag in ["dataset", "scale", "dims"] {
            if args.has(flag) {
                bail!("--{flag} conflicts with --data (the file already fixes the dataset)");
            }
        }
        if args.has("quality") {
            bail!("--quality conflicts with --data (file datasets carry no ground-truth labels)");
        }
        if metric == Metric::Haversine {
            bail!(
                "--metric haversine needs declared (lat, lon) data; drive file datasets \
                 through --spec with dataset.latlon = true"
            );
        }
        let path = std::path::Path::new(file);
        let summary = binfmt::summarize(path).with_context(|| format!("--data {file}"))?;
        if !metric.supports_dims(summary.dims) {
            bail!("--metric {} does not support the file's {} dims", metric.name(), summary.dims);
        }
        exp.spec.n_points = summary.count;
        exp.spec.dims = summary.dims;
        exp.data_file = Some(path.to_path_buf());
    }
    if args.has("oversample") || args.has("rounds") {
        if algo != Algorithm::KMedoidsScalableMR {
            bail!("--oversample/--rounds only apply to --algo kmedoids-scalable-mr");
        }
        let l = args.get_usize("oversample", 2 * k.max(1))?;
        let rounds = args.get_usize("rounds", 5)?;
        if l == 0 || rounds == 0 {
            bail!("--oversample and --rounds must be >= 1");
        }
        exp.oversample = Some((l, rounds));
    }
    if args.has("coreset-size") {
        if algo != Algorithm::KMedoidsCoresetMR {
            bail!("--coreset-size only applies to --algo kmedoids-coreset-mr");
        }
        let size = args.get_usize("coreset-size", 0)?;
        if size == 0 {
            bail!("--coreset-size must be >= 1");
        }
        exp.coreset_size = Some(size);
    }
    if let Some(s) = args.get("pruning") {
        let honors = matches!(
            algo,
            Algorithm::KMedoidsPlusPlusMR
                | Algorithm::KMedoidsRandomMR
                | Algorithm::KMedoidsScalableMR
                | Algorithm::KMedoidsCoresetMR
                | Algorithm::KMeansMR
        );
        if !honors {
            bail!(
                "--pruning only applies to the MR drivers (the serial engines always run \
                 the dense kernels); --algo {} does not",
                algo.name()
            );
        }
        exp.pruning = PruningMode::parse(s)
            .with_context(|| format!("bad --pruning {s:?} (on|off|auto)"))?;
    }
    if let Some(s) = args.get("lane") {
        let honors = matches!(
            algo,
            Algorithm::KMedoidsPlusPlusMR
                | Algorithm::KMedoidsRandomMR
                | Algorithm::KMedoidsScalableMR
                | Algorithm::KMedoidsCoresetMR
                | Algorithm::KMeansMR
        );
        if !honors {
            bail!(
                "--lane only applies to the MR drivers (the serial engines never submit \
                 MR jobs); --algo {} does not",
                algo.name()
            );
        }
        exp.lane = Lane::parse(s).with_context(|| {
            let hint = Lane::suggest(s)
                .map(|canon| format!(" — did you mean {canon:?}?"))
                .unwrap_or_default();
            format!("bad --lane {s:?} (hadoop-mr|in-memory-dag){hint}")
        })?;
    }
    if args.has("max-attempts") {
        let honors = matches!(
            algo,
            Algorithm::KMedoidsPlusPlusMR
                | Algorithm::KMedoidsRandomMR
                | Algorithm::KMedoidsScalableMR
                | Algorithm::KMedoidsCoresetMR
                | Algorithm::KMeansMR
        );
        if !honors {
            bail!(
                "--max-attempts only applies to the MR drivers (only MR jobs schedule \
                 task attempts); --algo {} does not",
                algo.name()
            );
        }
        if exp.lane == Lane::InMemoryDag {
            bail!(
                "--max-attempts only applies to the hadoop-mr lane (the in-memory DAG \
                 lane does not model task failures); drop it or switch --lane"
            );
        }
        let n = args.get_usize("max-attempts", 0)?;
        if n == 0 {
            bail!("--max-attempts must be >= 1");
        }
        exp.max_attempts = Some(n);
    }
    exp.with_quality = args.has("quality");
    exp.threads = args.get_usize("threads", 1)?;
    if exp.threads == 0 {
        bail!("--threads must be >= 1");
    }
    if args.has("checkpoint-dir") || args.has("resume") {
        let durable = matches!(
            algo,
            Algorithm::KMedoidsPlusPlusMR
                | Algorithm::KMedoidsRandomMR
                | Algorithm::KMedoidsScalableMR
                | Algorithm::KMedoidsCoresetMR
        );
        if !durable {
            bail!(
                "--checkpoint-dir/--resume only apply to the MR k-medoids algorithms \
                 (they emit and restore durable checkpoints); --algo {} does not",
                algo.name()
            );
        }
        if args.has("resume") && !args.has("checkpoint-dir") {
            bail!("--resume requires --checkpoint-dir (nowhere to load a snapshot from)");
        }
        exp.checkpoint_dir = args.get("checkpoint-dir").map(std::path::PathBuf::from);
        exp.resume = args.has("resume");
    }
    run_one_cell(&exp, &backend, trace)?;
    Ok(())
}

/// Parse a comma-separated positive integer list ("1,2,4") for `--flag`.
fn parse_usize_list(flag: &str, s: &str) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let n: usize = part
            .trim()
            .parse()
            .with_context(|| format!("--{flag} must be integers like 1,2,4 (got {part:?})"))?;
        if n == 0 {
            bail!("--{flag} entries must be >= 1");
        }
        out.push(n);
    }
    Ok(out)
}

/// Flags that only `bench scale` understands (`spec` is shared with
/// `bench serve`).
const SCALE_ONLY_FLAGS: &[&str] =
    &["nodes", "faults", "fail-rate", "no-faults", "no-speculation", "spec"];

/// Flags that only `bench serve` understands.
const SERVE_ONLY_FLAGS: &[&str] = &["queries", "update-frac", "batch", "coreset-size"];

/// Flags that only `bench perf` understands.
const PERF_ONLY_FLAGS: &[&str] = &["checkpoint-dir"];

fn cmd_bench(args: &Args) -> Result<()> {
    args.check_known(
        "bench",
        &[
            "scale", "seed", "backend", "trace", "threads", "out", "smoke", "nodes", "faults",
            "fail-rate", "no-faults", "no-speculation", "spec", "queries", "update-frac", "batch",
            "coreset-size", "checkpoint-dir",
        ],
    )?;
    args.check_positionals("bench", 1)?;
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("table6");

    if which == "perf" {
        for flag in SCALE_ONLY_FLAGS {
            if args.has(flag) {
                bail!("--{flag} only applies to `bench scale`");
            }
        }
        for flag in SERVE_ONLY_FLAGS {
            if args.has(flag) {
                bail!("--{flag} only applies to `bench serve`");
            }
        }
        return cmd_bench_perf(args);
    }
    if which == "scale" {
        for flag in SERVE_ONLY_FLAGS {
            if args.has(flag) {
                bail!("--{flag} only applies to `bench serve`");
            }
        }
        for flag in PERF_ONLY_FLAGS {
            if args.has(flag) {
                bail!("--{flag} only applies to `bench perf`");
            }
        }
        return cmd_bench_scale(args);
    }
    if which == "serve" {
        for flag in SCALE_ONLY_FLAGS {
            if *flag != "spec" && args.has(flag) {
                bail!("--{flag} only applies to `bench scale`");
            }
        }
        for flag in PERF_ONLY_FLAGS {
            if args.has(flag) {
                bail!("--{flag} only applies to `bench perf`");
            }
        }
        return cmd_bench_serve(args);
    }
    if which == "lanes" {
        // `--nodes` is shared with `bench scale`; the fault/speculation
        // knobs are not (the DAG lane does not model failures) and
        // lanes has no spec-file mode.
        if args.has("spec") {
            bail!("--spec does not apply to `bench lanes` (pass --nodes/--scale/--seed directly)");
        }
        for flag in SCALE_ONLY_FLAGS {
            if !["nodes", "spec"].contains(flag) && args.has(flag) {
                bail!("--{flag} only applies to `bench scale`");
            }
        }
        for flag in SERVE_ONLY_FLAGS {
            if args.has(flag) {
                bail!("--{flag} only applies to `bench serve`");
            }
        }
        for flag in PERF_ONLY_FLAGS {
            if args.has(flag) {
                bail!("--{flag} only applies to `bench perf`");
            }
        }
        return cmd_bench_lanes(args);
    }
    for flag in ["out", "smoke"] {
        if args.has(flag) {
            bail!("--{flag} only applies to `bench perf`, `bench scale` or `bench serve`");
        }
    }
    for flag in SCALE_ONLY_FLAGS {
        if args.has(flag) {
            bail!("--{flag} only applies to `bench scale`");
        }
    }
    for flag in SERVE_ONLY_FLAGS {
        if args.has(flag) {
            bail!("--{flag} only applies to `bench serve`");
        }
    }
    for flag in PERF_ONLY_FLAGS {
        if args.has(flag) {
            bail!("--{flag} only applies to `bench perf`");
        }
    }
    let suite_threads = args.get_usize("threads", 1)?;
    if suite_threads == 0 {
        bail!("--threads must be >= 1");
    }
    let opts = SuiteOpts::new(args.get_usize("scale", 1)?, args.get_u64("seed", 42)?)
        .with_trace(args.has("trace"))
        .with_threads(suite_threads);
    let backend = backend_from(args, 2048)?;
    match which {
        "table6" | "fig3" => {
            let results = kmedoids_mr::driver::suites::table6_suite(&backend, &opts);
            println!("\nTable 6 — execution time (ms), K-Medoids++ MR:\n");
            print!("{}", report::table6(&results));
            println!("\nFig. 4 — speedup vs 4-node cluster:\n");
            print!("{}", report::fig4_speedup(&results));
            println!("\nCSV:\n{}", report::to_csv(&results));
        }
        "fig4" => {
            let results = kmedoids_mr::driver::suites::table6_suite(&backend, &opts);
            println!("\nFig. 4 — speedup vs 4-node cluster:\n");
            print!("{}", report::fig4_speedup(&results));
        }
        "fig5" => {
            let results = kmedoids_mr::driver::suites::fig5_suite(&backend, &opts);
            println!("\nFig. 5 — comparative execution time (ms), 7 nodes:\n");
            print!("{}", report::fig5_comparative(&results));
            println!("\nCSV:\n{}", report::to_csv(&results));
        }
        "ablation" => {
            let results = kmedoids_mr::driver::suites::ablation_suite(&backend, &opts);
            println!("\nAblation — init strategy & iterations (dataset 1):\n");
            println!(
                "{:<18}{:>8}{:>12}{:>16}",
                "variant", "iters", "time(ms)", "cost"
            );
            for r in &results {
                println!(
                    "{:<18}{:>8}{:>12}{:>16.4e}",
                    r.algorithm, r.iterations, r.time_ms, r.cost
                );
            }
        }
        other => {
            bail!("unknown bench {other:?} (table6|fig4|fig5|ablation|perf|scale|serve|lanes)")
        }
    }
    Ok(())
}

/// `bench scale`: the paper's speedup/sizeup/scaleup experiments for the
/// three MR algorithms under the fault-tolerant scheduler, written to
/// `BENCH_scale.json` (see `driver::suites::scale_suite`). Exits non-zero
/// when the faults-on vs faults-off identity check reports a mismatch —
/// the blocking CI quality gate.
fn cmd_bench_scale(args: &Args) -> Result<()> {
    if args.has("trace") {
        bail!("--trace does not apply to `bench scale` (it prints its own progress)");
    }
    let smoke = args.has("smoke");
    let mut opts = if smoke { ScaleOpts::smoke() } else { ScaleOpts::default() };
    if let Some(path) = args.get("spec") {
        const SPEC_CONFLICTS: &[&str] =
            &["nodes", "faults", "fail-rate", "no-faults", "no-speculation", "scale", "seed"];
        for flag in SPEC_CONFLICTS {
            if args.has(flag) {
                bail!("--{flag} conflicts with --spec (put it in the spec file)");
            }
        }
        let src = std::fs::read_to_string(path).with_context(|| format!("read spec {path:?}"))?;
        opts = spec::scale_opts_from_str(&src, opts)?;
    } else {
        if let Some(s) = args.get("nodes") {
            opts.nodes_sweep = parse_usize_list("nodes", s)?;
        }
        opts.scale_div = args.get_usize("scale", opts.scale_div)?.max(1);
        opts.seed = args.get_u64("seed", opts.seed)?;
        opts.n_failures = args.get_usize("faults", opts.n_failures)?;
        if let Some(r) = args.get("fail-rate") {
            let r: f64 = r
                .parse()
                .with_context(|| format!("--fail-rate must be a number, got {r:?}"))?;
            if !(0.0..=0.9).contains(&r) {
                bail!("--fail-rate must be in [0, 0.9], got {r}");
            }
            opts.task_fail_rate = r;
        }
        if args.has("no-faults") {
            opts.faults = false;
        }
        if args.has("no-speculation") {
            opts.speculation = false;
        }
    }
    opts.smoke = smoke;
    opts.threads = args.get_usize("threads", 1)?.max(1);
    let backend = backend_from(args, 2048)?;
    let report = kmedoids_mr::driver::suites::scale_suite(&backend, &opts);
    let out = args.get("out").unwrap_or("BENCH_scale.json");
    std::fs::write(out, format!("{report}\n")).with_context(|| format!("write {out:?}"))?;

    println!("\nscale summary (full report: {out}):");
    for key in ["speedup", "sizeup", "scaleup"] {
        if let Some(curves) = report.get(key).and_then(|c| c.as_obj()) {
            println!("  {key}:");
            for (algo, curve) in curves {
                // Curves are ascending-x arrays of [x, ratio] pairs.
                let line: Vec<String> = curve
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|pair| {
                        let p = pair.as_arr()?;
                        let x = p.first()?.as_u64()?;
                        let r = p.get(1)?.as_f64()?;
                        Some(format!("{x}:{r:.2}"))
                    })
                    .collect();
                println!("    {algo:<22} {}", line.join("  "));
            }
        }
    }
    let faults_enabled = !matches!(report.get("faults"), Some(Json::Bool(false)));
    if !faults_enabled {
        println!("faults disabled (--no-faults): identity not checked");
        return Ok(());
    }
    match report.get("identity_ok").and_then(|v| v.as_bool()) {
        Some(true) => {
            println!("faults-on vs faults-off clustering output identical: yes");
            Ok(())
        }
        _ => bail!("faults-on vs faults-off clustering output MISMATCH (determinism bug)"),
    }
}

/// `bench serve`: mixed online query/update workload over the serving
/// subsystem — reader threads stream nearest-medoid queries through
/// epoch-swapped snapshots while the driver ingests delta mini-batches —
/// written to `BENCH_serve.json` (see `driver::suites::serve_suite`).
/// Exits non-zero when serving answers diverge from the batch assign
/// pass or an update increased the weighted coreset cost — the blocking
/// CI quality gates.
fn cmd_bench_serve(args: &Args) -> Result<()> {
    if args.has("trace") {
        bail!("--trace does not apply to `bench serve` (it prints its own progress)");
    }
    let smoke = args.has("smoke");
    let mut opts = if smoke { ServeOpts::smoke() } else { ServeOpts::default() };
    if let Some(path) = args.get("spec") {
        const SPEC_CONFLICTS: &[&str] =
            &["threads", "queries", "update-frac", "batch", "coreset-size", "scale", "seed"];
        for flag in SPEC_CONFLICTS {
            if args.has(flag) {
                bail!("--{flag} conflicts with --spec (put it in the spec file)");
            }
        }
        let src = std::fs::read_to_string(path).with_context(|| format!("read spec {path:?}"))?;
        opts = spec::serve_opts_from_str(&src, opts)?;
    } else {
        if let Some(s) = args.get("threads") {
            opts.threads = parse_usize_list("threads", s)?;
        }
        opts.queries = args.get_usize("queries", opts.queries)?.max(1);
        opts.scale_div = args.get_usize("scale", opts.scale_div)?.max(1);
        opts.seed = args.get_u64("seed", opts.seed)?;
        opts.batch = args.get_usize("batch", opts.batch)?.max(1);
        if args.has("coreset-size") {
            opts.coreset_size = Some(args.get_usize("coreset-size", 0)?.max(1));
        }
        if let Some(r) = args.get("update-frac") {
            let r: f64 = r
                .parse()
                .with_context(|| format!("--update-frac must be a number, got {r:?}"))?;
            if !(0.0..=10.0).contains(&r) {
                bail!("--update-frac must be in [0, 10], got {r}");
            }
            opts.update_frac = r;
        }
    }
    opts.smoke = smoke;
    let backend = backend_from(args, 2048)?;
    let report = kmedoids_mr::driver::suites::serve_suite(&backend, &opts);
    let out = args.get("out").unwrap_or("BENCH_serve.json");
    std::fs::write(out, format!("{report}\n")).with_context(|| format!("write {out:?}"))?;

    println!("\nserve summary (full report: {out}):");
    if let Some(rows) = report.get("sweep").and_then(|s| s.as_arr()) {
        println!(
            "{:>8} {:>14} {:>11} {:>11} {:>11} {:>8}",
            "threads", "qps", "p50(us)", "p99(us)", "p999(us)", "epochs"
        );
        for row in rows {
            let t = row.get("threads").and_then(|v| v.as_u64()).unwrap_or(0);
            let q = row.get("throughput_qps").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
            let p50 = row.get("p50_s").and_then(|v| v.as_f64()).unwrap_or(f64::NAN) * 1e6;
            let p99 = row.get("p99_s").and_then(|v| v.as_f64()).unwrap_or(f64::NAN) * 1e6;
            let p999 = row.get("p999_s").and_then(|v| v.as_f64()).unwrap_or(f64::NAN) * 1e6;
            let ep = row.get("final_epoch").and_then(|v| v.as_u64()).unwrap_or(0);
            println!("{t:>8} {q:>14.0} {p50:>11.1} {p99:>11.1} {p999:>11.1} {ep:>8}");
        }
    }
    match report.get("identity_ok").and_then(|v| v.as_bool()) {
        Some(true) => println!("serving assign byte-identical to the batch label pass: yes"),
        _ => bail!("serving assign DIVERGED from the batch label pass (serving bug)"),
    }
    match report.get("cost_monotone_ok").and_then(|v| v.as_bool()) {
        Some(true) => {
            println!("ingest-then-refine kept the weighted coreset cost monotone: yes");
            Ok(())
        }
        _ => bail!("an online update INCREASED the weighted coreset cost (refinement bug)"),
    }
}

/// `bench lanes`: the Hadoop-MR vs in-memory-DAG execution-lane
/// comparison for the four MR algorithms across cluster sizes, written
/// to `BENCH_lanes.json` (see `driver::suites::lanes_suite`). Exits
/// non-zero unless the DAG-lane fits are byte-identical to the
/// Hadoop-lane fits AND strictly faster on simulated time in every
/// cell — the blocking CI quality gates.
fn cmd_bench_lanes(args: &Args) -> Result<()> {
    if args.has("trace") {
        bail!("--trace does not apply to `bench lanes` (it prints its own progress)");
    }
    let smoke = args.has("smoke");
    let mut opts = if smoke { LanesOpts::smoke() } else { LanesOpts::default() };
    if let Some(s) = args.get("nodes") {
        opts.nodes_sweep = parse_usize_list("nodes", s)?;
    }
    opts.scale_div = args.get_usize("scale", opts.scale_div)?.max(1);
    opts.seed = args.get_u64("seed", opts.seed)?;
    opts.threads = args.get_usize("threads", 1)?.max(1);
    opts.smoke = smoke;
    let backend = backend_from(args, 2048)?;
    let report = kmedoids_mr::driver::suites::lanes_suite(&backend, &opts);
    let out = args.get("out").unwrap_or("BENCH_lanes.json");
    std::fs::write(out, format!("{report}\n")).with_context(|| format!("write {out:?}"))?;

    println!("\nlanes summary, mr-time / dag-time per cluster size (full report: {out}):");
    if let Some(curves) = report.get("speedup").and_then(|c| c.as_obj()) {
        for (algo, curve) in curves {
            // Curves are ascending-nodes arrays of [nodes, ratio] pairs.
            let line: Vec<String> = curve
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|pair| {
                    let p = pair.as_arr()?;
                    let x = p.first()?.as_u64()?;
                    let r = p.get(1)?.as_f64()?;
                    Some(format!("{x}:{r:.2}"))
                })
                .collect();
            println!("  {algo:<22} {}", line.join("  "));
        }
    }
    match report.get("identity_ok").and_then(|v| v.as_bool()) {
        Some(true) => println!("dag-lane output byte-identical to the hadoop-mr lane: yes"),
        _ => bail!("dag-lane output DIVERGED from the hadoop-mr lane (lane-identity bug)"),
    }
    match report.get("dag_faster_ok").and_then(|v| v.as_bool()) {
        Some(true) => {
            println!("dag lane strictly faster on sim time in every cell: yes");
            Ok(())
        }
        _ => bail!(
            "dag lane was NOT strictly faster than hadoop-mr in every cell \
             (cost-model regression)"
        ),
    }
}

/// `bench perf`: kernel + e2e wall-clock trajectory, written to
/// `BENCH_perf.json` (see `driver::suites::perf_suite`).
fn cmd_bench_perf(args: &Args) -> Result<()> {
    if args.has("trace") {
        bail!("--trace does not apply to `bench perf` (it prints its own progress)");
    }
    let smoke = args.has("smoke");
    let threads = match args.get("threads") {
        Some(s) => parse_usize_list("threads", s)?,
        None if smoke => vec![1, 2],
        None => vec![1, 2, 4],
    };
    let opts = kmedoids_mr::driver::suites::PerfOpts {
        scale_div: args.get_usize("scale", if smoke { 2000 } else { 10 })?.max(1),
        seed: args.get_u64("seed", 42)?,
        threads,
        smoke,
        checkpoint_dir: args.get("checkpoint-dir").map(std::path::PathBuf::from),
    };
    // Kernel staging buffers dominate below the block floor; keep the
    // production block size so the bench reflects the mapper's hot path.
    let backend = backend_from(args, 2048)?;
    let report = kmedoids_mr::driver::suites::perf_suite(&backend, &opts);
    let out = args.get("out").unwrap_or("BENCH_perf.json");
    std::fs::write(out, format!("{report}\n")).with_context(|| format!("write {out:?}"))?;

    println!("\nperf summary (full report: {out}):");
    if let Some(rows) = report.get("e2e").and_then(|e| e.as_arr()) {
        println!("{:>8} {:>12} {:>12} {:>10}", "threads", "wall(s)", "speedup", "pruned");
        for row in rows {
            let t = row.get("threads").and_then(|v| v.as_u64()).unwrap_or(0);
            let w = row.get("wall_s").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
            let s = report
                .get("speedup_vs_1_thread")
                .and_then(|m| m.get(&t.to_string()))
                .and_then(|v| v.as_f64())
                .unwrap_or(f64::NAN);
            let p = row.get("pruned_frac").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
            println!("{t:>8} {w:>12.3} {s:>11.2}x {:>9.0}%", p * 100.0);
        }
    }
    match report.get("identical_outputs").and_then(|v| v.as_bool()) {
        Some(true) => println!("outputs identical at every thread count: yes"),
        _ => bail!("outputs diverged across thread counts (determinism bug)"),
    }
    // Blocking pruning gate (CI runs --smoke): dense and pruned lanes
    // must agree byte-for-byte and the pruned lane must cut the exact
    // eval count by the declared floor.
    let gate = report.get("pruning").context("BENCH_perf.json is missing the pruning gate")?;
    let red = gate.get("reduction").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
    let floor = gate.get("floor").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
    match gate.get("ok").and_then(|v| v.as_bool()) {
        Some(true) => {
            println!(
                "pruned lane byte-identical to dense at {red:.1}x fewer dist evals \
                 (floor {floor:.1}x): yes"
            );
        }
        _ if gate.get("identical").and_then(|v| v.as_bool()) != Some(true) => {
            bail!("pruned assignment DIVERGED from the dense lane (bound-maintenance bug)")
        }
        _ => bail!("pruned lane reduced dist evals only {red:.2}x (< {floor:.1}x floor)"),
    }
    // Blocking ingest gate: the binary dataset format must decode the
    // same points as its CSV twin and beat CSV parsing by the declared
    // throughput floor.
    let ing = report.get("ingest").context("BENCH_perf.json is missing the ingest cell")?;
    let speedup = ing.get("speedup").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
    let ing_floor = ing.get("floor").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
    match ing.get("ok").and_then(|v| v.as_bool()) {
        Some(true) => {
            println!(
                "binary ingest identical to CSV at {speedup:.1}x the throughput \
                 (floor {ing_floor:.1}x): yes"
            );
            Ok(())
        }
        _ if ing.get("identical").and_then(|v| v.as_bool()) != Some(true) => {
            bail!("binary ingest decoded DIFFERENT points than its CSV twin (codec bug)")
        }
        _ => bail!("binary ingest only {speedup:.2}x faster than CSV (< {ing_floor:.1}x floor)"),
    }
}

fn cmd_inspect(args: &Args) -> Result<()> {
    args.check_known("inspect-artifacts", &[])?;
    args.check_positionals("inspect-artifacts", 0)?;
    let dir = runtime::default_artifacts_dir();
    let m = runtime::Manifest::load(&dir)?;
    println!("artifacts at {:?}:", m.dir);
    for u in &m.units {
        println!(
            "  {:<22} kind={:<9} B={:<6} K={:<4} pad={:e}  {:?}",
            u.name,
            format!("{:?}", u.kind),
            u.block,
            u.kpad,
            u.pad_coord,
            u.path.file_name().unwrap()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value_flags_and_positionals() {
        let a = Args::parse(&argv(&["table6", "--scale", "10", "--seed", "7"]));
        assert_eq!(a.positional, vec!["table6"]);
        assert_eq!(a.get("scale"), Some("10"));
        assert_eq!(a.get_usize("scale", 1).unwrap(), 10);
        assert_eq!(a.get_u64("seed", 42).unwrap(), 7);
        assert_eq!(a.get_usize("missing", 3).unwrap(), 3);
    }

    #[test]
    fn bare_flags_are_boolean() {
        let a = Args::parse(&argv(&["--quality", "--trace", "--nodes", "5"]));
        assert!(a.has("quality"));
        assert!(a.has("trace"));
        assert_eq!(a.get("quality"), Some("true"));
        assert_eq!(a.get_usize("nodes", 7).unwrap(), 5);
        // A bare flag directly before another flag stays boolean.
        let b = Args::parse(&argv(&["--quality", "--seed", "3"]));
        assert_eq!(b.get("quality"), Some("true"));
        assert_eq!(b.get_u64("seed", 0).unwrap(), 3);
    }

    #[test]
    fn boolean_flags_do_not_swallow_positionals() {
        // `bench --trace fig5` must keep fig5 as the suite name.
        let a = Args::parse(&argv(&["--trace", "fig5"]));
        assert_eq!(a.get("trace"), Some("true"));
        assert_eq!(a.positional, vec!["fig5"]);
        let b = Args::parse(&argv(&["fig5", "--quality", "--scale", "10"]));
        assert_eq!(b.positional, vec!["fig5"]);
        assert_eq!(b.get_usize("scale", 1).unwrap(), 10);
    }

    #[test]
    fn non_numeric_values_error_with_flag_name() {
        let a = Args::parse(&argv(&["--scale", "ten"]));
        let e = a.get_usize("scale", 1).unwrap_err();
        assert!(format!("{e:#}").contains("--scale"), "{e:#}");
        assert!(format!("{e:#}").contains("ten"), "{e:#}");
    }

    #[test]
    fn unknown_flags_are_rejected_with_suggestion() {
        // The motivating typo: `--node 7` used to be silently ignored.
        let a = Args::parse(&argv(&["--node", "7"]));
        let e = a.check_known("run", &["nodes", "seed", "scale"]).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("--node"), "{msg}");
        assert!(msg.contains("did you mean --nodes?"), "{msg}");
        assert!(msg.contains("run"), "{msg}");

        // Far-off flags list what is accepted, without a bogus suggestion.
        let b = Args::parse(&argv(&["--frobnicate", "1"]));
        let e = b.check_known("run", &["nodes", "seed"]).unwrap_err();
        let msg = format!("{e:#}");
        assert!(!msg.contains("did you mean"), "{msg}");
        assert!(msg.contains("--nodes") && msg.contains("--seed"), "{msg}");
    }

    #[test]
    fn known_flags_pass_the_check() {
        let a = Args::parse(&argv(&["--nodes", "5", "--seed", "1"]));
        assert!(a.check_known("run", &["nodes", "seed"]).is_ok());
        let none = Args::parse(&argv(&[]));
        assert!(none.check_known("inspect-artifacts", &[]).is_ok());
    }

    #[test]
    fn usize_lists_parse_and_reject_zero() {
        assert_eq!(parse_usize_list("threads", "1,2,4").unwrap(), vec![1, 2, 4]);
        assert_eq!(parse_usize_list("nodes", " 8 ").unwrap(), vec![8]);
        assert!(parse_usize_list("threads", "0,2").is_err());
        let e = parse_usize_list("nodes", "two").unwrap_err();
        assert!(format!("{e:#}").contains("--nodes"), "{e:#}");
        assert!(parse_usize_list("threads", "").is_err());
    }

    #[test]
    fn levenshtein_distances() {
        assert_eq!(levenshtein("node", "nodes"), 1);
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("same", "same"), 0);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
    }
}
