//! Virtual clock + deterministic event queue.
//!
//! `SimTime` is seconds as f64 wrapped for total ordering; ties are broken
//! by insertion sequence so identical schedules replay identically across
//! runs (determinism is asserted by tests and relied on by benches).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated wall-clock time in seconds since job submission.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct SimTime(pub f64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0.0);
    pub fn secs(s: f64) -> SimTime {
        SimTime(s)
    }
    pub fn max(self, other: SimTime) -> SimTime {
        if other.0 > self.0 {
            other
        } else {
            self
        }
    }
}

impl std::ops::Add<f64> for SimTime {
    type Output = SimTime;
    fn add(self, rhs: f64) -> SimTime {
        SimTime(self.0 + rhs)
    }
}

impl std::ops::Sub<SimTime> for SimTime {
    type Output = f64;
    fn sub(self, rhs: SimTime) -> f64 {
        self.0 - rhs.0
    }
}

/// Events the MapReduce engine reacts to.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A task attempt finished on a node.
    TaskDone { attempt_id: usize },
    /// A task attempt died partway through (transient failure from a
    /// [`crate::sim::FaultPlan`]); its partial sim time is charged and the
    /// task is retried up to the cluster's `max_attempts`.
    TaskFail { attempt_id: usize },
    /// A node fails (fail-stop); all attempts there die, its completed map
    /// outputs become unreadable (Hadoop semantics: re-execute those maps).
    NodeFail { node: usize },
    /// A failed node comes back empty (rejoins as a fresh TaskTracker).
    NodeRecover { node: usize },
    /// Periodic scheduler tick (speculative-execution checks).
    Tick,
}

struct Entry {
    at: SimTime,
    seq: u64,
    ev: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first. NaN times
        // are a programming error and must never be scheduled.
        other
            .at
            .0
            .partial_cmp(&self.at.0)
            .expect("NaN SimTime scheduled")
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic earliest-first event queue.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
    now: SimTime,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn schedule(&mut self, at: SimTime, ev: Event) {
        debug_assert!(at.0 >= self.now.0, "cannot schedule into the past");
        self.heap.push(Entry { at, seq: self.seq, ev });
        self.seq += 1;
    }

    pub fn schedule_in(&mut self, dt: f64, ev: Event) {
        let at = self.now + dt;
        self.schedule(at, ev);
    }

    /// Pop the next event, advancing the clock. Returns None when drained.
    pub fn next(&mut self) -> Option<(SimTime, Event)> {
        let e = self.heap.pop()?;
        debug_assert!(e.at.0 >= self.now.0);
        self.now = e.at;
        Some((e.at, e.ev))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    #[test]
    fn orders_by_time() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::secs(2.0), Event::Tick);
        q.schedule(SimTime::secs(1.0), Event::NodeFail { node: 3 });
        q.schedule(SimTime::secs(3.0), Event::TaskDone { attempt_id: 1 });
        let (t1, e1) = q.next().unwrap();
        assert_eq!(t1.0, 1.0);
        assert_eq!(e1, Event::NodeFail { node: 3 });
        assert_eq!(q.next().unwrap().1, Event::Tick);
        assert_eq!(q.next().unwrap().1, Event::TaskDone { attempt_id: 1 });
        assert!(q.next().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule(SimTime::secs(1.0), Event::TaskDone { attempt_id: i });
        }
        for i in 0..10 {
            assert_eq!(q.next().unwrap().1, Event::TaskDone { attempt_id: i });
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        for_all(30, 0xC10C4, |rng: &mut Rng| {
            let mut q = EventQueue::new();
            for i in 0..100 {
                q.schedule(SimTime::secs(rng.f64() * 100.0), Event::TaskDone { attempt_id: i });
            }
            let mut last = -1.0;
            while let Some((t, _)) = q.next() {
                assert!(t.0 >= last);
                assert_eq!(q.now().0, t.0);
                last = t.0;
            }
        });
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::secs(5.0), Event::Tick);
        q.next();
        q.schedule_in(2.0, Event::Tick);
        assert_eq!(q.next().unwrap().0 .0, 7.0);
    }
}
