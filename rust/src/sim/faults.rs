//! Fault plans: seeded, reproducible failure schedules for the simulated
//! cluster.
//!
//! A [`FaultPlan`] bundles everything the engine's fault machinery can
//! inject — fail-stop node losses (wired through
//! [`crate::dfs::NameNode::fail_node`] re-replication and HMaster region
//! failover), node recoveries, and a per-attempt transient task failure
//! rate (flaky TaskTracker JVMs, the paper-era commodity failure mode
//! that `mapred.map.max.attempts` exists to absorb). Every draw is a pure
//! function of the plan's `seed` plus the (job, task, attempt) identity,
//! so a plan replays identically across runs, thread counts, and
//! scheduling orders — the determinism contract the scale bench's
//! faults-on/faults-off identity check relies on.

use crate::util::rng::Rng;

/// A reproducible schedule of cluster faults. Apply with
/// [`crate::mapreduce::Cluster::apply_fault_plan`] or
/// [`crate::session::SessionBuilder::faults`].
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    /// Fail-stop node losses: (absolute sim seconds, node index). The
    /// master cannot be listed (as in the paper, master failure is out of
    /// scope).
    pub node_failures: Vec<(f64, usize)>,
    /// Node rejoins: (absolute sim seconds, node index). A recovered node
    /// comes back empty (its DFS replicas were re-replicated away).
    pub node_recoveries: Vec<(f64, usize)>,
    /// Probability that any single task attempt fails partway through
    /// (charged its partial sim time, then retried up to the cluster's
    /// `max_attempts`). 0 disables transient task failures.
    pub task_fail_rate: f64,
    /// Seed for the per-attempt failure draws.
    pub seed: u64,
}

impl FaultPlan {
    /// The empty plan (no faults).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Does this plan inject anything at all?
    pub fn is_empty(&self) -> bool {
        self.node_failures.is_empty()
            && self.node_recoveries.is_empty()
            && self.task_fail_rate <= 0.0
    }

    /// A seeded random plan over an `n_nodes` cluster: `n_failures`
    /// distinct non-master victims fail at times spread over
    /// `(0.2..0.8) * window_s`, each rejoining a quarter-window later,
    /// plus a transient `task_fail_rate`. With one node (master only) no
    /// node losses are planned — only task flakiness applies.
    pub fn seeded(
        seed: u64,
        n_nodes: usize,
        n_failures: usize,
        window_s: f64,
        task_fail_rate: f64,
    ) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA_17);
        let mut node_failures = Vec::new();
        let mut node_recoveries = Vec::new();
        if n_nodes > 1 && n_failures > 0 && window_s > 0.0 {
            let victims = rng.sample_indices(n_nodes - 1, n_failures.min(n_nodes - 1));
            for v in victims {
                let node = v + 1; // skip the master at index 0
                let at = window_s * (0.2 + 0.6 * rng.f64());
                node_failures.push((at, node));
                node_recoveries.push((at + 0.25 * window_s, node));
            }
            node_failures.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            node_recoveries.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        }
        FaultPlan { node_failures, node_recoveries, task_fail_rate, seed }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_is_deterministic_and_spares_the_master() {
        let a = FaultPlan::seeded(7, 8, 3, 100.0, 0.05);
        let b = FaultPlan::seeded(7, 8, 3, 100.0, 0.05);
        assert_eq!(a, b);
        assert_eq!(a.node_failures.len(), 3);
        assert_eq!(a.node_recoveries.len(), 3);
        assert!(a.node_failures.iter().all(|&(_, n)| n >= 1 && n < 8));
        assert!(a.node_failures.iter().all(|&(t, _)| t >= 20.0 && t <= 80.0));
        assert!(!a.is_empty());
    }

    #[test]
    fn seeded_single_node_plans_no_node_loss() {
        let p = FaultPlan::seeded(3, 1, 2, 50.0, 0.1);
        assert!(p.node_failures.is_empty());
        assert!(p.node_recoveries.is_empty());
        assert_eq!(p.task_fail_rate, 0.1);
        assert!(!p.is_empty(), "task flakiness still applies");
    }

    #[test]
    fn victims_are_distinct_and_capped() {
        let p = FaultPlan::seeded(11, 4, 10, 60.0, 0.0);
        assert_eq!(p.node_failures.len(), 3, "capped at the non-master count");
        let mut nodes: Vec<usize> = p.node_failures.iter().map(|&(_, n)| n).collect();
        nodes.sort_unstable();
        nodes.dedup();
        assert_eq!(nodes.len(), 3);
    }

    #[test]
    fn none_is_empty() {
        assert!(FaultPlan::none().is_empty());
        assert!(!FaultPlan { task_fail_rate: 0.5, ..FaultPlan::none() }.is_empty());
    }
}
