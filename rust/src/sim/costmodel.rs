//! Cost model: converts *measured work* (bytes, rows, distance
//! evaluations) into simulated task durations on a given node.
//!
//! Calibration targets the paper's testbed era (Hadoop ~1.x on VMware VMs
//! over commodity hosts, Table 3): heavy per-job and per-task overheads
//! (JVM spawn, heartbeat-delayed scheduling), text-row parsing on the
//! input path, and Java-speed distance loops. Absolute constants are
//! documented in EXPERIMENTS.md §Calibration; the *shape* of Table 6 and
//! Figs 3–5 (sub-linear speedup, better scaling for bigger datasets,
//! ++ < traditional < CLARANS) is insensitive to ±2× on any of them.

use crate::config::{ClusterConfig, NodeSpec};

/// Work performed by one task attempt, accumulated by the engine while the
/// task's real computation runs.
#[derive(Debug, Clone, Copy, Default)]
pub struct TaskWork {
    /// Input rows parsed (text coordinate rows, HBase cells).
    pub rows_parsed: u64,
    /// Point–medoid (or point–point) squared-distance evaluations.
    pub dist_evals: u64,
    /// Bytes read from a node-local disk (DFS local block or spill).
    pub local_read_bytes: u64,
    /// Bytes read over the network (non-local map input).
    pub remote_read_bytes: u64,
    /// Bytes written (map spill / reduce output).
    pub write_bytes: u64,
    /// Extra fixed CPU seconds (e.g. per-record reduce bookkeeping).
    pub extra_cpu_s: f64,
}

impl TaskWork {
    pub fn add(&mut self, other: &TaskWork) {
        self.rows_parsed += other.rows_parsed;
        self.dist_evals += other.dist_evals;
        self.local_read_bytes += other.local_read_bytes;
        self.remote_read_bytes += other.remote_read_bytes;
        self.write_bytes += other.write_bytes;
        self.extra_cpu_s += other.extra_cpu_s;
    }
}

/// Tunable rate constants. All rates are for a speed-1.0 core
/// (the Table 3 reference CPU, Intel i5-3210M).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Per-MR-job fixed overhead: job setup, split computation, cleanup.
    pub job_overhead_s: f64,
    /// Per-task-attempt overhead: JVM spawn + localization.
    pub task_overhead_s: f64,
    /// Scheduling latency per task (heartbeat-driven assignment).
    pub sched_delay_s: f64,
    /// Text rows parsed per second per speed-1.0 core.
    pub parse_rows_per_s: f64,
    /// Squared-distance evaluations per second per speed-1.0 core
    /// (Java-era double loop with object overhead).
    pub dist_evals_per_s: f64,
    /// Sequential disk read/write bandwidth, MB/s.
    pub disk_read_mb_s: f64,
    pub disk_write_mb_s: f64,
    /// Fraction of shuffle transfer hidden under the map phase
    /// (Hadoop's slow-start copy overlap).
    pub shuffle_overlap: f64,
    /// Fraction of DFS re-replication traffic hidden under normal
    /// execution. The NameNode copies every under-replicated block after
    /// a DataNode loss; the copies run in the background (and Hadoop
    /// throttles them), so only the non-overlapped remainder lands on
    /// the job timeline.
    pub rereplication_overlap: f64,
    /// DAG-lane per-task launch cost: dispatching a closure to an
    /// already-running executor core, replacing the Hadoop lane's JVM
    /// spawn (`task_overhead_s`) + heartbeat wait (`sched_delay_s`).
    pub dag_task_launch_s: f64,
    /// DAG-lane per-job fixed overhead: DAG scheduling on a resident
    /// driver, replacing the Hadoop lane's `job_overhead_s` (job setup,
    /// split computation, cleanup).
    pub dag_job_overhead_s: f64,
    /// Fraction of DAG-lane shuffle transfer hidden under upstream
    /// execution. Push-based shuffle streams partitions as they are
    /// produced, so overlap is much higher than Hadoop's slow-start
    /// copy phase (`shuffle_overlap`).
    pub dag_shuffle_overlap: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            job_overhead_s: 10.0,
            task_overhead_s: 2.0,
            sched_delay_s: 0.6,
            parse_rows_per_s: 65_000.0,
            dist_evals_per_s: 1.2e6,
            disk_read_mb_s: 60.0,
            disk_write_mb_s: 50.0,
            shuffle_overlap: 0.65,
            rereplication_overlap: 0.8,
            dag_task_launch_s: 0.05,
            dag_job_overhead_s: 0.3,
            dag_shuffle_overlap: 0.92,
        }
    }
}

impl CostModel {
    /// A model with near-zero overheads, for tests that want to assert on
    /// pure work accounting.
    pub fn bare() -> CostModel {
        CostModel {
            job_overhead_s: 0.0,
            task_overhead_s: 0.0,
            sched_delay_s: 0.0,
            shuffle_overlap: 0.0,
            dag_task_launch_s: 0.0,
            dag_job_overhead_s: 0.0,
            dag_shuffle_overlap: 0.0,
            ..CostModel::default()
        }
    }

    /// Simulated seconds of CPU time for `work` on `node`.
    pub fn cpu_seconds(&self, node: &NodeSpec, work: &TaskWork) -> f64 {
        let raw = work.rows_parsed as f64 / self.parse_rows_per_s
            + work.dist_evals as f64 / self.dist_evals_per_s
            + work.extra_cpu_s;
        raw / node.speed
    }

    /// Simulated seconds of I/O (disk) time for `work` on `node`.
    pub fn io_seconds(&self, work: &TaskWork) -> f64 {
        work.local_read_bytes as f64 / (self.disk_read_mb_s * 1e6)
            + work.write_bytes as f64 / (self.disk_write_mb_s * 1e6)
    }

    /// Network seconds for the remote-read portion, given the transfer
    /// path bandwidth in MB/s.
    pub fn net_seconds(&self, bytes: u64, mb_s: f64, latency_s: f64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            latency_s + bytes as f64 / (mb_s * 1e6)
        }
    }

    /// Full duration of a task attempt (excluding queueing).
    pub fn task_seconds(
        &self,
        cluster: &ClusterConfig,
        node_idx: usize,
        src_node: Option<usize>,
        work: &TaskWork,
    ) -> f64 {
        let node = &cluster.nodes[node_idx];
        let mut t = self.task_overhead_s + self.cpu_seconds(node, work) + self.io_seconds(work);
        if work.remote_read_bytes > 0 {
            let mb_s = match src_node {
                Some(s) if cluster.nodes[s].host == node.host => cluster.net.intra_host_mb_s,
                _ => cluster.net.inter_host_mb_s,
            };
            t += self.net_seconds(work.remote_read_bytes, mb_s, cluster.net.latency_s);
        }
        t
    }

    /// Simulated seconds DFS re-replication traffic adds to the cluster
    /// timeline after a DataNode loss: `bytes` copied cross-host at the
    /// inter-host bandwidth, with [`CostModel::rereplication_overlap`]
    /// of the transfer hidden under normal execution. Zero bytes cost
    /// zero (a node that held no replicas delays nothing).
    pub fn rereplication_seconds(&self, cluster: &ClusterConfig, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        (1.0 - self.rereplication_overlap)
            * self.net_seconds(bytes, cluster.net.inter_host_mb_s, cluster.net.latency_s)
    }

    /// Shuffle fetch time for one reducer pulling `bytes` from `src` to
    /// `dst`, after overlap with the map phase is credited.
    pub fn shuffle_seconds(
        &self,
        cluster: &ClusterConfig,
        src: usize,
        dst: usize,
        bytes: u64,
    ) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let mb_s = if cluster.nodes[src].host == cluster.nodes[dst].host {
            cluster.net.intra_host_mb_s
        } else {
            cluster.net.inter_host_mb_s
        };
        (1.0 - self.shuffle_overlap) * self.net_seconds(bytes, mb_s, cluster.net.latency_s)
    }

    /// DAG-lane duration of one task on an executor core: closure
    /// dispatch instead of JVM spawn + heartbeat scheduling, then the
    /// same measured CPU/disk work. Map inputs are either cached in
    /// executor memory or read node-locally, so there is no remote-read
    /// network term here; reducer shuffle is charged separately via
    /// [`CostModel::dag_shuffle_seconds`].
    pub fn dag_task_seconds(&self, cluster: &ClusterConfig, node_idx: usize, work: &TaskWork) -> f64 {
        let node = &cluster.nodes[node_idx];
        self.dag_task_launch_s + self.cpu_seconds(node, work) + self.io_seconds(work)
    }

    /// Push-based shuffle transfer for one reducer pulling `bytes` from
    /// `src` to `dst`: same network path as the Hadoop lane, but with
    /// [`CostModel::dag_shuffle_overlap`] of the transfer streamed under
    /// upstream execution.
    pub fn dag_shuffle_seconds(
        &self,
        cluster: &ClusterConfig,
        src: usize,
        dst: usize,
        bytes: u64,
    ) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let mb_s = if cluster.nodes[src].host == cluster.nodes[dst].host {
            cluster.net.intra_host_mb_s
        } else {
            cluster.net.inter_host_mb_s
        };
        (1.0 - self.dag_shuffle_overlap) * self.net_seconds(bytes, mb_s, cluster.net.latency_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> ClusterConfig {
        ClusterConfig::paper_cluster()
    }

    #[test]
    fn slower_node_takes_longer() {
        let m = CostModel::default();
        let c = cluster();
        let work = TaskWork { dist_evals: 10_000_000, ..Default::default() };
        let fast = m.task_seconds(&c, 0, None, &work); // master, speed 1.0
        let slow = m.task_seconds(&c, 3, None, &work); // E7500, speed 0.62
        assert!(slow > fast, "{slow} <= {fast}");
        // CPU portion should scale ~1/speed.
        let cpu_fast = m.cpu_seconds(&c.nodes[0], &work);
        let cpu_slow = m.cpu_seconds(&c.nodes[3], &work);
        assert!((cpu_slow / cpu_fast - 1.0 / 0.62).abs() < 1e-9);
    }

    #[test]
    fn remote_read_costs_more_cross_host() {
        let m = CostModel::default();
        let c = cluster();
        let work = TaskWork { remote_read_bytes: 64 << 20, ..Default::default() };
        // src on same host as dst (slave01 -> slave02, both host 1)
        let same = m.task_seconds(&c, 2, Some(1), &work);
        // src cross-host (slave03 on host 2)
        let cross = m.task_seconds(&c, 2, Some(3), &work);
        assert!(cross > same);
    }

    #[test]
    fn zero_work_is_just_overhead() {
        let m = CostModel::default();
        let c = cluster();
        let t = m.task_seconds(&c, 0, None, &TaskWork::default());
        assert!((t - m.task_overhead_s).abs() < 1e-12);
    }

    #[test]
    fn shuffle_zero_bytes_free() {
        let m = CostModel::default();
        let c = cluster();
        assert_eq!(m.shuffle_seconds(&c, 0, 1, 0), 0.0);
        assert!(m.shuffle_seconds(&c, 0, 1, 1 << 20) > 0.0);
    }

    #[test]
    fn rereplication_charges_scale_with_bytes() {
        let m = CostModel::default();
        let c = cluster();
        assert_eq!(m.rereplication_seconds(&c, 0), 0.0, "no replicas, no delay");
        let small = m.rereplication_seconds(&c, 64 << 20);
        let large = m.rereplication_seconds(&c, 512 << 20);
        assert!(small > 0.0);
        assert!(large > small, "{large} vs {small}");
        // Overlap credits most of the transfer.
        let full = m.net_seconds(512 << 20, c.net.inter_host_mb_s, c.net.latency_s);
        assert!(large < full, "overlap must hide part of the transfer");
    }

    #[test]
    fn dag_lane_tasks_and_shuffle_are_strictly_cheaper() {
        let m = CostModel::default();
        let c = cluster();
        let work = TaskWork { rows_parsed: 100_000, dist_evals: 1_000_000, ..Default::default() };
        let hadoop = m.task_seconds(&c, 1, None, &work);
        let dag = m.dag_task_seconds(&c, 1, &work);
        assert!(dag < hadoop, "{dag} >= {hadoop}");
        // The gap is exactly the launch-path fixed costs for local work
        // (sched_delay_s is charged at assignment time, not here).
        let gap = hadoop - dag;
        let expect = m.task_overhead_s - m.dag_task_launch_s;
        assert!((gap - expect).abs() < 1e-9, "{gap} vs {expect}");
        assert!(m.dag_shuffle_seconds(&c, 0, 1, 1 << 20) < m.shuffle_seconds(&c, 0, 1, 1 << 20));
        assert_eq!(m.dag_shuffle_seconds(&c, 0, 1, 0), 0.0);
        assert!(m.dag_job_overhead_s < m.job_overhead_s);
    }

    #[test]
    fn work_accumulates() {
        let mut a = TaskWork { rows_parsed: 1, dist_evals: 2, ..Default::default() };
        a.add(&TaskWork { rows_parsed: 10, write_bytes: 5, ..Default::default() });
        assert_eq!(a.rows_parsed, 11);
        assert_eq!(a.dist_evals, 2);
        assert_eq!(a.write_bytes, 5);
    }
}
