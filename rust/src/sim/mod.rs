//! Discrete-event simulation core.
//!
//! The MapReduce engine runs *real computation on a simulated clock*:
//! every task actually executes (PJRT kernels and all), while its
//! simulated duration comes from the cost model in [`costmodel`]. The
//! event queue in [`events`] orders task completions, node failures and
//! heartbeats deterministically.

pub mod costmodel;
pub mod events;
pub mod faults;

pub use costmodel::{CostModel, TaskWork};
pub use events::{Event, EventQueue, SimTime};
pub use faults::FaultPlan;

/// Convert a simulated time (seconds, f64) to the millisecond integer the
/// paper's Table 6 reports.
pub fn sim_ms(t: SimTime) -> u64 {
    (t.0 * 1e3).round() as u64
}
