//! [`CheckpointStore`]: atomic snapshot files in a directory.
//!
//! Write discipline: serialize to `.tmp-…` in the same directory,
//! `fsync` the file, then `rename(2)` over the final name (rename within
//! a directory is atomic on POSIX), and best-effort `fsync` the
//! directory so the rename itself is durable. A crash at any instant
//! leaves either the old snapshot set or the new one — never a torn
//! final file. [`CheckpointStore::latest`] additionally skips past a
//! corrupt newest file to the most recent loadable snapshot, so even
//! bit rot in the last write degrades to "resume from one boundary
//! earlier" instead of "start over".

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::persist::format::Checkpoint;
use crate::persist::PersistError;

const EXT: &str = "kmdc";

/// A directory of checkpoint snapshots, named `ckpt-<boundary>.kmdc`
/// (zero-padded, so lexicographic order is boundary order).
#[derive(Debug, Clone)]
pub struct CheckpointStore {
    dir: PathBuf,
    keep_all: bool,
}

impl CheckpointStore {
    /// Open (creating if needed) a checkpoint directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<CheckpointStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        Ok(CheckpointStore { dir, keep_all: false })
    }

    /// Keep every snapshot instead of pruning to the newest two. The
    /// chaos harness uses this to enumerate every kill point; production
    /// runs keep the default (current + one fallback).
    pub fn keep_all(mut self, on: bool) -> CheckpointStore {
        self.keep_all = on;
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Atomically persist `ck` as `ckpt-<iteration>.kmdc` and return the
    /// final path. Unless [`keep_all`](CheckpointStore::keep_all) is on,
    /// older snapshots beyond the newest two are pruned afterwards.
    pub fn save(&self, ck: &Checkpoint) -> Result<PathBuf> {
        let name = format!("ckpt-{:010}.{EXT}", ck.iteration);
        let final_path = self.dir.join(&name);
        let tmp_path = self.dir.join(format!(".tmp-{name}"));
        let bytes = ck.encode();
        {
            let mut f = fs::File::create(&tmp_path)
                .with_context(|| format!("creating {}", tmp_path.display()))?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        fs::rename(&tmp_path, &final_path)
            .with_context(|| format!("publishing {}", final_path.display()))?;
        // Make the rename durable; failure here only weakens durability
        // of the *directory entry*, not correctness of what it names.
        if let Ok(d) = fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
        if !self.keep_all {
            self.prune(2)?;
        }
        Ok(final_path)
    }

    fn prune(&self, keep: usize) -> Result<()> {
        let files = self.files()?;
        if files.len() > keep {
            for old in &files[..files.len() - keep] {
                let _ = fs::remove_file(old);
            }
        }
        Ok(())
    }

    /// All snapshot files, sorted oldest → newest.
    pub fn files(&self) -> Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        for entry in fs::read_dir(&self.dir)
            .with_context(|| format!("listing {}", self.dir.display()))?
        {
            let path = entry?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name.starts_with("ckpt-") && name.ends_with(&format!(".{EXT}")) {
                out.push(path);
            }
        }
        out.sort();
        Ok(out)
    }

    /// Load one snapshot file, strictly: any truncation/corruption is a
    /// typed [`PersistError`] inside the error chain (recover it with
    /// `err.downcast_ref::<PersistError>()`).
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes =
            fs::read(path).with_context(|| format!("reading checkpoint {}", path.display()))?;
        Checkpoint::decode(&bytes).map_err(|e| {
            anyhow::Error::new(e).context(format!("loading checkpoint {}", path.display()))
        })
    }

    /// The newest loadable snapshot (path + contents). Skips corrupt
    /// newer files with a warning on stderr; if nothing loads, returns
    /// the newest file's typed error, or [`PersistError::NoCheckpoint`]
    /// when the directory holds no snapshots at all.
    pub fn latest(&self) -> Result<(PathBuf, Checkpoint)> {
        let files = self.files()?;
        let mut first_err: Option<anyhow::Error> = None;
        for path in files.iter().rev() {
            match Self::load(path) {
                Ok(ck) => return Ok((path.clone(), ck)),
                Err(e) => {
                    eprintln!("warning: skipping unreadable checkpoint {}: {e:#}", path.display());
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        Err(first_err
            .unwrap_or_else(|| anyhow::Error::new(PersistError::NoCheckpoint(self.dir.clone()))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geo::{Metric, Point};
    use crate::util::tempdir::TempDir;

    fn ck(iter: u64) -> Checkpoint {
        Checkpoint {
            algorithm: "kmedoids-mr".into(),
            metric: Metric::SqEuclidean,
            dims: 2,
            k: 2,
            iteration: iter,
            sim_seconds: iter as f64,
            rng: [7, 0, 0, 0],
            converged: false,
            cost: 10.0 / (iter + 1) as f64,
            dist_evals: 100 * iter,
            epoch: 0,
            wal_seq: 0,
            medoids: vec![Point::new(iter as f32, 0.0), Point::new(0.0, iter as f32)],
            coreset: None,
            pending: Vec::new(),
        }
    }

    #[test]
    fn save_load_latest_roundtrip() {
        let tmp = TempDir::new("persist-store");
        let store = CheckpointStore::open(tmp.path()).unwrap();
        let p1 = store.save(&ck(1)).unwrap();
        assert_eq!(CheckpointStore::load(&p1).unwrap(), ck(1));
        store.save(&ck(2)).unwrap();
        let (path, latest) = store.latest().unwrap();
        assert_eq!(latest, ck(2));
        assert!(path.to_string_lossy().contains("ckpt-0000000002"));
    }

    #[test]
    fn prunes_to_two_unless_keep_all() {
        let tmp = TempDir::new("persist-prune");
        let store = CheckpointStore::open(tmp.path()).unwrap();
        for i in 1..=5 {
            store.save(&ck(i)).unwrap();
        }
        assert_eq!(store.files().unwrap().len(), 2);

        let tmp2 = TempDir::new("persist-keep");
        let store2 = CheckpointStore::open(tmp2.path()).unwrap().keep_all(true);
        for i in 1..=5 {
            store2.save(&ck(i)).unwrap();
        }
        assert_eq!(store2.files().unwrap().len(), 5);
    }

    #[test]
    fn latest_falls_back_past_corrupt_newest() {
        let tmp = TempDir::new("persist-fallback");
        let store = CheckpointStore::open(tmp.path()).unwrap().keep_all(true);
        store.save(&ck(1)).unwrap();
        let newest = store.save(&ck(2)).unwrap();
        // Torn newest file: truncate it mid-payload.
        let bytes = fs::read(&newest).unwrap();
        fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();
        let (_, latest) = store.latest().unwrap();
        assert_eq!(latest, ck(1), "must fall back to the last good snapshot");
    }

    #[test]
    fn empty_dir_is_typed_no_checkpoint() {
        let tmp = TempDir::new("persist-empty");
        let store = CheckpointStore::open(tmp.path()).unwrap();
        let err = store.latest().unwrap_err();
        assert!(matches!(
            err.downcast_ref::<PersistError>(),
            Some(PersistError::NoCheckpoint(_))
        ));
    }

    #[test]
    fn no_tmp_droppings_after_save() {
        let tmp = TempDir::new("persist-tmp");
        let store = CheckpointStore::open(tmp.path()).unwrap();
        store.save(&ck(1)).unwrap();
        let leftovers: Vec<_> = fs::read_dir(tmp.path())
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with(".tmp-"))
            .collect();
        assert!(leftovers.is_empty());
    }
}
