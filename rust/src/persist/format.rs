//! The on-disk checkpoint format: versioned, CRC-checked, little-endian.
//!
//! ## Layout (format version 1)
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"KMDC"
//! 4       4     format version (u32 LE)
//! 8       8     payload length (u64 LE)
//! 16      4     CRC-32 of the payload (u32 LE, IEEE polynomial)
//! 20      ...   payload
//! ```
//!
//! Payload, in order (all little-endian):
//!
//! ```text
//! u16           algorithm name length, then that many UTF-8 bytes
//! u8            metric code (0 = sq_euclidean, 1 = manhattan, 2 = haversine)
//! u8            dims
//! u32           k
//! u64           iteration (fit) / update count (serve)
//! f64           sim-clock seconds consumed so far
//! 4 x u64       RNG state (word 0 carries the base seed; solver streams
//!               are reseeded per call, so the base seed alone resumes
//!               every derived stream exactly)
//! u8            converged flag (0/1)
//! f64           cost at this boundary
//! u64           distance evaluations so far
//! u64           published model epoch (serve; 0 for fits)
//! u64           WAL sequence number covered by this snapshot (serve)
//! u32           medoid count, then count x dims f32 coordinates
//! u8            coreset-present flag; if 1: u32 count, count x dims f32
//!               coordinates, then count f64 weights
//! u32           pending-delta count, then count x dims f32 coordinates
//! ```
//!
//! The decoder is *strict*: every read is length-checked (no panicking
//! [`crate::util::codec::Dec`] here — these bytes come from disk, not
//! from our own shuffle), the CRC must match, unknown versions are
//! refused, and trailing bytes after the payload are an error. The
//! golden test in `rust/tests/crash_recovery.rs` pins this layout
//! byte-for-byte so any change must bump [`FORMAT_VERSION`].

use crate::clustering::{FitCheckpoint, FitResume};
use crate::geo::{Metric, Point, MAX_DIMS};
use crate::persist::PersistError;

/// File magic: "KMDC" (K-MeDoids Checkpoint).
pub const MAGIC: [u8; 4] = *b"KMDC";

/// Highest checkpoint format version this build reads and writes.
pub const FORMAT_VERSION: u32 = 1;

/// Fixed-size prefix before the payload: magic, version, length, CRC.
pub const HEADER_LEN: usize = 20;

/// CRC-32 (IEEE 802.3 polynomial, reflected), bitwise — checkpoints are
/// kilobytes, so a table is not worth vendoring.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

fn metric_code(m: Metric) -> u8 {
    match m {
        Metric::SqEuclidean => 0,
        Metric::Manhattan => 1,
        Metric::Haversine => 2,
    }
}

fn metric_from_code(c: u8) -> Option<Metric> {
    match c {
        0 => Some(Metric::SqEuclidean),
        1 => Some(Metric::Manhattan),
        2 => Some(Metric::Haversine),
        _ => None,
    }
}

/// One durable snapshot of a fit or serving session.
///
/// Everything needed to resume exactly: identity (algorithm, metric,
/// dims, k), progress (iteration, cost, sim-clock, distance-evaluation
/// counters, convergence flag), randomness (base seed in `rng[0]`), the
/// medoid coordinates, the weighted coreset pool (coreset fits and
/// serving), and — for serving — the published epoch, the WAL sequence
/// number this snapshot covers, and any deltas buffered but not yet
/// folded into the pool.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub algorithm: String,
    pub metric: Metric,
    pub dims: u8,
    pub k: u32,
    pub iteration: u64,
    pub sim_seconds: f64,
    pub rng: [u64; 4],
    pub converged: bool,
    pub cost: f64,
    pub dist_evals: u64,
    pub epoch: u64,
    pub wal_seq: u64,
    pub medoids: Vec<Point>,
    pub coreset: Option<(Vec<Point>, Vec<f64>)>,
    pub pending: Vec<Point>,
}

impl Checkpoint {
    /// Snapshot a fit boundary (what [`crate::persist::CheckpointSink`]
    /// writes on every `on_checkpoint` callback).
    pub fn from_fit(s: &FitCheckpoint<'_>) -> Checkpoint {
        Checkpoint {
            algorithm: s.algorithm.to_string(),
            metric: s.metric,
            dims: s.medoids.first().map(|p| p.dims()).unwrap_or(2) as u8,
            k: s.k as u32,
            iteration: s.iteration as u64,
            sim_seconds: s.sim_seconds,
            rng: [s.seed, 0, 0, 0],
            converged: s.converged,
            cost: s.cost,
            dist_evals: s.dist_evals,
            epoch: 0,
            wal_seq: 0,
            medoids: s.medoids.to_vec(),
            coreset: s.coreset.map(|(p, w)| (p.to_vec(), w.to_vec())),
            pending: Vec::new(),
        }
    }

    /// The base seed the snapshotted run was started with.
    pub fn seed(&self) -> u64 {
        self.rng[0]
    }

    /// Convert into the engine-facing resume state consumed by
    /// `KMedoidsBuilder::resume`.
    pub fn to_resume(&self) -> FitResume {
        FitResume {
            algorithm: self.algorithm.clone(),
            metric: self.metric,
            seed: self.seed(),
            iteration: self.iteration as usize,
            cost: self.cost,
            sim_seconds: self.sim_seconds,
            dist_evals: self.dist_evals,
            converged: self.converged,
            medoids: self.medoids.clone(),
            coreset: self.coreset.clone(),
        }
    }

    /// Serialize to the on-disk frame (header + payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(256 + self.medoids.len() * self.dims as usize * 4);
        let alg = self.algorithm.as_bytes();
        assert!(alg.len() <= u16::MAX as usize, "algorithm name too long");
        p.extend_from_slice(&(alg.len() as u16).to_le_bytes());
        p.extend_from_slice(alg);
        p.push(metric_code(self.metric));
        p.push(self.dims);
        p.extend_from_slice(&self.k.to_le_bytes());
        p.extend_from_slice(&self.iteration.to_le_bytes());
        p.extend_from_slice(&self.sim_seconds.to_le_bytes());
        for w in self.rng {
            p.extend_from_slice(&w.to_le_bytes());
        }
        p.push(self.converged as u8);
        p.extend_from_slice(&self.cost.to_le_bytes());
        p.extend_from_slice(&self.dist_evals.to_le_bytes());
        p.extend_from_slice(&self.epoch.to_le_bytes());
        p.extend_from_slice(&self.wal_seq.to_le_bytes());
        write_points(&mut p, &self.medoids, self.dims);
        match &self.coreset {
            None => p.push(0),
            Some((reps, weights)) => {
                assert_eq!(reps.len(), weights.len(), "coreset weight per rep");
                p.push(1);
                write_points(&mut p, reps, self.dims);
                for w in weights {
                    p.extend_from_slice(&w.to_le_bytes());
                }
            }
        }
        write_points(&mut p, &self.pending, self.dims);

        let mut out = Vec::with_capacity(HEADER_LEN + p.len());
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(p.len() as u64).to_le_bytes());
        out.extend_from_slice(&crc32(&p).to_le_bytes());
        out.extend_from_slice(&p);
        out
    }

    /// Strict deserialization: every failure mode is a specific
    /// [`PersistError`] variant, never a panic or a silent partial load.
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint, PersistError> {
        if bytes.len() < HEADER_LEN {
            return Err(PersistError::Truncated { need: HEADER_LEN, have: bytes.len() });
        }
        if bytes[0..4] != MAGIC {
            let mut found = [0u8; 4];
            found.copy_from_slice(&bytes[0..4]);
            return Err(PersistError::BadMagic { found });
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != FORMAT_VERSION {
            return Err(PersistError::UnsupportedVersion {
                found: version,
                supported: FORMAT_VERSION,
            });
        }
        let payload_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
        let stored_crc = u32::from_le_bytes(bytes[16..20].try_into().unwrap());
        let have_payload = (bytes.len() - HEADER_LEN) as u64;
        if payload_len > have_payload {
            return Err(PersistError::Truncated {
                need: HEADER_LEN.saturating_add(payload_len.min(usize::MAX as u64) as usize),
                have: bytes.len(),
            });
        }
        if payload_len < have_payload {
            return Err(PersistError::Malformed(format!(
                "{} trailing bytes after payload",
                have_payload - payload_len
            )));
        }
        let payload = &bytes[HEADER_LEN..];
        let computed = crc32(payload);
        if computed != stored_crc {
            return Err(PersistError::BadCrc { stored: stored_crc, computed });
        }

        let mut r = Reader::new(payload);
        let alg_len = r.u16()? as usize;
        let alg = r.take(alg_len)?;
        let algorithm = std::str::from_utf8(alg)
            .map_err(|_| PersistError::Malformed("algorithm name is not UTF-8".into()))?
            .to_string();
        let metric = metric_from_code(r.u8()?)
            .ok_or_else(|| PersistError::Malformed("unknown metric code".into()))?;
        let dims = r.u8()?;
        if !(1..=MAX_DIMS as u8).contains(&dims) {
            return Err(PersistError::Malformed(format!("dims {dims} out of 1..={MAX_DIMS}")));
        }
        let k = r.u32()?;
        let iteration = r.u64()?;
        let sim_seconds = r.f64()?;
        let rng = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
        let converged = match r.u8()? {
            0 => false,
            1 => true,
            v => return Err(PersistError::Malformed(format!("converged flag {v} not 0/1"))),
        };
        let cost = r.f64()?;
        let dist_evals = r.u64()?;
        let epoch = r.u64()?;
        let wal_seq = r.u64()?;
        let medoids = read_points(&mut r, dims)?;
        let coreset = match r.u8()? {
            0 => None,
            1 => {
                let reps = read_points(&mut r, dims)?;
                let mut weights = Vec::with_capacity(reps.len());
                for _ in 0..reps.len() {
                    weights.push(r.f64()?);
                }
                Some((reps, weights))
            }
            v => return Err(PersistError::Malformed(format!("coreset flag {v} not 0/1"))),
        };
        let pending = read_points(&mut r, dims)?;
        if !r.is_empty() {
            return Err(PersistError::Malformed(format!(
                "{} unread bytes inside payload",
                r.remaining()
            )));
        }
        Ok(Checkpoint {
            algorithm,
            metric,
            dims,
            k,
            iteration,
            sim_seconds,
            rng,
            converged,
            cost,
            dist_evals,
            epoch,
            wal_seq,
            medoids,
            coreset,
            pending,
        })
    }
}

fn write_points(out: &mut Vec<u8>, pts: &[Point], dims: u8) {
    out.extend_from_slice(&(pts.len() as u32).to_le_bytes());
    for p in pts {
        debug_assert_eq!(p.dims(), dims as usize, "checkpoint point dims mismatch");
        for &c in p.coords() {
            out.extend_from_slice(&c.to_le_bytes());
        }
    }
}

fn read_points(r: &mut Reader<'_>, dims: u8) -> Result<Vec<Point>, PersistError> {
    let n = r.u32()? as usize;
    let mut pts = Vec::with_capacity(n.min(1 << 20));
    let mut coords = [0f32; MAX_DIMS];
    for _ in 0..n {
        for c in coords.iter_mut().take(dims as usize) {
            *c = r.f32()?;
        }
        pts.push(Point::from_slice(&coords[..dims as usize]));
    }
    Ok(pts)
}

/// Length-checked little-endian reader over untrusted bytes. Unlike the
/// shuffle-path [`crate::util::codec::Dec`] (which panics, because wire
/// bugs are programmer errors), every read here returns a typed
/// [`PersistError::Truncated`].
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], PersistError> {
        if self.remaining() < n {
            return Err(PersistError::Truncated {
                need: self.pos + n,
                have: self.buf.len(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, PersistError> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u16(&mut self) -> Result<u16, PersistError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub(crate) fn u32(&mut self) -> Result<u32, PersistError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, PersistError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f32(&mut self) -> Result<f32, PersistError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, PersistError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            algorithm: "kmedoids-mr".into(),
            metric: Metric::Haversine,
            dims: 2,
            k: 3,
            iteration: 7,
            sim_seconds: 12.5,
            rng: [42, 0, 0, 0],
            converged: false,
            cost: 123.456,
            dist_evals: 9_001,
            epoch: 0,
            wal_seq: 0,
            medoids: vec![Point::new(1.0, 2.0), Point::new(3.0, 4.0), Point::new(5.0, 6.0)],
            coreset: Some((vec![Point::new(0.5, 0.5)], vec![17.0])),
            pending: vec![Point::new(-1.0, -2.0)],
        }
    }

    #[test]
    fn roundtrip() {
        let ck = sample();
        assert_eq!(Checkpoint::decode(&ck.encode()).unwrap(), ck);
        // No coreset, no pending, 3-D.
        let ck = Checkpoint {
            algorithm: "kmedoids++-mr".into(),
            metric: Metric::SqEuclidean,
            dims: 3,
            k: 2,
            coreset: None,
            pending: Vec::new(),
            medoids: vec![Point::from_slice(&[1.0, 2.0, 3.0]), Point::from_slice(&[4.0, 5.0, 6.0])],
            ..sample()
        };
        assert_eq!(Checkpoint::decode(&ck.encode()).unwrap(), ck);
    }

    #[test]
    fn crc32_known_vectors() {
        // The IEEE CRC-32 check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn truncation_detected_at_every_length() {
        let bytes = sample().encode();
        for cut in 0..bytes.len() {
            let err = Checkpoint::decode(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, PersistError::Truncated { .. }),
                "cut at {cut}: {err}"
            );
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample().encode();
        bytes[0] = b'X';
        assert!(matches!(
            Checkpoint::decode(&bytes).unwrap_err(),
            PersistError::BadMagic { found: [b'X', b'M', b'D', b'C'] }
        ));
    }

    #[test]
    fn future_version_rejected() {
        let mut bytes = sample().encode();
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        assert_eq!(
            Checkpoint::decode(&bytes).unwrap_err(),
            PersistError::UnsupportedVersion { found: 99, supported: FORMAT_VERSION }
        );
    }

    #[test]
    fn flipped_payload_bit_fails_crc() {
        let mut bytes = sample().encode();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        assert!(matches!(
            Checkpoint::decode(&bytes).unwrap_err(),
            PersistError::BadCrc { .. }
        ));
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut bytes = sample().encode();
        bytes.push(0);
        assert!(matches!(
            Checkpoint::decode(&bytes).unwrap_err(),
            PersistError::Malformed(_)
        ));
    }
}
