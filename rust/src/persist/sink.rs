//! [`CheckpointSink`]: the observer that makes fits durable.
//!
//! Registered by [`crate::session::SessionBuilder::checkpoint_dir`], it
//! receives the [`FitCheckpoint`] snapshot emitted at every iteration
//! boundary and persists it through a [`CheckpointStore`]. Observer
//! callbacks are infallible by contract, so a failed save is reported on
//! stderr and the fit continues — durability degrades, computation does
//! not abort.

use crate::clustering::{FitCheckpoint, IterationObserver};
use crate::persist::format::Checkpoint;
use crate::persist::store::CheckpointStore;

/// Persists every iteration-boundary snapshot of a fit to disk.
pub struct CheckpointSink {
    store: CheckpointStore,
}

impl CheckpointSink {
    pub fn new(store: CheckpointStore) -> CheckpointSink {
        CheckpointSink { store }
    }
}

impl IterationObserver for CheckpointSink {
    fn wants_checkpoints(&self) -> bool {
        true
    }
    fn on_checkpoint(&mut self, state: &FitCheckpoint<'_>) {
        let ck = Checkpoint::from_fit(state);
        if let Err(e) = self.store.save(&ck) {
            eprintln!(
                "warning: checkpoint save failed at iteration {} ({}): {e:#}",
                state.iteration,
                self.store.dir().display()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geo::{Metric, Point};
    use crate::util::tempdir::TempDir;

    #[test]
    fn sink_persists_resumable_snapshots() {
        let tmp = TempDir::new("persist-sink");
        let store = CheckpointStore::open(tmp.path()).unwrap().keep_all(true);
        let mut sink = CheckpointSink::new(store.clone());
        let medoids = [Point::new(1.0, 2.0), Point::new(3.0, 4.0)];
        for iter in 1..=3usize {
            sink.on_checkpoint(&FitCheckpoint {
                algorithm: "kmedoids-mr",
                metric: Metric::Manhattan,
                seed: 99,
                k: 2,
                iteration: iter,
                cost: 50.0 / iter as f64,
                sim_seconds: iter as f64,
                dist_evals: 1000 * iter as u64,
                converged: iter == 3,
                medoids: &medoids,
                coreset: None,
            });
        }
        assert_eq!(store.files().unwrap().len(), 3);
        let (_, ck) = store.latest().unwrap();
        assert_eq!(ck.iteration, 3);
        assert!(ck.converged);
        assert_eq!(ck.seed(), 99);
        let resume = ck.to_resume();
        assert_eq!(resume.medoids, medoids.to_vec());
        assert_eq!(resume.algorithm, "kmedoids-mr");
        assert_eq!(resume.metric, Metric::Manhattan);
    }
}
