//! [`DeltaWal`]: the write-ahead delta log behind serving durability.
//!
//! Protocol (see `EXPERIMENTS.md` §Recovery protocol):
//!
//! 1. [`crate::serve::ServeSession::ingest`] appends every delta batch
//!    here — CRC-framed, sequence-stamped, `fdatasync`ed — *before* the
//!    batch touches in-memory state.
//! 2. Each flush writes a full checkpoint recording the highest WAL
//!    sequence number folded into it, *then* truncates the log.
//! 3. Restore loads the newest checkpoint and replays only records with
//!    `seq > checkpoint.wal_seq`, so a crash between checkpoint and
//!    truncate cannot double-apply a batch.
//!
//! Frame layout per record (little-endian):
//!
//! ```text
//! u32  body length
//! u32  CRC-32 of the body
//! body = u64 seq · u8 dims · u32 count · count x dims f32 coords
//! ```
//!
//! A *torn tail* — the file ends inside a frame, the expected result of
//! a crash mid-append — is tolerated: replay stops at the last complete
//! record. A CRC mismatch on a *complete* frame is bit rot, and replay
//! refuses with a typed [`PersistError::BadCrc`].

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::geo::{Point, MAX_DIMS};
use crate::persist::format::{crc32, Reader};
use crate::persist::PersistError;

/// One replayed WAL record: the sequence number it was appended under
/// and the delta batch it carried.
#[derive(Debug, Clone, PartialEq)]
pub struct WalRecord {
    pub seq: u64,
    pub deltas: Vec<Point>,
}

/// Append-only write-ahead log of serve delta batches.
#[derive(Debug)]
pub struct DeltaWal {
    path: PathBuf,
    file: File,
}

impl DeltaWal {
    /// Open (creating if needed) the log at `path` for appending.
    pub fn open(path: impl Into<PathBuf>) -> Result<DeltaWal> {
        let path = path.into();
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening WAL {}", path.display()))?;
        Ok(DeltaWal { path, file })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one delta batch under sequence number `seq` and `fdatasync`
    /// it — the batch is durable when this returns.
    pub fn append(&mut self, seq: u64, deltas: &[Point]) -> Result<()> {
        let dims = deltas.first().map(|p| p.dims()).unwrap_or(2);
        let mut body = Vec::with_capacity(13 + deltas.len() * dims * 4);
        body.extend_from_slice(&seq.to_le_bytes());
        body.push(dims as u8);
        body.extend_from_slice(&(deltas.len() as u32).to_le_bytes());
        for p in deltas {
            debug_assert_eq!(p.dims(), dims, "WAL batch dims mismatch");
            for &c in p.coords() {
                body.extend_from_slice(&c.to_le_bytes());
            }
        }
        let mut frame = Vec::with_capacity(8 + body.len());
        frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&body).to_le_bytes());
        frame.extend_from_slice(&body);
        self.file
            .write_all(&frame)
            .with_context(|| format!("appending to WAL {}", self.path.display()))?;
        self.file.sync_data()?;
        Ok(())
    }

    /// Truncate the log to empty (called *after* a checkpoint has made
    /// its contents redundant — never before).
    pub fn reset(&mut self) -> Result<()> {
        self.file.set_len(0)?;
        self.file.sync_data()?;
        Ok(())
    }

    /// Replay every complete record in `path`, in append order. A
    /// missing file is an empty log. The torn-tail / bit-rot policy is
    /// described at the module level.
    pub fn replay(path: &Path) -> Result<Vec<WalRecord>> {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(e).with_context(|| format!("reading WAL {}", path.display())),
        };
        let mut out = Vec::new();
        let mut pos = 0usize;
        while pos < bytes.len() {
            if bytes.len() - pos < 8 {
                break; // torn tail: header incomplete
            }
            let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
            let stored = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
            if bytes.len() - pos - 8 < len {
                break; // torn tail: body incomplete
            }
            let body = &bytes[pos + 8..pos + 8 + len];
            let computed = crc32(body);
            if computed != stored {
                return Err(anyhow::Error::new(PersistError::BadCrc { stored, computed })
                    .context(format!("WAL {} record at byte {pos}", path.display())));
            }
            out.push(decode_body(body).map_err(|e| {
                anyhow::Error::new(e)
                    .context(format!("WAL {} record at byte {pos}", path.display()))
            })?);
            pos += 8 + len;
        }
        Ok(out)
    }
}

fn decode_body(body: &[u8]) -> Result<WalRecord, PersistError> {
    let mut r = Reader::new(body);
    let seq = r.u64()?;
    let dims = r.u8()? as usize;
    if !(1..=MAX_DIMS).contains(&dims) {
        return Err(PersistError::Malformed(format!("WAL dims {dims} out of 1..={MAX_DIMS}")));
    }
    let n = r.u32()? as usize;
    let mut deltas = Vec::with_capacity(n.min(1 << 20));
    let mut coords = [0f32; MAX_DIMS];
    for _ in 0..n {
        for c in coords.iter_mut().take(dims) {
            *c = r.f32()?;
        }
        deltas.push(Point::from_slice(&coords[..dims]));
    }
    if !r.is_empty() {
        return Err(PersistError::Malformed(format!(
            "{} unread bytes in WAL record",
            r.remaining()
        )));
    }
    Ok(WalRecord { seq, deltas })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    fn batch(tag: f32, n: usize) -> Vec<Point> {
        (0..n).map(|i| Point::new(tag, i as f32)).collect()
    }

    #[test]
    fn append_replay_roundtrip() {
        let tmp = TempDir::new("wal-roundtrip");
        let path = tmp.join("serve.wal");
        let mut wal = DeltaWal::open(&path).unwrap();
        wal.append(1, &batch(1.0, 3)).unwrap();
        wal.append(2, &batch(2.0, 1)).unwrap();
        wal.append(3, &[]).unwrap();
        let records = DeltaWal::replay(&path).unwrap();
        assert_eq!(records.len(), 3);
        assert_eq!(records[0], WalRecord { seq: 1, deltas: batch(1.0, 3) });
        assert_eq!(records[1].seq, 2);
        assert_eq!(records[2].deltas, Vec::new());
    }

    #[test]
    fn missing_file_is_empty_log() {
        let tmp = TempDir::new("wal-missing");
        assert_eq!(DeltaWal::replay(&tmp.join("nope.wal")).unwrap(), Vec::new());
    }

    #[test]
    fn reset_empties_log() {
        let tmp = TempDir::new("wal-reset");
        let path = tmp.join("serve.wal");
        let mut wal = DeltaWal::open(&path).unwrap();
        wal.append(1, &batch(1.0, 2)).unwrap();
        wal.reset().unwrap();
        assert_eq!(DeltaWal::replay(&path).unwrap(), Vec::new());
        // Appends after reset land in the now-empty file.
        wal.append(2, &batch(2.0, 1)).unwrap();
        let records = DeltaWal::replay(&path).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].seq, 2);
    }

    #[test]
    fn torn_tail_tolerated_at_every_cut() {
        let tmp = TempDir::new("wal-torn");
        let path = tmp.join("serve.wal");
        let mut wal = DeltaWal::open(&path).unwrap();
        wal.append(1, &batch(1.0, 2)).unwrap();
        let full = std::fs::read(&path).unwrap();
        let first_len = full.len();
        wal.append(2, &batch(2.0, 2)).unwrap();
        let full = std::fs::read(&path).unwrap();
        // Cut anywhere strictly inside the second frame: replay returns
        // exactly the first record, no error.
        for cut in first_len + 1..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let records = DeltaWal::replay(&path).unwrap();
            assert_eq!(records.len(), 1, "cut at {cut}");
            assert_eq!(records[0].seq, 1);
        }
    }

    #[test]
    fn mid_file_corruption_is_typed_error() {
        let tmp = TempDir::new("wal-rot");
        let path = tmp.join("serve.wal");
        let mut wal = DeltaWal::open(&path).unwrap();
        wal.append(1, &batch(1.0, 2)).unwrap();
        wal.append(2, &batch(2.0, 2)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[10] ^= 0xFF; // flip a bit inside the FIRST record's body
        std::fs::write(&path, &bytes).unwrap();
        let err = DeltaWal::replay(&path).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<PersistError>(), Some(PersistError::BadCrc { .. })),
            "{err:#}"
        );
    }
}
