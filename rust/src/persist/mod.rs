//! Durable checkpoint/restore for fits and serving sessions.
//!
//! The simulated cluster already survives task retries, speculation, and
//! node loss (PR 4) — but the *host process* was all-or-nothing: kill a
//! long `kmedoids-mr` fit or a [`crate::serve::ServeSession`] writer and
//! every iteration, ingested delta, and published epoch was gone. This
//! module makes host-process state durable:
//!
//! - [`format`]: a versioned, CRC-checked, little-endian binary
//!   checkpoint format ([`Checkpoint`]) — magic + header (format
//!   version, algorithm, metric, dims, k, iteration, sim-clock, RNG
//!   state) and body (medoid coordinates, the weighted coreset pool,
//!   pending serve deltas). Decoding is strict: truncation, a foreign
//!   magic, a CRC mismatch, or a future version each yield their own
//!   [`PersistError`] variant — never a silent partial load.
//! - [`store`]: [`CheckpointStore`] writes snapshots with tmp-file →
//!   `fsync` → rename discipline so a crash mid-write can never clobber
//!   the last good snapshot, and [`CheckpointStore::latest`] falls back
//!   past a corrupt newest file to the most recent loadable one.
//! - [`wal`]: [`DeltaWal`], the write-ahead delta log for serving.
//!   Every ingested delta batch is appended (CRC-framed, `fdatasync`ed)
//!   *before* it touches in-memory state; on restore the log is replayed
//!   on top of the latest snapshot to reconstruct the exact published
//!   epoch. A torn tail (crash mid-append) is tolerated; corruption
//!   before the tail is a typed error.
//! - [`sink`]: [`CheckpointSink`], an [`crate::clustering::IterationObserver`]
//!   that persists a snapshot at every iteration boundary of a fit.
//!   Attach it with [`crate::session::SessionBuilder::checkpoint_dir`].
//!
//! Because the whole engine is deterministic (same seed ⇒ byte-identical
//! medoids/costs/labels at any thread count), recovery is *provable*,
//! not probabilistic: `rust/tests/crash_recovery.rs` kills a run at
//! every iteration and serve-flush boundary, resumes from disk, and
//! asserts bitwise-identical final labels, costs, medoids, and epochs.

pub mod format;
pub mod sink;
pub mod store;
pub mod wal;

pub use format::{crc32, Checkpoint, FORMAT_VERSION, HEADER_LEN, MAGIC};
pub use sink::CheckpointSink;
pub use store::CheckpointStore;
pub use wal::{DeltaWal, WalRecord};

use std::path::PathBuf;

/// Typed failure modes of the persistence layer.
///
/// Carried inside [`anyhow::Error`] chains; recover the variant with
/// `err.downcast_ref::<PersistError>()` (the same pattern as
/// `driver::spec::SpecError`).
#[derive(Debug, Clone, PartialEq)]
pub enum PersistError {
    /// The file ended before a complete record could be read.
    Truncated {
        /// Bytes the decoder needed.
        need: usize,
        /// Bytes actually available.
        have: usize,
    },
    /// The first four bytes are not [`MAGIC`] — not a checkpoint file.
    BadMagic {
        /// The four bytes actually found.
        found: [u8; 4],
    },
    /// The file declares a format version this build cannot read.
    UnsupportedVersion {
        /// Version stamped in the file.
        found: u32,
        /// Highest version this build supports ([`FORMAT_VERSION`]).
        supported: u32,
    },
    /// The payload checksum does not match the header — bit rot or a
    /// partially overwritten file.
    BadCrc {
        /// CRC stored in the header.
        stored: u32,
        /// CRC computed over the payload actually read.
        computed: u32,
    },
    /// Structurally invalid content inside a frame that passed the CRC
    /// (impossible dims, unknown metric code, trailing garbage, …).
    Malformed(String),
    /// No loadable checkpoint exists in the directory.
    NoCheckpoint(PathBuf),
}

impl std::fmt::Display for PersistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PersistError::Truncated { need, have } => {
                write!(f, "checkpoint truncated: needed {need} bytes, have {have}")
            }
            PersistError::BadMagic { found } => {
                write!(f, "not a checkpoint file: bad magic {found:02x?}")
            }
            PersistError::UnsupportedVersion { found, supported } => write!(
                f,
                "checkpoint format version {found} not supported (this build reads <= {supported})"
            ),
            PersistError::BadCrc { stored, computed } => write!(
                f,
                "checkpoint CRC mismatch: header {stored:#010x} vs payload {computed:#010x}"
            ),
            PersistError::Malformed(what) => write!(f, "malformed checkpoint: {what}"),
            PersistError::NoCheckpoint(dir) => {
                write!(f, "no loadable checkpoint in {}", dir.display())
            }
        }
    }
}

impl std::error::Error for PersistError {}
