//! Online serving: epoch-swapped medoid snapshots, a nearest-medoid
//! query path, and mini-batch coreset updates.
//!
//! A finished fit is inert until something answers queries with it. This
//! subsystem turns a [`crate::clustering::ClusterOutcome`] into a live
//! model in three layers:
//!
//! 1. **Snapshot** — [`ClusterModel`] is an immutable publication of a
//!    fit (medoids, metric, dims, and an optional grid index for the 2-D
//!    squared-Euclidean fast path), shared as `Arc` across reader
//!    threads. [`ModelHandle`] holds the *current* snapshot and swaps it
//!    atomically on refit: readers never block on a writer and can never
//!    observe a torn model, because a model is never mutated after
//!    publication — only replaced. [`crate::session::ClusterSession::publish`]
//!    produces the snapshot from a fit.
//! 2. **Query** — [`ClusterModel::assign`] / [`ClusterModel::assign_batch`]
//!    answer nearest-medoid queries through the same
//!    [`crate::runtime::ComputeBackend`] assign kernels the batch label
//!    pass uses, so serving answers are byte-identical to the fit's
//!    label output (the conformance matrix pins this per algorithm and
//!    metric).
//! 3. **Update** — [`ServeSession::ingest`] buffers delta points into
//!    mini-batches, folds each batch into the weighted coreset carried
//!    over from the fit (the PR 5 compress-then-recluster substrate),
//!    runs cheap driver-side weighted refinement, and epoch-swaps the
//!    refined medoids into the handle, emitting
//!    [`crate::clustering::observe::IterationObserver`] drift events.
//!
//! 4. **Durability** — [`ServeSession::attach_persistence`] write-ahead
//!    logs every ingested batch and checkpoints the full session state
//!    at each flush ([`crate::persist`]); [`ServeSession::restore`]
//!    rebuilds the exact published epoch after a crash by replaying the
//!    log over the newest snapshot.
//!
//! `bench serve` (see `driver::suites::serve_suite`) drives a mixed
//! query/update workload over a thread sweep and records throughput and
//! p50/p99/p999 assign latencies into `BENCH_serve.json`.

mod model;
mod session;

pub use model::{ClusterModel, ModelHandle};
pub use session::{
    IngestError, ServeConfig, ServeSession, UpdateReport, SERVE_EVENT_NAME, WAL_FILE,
};
