//! Snapshot + query layers: immutable [`ClusterModel`] publications and
//! the lock-free [`ModelHandle`] epoch swap.

use crate::geo::index::SpatialIndex;
use crate::geo::{Metric, Point, PointSource};
use crate::runtime::{assign_points, ComputeBackend};
use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// An immutable, index-accelerated publication of a fit: the medoids,
/// the metric they minimize, and (for the 2-D squared-Euclidean fast
/// path) a conservative grid index that prunes the medoid slab per
/// query. Share it as `Arc<ClusterModel>` across any number of reader
/// threads — there is nothing to lock because nothing ever mutates.
///
/// Queries route through the same [`ComputeBackend`] assign kernels as
/// the batch label pass, so a served `(label, dist)` is byte-identical
/// to what the fit's label pass emitted for the same point (the
/// conformance matrix asserts this per algorithm × metric). The grid
/// index only ever *removes provably-losing medoids* from the staged
/// slab — its pruning margin dominates the f32 kernel error, so the
/// argmin (and its f32 distance) are unchanged.
pub struct ClusterModel {
    epoch: u64,
    backend: Arc<dyn ComputeBackend>,
    medoids: Vec<Point>,
    metric: Metric,
    dims: usize,
    grid: Option<SpatialIndex>,
}

impl ClusterModel {
    /// Wrap fitted medoids as a servable snapshot. Builds the grid index
    /// automatically for 2-D squared-Euclidean models with more than one
    /// medoid. The epoch starts at 0 ("unpublished"); [`ModelHandle`]
    /// stamps 1, 2, … as snapshots are published.
    pub fn new(
        backend: Arc<dyn ComputeBackend>,
        medoids: Vec<Point>,
        metric: Metric,
    ) -> ClusterModel {
        assert!(!medoids.is_empty(), "a model needs at least one medoid");
        let dims = medoids[0].dims();
        assert!(
            medoids.iter().all(|m| m.dims() == dims),
            "mixed-dims medoids in one model"
        );
        assert!(metric.supports_dims(dims), "{} does not support dims={dims}", metric.name());
        assert!(
            medoids.len() <= backend.kpad(),
            "k={} exceeds backend capacity {}",
            medoids.len(),
            backend.kpad()
        );
        let grid = if dims == 2 && metric == Metric::SqEuclidean && medoids.len() > 1 {
            SpatialIndex::build(&medoids, metric)
        } else {
            None
        };
        ClusterModel { epoch: 0, backend, medoids, metric, dims, grid }
    }

    /// Monotone publication epoch (0 until a [`ModelHandle`] publishes
    /// this snapshot).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
    pub fn k(&self) -> usize {
        self.medoids.len()
    }
    pub fn dims(&self) -> usize {
        self.dims
    }
    pub fn metric(&self) -> Metric {
        self.metric
    }
    pub fn medoids(&self) -> &[Point] {
        &self.medoids
    }
    /// Whether the 2-D fast-path grid index is active for this model.
    pub fn has_grid_index(&self) -> bool {
        self.grid.is_some()
    }

    /// Nearest-medoid query: `(medoid index, f32 dissimilarity)` exactly
    /// as the batch label pass would report for this point. When the grid
    /// index applies, only the cell's candidate medoids are staged into
    /// the kernel; the answer is provably identical (see [`SpatialIndex`]).
    pub fn assign(&self, p: &Point) -> (u32, f32) {
        assert_eq!(p.dims(), self.dims, "query dims mismatch");
        if let Some(grid) = &self.grid {
            if let Some(cell) = grid.cell(p) {
                if cell.cands.len() < self.medoids.len() {
                    let sub: Vec<Point> =
                        cell.cands.iter().map(|&j| self.medoids[j as usize]).collect();
                    let (local, dist) = self.kernel_one(p, &sub);
                    return (cell.cands[local as usize], dist);
                }
            }
        }
        self.kernel_one(p, &self.medoids)
    }

    fn kernel_one(&self, p: &Point, medoids: &[Point]) -> (u32, f32) {
        let res =
            assign_points(self.backend.as_ref(), std::slice::from_ref(p), medoids, self.metric)
                .expect("assign kernel failed in serve query");
        (res.labels[0], res.mindists[0])
    }

    /// Batch nearest-medoid query over any [`PointSource`]; returns
    /// `(labels, dissimilarities)` byte-identical to the batch label
    /// pass over the same points and medoids (per-point results do not
    /// depend on block boundaries).
    pub fn assign_batch<S>(&self, src: &S) -> (Vec<u32>, Vec<f32>)
    where
        S: PointSource + ?Sized,
    {
        let n = src.len();
        let mut labels = Vec::with_capacity(n);
        let mut dists = Vec::with_capacity(n);
        let chunk = self.backend.block().max(1) * 4;
        let mut buf: Vec<Point> = Vec::with_capacity(chunk.min(n));
        let mut start = 0usize;
        while start < n {
            let len = (n - start).min(chunk);
            buf.clear();
            for i in 0..len {
                buf.push(src.get(start + i));
            }
            let res = assign_points(self.backend.as_ref(), &buf, &self.medoids, self.metric)
                .expect("assign kernel failed in serve batch query");
            labels.extend_from_slice(&res.labels);
            dists.extend_from_slice(&res.mindists);
            start += len;
        }
        (labels, dists)
    }
}

/// The current-model slot readers share: an atomic pointer to the latest
/// published [`ClusterModel`], swapped wholesale on refit.
///
/// - **Readers never block**: [`ModelHandle::load`] is an atomic pointer
///   read plus a reference-count increment — no lock, no wait, even
///   while a writer is mid-publish.
/// - **No torn models**: a snapshot is fully constructed (and its epoch
///   stamped) *before* the pointer swap; readers see either the old
///   snapshot or the new one, never a mix.
/// - **Monotone epochs**: each publish stamps the next epoch (1, 2, …),
///   so any reader observing epochs over time sees a non-decreasing
///   sequence.
///
/// Every published snapshot is retained in a small log for the handle's
/// lifetime (a few `Point`s plus the grid index per epoch). That pin is
/// what makes the lock-free read sound without a garbage collector: the
/// raw pointer a reader just loaded can never be freed out from under
/// its reference-count increment.
pub struct ModelHandle {
    current: AtomicPtr<ClusterModel>,
    /// Every snapshot ever published through this handle (keeps the
    /// `current` pointee alive for concurrent readers; see above).
    published: Mutex<Vec<Arc<ClusterModel>>>,
    next_epoch: AtomicU64,
}

impl ModelHandle {
    /// Publish `model` as epoch 1 and return the handle readers share.
    pub fn new(model: ClusterModel) -> ModelHandle {
        ModelHandle::new_at(model, 1)
    }

    /// Publish `model` as epoch `first_epoch` (clamped to >= 1). This is
    /// the restore path: a checkpointed serve session republishes its
    /// snapshot under the epoch it was checkpointed at, so readers see
    /// the epoch sequence continue across a crash instead of restarting
    /// at 1.
    pub fn new_at(model: ClusterModel, first_epoch: u64) -> ModelHandle {
        let handle = ModelHandle {
            current: AtomicPtr::new(std::ptr::null_mut()),
            published: Mutex::new(Vec::new()),
            next_epoch: AtomicU64::new(first_epoch.max(1)),
        };
        handle.publish(model);
        handle
    }

    /// Atomically swap in a new snapshot; returns its stamped epoch.
    pub fn publish(&self, model: ClusterModel) -> u64 {
        let epoch = self.next_epoch.fetch_add(1, Ordering::Relaxed);
        let arc = Arc::new(ClusterModel { epoch, ..model });
        self.published.lock().unwrap().push(arc.clone());
        // The slot owns one strong count (via `into_raw`); the log above
        // owns another for the handle's lifetime.
        let ptr = Arc::into_raw(arc).cast_mut();
        let old = self.current.swap(ptr, Ordering::AcqRel);
        if !old.is_null() {
            // SAFETY: `old` came from `Arc::into_raw` in a previous
            // publish and carried the slot's strong count; the log still
            // holds its own count, so readers that loaded `old` before
            // the swap remain safe.
            unsafe { drop(Arc::from_raw(old)) };
        }
        epoch
    }

    /// Grab the current snapshot without blocking. The returned `Arc`
    /// stays valid (and immutable) no matter how many refits are
    /// published after this call.
    pub fn load(&self) -> Arc<ClusterModel> {
        let ptr = self.current.load(Ordering::Acquire);
        debug_assert!(!ptr.is_null(), "handle always holds a model after new()");
        // SAFETY: `ptr` came from `Arc::into_raw` in `publish`, and the
        // `published` log holds a strong count on that allocation for
        // the whole lifetime of `self`, so the count is >= 1 here and
        // the increment can never race with deallocation.
        unsafe {
            Arc::increment_strong_count(ptr);
            Arc::from_raw(ptr)
        }
    }

    /// Epoch of the currently visible snapshot.
    pub fn epoch(&self) -> u64 {
        self.load().epoch()
    }

    /// Number of snapshots published through this handle so far.
    pub fn epochs_published(&self) -> usize {
        self.published.lock().unwrap().len()
    }
}

impl Drop for ModelHandle {
    fn drop(&mut self) {
        let ptr = *self.current.get_mut();
        if !ptr.is_null() {
            // SAFETY: releases the slot's own strong count (see publish).
            unsafe { drop(Arc::from_raw(ptr)) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    fn be() -> Arc<dyn ComputeBackend> {
        Arc::new(NativeBackend::new(64, 8))
    }

    fn rand_points(rng: &mut Rng, n: usize, spread: f64) -> Vec<Point> {
        (0..n)
            .map(|_| {
                Point::new(
                    (rng.f64() * spread - spread / 2.0) as f32,
                    (rng.f64() * spread - spread / 2.0) as f32,
                )
            })
            .collect()
    }

    #[test]
    fn grid_pruned_assign_is_byte_identical_to_full_kernel() {
        for_all(20, 0x5E21, |rng| {
            let k = 2 + rng.below(6);
            let medoids = rand_points(rng, k, 2e4);
            let model = ClusterModel::new(be(), medoids.clone(), Metric::SqEuclidean);
            assert!(model.has_grid_index());
            let queries = rand_points(rng, 200, 6e4); // inside + outside the grid
            let (batch_labels, batch_dists) = model.assign_batch(queries.as_slice());
            for (i, q) in queries.iter().enumerate() {
                let (l, d) = model.assign(q);
                assert_eq!(l, batch_labels[i], "label differs at query {i}");
                assert_eq!(d.to_bits(), batch_dists[i].to_bits(), "dist differs at query {i}");
            }
        });
    }

    #[test]
    fn assign_matches_f64_oracle_distances() {
        for_all(10, 0x5E22, |rng| {
            let k = 1 + rng.below(7);
            let medoids = rand_points(rng, k, 100.0);
            let model = ClusterModel::new(be(), medoids.clone(), Metric::SqEuclidean);
            for q in rand_points(rng, 100, 150.0) {
                let (l, d) = model.assign(&q);
                let best = medoids.iter().map(|m| q.dist2(m)).fold(f64::INFINITY, f64::min);
                let got = q.dist2(&medoids[l as usize]);
                assert!(got <= best * 1.001 + 1e-3, "labeled {got} vs best {best}");
                assert!((d as f64 - got).abs() <= 1e-2 * got.max(1.0));
            }
        });
    }

    #[test]
    fn non_fast_path_models_have_no_grid_but_still_serve() {
        let medoids = vec![
            Point::from_slice(&[0.0, 0.0, 0.0]),
            Point::from_slice(&[10.0, 10.0, 10.0]),
        ];
        let model = ClusterModel::new(be(), medoids, Metric::Manhattan);
        assert!(!model.has_grid_index());
        let (l, d) = model.assign(&Point::from_slice(&[9.0, 9.0, 9.0]));
        assert_eq!(l, 1);
        assert!((d - 3.0).abs() < 1e-4);
    }

    #[test]
    fn handle_swaps_epochs_monotonically() {
        let m = |x: f32| ClusterModel::new(be(), vec![Point::new(x, 0.0)], Metric::SqEuclidean);
        let handle = ModelHandle::new(m(0.0));
        assert_eq!(handle.epoch(), 1);
        let first = handle.load();
        assert_eq!(first.epoch(), 1);
        assert_eq!(handle.publish(m(1.0)), 2);
        assert_eq!(handle.publish(m(2.0)), 3);
        assert_eq!(handle.epoch(), 3);
        assert_eq!(handle.epochs_published(), 3);
        // A snapshot loaded before the swaps is still intact.
        assert_eq!(first.epoch(), 1);
        assert_eq!(first.medoids()[0], Point::new(0.0, 0.0));
    }

    #[test]
    fn new_at_continues_a_checkpointed_epoch_sequence() {
        let m = |x: f32| ClusterModel::new(be(), vec![Point::new(x, 0.0)], Metric::SqEuclidean);
        let handle = ModelHandle::new_at(m(0.0), 7);
        assert_eq!(handle.epoch(), 7);
        assert_eq!(handle.publish(m(1.0)), 8);
        assert_eq!(ModelHandle::new_at(m(0.0), 0).epoch(), 1, "epoch 0 means unpublished");
    }

    #[test]
    fn loaded_snapshot_outlives_the_handle() {
        let model =
            ClusterModel::new(be(), vec![Point::new(3.0, 4.0)], Metric::SqEuclidean);
        let loaded = {
            let handle = ModelHandle::new(model);
            handle.load()
        };
        assert_eq!(loaded.epoch(), 1);
        assert_eq!(loaded.assign(&Point::new(3.0, 4.0)).0, 0);
    }

    #[test]
    fn concurrent_readers_see_whole_models() {
        // Smoke version of the epoch-swap property test (the full
        // concurrent matrix lives in tests/serve_epoch.rs): all medoids
        // of epoch e sit at x = 100·e, so any mixed snapshot would
        // mislabel the probe.
        let mk = |e: f32| {
            ClusterModel::new(
                be(),
                vec![Point::new(100.0 * e, 0.0), Point::new(100.0 * e, 50.0)],
                Metric::SqEuclidean,
            )
        };
        let handle = Arc::new(ModelHandle::new(mk(1.0)));
        std::thread::scope(|scope| {
            for _ in 0..3 {
                let handle = Arc::clone(&handle);
                scope.spawn(move || {
                    let mut last = 0u64;
                    for _ in 0..500 {
                        let m = handle.load();
                        let e = m.epoch();
                        assert!(e >= last, "epoch went backwards: {last} -> {e}");
                        last = e;
                        let probe = Point::new(100.0 * e as f32, 10.0);
                        let (l, d) = m.assign(&probe);
                        assert_eq!(l, 0, "epoch {e} mislabeled its own probe");
                        assert!(d < 101.0, "epoch {e} probe distance {d}");
                    }
                });
            }
            for e in 2..=6 {
                handle.publish(mk(e as f32));
                std::thread::yield_now();
            }
        });
        assert_eq!(handle.epochs_published(), 6);
    }

    #[test]
    #[should_panic(expected = "at least one medoid")]
    fn empty_model_rejected() {
        let _ = ClusterModel::new(be(), vec![], Metric::SqEuclidean);
    }

    #[test]
    #[should_panic(expected = "exceeds backend capacity")]
    fn oversized_k_rejected() {
        let _ = ClusterModel::new(be(), vec![Point::new(0.0, 0.0); 9], Metric::SqEuclidean);
    }
}
