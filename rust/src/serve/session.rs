//! Update layer: [`ServeSession`] — mini-batch delta ingest over the
//! weighted coreset, cheap driver-side refinement, epoch-swapped
//! publication.

use super::{ClusterModel, ModelHandle};
use crate::clustering::coreset::{default_coreset_size, weighted_refine_step};
use crate::clustering::observe::{IterationEvent, IterationObserver, ObserverHub};
use crate::clustering::seeding::{min_dists_chunked, plus_plus_serial, recluster_candidates};
use crate::clustering::ClusterOutcome;
use crate::geo::{Metric, Point, Weighted};
use crate::persist::{Checkpoint, CheckpointStore, DeltaWal};
use crate::runtime::ops::assign_weighted;
use crate::runtime::ComputeBackend;
use crate::session::{ClusterSession, DatasetHandle};
use crate::util::rng::Rng;
use std::path::Path;
use std::sync::Arc;

/// `algorithm` tag on the [`IterationEvent`]s a serve session emits —
/// one event per flushed mini-batch — and on the [`Checkpoint`]s a
/// durable serve session writes.
pub const SERVE_EVENT_NAME: &str = "serve-ingest";

/// File name of the write-ahead delta log inside a serve persistence
/// directory (next to the `ckpt-*.kmdc` snapshots).
pub const WAL_FILE: &str = "serve.wal";

/// Typed rejection for [`ServeSession::ingest`]: invalid deltas are
/// refused before any state (write-ahead log, buffer, model) is touched,
/// so a failed ingest leaves the session exactly as it was. Recover the
/// variant from the `anyhow` chain with
/// `err.downcast_ref::<IngestError>()`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum IngestError {
    /// A delta coordinate is NaN or infinite.
    NonFinite { index: usize, value: f32 },
    /// A delta's dimensionality differs from the served model's.
    DimsMismatch { index: usize, expected: usize, got: usize },
}

impl std::fmt::Display for IngestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IngestError::NonFinite { index, value } => {
                write!(f, "delta {index} has a non-finite coordinate ({value})")
            }
            IngestError::DimsMismatch { index, expected, got } => write!(
                f,
                "delta {index} dims mismatch (model serves {expected}-dimensional points, \
                 got {got})"
            ),
        }
    }
}

impl std::error::Error for IngestError {}

/// Knobs for the online update loop.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Deltas buffered before a refit is triggered (mini-batch size).
    pub batch_size: usize,
    /// Weighted alternating-refinement iterations per flush.
    pub refine_iters: usize,
    /// Weighted-representative budget carried between flushes; `None`
    /// uses [`default_coreset_size`] of the fit's `k` and `n`.
    pub coreset_size: Option<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { batch_size: 256, refine_iters: 2, coreset_size: None }
    }
}

/// What one flushed mini-batch did to the model.
#[derive(Debug, Clone, Copy)]
pub struct UpdateReport {
    /// Epoch the refined snapshot was published as.
    pub epoch: u64,
    /// Delta points folded in by this flush.
    pub batch: usize,
    /// Weighted coreset cost of the *previous* medoids on the updated
    /// coreset (before refinement).
    pub cost_before: f64,
    /// Weighted coreset cost of the refined medoids. Never above
    /// `cost_before` (up to kernel f32 rounding): refinement keeps the
    /// incumbent medoid as a candidate in every update step.
    pub cost_after: f64,
    /// Total medoid displacement old → new under the model metric.
    pub medoid_drift: f64,
    /// Representatives in the coreset after fold + recompression.
    pub coreset_len: usize,
}

/// The single-writer side of online serving. Owns the evolving weighted
/// coreset and the [`ModelHandle`] readers share; [`ServeSession::ingest`]
/// buffers delta points, and every full mini-batch is folded into the
/// coreset (unit-weight representatives, recompressed by the same
/// weighted ++ draw as the merge reducer once it exceeds twice the
/// budget), refined with a few exact weighted PAM steps, and published
/// as the next epoch — all driver-side, no MapReduce job, readers never
/// blocked.
///
/// Serving runs off the simulated cluster: emitted events carry
/// `sim_seconds == 0.0`, and work is accounted in `dist_evals` only.
pub struct ServeSession {
    backend: Arc<dyn ComputeBackend>,
    metric: Metric,
    k: usize,
    seed: u64,
    cfg: ServeConfig,
    handle: Arc<ModelHandle>,
    reps: Vec<Point>,
    weights: Vec<f64>,
    target: usize,
    buffer: Vec<Point>,
    observers: ObserverHub,
    updates: usize,
    dist_evals: u64,
    last: Option<UpdateReport>,
    /// Durability (see [`ServeSession::attach_persistence`]): sequence
    /// number of the last write-ahead-logged batch, the log itself, and
    /// the snapshot store. All `None`/0 until persistence is attached.
    wal_seq: u64,
    wal: Option<DeltaWal>,
    store: Option<CheckpointStore>,
}

impl ServeSession {
    /// Stand up serving from a finished fit: compress the fitted dataset
    /// to a weighted coreset (serial ++ representatives weighted by one
    /// kernel pass — the mapper-side recipe) and publish the fit's
    /// medoids as epoch 1.
    pub fn from_fit(
        session: &ClusterSession,
        data: &DatasetHandle,
        outcome: &ClusterOutcome,
        metric: Metric,
        cfg: ServeConfig,
    ) -> anyhow::Result<ServeSession> {
        let points = session.dataset_points(data);
        let k = outcome.medoids.len();
        anyhow::ensure!(k >= 1, "cannot serve a fit with no medoids");
        let n = points.len();
        let backend = session.backend();
        let seed = session.seed();
        let target = cfg.coreset_size.unwrap_or_else(|| default_coreset_size(k, n)).max(k).min(n);
        let mut rng = Rng::new(seed ^ 0x5E4E);
        let (reps, _) = plus_plus_serial(&points, target, &mut rng, metric);
        let (labels, _, _) = min_dists_chunked(backend.as_ref(), &points, &reps, metric);
        let mut weights = vec![0f64; reps.len()];
        for &l in &labels {
            weights[l as usize] += 1.0;
        }
        ServeSession::from_coreset(
            backend,
            metric,
            seed,
            cfg,
            outcome.medoids.clone(),
            reps,
            weights,
        )
    }

    /// Stand up serving from an explicit weighted coreset (what the fit
    /// pipeline or a checkpoint already has). `medoids` become epoch 1.
    pub fn from_coreset(
        backend: Arc<dyn ComputeBackend>,
        metric: Metric,
        seed: u64,
        cfg: ServeConfig,
        medoids: Vec<Point>,
        reps: Vec<Point>,
        weights: Vec<f64>,
    ) -> anyhow::Result<ServeSession> {
        ServeSession::build(backend, metric, seed, cfg, medoids, reps, weights, 1)
    }

    #[allow(clippy::too_many_arguments)]
    fn build(
        backend: Arc<dyn ComputeBackend>,
        metric: Metric,
        seed: u64,
        cfg: ServeConfig,
        medoids: Vec<Point>,
        reps: Vec<Point>,
        weights: Vec<f64>,
        first_epoch: u64,
    ) -> anyhow::Result<ServeSession> {
        anyhow::ensure!(!reps.is_empty(), "serving needs a non-empty coreset");
        anyhow::ensure!(reps.len() == weights.len(), "reps/weights length mismatch");
        let k = medoids.len();
        let target = cfg.coreset_size.unwrap_or(reps.len()).max(k).max(1);
        let model = ClusterModel::new(backend.clone(), medoids, metric);
        let handle = Arc::new(ModelHandle::new_at(model, first_epoch));
        Ok(ServeSession {
            backend,
            metric,
            k,
            seed,
            cfg: ServeConfig { batch_size: cfg.batch_size.max(1), ..cfg },
            handle,
            reps,
            weights,
            target,
            buffer: Vec::new(),
            observers: ObserverHub::default(),
            updates: 0,
            dist_evals: 0,
            last: None,
            wal_seq: 0,
            wal: None,
            store: None,
        })
    }

    /// Rebuild a serve session from the durable state in `dir`: load the
    /// newest good checkpoint, republish its medoids under the
    /// checkpointed epoch (readers see the epoch sequence continue, not
    /// restart), then replay write-ahead-logged delta batches the
    /// checkpoint does not cover (`seq > wal_seq`) through the normal
    /// ingest path — any flushes they trigger republish exactly the
    /// epochs the crashed session published. Finally persistence is
    /// re-attached (fresh snapshot, then WAL truncate), so the restored
    /// session is immediately durable again.
    ///
    /// Pass the same `cfg` the crashed session ran with; in particular an
    /// explicit [`ServeConfig::coreset_size`] keeps the recompression
    /// threshold — and therefore the replayed epochs — byte-identical.
    pub fn restore(
        backend: Arc<dyn ComputeBackend>,
        cfg: ServeConfig,
        dir: impl AsRef<Path>,
    ) -> anyhow::Result<ServeSession> {
        let dir = dir.as_ref();
        let store = CheckpointStore::open(dir)?;
        let (_, ck) = store.latest()?;
        anyhow::ensure!(
            ck.algorithm == SERVE_EVENT_NAME,
            "checkpoint in {} is a {:?} fit snapshot, not a serve snapshot",
            dir.display(),
            ck.algorithm
        );
        let (reps, weights) = ck
            .coreset
            .clone()
            .ok_or_else(|| anyhow::anyhow!("serve checkpoint carries no coreset pool"))?;
        let mut serve = ServeSession::build(
            backend,
            ck.metric,
            ck.seed(),
            cfg,
            ck.medoids.clone(),
            reps,
            weights,
            ck.epoch,
        )?;
        serve.updates = ck.iteration as usize;
        serve.dist_evals = ck.dist_evals;
        serve.buffer = ck.pending.clone();
        serve.wal_seq = ck.wal_seq;
        for rec in DeltaWal::replay(&dir.join(WAL_FILE))? {
            if rec.seq <= ck.wal_seq {
                continue; // already folded into the checkpoint
            }
            serve.wal_seq = serve.wal_seq.max(rec.seq);
            serve.ingest(&rec.deltas)?; // persistence not attached: in-memory replay
        }
        serve.attach_persistence(dir)?;
        Ok(serve)
    }

    /// Make this session durable in `dir` (created if needed): from now
    /// on every [`ingest`](ServeSession::ingest) write-ahead-logs its
    /// batch (CRC-framed, `fdatasync`ed) *before* touching in-memory
    /// state, and every flush writes an atomic [`Checkpoint`] snapshot
    /// and then truncates the log. Attaching immediately writes a
    /// snapshot of the current state, so [`ServeSession::restore`] works
    /// from this instant onward.
    pub fn attach_persistence(&mut self, dir: impl AsRef<Path>) -> anyhow::Result<()> {
        let dir = dir.as_ref();
        self.store = Some(CheckpointStore::open(dir)?);
        self.wal = Some(DeltaWal::open(dir.join(WAL_FILE))?);
        self.persist_snapshot()
    }

    /// Whether [`attach_persistence`](ServeSession::attach_persistence)
    /// is active.
    pub fn is_durable(&self) -> bool {
        self.store.is_some()
    }

    /// The full durable state of this instant as a [`Checkpoint`].
    fn checkpoint(&self) -> Checkpoint {
        let model = self.handle.load();
        Checkpoint {
            algorithm: SERVE_EVENT_NAME.to_string(),
            metric: self.metric,
            dims: model.dims() as u8,
            k: self.k as u32,
            iteration: self.updates as u64,
            sim_seconds: 0.0,
            rng: [self.seed, 0, 0, 0],
            converged: false,
            cost: self.last.map(|r| r.cost_after).unwrap_or(0.0),
            dist_evals: self.dist_evals,
            epoch: model.epoch(),
            wal_seq: self.wal_seq,
            medoids: model.medoids().to_vec(),
            coreset: Some((self.reps.clone(), self.weights.clone())),
            pending: self.buffer.clone(),
        }
    }

    /// Checkpoint-then-truncate: the snapshot is durable on disk before
    /// the WAL records it covers are dropped. A crash between the two
    /// steps only leaves already-covered records behind, and replay
    /// skips `seq <= wal_seq` — a batch can never be applied twice.
    fn persist_snapshot(&mut self) -> anyhow::Result<()> {
        let ck = self.checkpoint();
        if let Some(store) = &self.store {
            store.save(&ck)?;
        }
        if let Some(wal) = &mut self.wal {
            wal.reset()?;
        }
        Ok(())
    }

    /// The shared slot readers load snapshots from (clone freely across
    /// threads).
    pub fn handle(&self) -> Arc<ModelHandle> {
        self.handle.clone()
    }
    /// Current snapshot (shorthand for `handle().load()`).
    pub fn model(&self) -> Arc<ClusterModel> {
        self.handle.load()
    }
    /// Register an observer for subsequent update events.
    pub fn add_observer(&mut self, observer: Box<dyn IterationObserver>) {
        self.observers.add(observer);
    }
    /// Deltas buffered but not yet flushed into a refit.
    pub fn pending(&self) -> usize {
        self.buffer.len()
    }
    pub fn coreset_len(&self) -> usize {
        self.reps.len()
    }
    /// Total mass carried by the coreset (original points + deltas).
    pub fn coreset_weight(&self) -> f64 {
        self.weights.iter().sum()
    }
    /// Mini-batches flushed so far.
    pub fn updates(&self) -> usize {
        self.updates
    }
    pub fn k(&self) -> usize {
        self.k
    }
    /// Report of the most recent flush, if any.
    pub fn last_update(&self) -> Option<UpdateReport> {
        self.last
    }

    /// Buffer delta points; every full mini-batch triggers fold →
    /// recompress → refine → epoch swap. Returns how many epochs were
    /// published by this call.
    ///
    /// Invalid deltas (wrong dims, NaN/infinite coordinates) are refused
    /// with a typed [`IngestError`] before any state is touched. With
    /// persistence attached, the whole batch is write-ahead logged and
    /// synced before the buffer moves, so a crash at any later instant
    /// replays it.
    pub fn ingest(&mut self, deltas: &[Point]) -> anyhow::Result<usize> {
        let dims = self.model().dims();
        for (i, p) in deltas.iter().enumerate() {
            if p.dims() != dims {
                let e = IngestError::DimsMismatch { index: i, expected: dims, got: p.dims() };
                return Err(e.into());
            }
            if let Some(c) = p.coords().iter().copied().find(|c| !c.is_finite()) {
                return Err(IngestError::NonFinite { index: i, value: c }.into());
            }
        }
        if let Some(wal) = &mut self.wal {
            self.wal_seq += 1;
            wal.append(self.wal_seq, deltas)?;
        }
        self.buffer.extend_from_slice(deltas);
        let mut flushed = 0usize;
        while self.buffer.len() >= self.cfg.batch_size {
            let batch: Vec<Point> = self.buffer.drain(..self.cfg.batch_size).collect();
            self.flush_batch(batch)?;
            flushed += 1;
        }
        Ok(flushed)
    }

    /// Force-flush a partial mini-batch (e.g. at shutdown). Returns
    /// whether a new epoch was published.
    pub fn flush(&mut self) -> anyhow::Result<bool> {
        if self.buffer.is_empty() {
            return Ok(false);
        }
        let batch = std::mem::take(&mut self.buffer);
        self.flush_batch(batch)?;
        Ok(true)
    }

    fn flush_batch(&mut self, batch: Vec<Point>) -> anyhow::Result<()> {
        self.updates += 1;
        let batch_len = batch.len();

        // Fold: every delta enters as a unit-weight representative.
        self.reps.extend_from_slice(&batch);
        self.weights.resize(self.reps.len(), 1.0);

        // Recompress once the pool exceeds twice the budget — the merge
        // reducer's recipe: weighted ++ draw of `target` representatives,
        // then one kernel pass re-weights them by captured mass.
        if self.reps.len() > 2 * self.target {
            let mut rng = Rng::new(self.seed ^ 0x5ED ^ self.updates as u64);
            let new_reps = recluster_candidates(
                &self.reps,
                &self.weights,
                self.target,
                &self.reps,
                &mut rng,
                self.metric,
            );
            let (labels, _, assign_evals) =
                min_dists_chunked(self.backend.as_ref(), &self.reps, &new_reps, self.metric);
            self.dist_evals +=
                (self.target as u64) * self.reps.len() as u64 + assign_evals;
            let mut new_ws = vec![0f64; new_reps.len()];
            for (i, &l) in labels.iter().enumerate() {
                new_ws[l as usize] += self.weights[i];
            }
            self.reps = new_reps;
            self.weights = new_ws;
        }

        // Refine from the current snapshot's medoids. The incumbent stays
        // a candidate in every update step, so the assign/update chain —
        // and therefore cost_after vs cost_before — is non-increasing.
        let current = self.handle.load();
        let mut medoids = current.medoids().to_vec();
        let weights_f32: Vec<f32> = self.weights.iter().map(|&w| w as f32).collect();
        let mut cost_before = f64::NAN;
        for it in 0..self.cfg.refine_iters.max(1) {
            let step = weighted_refine_step(
                self.backend.as_ref(),
                &self.reps,
                &weights_f32,
                &medoids,
                self.metric,
                true,
            )?;
            self.dist_evals += step.dist_evals;
            if it == 0 {
                cost_before = step.cost;
            }
            medoids = step.medoids;
        }
        let coreset = Weighted::new(self.reps.as_slice(), &weights_f32);
        let fin = assign_weighted(self.backend.as_ref(), &coreset, &medoids, self.metric)?;
        self.dist_evals += fin.dist_evals;
        let cost_after: f64 = fin.cluster_cost.iter().sum();
        let drift: f64 = medoids
            .iter()
            .zip(current.medoids())
            .map(|(a, b)| self.metric.displacement(a, b))
            .sum();

        // Epoch swap: readers keep answering from the old snapshot until
        // the atomic pointer store, then see the refined one.
        let epoch = self
            .handle
            .publish(ClusterModel::new(self.backend.clone(), medoids, self.metric));
        self.last = Some(UpdateReport {
            epoch,
            batch: batch_len,
            cost_before,
            cost_after,
            medoid_drift: drift,
            coreset_len: self.reps.len(),
        });
        self.observers.iteration(&IterationEvent {
            algorithm: SERVE_EVENT_NAME,
            iteration: self.updates,
            cost: cost_after,
            medoid_drift: drift,
            sim_seconds: 0.0, // serving runs off the simulated cluster
            dist_evals: self.dist_evals,
        });
        if self.store.is_some() {
            self.persist_snapshot()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::observe::IterationLog;
    use crate::clustering::UpdateStrategy;
    use crate::driver::{Algorithm, Experiment};
    use crate::geo::datasets::{generate, SpatialSpec};
    use crate::session::ClusterSession;

    /// Fit the coreset pipeline (with labels) on a small planted dataset
    /// and stand up serving from it.
    fn serve_fixture(
        seed: u64,
        cfg: ServeConfig,
    ) -> (ServeSession, ClusterOutcome, Vec<Point>) {
        let mut spec = SpatialSpec::new(1500, 3, seed);
        spec.outlier_frac = 0.0;
        let dataset = generate(&spec);
        let mut session = ClusterSession::builder().test(4).seed(seed).build().unwrap();
        let data = session.ingest("pts", &dataset);
        let mut exp = Experiment::paper_cell(Algorithm::KMedoidsCoresetMR, 4, 0, seed);
        exp.spec = spec.clone();
        exp.k = 3;
        exp.update = UpdateStrategy::Exact;
        exp.with_quality = true;
        let out = exp.clusterer().fit(&mut session, &data).unwrap();
        let serve =
            ServeSession::from_fit(&session, &data, &out, Metric::SqEuclidean, cfg).unwrap();
        (serve, out, dataset.points)
    }

    fn jittered(points: &[Point], rng: &mut Rng, n: usize, dx: f32, dy: f32) -> Vec<Point> {
        (0..n)
            .map(|_| {
                let p = points[rng.below(points.len())];
                Point::new(p.x() + dx, p.y() + dy)
            })
            .collect()
    }

    #[test]
    fn serve_assign_is_byte_identical_to_fit_label_pass() {
        let (serve, out, points) = serve_fixture(41, ServeConfig::default());
        let model = serve.model();
        let (labels, _) = model.assign_batch(points.as_slice());
        assert_eq!(Some(labels), out.labels, "serve labels must match the batch label pass");
    }

    #[test]
    fn partial_batches_buffer_without_publishing() {
        let cfg = ServeConfig { batch_size: 100, ..ServeConfig::default() };
        let (mut serve, _, points) = serve_fixture(43, cfg);
        assert_eq!(serve.model().epoch(), 1);
        let flushed = serve.ingest(&points[..60]).unwrap();
        assert_eq!(flushed, 0);
        assert_eq!(serve.pending(), 60);
        assert_eq!(serve.model().epoch(), 1, "no epoch swap before a full mini-batch");
        assert!(serve.last_update().is_none());
        // Force-flush publishes the partial batch.
        assert!(serve.flush().unwrap());
        assert_eq!(serve.pending(), 0);
        assert_eq!(serve.model().epoch(), 2);
        assert_eq!(serve.last_update().unwrap().batch, 60);
    }

    #[test]
    fn ingest_then_refine_never_increases_weighted_coreset_cost() {
        let cfg = ServeConfig { batch_size: 128, ..ServeConfig::default() };
        let (mut serve, _, points) = serve_fixture(47, cfg);
        let mut rng = Rng::new(47);
        for round in 0..4 {
            let deltas = jittered(&points, &mut rng, 128, 300.0 * round as f32, 0.0);
            let flushed = serve.ingest(&deltas).unwrap();
            assert_eq!(flushed, 1);
            let rep = serve.last_update().unwrap();
            assert_eq!(rep.epoch, 2 + round as u64);
            assert!(
                rep.cost_after <= rep.cost_before * (1.0 + 1e-6),
                "round {round}: cost {} -> {}",
                rep.cost_before,
                rep.cost_after
            );
            assert!(rep.medoid_drift.is_finite());
            assert_eq!(serve.model().epoch(), rep.epoch);
        }
        assert_eq!(serve.updates(), 4);
    }

    #[test]
    fn coreset_recompression_bounds_the_pool() {
        let cfg =
            ServeConfig { batch_size: 64, coreset_size: Some(40), ..ServeConfig::default() };
        let (mut serve, _, points) = serve_fixture(53, cfg);
        let mut rng = Rng::new(53);
        let mass0 = serve.coreset_weight();
        for _ in 0..6 {
            let deltas = jittered(&points, &mut rng, 64, 50.0, -50.0);
            serve.ingest(&deltas).unwrap();
            assert!(
                serve.coreset_len() <= 2 * 40 + 64,
                "pool {} exceeded fold+budget bound",
                serve.coreset_len()
            );
        }
        // Recompression preserves total mass: original points + deltas.
        let mass = serve.coreset_weight();
        assert!(
            (mass - (mass0 + 6.0 * 64.0)).abs() < 1e-6 * mass,
            "coreset mass {mass} vs expected {}",
            mass0 + 6.0 * 64.0
        );
    }

    #[test]
    fn updates_are_deterministic_in_the_seed() {
        let run = || {
            let cfg = ServeConfig { batch_size: 96, ..ServeConfig::default() };
            let (mut serve, _, points) = serve_fixture(59, cfg);
            let mut rng = Rng::new(59);
            let deltas = jittered(&points, &mut rng, 3 * 96, 120.0, 80.0);
            serve.ingest(&deltas).unwrap();
            let m = serve.model();
            (m.epoch(), m.medoids().to_vec(), serve.last_update().unwrap().cost_after)
        };
        let a = run();
        let b = run();
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
        assert_eq!(a.2, b.2);
    }

    #[test]
    fn drifted_deltas_pull_medoids_toward_the_new_mass() {
        // Stream many deltas shifted far from the fitted data; after a
        // few mini-batches at least one medoid must follow the drift.
        let cfg = ServeConfig { batch_size: 200, refine_iters: 3, ..ServeConfig::default() };
        let (mut serve, _, points) = serve_fixture(61, cfg);
        let shift = 5.0e4f32;
        let near_shift = |ms: &[Point]| {
            ms.iter().map(|m| (m.x() - shift).abs()).fold(f32::INFINITY, f32::min)
        };
        let before = near_shift(serve.model().medoids());
        let mut rng = Rng::new(61);
        for _ in 0..5 {
            let deltas = jittered(&points, &mut rng, 200, shift, 0.0);
            serve.ingest(&deltas).unwrap();
        }
        let after = near_shift(serve.model().medoids());
        assert!(
            after < before / 2.0,
            "medoids did not follow the drift: nearest |x - shift| {before} -> {after}"
        );
    }

    #[test]
    fn events_stream_one_per_flush() {
        let cfg = ServeConfig { batch_size: 80, ..ServeConfig::default() };
        let (mut serve, _, points) = serve_fixture(67, cfg);
        let log = IterationLog::new();
        serve.add_observer(Box::new(log.clone()));
        let mut rng = Rng::new(67);
        let deltas = jittered(&points, &mut rng, 2 * 80 + 10, 10.0, 10.0);
        let flushed = serve.ingest(&deltas).unwrap();
        assert_eq!(flushed, 2);
        let events = log.events();
        assert_eq!(events.len(), 2);
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.algorithm, SERVE_EVENT_NAME);
            assert_eq!(e.iteration, i + 1);
            assert!(e.cost > 0.0 && e.medoid_drift.is_finite());
            assert_eq!(e.sim_seconds, 0.0, "serving is off the simulated clock");
        }
        assert!(events[1].dist_evals > events[0].dist_evals, "eval accounting is cumulative");
    }

    #[test]
    fn mismatched_delta_dims_rejected() {
        let (mut serve, _, _) = serve_fixture(71, ServeConfig::default());
        let err = serve.ingest(&[Point::from_slice(&[1.0, 2.0, 3.0])]).unwrap_err();
        assert!(err.to_string().contains("dims"), "unexpected error: {err:#}");
        assert!(
            matches!(
                err.downcast_ref::<IngestError>(),
                Some(IngestError::DimsMismatch { index: 0, expected: 2, got: 3 })
            ),
            "{err:#}"
        );
    }

    #[test]
    fn non_finite_deltas_rejected_before_any_state_moves() {
        let (mut serve, _, _) = serve_fixture(73, ServeConfig::default());
        let pending = serve.pending();
        let epoch = serve.model().epoch();
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let err = serve.ingest(&[Point::new(1.0, 1.0), Point::new(bad, 0.0)]).unwrap_err();
            assert!(
                matches!(
                    err.downcast_ref::<IngestError>(),
                    Some(IngestError::NonFinite { index: 1, .. })
                ),
                "{err:#}"
            );
        }
        assert_eq!(serve.pending(), pending, "rejected batch must not buffer");
        assert_eq!(serve.model().epoch(), epoch);
    }

    #[test]
    fn restore_reconstructs_the_published_epoch() {
        use crate::runtime::NativeBackend;
        use crate::util::tempdir::TempDir;

        let tmp = TempDir::new("serve-restore");
        let cfg =
            ServeConfig { batch_size: 64, coreset_size: Some(48), ..ServeConfig::default() };
        let (mut serve, _, points) = serve_fixture(79, cfg);
        serve.attach_persistence(tmp.path()).unwrap();
        assert!(serve.is_durable());
        let mut rng = Rng::new(79);
        // Two full mini-batches (each flush checkpoints) plus a partial
        // tail that survives only through the checkpointed pending buffer.
        let deltas = jittered(&points, &mut rng, 2 * 64 + 20, 30.0, -10.0);
        assert_eq!(serve.ingest(&deltas).unwrap(), 2);

        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(256, 16));
        let mut restored = ServeSession::restore(backend, cfg, tmp.path()).unwrap();
        let live = serve.model();
        let back = restored.model();
        assert_eq!(back.epoch(), live.epoch(), "epoch sequence must continue, not restart");
        assert_eq!(back.medoids(), live.medoids(), "medoids must restore bitwise");
        assert_eq!(restored.pending(), serve.pending());
        assert_eq!(restored.coreset_len(), serve.coreset_len());
        assert_eq!(restored.updates(), serve.updates());

        // The restored session continues byte-identically: same deltas in,
        // same epochs and medoids out.
        let more = jittered(&points, &mut rng, 2 * 64, -20.0, 40.0);
        assert_eq!(serve.ingest(&more).unwrap(), restored.ingest(&more).unwrap());
        assert_eq!(serve.model().epoch(), restored.model().epoch());
        assert_eq!(serve.model().medoids(), restored.model().medoids());
    }
}
