//! Cluster topology + job configuration.
//!
//! `paper_cluster()` reconstructs Table 3 of the paper: seven VMware nodes
//! on three physical hosts with heterogeneous CPUs. Speed factors are
//! normalized PassMark-style single-core ratios for the three CPUs (the
//! *relative* ordering is what shapes the speedup curves, see DESIGN.md
//! substitution table).

use crate::util::json::{obj, Json};

/// One simulated cluster node (a VMware VM in the paper).
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: String,
    /// Physical host the VM runs on; transfers between nodes on the same
    /// host are faster than cross-host transfers.
    pub host: usize,
    /// Cores visible to the VM (drives CPU speed only; task slots follow
    /// the Hadoop-1.x defaults below).
    pub cores: usize,
    /// Relative single-core speed (1.0 = Intel i5-3210M reference).
    pub speed: f64,
    /// RAM in GB (bounds in-memory shuffle; low-RAM nodes spill earlier).
    pub ram_gb: f64,
}

/// Full cluster description.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub nodes: Vec<NodeSpec>,
    /// Index of the master (NameNode/JobTracker/HMaster) node. The master
    /// also runs tasks in the paper's 4–7 node groups (it is counted as a
    /// cluster member in Table 4).
    pub master: usize,
    pub net: NetConfig,
    /// DFS block size in bytes (Hadoop default 64 MB in the paper's era).
    pub dfs_block_bytes: u64,
    pub dfs_replication: usize,
}

#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Same-host VM-to-VM bandwidth (virtio bridge), MB/s.
    pub intra_host_mb_s: f64,
    /// Cross-host bandwidth (100 Mb Ethernet era commodity), MB/s.
    pub inter_host_mb_s: f64,
    /// Per-transfer latency, seconds.
    pub latency_s: f64,
}

impl Default for NetConfig {
    fn default() -> Self {
        // 1 GbE between hosts (~110 MB/s effective), faster virtio locally.
        NetConfig { intra_host_mb_s: 400.0, inter_host_mb_s: 110.0, latency_s: 0.5e-3 }
    }
}

impl NodeSpec {
    /// Hadoop-1.x default `mapred.tasktracker.map.tasks.maximum` = 2,
    /// independent of core count (the era the paper's cluster ran).
    pub fn map_slots(&self) -> usize {
        2
    }
    /// Hadoop-1.x default `mapred.tasktracker.reduce.tasks.maximum` = 2.
    pub fn reduce_slots(&self) -> usize {
        2
    }
}

impl ClusterConfig {
    /// Table 3: Master (Intel i5-3210M, 4 cores, 8 GB) on Host1;
    /// Slave01–02 (AMD A8-5600K, 2 cores, 8 GB) on Host2;
    /// Slave03–06 (Intel E7500, 2 cores, 2 GB) on Host3.
    ///
    /// Speed factors ≈ single-thread performance relative to the i5-3210M:
    /// A8-5600K ≈ 0.85, E7500 ≈ 0.62 (era benchmark ratios).
    pub fn paper_cluster() -> ClusterConfig {
        let mut nodes = vec![NodeSpec {
            name: "master".into(),
            host: 0,
            cores: 4,
            speed: 1.0,
            ram_gb: 8.0,
        }];
        for i in 1..=2 {
            nodes.push(NodeSpec {
                name: format!("slave{i:02}"),
                host: 1,
                cores: 2,
                speed: 0.85,
                ram_gb: 8.0,
            });
        }
        for i in 3..=6 {
            nodes.push(NodeSpec {
                name: format!("slave{i:02}"),
                host: 2,
                cores: 2,
                speed: 0.62,
                ram_gb: 2.0,
            });
        }
        ClusterConfig {
            nodes,
            master: 0,
            net: NetConfig::default(),
            dfs_block_bytes: 64 << 20,
            dfs_replication: 3,
        }
    }

    /// Table 4: the n-node experiment groups are prefixes of the member
    /// list (Master, Slave01, Slave02, ...).
    pub fn cluster_subset(&self, n_nodes: usize) -> ClusterConfig {
        assert!((1..=self.nodes.len()).contains(&n_nodes));
        let mut c = self.clone();
        c.nodes.truncate(n_nodes);
        c.dfs_replication = c.dfs_replication.min(n_nodes);
        c
    }

    /// A parameterizable commodity cluster for the scaling suite
    /// (`bench scale`): `n_nodes` VMs, four per physical host, with
    /// single-core speeds cycling through era-typical desktop CPUs — the
    /// heterogeneity is what makes stragglers (and thus speculative
    /// execution) realistic at every sweep size. Smaller DFS blocks than
    /// the paper cluster keep multi-wave map scheduling meaningful at
    /// bench-scale datasets.
    pub fn commodity_cluster(n_nodes: usize) -> ClusterConfig {
        assert!(n_nodes >= 1, "a cluster needs at least the master");
        let speeds = [1.0, 0.85, 0.75, 0.62];
        let nodes = (0..n_nodes)
            .map(|i| NodeSpec {
                name: if i == 0 { "master".into() } else { format!("worker{i:02}") },
                host: i / 4,
                cores: 2,
                speed: speeds[i % speeds.len()],
                ram_gb: 4.0,
            })
            .collect();
        ClusterConfig {
            nodes,
            master: 0,
            net: NetConfig::default(),
            dfs_block_bytes: 2 << 20,
            dfs_replication: 3.min(n_nodes),
        }
    }

    /// A small homogeneous cluster for unit tests.
    pub fn test_cluster(n_nodes: usize) -> ClusterConfig {
        let nodes = (0..n_nodes)
            .map(|i| NodeSpec {
                name: format!("n{i}"),
                host: i / 2,
                cores: 2,
                speed: 1.0,
                ram_gb: 4.0,
            })
            .collect();
        ClusterConfig {
            nodes,
            master: 0,
            net: NetConfig::default(),
            dfs_block_bytes: 8 << 20,
            dfs_replication: 2.min(n_nodes),
        }
    }

    pub fn total_map_slots(&self) -> usize {
        self.nodes.iter().map(|n| n.map_slots()).sum()
    }

    /// Aggregate compute capacity (Σ cores·speed), the denominator of the
    /// ideal linear-speedup line.
    pub fn total_capacity(&self) -> f64 {
        self.nodes.iter().map(|n| n.cores as f64 * n.speed).sum()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("master", Json::Num(self.master as f64)),
            ("dfs_block_bytes", Json::Num(self.dfs_block_bytes as f64)),
            ("dfs_replication", Json::Num(self.dfs_replication as f64)),
            (
                "nodes",
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|n| {
                            obj(vec![
                                ("name", Json::Str(n.name.clone())),
                                ("host", Json::Num(n.host as f64)),
                                ("cores", Json::Num(n.cores as f64)),
                                ("speed", Json::Num(n.speed)),
                                ("ram_gb", Json::Num(n.ram_gb)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Option<ClusterConfig> {
        let nodes = j
            .get("nodes")?
            .as_arr()?
            .iter()
            .map(|n| {
                Some(NodeSpec {
                    name: n.get("name")?.as_str()?.to_string(),
                    host: n.get("host")?.as_usize()?,
                    cores: n.get("cores")?.as_usize()?,
                    speed: n.get("speed")?.as_f64()?,
                    ram_gb: n.get("ram_gb")?.as_f64()?,
                })
            })
            .collect::<Option<Vec<_>>>()?;
        Some(ClusterConfig {
            nodes,
            master: j.get("master")?.as_usize()?,
            net: NetConfig::default(),
            dfs_block_bytes: j.get("dfs_block_bytes")?.as_u64()?,
            dfs_replication: j.get("dfs_replication")?.as_usize()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_matches_table3() {
        let c = ClusterConfig::paper_cluster();
        assert_eq!(c.nodes.len(), 7);
        assert_eq!(c.nodes[0].cores, 4); // i5-3210M
        assert_eq!(c.nodes[1].host, 1);
        assert_eq!(c.nodes[3].host, 2);
        assert_eq!(c.nodes[6].ram_gb, 2.0); // E7500 tier
        assert!(c.nodes[0].speed > c.nodes[1].speed);
        assert!(c.nodes[1].speed > c.nodes[3].speed);
    }

    #[test]
    fn subsets_match_table4() {
        let c = ClusterConfig::paper_cluster();
        for n in 4..=7 {
            let s = c.cluster_subset(n);
            assert_eq!(s.nodes.len(), n);
            assert_eq!(s.nodes[0].name, "master");
            assert_eq!(s.nodes[n - 1].name, format!("slave{:02}", n - 1));
        }
    }

    #[test]
    fn commodity_cluster_shapes() {
        for n in [1usize, 2, 16] {
            let c = ClusterConfig::commodity_cluster(n);
            assert_eq!(c.nodes.len(), n);
            assert_eq!(c.master, 0);
            assert!(c.dfs_replication <= n);
            assert!(c.nodes.iter().all(|nd| nd.speed > 0.0));
        }
        let c = ClusterConfig::commodity_cluster(16);
        // Four nodes per host; heterogeneous speeds cycle.
        assert_eq!(c.nodes[3].host, 0);
        assert_eq!(c.nodes[4].host, 1);
        assert_eq!(c.nodes[15].host, 3);
        assert!(c.nodes.iter().any(|nd| nd.speed < 1.0));
        // Capacity grows monotonically through the sweep sizes.
        let caps: Vec<f64> = [1, 2, 4, 8, 16]
            .iter()
            .map(|&n| ClusterConfig::commodity_cluster(n).total_capacity())
            .collect();
        assert!(caps.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn capacity_monotone_in_nodes() {
        let c = ClusterConfig::paper_cluster();
        let caps: Vec<f64> = (4..=7).map(|n| c.cluster_subset(n).total_capacity()).collect();
        assert!(caps.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn json_roundtrip() {
        let c = ClusterConfig::paper_cluster();
        let j = c.to_json();
        let c2 = ClusterConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(c2.nodes.len(), c.nodes.len());
        assert_eq!(c2.nodes[3].name, c.nodes[3].name);
        assert_eq!(c2.dfs_block_bytes, c.dfs_block_bytes);
    }
}
