//! One-stop re-exports for the session-oriented API:
//! `use kmedoids_mr::prelude::*;`

pub use crate::clustering::api::{
    Clarans, ClaransBuilder, KMeans, KMeansBuilder, KMedoids, KMedoidsBuilder, SpatialClusterer,
};
pub use crate::clustering::observe::{
    FitCheckpoint, IterationEvent, IterationLog, IterationObserver, ObserverHub, StderrProgress,
};
pub use crate::clustering::{ClusterOutcome, FitResume, Init, IterParams, UpdateStrategy};
pub use crate::config::ClusterConfig;
pub use crate::driver::{run_experiment, Algorithm, Experiment, ExperimentResult};
pub use crate::geo::datasets::{generate, SpatialDataset, SpatialSpec};
pub use crate::geo::{Metric, Point};
pub use crate::mapreduce::{ExecConfig, ExecutionBackend, Lane};
pub use crate::persist::{Checkpoint, CheckpointSink, CheckpointStore, DeltaWal, PersistError};
pub use crate::runtime::{
    load_backend, BackendKind, ComputeBackend, NativeBackend, PrunedAssigner, PruningMode,
};
pub use crate::serve::{
    ClusterModel, IngestError, ModelHandle, ServeConfig, ServeSession, UpdateReport,
};
pub use crate::session::{ClusterSession, DatasetHandle, SessionBuilder};
pub use crate::sim::FaultPlan;
