//! Run-spec JSON: (de)serialize [`Experiment`] cells so any grid cell —
//! or a whole grid — can be driven from a file:
//!
//! ```text
//! kmedoids-mr run --spec cells.json
//! ```
//!
//! A spec file is either one cell object or an array of them. Every
//! field except the dataset has a default (`algorithm` defaults to the
//! paper's `kmedoids++-mr`, `nodes` to 7, `k` to 9, `seed` to 42,
//! `update` to the paper-scale sampled-adaptive strategy):
//!
//! ```text
//! {
//!   "algorithm": "kmedoids++-mr",
//!   "nodes": 7,
//!   "k": 9,
//!   "seed": 42,
//!   "with_quality": false,
//!   "fixed_iters": 6,
//!   "update": {"kind": "sampled_adaptive",
//!              "candidates": 256, "frac_div": 4, "min_sample": 16384},
//!   "dataset": {"n_points": 100000, "n_hotspots": 9, "seed": 42}
//! }
//! ```
//!
//! The dataset block also accepts the paper's Table 5 shorthand:
//! `{"paper_dataset": 0, "scale_div": 100}`.

use super::suites::{ScaleOpts, ServeOpts};
use super::{Algorithm, Experiment};
use crate::clustering::{PruningMode, UpdateStrategy};
use crate::geo::datasets::SpatialSpec;
use crate::geo::{Metric, MAX_DIMS};
use crate::mapreduce::Lane;
use crate::util::json::{obj, Json};
use anyhow::{bail, Context, Result};

// ---- typed errors -----------------------------------------------------------

/// Typed spec-parse error: every variant names the offending key (dotted
/// path, e.g. `"update.candidates"`), so tooling can react to *which*
/// field broke instead of grepping message text. Carried through
/// `anyhow` — recover it with `err.downcast_ref::<SpecError>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpecError {
    /// A required key is absent. `hint` (may be empty) suggests the fix.
    MissingKey { key: String, hint: String },
    /// A key is present that its context does not accept (typo guard).
    UnknownKey { key: String, context: String },
    /// A key is present but its value is out of domain.
    BadValue { key: String, message: String },
}

impl SpecError {
    /// The offending spec key.
    pub fn key(&self) -> &str {
        match self {
            SpecError::MissingKey { key, .. }
            | SpecError::UnknownKey { key, .. }
            | SpecError::BadValue { key, .. } => key,
        }
    }
    fn missing(key: impl Into<String>) -> SpecError {
        SpecError::MissingKey { key: key.into(), hint: String::new() }
    }
    fn bad(key: impl Into<String>, message: impl Into<String>) -> SpecError {
        SpecError::BadValue { key: key.into(), message: message.into() }
    }
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::MissingKey { key, hint } if hint.is_empty() => write!(f, "{key} missing"),
            SpecError::MissingKey { key, hint } => write!(f, "{key} missing ({hint})"),
            SpecError::UnknownKey { key, context } => write!(f, "unknown key {key:?} in {context}"),
            SpecError::BadValue { key, message } => write!(f, "{key} {message}"),
        }
    }
}

impl std::error::Error for SpecError {}

// ---- numeric decoding -------------------------------------------------------
// `Json::as_usize`/`as_u64` are saturating f64 casts (-5 → 0), which would
// silently accept nonsense; spec fields go through checked decoders instead.

/// A strictly positive integer (counts: points, k, nodes, samples, ...).
fn as_pos_usize(v: &Json, what: &str) -> Result<usize> {
    let f = v.as_f64().ok_or_else(|| SpecError::bad(what, "must be a number"))?;
    if !(f >= 1.0) || f.fract() != 0.0 || f > 9e15 {
        bail!(SpecError::bad(what, format!("must be a positive integer, got {f}")));
    }
    Ok(f as usize)
}

/// A non-negative integer (indices, seeds).
fn as_nonneg_u64(v: &Json, what: &str) -> Result<u64> {
    let f = v.as_f64().ok_or_else(|| SpecError::bad(what, "must be a number"))?;
    if !(f >= 0.0) || f.fract() != 0.0 || f > 9e15 {
        bail!(SpecError::bad(what, format!("must be a non-negative integer, got {f}")));
    }
    Ok(f as u64)
}

/// Reject unknown keys so a typo'd field (`"node"` for `"nodes"`) errors
/// instead of silently running with the default — the same rule the CLI
/// flag parser enforces.
fn check_known_keys(j: &Json, what: &str, allowed: &[&str]) -> Result<()> {
    let obj = j.as_obj().with_context(|| format!("{what} must be a JSON object"))?;
    for key in obj.keys() {
        if !allowed.contains(&key.as_str()) {
            bail!(SpecError::UnknownKey {
                key: key.clone(),
                context: format!("{what} (allowed: {})", allowed.join(", ")),
            });
        }
    }
    Ok(())
}

// ---- UpdateStrategy ---------------------------------------------------------

pub fn update_to_json(u: &UpdateStrategy) -> Json {
    match u {
        UpdateStrategy::Exact => obj(vec![("kind", Json::Str("exact".into()))]),
        UpdateStrategy::Sampled { candidates, member_sample } => obj(vec![
            ("kind", Json::Str("sampled".into())),
            ("candidates", Json::Num(*candidates as f64)),
            ("member_sample", Json::Num(*member_sample as f64)),
        ]),
        UpdateStrategy::SampledAdaptive { candidates, frac_div, min_sample } => obj(vec![
            ("kind", Json::Str("sampled_adaptive".into())),
            ("candidates", Json::Num(*candidates as f64)),
            ("frac_div", Json::Num(*frac_div as f64)),
            ("min_sample", Json::Num(*min_sample as f64)),
        ]),
        UpdateStrategy::CentroidNearest => {
            obj(vec![("kind", Json::Str("centroid_nearest".into()))])
        }
    }
}

pub fn update_from_json(j: &Json) -> Result<UpdateStrategy> {
    let kind = j
        .get("kind")
        .and_then(|k| k.as_str())
        .ok_or_else(|| SpecError::missing("update.kind"))?;
    // Per-kind key sets: a knob the kind ignores is an error, not noise.
    let allowed: &[&str] = match kind {
        "exact" | "centroid_nearest" => &["kind"],
        "sampled" => &["kind", "candidates", "member_sample"],
        "sampled_adaptive" => &["kind", "candidates", "frac_div", "min_sample"],
        other => bail!(SpecError::bad(
            "update.kind",
            format!("unknown value {other:?} (exact|sampled|sampled_adaptive|centroid_nearest)"),
        )),
    };
    check_known_keys(j, &format!("update (kind {kind:?})"), allowed)?;
    let num = |key: &str| {
        let v = j.get(key).ok_or_else(|| SpecError::missing(format!("update.{key}")))?;
        as_pos_usize(v, &format!("update.{key}"))
    };
    Ok(match kind {
        "exact" => UpdateStrategy::Exact,
        "sampled" => UpdateStrategy::Sampled {
            candidates: num("candidates")?,
            member_sample: num("member_sample")?,
        },
        "sampled_adaptive" => UpdateStrategy::SampledAdaptive {
            candidates: num("candidates")?,
            frac_div: num("frac_div")?,
            min_sample: num("min_sample")?,
        },
        _ => UpdateStrategy::CentroidNearest,
    })
}

// ---- SpatialSpec ------------------------------------------------------------

pub fn spatial_spec_to_json(s: &SpatialSpec) -> Json {
    obj(vec![
        ("n_points", Json::Num(s.n_points as f64)),
        ("n_hotspots", Json::Num(s.n_hotspots as f64)),
        ("extent", Json::Num(s.extent as f64)),
        ("sigma_frac", Json::Num(s.sigma_frac as f64)),
        ("noise_frac", Json::Num(s.noise_frac as f64)),
        ("outlier_frac", Json::Num(s.outlier_frac as f64)),
        ("dims", Json::Num(s.dims as f64)),
        ("latlon", Json::Bool(s.latlon)),
        ("seed", Json::Num(s.seed as f64)),
    ])
}

pub fn spatial_spec_from_json(j: &Json, default_seed: u64) -> Result<SpatialSpec> {
    let seed = match j.get("seed") {
        Some(v) => as_nonneg_u64(v, "dataset.seed")?,
        None => default_seed,
    };
    if let Some(v) = j.get("paper_dataset") {
        check_known_keys(j, "dataset", &["paper_dataset", "scale_div", "seed"])?;
        let i = as_nonneg_u64(v, "dataset.paper_dataset")? as usize;
        if i > 2 {
            bail!(SpecError::bad("dataset.paper_dataset", "must be 0, 1 or 2 (Table 5)"));
        }
        let scale = match j.get("scale_div") {
            Some(v) => as_pos_usize(v, "dataset.scale_div")?,
            None => 1,
        };
        return Ok(SpatialSpec::paper_dataset_scaled(i, scale, seed));
    }
    check_known_keys(
        j,
        "dataset",
        &[
            "n_points",
            "n_hotspots",
            "seed",
            "extent",
            "sigma_frac",
            "noise_frac",
            "outlier_frac",
            "dims",
            "latlon",
        ],
    )?;
    let n_points = as_pos_usize(
        j.get("n_points").ok_or_else(|| SpecError::MissingKey {
            key: "dataset.n_points".into(),
            hint: "or use {\"paper_dataset\": 0, \"scale_div\": N}".into(),
        })?,
        "dataset.n_points",
    )?;
    let n_hotspots = match j.get("n_hotspots") {
        Some(v) => as_pos_usize(v, "dataset.n_hotspots")?,
        None => 9,
    };
    let mut s = SpatialSpec::new(n_points, n_hotspots, seed);
    let mut float_field = |key: &str, slot: &mut f32, min: f64, max: f64| -> Result<()> {
        if let Some(v) = j.get(key) {
            let f = v
                .as_f64()
                .ok_or_else(|| SpecError::bad(format!("dataset.{key}"), "must be a number"))?;
            if !(f >= min && f <= max) {
                bail!(SpecError::bad(
                    format!("dataset.{key}"),
                    format!("must be in [{min}, {max}], got {f}"),
                ));
            }
            *slot = f as f32;
        }
        Ok(())
    };
    float_field("extent", &mut s.extent, 1e-6, 1e12)?;
    float_field("sigma_frac", &mut s.sigma_frac, 1e-9, 1.0)?;
    float_field("noise_frac", &mut s.noise_frac, 0.0, 1.0)?;
    float_field("outlier_frac", &mut s.outlier_frac, 0.0, 1.0)?;
    if let Some(v) = j.get("dims") {
        let d = as_pos_usize(v, "dataset.dims")?;
        if !(2..=MAX_DIMS).contains(&d) {
            bail!(SpecError::bad("dataset.dims", format!("must be in 2..={MAX_DIMS}, got {d}")));
        }
        s.dims = d;
    }
    if let Some(v) = j.get("latlon") {
        s.latlon = v
            .as_bool()
            .ok_or_else(|| SpecError::bad("dataset.latlon", "must be true or false"))?;
    }
    if s.latlon && s.dims != 2 {
        bail!(SpecError::bad("dataset.latlon", "requires dims = 2 ((lat, lon) pairs)"));
    }
    Ok(s)
}

/// Parse a `dataset: {"file": ...}` cell: the fit ingests the named
/// file (CSV or [`crate::geo::binfmt`] binary, sniffed by magic)
/// instead of generating points. The file is summarized *now* — a
/// missing or corrupt file, or a `dims` declaration that disagrees with
/// the file's actual dimensionality, is a typed [`SpecError`] at parse
/// time, not a panic at fit time. Returns the validation-carrier
/// [`SpatialSpec`] (n_points/dims filled from the file) plus the path.
fn file_dataset_from_json(j: &Json, seed: u64) -> Result<(SpatialSpec, std::path::PathBuf)> {
    check_known_keys(j, "dataset", &["file", "dims", "latlon"])?;
    let s = j
        .get("file")
        .expect("caller checked the file key")
        .as_str()
        .ok_or_else(|| SpecError::bad("dataset.file", "must be a path string"))?;
    if s.is_empty() {
        bail!(SpecError::bad("dataset.file", "must not be empty"));
    }
    let path = std::path::PathBuf::from(s);
    let summary = crate::geo::binfmt::summarize(&path)
        .map_err(|e| SpecError::bad("dataset.file", format!("{s:?}: {e:#}")))?;
    if let Some(v) = j.get("dims") {
        let d = as_pos_usize(v, "dataset.dims")?;
        if d != summary.dims {
            bail!(SpecError::bad(
                "dataset.dims",
                format!("file {s:?} has {} dims but the cell declares {d}", summary.dims),
            ));
        }
    }
    let mut spec = SpatialSpec::new(summary.count, 9, seed);
    spec.dims = summary.dims;
    if let Some(v) = j.get("latlon") {
        spec.latlon = v
            .as_bool()
            .ok_or_else(|| SpecError::bad("dataset.latlon", "must be true or false"))?;
        if spec.latlon && spec.dims != 2 {
            bail!(SpecError::bad("dataset.latlon", "requires dims = 2 ((lat, lon) pairs)"));
        }
    }
    Ok((spec, path))
}

// ---- Experiment -------------------------------------------------------------

/// Does this algorithm honor the `update` strategy knob?
fn algorithm_uses_update(a: Algorithm) -> bool {
    matches!(
        a,
        Algorithm::KMedoidsPlusPlusMR
            | Algorithm::KMedoidsRandomMR
            | Algorithm::KMedoidsScalableMR
            | Algorithm::KMedoidsSerial
    )
}

/// Does this algorithm honor `fixed_iters` (controlled iterations)? For
/// the coreset pipeline it pins the driver-side refinement count (the MR
/// job count is constant either way).
fn algorithm_uses_fixed_iters(a: Algorithm) -> bool {
    matches!(
        a,
        Algorithm::KMedoidsPlusPlusMR
            | Algorithm::KMedoidsRandomMR
            | Algorithm::KMedoidsScalableMR
            | Algorithm::KMedoidsCoresetMR
    )
}

/// Does this algorithm honor the `oversample` (ℓ, rounds) knob?
fn algorithm_uses_oversample(a: Algorithm) -> bool {
    matches!(a, Algorithm::KMedoidsScalableMR)
}

/// Does this algorithm honor the `coreset_size` knob?
fn algorithm_uses_coreset_size(a: Algorithm) -> bool {
    matches!(a, Algorithm::KMedoidsCoresetMR)
}

/// Does this algorithm honor the `pruning` lane toggle? The serial
/// engines always run dense kernels (their eval counts are part of the
/// Fig. 5 serial baseline), so the knob would be inert there.
fn algorithm_uses_pruning(a: Algorithm) -> bool {
    matches!(
        a,
        Algorithm::KMedoidsPlusPlusMR
            | Algorithm::KMedoidsRandomMR
            | Algorithm::KMedoidsScalableMR
            | Algorithm::KMedoidsCoresetMR
            | Algorithm::KMeansMR
    )
}

/// Does this algorithm honor the execution-`lane` knob (and its
/// Hadoop-lane companion `max_attempts`)? The serial engines never
/// submit MR jobs, so a lane there would be inert — refused instead.
fn algorithm_uses_lane(a: Algorithm) -> bool {
    matches!(
        a,
        Algorithm::KMedoidsPlusPlusMR
            | Algorithm::KMedoidsRandomMR
            | Algorithm::KMedoidsScalableMR
            | Algorithm::KMedoidsCoresetMR
            | Algorithm::KMeansMR
    )
}

/// Does this algorithm emit / restore durable checkpoints
/// ([`crate::persist`])? Only the MR k-medoids drivers fire the
/// per-iteration checkpoint event, so `checkpoint_dir` / `resume` on any
/// other cell would be silently inert — refused instead.
fn algorithm_uses_checkpoints(a: Algorithm) -> bool {
    matches!(
        a,
        Algorithm::KMedoidsPlusPlusMR
            | Algorithm::KMedoidsRandomMR
            | Algorithm::KMedoidsScalableMR
            | Algorithm::KMedoidsCoresetMR
    )
}

pub fn experiment_to_json(e: &Experiment) -> Json {
    let mut pairs = vec![
        ("algorithm", Json::Str(e.algorithm.name().to_string())),
        ("nodes", Json::Num(e.n_nodes as f64)),
        ("k", Json::Num(e.k as f64)),
        ("seed", Json::Num(e.seed as f64)),
        ("metric", Json::Str(e.metric.name().to_string())),
        ("with_quality", Json::Bool(e.with_quality)),
        ("threads", Json::Num(e.threads as f64)),
        (
            "dataset",
            match &e.data_file {
                Some(p) => {
                    // File cells re-declare dims (and latlon when set) so
                    // re-parsing the emitted spec re-checks the file
                    // against what this cell saw.
                    let mut d = vec![
                        ("file", Json::Str(p.to_string_lossy().into_owned())),
                        ("dims", Json::Num(e.spec.dims as f64)),
                    ];
                    if e.spec.latlon {
                        d.push(("latlon", Json::Bool(true)));
                    }
                    obj(d)
                }
                None => spatial_spec_to_json(&e.spec),
            },
        ),
    ];
    // Only emit knobs the algorithm honors, mirroring the parse-side
    // validation (a cell never claims settings its solver would ignore).
    if algorithm_uses_update(e.algorithm) {
        pairs.push(("update", update_to_json(&e.update)));
    }
    if algorithm_uses_fixed_iters(e.algorithm) {
        pairs.push((
            "fixed_iters",
            match e.fixed_iters {
                Some(n) => Json::Num(n as f64),
                None => Json::Null,
            },
        ));
    }
    if algorithm_uses_oversample(e.algorithm) {
        pairs.push((
            "oversample",
            match e.oversample {
                Some((l, rounds)) => obj(vec![
                    ("l", Json::Num(l as f64)),
                    ("rounds", Json::Num(rounds as f64)),
                ]),
                None => Json::Null,
            },
        ));
    }
    if algorithm_uses_coreset_size(e.algorithm) {
        pairs.push((
            "coreset_size",
            match e.coreset_size {
                Some(n) => Json::Num(n as f64),
                None => Json::Null,
            },
        ));
    }
    if algorithm_uses_pruning(e.algorithm) {
        pairs.push(("pruning", Json::Str(e.pruning.name().to_string())));
    }
    if algorithm_uses_lane(e.algorithm) {
        pairs.push(("lane", Json::Str(e.lane.name().to_string())));
        pairs.push((
            "max_attempts",
            match e.max_attempts {
                Some(n) => Json::Num(n as f64),
                None => Json::Null,
            },
        ));
    }
    if algorithm_uses_checkpoints(e.algorithm) {
        pairs.push((
            "checkpoint_dir",
            match &e.checkpoint_dir {
                Some(p) => Json::Str(p.to_string_lossy().into_owned()),
                None => Json::Null,
            },
        ));
        pairs.push(("resume", Json::Bool(e.resume)));
    }
    obj(pairs)
}

pub fn experiment_from_json(j: &Json) -> Result<Experiment> {
    check_known_keys(
        j,
        "spec cell",
        &[
            "algorithm",
            "nodes",
            "k",
            "seed",
            "metric",
            "with_quality",
            "update",
            "fixed_iters",
            "oversample",
            "coreset_size",
            "pruning",
            "lane",
            "max_attempts",
            "checkpoint_dir",
            "resume",
            "dataset",
            "threads",
        ],
    )?;
    let algorithm = match j.get("algorithm").and_then(|a| a.as_str()) {
        Some(s) => Algorithm::parse(s)
            .ok_or_else(|| SpecError::bad("algorithm", format!("unknown value {s:?}")))?,
        None => Algorithm::KMedoidsPlusPlusMR,
    };
    let seed = match j.get("seed") {
        Some(v) => as_nonneg_u64(v, "seed")?,
        None => 42,
    };
    let dataset_j = j.get("dataset").ok_or_else(|| SpecError::MissingKey {
        key: "dataset".into(),
        hint: "every spec cell needs a dataset block".into(),
    })?;
    let (spec, data_file) = if dataset_j.get("file").is_some() {
        let (s, p) = file_dataset_from_json(dataset_j, seed)?;
        (s, Some(p))
    } else {
        (spatial_spec_from_json(dataset_j, seed)?, None)
    };
    let metric = match j.get("metric").and_then(|m| m.as_str()) {
        Some(s) => Metric::parse(s).ok_or_else(|| {
            SpecError::bad(
                "metric",
                format!("unknown value {s:?} (sq_euclidean|manhattan|haversine)"),
            )
        })?,
        None => Metric::SqEuclidean,
    };
    if !metric.supports_dims(spec.dims) {
        bail!(SpecError::bad(
            "metric",
            format!("{:?} does not support dataset.dims = {}", metric.name(), spec.dims),
        ));
    }
    // Reject rather than silently misread: haversine interprets
    // coordinates as (lat, lon) degrees, so a planar map-unit dataset
    // would produce finite but meaningless great-circle costs (the CLI
    // path force-enables latlon for --metric haversine).
    if metric == Metric::Haversine && !spec.latlon {
        bail!(SpecError::bad(
            "metric",
            "\"haversine\" needs (lat, lon) data — set dataset.latlon = true",
        ));
    }
    let update = match j.get("update") {
        Some(u) => {
            // Reject rather than silently ignore: clarans/kmeans-mr run
            // with their own update rules.
            if !algorithm_uses_update(algorithm) {
                bail!(SpecError::bad(
                    "update",
                    format!(
                        "is ignored by algorithm {:?} — remove it from the spec cell",
                        algorithm.name()
                    ),
                ));
            }
            update_from_json(u)?
        }
        None => UpdateStrategy::paper_scale_default(),
    };
    let fixed_iters = match j.get("fixed_iters") {
        None | Some(Json::Null) => None,
        Some(v) => {
            if !algorithm_uses_fixed_iters(algorithm) {
                bail!(SpecError::bad(
                    "fixed_iters",
                    format!(
                        "is ignored by algorithm {:?} (only the MR k-medoids drivers support \
                         controlled iterations) — remove it from the spec cell",
                        algorithm.name()
                    ),
                ));
            }
            Some(as_pos_usize(v, "fixed_iters")?)
        }
    };
    let oversample = match j.get("oversample") {
        None | Some(Json::Null) => None,
        Some(v) => {
            if !algorithm_uses_oversample(algorithm) {
                bail!(SpecError::bad(
                    "oversample",
                    format!(
                        "is ignored by algorithm {:?} (only kmedoids-scalable-mr uses \
                         oversampled seeding) — remove it from the spec cell",
                        algorithm.name()
                    ),
                ));
            }
            check_known_keys(v, "oversample", &["l", "rounds"])?;
            let l = as_pos_usize(
                v.get("l").ok_or_else(|| SpecError::missing("oversample.l"))?,
                "oversample.l",
            )?;
            let rounds = as_pos_usize(
                v.get("rounds").ok_or_else(|| SpecError::missing("oversample.rounds"))?,
                "oversample.rounds",
            )?;
            Some((l, rounds))
        }
    };
    let coreset_size = match j.get("coreset_size") {
        None | Some(Json::Null) => None,
        Some(v) => {
            if !algorithm_uses_coreset_size(algorithm) {
                bail!(SpecError::bad(
                    "coreset_size",
                    format!(
                        "is ignored by algorithm {:?} (only kmedoids-coreset-mr builds a \
                         weighted coreset) — remove it from the spec cell",
                        algorithm.name()
                    ),
                ));
            }
            Some(as_pos_usize(v, "coreset_size")?)
        }
    };
    let pruning = match j.get("pruning") {
        None | Some(Json::Null) => PruningMode::Auto,
        Some(v) => {
            if !algorithm_uses_pruning(algorithm) {
                bail!(SpecError::bad(
                    "pruning",
                    format!(
                        "is ignored by algorithm {:?} (the serial engines always run the \
                         dense kernels) — remove it from the spec cell",
                        algorithm.name()
                    ),
                ));
            }
            let s = v
                .as_str()
                .ok_or_else(|| SpecError::bad("pruning", "must be \"on\", \"off\" or \"auto\""))?;
            PruningMode::parse(s).ok_or_else(|| {
                SpecError::bad("pruning", format!("unknown value {s:?} (on|off|auto)"))
            })?
        }
    };
    let lane = match j.get("lane") {
        None | Some(Json::Null) => Lane::HadoopMr,
        Some(v) => {
            if !algorithm_uses_lane(algorithm) {
                bail!(SpecError::bad(
                    "lane",
                    format!(
                        "is ignored by algorithm {:?} (the serial engines never submit MR \
                         jobs) — remove it from the spec cell",
                        algorithm.name()
                    ),
                ));
            }
            let s = v.as_str().ok_or_else(|| {
                SpecError::bad("lane", "must be \"hadoop-mr\" or \"in-memory-dag\"")
            })?;
            Lane::parse(s).ok_or_else(|| match Lane::suggest(s) {
                Some(sugg) => SpecError::bad(
                    "lane",
                    format!(
                        "unknown value {s:?} (hadoop-mr|in-memory-dag) — did you mean \
                         {sugg:?}?"
                    ),
                ),
                None => SpecError::bad(
                    "lane",
                    format!("unknown value {s:?} (hadoop-mr|in-memory-dag)"),
                ),
            })?
        }
    };
    let max_attempts = match j.get("max_attempts") {
        None | Some(Json::Null) => None,
        Some(v) => {
            if !algorithm_uses_lane(algorithm) {
                bail!(SpecError::bad(
                    "max_attempts",
                    format!(
                        "is ignored by algorithm {:?} (only the MR algorithms schedule \
                         task attempts) — remove it from the spec cell",
                        algorithm.name()
                    ),
                ));
            }
            if lane == Lane::InMemoryDag {
                bail!(SpecError::bad(
                    "max_attempts",
                    "only applies to the hadoop-mr lane (the in-memory DAG lane does not \
                     model task failures) — remove it or switch lanes",
                ));
            }
            Some(as_pos_usize(v, "max_attempts")?)
        }
    };
    let checkpoint_dir = match j.get("checkpoint_dir") {
        None | Some(Json::Null) => None,
        Some(v) => {
            if !algorithm_uses_checkpoints(algorithm) {
                bail!(SpecError::bad(
                    "checkpoint_dir",
                    format!(
                        "is ignored by algorithm {:?} (only the MR k-medoids drivers emit \
                         checkpoints) — remove it from the spec cell",
                        algorithm.name()
                    ),
                ));
            }
            let s = v
                .as_str()
                .ok_or_else(|| SpecError::bad("checkpoint_dir", "must be a directory path"))?;
            if s.is_empty() {
                bail!(SpecError::bad("checkpoint_dir", "must not be empty"));
            }
            Some(std::path::PathBuf::from(s))
        }
    };
    let resume = match j.get("resume") {
        Some(v) => {
            let b = v.as_bool().ok_or_else(|| SpecError::bad("resume", "must be true or false"))?;
            if b && !algorithm_uses_checkpoints(algorithm) {
                bail!(SpecError::bad(
                    "resume",
                    format!(
                        "is ignored by algorithm {:?} (only the MR k-medoids drivers restore \
                         checkpoints) — remove it from the spec cell",
                        algorithm.name()
                    ),
                ));
            }
            if b && checkpoint_dir.is_none() {
                bail!(SpecError::bad(
                    "resume",
                    "requires checkpoint_dir (nowhere to load a snapshot from)",
                ));
            }
            b
        }
        None => false,
    };
    let n_nodes = match j.get("nodes") {
        Some(v) => as_pos_usize(v, "nodes")?,
        None => 7,
    };
    let k = match j.get("k") {
        Some(v) => as_pos_usize(v, "k")?,
        None => 9,
    };
    let with_quality = match j.get("with_quality") {
        Some(v) => v
            .as_bool()
            .ok_or_else(|| SpecError::bad("with_quality", "must be true or false"))?,
        None => false,
    };
    if with_quality && data_file.is_some() {
        bail!(SpecError::bad(
            "with_quality",
            "file datasets carry no ground-truth labels, so ARI cannot be computed",
        ));
    }
    let threads = match j.get("threads") {
        Some(v) => as_pos_usize(v, "threads")?,
        None => 1,
    };
    Ok(Experiment {
        algorithm,
        n_nodes,
        spec,
        data_file,
        k,
        update,
        metric,
        oversample,
        coreset_size,
        checkpoint_dir,
        resume,
        seed,
        with_quality,
        fixed_iters,
        threads,
        pruning,
        lane,
        max_attempts,
    })
}

// ---- bench scale spec -------------------------------------------------------

/// Overlay a `bench scale` JSON spec onto `base` options. Keys:
///
/// ```text
/// {
///   "nodes_sweep": [1, 2, 4, 8, 16],
///   "speculation": true,
///   "faults": {"n_failures": 1, "task_fail_rate": 0.02},   // or false
///   "scale_div": 8,
///   "seed": 42
/// }
/// ```
pub fn scale_opts_from_json(j: &Json, mut base: ScaleOpts) -> Result<ScaleOpts> {
    check_known_keys(
        j,
        "scale spec",
        &["nodes_sweep", "speculation", "faults", "scale_div", "seed"],
    )?;
    if let Some(v) = j.get("nodes_sweep") {
        let arr = v
            .as_arr()
            .ok_or_else(|| SpecError::bad("nodes_sweep", "must be an array of node counts"))?;
        if arr.is_empty() {
            bail!(SpecError::bad("nodes_sweep", "must not be empty"));
        }
        base.nodes_sweep = arr
            .iter()
            .map(|x| as_pos_usize(x, "nodes_sweep entry"))
            .collect::<Result<Vec<usize>>>()?;
    }
    if let Some(v) = j.get("speculation") {
        base.speculation =
            v.as_bool().ok_or_else(|| SpecError::bad("speculation", "must be true or false"))?;
    }
    if let Some(v) = j.get("scale_div") {
        base.scale_div = as_pos_usize(v, "scale_div")?;
    }
    if let Some(v) = j.get("seed") {
        base.seed = as_nonneg_u64(v, "seed")?;
    }
    match j.get("faults") {
        None => {}
        Some(Json::Bool(b)) => base.faults = *b,
        Some(f @ Json::Obj(_)) => {
            check_known_keys(f, "faults", &["n_failures", "task_fail_rate"])?;
            base.faults = true;
            if let Some(v) = f.get("n_failures") {
                base.n_failures = as_pos_usize(v, "faults.n_failures")?;
            }
            if let Some(v) = f.get("task_fail_rate") {
                let r = v
                    .as_f64()
                    .ok_or_else(|| SpecError::bad("faults.task_fail_rate", "must be a number"))?;
                if !(0.0..=0.9).contains(&r) {
                    bail!(SpecError::bad(
                        "faults.task_fail_rate",
                        format!("must be in [0, 0.9], got {r}"),
                    ));
                }
                base.task_fail_rate = r;
            }
        }
        Some(_) => bail!(SpecError::bad("faults", "must be a boolean or an object")),
    }
    Ok(base)
}

/// Parse a `bench scale` spec source over the given defaults.
pub fn scale_opts_from_str(src: &str, base: ScaleOpts) -> Result<ScaleOpts> {
    let j = Json::parse(src).context("scale spec is not valid JSON")?;
    scale_opts_from_json(&j, base)
}

// ---- bench serve spec -------------------------------------------------------

/// Overlay a `bench serve` JSON spec onto `base` options. Keys:
///
/// ```text
/// {
///   "threads": [1, 4],
///   "queries": 20000,
///   "update_frac": 0.2,
///   "batch": 256,
///   "coreset_size": 128,           // or null for the k·(log₂n+1) default
///   "scale_div": 40,
///   "seed": 42
/// }
/// ```
pub fn serve_opts_from_json(j: &Json, mut base: ServeOpts) -> Result<ServeOpts> {
    check_known_keys(
        j,
        "serve spec",
        &["threads", "queries", "update_frac", "batch", "coreset_size", "scale_div", "seed"],
    )?;
    if let Some(v) = j.get("threads") {
        let arr = v.as_arr().ok_or_else(|| {
            SpecError::bad("threads", "must be an array of reader-thread counts")
        })?;
        if arr.is_empty() {
            bail!(SpecError::bad("threads", "must not be empty"));
        }
        base.threads = arr
            .iter()
            .map(|x| as_pos_usize(x, "threads entry"))
            .collect::<Result<Vec<usize>>>()?;
    }
    if let Some(v) = j.get("queries") {
        base.queries = as_pos_usize(v, "queries")?;
    }
    if let Some(v) = j.get("update_frac") {
        let r = v.as_f64().ok_or_else(|| SpecError::bad("update_frac", "must be a number"))?;
        if !(0.0..=10.0).contains(&r) {
            bail!(SpecError::bad("update_frac", format!("must be in [0, 10], got {r}")));
        }
        base.update_frac = r;
    }
    if let Some(v) = j.get("batch") {
        base.batch = as_pos_usize(v, "batch")?;
    }
    match j.get("coreset_size") {
        None => {}
        Some(Json::Null) => base.coreset_size = None,
        Some(v) => base.coreset_size = Some(as_pos_usize(v, "coreset_size")?),
    }
    if let Some(v) = j.get("scale_div") {
        base.scale_div = as_pos_usize(v, "scale_div")?;
    }
    if let Some(v) = j.get("seed") {
        base.seed = as_nonneg_u64(v, "seed")?;
    }
    Ok(base)
}

/// Parse a `bench serve` spec source over the given defaults.
pub fn serve_opts_from_str(src: &str, base: ServeOpts) -> Result<ServeOpts> {
    let j = Json::parse(src).context("serve spec is not valid JSON")?;
    serve_opts_from_json(&j, base)
}

/// Serialize a grid of cells (array form).
pub fn experiments_to_json(cells: &[Experiment]) -> Json {
    Json::Arr(cells.iter().map(experiment_to_json).collect())
}

/// Parse a spec source: one cell object or an array of cells.
pub fn experiments_from_str(src: &str) -> Result<Vec<Experiment>> {
    let j = Json::parse(src).context("run spec is not valid JSON")?;
    match &j {
        Json::Arr(cells) => {
            if cells.is_empty() {
                bail!("run spec array is empty");
            }
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| experiment_from_json(c).with_context(|| format!("spec cell {i}")))
                .collect()
        }
        Json::Obj(_) => Ok(vec![experiment_from_json(&j)?]),
        _ => bail!("run spec must be a JSON object or array of objects"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_cells() -> Vec<Experiment> {
        let updates = [
            UpdateStrategy::Exact,
            UpdateStrategy::Sampled { candidates: 64, member_sample: 1024 },
            UpdateStrategy::SampledAdaptive { candidates: 256, frac_div: 4, min_sample: 16_384 },
            UpdateStrategy::CentroidNearest,
        ];
        Algorithm::ALL
            .iter()
            .zip(updates.iter().cycle())
            .enumerate()
            .map(|(i, (&algorithm, &update))| {
                let mut e = Experiment::paper_cell(algorithm, 4 + (i % 4), i % 3, 7 + i as u64)
                    .scaled(100);
                // Only give a cell knobs its algorithm honors — the spec
                // format refuses settings the solver would ignore.
                if algorithm_uses_update(algorithm) {
                    e.update = update;
                }
                e.k = 3 + i;
                e.with_quality = i % 2 == 0;
                e.threads = 1 + (i % 3);
                e.metric = if i % 2 == 0 { Metric::SqEuclidean } else { Metric::Manhattan };
                if i % 3 == 0 {
                    e.spec.dims = 3;
                }
                e.fixed_iters = if algorithm_uses_fixed_iters(algorithm) && i % 2 == 1 {
                    Some(6)
                } else {
                    None
                };
                e.oversample = if algorithm_uses_oversample(algorithm) {
                    Some((16, 4))
                } else {
                    None
                };
                e.coreset_size = if algorithm_uses_coreset_size(algorithm) {
                    Some(128)
                } else {
                    None
                };
                e.pruning = if algorithm_uses_pruning(algorithm) && i % 2 == 1 {
                    PruningMode::On
                } else {
                    PruningMode::Auto
                };
                e.lane = if algorithm_uses_lane(algorithm) && i % 2 == 1 {
                    Lane::InMemoryDag
                } else {
                    Lane::HadoopMr
                };
                // max_attempts is a Hadoop-lane knob, so only cells that
                // stayed on that lane may carry it.
                e.max_attempts =
                    if algorithm_uses_lane(algorithm) && e.lane == Lane::HadoopMr && i % 3 == 0 {
                        Some(6)
                    } else {
                        None
                    };
                e.checkpoint_dir = if algorithm_uses_checkpoints(algorithm) && i % 2 == 0 {
                    Some(std::path::PathBuf::from(format!("ckpts/cell-{i}")))
                } else {
                    None
                };
                e.resume = e.checkpoint_dir.is_some() && i % 4 == 0;
                e
            })
            .collect()
    }

    #[test]
    fn experiment_json_roundtrip_all_algorithms_and_updates() {
        for cell in sample_cells() {
            let text = experiment_to_json(&cell).to_string();
            let parsed = Json::parse(&text).unwrap();
            let back = experiment_from_json(&parsed).unwrap();
            assert_eq!(back, cell, "roundtrip mismatch for {}", cell.algorithm.name());
        }
    }

    #[test]
    fn grid_roundtrips_as_array() {
        let cells = sample_cells();
        let text = experiments_to_json(&cells).to_string();
        let back = experiments_from_str(&text).unwrap();
        assert_eq!(back, cells);
    }

    #[test]
    fn single_object_spec_parses() {
        let cells = experiments_from_str(
            r#"{"dataset": {"n_points": 5000, "n_hotspots": 4}, "k": 4, "nodes": 5}"#,
        )
        .unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].algorithm, Algorithm::KMedoidsPlusPlusMR, "default algorithm");
        assert_eq!(cells[0].k, 4);
        assert_eq!(cells[0].n_nodes, 5);
        assert_eq!(cells[0].spec.n_points, 5000);
        assert_eq!(cells[0].seed, 42, "default seed");
        assert_eq!(cells[0].update, UpdateStrategy::paper_scale_default());
    }

    #[test]
    fn paper_dataset_shorthand() {
        let cells = experiments_from_str(
            r#"{"algorithm": "clarans", "dataset": {"paper_dataset": 1, "scale_div": 200}}"#,
        )
        .unwrap();
        let expect = SpatialSpec::paper_dataset_scaled(1, 200, 42);
        assert_eq!(cells[0].spec, expect);
        assert_eq!(cells[0].algorithm, Algorithm::Clarans);
    }

    #[test]
    fn bad_specs_have_helpful_errors() {
        let e = experiments_from_str("not json").unwrap_err();
        assert!(format!("{e:#}").contains("valid JSON"), "{e:#}");

        let e = experiments_from_str(r#"{"algorithm": "nope", "dataset": {"n_points": 10}}"#)
            .unwrap_err();
        assert!(format!("{e:#}").contains("nope"), "{e:#}");

        let e = experiments_from_str(r#"{"algorithm": "clarans"}"#).unwrap_err();
        assert!(format!("{e:#}").contains("dataset"), "{e:#}");

        let e = experiments_from_str(
            r#"{"dataset": {"n_points": 10}, "update": {"kind": "bogus"}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("bogus"), "{e:#}");

        assert!(experiments_from_str("[]").is_err());
        assert!(experiments_from_str("3").is_err());
    }

    #[test]
    fn negative_zero_and_fractional_numbers_are_rejected() {
        // The raw f64→usize cast would saturate -5 to 0; the spec layer
        // must refuse instead of ingesting an empty dataset.
        for bad in ["-5", "0", "2.5"] {
            let src = format!(r#"{{"dataset": {{"n_points": {bad}}}}}"#);
            let e = experiments_from_str(&src).unwrap_err();
            assert!(format!("{e:#}").contains("n_points"), "{bad}: {e:#}");
        }
        let e = experiments_from_str(
            r#"{"dataset": {"n_points": 100}, "fixed_iters": -1}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("fixed_iters"), "{e:#}");
        let e = experiments_from_str(r#"{"dataset": {"n_points": 100}, "nodes": 0}"#)
            .unwrap_err();
        assert!(format!("{e:#}").contains("nodes"), "{e:#}");
        let e = experiments_from_str(r#"{"dataset": {"n_points": 100}, "threads": 0}"#)
            .unwrap_err();
        assert!(format!("{e:#}").contains("threads"), "{e:#}");
        let e = experiments_from_str(r#"{"dataset": {"paper_dataset": -1}}"#).unwrap_err();
        assert!(format!("{e:#}").contains("paper_dataset"), "{e:#}");
    }

    #[test]
    fn typoed_and_mistyped_fields_are_rejected_not_defaulted() {
        // "node" (typo for "nodes") must error, not run with 7 nodes.
        let e = experiments_from_str(r#"{"node": 4, "dataset": {"n_points": 1000}}"#)
            .unwrap_err();
        assert!(format!("{e:#}").contains("node"), "{e:#}");

        let e = experiments_from_str(
            r#"{"dataset": {"n_points": 1000, "outliers": 0.5}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("outliers"), "{e:#}");

        // Wrong types on optional fields error instead of silently
        // falling back to the default.
        let e = experiments_from_str(
            r#"{"with_quality": "yes", "dataset": {"n_points": 1000}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("with_quality"), "{e:#}");
        let e = experiments_from_str(
            r#"{"dataset": {"n_points": 1000, "outlier_frac": "0.5"}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("outlier_frac"), "{e:#}");

        // A knob a specific update kind ignores is rejected too.
        let e = experiments_from_str(
            r#"{"dataset": {"n_points": 1000},
                "update": {"kind": "exact", "candidates": 8}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("candidates"), "{e:#}");
    }

    #[test]
    fn metric_and_dims_fields_parse_and_validate() {
        let cells = experiments_from_str(
            r#"{"metric": "manhattan", "dataset": {"n_points": 500, "dims": 3}}"#,
        )
        .unwrap();
        assert_eq!(cells[0].metric, Metric::Manhattan);
        assert_eq!(cells[0].spec.dims, 3);

        let cells = experiments_from_str(
            r#"{"metric": "haversine", "dataset": {"n_points": 500, "latlon": true}}"#,
        )
        .unwrap();
        assert_eq!(cells[0].metric, Metric::Haversine);
        assert!(cells[0].spec.latlon);

        // haversine + d>2 is refused at parse time.
        let e = experiments_from_str(
            r#"{"metric": "haversine", "dataset": {"n_points": 500, "dims": 3}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("haversine"), "{e:#}");

        // haversine over a planar (non-latlon) dataset is refused too:
        // it would silently misread map units as degrees.
        let e = experiments_from_str(
            r#"{"metric": "haversine", "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("latlon"), "{e:#}");

        // latlon requires dims 2; dims must be in range; unknown metrics error.
        let e = experiments_from_str(
            r#"{"dataset": {"n_points": 500, "dims": 4, "latlon": true}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("latlon"), "{e:#}");
        let e = experiments_from_str(r#"{"dataset": {"n_points": 500, "dims": 99}}"#)
            .unwrap_err();
        assert!(format!("{e:#}").contains("dims"), "{e:#}");
        let e = experiments_from_str(
            r#"{"metric": "cosine", "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("cosine"), "{e:#}");
    }

    #[test]
    fn oversample_knob_parses_for_scalable_only() {
        let cells = experiments_from_str(
            r#"{"algorithm": "kmedoids-scalable-mr",
                "oversample": {"l": 12, "rounds": 3},
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap();
        assert_eq!(cells[0].algorithm, Algorithm::KMedoidsScalableMR);
        assert_eq!(cells[0].oversample, Some((12, 3)));

        // Default (absent) oversample: engine falls back to ℓ=2k, 5 rounds.
        let cells = experiments_from_str(
            r#"{"algorithm": "kmedoids||-mr", "dataset": {"n_points": 500}}"#,
        )
        .unwrap();
        assert_eq!(cells[0].oversample, None);

        // Other algorithms refuse the knob.
        let e = experiments_from_str(
            r#"{"algorithm": "kmedoids++-mr", "oversample": {"l": 8, "rounds": 2},
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("oversample"), "{e:#}");

        // Malformed oversample blocks error with the bad key.
        let e = experiments_from_str(
            r#"{"algorithm": "kmedoids-scalable-mr", "oversample": {"l": 8},
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("rounds"), "{e:#}");
    }

    #[test]
    fn coreset_size_knob_parses_for_coreset_only() {
        let cells = experiments_from_str(
            r#"{"algorithm": "kmedoids-coreset-mr", "coreset_size": 256,
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap();
        assert_eq!(cells[0].algorithm, Algorithm::KMedoidsCoresetMR);
        assert_eq!(cells[0].coreset_size, Some(256));

        // Absent / null means the O(k·log n) default.
        let cells = experiments_from_str(
            r#"{"algorithm": "kmedoids-coreset", "coreset_size": null,
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap();
        assert_eq!(cells[0].coreset_size, None);

        // Other algorithms refuse the knob; bad values are rejected.
        let e = experiments_from_str(
            r#"{"algorithm": "kmedoids++-mr", "coreset_size": 64,
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("coreset_size"), "{e:#}");
        let e = experiments_from_str(
            r#"{"algorithm": "kmedoids-coreset-mr", "coreset_size": 0,
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("coreset_size"), "{e:#}");

        // The coreset pipeline runs with its own update rule: an explicit
        // "update" block is refused like for clarans/kmeans.
        let e = experiments_from_str(
            r#"{"algorithm": "kmedoids-coreset-mr", "dataset": {"n_points": 500},
                "update": {"kind": "exact"}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("update"), "{e:#}");
    }

    #[test]
    fn pruning_knob_parses_and_validates() {
        for (text, want) in [
            ("\"on\"", PruningMode::On),
            ("\"off\"", PruningMode::Off),
            ("\"auto\"", PruningMode::Auto),
        ] {
            let src = format!(
                r#"{{"algorithm": "kmedoids++-mr", "pruning": {text},
                    "dataset": {{"n_points": 500}}}}"#
            );
            let cells = experiments_from_str(&src).unwrap();
            assert_eq!(cells[0].pruning, want, "{text}");
        }

        // Absent / null means Auto (the durable-run interlock decides).
        let cells = experiments_from_str(
            r#"{"algorithm": "kmeans-mr", "pruning": null, "dataset": {"n_points": 500}}"#,
        )
        .unwrap();
        assert_eq!(cells[0].pruning, PruningMode::Auto);

        // The serial engines always run dense kernels: the knob is
        // refused there, as are unknown values anywhere.
        let e = experiments_from_str(
            r#"{"algorithm": "kmedoids-serial", "pruning": "on",
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("pruning"), "{e:#}");
        let e = experiments_from_str(
            r#"{"algorithm": "kmedoids++-mr", "pruning": "fast",
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("fast"), "{e:#}");
    }

    #[test]
    fn lane_knob_parses_and_validates() {
        for (text, want) in [
            ("\"hadoop-mr\"", Lane::HadoopMr),
            ("\"in-memory-dag\"", Lane::InMemoryDag),
            ("\"spark\"", Lane::InMemoryDag),
        ] {
            let src = format!(
                r#"{{"algorithm": "kmedoids++-mr", "lane": {text},
                    "dataset": {{"n_points": 500}}}}"#
            );
            let cells = experiments_from_str(&src).unwrap();
            assert_eq!(cells[0].lane, want, "{text}");
        }

        // Absent / null means the Hadoop lane (the default axis).
        let cells = experiments_from_str(
            r#"{"algorithm": "kmeans-mr", "lane": null, "dataset": {"n_points": 500}}"#,
        )
        .unwrap();
        assert_eq!(cells[0].lane, Lane::HadoopMr);

        // The serial engines never submit MR jobs: the knob is refused
        // there with a typed error.
        let e = experiments_from_str(
            r#"{"algorithm": "clarans", "lane": "hadoop-mr", "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert_eq!(e.downcast_ref::<SpecError>().unwrap().key(), "lane");

        // Unknown values get a did-you-mean hint when one is close.
        let e = experiments_from_str(
            r#"{"algorithm": "kmedoids++-mr", "lane": "sparkk",
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("did you mean") && msg.contains("in-memory-dag"), "{msg}");
        let e = experiments_from_str(
            r#"{"algorithm": "kmedoids++-mr", "lane": "completely-wrong",
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("unknown value") && !msg.contains("did you mean"), "{msg}");

        // max_attempts parses on the Hadoop lane, is refused on the DAG
        // lane and on algorithms without a lane.
        let cells = experiments_from_str(
            r#"{"algorithm": "kmedoids-mr", "max_attempts": 6,
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap();
        assert_eq!(cells[0].max_attempts, Some(6));
        let e = experiments_from_str(
            r#"{"algorithm": "kmedoids-mr", "lane": "in-memory-dag", "max_attempts": 6,
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert_eq!(e.downcast_ref::<SpecError>().unwrap().key(), "max_attempts");
        let e = experiments_from_str(
            r#"{"algorithm": "kmedoids-serial", "max_attempts": 6,
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert_eq!(e.downcast_ref::<SpecError>().unwrap().key(), "max_attempts");
    }

    #[test]
    fn checkpoint_keys_parse_and_validate() {
        let cells = experiments_from_str(
            r#"{"algorithm": "kmedoids++-mr", "checkpoint_dir": "out/ckpts",
                "resume": true, "dataset": {"n_points": 500}}"#,
        )
        .unwrap();
        assert_eq!(cells[0].checkpoint_dir, Some(std::path::PathBuf::from("out/ckpts")));
        assert!(cells[0].resume);

        // Null / absent means "no durability"; resume defaults off.
        let cells = experiments_from_str(
            r#"{"algorithm": "kmedoids-coreset-mr", "checkpoint_dir": null,
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap();
        assert_eq!(cells[0].checkpoint_dir, None);
        assert!(!cells[0].resume);

        // resume without a checkpoint_dir has nowhere to load from.
        let e = experiments_from_str(
            r#"{"algorithm": "kmedoids-mr", "resume": true, "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert_eq!(e.downcast_ref::<SpecError>().unwrap().key(), "resume");

        // Algorithms without checkpoint support refuse both knobs
        // rather than silently running non-durable.
        let e = experiments_from_str(
            r#"{"algorithm": "clarans", "checkpoint_dir": "c", "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("checkpoint_dir"), "{e:#}");
        let e = experiments_from_str(
            r#"{"algorithm": "kmeans-mr", "resume": true, "checkpoint_dir": "c",
                "dataset": {"n_points": 500}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("checkpoint_dir"), "{e:#}");

        // Bad shapes are rejected with the offending key.
        for bad in [
            r#"{"algorithm": "kmedoids++-mr", "checkpoint_dir": 3,
                "dataset": {"n_points": 500}}"#,
            r#"{"algorithm": "kmedoids++-mr", "checkpoint_dir": "",
                "dataset": {"n_points": 500}}"#,
            r#"{"algorithm": "kmedoids++-mr", "checkpoint_dir": "c", "resume": "yes",
                "dataset": {"n_points": 500}}"#,
        ] {
            let e = experiments_from_str(bad).unwrap_err();
            let s = e.downcast_ref::<SpecError>().expect("typed SpecError");
            assert!(s.key() == "checkpoint_dir" || s.key() == "resume", "{bad}: {s:?}");
        }
    }

    #[test]
    fn scale_spec_keys_overlay_defaults() {
        let opts = scale_opts_from_str(
            r#"{"nodes_sweep": [1, 2, 4], "speculation": false,
                "faults": {"n_failures": 2, "task_fail_rate": 0.1},
                "scale_div": 20, "seed": 7}"#,
            ScaleOpts::default(),
        )
        .unwrap();
        assert_eq!(opts.nodes_sweep, vec![1, 2, 4]);
        assert!(!opts.speculation);
        assert!(opts.faults);
        assert_eq!(opts.n_failures, 2);
        assert_eq!(opts.task_fail_rate, 0.1);
        assert_eq!(opts.scale_div, 20);
        assert_eq!(opts.seed, 7);

        // faults: false disables the identity twin; absent keys keep
        // the defaults.
        let opts = scale_opts_from_str(r#"{"faults": false}"#, ScaleOpts::default()).unwrap();
        assert!(!opts.faults);
        assert_eq!(opts.nodes_sweep, ScaleOpts::default().nodes_sweep);

        // Typos, bad shapes, and out-of-range knobs are rejected.
        for bad in [
            r#"{"node_sweep": [1]}"#,
            r#"{"nodes_sweep": []}"#,
            r#"{"nodes_sweep": [0]}"#,
            r#"{"faults": 3}"#,
            r#"{"faults": {"task_fail_rate": 2.0}}"#,
            r#"{"faults": {"rate": 0.1}}"#,
            r#"{"speculation": "yes"}"#,
        ] {
            assert!(
                scale_opts_from_str(bad, ScaleOpts::default()).is_err(),
                "should reject {bad}"
            );
        }
    }

    #[test]
    fn knobs_unsupported_by_the_algorithm_are_rejected_not_dropped() {
        // clarans ignores `update`: refusing beats silently running
        // something other than what the spec says.
        let e = experiments_from_str(
            r#"{"algorithm": "clarans", "dataset": {"n_points": 10},
                "update": {"kind": "exact"}}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("update"), "{e:#}");

        let e = experiments_from_str(
            r#"{"algorithm": "kmeans-mr", "dataset": {"n_points": 10}, "fixed_iters": 6}"#,
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("fixed_iters"), "{e:#}");

        // A null fixed_iters is the explicit "not set" spelling — fine
        // anywhere, as is `update` on any k-medoids variant.
        let cells = experiments_from_str(
            r#"{"algorithm": "kmedoids-serial", "dataset": {"n_points": 10},
                "fixed_iters": null, "update": {"kind": "exact"}}"#,
        )
        .unwrap();
        assert_eq!(cells[0].update, UpdateStrategy::Exact);
        assert_eq!(cells[0].fixed_iters, None);
    }

    #[test]
    fn serve_spec_keys_overlay_defaults() {
        let opts = serve_opts_from_str(
            r#"{"threads": [1, 2, 8], "queries": 5000, "update_frac": 0.5,
                "batch": 64, "coreset_size": 200, "scale_div": 100, "seed": 9}"#,
            ServeOpts::default(),
        )
        .unwrap();
        assert_eq!(opts.threads, vec![1, 2, 8]);
        assert_eq!(opts.queries, 5000);
        assert_eq!(opts.update_frac, 0.5);
        assert_eq!(opts.batch, 64);
        assert_eq!(opts.coreset_size, Some(200));
        assert_eq!(opts.scale_div, 100);
        assert_eq!(opts.seed, 9);

        // Absent keys keep the defaults; null coreset_size is the
        // explicit "auto" spelling.
        let opts =
            serve_opts_from_str(r#"{"coreset_size": null}"#, ServeOpts::default()).unwrap();
        assert_eq!(opts.coreset_size, None);
        assert_eq!(opts.queries, ServeOpts::default().queries);

        for bad in [
            r#"{"thread": [1]}"#,
            r#"{"threads": []}"#,
            r#"{"threads": [0]}"#,
            r#"{"threads": 4}"#,
            r#"{"queries": -1}"#,
            r#"{"update_frac": "half"}"#,
            r#"{"update_frac": -0.1}"#,
            r#"{"batch": 0}"#,
            r#"{"coreset_size": 0}"#,
        ] {
            assert!(
                serve_opts_from_str(bad, ServeOpts::default()).is_err(),
                "should reject {bad}"
            );
        }
    }

    #[test]
    fn spec_errors_are_typed_and_carry_the_offending_key() {
        // Missing required key.
        let e = experiments_from_str(r#"{"algorithm": "clarans"}"#).unwrap_err();
        let s = e.downcast_ref::<SpecError>().expect("typed SpecError");
        assert_eq!(s.key(), "dataset");
        assert!(matches!(s, SpecError::MissingKey { .. }), "{s:?}");

        // Unknown key (typo guard) names the typo'd key, not the field
        // it was probably meant to be.
        let e = experiments_from_str(
            r#"{"node": 4, "dataset": {"n_points": 10}}"#,
        )
        .unwrap_err();
        let s = e.downcast_ref::<SpecError>().expect("typed SpecError");
        assert_eq!(s.key(), "node");
        assert!(matches!(s, SpecError::UnknownKey { .. }), "{s:?}");

        // Out-of-domain value carries the dotted path to the field.
        let e = experiments_from_str(
            r#"{"dataset": {"n_points": 10, "outlier_frac": 3.0}}"#,
        )
        .unwrap_err();
        let s = e.downcast_ref::<SpecError>().expect("typed SpecError");
        assert_eq!(s.key(), "dataset.outlier_frac");
        assert!(matches!(s, SpecError::BadValue { .. }), "{s:?}");

        // Nested update knob errors are keyed too.
        let e = experiments_from_str(
            r#"{"dataset": {"n_points": 10},
                "update": {"kind": "sampled", "candidates": 8}}"#,
        )
        .unwrap_err();
        let s = e.downcast_ref::<SpecError>().expect("typed SpecError");
        assert_eq!(s.key(), "update.member_sample");

        // The scale/serve overlays speak the same error type.
        let e = serve_opts_from_str(r#"{"queries": 0}"#, ServeOpts::default()).unwrap_err();
        assert_eq!(e.downcast_ref::<SpecError>().unwrap().key(), "queries");
        let e = scale_opts_from_str(r#"{"scale_div": 0}"#, ScaleOpts::default()).unwrap_err();
        assert_eq!(e.downcast_ref::<SpecError>().unwrap().key(), "scale_div");
    }

    #[test]
    fn file_datasets_parse_validate_and_roundtrip() {
        use crate::geo::{binfmt, Point};
        let dir = crate::util::tempdir::TempDir::new("spec-file-dataset");
        let pts: Vec<Point> =
            (0..20).map(|i| Point::from_slice(&[i as f32, -(i as f32)])).collect();
        let bin = dir.join("pts.bin");
        binfmt::write_file(&bin, &pts, None).unwrap();
        let bin_s = bin.to_string_lossy().into_owned();

        // n_points/dims are learned from the file, and the path sticks.
        let cells =
            experiments_from_str(&format!(r#"{{"dataset": {{"file": "{bin_s}"}}, "k": 3}}"#))
                .unwrap();
        assert_eq!(cells[0].data_file.as_deref(), Some(bin.as_path()));
        assert_eq!(cells[0].spec.n_points, 20);
        assert_eq!(cells[0].spec.dims, 2);

        // File cells survive the to_json → from_json round trip.
        let text = experiment_to_json(&cells[0]).to_string();
        let back = experiment_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, cells[0]);

        // A matching dims declaration is accepted; a mismatch is typed.
        experiments_from_str(&format!(r#"{{"dataset": {{"file": "{bin_s}", "dims": 2}}}}"#))
            .unwrap();
        let e = experiments_from_str(&format!(
            r#"{{"dataset": {{"file": "{bin_s}", "dims": 3}}}}"#
        ))
        .unwrap_err();
        let s = e.downcast_ref::<SpecError>().expect("typed SpecError");
        assert_eq!(s.key(), "dataset.dims");
        assert!(matches!(s, SpecError::BadValue { .. }), "{s:?}");

        // A missing file is a typed error naming dataset.file.
        let e = experiments_from_str(r#"{"dataset": {"file": "no/such/file.bin"}}"#)
            .unwrap_err();
        assert_eq!(e.downcast_ref::<SpecError>().unwrap().key(), "dataset.file");

        // Generator knobs make no sense next to a file.
        let e = experiments_from_str(&format!(
            r#"{{"dataset": {{"file": "{bin_s}", "n_points": 5}}}}"#
        ))
        .unwrap_err();
        let s = e.downcast_ref::<SpecError>().expect("typed SpecError");
        assert!(matches!(s, SpecError::UnknownKey { .. }), "{s:?}");

        // File datasets carry no ground truth, so ARI is refused up front.
        let e = experiments_from_str(&format!(
            r#"{{"with_quality": true, "dataset": {{"file": "{bin_s}"}}}}"#
        ))
        .unwrap_err();
        assert_eq!(e.downcast_ref::<SpecError>().unwrap().key(), "with_quality");

        // CSV files come through the same (sniffed) door.
        let csv = dir.join("pts.csv");
        crate::geo::io::write_csv(&csv, &pts).unwrap();
        let cells = experiments_from_str(&format!(
            r#"{{"dataset": {{"file": "{}"}}}}"#,
            csv.to_string_lossy()
        ))
        .unwrap();
        assert!(cells[0].data_file.is_some());
        assert_eq!(cells[0].spec.n_points, 20);
        assert_eq!(cells[0].spec.dims, 2);
    }
}
