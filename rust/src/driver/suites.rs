//! Experiment suites: the exact cell grids behind each paper table/figure.
//!
//! Used by the CLI (`kmedoids-mr bench ...`), the cargo benches, and the
//! end-to-end example, so every entry point reproduces the same numbers.

use super::{run_experiment, Algorithm, Experiment, ExperimentResult};
use crate::clustering::{Init, UpdateStrategy};
use crate::runtime::ComputeBackend;
use std::sync::Arc;

/// Table 6 / Fig. 3 / Fig. 4: K-Medoids++ MR over 4–7 nodes × 3 datasets.
/// `scale_div` divides the dataset sizes (1 = the paper's full Table 5).
pub fn table6_suite(
    backend: &Arc<dyn ComputeBackend>,
    scale_div: usize,
    seed: u64,
) -> Vec<ExperimentResult> {
    let mut out = Vec::new();
    for dataset in 0..3 {
        for nodes in 4..=7 {
            let mut exp = Experiment::paper_cell(Algorithm::KMedoidsPlusPlusMR, nodes, dataset, seed)
                .scaled(scale_div.max(1));
            // Controlled iteration count: isolates the scaling behaviour
            // from per-dataset convergence luck (EXPERIMENTS.md §Method).
            exp.fixed_iters = Some(6);
            let r = run_experiment(&exp, backend);
            eprintln!(
                "  [table6] dataset {} x {} nodes -> {} ms ({} iters, wall {:.1}s)",
                dataset + 1,
                nodes,
                r.time_ms,
                r.iterations,
                r.wall_s
            );
            out.push(r);
        }
    }
    out
}

/// Fig. 5: comparative algorithms over the 3 dataset sizes — the paper's
/// "classic clustering algorithms for comparison are traditional
/// K-Medoids algorithm and CLARANS algorithm": the proposed parallel
/// K-Medoids++ (7 nodes) against the serial comparators on the master.
pub fn fig5_suite(
    backend: &Arc<dyn ComputeBackend>,
    scale_div: usize,
    seed: u64,
) -> Vec<ExperimentResult> {
    let algos = [
        Algorithm::KMedoidsPlusPlusMR,
        Algorithm::KMedoidsSerial,
        Algorithm::Clarans,
    ];
    let mut out = Vec::new();
    for algo in algos {
        for dataset in 0..3 {
            let mut exp = Experiment::paper_cell(algo, 7, dataset, seed).scaled(scale_div.max(1));
            if algo == Algorithm::KMedoidsPlusPlusMR {
                // Controlled iterations for the MR entry (as in Table 6);
                // the serial comparators keep natural convergence, which
                // only widens their gap.
                exp.fixed_iters = Some(6);
            }
            let r = run_experiment(&exp, backend);
            eprintln!(
                "  [fig5] {} dataset {} -> {} ms (wall {:.1}s)",
                algo.name(),
                dataset + 1,
                r.time_ms,
                r.wall_s
            );
            out.push(r);
        }
    }
    out
}

/// §3.1 ablation: ++ seeding vs random init (iterations to converge and
/// total time), plus update-strategy variants. Dataset 1, 7 nodes.
pub fn ablation_suite(
    backend: &Arc<dyn ComputeBackend>,
    scale_div: usize,
    seed: u64,
) -> Vec<ExperimentResult> {
    let mut out = Vec::new();
    let variants: Vec<(&str, Init, UpdateStrategy)> = vec![
        ("++/sampled", Init::PlusPlus, UpdateStrategy::paper_scale_default()),
        ("random/sampled", Init::Random, UpdateStrategy::paper_scale_default()),
        ("++/centroid", Init::PlusPlus, UpdateStrategy::CentroidNearest),
        ("random/centroid", Init::Random, UpdateStrategy::CentroidNearest),
    ];
    for (name, init, update) in variants {
        let algo = if init == Init::PlusPlus {
            Algorithm::KMedoidsPlusPlusMR
        } else {
            Algorithm::KMedoidsRandomMR
        };
        let mut exp = Experiment::paper_cell(algo, 7, 0, seed).scaled(scale_div.max(1));
        exp.update = update;
        let mut r = run_experiment(&exp, backend);
        // Relabel with the ablation variant name (leak: 4 static strings).
        r.algorithm = Box::leak(name.to_string().into_boxed_str());
        eprintln!("  [ablation] {name} -> {} ms, {} iters", r.time_ms, r.iterations);
        out.push(r);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn be() -> Arc<dyn ComputeBackend> {
        Arc::new(NativeBackend::new(256, 16))
    }

    #[test]
    fn table6_suite_small_has_12_cells_and_paper_shape() {
        // Heavy scale-down: structure test, not numbers. At this scale
        // each dataset is a single DFS block (one map task), so adding
        // nodes only re-shapes the reduce waves — allow 2% wobble from
        // slow-node placement; the strict monotonicity check runs at full
        // scale in the table6_scaling bench.
        let rs = table6_suite(&be(), 200, 5);
        assert_eq!(rs.len(), 12);
        assert!(rs.iter().all(|r| r.iterations == 6), "controlled iterations");
        for ds in [rs[0].n_points, rs[4].n_points, rs[8].n_points] {
            let times: Vec<u64> = rs
                .iter()
                .filter(|r| r.n_points == ds)
                .map(|r| r.time_ms)
                .collect();
            assert_eq!(times.len(), 4);
            assert!(
                times.windows(2).all(|w| w[1] as f64 <= w[0] as f64 * 1.02),
                "time should not grow materially with nodes: {times:?}"
            );
        }
        // Larger dataset takes longer at fixed cluster size.
        assert!(rs[0].time_ms <= rs[8].time_ms);
    }

    #[test]
    fn fig5_suite_ordering() {
        let rs = fig5_suite(&be(), 200, 5);
        assert_eq!(rs.len(), 9);
        // The proposed algorithm beats CLARANS at every size.
        for ds in 0..3 {
            let pp = rs.iter().find(|r| r.algorithm == "kmedoids++-mr" && r.n_points == rs[ds].n_points).unwrap();
            let cl = rs.iter().find(|r| r.algorithm == "clarans" && r.n_points == rs[ds].n_points).unwrap();
            assert!(
                pp.time_ms <= cl.time_ms,
                "kmedoids++ ({}) should beat clarans ({}) on dataset {}",
                pp.time_ms,
                cl.time_ms,
                ds + 1
            );
        }
    }
}
