//! Experiment suites: the exact cell grids behind each paper table/figure.
//!
//! Used by the CLI (`kmedoids-mr bench ...`), the cargo benches, and the
//! end-to-end example, so every entry point reproduces the same numbers.
//!
//! Session economics: each suite builds one [`ClusterSession`] per
//! cluster size, generates each dataset **once**, and ingests the shared
//! point set into every session ([`ClusterSession::ingest_points`] shares
//! the `Arc`, no copy) — cells then pay only the algorithm, not cluster
//! construction + generation + ingest as the old per-cell driver did.
//! With [`SuiteOpts::trace`] the sessions stream live per-iteration
//! progress to stderr through a [`StderrProgress`] observer.

use super::{run_cell, Algorithm, Experiment, ExperimentResult};
use crate::clustering::api::SpatialClusterer as _;
use crate::clustering::observe::StderrProgress;
use crate::clustering::{ClusterOutcome, Init, UpdateStrategy};
use crate::config::ClusterConfig;
use crate::geo::binfmt;
use crate::geo::datasets::{generate, SpatialSpec};
use crate::geo::{Metric, Point};
use crate::mapreduce::{locality_fraction, Lane};
use crate::runtime::{
    assign_points, pairwise_costs, pairwise_costs_src, ComputeBackend, PruningMode,
};
use crate::serve::{ServeConfig, ServeSession};
use crate::session::{ClusterSession, DatasetHandle};
use crate::sim::FaultPlan;
use crate::util::bench::{bench, header, BenchOpts};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// Shared suite knobs.
#[derive(Debug, Clone)]
pub struct SuiteOpts {
    /// Divide the Table 5 dataset sizes (1 = the paper's full scale).
    pub scale_div: usize,
    pub seed: u64,
    /// Stream per-iteration events to stderr while cells run.
    pub trace: bool,
    /// Real-compute worker threads for every suite session (wallclock
    /// only; the reported simulated numbers are identical at any value).
    pub threads: usize,
}

impl SuiteOpts {
    pub fn new(scale_div: usize, seed: u64) -> SuiteOpts {
        SuiteOpts { scale_div: scale_div.max(1), seed, trace: false, threads: 1 }
    }
    pub fn with_trace(mut self, trace: bool) -> SuiteOpts {
        self.trace = trace;
        self
    }
    pub fn with_threads(mut self, threads: usize) -> SuiteOpts {
        self.threads = threads.max(1);
        self
    }
}

/// Generate the three Table 5 datasets once (shared across sessions).
/// `scale_div` is re-clamped here because `SuiteOpts` fields are public.
fn paper_datasets(opts: &SuiteOpts) -> Vec<Arc<Vec<Point>>> {
    (0..3)
        .map(|i| {
            let spec = SpatialSpec::paper_dataset_scaled(i, opts.scale_div.max(1), opts.seed);
            Arc::new(generate(&spec).points)
        })
        .collect()
}

fn suite_session(
    backend: &Arc<dyn ComputeBackend>,
    nodes: usize,
    opts: &SuiteOpts,
    datasets: &[Arc<Vec<Point>>],
) -> (ClusterSession, Vec<DatasetHandle>) {
    let mut session = ClusterSession::builder()
        .cluster(ClusterConfig::paper_cluster())
        .nodes(nodes)
        .backend(backend.clone())
        .seed(opts.seed)
        .threads(opts.threads)
        .build()
        .expect("session build cannot fail with an explicit backend");
    if opts.trace {
        session.add_observer(Box::new(StderrProgress::new()));
    }
    let handles = datasets
        .iter()
        .enumerate()
        .map(|(i, pts)| session.ingest_points(&format!("dataset{}", i + 1), pts.clone()))
        .collect();
    (session, handles)
}

/// Table 6 / Fig. 3 / Fig. 4: K-Medoids++ MR over 4–7 nodes × 3 datasets.
pub fn table6_suite(backend: &Arc<dyn ComputeBackend>, opts: &SuiteOpts) -> Vec<ExperimentResult> {
    let datasets = paper_datasets(opts);
    // One session per cluster size, each with all three datasets ingested.
    let mut sessions: Vec<(ClusterSession, Vec<DatasetHandle>)> =
        (4..=7).map(|nodes| suite_session(backend, nodes, opts, &datasets)).collect();

    let mut out = Vec::new();
    for dataset in 0..3 {
        for (si, nodes) in (4..=7).enumerate() {
            let mut exp =
                Experiment::paper_cell(Algorithm::KMedoidsPlusPlusMR, nodes, dataset, opts.seed)
                    .scaled(opts.scale_div.max(1));
            // Controlled iteration count: isolates the scaling behaviour
            // from per-dataset convergence luck (EXPERIMENTS.md §Method).
            exp.fixed_iters = Some(6);
            let (session, handles) = &mut sessions[si];
            let r = run_cell(session, &exp, &handles[dataset]).expect("table6 cell failed");
            eprintln!(
                "  [table6] dataset {} x {} nodes -> {} ms ({} iters, wall {:.1}s)",
                dataset + 1,
                nodes,
                r.time_ms,
                r.iterations,
                r.wall_s
            );
            out.push(r);
        }
    }
    out
}

/// Fig. 5: comparative algorithms over the 3 dataset sizes — the paper's
/// "classic clustering algorithms for comparison are traditional
/// K-Medoids algorithm and CLARANS algorithm": the proposed parallel
/// K-Medoids++ (7 nodes) and the constant-round coreset pipeline against
/// the serial comparators on the master. One shared 7-node session hosts
/// all twelve cells.
pub fn fig5_suite(backend: &Arc<dyn ComputeBackend>, opts: &SuiteOpts) -> Vec<ExperimentResult> {
    let datasets = paper_datasets(opts);
    let (mut session, handles) = suite_session(backend, 7, opts, &datasets);
    let algos = [
        Algorithm::KMedoidsPlusPlusMR,
        Algorithm::KMedoidsCoresetMR,
        Algorithm::KMedoidsSerial,
        Algorithm::Clarans,
    ];
    let mut out = Vec::new();
    for algo in algos {
        for dataset in 0..3 {
            let mut exp =
                Experiment::paper_cell(algo, 7, dataset, opts.seed).scaled(opts.scale_div.max(1));
            if algo == Algorithm::KMedoidsPlusPlusMR {
                // Controlled iterations for the MR entry (as in Table 6);
                // the serial comparators keep natural convergence, which
                // only widens their gap.
                exp.fixed_iters = Some(6);
            }
            let r = run_cell(&mut session, &exp, &handles[dataset]).expect("fig5 cell failed");
            eprintln!(
                "  [fig5] {} dataset {} -> {} ms (wall {:.1}s)",
                algo.name(),
                dataset + 1,
                r.time_ms,
                r.wall_s
            );
            out.push(r);
        }
    }
    out
}

/// §3.1 ablation: ++ seeding vs random init (iterations to converge and
/// total time), plus update-strategy variants. Dataset 1, 7 nodes, one
/// shared session.
pub fn ablation_suite(
    backend: &Arc<dyn ComputeBackend>,
    opts: &SuiteOpts,
) -> Vec<ExperimentResult> {
    let spec = SpatialSpec::paper_dataset_scaled(0, opts.scale_div.max(1), opts.seed);
    let points = Arc::new(generate(&spec).points);
    let (mut session, handles) = suite_session(backend, 7, opts, std::slice::from_ref(&points));
    let data = &handles[0];

    let mut out = Vec::new();
    let variants: Vec<(&str, Init, UpdateStrategy)> = vec![
        ("++/sampled", Init::PlusPlus, UpdateStrategy::paper_scale_default()),
        ("random/sampled", Init::Random, UpdateStrategy::paper_scale_default()),
        ("++/centroid", Init::PlusPlus, UpdateStrategy::CentroidNearest),
        ("random/centroid", Init::Random, UpdateStrategy::CentroidNearest),
    ];
    for (name, init, update) in variants {
        let algo = if init == Init::PlusPlus {
            Algorithm::KMedoidsPlusPlusMR
        } else {
            Algorithm::KMedoidsRandomMR
        };
        let mut exp = Experiment::paper_cell(algo, 7, 0, opts.seed).scaled(opts.scale_div.max(1));
        exp.update = update;
        let mut r = run_cell(&mut session, &exp, data).expect("ablation cell failed");
        r.algorithm = name.to_string(); // relabel with the variant name
        eprintln!("  [ablation] {name} -> {} ms, {} iters", r.time_ms, r.iterations);
        out.push(r);
    }
    out
}

// ---- perf bench -------------------------------------------------------------

/// Knobs for the `bench perf` suite.
#[derive(Debug, Clone)]
pub struct PerfOpts {
    /// Divide the paper e2e dataset (dataset 1 of Table 5).
    pub scale_div: usize,
    pub seed: u64,
    /// Thread counts to sweep (1 must be included for the speedup base;
    /// it is added automatically if missing).
    pub threads: Vec<usize>,
    /// Tiny-n CI mode: one repeat, small kernels, fast by construction.
    pub smoke: bool,
    /// Durably checkpoint every e2e sweep fit into this directory
    /// ([`crate::persist`]); CI uploads it as the recovery artifact.
    pub checkpoint_dir: Option<std::path::PathBuf>,
}

impl Default for PerfOpts {
    fn default() -> Self {
        PerfOpts {
            scale_div: 10,
            seed: 42,
            threads: vec![1, 2, 4],
            smoke: false,
            checkpoint_dir: None,
        }
    }
}

/// One e2e row of the perf bench.
struct PerfRow {
    threads: usize,
    wall_s: f64,
    sim_seconds: f64,
    cost: f64,
    iterations: usize,
    dist_evals: u64,
    /// Fraction of the dense-lane distance evaluations this row skipped
    /// (0 when the sweep runs dense, e.g. under `--checkpoint-dir`).
    pruned_frac: f64,
    identical: bool,
}

/// Wall-clock perf trajectory: kernel throughput plus the paper e2e
/// workload (K-Medoids++ MR, 7 nodes, dataset 1) swept over real-compute
/// thread counts. Returns the `BENCH_perf.json` document; simulated
/// results are asserted identical across thread counts (the engine's
/// determinism contract), so the sweep measures *only* wall clock.
pub fn perf_suite(backend: &Arc<dyn ComputeBackend>, opts: &PerfOpts) -> Json {
    let mut threads = opts.threads.clone();
    if !threads.contains(&1) {
        threads.insert(0, 1);
    }
    threads.sort_unstable();
    threads.dedup();

    // ---- kernel micro-benches (per-call, single-threaded) ----------------
    header("perf: kernel hot path");
    let bench_opts =
        if opts.smoke { BenchOpts { warmup_iters: 1, iters: 2 } } else { BenchOpts::default() };
    let kn = if opts.smoke { 8_192 } else { 1 << 17 };
    let kdata = generate(&SpatialSpec::new(kn, 9, opts.seed));
    let medoids: Vec<Point> = kdata.points[..9].to_vec();
    // Exact per-call eval counts come from the counted kernels themselves
    // (not an n×k formula), so the artifact stays honest if a lane ever
    // evaluates more or fewer pairs than the closed form.
    let assign_evals = assign_points(backend.as_ref(), &kdata.points, &medoids, Metric::SqEuclidean)
        .unwrap()
        .dist_evals;
    let assign_stats = bench(&format!("assign {kn} pts x 9 medoids"), &bench_opts, || {
        assign_points(backend.as_ref(), &kdata.points, &medoids, Metric::SqEuclidean)
            .unwrap()
            .labels
            .len()
    });
    let pm = if opts.smoke { 4_096 } else { 1 << 14 };
    let cands: Vec<Point> = kdata.points[..256.min(kn)].to_vec();
    let pair_evals =
        pairwise_costs_src(backend.as_ref(), &cands[..], &kdata.points[..pm], Metric::SqEuclidean)
            .unwrap()
            .1;
    let pair_label = format!("pairwise {} cands x {pm} members", cands.len());
    let pair_stats = bench(&pair_label, &bench_opts, || {
        pairwise_costs(backend.as_ref(), &cands, &kdata.points[..pm], Metric::SqEuclidean)
            .unwrap()
            .len()
    });
    // One non-Euclidean, d>2 cell so the artifact tracks the generic
    // kernel path alongside the 2-D squared-Euclidean fast path.
    let gdata = generate(&SpatialSpec::new(kn, 9, opts.seed ^ 0xD3).with_dims(3));
    let gmedoids: Vec<Point> = gdata.points[..9].to_vec();
    let generic_evals =
        assign_points(backend.as_ref(), &gdata.points, &gmedoids, Metric::Manhattan)
            .unwrap()
            .dist_evals;
    let generic_stats = bench(
        &format!("assign {kn} pts x 9 medoids [d=3 manhattan]"),
        &bench_opts,
        || {
            assign_points(backend.as_ref(), &gdata.points, &gmedoids, Metric::Manhattan)
                .unwrap()
                .labels
                .len()
        },
    );
    let kernels = Json::Arr(vec![
        kernel_json(&assign_stats, assign_evals),
        kernel_json(&pair_stats, pair_evals),
        kernel_json(&generic_stats, generic_evals),
    ]);

    // ---- e2e thread sweep ------------------------------------------------
    let mut exp = Experiment::paper_cell(Algorithm::KMedoidsPlusPlusMR, 7, 0, opts.seed)
        .scaled(opts.scale_div.max(1));
    exp.fixed_iters = Some(6); // controlled iterations: same work per run
    let points = Arc::new(generate(&exp.spec).points);
    let repeats = if opts.smoke { 1 } else { 2 };

    // Dense-lane reference for the pruned-fraction column: same cell,
    // pruning forced off, no durability (checkpoint observers never
    // change eval counts, so this baseline also covers checkpointed
    // sweeps — where Auto runs dense and the fraction reads 0).
    let dense_e2e_evals = {
        let mut dexp = exp.clone();
        dexp.pruning = PruningMode::Off;
        let mut session = ClusterSession::builder()
            .cluster(ClusterConfig::paper_cluster())
            .nodes(7)
            .backend(backend.clone())
            .seed(opts.seed)
            .build()
            .expect("session build cannot fail with an explicit backend");
        let data = session.ingest_points("points", points.clone());
        dexp.clusterer().fit(&mut session, &data).expect("dense reference fit failed").dist_evals
    };

    header("perf: e2e wall clock vs threads (paper workload)");
    let mut rows: Vec<PerfRow> = Vec::new();
    let mut baseline: Option<(Vec<Point>, f64, f64, u64, usize)> = None;
    for &t in &threads {
        let mut builder = ClusterSession::builder()
            .cluster(ClusterConfig::paper_cluster())
            .nodes(7)
            .backend(backend.clone())
            .seed(opts.seed)
            .threads(t);
        if let Some(dir) = &opts.checkpoint_dir {
            builder = builder.checkpoint_dir(dir.clone());
        }
        let mut session =
            builder.build().unwrap_or_else(|e| panic!("perf session build failed: {e:#}"));
        let data = session.ingest_points("points", points.clone());
        let solver = exp.clusterer();
        let mut wall_s = f64::INFINITY;
        let mut outcome = None;
        for _ in 0..repeats {
            let t0 = Instant::now();
            let out = solver.fit(&mut session, &data).expect("perf e2e fit failed");
            wall_s = wall_s.min(t0.elapsed().as_secs_f64());
            outcome = Some(out);
        }
        let out = outcome.expect("at least one repeat ran");
        let summary =
            (out.medoids.clone(), out.cost, out.sim_seconds, out.dist_evals, out.iterations);
        // Record (rather than panic on) a mismatch: the caller inspects
        // `identical_outputs` / `identical_to_1_thread` and fails with the
        // full report, so a determinism regression still produces the
        // BENCH_perf.json diagnostic instead of a bare backtrace.
        let identical = match &baseline {
            None => {
                baseline = Some(summary);
                true
            }
            Some(base) => *base == summary,
        };
        let pruned_frac =
            (1.0 - out.dist_evals as f64 / dense_e2e_evals.max(1) as f64).max(0.0);
        eprintln!(
            "  [perf] threads={t:<3} wall {wall_s:>8.3}s  sim {:.1}s  cost {:.4e}  \
             pruned {:.0}%{}",
            out.sim_seconds,
            out.cost,
            pruned_frac * 100.0,
            if identical { "" } else { "  MISMATCH" }
        );
        rows.push(PerfRow {
            threads: t,
            wall_s,
            sim_seconds: out.sim_seconds,
            cost: out.cost,
            iterations: out.iterations,
            dist_evals: out.dist_evals,
            pruned_frac,
            identical,
        });
    }

    let base_wall = rows.iter().find(|r| r.threads == 1).map(|r| r.wall_s).unwrap_or(0.0);
    let mut speedup = BTreeMap::new();
    for r in &rows {
        let ratio = base_wall / r.wall_s;
        // Sub-resolution walls could yield inf/NaN, which are not JSON.
        let ratio = if ratio.is_finite() { ratio } else { 0.0 };
        speedup.insert(format!("{}", r.threads), Json::Num(ratio));
        if r.threads > 1 {
            eprintln!("  [perf] speedup @{} threads: {ratio:.2}x", r.threads);
        }
    }

    let e2e = Json::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("threads", Json::Num(r.threads as f64)),
                    ("wall_s", Json::Num(r.wall_s)),
                    ("sim_seconds", Json::Num(r.sim_seconds)),
                    ("cost", Json::Num(r.cost)),
                    ("iterations", Json::Num(r.iterations as f64)),
                    ("dist_evals", Json::Num(r.dist_evals as f64)),
                    ("pruned_frac", Json::Num(r.pruned_frac)),
                    ("identical_to_1_thread", Json::Bool(r.identical)),
                ])
            })
            .collect(),
    );

    // ---- pruned vs dense assignment-lane gate ----------------------------
    // Force the lanes explicitly (never Auto): the e2e sweep above may be
    // checkpointed (CI passes --checkpoint-dir), which Auto rightly runs
    // dense, so the gate stands up its own durability-free sessions on a
    // clustered dataset where bound pruning must pay off. Blocking checks:
    // the lanes agree byte-for-byte and the pruned lane cuts the exact
    // distance-eval count by at least PRUNING_EVAL_FLOOR.
    header("perf: pruned vs dense assignment lane (identity + eval floor)");
    let gn = if opts.smoke { 4_000 } else { 40_000 };
    let mut gexp = Experiment::paper_cell(Algorithm::KMedoidsPlusPlusMR, 7, 0, opts.seed);
    gexp.spec = SpatialSpec::new(gn, 9, opts.seed ^ 0x9E37);
    gexp.k = 16;
    gexp.update = UpdateStrategy::CentroidNearest;
    gexp.fixed_iters = Some(10);
    gexp.with_quality = true; // labels feed the identity check
    let gpoints = Arc::new(generate(&gexp.spec).points);
    let gate_fit = |mode: PruningMode| {
        let mut session = ClusterSession::builder()
            .cluster(ClusterConfig::paper_cluster())
            .nodes(7)
            .backend(backend.clone())
            .seed(opts.seed)
            .build()
            .expect("session build cannot fail with an explicit backend");
        let data = session.ingest_points("pruning-gate", gpoints.clone());
        let mut e = gexp.clone();
        e.pruning = mode;
        e.clusterer().fit(&mut session, &data).expect("pruning gate fit failed")
    };
    let dense = gate_fit(PruningMode::Off);
    let pruned = gate_fit(PruningMode::On);
    let gate_identical = pruned.medoids == dense.medoids
        && pruned.cost.to_bits() == dense.cost.to_bits()
        && pruned.iterations == dense.iterations
        && pruned.labels == dense.labels;
    let reduction = dense.dist_evals as f64 / pruned.dist_evals.max(1) as f64;
    let gate_pruned_frac =
        (1.0 - pruned.dist_evals as f64 / dense.dist_evals.max(1) as f64).max(0.0);
    let gate_ok = gate_identical && reduction >= PRUNING_EVAL_FLOOR;
    eprintln!(
        "  [perf] pruning gate: dense {} evals vs pruned {} evals -> {reduction:.1}x \
         (floor {PRUNING_EVAL_FLOOR:.1}x), identical={gate_identical}{}",
        dense.dist_evals,
        pruned.dist_evals,
        if gate_ok { "" } else { "  GATE FAILED" }
    );
    let pruning_gate = obj(vec![
        ("n_points", Json::Num(gn as f64)),
        ("k", Json::Num(gexp.k as f64)),
        ("iterations", Json::Num(dense.iterations as f64)),
        ("dense_evals", Json::Num(dense.dist_evals as f64)),
        ("pruned_evals", Json::Num(pruned.dist_evals as f64)),
        ("reduction", Json::Num(reduction)),
        ("floor", Json::Num(PRUNING_EVAL_FLOOR)),
        ("pruned_frac", Json::Num(gate_pruned_frac)),
        ("identical", Json::Bool(gate_identical)),
        ("ok", Json::Bool(gate_ok)),
    ]);

    // ---- CSV vs binary file-ingest gate ----------------------------------
    // Twin one generated dataset into a CSV file and a binary dataset
    // file, decode both back, and require (a) bit-identical points — CSV
    // floats print shortest-roundtrip, so parsing must reproduce every
    // f32 exactly — and (b) the binary decode beating the CSV parse by
    // at least INGEST_SPEEDUP_FLOOR on row rate. The binary file's
    // manifest is embedded so the artifact names the exact bytes the
    // cell measured.
    header("perf: file-ingest throughput, CSV vs binary (identity + speedup floor)");
    let in_n = if opts.smoke { 20_000 } else { 200_000 };
    let ingest_spec = SpatialSpec::new(in_n, 9, opts.seed ^ 0x51ED);
    let ingest_points = generate(&ingest_spec).points;
    let tmp = crate::util::tempdir::TempDir::new("perf-ingest");
    let csv_path = tmp.join("ingest.csv");
    let bin_path = tmp.join("ingest.bin");
    crate::geo::io::write_csv(&csv_path, &ingest_points).expect("write ingest CSV twin");
    binfmt::write_file(&bin_path, &ingest_points, None).expect("write ingest binary twin");
    let manifest = binfmt::emit_manifest(
        "perf-ingest",
        &bin_path,
        obj(vec![("generator", super::spec::spatial_spec_to_json(&ingest_spec))]),
    )
    .expect("ingest manifest");
    let mut csv_s = f64::INFINITY;
    let mut csv_points = Vec::new();
    for _ in 0..3 {
        let t0 = Instant::now();
        csv_points = crate::geo::io::read_csv(&csv_path).expect("read ingest CSV twin");
        csv_s = csv_s.min(t0.elapsed().as_secs_f64());
    }
    let mut bin_s = f64::INFINITY;
    let mut bin_points = Vec::new();
    for _ in 0..3 {
        let t0 = Instant::now();
        bin_points =
            binfmt::DatasetFile::read(&bin_path).expect("read ingest binary twin").points();
        bin_s = bin_s.min(t0.elapsed().as_secs_f64());
    }
    let ingest_identical = csv_points == ingest_points && bin_points == ingest_points;
    let ingest_speedup = if bin_s > 0.0 { csv_s / bin_s } else { 0.0 };
    let ingest_ok = ingest_identical && ingest_speedup >= INGEST_SPEEDUP_FLOOR;
    eprintln!(
        "  [perf] ingest {in_n} pts: csv {:.0} rows/s vs binary {:.0} rows/s -> \
         {ingest_speedup:.1}x (floor {INGEST_SPEEDUP_FLOOR:.1}x), identical={ingest_identical}{}",
        in_n as f64 / csv_s,
        in_n as f64 / bin_s,
        if ingest_ok { "" } else { "  GATE FAILED" }
    );
    let ingest_cell = obj(vec![
        ("n_points", Json::Num(in_n as f64)),
        ("csv_s", Json::Num(csv_s)),
        ("bin_s", Json::Num(bin_s)),
        ("csv_rows_per_s", Json::Num(in_n as f64 / csv_s)),
        ("bin_rows_per_s", Json::Num(in_n as f64 / bin_s)),
        ("speedup", Json::Num(ingest_speedup)),
        ("floor", Json::Num(INGEST_SPEEDUP_FLOOR)),
        ("identical", Json::Bool(ingest_identical)),
        ("manifest", manifest.to_json()),
        ("ok", Json::Bool(ingest_ok)),
    ]);

    obj(vec![
        ("bench", Json::Str("perf".into())),
        ("smoke", Json::Bool(opts.smoke)),
        ("backend", Json::Str(backend.name().to_string())),
        ("scale_div", Json::Num(opts.scale_div as f64)),
        ("seed", Json::Num(opts.seed as f64)),
        ("n_points", Json::Num(points.len() as f64)),
        ("kernels", kernels),
        ("e2e", e2e),
        ("speedup_vs_1_thread", Json::Obj(speedup)),
        ("pruning", pruning_gate),
        ("ingest", ingest_cell),
        ("identical_outputs", Json::Bool(rows.iter().all(|r| r.identical))),
    ])
}

/// Minimum dense/pruned exact-eval ratio the `bench perf` gate (and CI's
/// `--smoke` run) requires on the clustered gate dataset.
pub const PRUNING_EVAL_FLOOR: f64 = 3.0;

/// Minimum binary-over-CSV row-rate ratio the `bench perf` file-ingest
/// gate requires when decoding the same dataset from both formats.
pub const INGEST_SPEEDUP_FLOOR: f64 = 5.0;

fn kernel_json(stats: &crate::util::bench::Stats, dist_evals_exact: u64) -> Json {
    let mut j = stats.to_json();
    if let Json::Obj(map) = &mut j {
        map.insert("dist_evals_exact".into(), Json::Num(dist_evals_exact as f64));
        map.insert(
            "dist_evals_per_s".into(),
            Json::Num(dist_evals_exact as f64 / stats.median_s),
        );
    }
    j
}

// ---- scale bench ------------------------------------------------------------

/// Knobs for the `bench scale` suite (the paper's speedup / sizeup /
/// scaleup experiments under a fault-tolerant scheduler).
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleOpts {
    /// Divide the base dataset (Table 5 dataset 1) for the fixed-n
    /// speedup sweep; sizeup/scaleup grow multiples of that base.
    pub scale_div: usize,
    pub seed: u64,
    /// Cluster sizes of the speedup sweep; the same values serve as the
    /// growth multipliers of sizeup (fixed nodes = the sweep max) and
    /// scaleup (nodes and data grown together).
    pub nodes_sweep: Vec<usize>,
    /// Speculative execution on every suite session.
    pub speculation: bool,
    /// Run the faults-on twin of every cell and check the clustering
    /// output is byte-identical (the identity gate CI enforces).
    pub faults: bool,
    /// Fail-stop node losses injected per faulty cell (non-master).
    pub n_failures: usize,
    /// Transient per-attempt task failure rate in faulty cells.
    pub task_fail_rate: f64,
    /// Tiny-n CI mode.
    pub smoke: bool,
    /// Real-compute worker threads (wallclock only).
    pub threads: usize,
}

impl Default for ScaleOpts {
    fn default() -> Self {
        ScaleOpts {
            scale_div: 8,
            seed: 42,
            nodes_sweep: vec![1, 2, 4, 8, 16],
            speculation: true,
            faults: true,
            n_failures: 1,
            task_fail_rate: 0.02,
            smoke: false,
            threads: 1,
        }
    }
}

impl ScaleOpts {
    /// CI smoke defaults: tiny base n, short sweep, one fault per cell.
    pub fn smoke() -> ScaleOpts {
        ScaleOpts {
            scale_div: 400,
            nodes_sweep: vec![1, 2, 4],
            smoke: true,
            ..ScaleOpts::default()
        }
    }
}

/// Controlled iteration count for every scale cell: isolates the scaling
/// curves from per-dataset convergence luck, as in Table 6.
const SCALE_ITERS: usize = 4;

/// One (experiment, algorithm, nodes, n) cell of the scale bench.
#[derive(Clone)]
struct ScaleCell {
    experiment: &'static str,
    algorithm: &'static str,
    nodes: usize,
    n_points: usize,
    time_ms: u64,
    iterations: usize,
    cost: f64,
    dist_evals: u64,
    jobs: usize,
    attempts: usize,
    speculative: usize,
    failed_attempts: usize,
    node_local: usize,
    host_local: usize,
    remote: usize,
    wall_s: f64,
    fault: Option<FaultCell>,
}

/// The faults-on twin of a cell: same clustering work under a seeded
/// fault plan, plus the byte-identity verdict.
#[derive(Clone)]
struct FaultCell {
    time_ms: u64,
    failed_attempts: usize,
    n_node_failures: usize,
    task_fail_rate: f64,
    identical: bool,
}

impl ScaleCell {
    fn locality_ratio(&self) -> f64 {
        locality_fraction(self.node_local, self.host_local, self.remote)
    }

    fn to_json(&self) -> Json {
        let fault = match &self.fault {
            None => Json::Null,
            Some(f) => obj(vec![
                ("time_ms", Json::Num(f.time_ms as f64)),
                ("failed_attempts", Json::Num(f.failed_attempts as f64)),
                ("n_node_failures", Json::Num(f.n_node_failures as f64)),
                ("task_fail_rate", Json::Num(f.task_fail_rate)),
                ("identical", Json::Bool(f.identical)),
            ]),
        };
        obj(vec![
            ("experiment", Json::Str(self.experiment.to_string())),
            ("algorithm", Json::Str(self.algorithm.to_string())),
            ("nodes", Json::Num(self.nodes as f64)),
            ("n_points", Json::Num(self.n_points as f64)),
            ("time_ms", Json::Num(self.time_ms as f64)),
            ("iterations", Json::Num(self.iterations as f64)),
            ("cost", Json::Num(self.cost)),
            ("dist_evals", Json::Num(self.dist_evals as f64)),
            ("jobs", Json::Num(self.jobs as f64)),
            (
                "attempts",
                obj(vec![
                    ("total", Json::Num(self.attempts as f64)),
                    ("speculative", Json::Num(self.speculative as f64)),
                    ("failed", Json::Num(self.failed_attempts as f64)),
                ]),
            ),
            (
                "locality",
                obj(vec![
                    ("node_local", Json::Num(self.node_local as f64)),
                    ("host_local", Json::Num(self.host_local as f64)),
                    ("remote", Json::Num(self.remote as f64)),
                    ("node_local_ratio", Json::Num(self.locality_ratio())),
                ]),
            ),
            ("wall_s", Json::Num(self.wall_s)),
            ("fault", fault),
        ])
    }
}

/// Everything one fit contributes to a cell row.
struct ScaleFit {
    out: ClusterOutcome,
    jobs: usize,
    attempts: usize,
    speculative: usize,
    failed: usize,
    node_local: usize,
    host_local: usize,
    remote: usize,
    wall_s: f64,
}

fn scale_fit(
    backend: &Arc<dyn ComputeBackend>,
    opts: &ScaleOpts,
    algo: Algorithm,
    nodes: usize,
    points: &Arc<Vec<Point>>,
    plan: Option<FaultPlan>,
) -> ScaleFit {
    let mut builder = ClusterSession::builder()
        .cluster(ClusterConfig::commodity_cluster(nodes))
        .backend(backend.clone())
        .seed(opts.seed)
        .threads(opts.threads)
        .speculation(opts.speculation);
    if let Some(p) = plan {
        // Transient failures must stay transient: size the retry budget
        // so that the chance of any task exhausting it is ~1e-12 even at
        // the highest accepted fail rate (an identity cell must never
        // abort on a retryable fault).
        let rate = p.task_fail_rate;
        let budget = if rate > 0.0 && rate < 1.0 {
            ((1e-12f64).ln() / rate.ln()).ceil() as usize
        } else {
            0
        };
        builder = builder.faults(p).max_attempts(budget.clamp(16, 512));
    }
    let mut session = builder.build().expect("session build cannot fail with an explicit backend");
    let data = session.ingest_points("points", points.clone());
    let mut exp = Experiment::paper_cell(algo, nodes, 0, opts.seed);
    exp.spec = SpatialSpec::new(points.len(), 9, opts.seed);
    exp.fixed_iters = Some(SCALE_ITERS);
    let wall0 = Instant::now();
    let out = exp.clusterer().fit(&mut session, &data).expect("scale cell failed");
    let wall_s = wall0.elapsed().as_secs_f64();
    let h = session.history();
    ScaleFit {
        jobs: session.jobs_run(),
        attempts: h.iter().map(|j| j.n_attempts).sum(),
        speculative: h.iter().map(|j| j.n_speculative).sum(),
        failed: h.iter().map(|j| j.n_failed_attempts).sum(),
        node_local: h.iter().map(|j| j.n_node_local_maps).sum(),
        host_local: h.iter().map(|j| j.n_host_local_maps).sum(),
        remote: h.iter().map(|j| j.n_remote_maps).sum(),
        out,
        wall_s,
    }
}

fn scale_cell(
    backend: &Arc<dyn ComputeBackend>,
    opts: &ScaleOpts,
    experiment: &'static str,
    algo: Algorithm,
    nodes: usize,
    points: &Arc<Vec<Point>>,
) -> ScaleCell {
    let healthy = scale_fit(backend, opts, algo, nodes, points, None);
    let fault = if opts.faults {
        // Kill nodes inside the healthy run's window so the loss always
        // lands mid-computation; the plan is pure function of the cell.
        let plan = FaultPlan::seeded(
            opts.seed ^ ((nodes as u64) << 8) ^ points.len() as u64,
            nodes,
            opts.n_failures,
            healthy.out.sim_seconds,
            opts.task_fail_rate,
        );
        let n_node_failures = plan.node_failures.len();
        let task_fail_rate = plan.task_fail_rate;
        let faulty = scale_fit(backend, opts, algo, nodes, points, Some(plan));
        let identical = faulty.out.medoids == healthy.out.medoids
            && faulty.out.cost == healthy.out.cost
            && faulty.out.dist_evals == healthy.out.dist_evals
            && faulty.out.iterations == healthy.out.iterations;
        Some(FaultCell {
            time_ms: (faulty.out.sim_seconds * 1e3).round() as u64,
            failed_attempts: faulty.failed,
            n_node_failures,
            task_fail_rate,
            identical,
        })
    } else {
        None
    };
    ScaleCell {
        experiment,
        algorithm: algo.name(),
        nodes,
        n_points: points.len(),
        time_ms: (healthy.out.sim_seconds * 1e3).round() as u64,
        iterations: healthy.out.iterations,
        cost: healthy.out.cost,
        dist_evals: healthy.out.dist_evals,
        jobs: healthy.jobs,
        attempts: healthy.attempts,
        speculative: healthy.speculative,
        failed_attempts: healthy.failed,
        node_local: healthy.node_local,
        host_local: healthy.host_local,
        remote: healthy.remote,
        wall_s: healthy.wall_s,
        fault,
    }
}

/// Per-algorithm ratio curves for one experiment, as `[x, ratio]` pairs
/// in ascending-x order (object keys would sort lexicographically —
/// "16" before "2"). `invert` selects base/t (speedup: bigger is
/// better) vs t/base (sizeup/scaleup growth).
fn ratio_curves(cells: &[ScaleCell], experiment: &str, invert: bool) -> Json {
    let mut by_algo: BTreeMap<String, Vec<(usize, u64)>> = BTreeMap::new();
    for c in cells.iter().filter(|c| c.experiment == experiment) {
        let x = if experiment == "sizeup" { c.n_points } else { c.nodes };
        by_algo.entry(c.algorithm.to_string()).or_default().push((x, c.time_ms));
    }
    let mut out = BTreeMap::new();
    for (algo, mut pts) in by_algo {
        pts.sort_unstable();
        let base = pts.first().map(|&(_, t)| t).unwrap_or(1).max(1);
        let curve: Vec<Json> = pts
            .iter()
            .map(|&(x, t)| {
                let t = t.max(1);
                let r = if invert { base as f64 / t as f64 } else { t as f64 / base as f64 };
                Json::Arr(vec![Json::Num(x as f64), Json::Num(r)])
            })
            .collect();
        out.insert(algo, Json::Arr(curve));
    }
    Json::Obj(out)
}

/// The paper's three scaling experiments — speedup (fixed n, growing
/// cluster), sizeup (fixed cluster, growing n), scaleup (both grown
/// together) — for the four MR algorithms (the three iterative drivers
/// plus the constant-round coreset pipeline), on the commodity cluster
/// with the fault-tolerant scheduler. Every cell reports sim time, job
/// and iteration counts, locality ratios, and attempt statistics; with
/// [`ScaleOpts::faults`] each cell also runs a fault-injected twin and
/// verifies the clustering output is byte-identical. Returns the
/// `BENCH_scale.json` document.
pub fn scale_suite(backend: &Arc<dyn ComputeBackend>, opts: &ScaleOpts) -> Json {
    let mut sweep = opts.nodes_sweep.clone();
    sweep.retain(|&n| n >= 1);
    sweep.sort_unstable();
    sweep.dedup();
    if sweep.is_empty() {
        sweep = ScaleOpts::default().nodes_sweep;
    }
    let max_nodes = *sweep.last().unwrap();
    let algos = [
        Algorithm::KMedoidsPlusPlusMR,
        Algorithm::KMedoidsRandomMR,
        Algorithm::KMedoidsScalableMR,
        Algorithm::KMedoidsCoresetMR,
    ];
    let n_base = SpatialSpec::paper_dataset_scaled(0, opts.scale_div.max(1), opts.seed).n_points;

    // One generation per distinct size, shared across every session.
    let mut cache: BTreeMap<usize, Arc<Vec<Point>>> = BTreeMap::new();
    fn dataset(
        cache: &mut BTreeMap<usize, Arc<Vec<Point>>>,
        n: usize,
        seed: u64,
    ) -> Arc<Vec<Point>> {
        cache
            .entry(n)
            .or_insert_with(|| Arc::new(generate(&SpatialSpec::new(n, 9, seed)).points))
            .clone()
    }

    let mut cells: Vec<ScaleCell> = Vec::new();
    // The three experiments overlap at their corners (e.g. scaleup m=1
    // is the same cell as speedup nodes=1): memoize by (algo, nodes, n)
    // so each distinct cell — and its fault twin — is computed once.
    let mut memo: BTreeMap<(&'static str, usize, usize), ScaleCell> = BTreeMap::new();
    let run = |cells: &mut Vec<ScaleCell>,
               cache: &mut BTreeMap<usize, Arc<Vec<Point>>>,
               memo: &mut BTreeMap<(&'static str, usize, usize), ScaleCell>,
               experiment: &'static str,
               nodes: usize,
               n: usize| {
        let pts = dataset(cache, n, opts.seed);
        for algo in algos {
            let mut c = match memo.get(&(algo.name(), nodes, n)) {
                Some(cached) => cached.clone(),
                None => {
                    let fresh = scale_cell(backend, opts, experiment, algo, nodes, &pts);
                    memo.insert((algo.name(), nodes, n), fresh.clone());
                    fresh
                }
            };
            c.experiment = experiment;
            let verdict = match &c.fault {
                Some(f) if !f.identical => "  IDENTITY MISMATCH",
                Some(_) => "  faults: identical",
                None => "",
            };
            eprintln!(
                "  [scale/{experiment}] {:<22} nodes={:<3} n={:<8} -> {:>8} ms  ({} jobs, \
                 locality {:.2}){verdict}",
                c.algorithm,
                nodes,
                n,
                c.time_ms,
                c.jobs,
                c.locality_ratio(),
            );
            cells.push(c);
        }
    };

    header("scale: speedup (fixed n, growing cluster)");
    for &nodes in &sweep {
        run(&mut cells, &mut cache, &mut memo, "speedup", nodes, n_base);
    }
    header("scale: sizeup (fixed cluster, growing n)");
    for &m in &sweep {
        run(&mut cells, &mut cache, &mut memo, "sizeup", max_nodes, n_base * m);
    }
    header("scale: scaleup (cluster and n grown together)");
    for &m in &sweep {
        run(&mut cells, &mut cache, &mut memo, "scaleup", m, n_base * m);
    }

    let identity_ok =
        cells.iter().all(|c| c.fault.as_ref().map(|f| f.identical).unwrap_or(true));
    let faults = if opts.faults {
        obj(vec![
            ("n_failures", Json::Num(opts.n_failures as f64)),
            ("task_fail_rate", Json::Num(opts.task_fail_rate)),
        ])
    } else {
        Json::Bool(false)
    };
    obj(vec![
        ("bench", Json::Str("scale".into())),
        ("smoke", Json::Bool(opts.smoke)),
        ("backend", Json::Str(backend.name().to_string())),
        ("seed", Json::Num(opts.seed as f64)),
        ("scale_div", Json::Num(opts.scale_div as f64)),
        ("n_base", Json::Num(n_base as f64)),
        (
            "nodes_sweep",
            Json::Arr(sweep.iter().map(|&n| Json::Num(n as f64)).collect()),
        ),
        ("speculation", Json::Bool(opts.speculation)),
        ("faults", faults),
        ("cells", Json::Arr(cells.iter().map(ScaleCell::to_json).collect())),
        ("speedup", ratio_curves(&cells, "speedup", true)),
        ("sizeup", ratio_curves(&cells, "sizeup", false)),
        ("scaleup", ratio_curves(&cells, "scaleup", false)),
        ("identity_ok", Json::Bool(identity_ok)),
    ])
}

// ---- lanes bench ------------------------------------------------------------

/// Knobs for the `bench lanes` suite (the Hadoop MR lane vs the
/// in-memory DAG lane, per MR algorithm, across cluster sizes).
#[derive(Debug, Clone, PartialEq)]
pub struct LanesOpts {
    /// Divide the base dataset (Table 5 dataset 1).
    pub scale_div: usize,
    pub seed: u64,
    /// Cluster sizes swept for every algorithm × lane pair.
    pub nodes_sweep: Vec<usize>,
    /// Real-compute worker threads (wallclock only).
    pub threads: usize,
    /// Tiny-n CI mode.
    pub smoke: bool,
}

impl Default for LanesOpts {
    fn default() -> Self {
        LanesOpts {
            scale_div: 32,
            seed: 42,
            nodes_sweep: vec![1, 2, 4, 8],
            threads: 1,
            smoke: false,
        }
    }
}

impl LanesOpts {
    /// CI smoke defaults: tiny base n, short sweep, same JSON schema.
    pub fn smoke() -> LanesOpts {
        LanesOpts {
            scale_div: 400,
            nodes_sweep: vec![1, 2, 4],
            smoke: true,
            ..LanesOpts::default()
        }
    }
}

/// Controlled iteration count for every lanes cell (as in `bench
/// scale`): both lanes must do the same algorithmic work for the
/// identity gate to mean anything, and pinning the count keeps that
/// visibly so.
const LANES_ITERS: usize = 4;

/// What one lane's fit contributes to a lanes cell.
struct LaneFit {
    out: ClusterOutcome,
    jobs: usize,
    wall_s: f64,
}

fn lane_fit(
    backend: &Arc<dyn ComputeBackend>,
    opts: &LanesOpts,
    algo: Algorithm,
    nodes: usize,
    lane: Lane,
    points: &Arc<Vec<Point>>,
) -> LaneFit {
    let mut session = ClusterSession::builder()
        .cluster(ClusterConfig::commodity_cluster(nodes))
        .backend(backend.clone())
        .seed(opts.seed)
        .threads(opts.threads)
        .lane(lane)
        .build()
        .expect("session build cannot fail with an explicit backend");
    let data = session.ingest_points("points", points.clone());
    let mut exp = Experiment::paper_cell(algo, nodes, 0, opts.seed);
    exp.spec = SpatialSpec::new(points.len(), 9, opts.seed);
    exp.fixed_iters = Some(LANES_ITERS);
    exp.with_quality = true; // labels feed the identity gate
    exp.lane = lane;
    let wall0 = Instant::now();
    let out = exp.clusterer().fit(&mut session, &data).expect("lanes cell failed");
    LaneFit { jobs: session.jobs_run(), out, wall_s: wall0.elapsed().as_secs_f64() }
}

/// The MR-vs-DAG comparison (the arXiv 1605.01802 axis): every MR
/// algorithm × cluster size runs the identical fit once per execution
/// lane on the same ingested dataset, and the suite gates on two
/// blocking verdicts — `identity_ok` (the DAG-lane fit is
/// byte-identical to the Hadoop-lane fit: medoids, cost bits,
/// iterations, labels, job counts, and exact distance-eval counts) and
/// `dag_faster_ok` (the DAG lane's simulated time is strictly below the
/// Hadoop lane's in every cell). Returns the `BENCH_lanes.json`
/// document.
pub fn lanes_suite(backend: &Arc<dyn ComputeBackend>, opts: &LanesOpts) -> Json {
    let mut sweep = opts.nodes_sweep.clone();
    sweep.retain(|&n| n >= 1);
    sweep.sort_unstable();
    sweep.dedup();
    if sweep.is_empty() {
        sweep = LanesOpts::default().nodes_sweep;
    }
    let algos = [
        Algorithm::KMedoidsPlusPlusMR,
        Algorithm::KMedoidsRandomMR,
        Algorithm::KMedoidsScalableMR,
        Algorithm::KMedoidsCoresetMR,
    ];
    let spec = SpatialSpec::paper_dataset_scaled(0, opts.scale_div.max(1), opts.seed);
    let points = Arc::new(generate(&spec).points);
    let k = Experiment::paper_cell(Algorithm::KMedoidsPlusPlusMR, 1, 0, opts.seed).k;

    header("lanes: hadoop-mr vs in-memory-dag (identity + sim time)");
    let mut cells: Vec<Json> = Vec::new();
    let mut ratios: BTreeMap<String, Vec<(usize, f64)>> = BTreeMap::new();
    let mut identity_ok = true;
    let mut dag_faster_ok = true;
    for algo in algos {
        for &nodes in &sweep {
            let mr = lane_fit(backend, opts, algo, nodes, Lane::HadoopMr, &points);
            let dag = lane_fit(backend, opts, algo, nodes, Lane::InMemoryDag, &points);
            let identical = dag.out.medoids == mr.out.medoids
                && dag.out.cost.to_bits() == mr.out.cost.to_bits()
                && dag.out.iterations == mr.out.iterations
                && dag.out.labels == mr.out.labels
                && dag.out.dist_evals == mr.out.dist_evals
                && dag.jobs == mr.jobs;
            let dag_faster = dag.out.sim_seconds < mr.out.sim_seconds;
            identity_ok &= identical;
            dag_faster_ok &= dag_faster;
            let ratio = mr.out.sim_seconds / dag.out.sim_seconds.max(1e-9);
            ratios.entry(algo.name().to_string()).or_default().push((nodes, ratio));
            let verdict = match (identical, dag_faster) {
                (false, _) => "  IDENTITY MISMATCH",
                (true, false) => "  DAG NOT FASTER",
                (true, true) => "",
            };
            eprintln!(
                "  [lanes] {:<22} nodes={:<3} -> mr {:>8} ms vs dag {:>8} ms \
                 ({ratio:.1}x){verdict}",
                algo.name(),
                nodes,
                (mr.out.sim_seconds * 1e3).round() as u64,
                (dag.out.sim_seconds * 1e3).round() as u64,
            );
            cells.push(obj(vec![
                ("algorithm", Json::Str(algo.name().to_string())),
                ("nodes", Json::Num(nodes as f64)),
                ("n_points", Json::Num(points.len() as f64)),
                ("mr_time_ms", Json::Num((mr.out.sim_seconds * 1e3).round())),
                ("dag_time_ms", Json::Num((dag.out.sim_seconds * 1e3).round())),
                ("speedup", Json::Num(ratio)),
                ("jobs", Json::Num(mr.jobs as f64)),
                ("iterations", Json::Num(mr.out.iterations as f64)),
                ("cost", Json::Num(mr.out.cost)),
                ("dist_evals", Json::Num(mr.out.dist_evals as f64)),
                ("wall_s", Json::Num(mr.wall_s + dag.wall_s)),
                ("identical", Json::Bool(identical)),
                ("dag_faster", Json::Bool(dag_faster)),
            ]));
        }
    }

    // Per-algorithm speedup curves as `[nodes, mr/dag]` pairs in
    // ascending-nodes order (same shape as the scale bench's curves).
    let speedup = Json::Obj(
        ratios
            .into_iter()
            .map(|(algo, mut pts)| {
                pts.sort_unstable_by_key(|&(n, _)| n);
                let curve: Vec<Json> = pts
                    .iter()
                    .map(|&(n, r)| Json::Arr(vec![Json::Num(n as f64), Json::Num(r)]))
                    .collect();
                (algo, Json::Arr(curve))
            })
            .collect(),
    );

    obj(vec![
        ("bench", Json::Str("lanes".into())),
        ("smoke", Json::Bool(opts.smoke)),
        ("backend", Json::Str(backend.name().to_string())),
        ("seed", Json::Num(opts.seed as f64)),
        ("scale_div", Json::Num(opts.scale_div.max(1) as f64)),
        ("n_points", Json::Num(points.len() as f64)),
        ("k", Json::Num(k as f64)),
        ("fixed_iters", Json::Num(LANES_ITERS as f64)),
        (
            "nodes_sweep",
            Json::Arr(sweep.iter().map(|&n| Json::Num(n as f64)).collect()),
        ),
        ("cells", Json::Arr(cells)),
        ("speedup", speedup),
        ("identity_ok", Json::Bool(identity_ok)),
        ("dag_faster_ok", Json::Bool(dag_faster_ok)),
    ])
}

// ---------------------------------------------------------------------------
// Serving bench: mixed nearest-medoid query / mini-batch update workload.
// ---------------------------------------------------------------------------

/// Knobs for `bench serve` — a mixed online workload over one published
/// model: reader threads stream nearest-medoid queries through lock-free
/// [`crate::serve::ModelHandle::load`]s while the driver thread ingests
/// delta mini-batches that re-weight the coreset, refine the medoids,
/// and epoch-swap a new snapshot.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Divide the Table 5 dataset-1 size (same axis as the other benches).
    pub scale_div: usize,
    pub seed: u64,
    /// Reader-thread counts to sweep.
    pub threads: Vec<usize>,
    /// Total queries per sweep point (split across the readers).
    pub queries: usize,
    /// Delta points ingested per sweep point, as a fraction of `queries`.
    pub update_frac: f64,
    /// Serving mini-batch size (one epoch swap per full batch).
    pub batch: usize,
    /// Coreset budget override (`None` = the k·(log₂n + 1) default).
    pub coreset_size: Option<usize>,
    pub smoke: bool,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            scale_div: 40,
            seed: 42,
            threads: vec![1, 4],
            queries: 20_000,
            update_frac: 0.2,
            batch: 256,
            coreset_size: None,
            smoke: false,
        }
    }
}

impl ServeOpts {
    /// CI preset: small dataset, short query stream, same JSON schema.
    pub fn smoke() -> ServeOpts {
        ServeOpts { scale_div: 400, queries: 5_000, batch: 128, smoke: true, ..Default::default() }
    }
}

/// Draw a serving stream by jittering base points. `shift` biases every
/// draw in +x/+y so delta streams actually move mass (queries use 0).
fn serve_stream(points: &[Point], n: usize, jitter: f32, shift: f32, rng: &mut Rng) -> Vec<Point> {
    (0..n)
        .map(|_| {
            let p = &points[rng.below(points.len())];
            let dx = (rng.f64() as f32 - 0.5) * jitter + shift;
            let dy = (rng.f64() as f32 - 0.5) * jitter + shift;
            Point::new(p.x() + dx, p.y() + dy)
        })
        .collect()
}

/// Nearest-rank percentile over an already-sorted sample.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize).saturating_sub(1);
    sorted[idx.min(sorted.len() - 1)]
}

/// Serving bench: fit the coreset pipeline once, publish the model, then
/// for each reader-thread count replay a mixed workload — readers hammer
/// [`crate::serve::ClusterModel::assign`] through epoch-swapped handle
/// loads while the driver ingests delta mini-batches. Emits the
/// `BENCH_serve.json` document with two blocking gates: `identity_ok`
/// (serving answers byte-identical to a batch assign pass over the fit's
/// medoids) and `cost_monotone_ok` (no ingest-then-refine step increased
/// the weighted coreset cost).
pub fn serve_suite(backend: &Arc<dyn ComputeBackend>, opts: &ServeOpts) -> Json {
    let mut threads = opts.threads.clone();
    threads.retain(|&t| t >= 1);
    threads.sort_unstable();
    threads.dedup();
    if threads.is_empty() {
        threads = ServeOpts::default().threads;
    }

    header("serve: base fit + publish");
    let mut exp = Experiment::paper_cell(Algorithm::KMedoidsCoresetMR, 4, 0, opts.seed)
        .scaled(opts.scale_div.max(1));
    exp.with_quality = true; // labels feed the identity gate below
    exp.coreset_size = opts.coreset_size;
    let points = Arc::new(generate(&exp.spec).points);
    let mut session = ClusterSession::builder()
        .cluster(ClusterConfig::paper_cluster())
        .nodes(exp.n_nodes)
        .backend(backend.clone())
        .seed(opts.seed)
        .build()
        .expect("session build cannot fail with an explicit backend");
    let data = session.ingest_points("serve-base", points.clone());
    let out = exp.clusterer().fit(&mut session, &data).expect("serve base fit failed");
    let cfg = ServeConfig {
        batch_size: opts.batch.max(1),
        coreset_size: opts.coreset_size,
        ..ServeConfig::default()
    };
    let base =
        ServeSession::from_fit(&session, &data, &out, exp.metric, cfg).expect("serve stand-up");
    let model = base.model();

    // Identity gate: the serving path (grid-pruned single-point assign
    // and the chunked batch walk) must agree bitwise with one flat
    // kernel pass over the fit's medoids, and with the fit's own labels.
    let (slabels, sdists) = model.assign_batch(points.as_slice());
    let fresh = assign_points(backend.as_ref(), &points, &out.medoids, exp.metric)
        .expect("oracle assign pass failed");
    let mut identity_ok = slabels == fresh.labels
        && sdists.len() == fresh.mindists.len()
        && sdists.iter().zip(&fresh.mindists).all(|(a, b)| a.to_bits() == b.to_bits());
    if let Some(labels) = &out.labels {
        identity_ok &= slabels == *labels;
    }
    let stride = (points.len() / 64).max(1);
    for i in (0..points.len()).step_by(stride) {
        let (l, d) = model.assign(&points[i]);
        identity_ok &= l == slabels[i] && d.to_bits() == sdists[i].to_bits();
    }
    eprintln!(
        "  [serve] n={} k={} coreset={} grid_index={} identity_ok={}",
        points.len(),
        model.k(),
        base.coreset_len(),
        model.has_grid_index(),
        identity_ok,
    );

    header("serve: mixed query/update sweep");
    let n_updates = ((opts.queries as f64) * opts.update_frac.max(0.0)).round() as usize;
    let mut cost_monotone_ok = true;
    let mut rows: Vec<Json> = Vec::new();
    for &t in &threads {
        // Fresh session per sweep point: `from_fit` is deterministic in
        // the session seed, so every thread count replays the identical
        // update sequence and only the read-side concurrency varies.
        let mut serve = ServeSession::from_fit(&session, &data, &out, exp.metric, cfg)
            .expect("serve stand-up");
        let reader_queries: Vec<Vec<Point>> = (0..t)
            .map(|r| {
                let mut rng = Rng::new(opts.seed ^ 0x0BE5 ^ ((r as u64) << 16));
                serve_stream(&points, opts.queries.div_ceil(t), 250.0, 0.0, &mut rng)
            })
            .collect();
        let mut rng = Rng::new(opts.seed ^ 0xD17A);
        let deltas = serve_stream(&points, n_updates, 250.0, 1500.0, &mut rng);
        let handle = serve.handle();
        let mut last = None;
        let wall0 = Instant::now();
        let lats: Vec<Vec<f64>> = std::thread::scope(|scope| {
            let readers: Vec<_> = reader_queries
                .iter()
                .map(|qs| {
                    let handle = handle.clone();
                    scope.spawn(move || {
                        let mut lat = Vec::with_capacity(qs.len());
                        for q in qs {
                            let t0 = Instant::now();
                            let m = handle.load();
                            std::hint::black_box(m.assign(q));
                            lat.push(t0.elapsed().as_secs_f64());
                        }
                        lat
                    })
                })
                .collect();
            // The driver thread is the writer: ingest the delta stream
            // in mini-batches while the readers run.
            for chunk in deltas.chunks(opts.batch.max(1)) {
                if serve.ingest(chunk).expect("serve ingest failed") > 0 {
                    if let Some(rep) = serve.last_update() {
                        cost_monotone_ok &= rep.cost_after <= rep.cost_before * (1.0 + 1e-6);
                        last = Some(rep);
                    }
                }
            }
            if serve.flush().expect("serve flush failed") {
                if let Some(rep) = serve.last_update() {
                    cost_monotone_ok &= rep.cost_after <= rep.cost_before * (1.0 + 1e-6);
                    last = Some(rep);
                }
            }
            readers.into_iter().map(|r| r.join().expect("reader panicked")).collect()
        });
        let wall_s = wall0.elapsed().as_secs_f64();
        let mut all: Vec<f64> = lats.into_iter().flatten().collect();
        all.sort_by(f64::total_cmp);
        let throughput = all.len() as f64 / wall_s.max(1e-9);
        let (p50, p99, p999) =
            (percentile(&all, 0.50), percentile(&all, 0.99), percentile(&all, 0.999));
        let final_epoch = handle.epoch();
        eprintln!(
            "  [serve] threads={:<3} -> {:>9.0} q/s  p50={:>7.1}us p99={:>7.1}us \
             p999={:>7.1}us  ({} updates, epoch {})",
            t,
            throughput,
            p50 * 1e6,
            p99 * 1e6,
            p999 * 1e6,
            serve.updates(),
            final_epoch,
        );
        rows.push(obj(vec![
            ("threads", Json::Num(t as f64)),
            ("wall_s", Json::Num(wall_s)),
            ("throughput_qps", Json::Num(throughput)),
            ("p50_s", Json::Num(p50)),
            ("p99_s", Json::Num(p99)),
            ("p999_s", Json::Num(p999)),
            ("updates", Json::Num(serve.updates() as f64)),
            ("epochs_published", Json::Num(handle.epochs_published() as f64)),
            ("final_epoch", Json::Num(final_epoch as f64)),
            ("cost_before", Json::Num(last.map(|r| r.cost_before).unwrap_or(0.0))),
            ("cost_after", Json::Num(last.map(|r| r.cost_after).unwrap_or(0.0))),
        ]));
    }

    obj(vec![
        ("bench", Json::Str("serve".into())),
        ("smoke", Json::Bool(opts.smoke)),
        ("backend", Json::Str(backend.name().to_string())),
        ("seed", Json::Num(opts.seed as f64)),
        ("scale_div", Json::Num(opts.scale_div.max(1) as f64)),
        ("n_points", Json::Num(points.len() as f64)),
        ("k", Json::Num(out.medoids.len() as f64)),
        ("queries", Json::Num(opts.queries as f64)),
        ("update_frac", Json::Num(opts.update_frac)),
        ("batch", Json::Num(opts.batch.max(1) as f64)),
        ("coreset_target", Json::Num(base.coreset_len() as f64)),
        ("identity_ok", Json::Bool(identity_ok)),
        ("cost_monotone_ok", Json::Bool(cost_monotone_ok)),
        ("sweep", Json::Arr(rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn be() -> Arc<dyn ComputeBackend> {
        Arc::new(NativeBackend::new(256, 16))
    }

    #[test]
    fn table6_suite_small_has_12_cells_and_paper_shape() {
        // Heavy scale-down: structure test, not numbers. At this scale
        // each dataset is a single DFS block (one map task), so adding
        // nodes only re-shapes the reduce waves — allow 2% wobble from
        // slow-node placement; the strict monotonicity check runs at full
        // scale in the table6_scaling bench.
        let rs = table6_suite(&be(), &SuiteOpts::new(200, 5));
        assert_eq!(rs.len(), 12);
        assert!(rs.iter().all(|r| r.iterations == 6), "controlled iterations");
        for ds in [rs[0].n_points, rs[4].n_points, rs[8].n_points] {
            let times: Vec<u64> = rs
                .iter()
                .filter(|r| r.n_points == ds)
                .map(|r| r.time_ms)
                .collect();
            assert_eq!(times.len(), 4);
            assert!(
                times.windows(2).all(|w| w[1] as f64 <= w[0] as f64 * 1.02),
                "time should not grow materially with nodes: {times:?}"
            );
        }
        // Larger dataset takes longer at fixed cluster size.
        assert!(rs[0].time_ms <= rs[8].time_ms);
    }

    #[test]
    fn perf_suite_smoke_is_consistent() {
        let opts = PerfOpts {
            scale_div: 2000,
            seed: 5,
            threads: vec![2],
            smoke: true,
            checkpoint_dir: None,
        };
        let j = perf_suite(&be(), &opts);
        assert_eq!(j.get("bench").unwrap().as_str(), Some("perf"));
        // 1 thread is added automatically as the speedup base.
        let e2e = j.get("e2e").unwrap().as_arr().unwrap();
        assert_eq!(e2e.len(), 2);
        assert_eq!(e2e[0].get("threads").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("identical_outputs").unwrap().as_bool(), Some(true));
        let s1 = j.get("speedup_vs_1_thread").unwrap().get("1").unwrap().as_f64().unwrap();
        assert!((s1 - 1.0).abs() < 1e-9);
        assert_eq!(j.get("kernels").unwrap().as_arr().unwrap().len(), 3);
        // Kernel throughput derives from the counted kernels (n×k here by
        // construction for the dense assign bench).
        let k0 = &j.get("kernels").unwrap().as_arr().unwrap()[0];
        assert_eq!(k0.get("dist_evals_exact").unwrap().as_f64(), Some((8_192 * 9) as f64));
        // The pruning gate holds: byte-identical lanes and the exact eval
        // count down by at least the declared floor.
        let gate = j.get("pruning").unwrap();
        assert_eq!(gate.get("identical").unwrap().as_bool(), Some(true));
        assert_eq!(gate.get("ok").unwrap().as_bool(), Some(true));
        let red = gate.get("reduction").unwrap().as_f64().unwrap();
        assert!(red >= PRUNING_EVAL_FLOOR, "pruning reduction {red:.2}x below floor");
        // No checkpoint sink in this sweep, so Auto prunes the e2e rows.
        let e2e0 = &j.get("e2e").unwrap().as_arr().unwrap()[0];
        assert!(e2e0.get("pruned_frac").unwrap().as_f64().unwrap() > 0.0);
        // The file-ingest gate holds: both formats decode the same points
        // and the binary lane clears the row-rate floor, with a manifest
        // whose checksum names the measured bytes.
        let ing = j.get("ingest").unwrap();
        assert_eq!(ing.get("identical").unwrap().as_bool(), Some(true));
        assert_eq!(ing.get("ok").unwrap().as_bool(), Some(true));
        let sp = ing.get("speedup").unwrap().as_f64().unwrap();
        assert!(sp >= INGEST_SPEEDUP_FLOOR, "ingest speedup {sp:.2}x below floor");
        let man = ing.get("manifest").unwrap();
        assert_eq!(man.get("format").unwrap().as_str(), Some(binfmt::FORMAT_BINARY));
        assert_eq!(man.get("count").unwrap().as_usize(), ing.get("n_points").unwrap().as_usize());
        // The document is valid, re-parseable JSON.
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn perf_suite_checkpointed_sweep_runs_dense() {
        // CI runs `bench perf --smoke --checkpoint-dir ...`: with a durable
        // sink attached, Auto must fall back to the dense lane (bounds are
        // not persisted), so the pruned fraction reads exactly 0 while the
        // explicit-lane gate still passes.
        let dir = std::env::temp_dir().join(format!("perf-ckpt-gate-{}", std::process::id()));
        let opts = PerfOpts {
            scale_div: 2000,
            seed: 5,
            threads: vec![1],
            smoke: true,
            checkpoint_dir: Some(dir.clone()),
        };
        let j = perf_suite(&be(), &opts);
        let _ = std::fs::remove_dir_all(&dir);
        for row in j.get("e2e").unwrap().as_arr().unwrap() {
            assert_eq!(row.get("pruned_frac").unwrap().as_f64(), Some(0.0));
        }
        assert_eq!(j.get("pruning").unwrap().get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("ingest").unwrap().get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("identical_outputs").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn scale_suite_smoke_structure_and_identity() {
        let mut opts = ScaleOpts::smoke();
        opts.scale_div = 1300; // ~1000 points per base cell
        opts.nodes_sweep = vec![1, 2];
        opts.task_fail_rate = 0.1;
        let j = scale_suite(&be(), &opts);
        assert_eq!(j.get("bench").unwrap().as_str(), Some("scale"));
        let cells = j.get("cells").unwrap().as_arr().unwrap();
        // 4 algorithms x (speedup + sizeup + scaleup) x 2 sweep points.
        assert_eq!(cells.len(), 4 * 3 * 2);
        // Every cell ran its faults-on twin and stayed byte-identical —
        // the determinism contract the CI gate enforces.
        assert_eq!(j.get("identity_ok").unwrap().as_bool(), Some(true));
        for c in cells {
            let f = c.get("fault").unwrap();
            assert_eq!(f.get("identical").and_then(|b| b.as_bool()), Some(true), "{c}");
            assert!(c.get("jobs").unwrap().as_usize().unwrap() > 0);
            let loc = c.get("locality").unwrap();
            let ratio = loc.get("node_local_ratio").unwrap().as_f64().unwrap();
            assert!((0.0..=1.0).contains(&ratio));
        }
        // Ratio curves exist for the four MR algorithms.
        for key in ["speedup", "sizeup", "scaleup"] {
            let curves = j.get(key).unwrap().as_obj().unwrap();
            assert_eq!(curves.len(), 4, "{key}");
        }
        // The coreset pipeline runs fewer jobs than kmedoids-mr in every
        // shared cell (constant rounds vs one job pair per iteration) —
        // the acceptance bar the bench must keep visible.
        for exp_name in ["speedup", "sizeup", "scaleup"] {
            for c in cells.iter().filter(|c| {
                c.get("experiment").and_then(|e| e.as_str()) == Some(exp_name)
            }) {
                let algo = c.get("algorithm").and_then(|a| a.as_str()).unwrap();
                if algo != "kmedoids-coreset-mr" {
                    continue;
                }
                let nodes = c.get("nodes").unwrap().as_usize().unwrap();
                let n = c.get("n_points").unwrap().as_usize().unwrap();
                let twin = cells
                    .iter()
                    .find(|t| {
                        t.get("experiment").and_then(|e| e.as_str()) == Some(exp_name)
                            && t.get("algorithm").and_then(|a| a.as_str())
                                == Some("kmedoids-mr")
                            && t.get("nodes").unwrap().as_usize() == Some(nodes)
                            && t.get("n_points").unwrap().as_usize() == Some(n)
                    })
                    .expect("kmedoids-mr twin cell");
                let jc = c.get("jobs").unwrap().as_usize().unwrap();
                let jm = twin.get("jobs").unwrap().as_usize().unwrap();
                assert!(jc < jm, "{exp_name} nodes={nodes}: coreset {jc} jobs vs mr {jm}");
            }
        }
        // The document is valid, re-parseable JSON.
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    /// Exact-key-set assertion: a bench refactor that drops or renames a
    /// field CI artifacts depend on must fail here, not silently ship.
    fn assert_exact_keys(j: &Json, what: &str, expect: &[&str]) {
        let obj = j.as_obj().unwrap_or_else(|| panic!("{what} must be a JSON object"));
        let got: Vec<&str> = obj.keys().map(|k| k.as_str()).collect();
        let mut want: Vec<&str> = expect.to_vec();
        want.sort_unstable(); // BTreeMap iterates sorted
        assert_eq!(got, want, "{what}: key set drifted");
    }

    #[test]
    fn golden_schema_bench_perf_json() {
        let opts = PerfOpts {
            scale_div: 2000,
            seed: 5,
            threads: vec![2],
            smoke: true,
            checkpoint_dir: None,
        };
        let j = perf_suite(&be(), &opts);
        assert_exact_keys(
            &j,
            "BENCH_perf.json",
            &[
                "bench",
                "smoke",
                "backend",
                "scale_div",
                "seed",
                "n_points",
                "kernels",
                "e2e",
                "speedup_vs_1_thread",
                "pruning",
                "ingest",
                "identical_outputs",
            ],
        );
        for row in j.get("e2e").unwrap().as_arr().unwrap() {
            assert_exact_keys(
                row,
                "BENCH_perf.json e2e row",
                &[
                    "threads",
                    "wall_s",
                    "sim_seconds",
                    "cost",
                    "iterations",
                    "dist_evals",
                    "pruned_frac",
                    "identical_to_1_thread",
                ],
            );
        }
        for row in j.get("kernels").unwrap().as_arr().unwrap() {
            assert_exact_keys(
                row,
                "BENCH_perf.json kernel row",
                &[
                    "name",
                    "iters",
                    "min_s",
                    "median_s",
                    "mean_s",
                    "p95_s",
                    "dist_evals_exact",
                    "dist_evals_per_s",
                ],
            );
        }
        assert_exact_keys(
            j.get("pruning").unwrap(),
            "BENCH_perf.json pruning gate",
            &[
                "n_points",
                "k",
                "iterations",
                "dense_evals",
                "pruned_evals",
                "reduction",
                "floor",
                "pruned_frac",
                "identical",
                "ok",
            ],
        );
        assert_exact_keys(
            j.get("ingest").unwrap(),
            "BENCH_perf.json ingest gate",
            &[
                "n_points",
                "csv_s",
                "bin_s",
                "csv_rows_per_s",
                "bin_rows_per_s",
                "speedup",
                "floor",
                "identical",
                "manifest",
                "ok",
            ],
        );
        assert_exact_keys(
            j.get("ingest").unwrap().get("manifest").unwrap(),
            "BENCH_perf.json ingest manifest",
            &["count", "crc32", "dims", "file", "format", "name", "provenance", "weights"],
        );
    }

    #[test]
    fn golden_schema_bench_scale_json() {
        // Single sweep point: the three experiments collapse onto one
        // memoized cell per algorithm, so this is the cheapest full-shape
        // document.
        let mut opts = ScaleOpts::smoke();
        opts.scale_div = 1600;
        opts.nodes_sweep = vec![1];
        let j = scale_suite(&be(), &opts);
        assert_exact_keys(
            &j,
            "BENCH_scale.json",
            &[
                "bench",
                "smoke",
                "backend",
                "seed",
                "scale_div",
                "n_base",
                "nodes_sweep",
                "speculation",
                "faults",
                "cells",
                "speedup",
                "sizeup",
                "scaleup",
                "identity_ok",
            ],
        );
        assert_exact_keys(
            j.get("faults").unwrap(),
            "BENCH_scale.json faults",
            &["n_failures", "task_fail_rate"],
        );
        let cells = j.get("cells").unwrap().as_arr().unwrap();
        assert!(!cells.is_empty());
        for c in cells {
            assert_exact_keys(
                c,
                "BENCH_scale.json cell",
                &[
                    "experiment",
                    "algorithm",
                    "nodes",
                    "n_points",
                    "time_ms",
                    "iterations",
                    "cost",
                    "dist_evals",
                    "jobs",
                    "attempts",
                    "locality",
                    "wall_s",
                    "fault",
                ],
            );
            assert_exact_keys(
                c.get("attempts").unwrap(),
                "cell attempts",
                &["total", "speculative", "failed"],
            );
            assert_exact_keys(
                c.get("locality").unwrap(),
                "cell locality",
                &["node_local", "host_local", "remote", "node_local_ratio"],
            );
            assert_exact_keys(
                c.get("fault").unwrap(),
                "cell fault twin",
                &[
                    "time_ms",
                    "failed_attempts",
                    "n_node_failures",
                    "task_fail_rate",
                    "identical",
                ],
            );
        }
    }

    #[test]
    fn lanes_suite_smoke_identity_and_speedup() {
        let mut opts = LanesOpts::smoke();
        opts.scale_div = 1600;
        opts.nodes_sweep = vec![1, 2];
        opts.seed = 7;
        let j = lanes_suite(&be(), &opts);
        assert_eq!(j.get("bench").unwrap().as_str(), Some("lanes"));
        // Both blocking gates hold at test scale.
        assert_eq!(j.get("identity_ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("dag_faster_ok").unwrap().as_bool(), Some(true));
        let cells = j.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 8, "4 MR algorithms x 2 cluster sizes");
        for c in cells {
            assert_eq!(c.get("identical").unwrap().as_bool(), Some(true));
            assert_eq!(c.get("dag_faster").unwrap().as_bool(), Some(true));
            assert!(c.get("speedup").unwrap().as_f64().unwrap() > 1.0);
        }
        // Every per-algorithm curve stays strictly above 1x at every
        // swept cluster size.
        let curves = j.get("speedup").unwrap().as_obj().unwrap();
        assert_eq!(curves.len(), 4);
        for (algo, curve) in curves {
            for pt in curve.as_arr().unwrap() {
                let pair = pt.as_arr().unwrap();
                assert!(
                    pair[1].as_f64().unwrap() > 1.0,
                    "{algo} @ nodes={:?}: dag must be strictly faster",
                    pair[0]
                );
            }
        }
        // The document is valid, re-parseable JSON.
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn golden_schema_bench_lanes_json() {
        let mut opts = LanesOpts::smoke();
        opts.scale_div = 1600;
        opts.nodes_sweep = vec![1];
        let j = lanes_suite(&be(), &opts);
        assert_exact_keys(
            &j,
            "BENCH_lanes.json",
            &[
                "bench",
                "smoke",
                "backend",
                "seed",
                "scale_div",
                "n_points",
                "k",
                "fixed_iters",
                "nodes_sweep",
                "cells",
                "speedup",
                "identity_ok",
                "dag_faster_ok",
            ],
        );
        let cells = j.get("cells").unwrap().as_arr().unwrap();
        assert!(!cells.is_empty());
        for c in cells {
            assert_exact_keys(
                c,
                "BENCH_lanes.json cell",
                &[
                    "algorithm",
                    "nodes",
                    "n_points",
                    "mr_time_ms",
                    "dag_time_ms",
                    "speedup",
                    "jobs",
                    "iterations",
                    "cost",
                    "dist_evals",
                    "wall_s",
                    "identical",
                    "dag_faster",
                ],
            );
        }
    }

    #[test]
    fn serve_suite_smoke_is_consistent() {
        let mut opts = ServeOpts::smoke();
        opts.scale_div = 1300; // ~1000 base points
        opts.seed = 7;
        opts.threads = vec![2];
        opts.queries = 400;
        opts.batch = 64;
        let j = serve_suite(&be(), &opts);
        assert_eq!(j.get("bench").unwrap().as_str(), Some("serve"));
        // Both blocking gates hold at test scale.
        assert_eq!(j.get("identity_ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("cost_monotone_ok").unwrap().as_bool(), Some(true));
        let sweep = j.get("sweep").unwrap().as_arr().unwrap();
        assert_eq!(sweep.len(), 1);
        let row = &sweep[0];
        assert_eq!(row.get("threads").unwrap().as_usize(), Some(2));
        assert!(row.get("throughput_qps").unwrap().as_f64().unwrap() > 0.0);
        // 400 queries x 0.2 update_frac = 80 deltas over batch 64: one
        // full mini-batch plus one forced partial flush -> 2 updates,
        // each published past the fit's epoch 1.
        assert_eq!(row.get("updates").unwrap().as_usize(), Some(2));
        assert!(row.get("final_epoch").unwrap().as_usize().unwrap() >= 3);
        let p50 = row.get("p50_s").unwrap().as_f64().unwrap();
        let p99 = row.get("p99_s").unwrap().as_f64().unwrap();
        let p999 = row.get("p999_s").unwrap().as_f64().unwrap();
        assert!(p50 <= p99 && p99 <= p999, "percentiles must be ordered");
        assert!(row.get("cost_after").unwrap().as_f64().unwrap() > 0.0);
        // The document is valid, re-parseable JSON.
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn golden_schema_bench_serve_json() {
        let mut opts = ServeOpts::smoke();
        opts.scale_div = 1300;
        opts.seed = 7;
        opts.threads = vec![1];
        opts.queries = 200;
        opts.batch = 32;
        let j = serve_suite(&be(), &opts);
        assert_exact_keys(
            &j,
            "BENCH_serve.json",
            &[
                "bench",
                "smoke",
                "backend",
                "seed",
                "scale_div",
                "n_points",
                "k",
                "queries",
                "update_frac",
                "batch",
                "coreset_target",
                "identity_ok",
                "cost_monotone_ok",
                "sweep",
            ],
        );
        for row in j.get("sweep").unwrap().as_arr().unwrap() {
            assert_exact_keys(
                row,
                "BENCH_serve.json sweep row",
                &[
                    "threads",
                    "wall_s",
                    "throughput_qps",
                    "p50_s",
                    "p99_s",
                    "p999_s",
                    "updates",
                    "epochs_published",
                    "final_epoch",
                    "cost_before",
                    "cost_after",
                ],
            );
        }
    }

    #[test]
    fn fig5_suite_ordering() {
        let rs = fig5_suite(&be(), &SuiteOpts::new(200, 5));
        assert_eq!(rs.len(), 12);
        // The proposed algorithm beats CLARANS at every size.
        for ds in 0..3 {
            let pp = rs
                .iter()
                .find(|r| r.algorithm == "kmedoids++-mr" && r.n_points == rs[ds].n_points)
                .unwrap();
            let cl = rs
                .iter()
                .find(|r| r.algorithm == "clarans" && r.n_points == rs[ds].n_points)
                .unwrap();
            assert!(
                pp.time_ms <= cl.time_ms,
                "kmedoids++ ({}) should beat clarans ({}) on dataset {}",
                pp.time_ms,
                cl.time_ms,
                ds + 1
            );
        }
    }
}
