//! Experiment suites: the exact cell grids behind each paper table/figure.
//!
//! Used by the CLI (`kmedoids-mr bench ...`), the cargo benches, and the
//! end-to-end example, so every entry point reproduces the same numbers.
//!
//! Session economics: each suite builds one [`ClusterSession`] per
//! cluster size, generates each dataset **once**, and ingests the shared
//! point set into every session ([`ClusterSession::ingest_points`] shares
//! the `Arc`, no copy) — cells then pay only the algorithm, not cluster
//! construction + generation + ingest as the old per-cell driver did.
//! With [`SuiteOpts::trace`] the sessions stream live per-iteration
//! progress to stderr through a [`StderrProgress`] observer.

use super::{run_cell, Algorithm, Experiment, ExperimentResult};
use crate::clustering::observe::StderrProgress;
use crate::clustering::{Init, UpdateStrategy};
use crate::config::ClusterConfig;
use crate::geo::datasets::{generate, SpatialSpec};
use crate::geo::Point;
use crate::runtime::ComputeBackend;
use crate::session::{ClusterSession, DatasetHandle};
use std::sync::Arc;

/// Shared suite knobs.
#[derive(Debug, Clone)]
pub struct SuiteOpts {
    /// Divide the Table 5 dataset sizes (1 = the paper's full scale).
    pub scale_div: usize,
    pub seed: u64,
    /// Stream per-iteration events to stderr while cells run.
    pub trace: bool,
}

impl SuiteOpts {
    pub fn new(scale_div: usize, seed: u64) -> SuiteOpts {
        SuiteOpts { scale_div: scale_div.max(1), seed, trace: false }
    }
    pub fn with_trace(mut self, trace: bool) -> SuiteOpts {
        self.trace = trace;
        self
    }
}

/// Generate the three Table 5 datasets once (shared across sessions).
/// `scale_div` is re-clamped here because `SuiteOpts` fields are public.
fn paper_datasets(opts: &SuiteOpts) -> Vec<Arc<Vec<Point>>> {
    (0..3)
        .map(|i| {
            let spec = SpatialSpec::paper_dataset_scaled(i, opts.scale_div.max(1), opts.seed);
            Arc::new(generate(&spec).points)
        })
        .collect()
}

fn suite_session(
    backend: &Arc<dyn ComputeBackend>,
    nodes: usize,
    opts: &SuiteOpts,
    datasets: &[Arc<Vec<Point>>],
) -> (ClusterSession, Vec<DatasetHandle>) {
    let mut session = ClusterSession::builder()
        .cluster(ClusterConfig::paper_cluster())
        .nodes(nodes)
        .backend(backend.clone())
        .seed(opts.seed)
        .build()
        .expect("session build cannot fail with an explicit backend");
    if opts.trace {
        session.add_observer(Box::new(StderrProgress::new()));
    }
    let handles = datasets
        .iter()
        .enumerate()
        .map(|(i, pts)| session.ingest_points(&format!("dataset{}", i + 1), pts.clone()))
        .collect();
    (session, handles)
}

/// Table 6 / Fig. 3 / Fig. 4: K-Medoids++ MR over 4–7 nodes × 3 datasets.
pub fn table6_suite(backend: &Arc<dyn ComputeBackend>, opts: &SuiteOpts) -> Vec<ExperimentResult> {
    let datasets = paper_datasets(opts);
    // One session per cluster size, each with all three datasets ingested.
    let mut sessions: Vec<(ClusterSession, Vec<DatasetHandle>)> =
        (4..=7).map(|nodes| suite_session(backend, nodes, opts, &datasets)).collect();

    let mut out = Vec::new();
    for dataset in 0..3 {
        for (si, nodes) in (4..=7).enumerate() {
            let mut exp =
                Experiment::paper_cell(Algorithm::KMedoidsPlusPlusMR, nodes, dataset, opts.seed)
                    .scaled(opts.scale_div.max(1));
            // Controlled iteration count: isolates the scaling behaviour
            // from per-dataset convergence luck (EXPERIMENTS.md §Method).
            exp.fixed_iters = Some(6);
            let (session, handles) = &mut sessions[si];
            let r = run_cell(session, &exp, &handles[dataset]).expect("table6 cell failed");
            eprintln!(
                "  [table6] dataset {} x {} nodes -> {} ms ({} iters, wall {:.1}s)",
                dataset + 1,
                nodes,
                r.time_ms,
                r.iterations,
                r.wall_s
            );
            out.push(r);
        }
    }
    out
}

/// Fig. 5: comparative algorithms over the 3 dataset sizes — the paper's
/// "classic clustering algorithms for comparison are traditional
/// K-Medoids algorithm and CLARANS algorithm": the proposed parallel
/// K-Medoids++ (7 nodes) against the serial comparators on the master.
/// One shared 7-node session hosts all nine cells.
pub fn fig5_suite(backend: &Arc<dyn ComputeBackend>, opts: &SuiteOpts) -> Vec<ExperimentResult> {
    let datasets = paper_datasets(opts);
    let (mut session, handles) = suite_session(backend, 7, opts, &datasets);
    let algos = [
        Algorithm::KMedoidsPlusPlusMR,
        Algorithm::KMedoidsSerial,
        Algorithm::Clarans,
    ];
    let mut out = Vec::new();
    for algo in algos {
        for dataset in 0..3 {
            let mut exp =
                Experiment::paper_cell(algo, 7, dataset, opts.seed).scaled(opts.scale_div.max(1));
            if algo == Algorithm::KMedoidsPlusPlusMR {
                // Controlled iterations for the MR entry (as in Table 6);
                // the serial comparators keep natural convergence, which
                // only widens their gap.
                exp.fixed_iters = Some(6);
            }
            let r = run_cell(&mut session, &exp, &handles[dataset]).expect("fig5 cell failed");
            eprintln!(
                "  [fig5] {} dataset {} -> {} ms (wall {:.1}s)",
                algo.name(),
                dataset + 1,
                r.time_ms,
                r.wall_s
            );
            out.push(r);
        }
    }
    out
}

/// §3.1 ablation: ++ seeding vs random init (iterations to converge and
/// total time), plus update-strategy variants. Dataset 1, 7 nodes, one
/// shared session.
pub fn ablation_suite(
    backend: &Arc<dyn ComputeBackend>,
    opts: &SuiteOpts,
) -> Vec<ExperimentResult> {
    let spec = SpatialSpec::paper_dataset_scaled(0, opts.scale_div.max(1), opts.seed);
    let points = Arc::new(generate(&spec).points);
    let (mut session, handles) = suite_session(backend, 7, opts, std::slice::from_ref(&points));
    let data = &handles[0];

    let mut out = Vec::new();
    let variants: Vec<(&str, Init, UpdateStrategy)> = vec![
        ("++/sampled", Init::PlusPlus, UpdateStrategy::paper_scale_default()),
        ("random/sampled", Init::Random, UpdateStrategy::paper_scale_default()),
        ("++/centroid", Init::PlusPlus, UpdateStrategy::CentroidNearest),
        ("random/centroid", Init::Random, UpdateStrategy::CentroidNearest),
    ];
    for (name, init, update) in variants {
        let algo = if init == Init::PlusPlus {
            Algorithm::KMedoidsPlusPlusMR
        } else {
            Algorithm::KMedoidsRandomMR
        };
        let mut exp = Experiment::paper_cell(algo, 7, 0, opts.seed).scaled(opts.scale_div.max(1));
        exp.update = update;
        let mut r = run_cell(&mut session, &exp, data).expect("ablation cell failed");
        r.algorithm = name.to_string(); // relabel with the variant name
        eprintln!("  [ablation] {name} -> {} ms, {} iters", r.time_ms, r.iterations);
        out.push(r);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn be() -> Arc<dyn ComputeBackend> {
        Arc::new(NativeBackend::new(256, 16))
    }

    #[test]
    fn table6_suite_small_has_12_cells_and_paper_shape() {
        // Heavy scale-down: structure test, not numbers. At this scale
        // each dataset is a single DFS block (one map task), so adding
        // nodes only re-shapes the reduce waves — allow 2% wobble from
        // slow-node placement; the strict monotonicity check runs at full
        // scale in the table6_scaling bench.
        let rs = table6_suite(&be(), &SuiteOpts::new(200, 5));
        assert_eq!(rs.len(), 12);
        assert!(rs.iter().all(|r| r.iterations == 6), "controlled iterations");
        for ds in [rs[0].n_points, rs[4].n_points, rs[8].n_points] {
            let times: Vec<u64> = rs
                .iter()
                .filter(|r| r.n_points == ds)
                .map(|r| r.time_ms)
                .collect();
            assert_eq!(times.len(), 4);
            assert!(
                times.windows(2).all(|w| w[1] as f64 <= w[0] as f64 * 1.02),
                "time should not grow materially with nodes: {times:?}"
            );
        }
        // Larger dataset takes longer at fixed cluster size.
        assert!(rs[0].time_ms <= rs[8].time_ms);
    }

    #[test]
    fn fig5_suite_ordering() {
        let rs = fig5_suite(&be(), &SuiteOpts::new(200, 5));
        assert_eq!(rs.len(), 9);
        // The proposed algorithm beats CLARANS at every size.
        for ds in 0..3 {
            let pp = rs
                .iter()
                .find(|r| r.algorithm == "kmedoids++-mr" && r.n_points == rs[ds].n_points)
                .unwrap();
            let cl = rs
                .iter()
                .find(|r| r.algorithm == "clarans" && r.n_points == rs[ds].n_points)
                .unwrap();
            assert!(
                pp.time_ms <= cl.time_ms,
                "kmedoids++ ({}) should beat clarans ({}) on dataset {}",
                pp.time_ms,
                cl.time_ms,
                ds + 1
            );
        }
    }
}
