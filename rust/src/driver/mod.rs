//! Experiment driver: the paper's evaluation grid on top of the session
//! API.
//!
//! One [`Experiment`] = one (dataset, cluster size, algorithm) cell of
//! the paper's evaluation. Cells run against a
//! [`crate::session::ClusterSession`]: [`run_cell`] fits the cell's
//! algorithm (via [`Experiment::clusterer`] and the
//! [`SpatialClusterer`] trait) on a dataset already ingested into the
//! session, so suites build each cluster once, ingest each dataset once,
//! and pay only the algorithm per cell. [`run_experiment`] remains as
//! the one-call compatibility shim: it wraps a fresh single-use session
//! per cell (generate → ingest → fit) and returns the same
//! paper-comparable numbers as before the session redesign.
//!
//! Cells are JSON-serializable through [`spec`] (`kmedoids-mr run --spec
//! cells.json` drives any grid from a file); the canonical grids behind
//! each table/figure live in [`suites`].

pub mod spec;
pub mod suites;

use crate::clustering::api::{Clarans, KMeans, KMedoids, SpatialClusterer};
use crate::clustering::{metrics, FitResume, Init, PruningMode, UpdateStrategy};
use crate::config::ClusterConfig;
use crate::geo::datasets::SpatialSpec;
use crate::geo::Metric;
use crate::mapreduce::Lane;
use crate::persist::CheckpointStore;
use crate::runtime::ComputeBackend;
use crate::session::{ClusterSession, DatasetHandle};
use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;

/// Algorithm selector (the rows of Fig. 5 plus ablations).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Algorithm {
    /// The paper's contribution: MR K-Medoids with ++ seeding.
    KMedoidsPlusPlusMR,
    /// "Traditional K-Medoids" parallelized: MR with random init.
    KMedoidsRandomMR,
    /// MR K-Medoids with k-means||-style oversampled seeding (Bahmani
    /// et al.): O(rounds) seeding jobs instead of k−1.
    KMedoidsScalableMR,
    /// Constant-round weighted-coreset pipeline (Ene et al.): two MR
    /// jobs total regardless of iteration count.
    KMedoidsCoresetMR,
    /// Serial traditional K-Medoids (single node).
    KMedoidsSerial,
    /// CLARANS (serial, Ng & Han).
    Clarans,
    /// Parallel k-means (robustness ablation).
    KMeansMR,
}

impl Algorithm {
    pub const ALL: [Algorithm; 7] = [
        Algorithm::KMedoidsPlusPlusMR,
        Algorithm::KMedoidsRandomMR,
        Algorithm::KMedoidsScalableMR,
        Algorithm::KMedoidsCoresetMR,
        Algorithm::KMedoidsSerial,
        Algorithm::Clarans,
        Algorithm::KMeansMR,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::KMedoidsPlusPlusMR => "kmedoids++-mr",
            Algorithm::KMedoidsRandomMR => "kmedoids-mr",
            Algorithm::KMedoidsScalableMR => "kmedoids-scalable-mr",
            Algorithm::KMedoidsCoresetMR => "kmedoids-coreset-mr",
            Algorithm::KMedoidsSerial => "kmedoids-serial",
            Algorithm::Clarans => "clarans",
            Algorithm::KMeansMR => "kmeans-mr",
        }
    }
    pub fn parse(s: &str) -> Option<Algorithm> {
        Some(match s {
            "kmedoids++-mr" | "kmedoids++" => Algorithm::KMedoidsPlusPlusMR,
            "kmedoids-mr" => Algorithm::KMedoidsRandomMR,
            "kmedoids-scalable-mr" | "kmedoids||-mr" | "kmedoids-scalable" => {
                Algorithm::KMedoidsScalableMR
            }
            "kmedoids-coreset-mr" | "kmedoids-coreset" => Algorithm::KMedoidsCoresetMR,
            "kmedoids-serial" => Algorithm::KMedoidsSerial,
            "clarans" => Algorithm::Clarans,
            "kmeans-mr" | "kmeans" => Algorithm::KMeansMR,
            _ => return None,
        })
    }
}

/// One experiment cell.
#[derive(Debug, Clone, PartialEq)]
pub struct Experiment {
    pub algorithm: Algorithm,
    pub n_nodes: usize,
    pub spec: SpatialSpec,
    /// Fit from a dataset file (CSV or [`crate::geo::binfmt`] binary,
    /// sniffed by magic) instead of generating from `spec` — the
    /// `dataset: {"file": ...}` spec cell / CLI `run --data FILE`. When
    /// set, `spec` is only the carrier of generator defaults; the
    /// session ingests through `ClusterSession::ingest_file`.
    pub data_file: Option<PathBuf>,
    pub k: usize,
    pub update: UpdateStrategy,
    /// Dissimilarity of the fit (the dataset's dims must be supported).
    pub metric: Metric,
    /// `(l, rounds)` for the scalable (k-means||-style) seeding; `None`
    /// uses Bahmani et al.'s defaults (ℓ = 2k, 5 rounds). Only honored
    /// by [`Algorithm::KMedoidsScalableMR`].
    pub oversample: Option<(usize, usize)>,
    /// Weighted-representative budget of the coreset pipeline; `None`
    /// uses the O(k·log n) default. Only honored by
    /// [`Algorithm::KMedoidsCoresetMR`].
    pub coreset_size: Option<usize>,
    pub seed: u64,
    /// Run the final labeling pass and quality metrics (slower).
    pub with_quality: bool,
    /// Controlled iteration count (see `IterParams::fixed_iters`).
    pub fixed_iters: Option<usize>,
    /// Real-compute worker threads (wallclock only; results and simulated
    /// time are identical at any value). Applied when a session is built
    /// *for* this cell ([`run_experiment`], the CLI, spec files); cells
    /// run through [`run_cell`] inherit the session's setting.
    pub threads: usize,
    /// Persist a durable [`crate::persist::Checkpoint`] after every
    /// solver iteration into this directory. Applied when a session is
    /// built *for* this cell (like `threads`); cells run through
    /// [`run_cell`] inherit the session's observers, but `resume` still
    /// loads from here.
    pub checkpoint_dir: Option<PathBuf>,
    /// Continue from the newest checkpoint in `checkpoint_dir` instead
    /// of seeding fresh (MR K-Medoids algorithms only). The resumed fit
    /// is byte-identical to the uninterrupted run.
    pub resume: bool,
    /// Assignment-lane selection (`--pruning on|off|auto`): the pruned
    /// lane returns byte-identical labels/costs with fewer distance
    /// evaluations; `Auto` (default) prunes unless the cell checkpoints
    /// or resumes. Honored by the MR K-Medoids drivers and k-means.
    pub pruning: PruningMode,
    /// Execution lane the cell's jobs run through (`--lane
    /// hadoop-mr|in-memory-dag`): outputs are byte-identical across
    /// lanes, only simulated time differs. MR algorithms only — the
    /// serial algorithms refuse a non-default lane.
    pub lane: Lane,
    /// Transient-failure retry budget per task (`--max-attempts`),
    /// applied when a session is built *for* this cell (like
    /// `threads`). Hadoop lane only.
    pub max_attempts: Option<usize>,
}

impl Experiment {
    pub fn paper_cell(
        algorithm: Algorithm,
        n_nodes: usize,
        dataset: usize,
        seed: u64,
    ) -> Experiment {
        Experiment {
            algorithm,
            n_nodes,
            spec: SpatialSpec::paper_dataset(dataset, seed),
            data_file: None,
            k: 9,
            update: UpdateStrategy::paper_scale_default(),
            metric: Metric::SqEuclidean,
            oversample: None,
            coreset_size: None,
            seed,
            with_quality: false,
            fixed_iters: None,
            threads: 1,
            checkpoint_dir: None,
            resume: false,
            pruning: PruningMode::Auto,
            lane: Lane::HadoopMr,
            max_attempts: None,
        }
    }

    /// Same cell scaled down by `scale_div` for quick runs.
    pub fn scaled(mut self, scale_div: usize) -> Experiment {
        self.spec.n_points = (self.spec.n_points / scale_div).max(1000);
        self
    }

    /// Build this cell's solver through the fluent builders — the single
    /// mapping from the [`Algorithm`] grid axis onto [`SpatialClusterer`]
    /// implementations.
    pub fn clusterer(&self) -> Box<dyn SpatialClusterer> {
        self.clusterer_with(None).expect("no resume state: builder mapping is infallible")
    }

    /// [`Experiment::clusterer`] continuing from `resume` when given.
    /// Only the MR K-Medoids algorithms can resume; the rest refuse.
    pub fn clusterer_with(&self, resume: Option<FitResume>) -> Result<Box<dyn SpatialClusterer>> {
        Ok(match self.algorithm {
            Algorithm::KMedoidsPlusPlusMR
            | Algorithm::KMedoidsRandomMR
            | Algorithm::KMedoidsScalableMR => {
                let mut b = KMedoids::mapreduce()
                    .k(self.k)
                    .seed(self.seed)
                    .update(self.update)
                    .metric(self.metric)
                    .pruning(self.pruning)
                    .lane(self.lane)
                    .label_pass(self.with_quality);
                b = match self.algorithm {
                    Algorithm::KMedoidsPlusPlusMR => b.plus_plus(),
                    Algorithm::KMedoidsRandomMR => b.random_init(),
                    _ => match self.oversample {
                        Some((l, rounds)) => b.oversample(l, rounds),
                        None => b.init(Init::oversample_default(self.k)),
                    },
                };
                if let Some(n) = self.fixed_iters {
                    b = b.fixed_iters(n);
                }
                if let Some(r) = resume {
                    b = b.resume(r);
                }
                Box::new(b.build())
            }
            Algorithm::KMedoidsCoresetMR => {
                let mut b = KMedoids::coreset()
                    .k(self.k)
                    .seed(self.seed)
                    .metric(self.metric)
                    .pruning(self.pruning)
                    .lane(self.lane)
                    .label_pass(self.with_quality);
                if let Some(size) = self.coreset_size {
                    b = b.coreset_size(size);
                }
                if let Some(n) = self.fixed_iters {
                    // For the coreset pipeline fixed_iters pins the
                    // driver-side refinement count — the job count stays
                    // constant either way.
                    b = b.fixed_iters(n);
                }
                if let Some(r) = resume {
                    b = b.resume(r);
                }
                Box::new(b.build())
            }
            Algorithm::KMedoidsSerial => {
                anyhow::ensure!(
                    resume.is_none(),
                    "{} cannot resume from a checkpoint (only the MR K-Medoids drivers \
                     emit and restore checkpoints)",
                    self.algorithm.name()
                );
                anyhow::ensure!(
                    self.lane == Lane::HadoopMr,
                    "{} runs serially and never submits MR jobs; execution lanes only \
                     apply to the MR algorithms",
                    self.algorithm.name()
                );
                Box::new(
                    KMedoids::serial()
                        .k(self.k)
                        .seed(self.seed)
                        .update(self.update)
                        .metric(self.metric)
                        .label_pass(self.with_quality)
                        .build(),
                )
            }
            Algorithm::Clarans => {
                anyhow::ensure!(
                    resume.is_none(),
                    "{} cannot resume from a checkpoint (only the MR K-Medoids drivers \
                     emit and restore checkpoints)",
                    self.algorithm.name()
                );
                anyhow::ensure!(
                    self.lane == Lane::HadoopMr,
                    "{} runs serially and never submits MR jobs; execution lanes only \
                     apply to the MR algorithms",
                    self.algorithm.name()
                );
                Box::new(Clarans::serial().k(self.k).seed(self.seed).metric(self.metric).build())
            }
            Algorithm::KMeansMR => {
                anyhow::ensure!(
                    resume.is_none(),
                    "{} cannot resume from a checkpoint (only the MR K-Medoids drivers \
                     emit and restore checkpoints)",
                    self.algorithm.name()
                );
                Box::new(
                    KMeans::mapreduce()
                        .plus_plus()
                        .k(self.k)
                        .seed(self.seed)
                        .metric(self.metric)
                        .pruning(self.pruning)
                        .lane(self.lane)
                        .build(),
                )
            }
        })
    }

    /// Load the newest checkpoint from [`Experiment::checkpoint_dir`]
    /// when [`Experiment::resume`] is set; `Ok(None)` otherwise. Typed
    /// [`crate::persist::PersistError`]s from the store (no checkpoint,
    /// corruption) surface through the `anyhow` chain.
    pub fn resolve_resume(&self) -> Result<Option<FitResume>> {
        if !self.resume {
            return Ok(None);
        }
        let dir = self.checkpoint_dir.as_ref().ok_or_else(|| {
            anyhow::anyhow!("resume requires checkpoint_dir (nowhere to load a snapshot from)")
        })?;
        let (_, ck) = CheckpointStore::open(dir)?.latest()?;
        Ok(Some(ck.to_resume()))
    }
}

/// Result row: everything the paper's tables/figures report.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    pub algorithm: String,
    pub n_nodes: usize,
    pub n_points: usize,
    pub dataset_mb: f64,
    /// Simulated execution time in ms (Table 6 unit).
    pub time_ms: u64,
    pub iterations: usize,
    pub cost: f64,
    pub dist_evals: u64,
    /// Adjusted Rand Index vs. generator truth (when `with_quality`).
    pub ari: Option<f64>,
    /// Real wall-clock seconds this cell took to compute.
    pub wall_s: f64,
}

/// Run one cell against a dataset already ingested into `session`. The
/// session's registered observers stream the fit's iteration events; the
/// session clock and counters keep accruing across cells.
pub fn run_cell(
    session: &mut ClusterSession,
    exp: &Experiment,
    data: &DatasetHandle,
) -> Result<ExperimentResult> {
    // The session's cluster is what actually runs; refuse a cell whose
    // nodes axis disagrees instead of silently collapsing a scaling grid
    // onto one cluster size.
    anyhow::ensure!(
        exp.n_nodes == session.config().nodes.len(),
        "experiment wants {} nodes but the session cluster has {}",
        exp.n_nodes,
        session.config().nodes.len()
    );
    let wall0 = std::time::Instant::now();
    let outcome = exp.clusterer_with(exp.resolve_resume()?)?.fit(session, data)?;

    let ari = if exp.with_quality {
        let truth = session.dataset_truth(data).ok_or_else(|| {
            anyhow::anyhow!(
                "with_quality requires generator ground truth, but dataset {:?} was ingested \
                 without it (use ingest/ingest_spec instead of ingest_points)",
                data.name()
            )
        })?;
        let points = session.dataset_points(data);
        let labels = match &outcome.labels {
            Some(l) => l.clone(),
            None => metrics::brute_labels_metric(&points, &outcome.medoids, exp.metric),
        };
        Some(metrics::adjusted_rand_index(&labels, truth))
    } else {
        None
    };

    Ok(ExperimentResult {
        algorithm: exp.algorithm.name().to_string(),
        n_nodes: session.config().nodes.len(),
        n_points: session.dataset_n_points(data),
        dataset_mb: session.dataset_bytes(data) as f64 / (1u64 << 20) as f64,
        time_ms: (outcome.sim_seconds * 1e3).round() as u64,
        iterations: outcome.iterations,
        cost: outcome.cost,
        dist_evals: outcome.dist_evals,
        ari,
        wall_s: wall0.elapsed().as_secs_f64(),
    })
}

/// Compatibility shim: run one cell end to end on a fresh single-use
/// session (generate → ingest → fit), exactly like the pre-session API.
/// Suites that run many cells should build a [`ClusterSession`] and use
/// [`run_cell`] instead, paying cluster construction and ingest once.
pub fn run_experiment(exp: &Experiment, backend: &Arc<dyn ComputeBackend>) -> ExperimentResult {
    let wall0 = std::time::Instant::now();
    let mut builder = ClusterSession::builder()
        .cluster(ClusterConfig::paper_cluster().cluster_subset(exp.n_nodes))
        .backend(backend.clone())
        .seed(exp.seed)
        .threads(exp.threads);
    if let Some(dir) = &exp.checkpoint_dir {
        builder = builder.checkpoint_dir(dir.clone());
    }
    if let Some(n) = exp.max_attempts {
        builder = builder.max_attempts(n);
    }
    let mut session = builder.build().unwrap_or_else(|e| panic!("session build failed: {e:#}"));
    let data = match &exp.data_file {
        Some(path) => session
            .ingest_file("points", path)
            .unwrap_or_else(|e| panic!("ingest {path:?} failed: {e:#}")),
        None => session.ingest_spec("points", &exp.spec),
    };
    let mut r = run_cell(&mut session, exp, &data)
        .unwrap_or_else(|e| panic!("experiment {} failed: {e:#}", exp.algorithm.name()));
    r.wall_s = wall0.elapsed().as_secs_f64();
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn be() -> Arc<dyn ComputeBackend> {
        Arc::new(NativeBackend::new(256, 16))
    }

    fn quick_exp(algorithm: Algorithm, n_nodes: usize) -> Experiment {
        let mut spec = SpatialSpec::new(6000, 5, 71);
        spec.outlier_frac = 0.0; // quality assertions need clean recovery
        Experiment {
            algorithm,
            n_nodes,
            spec,
            data_file: None,
            fixed_iters: None,
            k: 5,
            update: UpdateStrategy::Sampled { candidates: 64, member_sample: 1024 },
            metric: Metric::SqEuclidean,
            oversample: None,
            coreset_size: None,
            seed: 71,
            with_quality: true,
            threads: 1,
            checkpoint_dir: None,
            resume: false,
            pruning: PruningMode::Auto,
            lane: Lane::HadoopMr,
            max_attempts: None,
        }
    }

    #[test]
    fn mr_cell_runs_and_reports() {
        let r = run_experiment(&quick_exp(Algorithm::KMedoidsPlusPlusMR, 4), &be());
        assert_eq!(r.algorithm, "kmedoids++-mr");
        assert!(r.time_ms > 0);
        assert!(r.iterations >= 1);
        assert!(r.ari.unwrap() > 0.8, "ari {:?}", r.ari);
    }

    #[test]
    fn serial_cell_runs() {
        let r = run_experiment(&quick_exp(Algorithm::KMedoidsSerial, 4), &be());
        assert!(r.time_ms > 0);
        assert!(r.cost > 0.0);
    }

    #[test]
    fn clarans_cell_runs() {
        let r = run_experiment(&quick_exp(Algorithm::Clarans, 4), &be());
        assert!(r.time_ms > 0);
        assert!(r.ari.unwrap() > 0.5);
    }

    #[test]
    fn kmeans_cell_runs() {
        let r = run_experiment(&quick_exp(Algorithm::KMeansMR, 4), &be());
        assert!(r.time_ms > 0);
    }

    #[test]
    fn algorithm_names_roundtrip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::parse(a.name()), Some(a));
        }
        assert_eq!(Algorithm::parse("kmedoids||-mr"), Some(Algorithm::KMedoidsScalableMR));
        assert_eq!(Algorithm::parse("kmedoids-coreset"), Some(Algorithm::KMedoidsCoresetMR));
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn coreset_cell_runs_with_fewer_jobs_than_iterative_mr() {
        // The acceptance bar: at equal k the coreset pipeline runs fewer
        // MR jobs than the random-init iterative driver, with comparable
        // recovery quality.
        let mut session = ClusterSession::builder().test(4).seed(71).build().unwrap();
        let mut spec = SpatialSpec::new(5000, 5, 71);
        spec.outlier_frac = 0.0;
        let data = session.ingest_spec("pts", &spec);

        let mut coreset = quick_exp(Algorithm::KMedoidsCoresetMR, 4);
        coreset.spec = spec.clone();
        // Pin iterations on both cells (as `bench scale` does) so the
        // job-count comparison cannot hinge on convergence luck.
        coreset.fixed_iters = Some(4);
        let jobs_before = session.jobs_run();
        let rc = run_cell(&mut session, &coreset, &data).unwrap();
        let coreset_jobs = session.jobs_run() - jobs_before;
        assert_eq!(rc.algorithm, "kmedoids-coreset-mr");
        assert!(rc.ari.unwrap() > 0.8, "ari {:?}", rc.ari);

        let mut iterative = quick_exp(Algorithm::KMedoidsRandomMR, 4);
        iterative.spec = spec;
        iterative.fixed_iters = Some(4);
        let jobs_before = session.jobs_run();
        let ri = run_cell(&mut session, &iterative, &data).unwrap();
        let iterative_jobs = session.jobs_run() - jobs_before;
        assert!(
            coreset_jobs < iterative_jobs,
            "coreset ran {coreset_jobs} jobs vs kmedoids-mr {iterative_jobs}"
        );
        assert_eq!(coreset_jobs, 2, "coreset is constant-round: merge job + cost pass");
        // Quality within a modest factor of the iterative fit.
        assert!(rc.cost <= ri.cost * 2.5, "coreset {} vs iterative {}", rc.cost, ri.cost);
    }

    #[test]
    fn metric_dims_cell_runs_end_to_end() {
        // One non-Euclidean, d>2 cell through the full driver path.
        let mut exp = quick_exp(Algorithm::KMedoidsPlusPlusMR, 4);
        exp.spec = exp.spec.clone().with_dims(3);
        exp.metric = Metric::Manhattan;
        let r = run_experiment(&exp, &be());
        assert_eq!(r.algorithm, "kmedoids++-mr");
        assert!(r.time_ms > 0);
        assert!(r.ari.unwrap() > 0.7, "ari {:?} (3-D Manhattan cell)", r.ari);
    }

    #[test]
    fn paper_cell_has_table5_shape() {
        let e = Experiment::paper_cell(Algorithm::KMedoidsPlusPlusMR, 7, 0, 1);
        assert_eq!(e.spec.n_points, 1_316_792);
        assert_eq!(e.k, 9);
        let scaled = e.scaled(100);
        assert_eq!(scaled.spec.n_points, 13_167);
    }

    #[test]
    fn every_algorithm_is_runnable_through_the_trait() {
        // All five grid algorithms fit on ONE shared session + one
        // ingested dataset, through `SpatialClusterer` only.
        let mut session = ClusterSession::builder().test(4).seed(71).build().unwrap();
        let mut spec = SpatialSpec::new(3000, 4, 71);
        spec.outlier_frac = 0.0;
        let data = session.ingest_spec("grid", &spec);
        for algorithm in Algorithm::ALL {
            let mut exp = quick_exp(algorithm, 4);
            exp.k = 4;
            exp.with_quality = false;
            let r = run_cell(&mut session, &exp, &data)
                .unwrap_or_else(|e| panic!("{} failed: {e:#}", algorithm.name()));
            assert_eq!(r.algorithm, algorithm.name());
            assert!(r.time_ms > 0, "{}", algorithm.name());
            assert!(r.cost > 0.0, "{}", algorithm.name());
            assert_eq!(r.n_points, 3000);
        }
    }

    #[test]
    fn dag_lane_cell_matches_mr_cell_byte_for_byte() {
        let mut exp = quick_exp(Algorithm::KMedoidsPlusPlusMR, 4);
        exp.fixed_iters = Some(3);
        let mr = run_experiment(&exp, &be());
        exp.lane = Lane::InMemoryDag;
        let dag = run_experiment(&exp, &be());
        assert_eq!(dag.cost.to_bits(), mr.cost.to_bits());
        assert_eq!(dag.dist_evals, mr.dist_evals);
        assert_eq!(dag.iterations, mr.iterations);
        assert_eq!(dag.ari, mr.ari);
        assert!(dag.time_ms < mr.time_ms, "dag {} !< mr {}", dag.time_ms, mr.time_ms);
    }

    #[test]
    fn serial_cell_refuses_a_dag_lane() {
        let mut session = ClusterSession::builder().test(4).seed(71).build().unwrap();
        let data = session.ingest_spec("pts", &SpatialSpec::new(2000, 3, 71));
        for algorithm in [Algorithm::Clarans, Algorithm::KMedoidsSerial] {
            let mut exp = quick_exp(algorithm, 4);
            exp.lane = Lane::InMemoryDag;
            let e = run_cell(&mut session, &exp, &data).unwrap_err();
            assert!(format!("{e:#}").contains("lanes"), "{}: {e:#}", algorithm.name());
        }
    }

    #[test]
    fn checkpointed_cell_resumes_byte_identically() {
        use crate::util::tempdir::TempDir;
        let tmp = TempDir::new("driver-resume");
        let mut exp = quick_exp(Algorithm::KMedoidsPlusPlusMR, 4);
        exp.checkpoint_dir = Some(tmp.path().to_path_buf());
        let full = run_experiment(&exp, &be());
        // Resume from the newest snapshot (the converged final state):
        // the fit must report the same numbers without re-iterating.
        exp.resume = true;
        let resumed = run_experiment(&exp, &be());
        assert_eq!(resumed.cost.to_bits(), full.cost.to_bits());
        assert_eq!(resumed.iterations, full.iterations);
        assert_eq!(resumed.ari, full.ari);
    }

    #[test]
    fn resume_without_checkpoint_dir_is_refused() {
        let mut session = ClusterSession::builder().test(4).seed(71).build().unwrap();
        let data = session.ingest_spec("pts", &SpatialSpec::new(2000, 3, 71));
        let mut exp = quick_exp(Algorithm::KMedoidsPlusPlusMR, 4);
        exp.resume = true;
        let e = run_cell(&mut session, &exp, &data).unwrap_err();
        assert!(format!("{e:#}").contains("checkpoint_dir"), "{e:#}");
    }

    #[test]
    fn shim_matches_session_path_on_sim_time() {
        // The compatibility shim and an explicitly-built fresh session
        // must produce identical simulated results.
        let exp = quick_exp(Algorithm::KMedoidsPlusPlusMR, 4);
        let shim = run_experiment(&exp, &be());

        let mut session = ClusterSession::builder()
            .cluster(ClusterConfig::paper_cluster().cluster_subset(exp.n_nodes))
            .backend(be())
            .seed(exp.seed)
            .build()
            .unwrap();
        let data = session.ingest_spec("points", &exp.spec);
        let direct = run_cell(&mut session, &exp, &data).unwrap();

        assert_eq!(shim.time_ms, direct.time_ms);
        assert_eq!(shim.cost, direct.cost);
        assert_eq!(shim.dist_evals, direct.dist_evals);
        assert_eq!(shim.ari, direct.ari);
    }
}
