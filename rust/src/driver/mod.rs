//! Experiment driver: end-to-end orchestration shared by the CLI, the
//! examples, and every bench.
//!
//! One [`Experiment`] = one (dataset, cluster size, algorithm) cell of the
//! paper's evaluation. [`run_experiment`] builds the simulated cluster,
//! ingests the dataset into HBase (regions) + HDFS metadata, runs the
//! requested algorithm, and returns the paper-comparable numbers
//! (execution time in ms, iterations, cost, quality).

pub mod suites;

use crate::clustering::clarans::{clarans, ClaransParams};
use crate::clustering::kmeans::ParallelKMeans;
use crate::clustering::pam::alternating_kmedoids;
use crate::clustering::parallel::ParallelKMedoids;
use crate::clustering::{metrics, ClusterOutcome, Init, IterParams, UpdateStrategy};
use crate::config::ClusterConfig;
use crate::geo::datasets::{self, SpatialDataset, SpatialSpec};
use crate::mapreduce::{input_from_table, Cluster};
use crate::runtime::ComputeBackend;
use crate::sim::CostModel;
use std::sync::Arc;

/// Algorithm selector (the rows of Fig. 5 plus ablations).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Algorithm {
    /// The paper's contribution: MR K-Medoids with ++ seeding.
    KMedoidsPlusPlusMR,
    /// "Traditional K-Medoids" parallelized: MR with random init.
    KMedoidsRandomMR,
    /// Serial traditional K-Medoids (single node).
    KMedoidsSerial,
    /// CLARANS (serial, Ng & Han).
    Clarans,
    /// Parallel k-means (robustness ablation).
    KMeansMR,
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::KMedoidsPlusPlusMR => "kmedoids++-mr",
            Algorithm::KMedoidsRandomMR => "kmedoids-mr",
            Algorithm::KMedoidsSerial => "kmedoids-serial",
            Algorithm::Clarans => "clarans",
            Algorithm::KMeansMR => "kmeans-mr",
        }
    }
    pub fn parse(s: &str) -> Option<Algorithm> {
        Some(match s {
            "kmedoids++-mr" | "kmedoids++" => Algorithm::KMedoidsPlusPlusMR,
            "kmedoids-mr" => Algorithm::KMedoidsRandomMR,
            "kmedoids-serial" => Algorithm::KMedoidsSerial,
            "clarans" => Algorithm::Clarans,
            "kmeans-mr" | "kmeans" => Algorithm::KMeansMR,
            _ => return None,
        })
    }
}

/// One experiment cell.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub algorithm: Algorithm,
    pub n_nodes: usize,
    pub spec: SpatialSpec,
    pub k: usize,
    pub update: UpdateStrategy,
    pub seed: u64,
    /// Run the final labeling pass and quality metrics (slower).
    pub with_quality: bool,
    /// Controlled iteration count (see `IterParams::fixed_iters`).
    pub fixed_iters: Option<usize>,
}

impl Experiment {
    pub fn paper_cell(algorithm: Algorithm, n_nodes: usize, dataset: usize, seed: u64) -> Experiment {
        Experiment {
            algorithm,
            n_nodes,
            spec: SpatialSpec::paper_dataset(dataset, seed),
            k: 9,
            update: UpdateStrategy::paper_scale_default(),
            seed,
            with_quality: false,
            fixed_iters: None,
        }
    }

    /// Same cell scaled down by `scale_div` for quick runs.
    pub fn scaled(mut self, scale_div: usize) -> Experiment {
        self.spec.n_points = (self.spec.n_points / scale_div).max(1000);
        self
    }
}

/// Result row: everything the paper's tables/figures report.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    pub algorithm: &'static str,
    pub n_nodes: usize,
    pub n_points: usize,
    pub dataset_mb: f64,
    /// Simulated execution time in ms (Table 6 unit).
    pub time_ms: u64,
    pub iterations: usize,
    pub cost: f64,
    pub dist_evals: u64,
    /// Adjusted Rand Index vs. generator truth (when `with_quality`).
    pub ari: Option<f64>,
    /// Real wall-clock seconds this cell took to compute.
    pub wall_s: f64,
}

/// Build a simulated cluster with the dataset ingested into HBase + HDFS.
pub fn setup_cluster(
    cfg: &ClusterConfig,
    dataset: &SpatialDataset,
    seed: u64,
) -> (Cluster, crate::mapreduce::Input, Arc<Vec<crate::geo::Point>>) {
    let mut cluster = Cluster::new(cfg.clone(), seed);
    let points = Arc::new(dataset.points.clone());
    let row_bytes = datasets::paper_row_bytes();
    let total_bytes = points.len() as u64 * row_bytes;
    // HDFS file backing the HBase table's HFiles.
    cluster.namenode.create_file("hbase/points", points.len() as u64, total_bytes);
    // HBase regions sized like DFS blocks (one split per region).
    cluster.hmaster.create_points_table("points", points.clone(), row_bytes, cfg.dfs_block_bytes);
    let input = input_from_table(&cluster.hmaster, "points");
    (cluster, input, points)
}

/// Run one experiment cell end to end.
pub fn run_experiment(exp: &Experiment, backend: &Arc<dyn ComputeBackend>) -> ExperimentResult {
    let wall0 = std::time::Instant::now();
    let dataset = datasets::generate(&exp.spec);
    let cfg = ClusterConfig::paper_cluster().cluster_subset(exp.n_nodes);
    let cost_model = CostModel::default();
    let row_bytes = datasets::paper_row_bytes();
    let dataset_bytes = dataset.points.len() as u64 * row_bytes;

    let outcome: ClusterOutcome = match exp.algorithm {
        Algorithm::KMedoidsPlusPlusMR | Algorithm::KMedoidsRandomMR => {
            let (mut cluster, input, points) = setup_cluster(&cfg, &dataset, exp.seed);
            cluster.cost = cost_model;
            let mut params = IterParams::new(exp.k, exp.seed);
            params.fixed_iters = exp.fixed_iters;
            let mut drv = ParallelKMedoids::new(backend.clone(), params);
            drv.init = if exp.algorithm == Algorithm::KMedoidsPlusPlusMR {
                Init::PlusPlus
            } else {
                Init::Random
            };
            drv.update = exp.update;
            drv.label_pass = exp.with_quality;
            drv.run(&mut cluster, &input, &points)
        }
        Algorithm::KMeansMR => {
            let (mut cluster, input, points) = setup_cluster(&cfg, &dataset, exp.seed);
            cluster.cost = cost_model;
            let km = ParallelKMeans {
                backend: backend.clone(),
                init: Init::PlusPlus,
                params: IterParams::new(exp.k, exp.seed),
            };
            km.run(&mut cluster, &input, &points)
        }
        Algorithm::KMedoidsSerial => alternating_kmedoids(// "traditional K-Medoids" (Fig. 5)
            backend.as_ref(),
            &dataset.points,
            &IterParams::new(exp.k, exp.seed),
            Init::Random,
            exp.update,
            &cfg,
            &cost_model,
            dataset_bytes,
        ),
        Algorithm::Clarans => {
            // Sampled cost evaluation above 100k points (see DESIGN.md).
            // The sample grows with n so CLARANS' time keeps its paper
            // scaling with dataset size.
            let n = dataset.points.len();
            let mut p = ClaransParams::recommended(exp.k, n, exp.seed);
            if n > 100_000 {
                p.cost_sample = (16_000 + n / 100).min(n);
                p.max_neighbor = p.max_neighbor.min(1_500);
            }
            clarans(&dataset.points, &p, &cfg, &cost_model, dataset_bytes)
        }
    };

    let ari = if exp.with_quality {
        let labels = match &outcome.labels {
            Some(l) => l.clone(),
            None => metrics::brute_labels(&dataset.points, &outcome.medoids),
        };
        Some(metrics::adjusted_rand_index(&labels, &dataset.truth))
    } else {
        None
    };

    ExperimentResult {
        algorithm: exp.algorithm.name(),
        n_nodes: exp.n_nodes,
        n_points: dataset.points.len(),
        dataset_mb: dataset_bytes as f64 / (1u64 << 20) as f64,
        time_ms: (outcome.sim_seconds * 1e3).round() as u64,
        iterations: outcome.iterations,
        cost: outcome.cost,
        dist_evals: outcome.dist_evals,
        ari,
        wall_s: wall0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn be() -> Arc<dyn ComputeBackend> {
        Arc::new(NativeBackend::new(256, 16))
    }

    fn quick_exp(algorithm: Algorithm, n_nodes: usize) -> Experiment {
        let mut spec = SpatialSpec::new(6000, 5, 71);
        spec.outlier_frac = 0.0; // quality assertions need clean recovery
        Experiment {
            algorithm,
            n_nodes,
            spec,
            fixed_iters: None,
            k: 5,
            update: UpdateStrategy::Sampled { candidates: 64, member_sample: 1024 },
            seed: 71,
            with_quality: true,
        }
    }

    #[test]
    fn mr_cell_runs_and_reports() {
        let r = run_experiment(&quick_exp(Algorithm::KMedoidsPlusPlusMR, 4), &be());
        assert_eq!(r.algorithm, "kmedoids++-mr");
        assert!(r.time_ms > 0);
        assert!(r.iterations >= 1);
        assert!(r.ari.unwrap() > 0.8, "ari {:?}", r.ari);
    }

    #[test]
    fn serial_cell_runs() {
        let r = run_experiment(&quick_exp(Algorithm::KMedoidsSerial, 4), &be());
        assert!(r.time_ms > 0);
        assert!(r.cost > 0.0);
    }

    #[test]
    fn clarans_cell_runs() {
        let r = run_experiment(&quick_exp(Algorithm::Clarans, 4), &be());
        assert!(r.time_ms > 0);
        assert!(r.ari.unwrap() > 0.5);
    }

    #[test]
    fn kmeans_cell_runs() {
        let r = run_experiment(&quick_exp(Algorithm::KMeansMR, 4), &be());
        assert!(r.time_ms > 0);
    }

    #[test]
    fn algorithm_names_roundtrip() {
        for a in [
            Algorithm::KMedoidsPlusPlusMR,
            Algorithm::KMedoidsRandomMR,
            Algorithm::KMedoidsSerial,
            Algorithm::Clarans,
            Algorithm::KMeansMR,
        ] {
            assert_eq!(Algorithm::parse(a.name()), Some(a));
        }
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn paper_cell_has_table5_shape() {
        let e = Experiment::paper_cell(Algorithm::KMedoidsPlusPlusMR, 7, 0, 1);
        assert_eq!(e.spec.n_points, 1_316_792);
        assert_eq!(e.k, 9);
        let scaled = e.scaled(100);
        assert_eq!(scaled.spec.n_points, 13_167);
    }
}
