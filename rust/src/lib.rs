//! # kmedoids-mr
//!
//! Reproduction of *"Parallel K-Medoids++ Spatial Clustering Algorithm
//! Based on MapReduce"* (Yue, Man, Yue, Liu — CS.DC 2016) as a
//! three-layer Rust + JAX/Pallas system:
//!
//! - **L3 (this crate)**: a complete MapReduce runtime (HDFS-lite,
//!   HBase-lite, JobTracker with locality/speculation/fault-tolerance)
//!   running on a deterministic discrete-event cluster simulator, plus the
//!   paper's parallel K-Medoids++ driver and every baseline
//!   (PAM, CLARANS, parallel k-means).
//! - **L2/L1 (python/, build-time only)**: the distance/assignment hot
//!   path as JAX graphs wrapping Pallas kernels, AOT-lowered to HLO text
//!   artifacts executed from Rust through PJRT ([`runtime`]).
//!
//! ## Public API: sessions, solvers, observers
//!
//! The API is organized around three layers (import everything from
//! [`prelude`]):
//!
//! 1. **[`session::ClusterSession`]** owns the simulated cluster, the
//!    compute backend, and the ingested datasets as reusable
//!    [`session::DatasetHandle`]s — build and ingest once, then run many
//!    algorithms against the same data, with per-session counters and
//!    sim-clock accounting.
//! 2. **[`clustering::api::SpatialClusterer`]** is the trait every
//!    algorithm implements, each constructed through a fluent builder:
//!    `KMedoids::mapreduce().plus_plus().k(9).build()`,
//!    `KMedoids::coreset()` (constant-round weighted-coreset pipeline),
//!    `KMedoids::serial()`, `KMeans::mapreduce()`, `Clarans::serial()`.
//! 3. **[`clustering::observe::IterationObserver`]** hooks registered on
//!    the session stream one [`clustering::observe::IterationEvent`] per
//!    outer iteration (cost, medoid drift, sim seconds, distance evals)
//!    to the CLI, report module, and benches while a fit runs.
//!
//! The experiment grid of the paper sits on top in [`driver`]
//! ([`driver::Experiment`] cells, JSON run-specs in [`driver::spec`], and
//! the Table 6 / Fig. 4 / Fig. 5 suites in [`driver::suites`]);
//! [`driver::run_experiment`] remains as a one-call compatibility shim
//! that wraps a fresh single-use session.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for the
//! measured reproduction of every table/figure.

pub mod clustering;
pub mod config;
pub mod dfs;
pub mod driver;
pub mod geo;
pub mod hbase;
pub mod mapreduce;
pub mod persist;
pub mod prelude;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod sim;
pub mod util;
