//! # kmedoids-mr
//!
//! Reproduction of *"Parallel K-Medoids++ Spatial Clustering Algorithm
//! Based on MapReduce"* (Yue, Man, Yue, Liu — CS.DC 2016) as a
//! three-layer Rust + JAX/Pallas system:
//!
//! - **L3 (this crate)**: a complete MapReduce runtime (HDFS-lite,
//!   HBase-lite, JobTracker with locality/speculation/fault-tolerance)
//!   running on a deterministic discrete-event cluster simulator, plus the
//!   paper's parallel K-Medoids++ driver and every baseline
//!   (PAM, CLARANS, parallel k-means).
//! - **L2/L1 (python/, build-time only)**: the distance/assignment hot
//!   path as JAX graphs wrapping Pallas kernels, AOT-lowered to HLO text
//!   artifacts executed from Rust through PJRT ([`runtime`]).
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for the
//! measured reproduction of every table/figure.

pub mod clustering;
pub mod config;
pub mod dfs;
pub mod driver;
pub mod geo;
pub mod hbase;
pub mod mapreduce;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
