//! Parallel K-Medoids / K-Medoids++ on MapReduce — the paper's §3.2–3.3.
//!
//! Each outer iteration is one MR job:
//! - **Map** (Table 1): assign every point of the split to its nearest
//!   medoid (through the AOT Pallas/JAX assign kernel for the 2-D
//!   squared-Euclidean fast path, the generic metric kernels otherwise)
//!   and emit `(clusterID, member coordinates)`. Member coordinates are
//!   packed per (cluster, split) block — byte-identical shuffle volume to
//!   the paper's per-point emits, without per-record allocation overhead.
//! - **Reduce** (Table 2): gather the cluster's members and choose the
//!   candidate with the least total cost as the new medoid (exact PAM
//!   update, sampled update, or centroid-nearest — [`UpdateStrategy`]).
//! - **Driver** (§3.3 step 3): compare the new medoids file with the
//!   previous one; if unchanged, emit the result, else iterate.
//!
//! The medoids file lives in an HBase cell table (`__medoids__`), matching
//! the paper's "file of medoids" that mappers load each iteration.
//!
//! The whole driver is metric- and dimension-generic: the run's
//! [`Metric`] and the dataset's dimensionality thread through the wire
//! format (coordinate runs are `dims` f32s per point), the kernels, and
//! the update step, and outputs stay byte-identical across compute
//! thread counts for every `(dims, metric)` pair (enforced by tests).
//!
//! The driver is also execution-lane agnostic: it submits [`JobSpec`]s
//! through [`Cluster::try_run_job`], which dispatches to the cluster's
//! active [`crate::mapreduce::Lane`] — the Hadoop MR scheduler or the
//! in-memory DAG runtime. Jobs reuse the same map/reduce compute either
//! way, so a fit's medoids, labels, cost bits, and dist-eval counters
//! are byte-identical across lanes; only simulated time differs (the
//! DAG lane keeps parsed splits resident across the iteration loop,
//! which is precisely where iterative K-Medoids wins on it).

use super::observe::{FitCheckpoint, IterationEvent, ObserverHub};
use super::seeding::init_mr;
use super::{ClusterOutcome, FitResume, Init, IterParams, UpdateStrategy};
use crate::geo::{Metric, Point, PointSource};
use crate::mapreduce::{
    Cluster, Input, JobSpec, MapCtx, Mapper, ReduceCtx, Reducer,
};
use crate::runtime::{assign_points, ops, ComputeBackend, PrunedAssigner};
use crate::util::codec::{
    decode_cluster_key, decode_point_coords, encode_cluster_key, encode_point_coords, Dec, Enc,
    PackedPoints,
};
use crate::util::nearest::{argmin_f64, nearest_point};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Driver configuration for the MR K-Medoids family.
pub struct ParallelKMedoids {
    pub backend: Arc<dyn ComputeBackend>,
    pub init: Init,
    pub update: UpdateStrategy,
    pub params: IterParams,
    /// Dissimilarity the fit minimizes (kernel-dispatched).
    pub metric: Metric,
    /// Run a final map-only labeling job (the paper's "output the
    /// clustering result" step). Costs one more pass of simulated time.
    pub label_pass: bool,
    /// Override the algorithm name events are tagged with (used by the
    /// k-means driver when it falls back to medoid updates for
    /// non-Euclidean metrics).
    pub event_label: Option<&'static str>,
    /// Restored mid-fit state: skip seeding and continue from this
    /// checkpoint boundary. Because per-iteration RNG streams are
    /// reseeded from the base seed, the resumed trajectory is
    /// byte-identical to the uninterrupted one (chaos-harness enforced).
    pub resume: Option<FitResume>,
}

impl ParallelKMedoids {
    pub fn new(backend: Arc<dyn ComputeBackend>, params: IterParams) -> ParallelKMedoids {
        ParallelKMedoids {
            backend,
            init: Init::PlusPlus,
            update: UpdateStrategy::Exact,
            params,
            metric: Metric::SqEuclidean,
            label_pass: false,
            event_label: None,
            resume: None,
        }
    }

    /// Reject a checkpoint that was not written by this exact fit
    /// configuration — resuming across algorithm/metric/seed/k/dims
    /// would silently produce a different (wrong) trajectory.
    fn validate_resume(&self, r: &FitResume, dims: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            r.algorithm == self.event_name(),
            "resume checkpoint was written by '{}' but this fit is '{}'",
            r.algorithm,
            self.event_name()
        );
        anyhow::ensure!(
            r.metric == self.metric,
            "resume checkpoint metric '{}' does not match fit metric '{}'",
            r.metric.name(),
            self.metric.name()
        );
        anyhow::ensure!(
            r.seed == self.params.seed,
            "resume checkpoint seed {} does not match fit seed {} (rerun with --seed {})",
            r.seed,
            self.params.seed,
            r.seed
        );
        anyhow::ensure!(
            r.medoids.len() == self.params.k,
            "resume checkpoint has {} medoids but k = {}",
            r.medoids.len(),
            self.params.k
        );
        anyhow::ensure!(
            r.medoids.iter().all(|m| m.dims() == dims),
            "resume checkpoint medoids are not {dims}-dimensional like the data"
        );
        Ok(())
    }

    /// Run to convergence on the simulated cluster. Panics on job-level
    /// failure; use [`ParallelKMedoids::run_observed`] to propagate errors
    /// and stream per-iteration events.
    pub fn run(
        &self,
        cluster: &mut Cluster,
        input: &Input,
        points: &Arc<Vec<Point>>,
    ) -> ClusterOutcome {
        self.run_observed(cluster, input, points, &mut ObserverHub::default())
            .expect("parallel k-medoids job failed")
    }

    /// The algorithm name events are tagged with (`Algorithm` vocabulary).
    fn event_name(&self) -> &'static str {
        if let Some(label) = self.event_label {
            return label;
        }
        match self.init {
            Init::PlusPlus => "kmedoids++-mr",
            Init::Random => "kmedoids-mr",
            Init::OverSample { .. } => "kmedoids-scalable-mr",
        }
    }

    /// Run to convergence, emitting one [`IterationEvent`] per outer
    /// iteration through `hub`. Event `sim_seconds`/`dist_evals` are
    /// cumulative from the start of the fit (seeding included), so with
    /// `label_pass == false` the last event matches the final
    /// [`ClusterOutcome`] exactly.
    pub fn run_observed(
        &self,
        cluster: &mut Cluster,
        input: &Input,
        points: &Arc<Vec<Point>>,
        hub: &mut ObserverHub,
    ) -> anyhow::Result<ClusterOutcome> {
        let k = self.params.k;
        let t_start = cluster.now().0;
        let dims = points.first().map(|p| p.dims()).unwrap_or(2);
        anyhow::ensure!(
            self.metric.supports_dims(dims),
            "metric {} does not support {dims}-dimensional data",
            self.metric.name()
        );

        // §3.2 step (1): initial medoids — or, on resume, the restored
        // checkpoint boundary (seeding is skipped entirely; its cost was
        // already paid and is carried in the checkpoint's counters).
        let (mut medoids, start_iter, start_cost, start_evals, sim_offset, already_converged) =
            match &self.resume {
                Some(r) => {
                    self.validate_resume(r, dims)?;
                    (
                        r.medoids.clone(),
                        r.iteration,
                        r.cost,
                        r.dist_evals,
                        r.sim_seconds,
                        r.converged,
                    )
                }
                None => {
                    let (medoids, _seed_s) = init_mr(
                        self.init,
                        cluster,
                        input,
                        points,
                        &self.backend,
                        k,
                        self.params.seed,
                        self.metric,
                    )?;
                    (medoids, 0, f64::INFINITY, 0, 0.0, false)
                }
            };

        // The paper's medoids file (HBase cell table).
        if cluster.hmaster.table("__medoids__").is_none() {
            cluster.hmaster.create_cell_table("__medoids__", &["m"]);
        }
        write_medoids_file(cluster, &medoids);

        // Pruned assignment lane: byte-identical labels/cost either way,
        // fewer distance evaluations. `Auto` keeps the dense lane for
        // checkpointed/resumed fits so `dist_evals` stays byte-identical
        // with a crash-resumed rerun (bounds are not persisted).
        let pruned: Option<Arc<PrunedAssigner>> = self
            .params
            .pruning
            .enabled(hub.wants_checkpoints(), self.resume.is_some())
            .then(|| Arc::new(PrunedAssigner::new(self.metric)));

        let n_reduces = k.min(total_reduce_slots(cluster)).max(1);
        let mut iterations = start_iter;
        let mut cost = start_cost;
        let mut dist_evals = start_evals;

        let iter_cap = self.params.fixed_iters.unwrap_or(self.params.max_iters);
        let first_iter = if already_converged { iter_cap } else { start_iter };
        for iter in first_iter..iter_cap {
            iterations = iter + 1;
            // One shared, immutable medoid slab per iteration: the mapper
            // and reducer hold `Arc` clones instead of deep-copied
            // `Vec<Point>`s (§Perf: no per-job medoid duplication).
            let shared_medoids: Arc<[Point]> = Arc::from(medoids.as_slice());
            if let Some(pa) = &pruned {
                pa.begin_epoch(&medoids);
            }
            let job = JobSpec::new(
                &format!("kmedoids-iter{iter}"),
                input.clone(),
                Arc::new(AssignMapper {
                    backend: self.backend.clone(),
                    medoids: shared_medoids.clone(),
                    metric: self.metric,
                    pruned: pruned.clone(),
                }),
            )
            .with_reducer(
                Arc::new(UpdateReducer {
                    backend: self.backend.clone(),
                    medoids: shared_medoids,
                    update: self.update,
                    metric: self.metric,
                    // Seed fixed across iterations: the sampled update's
                    // candidate draw must be a deterministic function of
                    // the (stable) member set so the medoid-equality
                    // convergence test can actually fire.
                    seed: self.params.seed,
                }),
                n_reduces,
            )
            // Cluster ids are dense small ints: modulo keeps reducers even.
            .with_partitioner(Arc::new(|key: &[u8], n: usize| {
                decode_cluster_key(key) as usize % n
            }));

            let result = cluster.try_run_job(&job)?;
            let new_cost = result.counters.get("assign.cost.units") as f64;
            dist_evals += result.counters.get("work.dist.evals");

            // Decode the updated medoids file.
            let mut new_medoids = medoids.clone();
            for (key, val) in &result.output {
                let j = decode_cluster_key(key) as usize;
                new_medoids[j] = decode_point_coords(val, dims);
            }
            write_medoids_file(cluster, &new_medoids);

            // §3.3 step (3): stop when the medoids file is unchanged.
            let unchanged = new_medoids.iter().zip(&medoids).all(|(a, b)| a == b);
            let cost_flat = cost.is_finite()
                && (cost - new_cost).abs() <= self.params.rel_tol * cost.abs().max(1.0);
            let drift: f64 = new_medoids
                .iter()
                .zip(&medoids)
                .map(|(a, b)| self.metric.displacement(a, b))
                .sum();
            medoids = new_medoids;
            cost = new_cost;
            let converged_now = self.params.fixed_iters.is_none() && (unchanged || cost_flat);
            hub.iteration(&IterationEvent {
                algorithm: self.event_name(),
                iteration: iterations,
                cost,
                medoid_drift: drift,
                sim_seconds: sim_offset + (cluster.now().0 - t_start),
                dist_evals,
            });
            // A resumable snapshot exists at every iteration boundary;
            // `converged` must be recorded so that resuming from the
            // final snapshot runs zero further iterations (one more
            // `cost_flat` iteration would move the medoids again).
            hub.checkpoint(&FitCheckpoint {
                algorithm: self.event_name(),
                metric: self.metric,
                seed: self.params.seed,
                k,
                iteration: iterations,
                cost,
                sim_seconds: sim_offset + (cluster.now().0 - t_start),
                dist_evals,
                converged: converged_now,
                medoids: &medoids,
                coreset: None,
            });
            if converged_now {
                break;
            }
        }

        // Optional final labeling pass (map-only). Its distance
        // evaluations count toward the outcome and the session counters
        // exactly like every iteration's (they are charged to the
        // simulated clock either way — the accounting must agree).
        let labels = if self.label_pass {
            if let Some(pa) = &pruned {
                pa.begin_epoch(&medoids);
            }
            let (labels, label_evals) = run_label_pass(
                cluster,
                input,
                points,
                &self.backend,
                &medoids,
                self.metric,
                pruned.clone(),
            )?;
            dist_evals += label_evals;
            Some(labels)
        } else {
            None
        };

        Ok(ClusterOutcome {
            medoids,
            labels,
            cost,
            iterations,
            sim_seconds: sim_offset + (cluster.now().0 - t_start),
            dist_evals,
        })
    }
}

fn total_reduce_slots(cluster: &Cluster) -> usize {
    cluster.config.nodes.iter().map(|n| n.reduce_slots()).sum()
}

fn write_medoids_file(cluster: &mut Cluster, medoids: &[Point]) {
    for (j, m) in medoids.iter().enumerate() {
        cluster.hmaster.put("__medoids__", j as u64, "m:xy", encode_point_coords(m));
    }
}

// ---- map side --------------------------------------------------------------

/// Table 1: nearest-medoid assignment for one split.
struct AssignMapper {
    backend: Arc<dyn ComputeBackend>,
    /// Shared with the reducer and the driver — no per-job deep copy.
    medoids: Arc<[Point]>,
    metric: Metric,
    /// Pruned lane (byte-identical output, fewer evals) — `None` runs
    /// the dense kernels. Split state is keyed by `row_start`, which is
    /// stable per split across iterations.
    pruned: Option<Arc<PrunedAssigner>>,
}

impl Mapper for AssignMapper {
    fn map_points(&self, ctx: &mut MapCtx, row_start: u64, pts: &[Point]) {
        let res = match &self.pruned {
            Some(pa) => pa.assign_split(self.backend.as_ref(), row_start, pts, &self.medoids),
            None => assign_points(self.backend.as_ref(), pts, &self.medoids, self.metric),
        }
        .expect("assign kernel failed");
        ctx.charge_dist_evals(res.dist_evals);
        ctx.counters.inc("work.dist.evals", res.dist_evals);

        // Pack members per cluster straight into the emit byte buffers
        // (same shuffle bytes as per-point emits, no intermediate
        // `Vec<f32>` staging — the wire format is written in one pass;
        // dims f32s per point).
        let k = self.medoids.len();
        let mut bufs: Vec<Vec<u8>> = vec![Vec::new(); k];
        for (p, &l) in pts.iter().zip(&res.labels) {
            let b = &mut bufs[l as usize];
            for c in p.coords() {
                b.extend_from_slice(&c.to_le_bytes());
            }
        }
        for (j, bytes) in bufs.into_iter().enumerate() {
            if !bytes.is_empty() {
                ctx.emit(encode_cluster_key(j as u32), bytes);
            }
        }
        // Iteration cost E (Eq. 1) via counters (integral map units²).
        let split_cost: f64 = res.cluster_cost.iter().sum();
        ctx.counters.inc("assign.cost.units", split_cost.round() as u64);
    }
}

// ---- reduce side -------------------------------------------------------------

/// Table 2: choose the least-cost candidate as the cluster's new medoid.
struct UpdateReducer {
    backend: Arc<dyn ComputeBackend>,
    /// Shared with the mapper and the driver — no per-job deep copy.
    medoids: Arc<[Point]>,
    update: UpdateStrategy,
    metric: Metric,
    seed: u64,
}

impl Reducer for UpdateReducer {
    fn reduce(&self, ctx: &mut ReduceCtx, key: &[u8], values: &[Vec<u8>]) {
        let j = decode_cluster_key(key) as usize;
        let current = self.medoids[j];
        // Zero-copy member view: the shuffle values are packed coordinate
        // runs (dims f32s per point), read as `&[f32]` views in place
        // (decode only on the misaligned/big-endian fallback) — no
        // `Vec<Point>`.
        let members = PackedPoints::new(current.dims(), values.iter().map(|v| v.as_slice()));
        if members.is_empty() {
            ctx.emit(key.to_vec(), encode_point_coords(&current));
            return;
        }
        let new_medoid = choose_medoid(
            self.backend.as_ref(),
            &members,
            current,
            self.update,
            self.metric,
            self.seed ^ j as u64,
            ctx,
        );
        ctx.emit(key.to_vec(), encode_point_coords(&new_medoid));
    }
}

/// The medoid-update step, shared with the serial baselines. Generic over
/// [`PointSource`] so the MR reducer can pass zero-copy shuffle-byte
/// views while the serial engines pass plain `&[Point]` slices.
pub fn choose_medoid<M: PointSource + ?Sized>(
    backend: &dyn ComputeBackend,
    members: &M,
    current: Point,
    update: UpdateStrategy,
    metric: Metric,
    seed: u64,
    ctx: &mut ReduceCtx,
) -> Point {
    let m = members.len();
    match update {
        UpdateStrategy::Exact => {
            let (costs, evals) = ops::pairwise_costs_src(backend, members, members, metric)
                .expect("pairwise kernel");
            ctx.charge_dist_evals(evals);
            ctx.counters.inc("work.dist.evals", evals);
            members.get(argmin_f64(&costs))
        }
        UpdateStrategy::SampledAdaptive { candidates, frac_div, min_sample } => {
            let member_sample = (m / frac_div.max(1)).max(min_sample);
            choose_medoid(
                backend,
                members,
                current,
                UpdateStrategy::Sampled { candidates, member_sample },
                metric,
                seed,
                ctx,
            )
        }
        UpdateStrategy::Sampled { candidates, member_sample } => {
            let mut rng = Rng::new(seed);
            let cand_idx = rng.sample_indices(m, candidates.min(m));
            // Candidate 0 is always the current medoid so "keep" is always
            // on the table (prevents thrash near convergence).
            let mut cands: Vec<Point> = vec![current];
            cands.extend(cand_idx.iter().map(|&i| members.get(i)));
            let sample: Vec<Point> = if m <= member_sample {
                (0..m).map(|i| members.get(i)).collect()
            } else {
                rng.sample_indices(m, member_sample)
                    .into_iter()
                    .map(|i| members.get(i))
                    .collect()
            };
            let (costs, evals) =
                ops::pairwise_costs_src(backend, cands.as_slice(), sample.as_slice(), metric)
                    .expect("pairwise kernel");
            ctx.charge_dist_evals(evals);
            ctx.counters.inc("work.dist.evals", evals);
            cands[argmin_f64(&costs)]
        }
        UpdateStrategy::CentroidNearest => {
            // Mean anchor, then the member nearest the anchor under the
            // run's metric (Zhang & Couloigner style fast update; for
            // non-Euclidean metrics the mean is only a search anchor,
            // the result is still a data point). O(m).
            let c = if metric == Metric::Haversine {
                // Spherical mean: average the members' unit vectors and
                // convert back to (lat, lon) — a raw degree-space mean
                // breaks for clusters straddling the antimeridian
                // (members at +179° and −179° would average to ~0°,
                // the opposite side of the planet).
                let (mut sx, mut sy, mut sz) = (0f64, 0f64, 0f64);
                for i in 0..m {
                    let p = members.get(i);
                    let lat = (p.x() as f64).to_radians();
                    let lon = (p.y() as f64).to_radians();
                    sx += lat.cos() * lon.cos();
                    sy += lat.cos() * lon.sin();
                    sz += lat.sin();
                }
                let lat = sz.atan2((sx * sx + sy * sy).sqrt()).to_degrees();
                let lon = sy.atan2(sx).to_degrees();
                Point::new(lat as f32, lon as f32)
            } else {
                let dims = members.dims();
                let mut sums = vec![0f64; dims];
                for i in 0..m {
                    let p = members.get(i);
                    for (t, s) in sums.iter_mut().enumerate() {
                        *s += p.coord(t) as f64;
                    }
                }
                let mean: Vec<f32> = sums.iter().map(|s| (*s / m as f64) as f32).collect();
                Point::from_slice(&mean)
            };
            let (best, _) = nearest_point(c, (0..m).map(|i| members.get(i)), metric)
                .expect("non-empty member set");
            let evals = 2 * m as u64;
            ctx.charge_dist_evals(evals);
            ctx.counters.inc("work.dist.evals", evals);
            members.get(best)
        }
    }
}

// ---- final labeling pass ----------------------------------------------------

struct LabelMapper {
    backend: Arc<dyn ComputeBackend>,
    medoids: Arc<[Point]>,
    metric: Metric,
    pruned: Option<Arc<PrunedAssigner>>,
}

impl Mapper for LabelMapper {
    fn map_points(&self, ctx: &mut MapCtx, row_start: u64, pts: &[Point]) {
        let res = match &self.pruned {
            Some(pa) => pa.assign_split(self.backend.as_ref(), row_start, pts, &self.medoids),
            None => assign_points(self.backend.as_ref(), pts, &self.medoids, self.metric),
        }
        .expect("assign kernel failed");
        // Charge the sim *and* the work counter — the label pass's evals
        // must reach `ClusterOutcome::dist_evals` like every other pass.
        ctx.charge_dist_evals(res.dist_evals);
        ctx.counters.inc("work.dist.evals", res.dist_evals);
        let mut enc = Enc::with_capacity(4 * pts.len());
        for &l in &res.labels {
            enc = enc.u32(l);
        }
        ctx.emit(Enc::new().u64(row_start).done(), enc.done());
    }
}

/// Run the final map-only labeling job. Returns the labels plus the
/// pass's distance evaluations (from the job's `work.dist.evals`
/// counter) so the driver can fold them into the outcome total.
fn run_label_pass(
    cluster: &mut Cluster,
    input: &Input,
    points: &Arc<Vec<Point>>,
    backend: &Arc<dyn ComputeBackend>,
    medoids: &[Point],
    metric: Metric,
    pruned: Option<Arc<PrunedAssigner>>,
) -> anyhow::Result<(Vec<u32>, u64)> {
    let job = JobSpec::new(
        "kmedoids-labels",
        input.clone(),
        Arc::new(LabelMapper {
            backend: backend.clone(),
            medoids: Arc::from(medoids),
            metric,
            pruned,
        }),
    );
    let result = cluster.try_run_job(&job)?;
    let mut labels = vec![0u32; points.len()];
    for (key, val) in &result.output {
        let row_start = Dec::new(key).u64() as usize;
        let mut d = Dec::new(val);
        let mut i = row_start;
        while !d.is_empty() {
            labels[i] = d.u32();
            i += 1;
        }
    }
    Ok((labels, result.counters.get("work.dist.evals")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::metrics::{adjusted_rand_index, total_cost, total_cost_metric};
    use crate::config::ClusterConfig;
    use crate::geo::datasets::{generate, SpatialSpec};
    use crate::mapreduce::{SplitMeta, SplitOrigin};
    use crate::runtime::NativeBackend;

    fn backend() -> Arc<dyn ComputeBackend> {
        Arc::new(NativeBackend::new(256, 16))
    }

    fn make_input(points: &Arc<Vec<Point>>, n_splits: usize) -> Input {
        let total = points.len() as u64;
        let splits = (0..n_splits as u64)
            .map(|i| SplitMeta {
                row_start: total * i / n_splits as u64,
                row_end: total * (i + 1) / n_splits as u64,
                bytes: 4 << 20,
                preferred: vec![],
                origin: SplitOrigin::Adhoc,
            })
            .collect();
        Input::Points { points: points.clone(), splits }
    }

    fn run_once(
        n: usize,
        k: usize,
        init: Init,
        update: UpdateStrategy,
        seed: u64,
    ) -> (ClusterOutcome, Arc<Vec<Point>>, Vec<Option<u32>>) {
        // Recovery tests use outlier-free data: squared-distance ++
        // seeding is known to seed on extreme outliers (see the dedicated
        // robustness test in kmeans.rs for the outlier behaviour).
        let mut spec = SpatialSpec::new(n, k, seed);
        spec.outlier_frac = 0.0;
        let d = generate(&spec);
        let points = Arc::new(d.points);
        let input = make_input(&points, 6);
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), seed);
        let mut driver = ParallelKMedoids::new(backend(), IterParams::new(k, seed));
        driver.init = init;
        driver.update = update;
        driver.label_pass = true;
        let out = driver.run(&mut cluster, &input, &points);
        (out, points, d.truth)
    }

    #[test]
    fn recovers_planted_clusters() {
        let (out, points, truth) = run_once(4000, 5, Init::PlusPlus, UpdateStrategy::Exact, 3);
        assert_eq!(out.medoids.len(), 5);
        assert!((1..30).contains(&out.iterations));
        let labels = out.labels.as_ref().unwrap();
        let ari = adjusted_rand_index(labels, &truth);
        assert!(ari > 0.9, "ARI {ari} too low — clusters not recovered");
        // Cost from counters matches the brute-force Eq. 1 cost.
        let brute = total_cost(&points, &out.medoids);
        assert!(
            (out.cost - brute).abs() / brute.max(1.0) < 0.01,
            "counter cost {} vs brute {brute}",
            out.cost
        );
    }

    #[test]
    fn medoids_are_data_points() {
        let (out, points, _) = run_once(2000, 4, Init::PlusPlus, UpdateStrategy::Exact, 5);
        for m in &out.medoids {
            assert!(
                points.iter().any(|p| p == m),
                "medoid {m:?} must be an input point (K-Medoids, not K-Means)"
            );
        }
    }

    #[test]
    fn deterministic() {
        let a = run_once(2000, 4, Init::PlusPlus, UpdateStrategy::Exact, 7).0;
        let b = run_once(2000, 4, Init::PlusPlus, UpdateStrategy::Exact, 7).0;
        assert_eq!(a.medoids, b.medoids);
        assert_eq!(a.sim_seconds, b.sim_seconds);
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn sampled_update_close_to_exact() {
        let exact = run_once(4000, 5, Init::PlusPlus, UpdateStrategy::Exact, 11).0;
        let sampled = run_once(
            4000,
            5,
            Init::PlusPlus,
            UpdateStrategy::Sampled { candidates: 128, member_sample: 2048 },
            11,
        )
        .0;
        assert!(
            sampled.cost < exact.cost * 1.15,
            "sampled {} vs exact {}",
            sampled.cost,
            exact.cost
        );
    }

    #[test]
    fn centroid_nearest_converges() {
        // Seed chosen to land in the global basin (alternating k-medoids
        // is a local-optimum method like Lloyd's).
        let (out, _, truth) =
            run_once(4000, 4, Init::PlusPlus, UpdateStrategy::CentroidNearest, 62);
        let ari = adjusted_rand_index(out.labels.as_ref().unwrap(), &truth);
        assert!(ari > 0.8, "ARI {ari}");
    }

    #[test]
    fn oversample_init_recovers_clusters() {
        let (out, _, truth) =
            run_once(4000, 5, Init::oversample_default(5), UpdateStrategy::Exact, 3);
        assert_eq!(out.medoids.len(), 5);
        let ari = adjusted_rand_index(out.labels.as_ref().unwrap(), &truth);
        assert!(ari > 0.9, "ARI {ari} (|| seeding)");
    }

    #[test]
    fn plus_plus_converges_in_fewer_or_equal_iterations_on_average() {
        // The paper's §3.1 claim. Averaged over seeds to kill variance.
        let seeds = [101u64, 103, 107, 109, 113, 127, 131, 137];
        let mut pp = 0usize;
        let mut rnd = 0usize;
        for &s in &seeds {
            pp += run_once(2500, 6, Init::PlusPlus, UpdateStrategy::Exact, s).0.iterations;
            rnd += run_once(2500, 6, Init::Random, UpdateStrategy::Exact, s).0.iterations;
        }
        assert!(
            pp <= rnd,
            "++ iterations {pp} should not exceed random-init iterations {rnd}"
        );
    }

    #[test]
    fn empty_cluster_keeps_medoid() {
        // k larger than natural clusters; some clusters may end up empty —
        // driver must not panic and must keep k medoids.
        let (out, _, _) = run_once(300, 8, Init::Random, UpdateStrategy::Exact, 17);
        assert_eq!(out.medoids.len(), 8);
    }

    #[test]
    fn compute_threads_produce_identical_fits() {
        // The whole point of the worker pool: threads ∈ {1, 2, 8} change
        // only the wall clock. Medoids, cost, simulated time, distance
        // evals, and labels must be byte-identical.
        for &seed in &[3u64, 41] {
            let mut spec = SpatialSpec::new(3000, 4, seed);
            spec.outlier_frac = 0.0;
            let d = generate(&spec);
            let points = Arc::new(d.points);
            let run = |threads: usize| {
                let input = make_input(&points, 6);
                let mut cluster =
                    Cluster::new(ClusterConfig::test_cluster(4), seed).with_threads(threads);
                let mut driver = ParallelKMedoids::new(backend(), IterParams::new(4, seed));
                driver.label_pass = true;
                let out = driver.run(&mut cluster, &input, &points);
                (out.medoids, out.cost, out.sim_seconds, out.dist_evals, out.labels)
            };
            let base = run(1);
            assert_eq!(base, run(2), "seed {seed}: 2 threads diverged");
            assert_eq!(base, run(8), "seed {seed}: 8 threads diverged");
        }
    }

    #[test]
    fn compute_threads_identical_for_every_dims_metric_pair() {
        // The byte-identical-across-thread-counts invariant (PR 2) must
        // hold for every supported (dims, metric) combination, at
        // d ∈ {2, 3, 8}: medoids, cost, sim clock, evals, and labels.
        let combos: [(usize, bool, Metric); 7] = [
            (2, false, Metric::SqEuclidean),
            (2, false, Metric::Manhattan),
            (2, true, Metric::Haversine),
            (3, false, Metric::SqEuclidean),
            (3, false, Metric::Manhattan),
            (8, false, Metric::SqEuclidean),
            (8, false, Metric::Manhattan),
        ];
        for (dims, latlon, metric) in combos {
            let spec = if latlon {
                SpatialSpec::latlon(1000, 3, 29)
            } else {
                let mut s = SpatialSpec::new(1000, 3, 29);
                s.outlier_frac = 0.0;
                s.with_dims(dims)
            };
            let d = generate(&spec);
            let points = Arc::new(d.points);
            let run = |threads: usize| {
                let input = make_input(&points, 5);
                let mut cluster =
                    Cluster::new(ClusterConfig::test_cluster(4), 29).with_threads(threads);
                let mut driver = ParallelKMedoids::new(backend(), IterParams::new(3, 29));
                driver.metric = metric;
                driver.label_pass = true;
                let out = driver.run(&mut cluster, &input, &points);
                (out.medoids, out.cost, out.sim_seconds, out.dist_evals, out.labels)
            };
            let base = run(1);
            assert_eq!(base, run(4), "d={dims} {metric:?}: 4 threads diverged");
            // Medoids keep the dataset's dimensionality.
            assert!(base.0.iter().all(|m| m.dims() == dims), "d={dims} {metric:?}");
        }
    }

    #[test]
    fn manhattan_fit_minimizes_manhattan_cost() {
        let mut spec = SpatialSpec::new(3000, 4, 47);
        spec.outlier_frac = 0.0;
        let d = generate(&spec);
        let points = Arc::new(d.points);
        let input = make_input(&points, 5);
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 47);
        let mut driver = ParallelKMedoids::new(backend(), IterParams::new(4, 47));
        driver.metric = Metric::Manhattan;
        let out = driver.run(&mut cluster, &input, &points);
        // Counter cost equals the brute-force L1 objective.
        let brute = total_cost_metric(&points, &out.medoids, Metric::Manhattan);
        assert!(
            (out.cost - brute).abs() / brute.max(1.0) < 0.01,
            "counter {} vs brute {brute}",
            out.cost
        );
        // Medoids are data points (K-Medoids invariant, any metric).
        for m in &out.medoids {
            assert!(points.iter().any(|p| p == m));
        }
    }

    #[test]
    fn haversine_fit_on_latlon_clouds() {
        let d = generate(&SpatialSpec::latlon(3000, 4, 59));
        let points = Arc::new(d.points);
        let input = make_input(&points, 5);
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 59);
        let mut driver = ParallelKMedoids::new(backend(), IterParams::new(4, 59));
        driver.metric = Metric::Haversine;
        driver.label_pass = true;
        let out = driver.run(&mut cluster, &input, &points);
        let ari = adjusted_rand_index(out.labels.as_ref().unwrap(), &d.truth);
        assert!(ari > 0.8, "ARI {ari} (haversine city recovery)");
        // Every fitted medoid sits within a few hundred km of a true city.
        let sigma_km = 90.0 * 0.03 * 111.2;
        for m in &out.medoids {
            let nearest = d
                .centers
                .iter()
                .map(|c| Metric::Haversine.distance(m, c))
                .fold(f64::INFINITY, f64::min);
            assert!(nearest < 6.0 * sigma_km, "medoid {nearest} km from any city");
        }
    }

    #[test]
    fn centroid_nearest_haversine_survives_the_antimeridian() {
        // A city straddling lon ±180: members at +179.x and −179.x
        // degrees. A raw degree-space mean anchor would land near lon 0
        // (the far side of the planet); the spherical mean must keep the
        // chosen medoid inside the cluster. One far member near lon 0
        // makes the failure observable: the degree-mean anchor would
        // select it.
        let mut members: Vec<Point> = Vec::new();
        for i in 0..10 {
            let lon = if i % 2 == 0 { 179.2 + 0.05 * i as f32 } else { -179.2 - 0.05 * i as f32 };
            members.push(Point::new(10.0 + 0.1 * i as f32, lon));
        }
        members.push(Point::new(10.0, 1.0)); // lone point near lon 0
        let mut ctx = ReduceCtx::default();
        let chosen = choose_medoid(
            backend().as_ref(),
            members.as_slice(),
            members[0],
            UpdateStrategy::CentroidNearest,
            Metric::Haversine,
            1,
            &mut ctx,
        );
        assert!(
            chosen.y().abs() > 170.0,
            "medoid {chosen:?} must stay in the straddling cluster, not jump to lon ~0"
        );
    }

    #[test]
    fn label_pass_evals_are_accounted() {
        use crate::clustering::PruningMode;
        let run = |label_pass: bool, pruning: PruningMode| {
            let mut spec = SpatialSpec::new(2000, 4, 13);
            spec.outlier_frac = 0.0;
            let d = generate(&spec);
            let points = Arc::new(d.points);
            let input = make_input(&points, 5);
            let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 13);
            let mut driver = ParallelKMedoids::new(backend(), IterParams::new(4, 13));
            driver.params.pruning = pruning;
            driver.label_pass = label_pass;
            let out = driver.run(&mut cluster, &input, &points);
            (out, cluster.counters.get("work.dist.evals"))
        };
        // Dense lane: the exact n×k arithmetic is checkable.
        let (without, _) = run(false, PruningMode::Off);
        let (with, session_evals) = run(true, PruningMode::Off);
        // Same fit, plus exactly one n×k labeling scan on top.
        let label_evals = 2000u64 * 4;
        assert_eq!(with.dist_evals, without.dist_evals + label_evals);
        // And the session-level counter agrees with the outcome total.
        assert_eq!(session_evals, with.dist_evals);
        // Pruned lane: identical fit, strictly fewer assignment evals,
        // and the session counter still agrees with the outcome.
        let (pruned, pruned_session) = run(true, PruningMode::On);
        assert_eq!(pruned.medoids, with.medoids);
        assert_eq!(pruned.labels, with.labels);
        assert_eq!(pruned.cost.to_bits(), with.cost.to_bits());
        assert!(pruned.dist_evals < with.dist_evals);
        assert_eq!(pruned_session, pruned.dist_evals);
    }

    #[test]
    fn sim_time_scales_with_cluster_size() {
        let d = generate(&SpatialSpec::new(30_000, 5, 19));
        let points = Arc::new(d.points);
        let dur = |nodes: usize| {
            let input = make_input(&points, 12);
            let mut cluster = Cluster::new(
                ClusterConfig::paper_cluster().cluster_subset(nodes),
                19,
            );
            let mut drv = ParallelKMedoids::new(backend(), IterParams::new(5, 19));
            drv.update = UpdateStrategy::Sampled { candidates: 64, member_sample: 1024 };
            drv.run(&mut cluster, &input, &points).sim_seconds
        };
        let d4 = dur(4);
        let d7 = dur(7);
        assert!(d7 < d4, "7-node {d7} should beat 4-node {d4}");
    }
}
