//! Medoid initialization (paper §3.1): the K-Medoids++ weighted seeding
//! of Arthur & Vassilvitskii, both serial and as MapReduce rounds, plus
//! uniform random init for the "traditional" baseline and the
//! k-means||-style oversampled seeding of Bahmani et al. (*Scalable
//! K-Means++*, VLDB 2012) generalized to arbitrary [`Metric`]s.
//!
//! MR ++ version (one map-only job per round, k−1 rounds):
//! the mapper computes `D(p) = min over current medoids` for its split
//! (through the same assign kernel as the clustering mapper) and emits a
//! single record: the split's total weight `S_i` and one candidate drawn
//! within the split with probability `D(p)/S_i` (weighted reservoir, A-Res
//! with a deterministic per-split stream). The driver then picks a split
//! with probability `S_i/ΣS` and takes its candidate — exactly the global
//! `D(p)/ΣD` draw of §3.1 steps (2)–(3), in one distributed pass.
//!
//! MR || version (one map-only job per oversampling round + one weighting
//! job): each round every point is drawn independently with probability
//! `min(1, ℓ·D(p)/ψ)` where `ψ` is the previous round's total cost, so a
//! round lands ≈ ℓ candidates; after `rounds` rounds the candidate set is
//! weighted by cluster population and reclustered to k medoids on the
//! driver. O(rounds) jobs instead of k−1 — the seeding to use when k is
//! large relative to the cluster's job overhead.
//!
//! Every drawn candidate is deduplicated against the already-chosen
//! medoids ([`dedupe_candidate`]): a duplicated medoid coordinate would
//! create a degenerate empty cluster downstream (ties assign to the
//! lower index). Duplicates are kept only when the dataset has fewer
//! distinct coordinates than k.

use super::Init;
use crate::geo::{Metric, Point};
use crate::mapreduce::{Cluster, Input, JobSpec, MapCtx, Mapper};
use crate::runtime::{assign_points, ComputeBackend};
use crate::sim::TaskWork;
use crate::util::codec::{Dec, Enc};
use crate::util::rng::Rng;
use std::sync::Arc;

/// If `next` coincides with an already-chosen medoid, return the first
/// point (in index order) whose coordinates differ from every chosen
/// medoid; keep `next` only when no such point exists (fewer distinct
/// coordinates than medoids — fully degenerate input). Deterministic.
pub fn dedupe_candidate(points: &[Point], medoids: &[Point], next: Point) -> Point {
    if !medoids.contains(&next) {
        return next;
    }
    for p in points {
        if !medoids.contains(p) {
            return *p;
        }
    }
    next
}

/// Serial ++ seeding (used by the serial baselines and as the oracle for
/// the MR version's distribution tests). Weights are the metric's own
/// dissimilarity (squared distance for `SqEuclidean`, as in §3.1).
pub fn plus_plus_serial(
    points: &[Point],
    k: usize,
    rng: &mut Rng,
    metric: Metric,
) -> (Vec<Point>, u64) {
    assert!((1..=points.len()).contains(&k));
    let mut medoids = Vec::with_capacity(k);
    medoids.push(points[rng.below(points.len())]);
    let mut d2: Vec<f64> = points.iter().map(|p| metric.distance(p, &medoids[0])).collect();
    let mut dist_evals = points.len() as u64;
    while medoids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            // All points coincide with medoids; fall back to uniform.
            points[rng.below(points.len())]
        } else {
            let mut r = rng.f64() * total;
            let mut pick = points.len() - 1;
            for (i, &w) in d2.iter().enumerate() {
                r -= w;
                if r <= 0.0 {
                    pick = i;
                    break;
                }
            }
            points[pick]
        };
        // Both fallbacks above (uniform draw; float-dust landing on the
        // last index) can hand back a point that coincides with a chosen
        // medoid — dedupe so k distinct coordinates yield k distinct
        // medoids.
        let next = dedupe_candidate(points, &medoids, next);
        medoids.push(next);
        for (i, p) in points.iter().enumerate() {
            d2[i] = d2[i].min(metric.distance(p, &next));
        }
        dist_evals += points.len() as u64;
    }
    (medoids, dist_evals)
}

/// Uniform random distinct init ("select k points arbitrarily", §2.3).
pub fn random_init(points: &[Point], k: usize, rng: &mut Rng) -> Vec<Point> {
    rng.sample_indices(points.len(), k).into_iter().map(|i| points[i]).collect()
}

// ---- k-means||-style oversampled seeding (serial) ---------------------------

/// Serial k-means||-style seeding (Bahmani et al.): `rounds` oversampling
/// rounds at factor `l`, then population-weighted reclustering of the
/// candidate set to k medoids. Returns (medoids, distance evaluations).
pub fn oversample_serial(
    points: &[Point],
    k: usize,
    l: usize,
    rounds: usize,
    rng: &mut Rng,
    metric: Metric,
) -> (Vec<Point>, u64) {
    assert!((1..=points.len()).contains(&k));
    assert!(l >= 1);
    let n = points.len();
    let mut evals = 0u64;
    let mut cands = vec![points[rng.below(n)]];
    let mut d: Vec<f64> = points.iter().map(|p| metric.distance(p, &cands[0])).collect();
    // Nearest-candidate labels, maintained for free inside the distance
    // update (strict `<` keeps the first-index-wins tie rule): the
    // weighting pass below then needs no extra distance work.
    let mut labels = vec![0u32; n];
    evals += n as u64;
    for _ in 0..rounds {
        let psi: f64 = d.iter().sum();
        if psi <= 0.0 {
            break;
        }
        // Independent draws: ≈ l candidates land per round.
        let mut drawn: Vec<Point> = Vec::new();
        for (i, p) in points.iter().enumerate() {
            if d[i] > 0.0 && rng.f64() < (l as f64 * d[i] / psi).min(1.0) {
                drawn.push(*p);
            }
        }
        for c in drawn {
            cands.push(c);
            let ci = (cands.len() - 1) as u32;
            for (i, p) in points.iter().enumerate() {
                let dist = metric.distance(p, &c);
                if dist < d[i] {
                    d[i] = dist;
                    labels[i] = ci;
                }
            }
            evals += n as u64;
        }
    }
    // Weight candidates by the population they capture, then recluster.
    let mut weights = vec![0f64; cands.len()];
    for &lab in &labels {
        weights[lab as usize] += 1.0;
    }
    let medoids = recluster_candidates(&cands, &weights, k, points, rng, metric);
    // Recluster work: one |C|-length distance vector for the first pick
    // plus one update pass per remaining medoid — k · |C| evaluations.
    evals += (k as u64) * cands.len() as u64;
    (medoids, evals)
}

/// Recluster a weighted candidate set to k medoids via weighted ++
/// seeding (draw probability ∝ weight · distance-to-chosen), deduping
/// every draw against the chosen set; tops up from `fallback` (the full
/// dataset) when the candidate pool runs out of distinct coordinates.
/// Shared with the coreset pipeline ([`super::coreset`]), whose
/// driver-side recluster is the same weighted draw.
pub(crate) fn recluster_candidates(
    cands: &[Point],
    weights: &[f64],
    k: usize,
    fallback: &[Point],
    rng: &mut Rng,
    metric: Metric,
) -> Vec<Point> {
    assert!(!cands.is_empty());
    assert_eq!(cands.len(), weights.len());
    let mut medoids = Vec::with_capacity(k);
    let total_w: f64 = weights.iter().filter(|w| **w > 0.0).sum();
    let first = if total_w > 0.0 { cands[rng.weighted(weights)] } else { cands[0] };
    medoids.push(first);
    let mut d: Vec<f64> = cands.iter().map(|c| metric.distance(c, &first)).collect();
    while medoids.len() < k {
        let draw: Vec<f64> = d.iter().zip(weights).map(|(dd, w)| dd * w).collect();
        let next = if draw.iter().any(|v| *v > 0.0) {
            cands[rng.weighted(&draw)]
        } else {
            // Candidate pool exhausted (all coincide with chosen
            // medoids): dedupe_candidate scans the dataset for a fresh
            // coordinate.
            medoids[0]
        };
        let next = dedupe_candidate(fallback, &medoids, next);
        medoids.push(next);
        for (i, c) in cands.iter().enumerate() {
            d[i] = d[i].min(metric.distance(c, &next));
        }
    }
    medoids
}

// ---- MapReduce ++ seeding -------------------------------------------------

/// Mapper for one ++ seeding round: emits
/// (split_id, [S_i, cand coords...]).
struct SeedRoundMapper {
    backend: Arc<dyn ComputeBackend>,
    medoids: Vec<Point>,
    metric: Metric,
    /// Deterministic stream: candidate draw depends only on (seed, round,
    /// split start row), not on scheduling.
    seed: u64,
    round: u32,
}

impl Mapper for SeedRoundMapper {
    fn map_points(&self, ctx: &mut MapCtx, row_start: u64, pts: &[Point]) {
        let res = assign_points(self.backend.as_ref(), pts, &self.medoids, self.metric)
            .expect("assign kernel failed in seeding mapper");
        ctx.charge_dist_evals(res.dist_evals);
        // Weighted reservoir (one draw ~ D(p)/S within the split).
        let mut rng = Rng::new(self.seed ^ ((self.round as u64) << 32) ^ row_start);
        let mut total = 0.0f64;
        let mut cand: Option<Point> = None;
        for (p, &d) in pts.iter().zip(&res.mindists) {
            let w = d as f64;
            if w <= 0.0 {
                continue;
            }
            total += w;
            if rng.f64() < w / total {
                cand = Some(*p);
            }
        }
        if let Some(c) = cand {
            let v = Enc::new().f64(total).f32s(c.coords()).done();
            ctx.emit(Enc::new().u64(row_start).done(), v);
        }
        ctx.counters.inc("seed.splits", 1);
    }
}

/// Run K-Medoids++ seeding as k−1 MapReduce rounds over `input`.
/// Returns (medoids, simulated seconds spent seeding).
#[allow(clippy::too_many_arguments)]
pub fn plus_plus_mr(
    cluster: &mut Cluster,
    input: &Input,
    all_points: &Arc<Vec<Point>>,
    backend: &Arc<dyn ComputeBackend>,
    k: usize,
    seed: u64,
    metric: Metric,
) -> anyhow::Result<(Vec<Point>, f64)> {
    assert!((1..=all_points.len()).contains(&k));
    let mut rng = Rng::new(seed ^ 0x5EED);
    let mut medoids = vec![all_points[rng.below(all_points.len())]];
    let t0 = cluster.now().0;
    for round in 1..k {
        let job = JobSpec::new(
            &format!("kmedoids++-seed-r{round}"),
            input.clone(),
            Arc::new(SeedRoundMapper {
                backend: backend.clone(),
                medoids: medoids.clone(),
                metric,
                seed,
                round: round as u32,
            }),
        );
        let result = cluster.try_run_job(&job)?;
        // Driver-side global draw: pick a split ∝ S_i, take its candidate.
        let mut weights = Vec::with_capacity(result.output.len());
        let mut cands = Vec::with_capacity(result.output.len());
        for (_, v) in &result.output {
            let mut d = Dec::new(v);
            weights.push(d.f64());
            cands.push(Point::from_slice(&d.rest_f32s()));
        }
        let next = if weights.is_empty() || weights.iter().sum::<f64>() <= 0.0 {
            all_points[rng.below(all_points.len())]
        } else {
            cands[rng.weighted(&weights)]
        };
        // The zero-weight fallback draws uniformly and can coincide with
        // a chosen medoid — dedupe (degenerate empty cluster otherwise).
        let next = dedupe_candidate(all_points, &medoids, next);
        medoids.push(next);
    }
    Ok((medoids, cluster.now().0 - t0))
}

// ---- MapReduce || seeding ---------------------------------------------------

/// Min-distance of every point to a candidate set that may exceed the
/// backend's padded-k capacity: chunked assign calls, elementwise
/// first-wins merge (labels are global candidate indices). The third
/// tuple element is the number of distance evaluations performed.
pub(crate) fn min_dists_chunked(
    be: &dyn ComputeBackend,
    pts: &[Point],
    cands: &[Point],
    metric: Metric,
) -> (Vec<u32>, Vec<f32>, u64) {
    assert!(!cands.is_empty());
    let chunk = be.kpad().max(1);
    let mut labels = vec![0u32; pts.len()];
    let mut best = vec![f32::INFINITY; pts.len()];
    let mut off = 0u32;
    let mut evals = 0u64;
    for ch in cands.chunks(chunk) {
        let res = assign_points(be, pts, ch, metric).expect("assign kernel failed");
        for i in 0..pts.len() {
            if res.mindists[i] < best[i] {
                best[i] = res.mindists[i];
                labels[i] = off + res.labels[i];
            }
        }
        evals += res.dist_evals;
        off += ch.len() as u32;
    }
    (labels, best, evals)
}

/// Mapper for one || oversampling round: emits
/// (split_id, [S_i, count, cand coords...]). With `sample == false` it
/// only reports the split cost (the ψ bootstrap pass).
struct OverSampleRoundMapper {
    backend: Arc<dyn ComputeBackend>,
    cands: Arc<Vec<Point>>,
    metric: Metric,
    seed: u64,
    round: u32,
    l: usize,
    /// Previous round's total cost ψ (the sampling denominator).
    psi: f64,
    sample: bool,
}

impl Mapper for OverSampleRoundMapper {
    fn map_points(&self, ctx: &mut MapCtx, row_start: u64, pts: &[Point]) {
        let (_, mindists, evals) =
            min_dists_chunked(self.backend.as_ref(), pts, &self.cands, self.metric);
        ctx.charge_dist_evals(evals);
        let total: f64 = mindists.iter().map(|&d| d as f64).sum();
        let mut drawn: Vec<Point> = Vec::new();
        if self.sample && self.psi > 0.0 {
            let mut rng =
                Rng::new(self.seed ^ 0x05A3 ^ ((self.round as u64) << 32) ^ row_start);
            for (p, &d) in pts.iter().zip(&mindists) {
                let w = d as f64;
                if w > 0.0 && rng.f64() < (self.l as f64 * w / self.psi).min(1.0) {
                    drawn.push(*p);
                }
            }
        }
        let mut enc = Enc::new().f64(total).u32(drawn.len() as u32);
        for p in &drawn {
            enc = enc.f32s(p.coords());
        }
        ctx.emit(Enc::new().u64(row_start).done(), enc.done());
        ctx.counters.inc("seed.splits", 1);
    }
}

/// Mapper for the || weighting pass: assigns the split's points to their
/// nearest candidate and emits the per-candidate population counts.
struct CandWeightMapper {
    backend: Arc<dyn ComputeBackend>,
    cands: Arc<Vec<Point>>,
    metric: Metric,
}

impl Mapper for CandWeightMapper {
    fn map_points(&self, ctx: &mut MapCtx, row_start: u64, pts: &[Point]) {
        let (labels, _, evals) =
            min_dists_chunked(self.backend.as_ref(), pts, &self.cands, self.metric);
        ctx.charge_dist_evals(evals);
        let mut counts = vec![0u64; self.cands.len()];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        let mut enc = Enc::with_capacity(8 * counts.len());
        for c in counts {
            enc = enc.u64(c);
        }
        ctx.emit(Enc::new().u64(row_start).done(), enc.done());
    }
}

/// Run k-means||-style oversampled seeding over `input`: one ψ bootstrap
/// job, `rounds` sampling jobs, one weighting job, then a driver-side
/// weighted recluster to k medoids. Returns (medoids, simulated seconds).
#[allow(clippy::too_many_arguments)]
pub fn oversample_mr(
    cluster: &mut Cluster,
    input: &Input,
    all_points: &Arc<Vec<Point>>,
    backend: &Arc<dyn ComputeBackend>,
    k: usize,
    l: usize,
    rounds: usize,
    seed: u64,
    metric: Metric,
) -> anyhow::Result<(Vec<Point>, f64)> {
    assert!((1..=all_points.len()).contains(&k));
    assert!(l >= 1);
    let dims = all_points[0].dims();
    let mut rng = Rng::new(seed ^ 0x0B5A);
    let mut cands = vec![all_points[rng.below(all_points.len())]];
    let t0 = cluster.now().0;
    let mut psi = 0.0f64;
    // Round 0 bootstraps ψ; rounds 1..=rounds sample with the previous
    // round's ψ as the denominator (Bahmani et al.'s per-round cost).
    for round in 0..=rounds {
        let sample = round > 0;
        let job = JobSpec::new(
            &format!("kmedoids||-seed-r{round}"),
            input.clone(),
            Arc::new(OverSampleRoundMapper {
                backend: backend.clone(),
                cands: Arc::new(cands.clone()),
                metric,
                seed,
                round: round as u32,
                l,
                psi,
                sample,
            }),
        );
        let result = cluster.try_run_job(&job)?;
        let mut new_psi = 0.0f64;
        for (_, v) in &result.output {
            let mut d = Dec::new(v);
            new_psi += d.f64();
            let cnt = d.u32() as usize;
            let drawn = d.rest_points(dims);
            assert_eq!(drawn.len(), cnt, "|| seeding wire mismatch");
            cands.extend(drawn);
        }
        psi = new_psi;
        if psi <= 0.0 {
            break;
        }
    }
    // Weighting pass: candidate population counts across all splits.
    let wjob = JobSpec::new(
        "kmedoids||-seed-weights",
        input.clone(),
        Arc::new(CandWeightMapper {
            backend: backend.clone(),
            cands: Arc::new(cands.clone()),
            metric,
        }),
    );
    let result = cluster.try_run_job(&wjob)?;
    let mut weights = vec![0f64; cands.len()];
    for (_, v) in &result.output {
        let mut d = Dec::new(v);
        for w in weights.iter_mut() {
            *w += d.u64() as f64;
        }
    }
    let medoids = recluster_candidates(&cands, &weights, k, all_points, &mut rng, metric);
    // Driver-side recluster work (k · |C| distance evaluations on the
    // master) charged to the simulated clock like every other compute —
    // same accounting rule the serial twin applies to its eval count.
    let work = TaskWork { dist_evals: (k as u64) * cands.len() as u64, ..Default::default() };
    let secs = cluster.cost.cpu_seconds(&cluster.config.nodes[cluster.config.master], &work);
    cluster.advance_secs(secs);
    Ok((medoids, cluster.now().0 - t0))
}

/// Dispatch on [`Init`] for the MR drivers.
#[allow(clippy::too_many_arguments)]
pub fn init_mr(
    init: Init,
    cluster: &mut Cluster,
    input: &Input,
    all_points: &Arc<Vec<Point>>,
    backend: &Arc<dyn ComputeBackend>,
    k: usize,
    seed: u64,
    metric: Metric,
) -> anyhow::Result<(Vec<Point>, f64)> {
    match init {
        Init::PlusPlus => plus_plus_mr(cluster, input, all_points, backend, k, seed, metric),
        Init::OverSample { l, rounds } => {
            oversample_mr(cluster, input, all_points, backend, k, l, rounds, seed, metric)
        }
        Init::Random => {
            // The paper's traditional init is a driver-side draw (no MR
            // pass needed — medoids file written directly).
            let mut rng = Rng::new(seed ^ 0x7A2D);
            Ok((random_init(all_points, k, &mut rng), 0.0))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::metrics::{total_cost, total_cost_metric};
    use crate::config::ClusterConfig;
    use crate::geo::datasets::{generate, SpatialSpec};
    use crate::mapreduce::{SplitMeta, SplitOrigin};
    use crate::runtime::NativeBackend;
    use crate::util::proptest::for_all;

    fn backend() -> Arc<dyn ComputeBackend> {
        Arc::new(NativeBackend::new(256, 16))
    }

    fn make_input(points: &Arc<Vec<Point>>, n_splits: usize) -> Input {
        let total = points.len() as u64;
        let splits = (0..n_splits as u64)
            .map(|i| SplitMeta {
                row_start: total * i / n_splits as u64,
                row_end: total * (i + 1) / n_splits as u64,
                bytes: 1 << 20,
                preferred: vec![],
                origin: SplitOrigin::Adhoc,
            })
            .collect();
        Input::Points { points: points.clone(), splits }
    }

    #[test]
    fn serial_seeding_selects_k_distinct_spread_points() {
        let d = generate(&SpatialSpec::new(5000, 6, 11));
        let mut rng = Rng::new(1);
        let (med, evals) = plus_plus_serial(&d.points, 6, &mut rng, Metric::SqEuclidean);
        assert_eq!(med.len(), 6);
        assert_eq!(evals, 5 * 5000 + 5000);
        for i in 0..6 {
            for j in 0..i {
                assert!(med[i].dist2(&med[j]) > 0.0, "medoids must differ");
            }
        }
    }

    #[test]
    fn plus_plus_beats_random_on_expected_cost() {
        // §3.1's whole point: ++ seeding gives lower initial cost.
        let d = generate(&SpatialSpec::new(8000, 8, 21));
        let trials = 10;
        let (mut pp, mut rand) = (0.0, 0.0);
        for t in 0..trials {
            let mut rng = Rng::new(100 + t);
            let seeds = plus_plus_serial(&d.points, 8, &mut rng, Metric::SqEuclidean).0;
            pp += total_cost(&d.points, &seeds);
            let mut rng = Rng::new(200 + t);
            rand += total_cost(&d.points, &random_init(&d.points, 8, &mut rng));
        }
        assert!(pp < rand * 0.8, "++ {pp} should beat random {rand} clearly");
    }

    #[test]
    fn mr_seeding_matches_serial_quality() {
        let d = generate(&SpatialSpec::new(6000, 5, 31));
        let points = Arc::new(d.points);
        let input = make_input(&points, 6);
        let be = backend();
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 5);
        let (med, sim_s) =
            plus_plus_mr(&mut cluster, &input, &points, &be, 5, 77, Metric::SqEuclidean).unwrap();
        assert_eq!(med.len(), 5);
        assert!(sim_s > 0.0, "seeding consumed simulated time");
        // Quality: cost within 2x of a serial ++ run (same structure).
        let mut rng = Rng::new(77);
        let serial = plus_plus_serial(&points, 5, &mut rng, Metric::SqEuclidean).0;
        let c_mr = total_cost(&points, &med);
        let c_serial = total_cost(&points, &serial);
        assert!(c_mr < c_serial * 2.5, "mr {c_mr} vs serial {c_serial}");
    }

    #[test]
    fn mr_seeding_deterministic() {
        let d = generate(&SpatialSpec::new(3000, 4, 41));
        let points = Arc::new(d.points);
        let be = backend();
        let run = || {
            let input = make_input(&points, 5);
            let mut cluster = Cluster::new(ClusterConfig::test_cluster(3), 5);
            plus_plus_mr(&mut cluster, &input, &points, &be, 4, 99, Metric::SqEuclidean)
                .unwrap()
                .0
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn random_init_distinct() {
        for_all(20, 0x1717, |rng| {
            let d = generate(&SpatialSpec::new(200 + rng.below(200), 3, rng.next_u64()));
            let k = 1 + rng.below(8);
            let med = random_init(&d.points, k, rng);
            assert_eq!(med.len(), k);
        });
    }

    #[test]
    fn degenerate_all_identical_points() {
        let points = vec![Point::new(1.0, 1.0); 50];
        let mut rng = Rng::new(3);
        let (med, _) = plus_plus_serial(&points, 3, &mut rng, Metric::SqEuclidean);
        assert_eq!(med.len(), 3); // falls back to uniform draws
    }

    #[test]
    fn dedupe_candidate_regression() {
        // The bug: the uniform/float-dust fallbacks in ++ seeding could
        // hand back a point coinciding with a chosen medoid, producing a
        // degenerate empty cluster downstream. The dedupe must swap in
        // the first coordinate-distinct point — and only give up when
        // none exists.
        let a = Point::new(1.0, 1.0);
        let b = Point::new(2.0, 2.0);
        let c = Point::new(3.0, 3.0);
        let points = vec![a, a, a, b, c];
        // A drawn duplicate is replaced by the first non-medoid point.
        assert_eq!(dedupe_candidate(&points, &[a], a), b);
        assert_eq!(dedupe_candidate(&points, &[a, b], a), c);
        assert_eq!(dedupe_candidate(&points, &[a, b], b), c);
        // Non-duplicates pass through untouched.
        assert_eq!(dedupe_candidate(&points, &[a], c), c);
        // Fully degenerate: every point is a medoid — duplicate kept.
        assert_eq!(dedupe_candidate(&points, &[a, b, c], a), a);
    }

    #[test]
    fn seeding_never_duplicates_medoids_on_duplicate_heavy_data() {
        // End-to-end regression guard for the dedupe: datasets whose
        // points are heavily duplicated must still yield k distinct
        // medoids (the data always has ≥ k distinct coordinates here).
        for_all(30, 0xDED0, |rng| {
            let k = 2 + rng.below(4);
            let distinct = k + rng.below(4);
            let mut points = Vec::new();
            for i in 0..distinct {
                let p = Point::new(i as f32 * 10.0, -(i as f32));
                for _ in 0..1 + rng.below(8) {
                    points.push(p);
                }
            }
            let (med, _) = plus_plus_serial(&points, k, rng, Metric::SqEuclidean);
            for i in 0..med.len() {
                for j in 0..i {
                    assert_ne!(med[i], med[j], "duplicate medoid at k={k}");
                }
            }
        });
    }

    #[test]
    fn plus_plus_serial_works_under_every_metric() {
        let d = generate(&SpatialSpec::new(3000, 4, 51));
        for metric in [Metric::SqEuclidean, Metric::Manhattan] {
            let mut rng = Rng::new(5);
            let (med, _) = plus_plus_serial(&d.points, 4, &mut rng, metric);
            assert_eq!(med.len(), 4);
            // Seeded cost beats random init on average under the same metric.
            let mut rng = Rng::new(6);
            let rand_cost =
                total_cost_metric(&d.points, &random_init(&d.points, 4, &mut rng), metric);
            let pp_cost = total_cost_metric(&d.points, &med, metric);
            assert!(pp_cost < rand_cost * 1.5, "{metric:?}: {pp_cost} vs {rand_cost}");
        }
        let g = generate(&SpatialSpec::latlon(2000, 4, 53));
        let mut rng = Rng::new(7);
        let (med, _) = plus_plus_serial(&g.points, 4, &mut rng, Metric::Haversine);
        assert_eq!(med.len(), 4);
    }

    #[test]
    fn oversample_serial_quality_and_shape() {
        let mut spec = SpatialSpec::new(6000, 5, 61);
        spec.outlier_frac = 0.0;
        let d = generate(&spec);
        let mut rng = Rng::new(9);
        let (med, evals) = oversample_serial(&d.points, 5, 10, 5, &mut rng, Metric::SqEuclidean);
        assert_eq!(med.len(), 5);
        assert!(evals > 0);
        for i in 0..5 {
            for j in 0..i {
                assert_ne!(med[i], med[j], "|| medoids must be distinct");
            }
        }
        // Costs in the same ballpark as serial ++ (both are seedings of
        // the same objective; || averages a touch better per Bahmani).
        let mut rng = Rng::new(9);
        let pp = plus_plus_serial(&d.points, 5, &mut rng, Metric::SqEuclidean).0;
        let c_os = total_cost(&d.points, &med);
        let c_pp = total_cost(&d.points, &pp);
        assert!(c_os < c_pp * 2.0, "|| {c_os} vs ++ {c_pp}");
    }

    #[test]
    fn min_dists_chunked_matches_unchunked() {
        // Candidate sets larger than kpad must merge chunk argmins into
        // the same labels/distances a single scan would produce.
        let d = generate(&SpatialSpec::new(800, 4, 71));
        let be_small = NativeBackend::new(64, 4); // kpad 4 forces chunking
        let cands: Vec<Point> = d.points[..11].to_vec();
        let (labels, dists, evals) =
            min_dists_chunked(&be_small, &d.points, &cands, Metric::SqEuclidean);
        assert_eq!(evals, (d.points.len() * cands.len()) as u64);
        for (i, p) in d.points.iter().enumerate() {
            let (bj, bd) = cands
                .iter()
                .enumerate()
                .map(|(j, c)| (j, p.dist2(c)))
                .fold(
                    (0usize, f64::INFINITY),
                    |acc, (j, dd)| if dd < acc.1 { (j, dd) } else { acc },
                );
            assert!(
                (dists[i] as f64 - bd).abs() < 1e-2 * bd.max(1.0),
                "point {i}: {} vs {bd}",
                dists[i]
            );
            // Labels may differ only on f32 near-ties; check via distance.
            let got_d = p.dist2(&cands[labels[i] as usize]);
            assert!((got_d - bd).abs() < 1e-2 * bd.max(1.0), "label {} vs {bj}", labels[i]);
        }
    }

    #[test]
    fn oversample_mr_deterministic_and_reasonable() {
        let mut spec = SpatialSpec::new(4000, 4, 81);
        spec.outlier_frac = 0.0;
        let d = generate(&spec);
        let points = Arc::new(d.points);
        let be = backend();
        let run = || {
            let input = make_input(&points, 5);
            let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 7);
            oversample_mr(&mut cluster, &input, &points, &be, 4, 8, 4, 123, Metric::SqEuclidean)
                .unwrap()
        };
        let (med, sim_s) = run();
        assert_eq!(med.len(), 4);
        assert!(sim_s > 0.0, "|| seeding consumed simulated time");
        assert_eq!(med, run().0, "deterministic in the seed");
        // Quality: within 2.5x of serial ++ cost.
        let mut rng = Rng::new(123);
        let pp = plus_plus_serial(&points, 4, &mut rng, Metric::SqEuclidean).0;
        let c_mr = total_cost(&points, &med);
        let c_pp = total_cost(&points, &pp);
        assert!(c_mr < c_pp * 2.5, "|| mr {c_mr} vs ++ serial {c_pp}");
    }

    #[test]
    fn oversample_mr_uses_fewer_jobs_than_plus_plus_for_large_k() {
        let d = generate(&SpatialSpec::new(3000, 9, 91));
        let points = Arc::new(d.points);
        let be = backend();
        let k = 12;
        let jobs_pp = {
            let input = make_input(&points, 4);
            let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 7);
            plus_plus_mr(&mut cluster, &input, &points, &be, k, 3, Metric::SqEuclidean).unwrap();
            cluster.jobs_run
        };
        let jobs_os = {
            let input = make_input(&points, 4);
            let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 7);
            oversample_mr(&mut cluster, &input, &points, &be, k, 2 * k, 4, 3, Metric::SqEuclidean)
                .unwrap();
            cluster.jobs_run
        };
        assert_eq!(jobs_pp, k - 1);
        assert!(jobs_os < jobs_pp, "|| ran {jobs_os} jobs vs ++ {jobs_pp}");
    }
}
