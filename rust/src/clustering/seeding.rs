//! Medoid initialization (paper §3.1): the K-Medoids++ weighted seeding
//! of Arthur & Vassilvitskii, both serial and as MapReduce rounds, plus
//! uniform random init for the "traditional" baseline.
//!
//! MR version (one map-only job per round, k−1 rounds):
//! the mapper computes `D(p) = min over current medoids` for its split
//! (through the same assign kernel as the clustering mapper) and emits a
//! single record: the split's total weight `S_i` and one candidate drawn
//! within the split with probability `D(p)/S_i` (weighted reservoir, A-Res
//! with a deterministic per-split stream). The driver then picks a split
//! with probability `S_i/ΣS` and takes its candidate — exactly the global
//! `D(p)/ΣD` draw of §3.1 steps (2)–(3), in one distributed pass.

use super::Init;
use crate::geo::Point;
use crate::mapreduce::{Cluster, Input, JobSpec, MapCtx, Mapper};
use crate::runtime::{assign_points, ops::assign_dist_evals, ComputeBackend};
use crate::util::codec::{Dec, Enc};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Serial ++ seeding (used by the serial baselines and as the oracle for
/// the MR version's distribution tests).
pub fn plus_plus_serial(points: &[Point], k: usize, rng: &mut Rng) -> (Vec<Point>, u64) {
    assert!(k >= 1 && k <= points.len());
    let mut medoids = Vec::with_capacity(k);
    medoids.push(points[rng.below(points.len())]);
    let mut d2: Vec<f64> = points.iter().map(|p| p.dist2(&medoids[0])).collect();
    let mut dist_evals = points.len() as u64;
    while medoids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            // All points coincide with medoids; fall back to uniform.
            points[rng.below(points.len())]
        } else {
            let mut r = rng.f64() * total;
            let mut pick = points.len() - 1;
            for (i, &w) in d2.iter().enumerate() {
                r -= w;
                if r <= 0.0 {
                    pick = i;
                    break;
                }
            }
            points[pick]
        };
        medoids.push(next);
        for (i, p) in points.iter().enumerate() {
            d2[i] = d2[i].min(p.dist2(&next));
        }
        dist_evals += points.len() as u64;
    }
    (medoids, dist_evals)
}

/// Uniform random distinct init ("select k points arbitrarily", §2.3).
pub fn random_init(points: &[Point], k: usize, rng: &mut Rng) -> Vec<Point> {
    rng.sample_indices(points.len(), k).into_iter().map(|i| points[i]).collect()
}

// ---- MapReduce ++ seeding -------------------------------------------------

/// Mapper for one seeding round: emits (split_id, [S_i, cand_x, cand_y]).
struct SeedRoundMapper {
    backend: Arc<dyn ComputeBackend>,
    medoids: Vec<Point>,
    /// Deterministic stream: candidate draw depends only on (seed, round,
    /// split start row), not on scheduling.
    seed: u64,
    round: u32,
}

impl Mapper for SeedRoundMapper {
    fn map_points(&self, ctx: &mut MapCtx, row_start: u64, pts: &[Point]) {
        let res = assign_points(self.backend.as_ref(), pts, &self.medoids)
            .expect("assign kernel failed in seeding mapper");
        ctx.charge_dist_evals(assign_dist_evals(pts.len(), self.medoids.len()));
        // Weighted reservoir (one draw ~ D(p)/S within the split).
        let mut rng = Rng::new(self.seed ^ ((self.round as u64) << 32) ^ row_start);
        let mut total = 0.0f64;
        let mut cand: Option<Point> = None;
        for (p, &d) in pts.iter().zip(&res.mindists) {
            let w = d as f64;
            if w <= 0.0 {
                continue;
            }
            total += w;
            if rng.f64() < w / total {
                cand = Some(*p);
            }
        }
        if let Some(c) = cand {
            let v = Enc::new().f64(total).f32(c.x).f32(c.y).done();
            ctx.emit(Enc::new().u64(row_start).done(), v);
        }
        ctx.counters.inc("seed.splits", 1);
    }
}

/// Run K-Medoids++ seeding as k−1 MapReduce rounds over `input`.
/// Returns (medoids, simulated seconds spent seeding).
pub fn plus_plus_mr(
    cluster: &mut Cluster,
    input: &Input,
    all_points: &Arc<Vec<Point>>,
    backend: &Arc<dyn ComputeBackend>,
    k: usize,
    seed: u64,
) -> anyhow::Result<(Vec<Point>, f64)> {
    assert!(k >= 1 && (k as usize) <= all_points.len());
    let mut rng = Rng::new(seed ^ 0x5EED);
    let mut medoids = vec![all_points[rng.below(all_points.len())]];
    let t0 = cluster.now().0;
    for round in 1..k {
        let job = JobSpec::new(
            &format!("kmedoids++-seed-r{round}"),
            input.clone(),
            Arc::new(SeedRoundMapper {
                backend: backend.clone(),
                medoids: medoids.clone(),
                seed,
                round: round as u32,
            }),
        );
        let result = cluster.try_run_job(&job)?;
        // Driver-side global draw: pick a split ∝ S_i, take its candidate.
        let mut weights = Vec::with_capacity(result.output.len());
        let mut cands = Vec::with_capacity(result.output.len());
        for (_, v) in &result.output {
            let mut d = Dec::new(v);
            weights.push(d.f64());
            cands.push(Point::new(d.f32(), d.f32()));
        }
        let next = if weights.is_empty() || weights.iter().sum::<f64>() <= 0.0 {
            all_points[rng.below(all_points.len())]
        } else {
            cands[rng.weighted(&weights)]
        };
        medoids.push(next);
    }
    Ok((medoids, cluster.now().0 - t0))
}

/// Dispatch on [`Init`] for the MR drivers.
pub fn init_mr(
    init: Init,
    cluster: &mut Cluster,
    input: &Input,
    all_points: &Arc<Vec<Point>>,
    backend: &Arc<dyn ComputeBackend>,
    k: usize,
    seed: u64,
) -> anyhow::Result<(Vec<Point>, f64)> {
    match init {
        Init::PlusPlus => plus_plus_mr(cluster, input, all_points, backend, k, seed),
        Init::Random => {
            // The paper's traditional init is a driver-side draw (no MR
            // pass needed — medoids file written directly).
            let mut rng = Rng::new(seed ^ 0x7A2D);
            Ok((random_init(all_points, k, &mut rng), 0.0))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::metrics::total_cost;
    use crate::config::ClusterConfig;
    use crate::geo::datasets::{generate, SpatialSpec};
    use crate::mapreduce::SplitMeta;
    use crate::runtime::NativeBackend;
    use crate::util::proptest::for_all;

    fn backend() -> Arc<dyn ComputeBackend> {
        Arc::new(NativeBackend::new(256, 16))
    }

    fn make_input(points: &Arc<Vec<Point>>, n_splits: usize) -> Input {
        let total = points.len() as u64;
        let splits = (0..n_splits as u64)
            .map(|i| SplitMeta {
                row_start: total * i / n_splits as u64,
                row_end: total * (i + 1) / n_splits as u64,
                bytes: 1 << 20,
                preferred: vec![],
            })
            .collect();
        Input::Points { points: points.clone(), splits }
    }

    #[test]
    fn serial_seeding_selects_k_distinct_spread_points() {
        let d = generate(&SpatialSpec::new(5000, 6, 11));
        let mut rng = Rng::new(1);
        let (med, evals) = plus_plus_serial(&d.points, 6, &mut rng);
        assert_eq!(med.len(), 6);
        assert_eq!(evals, 5 * 5000 + 5000);
        for i in 0..6 {
            for j in 0..i {
                assert!(med[i].dist2(&med[j]) > 0.0, "medoids must differ");
            }
        }
    }

    #[test]
    fn plus_plus_beats_random_on_expected_cost() {
        // §3.1's whole point: ++ seeding gives lower initial cost.
        let d = generate(&SpatialSpec::new(8000, 8, 21));
        let trials = 10;
        let (mut pp, mut rand) = (0.0, 0.0);
        for t in 0..trials {
            let mut rng = Rng::new(100 + t);
            pp += total_cost(&d.points, &plus_plus_serial(&d.points, 8, &mut rng).0);
            let mut rng = Rng::new(200 + t);
            rand += total_cost(&d.points, &random_init(&d.points, 8, &mut rng));
        }
        assert!(pp < rand * 0.8, "++ {pp} should beat random {rand} clearly");
    }

    #[test]
    fn mr_seeding_matches_serial_quality() {
        let d = generate(&SpatialSpec::new(6000, 5, 31));
        let points = Arc::new(d.points);
        let input = make_input(&points, 6);
        let be = backend();
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 5);
        let (med, sim_s) = plus_plus_mr(&mut cluster, &input, &points, &be, 5, 77).unwrap();
        assert_eq!(med.len(), 5);
        assert!(sim_s > 0.0, "seeding consumed simulated time");
        // Quality: cost within 2x of a serial ++ run (same structure).
        let mut rng = Rng::new(77);
        let serial = plus_plus_serial(&points, 5, &mut rng).0;
        let c_mr = total_cost(&points, &med);
        let c_serial = total_cost(&points, &serial);
        assert!(c_mr < c_serial * 2.5, "mr {c_mr} vs serial {c_serial}");
    }

    #[test]
    fn mr_seeding_deterministic() {
        let d = generate(&SpatialSpec::new(3000, 4, 41));
        let points = Arc::new(d.points);
        let be = backend();
        let run = || {
            let input = make_input(&points, 5);
            let mut cluster = Cluster::new(ClusterConfig::test_cluster(3), 5);
            plus_plus_mr(&mut cluster, &input, &points, &be, 4, 99).unwrap().0
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn random_init_distinct() {
        for_all(20, 0x1717, |rng| {
            let d = generate(&SpatialSpec::new(200 + rng.below(200), 3, rng.next_u64()));
            let k = 1 + rng.below(8);
            let med = random_init(&d.points, k, rng);
            assert_eq!(med.len(), k);
        });
    }

    #[test]
    fn degenerate_all_identical_points() {
        let points = vec![Point::new(1.0, 1.0); 50];
        let mut rng = Rng::new(3);
        let (med, _) = plus_plus_serial(&points, 3, &mut rng);
        assert_eq!(med.len(), 3); // falls back to uniform draws
    }
}
