//! Parallel k-means on MapReduce (Zhao, Ma & He — the paper's Ref. 6).
//!
//! Included as the robustness ablation the paper's introduction motivates:
//! k-means is the faster algorithm but its means chase outliers, which is
//! why the paper builds K-Medoids. The MR structure mirrors the K-Medoids
//! driver: map = assign + partial (sum, count) per cluster (combiner-style
//! pre-aggregation in the mapper), reduce = new mean.
//!
//! The mean-update is only valid when the arithmetic mean minimizes the
//! within-cluster cost — i.e. under squared Euclidean distance
//! ([`Metric::mean_is_minimizer`]). For every other metric the driver
//! falls back to a medoid update (centroid-nearest, through the
//! K-Medoids MR engine), still reported under the `kmeans-mr` event name:
//! the "centers" are then data points, which is exactly the correct
//! generalization (there is no closed-form mean under L1/haversine).
//!
//! Like the K-Medoids driver, this one submits jobs through
//! [`Cluster::try_run_job`] and therefore runs unchanged on either
//! execution lane ([`crate::mapreduce::Lane`]); outputs are
//! byte-identical across lanes, only simulated time differs.

use super::observe::{IterationEvent, ObserverHub};
use super::parallel::ParallelKMedoids;
use super::seeding::{oversample_serial, plus_plus_serial, random_init};
use super::{ClusterOutcome, Init, IterParams, UpdateStrategy};
use crate::geo::{Metric, Point};
use crate::mapreduce::{Cluster, Input, JobSpec, MapCtx, Mapper, ReduceCtx, Reducer, Val};
use crate::runtime::{assign_points, ComputeBackend, PrunedAssigner};
use crate::util::codec::{decode_cluster_key, decode_point_coords, encode_cluster_key, Dec, Enc};
use crate::util::rng::Rng;
use std::sync::Arc;

struct KMeansMapper {
    backend: Arc<dyn ComputeBackend>,
    centers: Vec<Point>,
    pruned: Option<Arc<PrunedAssigner>>,
}

impl Mapper for KMeansMapper {
    fn map_points(&self, ctx: &mut MapCtx, row_start: u64, pts: &[Point]) {
        let res = match &self.pruned {
            Some(pa) => pa.assign_split(self.backend.as_ref(), row_start, pts, &self.centers),
            None => assign_points(self.backend.as_ref(), pts, &self.centers, Metric::SqEuclidean),
        }
        .expect("assign kernel failed");
        ctx.charge_dist_evals(res.dist_evals);
        ctx.counters.inc("work.dist.evals", res.dist_evals);
        let k = self.centers.len();
        let dims = self.centers[0].dims();
        // Per-cluster per-dimension partial sums + counts (combiner-style
        // pre-aggregation; wire format: dims f64 sums then the count).
        let mut sums = vec![0f64; k * dims];
        let mut cnt = vec![0u64; k];
        for (p, &l) in pts.iter().zip(&res.labels) {
            let row = &mut sums[l as usize * dims..(l as usize + 1) * dims];
            for (s, c) in row.iter_mut().zip(p.coords()) {
                *s += *c as f64;
            }
            cnt[l as usize] += 1;
        }
        for j in 0..k {
            if cnt[j] > 0 {
                let mut enc = Enc::with_capacity(8 * (dims + 1));
                for s in &sums[j * dims..(j + 1) * dims] {
                    enc = enc.f64(*s);
                }
                ctx.emit(encode_cluster_key(j as u32), enc.u64(cnt[j]).done());
            }
        }
        let split_cost: f64 = res.cluster_cost.iter().sum();
        ctx.counters.inc("assign.cost.units", split_cost.round() as u64);
    }
}

struct MeanReducer {
    dims: usize,
}

impl Reducer for MeanReducer {
    fn reduce(&self, ctx: &mut ReduceCtx, key: &[u8], values: &[Val]) {
        let mut sums = vec![0f64; self.dims];
        let mut n = 0u64;
        for v in values {
            let mut d = Dec::new(v);
            for s in sums.iter_mut() {
                *s += d.f64();
            }
            n += d.u64();
        }
        if n == 0 {
            return;
        }
        if ctx.is_combine {
            // Combiner must preserve the partial-sum wire format.
            let mut enc = Enc::with_capacity(8 * (self.dims + 1));
            for s in &sums {
                enc = enc.f64(*s);
            }
            ctx.emit(key.to_vec(), enc.u64(n).done());
        } else {
            let mean: Vec<f32> = sums.iter().map(|s| (*s / n as f64) as f32).collect();
            ctx.emit(key.to_vec(), Enc::new().f32s(&mean).done());
        }
    }
}

pub struct ParallelKMeans {
    pub backend: Arc<dyn ComputeBackend>,
    pub init: Init,
    pub params: IterParams,
    /// Dissimilarity of the fit. Mean updates only under `SqEuclidean`;
    /// anything else falls back to the medoid update (see module docs).
    pub metric: Metric,
}

impl ParallelKMeans {
    /// Run to convergence; panics on job-level failure. Use
    /// [`ParallelKMeans::run_observed`] for the fallible, streaming path.
    pub fn run(
        &self,
        cluster: &mut Cluster,
        input: &Input,
        points: &Arc<Vec<Point>>,
    ) -> ClusterOutcome {
        self.run_observed(cluster, input, points, &mut ObserverHub::default())
            .expect("parallel k-means job failed")
    }

    /// Run to convergence, emitting one [`IterationEvent`] per Lloyd
    /// iteration. Last event matches the final [`ClusterOutcome`].
    pub fn run_observed(
        &self,
        cluster: &mut Cluster,
        input: &Input,
        points: &Arc<Vec<Point>>,
        hub: &mut ObserverHub,
    ) -> anyhow::Result<ClusterOutcome> {
        if !self.metric.mean_is_minimizer() {
            // Non-Euclidean metric: the arithmetic mean is not the
            // within-cluster cost minimizer, so run the medoid-update
            // engine (centroid-nearest: one O(m) pass per cluster, the
            // closest analogue of a mean step) under the k-means label.
            let drv = ParallelKMedoids {
                backend: self.backend.clone(),
                init: self.init,
                update: UpdateStrategy::CentroidNearest,
                params: self.params.clone(),
                metric: self.metric,
                label_pass: false,
                event_label: Some("kmeans-mr"),
                resume: None,
            };
            return drv.run_observed(cluster, input, points, hub);
        }
        let k = self.params.k;
        let t0 = cluster.now().0;
        let mut rng = Rng::new(self.params.seed);
        let mut centers = match self.init {
            Init::PlusPlus => plus_plus_serial(points, k, &mut rng, self.metric).0,
            Init::Random => random_init(points, k, &mut rng),
            Init::OverSample { l, rounds } => {
                oversample_serial(points, k, l, rounds, &mut rng, self.metric).0
            }
        };
        let dims = centers[0].dims();
        // Pruned assignment lane (same Auto resolution as the K-Medoids
        // driver; k-means has no resume path, so only checkpointing can
        // veto it). Labels, partial sums and cost bits are identical to
        // the dense lane by construction — only dist_evals shrink.
        let pruned: Option<Arc<PrunedAssigner>> = self
            .params
            .pruning
            .enabled(hub.wants_checkpoints(), false)
            .then(|| Arc::new(PrunedAssigner::new(self.metric)));
        let mut cost = f64::INFINITY;
        let mut iterations = 0;
        let mut dist_evals = 0u64;
        for iter in 0..self.params.max_iters {
            iterations = iter + 1;
            if let Some(pa) = &pruned {
                pa.begin_epoch(&centers);
            }
            let job = JobSpec::new(
                &format!("kmeans-iter{iter}"),
                input.clone(),
                Arc::new(KMeansMapper {
                    backend: self.backend.clone(),
                    centers: centers.clone(),
                    pruned: pruned.clone(),
                }),
            )
            .with_combiner(Arc::new(MeanReducer { dims }))
            .with_reducer(Arc::new(MeanReducer { dims }), k.min(4).max(1));
            let result = cluster.try_run_job(&job)?;
            dist_evals += result.counters.get("work.dist.evals");
            let new_cost = result.counters.get("assign.cost.units") as f64;
            let mut new_centers = centers.clone();
            for (key, val) in &result.output {
                let j = decode_cluster_key(key) as usize;
                new_centers[j] = decode_point_coords(val, dims);
            }
            let moved: f64 =
                new_centers.iter().zip(&centers).map(|(a, b)| a.dist2(b)).sum::<f64>();
            let drift: f64 =
                new_centers.iter().zip(&centers).map(|(a, b)| a.dist2(b).sqrt()).sum();
            centers = new_centers;
            let done = moved == 0.0
                || (cost.is_finite()
                    && (cost - new_cost).abs() <= self.params.rel_tol * cost.abs().max(1.0));
            cost = new_cost;
            hub.iteration(&IterationEvent {
                algorithm: "kmeans-mr",
                iteration: iterations,
                cost,
                medoid_drift: drift,
                sim_seconds: cluster.now().0 - t0,
                dist_evals,
            });
            if done {
                break;
            }
        }
        Ok(ClusterOutcome {
            medoids: centers,
            labels: None,
            cost,
            iterations,
            sim_seconds: cluster.now().0 - t0,
            dist_evals,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::metrics::{adjusted_rand_index, brute_labels, brute_labels_metric};
    use crate::config::ClusterConfig;
    use crate::geo::datasets::{generate, SpatialSpec};
    use crate::mapreduce::{SplitMeta, SplitOrigin};
    use crate::runtime::NativeBackend;

    fn make_input(points: &Arc<Vec<Point>>, n_splits: usize) -> Input {
        let total = points.len() as u64;
        let splits = (0..n_splits as u64)
            .map(|i| SplitMeta {
                row_start: total * i / n_splits as u64,
                row_end: total * (i + 1) / n_splits as u64,
                bytes: 1 << 20,
                preferred: vec![],
                origin: SplitOrigin::Adhoc,
            })
            .collect();
        Input::Points { points: points.clone(), splits }
    }

    #[test]
    fn kmeans_recovers_clean_clusters() {
        // Seed chosen to converge to the global optimum (Lloyd's is a
        // local-optimum method; other seeds legitimately merge clusters).
        let mut spec = SpatialSpec::new(4000, 4, 62);
        spec.outlier_frac = 0.0; // no outliers: k-means' happy case
        let d = generate(&spec);
        let points = Arc::new(d.points);
        let input = make_input(&points, 5);
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 62);
        let km = ParallelKMeans {
            backend: Arc::new(NativeBackend::new(256, 16)),
            init: Init::PlusPlus,
            params: IterParams::new(4, 62),
            metric: Metric::SqEuclidean,
        };
        let out = km.run(&mut cluster, &input, &points);
        let labels = brute_labels(&points, &out.medoids);
        let ari = adjusted_rand_index(&labels, &d.truth);
        assert!(ari > 0.9, "ARI {ari}");
        assert!(out.iterations >= 2);
    }

    #[test]
    fn kmeans_mean_update_generalizes_to_3d() {
        let mut spec = SpatialSpec::new(3000, 3, 64).with_dims(3);
        spec.outlier_frac = 0.0;
        let d = generate(&spec);
        let points = Arc::new(d.points);
        let input = make_input(&points, 4);
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 64);
        let km = ParallelKMeans {
            backend: Arc::new(NativeBackend::new(256, 16)),
            init: Init::PlusPlus,
            params: IterParams::new(3, 64),
            metric: Metric::SqEuclidean,
        };
        let out = km.run(&mut cluster, &input, &points);
        assert!(out.medoids.iter().all(|c| c.dims() == 3));
        let labels = brute_labels(&points, &out.medoids);
        let ari = adjusted_rand_index(&labels, &d.truth);
        assert!(ari > 0.85, "ARI {ari} (3-D mean update)");
    }

    #[test]
    fn non_euclidean_kmeans_falls_back_to_medoid_update() {
        // Under Manhattan the mean is not the minimizer: the driver must
        // run the medoid fallback, whose "centers" are data points —
        // the observable contract of the fallback.
        let mut spec = SpatialSpec::new(2500, 4, 66);
        spec.outlier_frac = 0.0;
        let d = generate(&spec);
        let points = Arc::new(d.points);
        let input = make_input(&points, 4);
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 66);
        let km = ParallelKMeans {
            backend: Arc::new(NativeBackend::new(256, 16)),
            init: Init::PlusPlus,
            params: IterParams::new(4, 66),
            metric: Metric::Manhattan,
        };
        let mut hub = ObserverHub::default();
        let log = crate::clustering::observe::IterationLog::new();
        hub.add(Box::new(log.clone()));
        let out = km.run_observed(&mut cluster, &input, &points, &mut hub).unwrap();
        for c in &out.medoids {
            assert!(
                points.iter().any(|p| p == c),
                "non-Euclidean k-means center {c:?} must be a data point"
            );
        }
        // Events still stream under the k-means name.
        assert!(!log.events().is_empty());
        assert!(log.events().iter().all(|e| e.algorithm == "kmeans-mr"));
        // And the fit still recovers the planted structure.
        let labels = brute_labels_metric(&points, &out.medoids, Metric::Manhattan);
        let ari = adjusted_rand_index(&labels, &d.truth);
        assert!(ari > 0.8, "ARI {ari} (Manhattan medoid fallback)");
    }

    #[test]
    fn outliers_drag_kmeans_centers_but_not_kmedoid_medoids() {
        // The paper's §1 motivation, quantified. Same random init for
        // both algorithms (so ++ seeding's own outlier-sensitivity does
        // not confound the comparison); the metric is *coverage*: how far
        // each true hotspot center is from the nearest fitted
        // center/medoid, aggregated over several seeds because both
        // methods are local-optimum algorithms and any single seed is
        // dominated by which basin it lands in.
        let be: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::new(256, 16));
        let mut km_total = 0.0;
        let mut kmed_total = 0.0;
        for seed in 67u64..=74 {
            let mut spec = SpatialSpec::new(3000, 3, seed);
            spec.outlier_frac = 0.03; // exaggerated outlier rate
            let d = generate(&spec);
            let points = Arc::new(d.points);
            let input = make_input(&points, 5);

            let mut c1 = Cluster::new(ClusterConfig::test_cluster(4), seed);
            let km = ParallelKMeans {
                backend: be.clone(),
                init: Init::Random,
                params: IterParams::new(3, seed),
                metric: Metric::SqEuclidean,
            };
            let km_out = km.run(&mut c1, &input, &points);

            let mut c2 = Cluster::new(ClusterConfig::test_cluster(4), seed);
            let mut drv = crate::clustering::parallel::ParallelKMedoids::new(
                be.clone(),
                IterParams::new(3, seed),
            );
            drv.init = Init::Random;
            drv.update = crate::clustering::UpdateStrategy::Exact;
            let kmed_out = drv.run(&mut c2, &input, &points);

            let coverage = |cs: &[Point]| -> f64 {
                d.centers
                    .iter()
                    .map(|t| cs.iter().map(|c| t.dist2(c).sqrt()).fold(f64::INFINITY, f64::min))
                    .sum::<f64>()
                    / d.centers.len() as f64
            };
            km_total += coverage(&km_out.medoids);
            kmed_total += coverage(&kmed_out.medoids);
        }
        assert!(
            kmed_total < km_total,
            "aggregate medoid coverage ({kmed_total:.0}) should beat means ({km_total:.0})"
        );
    }
}
