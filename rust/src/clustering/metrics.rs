//! Cluster-quality metrics: Eq. 1 total cost, adjusted Rand index against
//! generator ground truth, and a sampled silhouette coefficient.

use crate::geo::{Metric, Point};
use crate::util::nearest::nearest_point;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Total cost E (paper Eq. 1): Σ over points of squared distance to the
/// nearest medoid. Brute force — used as the verification oracle.
pub fn total_cost(points: &[Point], medoids: &[Point]) -> f64 {
    total_cost_metric(points, medoids, Metric::SqEuclidean)
}

/// [`total_cost`] under any [`Metric`]: Σ over points of the metric's
/// dissimilarity to the nearest medoid (the general K-Medoids objective).
pub fn total_cost_metric(points: &[Point], medoids: &[Point], metric: Metric) -> f64 {
    assert!(!medoids.is_empty());
    points
        .iter()
        .map(|p| medoids.iter().map(|m| metric.distance(p, m)).fold(f64::INFINITY, f64::min))
        .sum()
}

/// Weighted total cost: `Σ w_i · d(p_i, nearest medoid)` — the objective
/// a weighted coreset stands in for. Brute force, the verification oracle
/// for the weighted pipeline ([`crate::clustering::coreset`]). With every
/// weight 1.0 this is exactly [`total_cost_metric`], and duplicating a
/// point is equivalent to doubling its weight (both invariants are
/// property-tested).
pub fn weighted_total_cost_metric(
    points: &[Point],
    weights: &[f32],
    medoids: &[Point],
    metric: Metric,
) -> f64 {
    assert!(!medoids.is_empty());
    assert_eq!(points.len(), weights.len(), "one weight per point");
    points
        .iter()
        .zip(weights)
        .map(|(p, &w)| {
            w as f64
                * medoids.iter().map(|m| metric.distance(p, m)).fold(f64::INFINITY, f64::min)
        })
        .sum()
}

/// Nearest-medoid labels, brute force (shared first-min-wins scan from
/// [`crate::util::nearest`]).
pub fn brute_labels(points: &[Point], medoids: &[Point]) -> Vec<u32> {
    brute_labels_metric(points, medoids, Metric::SqEuclidean)
}

/// [`brute_labels`] under any [`Metric`].
pub fn brute_labels_metric(points: &[Point], medoids: &[Point], metric: Metric) -> Vec<u32> {
    assert!(!medoids.is_empty());
    points
        .iter()
        .map(|p| {
            nearest_point(*p, medoids.iter().copied(), metric).expect("non-empty medoids").0 as u32
        })
        .collect()
}

/// Adjusted Rand Index between predicted labels and generator truth
/// (points with no true cluster — noise/outliers — are skipped).
pub fn adjusted_rand_index(pred: &[u32], truth: &[Option<u32>]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let pairs: Vec<(u32, u32)> = pred
        .iter()
        .zip(truth)
        .filter_map(|(&p, t)| t.map(|t| (p, t)))
        .collect();
    let n = pairs.len();
    if n < 2 {
        return 1.0;
    }
    let mut cont: HashMap<(u32, u32), u64> = HashMap::new();
    let mut rows: HashMap<u32, u64> = HashMap::new();
    let mut cols: HashMap<u32, u64> = HashMap::new();
    for &(p, t) in &pairs {
        *cont.entry((p, t)).or_insert(0) += 1;
        *rows.entry(p).or_insert(0) += 1;
        *cols.entry(t).or_insert(0) += 1;
    }
    let c2 = |x: u64| (x * x.saturating_sub(1) / 2) as f64;
    let sum_ij: f64 = cont.values().map(|&v| c2(v)).sum();
    let sum_i: f64 = rows.values().map(|&v| c2(v)).sum();
    let sum_j: f64 = cols.values().map(|&v| c2(v)).sum();
    let total = c2(n as u64);
    let expected = sum_i * sum_j / total;
    let max_index = 0.5 * (sum_i + sum_j);
    if (max_index - expected).abs() < 1e-12 {
        return 1.0;
    }
    (sum_ij - expected) / (max_index - expected)
}

/// Silhouette coefficient estimated on a deterministic sample (full
/// silhouette is O(n²)). Returns a value in [-1, 1].
pub fn silhouette_sampled(
    points: &[Point],
    labels: &[u32],
    k: usize,
    sample: usize,
    seed: u64,
) -> f64 {
    assert_eq!(points.len(), labels.len());
    let n = points.len();
    if n < 2 || k < 2 {
        return 0.0;
    }
    let mut rng = Rng::new(seed);
    let idx = rng.sample_indices(n, sample.min(n));
    // Pre-bucket points by cluster, sampling each bucket too.
    let mut buckets: Vec<Vec<Point>> = vec![Vec::new(); k];
    for (p, &l) in points.iter().zip(labels) {
        let b = &mut buckets[l as usize];
        if b.len() < 2000 {
            b.push(*p);
        } else {
            // Reservoir: keep the per-cluster sample unbiased.
            let j = rng.below(b.len() * 4);
            if j < 2000 {
                b[j % 2000] = *p;
            }
        }
    }
    let mut total = 0.0;
    let mut counted = 0usize;
    for &i in &idx {
        let li = labels[i] as usize;
        if buckets[li].len() < 2 {
            continue;
        }
        let mean_to = |bucket: &[Point]| -> f64 {
            bucket.iter().map(|q| points[i].dist2(q).sqrt()).sum::<f64>() / bucket.len() as f64
        };
        let a = mean_to(&buckets[li]);
        let b = (0..k)
            .filter(|&j| j != li && !buckets[j].is_empty())
            .map(|j| mean_to(&buckets[j]))
            .fold(f64::INFINITY, f64::min);
        if !b.is_finite() {
            continue;
        }
        total += (b - a) / a.max(b).max(1e-12);
        counted += 1;
    }
    if counted == 0 {
        0.0
    } else {
        total / counted as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_blobs() -> (Vec<Point>, Vec<u32>) {
        let mut pts = Vec::new();
        let mut labels = Vec::new();
        for i in 0..50 {
            pts.push(Point::new(i as f32 * 0.01, 0.0));
            labels.push(0);
            pts.push(Point::new(100.0 + i as f32 * 0.01, 0.0));
            labels.push(1);
        }
        (pts, labels)
    }

    #[test]
    fn total_cost_zero_on_medoids() {
        let pts = vec![Point::new(1.0, 2.0), Point::new(3.0, 4.0)];
        assert_eq!(total_cost(&pts, &pts), 0.0);
        assert!(total_cost(&pts, &[Point::new(0.0, 0.0)]) > 0.0);
    }

    #[test]
    fn ari_perfect_and_permuted() {
        let truth: Vec<Option<u32>> = vec![Some(0), Some(0), Some(1), Some(1), Some(2), Some(2)];
        let pred = vec![5u32, 5, 7, 7, 9, 9]; // same partition, relabeled
        assert!((adjusted_rand_index(&pred, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ari_random_near_zero() {
        let mut rng = Rng::new(4);
        let truth: Vec<Option<u32>> = (0..2000).map(|_| Some(rng.below(3) as u32)).collect();
        let pred: Vec<u32> = (0..2000).map(|_| rng.below(3) as u32).collect();
        let ari = adjusted_rand_index(&pred, &truth);
        assert!(ari.abs() < 0.05, "ari {ari}");
    }

    #[test]
    fn ari_ignores_noise() {
        let truth = vec![Some(0), Some(0), None, Some(1), Some(1), None];
        let pred = vec![0u32, 0, 9, 1, 1, 3];
        assert!((adjusted_rand_index(&pred, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn silhouette_high_for_separated_blobs() {
        let (pts, labels) = two_blobs();
        let s = silhouette_sampled(&pts, &labels, 2, 100, 1);
        assert!(s > 0.9, "silhouette {s}");
    }

    #[test]
    fn silhouette_low_for_bad_split() {
        let (pts, _) = two_blobs();
        // Random labels: silhouette should be much worse.
        let mut rng = Rng::new(2);
        let bad: Vec<u32> = (0..pts.len()).map(|_| rng.below(2) as u32).collect();
        let s = silhouette_sampled(&pts, &bad, 2, 100, 1);
        assert!(s < 0.3, "silhouette {s}");
    }

    #[test]
    fn brute_labels_pick_nearest() {
        let pts = vec![Point::new(0.0, 0.0), Point::new(10.0, 0.0)];
        let med = vec![Point::new(1.0, 0.0), Point::new(9.0, 0.0)];
        assert_eq!(brute_labels(&pts, &med), vec![0, 1]);
    }

    #[test]
    fn weighted_cost_with_unit_weights_is_unweighted_cost() {
        use crate::util::proptest::for_all;
        for metric in [Metric::SqEuclidean, Metric::Manhattan] {
            for_all(20, 0x3E16, |rng| {
                let n = 3 + rng.below(60);
                let k = 1 + rng.below(4);
                let mk = |rng: &mut Rng, n: usize| -> Vec<Point> {
                    (0..n)
                        .map(|_| {
                            Point::new(
                                rng.range_f64(-50.0, 50.0) as f32,
                                rng.range_f64(-50.0, 50.0) as f32,
                            )
                        })
                        .collect()
                };
                let pts = mk(rng, n);
                let med = mk(rng, k);
                let ones = vec![1.0f32; n];
                let w = weighted_total_cost_metric(&pts, &ones, &med, metric);
                let u = total_cost_metric(&pts, &med, metric);
                assert!((w - u).abs() <= 1e-9 * u.max(1.0), "{metric:?}: {w} vs {u}");
            });
        }
    }

    #[test]
    fn duplicating_a_point_equals_doubling_its_weight() {
        use crate::util::proptest::for_all;
        for_all(30, 0x3E17, |rng| {
            let n = 2 + rng.below(40);
            let pts: Vec<Point> = (0..n)
                .map(|_| {
                    Point::new(
                        rng.range_f64(-50.0, 50.0) as f32,
                        rng.range_f64(-50.0, 50.0) as f32,
                    )
                })
                .collect();
            let med = vec![pts[0], pts[n / 2]];
            let mut weights: Vec<f32> = (0..n).map(|_| 1.0 + rng.below(4) as f32).collect();
            let dup = rng.below(n);
            // Version A: point `dup` appears twice at its own weight.
            let mut pts_a = pts.clone();
            pts_a.push(pts[dup]);
            let mut w_a = weights.clone();
            w_a.push(weights[dup]);
            let a = weighted_total_cost_metric(&pts_a, &w_a, &med, Metric::SqEuclidean);
            // Version B: point `dup` appears once at double weight.
            weights[dup] *= 2.0;
            let b = weighted_total_cost_metric(&pts, &weights, &med, Metric::SqEuclidean);
            assert!((a - b).abs() <= 1e-9 * a.max(1.0), "{a} vs {b}");
        });
    }

    #[test]
    fn metric_variants_of_cost_and_labels() {
        // From (0, 0): squared L2 prefers (2, 2) (8 < 9), L1 prefers
        // (0, 3) (3 < 4) — the metrics disagree on the nearest medoid.
        let pts = vec![Point::new(0.0, 0.0)];
        let med = vec![Point::new(2.0, 2.0), Point::new(0.0, 3.0)];
        // Default wrappers are the squared-Euclidean oracles.
        assert_eq!(total_cost(&pts, &med), total_cost_metric(&pts, &med, Metric::SqEuclidean));
        assert_eq!(brute_labels(&pts, &med), brute_labels_metric(&pts, &med, Metric::SqEuclidean));
        assert_eq!(brute_labels(&pts, &med), vec![0]);
        assert_eq!(total_cost(&pts, &med), 8.0);
        assert_eq!(brute_labels_metric(&pts, &med, Metric::Manhattan), vec![1]);
        assert_eq!(total_cost_metric(&pts, &med, Metric::Manhattan), 3.0);
    }
}
