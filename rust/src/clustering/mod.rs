//! Clustering algorithms: the paper's parallel K-Medoids++ plus every
//! comparator it is evaluated against.
//!
//! | Algorithm | Module | Role |
//! |---|---|---|
//! | Parallel K-Medoids++ (MR) | [`parallel`] | the paper's contribution (§3) |
//! | Parallel K-Medoids, random init (MR) | [`parallel`] | "traditional K-Medoids" in Fig. 5 |
//! | Weighted-coreset K-Medoids (MR) | [`coreset`] | constant-round pipeline (Ene et al.) |
//! | Serial alternating K-Medoids | [`pam`] | §2.3 baseline |
//! | PAM (build + swap) | [`pam`] | exact small-n reference |
//! | CLARANS | [`clarans`] | Fig. 5 comparator |
//! | Parallel k-means (MR) | [`kmeans`] | robustness ablation (§1 motivation) |

pub mod api;
pub mod clarans;
pub mod coreset;
pub mod kmeans;
pub mod metrics;
pub mod observe;
pub mod pam;
pub mod parallel;
pub mod seeding;

pub use api::{
    Clarans, ClaransBuilder, KMeans, KMeansBuilder, KMedoids, KMedoidsBuilder, SpatialClusterer,
};
pub use observe::{
    FitCheckpoint, IterationEvent, IterationLog, IterationObserver, ObserverHub, StderrProgress,
};

use crate::geo::{Metric, Point};
pub use crate::runtime::pruned::PruningMode;

/// How a reducer picks the next medoid of a cluster.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum UpdateStrategy {
    /// Exact PAM-style update: every member is a candidate, cost over all
    /// members. O(m²) distance evaluations per cluster.
    Exact,
    /// Candidate sampling: `candidates` sampled members (plus the current
    /// medoid) scored against up to `member_sample` sampled members.
    /// Unbiased argmin estimate; the only tractable choice at the paper's
    /// 3.2M-point scale (see DESIGN.md substitutions).
    Sampled { candidates: usize, member_sample: usize },
    /// Like `Sampled`, but the member sample grows with the cluster
    /// (`max(min_sample, m / frac_div)`), so the reduce phase scales with
    /// dataset size the way the paper's exact Table 2 reducer does.
    SampledAdaptive { candidates: usize, frac_div: usize, min_sample: usize },
    /// Pick the member nearest the cluster centroid (Zhang & Couloigner
    /// style fast update). O(m).
    CentroidNearest,
}

impl UpdateStrategy {
    pub fn paper_scale_default() -> UpdateStrategy {
        UpdateStrategy::SampledAdaptive { candidates: 256, frac_div: 4, min_sample: 16_384 }
    }
}

/// Common result type for every algorithm.
#[derive(Debug, Clone)]
pub struct ClusterOutcome {
    pub medoids: Vec<Point>,
    /// Final assignment (present when the driver ran a labeling pass).
    pub labels: Option<Vec<u32>>,
    /// Total cost E (Eq. 1): sum of squared distances to medoids.
    pub cost: f64,
    /// Outer iterations until convergence.
    pub iterations: usize,
    /// Simulated wall-clock seconds (MR jobs on the simulated cluster, or
    /// the serial cost model for serial algorithms).
    pub sim_seconds: f64,
    /// Distance evaluations actually performed (work ground truth).
    pub dist_evals: u64,
}

/// Convergence / iteration-control knobs shared by the iterative solvers.
#[derive(Debug, Clone)]
pub struct IterParams {
    pub k: usize,
    pub max_iters: usize,
    /// Stop when medoids are unchanged (the paper's criterion). As a
    /// safety net we also stop when cost improves by less than `rel_tol`.
    pub rel_tol: f64,
    /// When set, run exactly this many outer iterations regardless of
    /// convergence. Used by the Table 6 scaling suite so that the
    /// time-vs-dataset-size comparison is not confounded by per-dataset
    /// convergence luck (iteration counts vary with the synthetic seed;
    /// the paper's monotone Table 6 implies near-equal counts). Documented
    /// in EXPERIMENTS.md §Method.
    pub fixed_iters: Option<usize>,
    pub seed: u64,
    /// Triangle-inequality pruned assignment lane
    /// ([`crate::runtime::PrunedAssigner`]). Outputs are byte-identical
    /// either way; only `dist_evals` (and therefore simulated time)
    /// shrink. `Auto` (the default) enables pruning unless the fit
    /// writes checkpoints or resumes from one.
    pub pruning: PruningMode,
}

impl IterParams {
    pub fn new(k: usize, seed: u64) -> IterParams {
        // rel_tol 1e-3 ≈ the paper's "total cost remains the same" with
        // a sampled update in the loop (exact equality still fires first
        // for the Exact strategy).
        IterParams {
            k,
            max_iters: 30,
            rel_tol: 1e-3,
            fixed_iters: None,
            seed,
            pruning: PruningMode::Auto,
        }
    }
}

/// Restored mid-fit state a solver continues from instead of seeding —
/// the engine-facing form of a loaded [`crate::persist::Checkpoint`]
/// (convert with `Checkpoint::to_resume`). The MR drivers validate it
/// against their own configuration (algorithm name, metric, seed, k,
/// dims) and then skip seeding/coreset construction entirely: because
/// every per-iteration RNG stream is reseeded from the base seed, a
/// resumed run replays the exact byte-for-byte trajectory of the
/// uninterrupted one.
#[derive(Debug, Clone, PartialEq)]
pub struct FitResume {
    /// Algorithm the checkpoint was written by (`Algorithm::name`
    /// vocabulary); must match the resuming solver.
    pub algorithm: String,
    /// Metric of the checkpointed fit; must match the resuming solver.
    pub metric: Metric,
    /// Base seed of the checkpointed fit; must match the resuming solver.
    pub seed: u64,
    /// Completed outer iterations.
    pub iteration: usize,
    /// Cost at the checkpoint boundary.
    pub cost: f64,
    /// Simulated seconds already consumed (added to resumed telemetry).
    pub sim_seconds: f64,
    /// Distance evaluations already performed.
    pub dist_evals: u64,
    /// Whether the fit had already converged at this boundary; a resumed
    /// converged fit runs no further iterations.
    pub converged: bool,
    /// Medoids at the boundary.
    pub medoids: Vec<Point>,
    /// Weighted coreset pool (required to resume the coreset driver).
    pub coreset: Option<(Vec<Point>, Vec<f64>)>,
}

/// Initialization flavor (the paper's §3.1 ablation axis, plus the
/// k-means||-style oversampled seeding of Bahmani et al., *Scalable
/// K-Means++*).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Init {
    /// K-Medoids++ weighted seeding (Arthur & Vassilvitskii).
    PlusPlus,
    /// Uniform random distinct points ("traditional").
    Random,
    /// k-means||-style oversampled seeding (Bahmani et al.): each of
    /// `rounds` rounds samples every point independently with probability
    /// `min(1, l·d(p)/ψ)` (≈ `l` candidates per round, O(log ψ) rounds in
    /// the paper), then the weighted candidate set is reclustered to k
    /// medoids. One MR pass per round instead of one per medoid, so
    /// seeding needs O(rounds) jobs rather than k−1.
    OverSample { l: usize, rounds: usize },
}

impl Init {
    /// Bahmani et al.'s recommended defaults for k clusters: oversampling
    /// factor ℓ = 2k per round, 5 rounds.
    pub fn oversample_default(k: usize) -> Init {
        Init::OverSample { l: (2 * k).max(2), rounds: 5 }
    }
}
