//! Serial K-Medoids baselines.
//!
//! - [`alternating_kmedoids`] — the "traditional K-Medoids" of the paper's
//!   §2.3 / Fig. 5: assign all points to the nearest medoid, then per
//!   cluster pick the member with the least total cost; repeat until the
//!   medoids stop changing. Runs on one node (the master), so its
//!   simulated time comes from the serial cost model.
//! - [`pam_swap`] — the classic PAM build+swap of Kaufman & Rousseeuw
//!   (§2.3's "earliest K-Medoids algorithm"): exact but O(k(n−k)²) per
//!   pass; used as the quality reference on small inputs.
//!
//! Both are metric-generic: the run's [`Metric`] drives assignment,
//! update, and cost exactly as in the MR drivers, so serial-vs-parallel
//! comparisons stay apples-to-apples for every `(dims, metric)` pair.
//!
//! Neither engine submits MR jobs, so execution lanes
//! ([`crate::mapreduce::Lane`]) do not apply here — the fluent API
//! refuses a lane override on `kmedoids-serial` rather than silently
//! ignoring it.

use super::observe::{IterationEvent, ObserverHub};
use super::seeding::{oversample_serial, plus_plus_serial, random_init};
use super::{ClusterOutcome, Init, IterParams, UpdateStrategy};
use crate::config::ClusterConfig;
use crate::geo::{Metric, Point};
use crate::mapreduce::ReduceCtx;
use crate::runtime::ComputeBackend;
use crate::sim::{CostModel, TaskWork};
use crate::util::rng::Rng;

/// Simulated seconds for a serial computation on the master node:
/// CPU from the work meter plus one full dataset scan per pass.
pub fn serial_seconds(
    cfg: &ClusterConfig,
    cost: &CostModel,
    work: &TaskWork,
    scans: u64,
    dataset_bytes: u64,
) -> f64 {
    let node = &cfg.nodes[cfg.master];
    cost.cpu_seconds(node, work)
        + scans as f64 * dataset_bytes as f64 / (cost.disk_read_mb_s * 1e6)
}

/// Traditional serial K-Medoids (alternating assignment / least-cost
/// medoid update). `update` controls the per-cluster update exactly like
/// the MR reducer, so serial-vs-parallel comparisons are apples-to-apples.
#[allow(clippy::too_many_arguments)]
pub fn alternating_kmedoids(
    backend: &dyn ComputeBackend,
    points: &[Point],
    params: &IterParams,
    init: Init,
    update: UpdateStrategy,
    metric: Metric,
    cfg: &ClusterConfig,
    cost_model: &CostModel,
    dataset_bytes: u64,
) -> ClusterOutcome {
    alternating_kmedoids_observed(
        backend,
        points,
        params,
        init,
        update,
        metric,
        cfg,
        cost_model,
        dataset_bytes,
        &mut ObserverHub::default(),
    )
}

/// [`alternating_kmedoids`] with per-iteration streaming: one
/// [`IterationEvent`] per alternation, whose cumulative `sim_seconds`
/// uses the same serial cost formula as the final outcome (so the last
/// event matches the returned [`ClusterOutcome`] exactly).
#[allow(clippy::too_many_arguments)]
pub fn alternating_kmedoids_observed(
    backend: &dyn ComputeBackend,
    points: &[Point],
    params: &IterParams,
    init: Init,
    update: UpdateStrategy,
    metric: Metric,
    cfg: &ClusterConfig,
    cost_model: &CostModel,
    dataset_bytes: u64,
    hub: &mut ObserverHub,
) -> ClusterOutcome {
    let k = params.k;
    let mut rng = Rng::new(params.seed);
    let (mut medoids, seed_evals) = match init {
        Init::PlusPlus => plus_plus_serial(points, k, &mut rng, metric),
        Init::Random => (random_init(points, k, &mut rng), 0),
        Init::OverSample { l, rounds } => {
            oversample_serial(points, k, l, rounds, &mut rng, metric)
        }
    };
    let mut dist_evals = seed_evals;
    let mut iterations = 0usize;
    let mut cost = f64::INFINITY;
    let mut labels: Vec<u32> = vec![0; points.len()];

    for iter in 0..params.max_iters {
        iterations = iter + 1;
        // Assignment pass.
        let res = crate::runtime::assign_points(backend, points, &medoids, metric)
            .expect("assign kernel failed");
        dist_evals += res.dist_evals;
        labels.copy_from_slice(&res.labels);
        let new_cost: f64 = res.cluster_cost.iter().sum();

        // Per-cluster least-cost medoid update (same code as the reducer).
        let mut members: Vec<Vec<Point>> = vec![Vec::new(); k];
        for (p, &l) in points.iter().zip(&labels) {
            members[l as usize].push(*p);
        }
        let mut new_medoids = medoids.clone();
        let mut rctx = ReduceCtx::default();
        for j in 0..k {
            if members[j].is_empty() {
                continue;
            }
            new_medoids[j] = super::parallel::choose_medoid(
                backend,
                members[j].as_slice(),
                medoids[j],
                update,
                metric,
                params.seed ^ (iter as u64) << 20 ^ j as u64,
                &mut rctx,
            );
        }
        dist_evals += rctx.work.dist_evals;

        let unchanged = new_medoids.iter().zip(&medoids).all(|(a, b)| a == b);
        let cost_flat = cost.is_finite()
            && (cost - new_cost).abs() <= params.rel_tol * cost.abs().max(1.0);
        let drift: f64 =
            new_medoids.iter().zip(&medoids).map(|(a, b)| metric.displacement(a, b)).sum();
        medoids = new_medoids;
        cost = new_cost;
        // Running sim time with the same formula as the final outcome.
        let work_so_far = TaskWork {
            rows_parsed: points.len() as u64 * (iterations as u64 + 1),
            dist_evals,
            ..Default::default()
        };
        hub.iteration(&IterationEvent {
            algorithm: "kmedoids-serial",
            iteration: iterations,
            cost,
            medoid_drift: drift,
            sim_seconds: serial_seconds(
                cfg,
                cost_model,
                &work_so_far,
                iterations as u64 + 1,
                dataset_bytes,
            ),
            dist_evals,
        });
        if unchanged || cost_flat {
            break;
        }
    }

    let work = TaskWork {
        rows_parsed: points.len() as u64 * (iterations as u64 + 1),
        dist_evals,
        ..Default::default()
    };
    let sim_seconds = serial_seconds(cfg, cost_model, &work, iterations as u64 + 1, dataset_bytes);
    ClusterOutcome { medoids, labels: Some(labels), cost, iterations, sim_seconds, dist_evals }
}

/// Classic PAM: greedy BUILD then steepest-descent SWAP under `metric`.
/// Exact; only for small n (cost O(k(n−k)²) per sweep).
pub fn pam_swap(
    points: &[Point],
    k: usize,
    seed: u64,
    max_sweeps: usize,
    metric: Metric,
) -> (Vec<Point>, f64, u64) {
    assert!((1..=points.len()).contains(&k));
    let dims = points.first().map(|p| p.dims()).unwrap_or(2);
    assert!(
        metric.supports_dims(dims),
        "{} does not support dims={dims}",
        metric.name()
    );
    let n = points.len();
    let mut dist_evals = 0u64;

    // BUILD: first medoid = minimizer of total distance; then greedily add
    // the point that most reduces cost.
    let mut in_set = vec![false; n];
    let mut medoid_idx: Vec<usize> = Vec::with_capacity(k);
    {
        let mut best = (0usize, f64::INFINITY);
        for i in 0..n {
            let c: f64 = points.iter().map(|p| metric.distance(&points[i], p)).sum();
            dist_evals += n as u64;
            if c < best.1 {
                best = (i, c);
            }
        }
        medoid_idx.push(best.0);
        in_set[best.0] = true;
    }
    let mut nearest: Vec<f64> =
        points.iter().map(|p| metric.distance(p, &points[medoid_idx[0]])).collect();
    dist_evals += n as u64;
    while medoid_idx.len() < k {
        let mut best = (usize::MAX, 0.0f64);
        for cand in 0..n {
            if in_set[cand] {
                continue;
            }
            let mut gain = 0.0;
            for (j, p) in points.iter().enumerate() {
                let d = metric.distance(p, &points[cand]);
                if d < nearest[j] {
                    gain += nearest[j] - d;
                }
            }
            dist_evals += n as u64;
            if gain > best.1 || best.0 == usize::MAX {
                best = (cand, gain);
            }
        }
        let c = best.0;
        in_set[c] = true;
        medoid_idx.push(c);
        for (j, p) in points.iter().enumerate() {
            nearest[j] = nearest[j].min(metric.distance(p, &points[c]));
        }
        dist_evals += n as u64;
    }

    // SWAP: repeat best (medoid, non-medoid) swap while cost improves.
    let cost_of = |set: &[usize], evals: &mut u64| -> f64 {
        *evals += (set.len() * n) as u64;
        points
            .iter()
            .map(|p| {
                set.iter().map(|&m| metric.distance(p, &points[m])).fold(f64::INFINITY, f64::min)
            })
            .sum()
    };
    let mut cur_cost = cost_of(&medoid_idx, &mut dist_evals);
    for _ in 0..max_sweeps {
        let mut best: Option<(usize, usize, f64)> = None;
        for mi in 0..k {
            for cand in 0..n {
                if in_set[cand] {
                    continue;
                }
                let mut trial = medoid_idx.clone();
                trial[mi] = cand;
                let c = cost_of(&trial, &mut dist_evals);
                if c < cur_cost && best.map(|(_, _, bc)| c < bc).unwrap_or(true) {
                    best = Some((mi, cand, c));
                }
            }
        }
        match best {
            Some((mi, cand, c)) => {
                in_set[medoid_idx[mi]] = false;
                in_set[cand] = true;
                medoid_idx[mi] = cand;
                cur_cost = c;
            }
            None => break,
        }
    }
    let _ = seed;
    (medoid_idx.into_iter().map(|i| points[i]).collect(), cur_cost, dist_evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::metrics::{adjusted_rand_index, total_cost, total_cost_metric};
    use crate::geo::datasets::{generate, SpatialSpec};
    use crate::runtime::NativeBackend;

    fn be() -> NativeBackend {
        NativeBackend::new(256, 16)
    }

    fn env() -> (ClusterConfig, CostModel) {
        (ClusterConfig::paper_cluster(), CostModel::default())
    }

    #[test]
    fn alternating_recovers_clusters() {
        let d = generate(&SpatialSpec::new(3000, 5, 23));
        let (cfg, cm) = env();
        let out = alternating_kmedoids(
            &be(),
            &d.points,
            &IterParams::new(5, 23),
            Init::PlusPlus,
            UpdateStrategy::Exact,
            Metric::SqEuclidean,
            &cfg,
            &cm,
            1 << 20,
        );
        let ari = adjusted_rand_index(out.labels.as_ref().unwrap(), &d.truth);
        assert!(ari > 0.9, "ARI {ari}");
        assert!(out.sim_seconds > 0.0);
    }

    #[test]
    fn alternating_manhattan_3d() {
        // The serial baseline runs the full generic path: 3-D data under
        // the L1 metric, medoids stay data points, counter cost matches
        // the brute-force L1 objective.
        let mut spec = SpatialSpec::new(1500, 4, 27).with_dims(3);
        spec.outlier_frac = 0.0;
        let d = generate(&spec);
        let (cfg, cm) = env();
        let out = alternating_kmedoids(
            &be(),
            &d.points,
            &IterParams::new(4, 27),
            Init::PlusPlus,
            UpdateStrategy::Exact,
            Metric::Manhattan,
            &cfg,
            &cm,
            1 << 20,
        );
        assert!(out.medoids.iter().all(|m| m.dims() == 3));
        for m in &out.medoids {
            assert!(d.points.iter().any(|p| p == m), "medoid must be a data point");
        }
        let brute = total_cost_metric(&d.points, &out.medoids, Metric::Manhattan);
        assert!((out.cost - brute).abs() / brute.max(1.0) < 0.01, "{} vs {brute}", out.cost);
    }

    #[test]
    fn serial_time_increases_with_work() {
        let (cfg, cm) = env();
        let small = TaskWork { dist_evals: 1_000, ..Default::default() };
        let big = TaskWork { dist_evals: 100_000_000, ..Default::default() };
        assert!(
            serial_seconds(&cfg, &cm, &big, 1, 1 << 20)
                > serial_seconds(&cfg, &cm, &small, 1, 1 << 20)
        );
    }

    #[test]
    fn pam_swap_beats_or_matches_alternating_cost() {
        let d = generate(&SpatialSpec::new(400, 4, 29));
        let (cfg, cm) = env();
        let alt = alternating_kmedoids(
            &be(),
            &d.points,
            &IterParams::new(4, 29),
            Init::Random,
            UpdateStrategy::Exact,
            Metric::SqEuclidean,
            &cfg,
            &cm,
            1 << 20,
        );
        let (_, pam_cost, _) = pam_swap(&d.points, 4, 29, 10, Metric::SqEuclidean);
        assert!(
            pam_cost <= alt.cost * 1.001,
            "PAM {pam_cost} should be at least as good as alternating {}",
            alt.cost
        );
    }

    #[test]
    fn pam_medoids_are_data_points_and_distinct() {
        let d = generate(&SpatialSpec::new(200, 3, 31));
        let (med, _, _) = pam_swap(&d.points, 3, 31, 5, Metric::SqEuclidean);
        assert_eq!(med.len(), 3);
        for i in 0..3 {
            assert!(d.points.iter().any(|p| p == &med[i]));
            for j in 0..i {
                assert!(med[i].dist2(&med[j]) > 0.0);
            }
        }
    }

    #[test]
    fn alternating_cost_matches_bruteforce() {
        let d = generate(&SpatialSpec::new(1000, 3, 37));
        let (cfg, cm) = env();
        let out = alternating_kmedoids(
            &be(),
            &d.points,
            &IterParams::new(3, 37),
            Init::PlusPlus,
            UpdateStrategy::Exact,
            Metric::SqEuclidean,
            &cfg,
            &cm,
            1 << 20,
        );
        let brute = total_cost(&d.points, &out.medoids);
        assert!((out.cost - brute).abs() / brute < 0.01, "{} vs {brute}", out.cost);
    }
}
