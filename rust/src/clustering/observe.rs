//! Streaming iteration observers: live per-iteration telemetry from every
//! solver, consumed by the CLI, the report module, and the benches.
//!
//! Each outer iteration of a [`crate::clustering::api::SpatialClusterer`]
//! fit emits one [`IterationEvent`] through the session's [`ObserverHub`].
//! Events are cumulative *within one fit*: `sim_seconds` and `dist_evals`
//! count from the start of the fit, so the last event of a run matches the
//! final [`ClusterOutcome`] totals (asserted by tests) — except for
//! optional post-convergence passes such as the labeling job, which run
//! after the last iteration event.

use super::ClusterOutcome;
use crate::geo::{Metric, Point};
use std::cell::RefCell;
use std::rc::Rc;

/// One outer iteration of a clustering fit.
///
/// For CLARANS, whose "iterations" are accepted swap moves, `cost` is the
/// (possibly sampled) evaluation cost of the accepted node, while the
/// final outcome reports the exact Eq. 1 cost.
#[derive(Debug, Clone, PartialEq)]
pub struct IterationEvent {
    /// Algorithm name (same vocabulary as `Algorithm::name`).
    pub algorithm: &'static str,
    /// 1-based outer iteration index.
    pub iteration: usize,
    /// Total cost E (Eq. 1) after this iteration.
    pub cost: f64,
    /// Sum over clusters of the distance each medoid/center moved.
    pub medoid_drift: f64,
    /// Simulated seconds elapsed since the fit started (cumulative,
    /// including seeding rounds for the MR drivers).
    pub sim_seconds: f64,
    /// Distance evaluations performed since the fit started (cumulative).
    pub dist_evals: u64,
}

/// A consistent, resumable snapshot of a fit at an iteration boundary,
/// borrowed from the solver's live state. Emitted through
/// [`IterationObserver::on_checkpoint`] right after each
/// [`IterationEvent`], it carries everything a durable checkpoint needs
/// that the (telemetry-oriented) event does not: the medoid coordinates,
/// the weighted coreset pool, the base seed, and whether the fit
/// converged at this boundary (resuming from a converged snapshot must
/// not run an extra iteration).
#[derive(Debug, Clone, PartialEq)]
pub struct FitCheckpoint<'a> {
    /// Algorithm name (same vocabulary as `Algorithm::name`).
    pub algorithm: &'static str,
    /// Metric the fit runs under.
    pub metric: Metric,
    /// Base seed the fit was started with. Every solver RNG stream is
    /// reseeded per call from this value, so it alone resumes the run.
    pub seed: u64,
    /// Cluster count.
    pub k: usize,
    /// 1-based outer iteration index (matches the paired event).
    pub iteration: usize,
    /// Total cost after this iteration.
    pub cost: f64,
    /// Simulated seconds consumed since the fit started.
    pub sim_seconds: f64,
    /// Cumulative distance evaluations.
    pub dist_evals: u64,
    /// True when the fit's convergence test fired at this boundary.
    pub converged: bool,
    /// Current medoids.
    pub medoids: &'a [Point],
    /// Weighted coreset pool (coreset driver only): reps + f64 weights.
    pub coreset: Option<(&'a [Point], &'a [f64])>,
}

/// Hook receiving the event stream of a fit. All methods default to
/// no-ops so observers implement only what they need.
pub trait IterationObserver {
    /// A fit is starting on `n_points` points with `k` clusters.
    fn on_fit_start(&mut self, _algorithm: &'static str, _n_points: usize, _k: usize) {}
    /// One outer iteration completed.
    fn on_iteration(&mut self, _event: &IterationEvent) {}
    /// A resumable snapshot is available at an iteration boundary
    /// (emitted right after `on_iteration`). Durable sinks
    /// ([`crate::persist::CheckpointSink`]) persist it; telemetry
    /// observers ignore it.
    fn on_checkpoint(&mut self, _state: &FitCheckpoint<'_>) {}
    /// The fit finished with `outcome`.
    fn on_fit_end(&mut self, _outcome: &ClusterOutcome) {}
    /// The fit aborted with an error after `on_fit_start`. Every fit
    /// ends in exactly one of `on_fit_end` / `on_fit_error`, so stateful
    /// observers can rely on the start/end pairing.
    fn on_fit_error(&mut self, _algorithm: &'static str, _message: &str) {}
    /// True for durable checkpoint sinks. Solvers consult
    /// [`ObserverHub::wants_checkpoints`] to resolve
    /// `PruningMode::Auto`: pruned-lane bounds are not persisted, so a
    /// checkpointed fit keeps the dense lane to stay byte-identical
    /// (including `dist_evals`) with a crash-resumed rerun.
    fn wants_checkpoints(&self) -> bool {
        false
    }
}

/// Fan-out registry for observers, owned by the `ClusterSession` and
/// threaded through the solver engines.
#[derive(Default)]
pub struct ObserverHub {
    observers: Vec<Box<dyn IterationObserver>>,
}

impl ObserverHub {
    pub fn add(&mut self, observer: Box<dyn IterationObserver>) {
        self.observers.push(observer);
    }
    pub fn clear(&mut self) {
        self.observers.clear();
    }
    pub fn len(&self) -> usize {
        self.observers.len()
    }
    pub fn is_empty(&self) -> bool {
        self.observers.is_empty()
    }

    pub fn fit_start(&mut self, algorithm: &'static str, n_points: usize, k: usize) {
        for o in &mut self.observers {
            o.on_fit_start(algorithm, n_points, k);
        }
    }
    pub fn iteration(&mut self, event: &IterationEvent) {
        for o in &mut self.observers {
            o.on_iteration(event);
        }
    }
    pub fn checkpoint(&mut self, state: &FitCheckpoint<'_>) {
        for o in &mut self.observers {
            o.on_checkpoint(state);
        }
    }
    pub fn fit_end(&mut self, outcome: &ClusterOutcome) {
        for o in &mut self.observers {
            o.on_fit_end(outcome);
        }
    }
    pub fn fit_error(&mut self, algorithm: &'static str, message: &str) {
        for o in &mut self.observers {
            o.on_fit_error(algorithm, message);
        }
    }
    /// Does any registered observer persist durable checkpoints?
    pub fn wants_checkpoints(&self) -> bool {
        self.observers.iter().any(|o| o.wants_checkpoints())
    }
}

/// Recording observer: collects every event into shared storage, so the
/// caller keeps a handle (a clone) while the session owns the boxed
/// observer.
///
/// ```text
/// let log = IterationLog::new();
/// session.add_observer(Box::new(log.clone()));
/// clusterer.fit(&mut session, &data)?;
/// for ev in log.events() { ... }
/// ```
#[derive(Clone, Default)]
pub struct IterationLog {
    events: Rc<RefCell<Vec<IterationEvent>>>,
}

impl IterationLog {
    pub fn new() -> IterationLog {
        IterationLog::default()
    }
    /// Snapshot of all recorded events.
    pub fn events(&self) -> Vec<IterationEvent> {
        self.events.borrow().clone()
    }
    pub fn len(&self) -> usize {
        self.events.borrow().len()
    }
    pub fn is_empty(&self) -> bool {
        self.events.borrow().is_empty()
    }
    pub fn last(&self) -> Option<IterationEvent> {
        self.events.borrow().last().cloned()
    }
    pub fn clear(&self) {
        self.events.borrow_mut().clear();
    }
}

impl IterationObserver for IterationLog {
    fn on_iteration(&mut self, event: &IterationEvent) {
        self.events.borrow_mut().push(event.clone());
    }
}

/// Live-progress observer: one stderr line per iteration (the CLI's and
/// benches' streaming view).
#[derive(Default)]
pub struct StderrProgress;

impl StderrProgress {
    pub fn new() -> StderrProgress {
        StderrProgress
    }
}

impl IterationObserver for StderrProgress {
    fn on_fit_start(&mut self, algorithm: &'static str, n_points: usize, k: usize) {
        eprintln!("    [{algorithm}] fit start: {n_points} points, k={k}");
    }
    fn on_iteration(&mut self, ev: &IterationEvent) {
        eprintln!(
            "    [{}] iter {:>3}: cost {:.4e}  drift {:>10.2}  sim {:>8.1}s  dist-evals {}",
            ev.algorithm, ev.iteration, ev.cost, ev.medoid_drift, ev.sim_seconds, ev.dist_evals
        );
    }
    fn on_fit_end(&mut self, outcome: &ClusterOutcome) {
        eprintln!(
            "    [done] {} iterations, cost {:.4e}, sim {:.1}s",
            outcome.iterations, outcome.cost, outcome.sim_seconds
        );
    }
    fn on_fit_error(&mut self, algorithm: &'static str, message: &str) {
        eprintln!("    [{algorithm}] fit FAILED: {message}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(i: usize) -> IterationEvent {
        IterationEvent {
            algorithm: "test",
            iteration: i,
            cost: 100.0 / i as f64,
            medoid_drift: 1.0,
            sim_seconds: i as f64,
            dist_evals: 10 * i as u64,
        }
    }

    #[test]
    fn log_records_through_hub() {
        let log = IterationLog::new();
        let mut hub = ObserverHub::default();
        hub.add(Box::new(log.clone()));
        assert_eq!(hub.len(), 1);
        hub.fit_start("test", 100, 3);
        hub.iteration(&ev(1));
        hub.iteration(&ev(2));
        assert_eq!(log.len(), 2);
        assert_eq!(log.last().unwrap().iteration, 2);
        assert_eq!(log.events()[0].dist_evals, 10);
    }

    #[test]
    fn multiple_observers_all_fire() {
        let a = IterationLog::new();
        let b = IterationLog::new();
        let mut hub = ObserverHub::default();
        hub.add(Box::new(a.clone()));
        hub.add(Box::new(b.clone()));
        hub.iteration(&ev(1));
        assert_eq!(a.len(), 1);
        assert_eq!(b.len(), 1);
        hub.clear();
        assert!(hub.is_empty());
        hub.iteration(&ev(2));
        assert_eq!(a.len(), 1, "cleared observers stop receiving");
    }
}
