//! Constant-round weighted-coreset K-Medoids on MapReduce
//! (`kmedoids-coreset-mr`).
//!
//! The paper's §3.2 loop pays one full assign/update job pair per outer
//! iteration. Following the composable-coreset line (Ene et al., *Fast
//! Clustering using MapReduce*; Mazzetto et al., *Accurate MapReduce
//! Algorithms for k-median and k-means in General Metric Spaces* — both
//! in PAPERS.md), this driver gets a comparable-quality clustering in a
//! **constant number of jobs**, independent of the iteration count:
//!
//! 1. **Map** — each split is locally clustered to `per_split` weighted
//!    representatives (serial ++ seeding inside the mapper, then one
//!    kernel assignment pass); the rep's weight is the number of split
//!    points it captures. Emitted as a weighted run
//!    ([`crate::util::codec::encode_weighted_run`]).
//! 2. **Reduce** — one reducer merges the per-split coresets (zero-copy
//!    [`PackedPoints::weighted`] view over the shuffle bytes) and, when
//!    the merged set exceeds the target size, recompresses it to
//!    `coreset_size` weighted representatives through the weighted
//!    kernels ([`crate::runtime::ops::assign_weighted`]).
//! 3. **Driver** — weighted recluster of the coreset to k medoids
//!    (the same weighted ++ machinery as `oversample`'s recluster in
//!    [`super::seeding`]) followed by weighted alternating refinement on
//!    the coreset, all charged to the master's simulated clock.
//! 4. **Final pass** — one map-only job computes the exact full-data cost
//!    (and labels, when requested) under the run's metric.
//!
//! Two MR jobs total, versus one per iteration for `kmedoids-mr` — the
//! shuffle moves O(coreset) bytes instead of O(n) per iteration. The
//! conformance harness (`rust/tests/conformance.rs`) checks the cost
//! stays within a declared factor of the brute-force oracle.
//!
//! Both jobs go through [`Cluster::try_run_job`], so the pipeline runs
//! unchanged on either execution lane ([`crate::mapreduce::Lane`]) with
//! byte-identical output. (With only two jobs it profits least from
//! the DAG lane's split cache — the interesting lane contrast is the
//! iterative drivers'.)

use super::observe::{FitCheckpoint, IterationEvent, ObserverHub};
use super::seeding::{min_dists_chunked, recluster_candidates};
use super::{ClusterOutcome, FitResume, IterParams};
use crate::geo::{Metric, Point, PointSource, Weighted, WeightedSource};
use crate::mapreduce::{Cluster, Input, JobSpec, MapCtx, Mapper, ReduceCtx, Reducer};
use crate::runtime::{
    assign_points,
    ops::{assign_weighted, weighted_pairwise_costs_src},
    ComputeBackend, PrunedAssigner,
};
use crate::sim::TaskWork;
use crate::util::codec::{encode_cluster_key, encode_weighted_run, Dec, Enc, PackedPoints};
use crate::util::nearest::argmin_f64;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Default coreset size: O(k·log n) weighted representatives (the usual
/// composable-coreset budget), capped at n. Already ≥ k whenever k ≤ n
/// (`k·(log n + 1) ≥ k`), and total for k > n too — unlike `clamp(k, n)`,
/// which would panic on an inverted range.
pub fn default_coreset_size(k: usize, n: usize) -> usize {
    let log_n = (n.max(2) as f64).log2().ceil() as usize;
    (k * (log_n + 1)).min(n.max(1))
}

/// Driver configuration for the constant-round coreset pipeline.
pub struct CoresetKMedoids {
    pub backend: Arc<dyn ComputeBackend>,
    pub params: IterParams,
    /// Dissimilarity the fit minimizes (kernel-dispatched).
    pub metric: Metric,
    /// Total weighted-representative budget; `None` uses
    /// [`default_coreset_size`].
    pub coreset_size: Option<usize>,
    /// Also emit per-point labels from the final pass (no extra job —
    /// the cost pass carries them).
    pub label_pass: bool,
    /// Restored mid-fit state: skip the coreset-construction jobs and
    /// the recluster, continue refining from this checkpoint boundary
    /// (the checkpoint must carry the weighted coreset pool).
    pub resume: Option<FitResume>,
}

pub const CORESET_EVENT_NAME: &str = "kmedoids-coreset-mr";

impl CoresetKMedoids {
    pub fn new(backend: Arc<dyn ComputeBackend>, params: IterParams) -> CoresetKMedoids {
        CoresetKMedoids {
            backend,
            params,
            metric: Metric::SqEuclidean,
            coreset_size: None,
            label_pass: false,
            resume: None,
        }
    }

    /// Reject a checkpoint that does not match this fit configuration
    /// (see `ParallelKMedoids::validate_resume` for the rationale).
    fn validate_resume(&self, r: &FitResume, dims: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            r.algorithm == CORESET_EVENT_NAME,
            "resume checkpoint was written by '{}' but this fit is '{CORESET_EVENT_NAME}'",
            r.algorithm
        );
        anyhow::ensure!(
            r.metric == self.metric,
            "resume checkpoint metric '{}' does not match fit metric '{}'",
            r.metric.name(),
            self.metric.name()
        );
        anyhow::ensure!(
            r.seed == self.params.seed,
            "resume checkpoint seed {} does not match fit seed {} (rerun with --seed {})",
            r.seed,
            self.params.seed,
            r.seed
        );
        anyhow::ensure!(
            r.medoids.len() == self.params.k,
            "resume checkpoint has {} medoids but k = {}",
            r.medoids.len(),
            self.params.k
        );
        anyhow::ensure!(
            r.medoids.iter().all(|m| m.dims() == dims),
            "resume checkpoint medoids are not {dims}-dimensional like the data"
        );
        let (reps, weights) = r
            .coreset
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("resume checkpoint carries no coreset pool"))?;
        anyhow::ensure!(!reps.is_empty(), "resume checkpoint coreset pool is empty");
        anyhow::ensure!(
            reps.len() == weights.len() && reps.iter().all(|p| p.dims() == dims),
            "resume checkpoint coreset pool is malformed"
        );
        Ok(())
    }

    /// Run the constant-round pipeline. Iteration events cover the
    /// driver-side weighted refinement on the coreset (`cost` there is
    /// the *weighted coreset* objective); the returned
    /// [`ClusterOutcome::cost`] is the exact full-data cost from the
    /// final pass.
    pub fn run_observed(
        &self,
        cluster: &mut Cluster,
        input: &Input,
        points: &Arc<Vec<Point>>,
        hub: &mut ObserverHub,
    ) -> anyhow::Result<ClusterOutcome> {
        let k = self.params.k;
        let t_start = cluster.now().0;
        anyhow::ensure!(!points.is_empty(), "cannot cluster an empty dataset");
        let dims = points[0].dims();
        anyhow::ensure!(
            self.metric.supports_dims(dims),
            "metric {} does not support {dims}-dimensional data",
            self.metric.name()
        );
        let n = points.len();
        let target = self.coreset_size.unwrap_or_else(|| default_coreset_size(k, n)).max(k).min(n);
        let n_splits = input.splits().len().max(1);
        let per_split = per_split_budget(target, n_splits, k);

        // ---- jobs 1+2 + recluster — or the restored checkpoint state --------
        // On resume the pool, medoids, and counters come from the
        // checkpoint; the construction jobs and the recluster are
        // skipped entirely (their cost is carried in the counters).
        let cands: Vec<Point>;
        let weights: Vec<f64>;
        let mut medoids: Vec<Point>;
        let start_iter: usize;
        let start_cost: f64;
        let mut dist_evals: u64;
        let sim_offset: f64;
        let already_converged: bool;
        let mut local_evals: u64;
        match &self.resume {
            Some(r) => {
                self.validate_resume(r, dims)?;
                let (reps, ws) = r.coreset.clone().expect("validated above");
                cands = reps;
                weights = ws;
                medoids = r.medoids.clone();
                start_iter = r.iteration;
                start_cost = r.cost;
                dist_evals = r.dist_evals;
                sim_offset = r.sim_seconds;
                already_converged = r.converged;
                local_evals = 0u64;
            }
            None => {
                let job = JobSpec::new(
                    "kmedoids-coreset",
                    input.clone(),
                    Arc::new(CoresetMapper {
                        backend: self.backend.clone(),
                        metric: self.metric,
                        per_split,
                        seed: self.params.seed,
                    }),
                )
                .with_reducer(
                    Arc::new(CoresetMergeReducer {
                        backend: self.backend.clone(),
                        metric: self.metric,
                        dims,
                        target,
                        seed: self.params.seed,
                    }),
                    1,
                );
                let result = cluster.try_run_job(&job)?;
                dist_evals = result.counters.get("work.dist.evals");

                anyhow::ensure!(
                    result.output.len() == 1,
                    "coreset merge must emit one weighted run"
                );
                let merged = PackedPoints::weighted(dims, [result.output[0].1.as_slice()]);
                let mut pts: Vec<Point> = Vec::with_capacity(merged.len());
                let mut ws: Vec<f64> = Vec::with_capacity(merged.len());
                for i in 0..merged.len() {
                    pts.push(merged.get(i));
                    ws.push(merged.weight(i) as f64);
                }
                anyhow::ensure!(!pts.is_empty(), "coreset job produced no representatives");

                // Driver-side weighted recluster of the coreset to k medoids.
                let mut rng = Rng::new(self.params.seed ^ 0xC05E);
                medoids = recluster_candidates(&pts, &ws, k, points, &mut rng, self.metric);
                local_evals = (k as u64) * pts.len() as u64;
                cands = pts;
                weights = ws;
                start_iter = 0;
                start_cost = f64::INFINITY;
                sim_offset = 0.0;
                already_converged = false;
            }
        }

        let weights_f32: Vec<f32> = weights.iter().map(|&w| w as f32).collect();
        let iter_cap = self.params.fixed_iters.unwrap_or(self.params.max_iters).max(1);
        let mut iterations = start_iter;
        let mut cost = start_cost;
        let first_iter = if already_converged { iter_cap } else { start_iter };
        for _iter in first_iter..iter_cap {
            iterations += 1;
            let step = weighted_refine_step(
                self.backend.as_ref(),
                &cands,
                &weights_f32,
                &medoids,
                self.metric,
                false,
            )?;
            local_evals += step.dist_evals;
            let new_cost = step.cost;
            let new_medoids = step.medoids;
            let unchanged = new_medoids == medoids;
            let cost_flat = cost.is_finite()
                && (cost - new_cost).abs() <= self.params.rel_tol * cost.abs().max(1.0);
            let drift: f64 = new_medoids
                .iter()
                .zip(&medoids)
                .map(|(a, b)| self.metric.displacement(a, b))
                .sum();
            medoids = new_medoids;
            cost = new_cost;
            // Charge this refinement iteration's work to the master's
            // simulated clock (same accounting rule as oversample_mr's
            // driver-side recluster), then emit the event with the
            // cumulative fit clock.
            let evals_now = std::mem::take(&mut local_evals);
            let work = TaskWork { dist_evals: evals_now, ..Default::default() };
            let master = &cluster.config.nodes[cluster.config.master];
            let secs = cluster.cost.cpu_seconds(master, &work);
            cluster.advance_secs(secs);
            dist_evals += evals_now;
            let converged_now = self.params.fixed_iters.is_none() && (unchanged || cost_flat);
            hub.iteration(&IterationEvent {
                algorithm: CORESET_EVENT_NAME,
                iteration: iterations,
                cost,
                medoid_drift: drift,
                sim_seconds: sim_offset + (cluster.now().0 - t_start),
                dist_evals,
            });
            // Resumable snapshot: the weighted pool rides along so a
            // resumed run can skip the construction jobs entirely.
            hub.checkpoint(&FitCheckpoint {
                algorithm: CORESET_EVENT_NAME,
                metric: self.metric,
                seed: self.params.seed,
                k,
                iteration: iterations,
                cost,
                sim_seconds: sim_offset + (cluster.now().0 - t_start),
                dist_evals,
                converged: converged_now,
                medoids: &medoids,
                coreset: Some((&cands, &weights)),
            });
            if converged_now {
                break;
            }
        }

        // ---- final pass: exact full-data cost (+ labels) --------------------
        // Same Auto resolution as the iterative driver: durability
        // (checkpoints or a resume) pins the dense lane so dist_evals
        // stay comparable across interrupted and uninterrupted runs.
        let pruned: Option<Arc<PrunedAssigner>> = self
            .params
            .pruning
            .enabled(hub.wants_checkpoints(), self.resume.is_some())
            .then(|| Arc::new(PrunedAssigner::new(self.metric)));
        if let Some(pa) = &pruned {
            pa.begin_epoch(&medoids);
        }
        let job = JobSpec::new(
            "kmedoids-coreset-cost",
            input.clone(),
            Arc::new(CostLabelMapper {
                backend: self.backend.clone(),
                medoids: Arc::from(medoids.as_slice()),
                metric: self.metric,
                with_labels: self.label_pass,
                pruned,
            }),
        );
        let result = cluster.try_run_job(&job)?;
        dist_evals += result.counters.get("work.dist.evals");
        let mut total_cost = 0.0f64;
        let mut labels = if self.label_pass { Some(vec![0u32; n]) } else { None };
        for (key, val) in &result.output {
            let row_start = Dec::new(key).u64() as usize;
            let mut d = Dec::new(val);
            total_cost += d.f64();
            if let Some(labels) = labels.as_mut() {
                let mut i = row_start;
                while !d.is_empty() {
                    labels[i] = d.u32();
                    i += 1;
                }
            }
        }

        Ok(ClusterOutcome {
            medoids,
            labels,
            cost: total_cost,
            iterations,
            sim_seconds: sim_offset + (cluster.now().0 - t_start),
            dist_evals,
        })
    }
}

/// What one [`weighted_refine_step`] produced.
pub(crate) struct RefineStep {
    pub medoids: Vec<Point>,
    /// Weighted coreset cost of the medoids passed *in*.
    pub cost: f64,
    pub dist_evals: u64,
}

/// One weighted alternating-refinement step on a coreset: a weighted
/// assignment of the representatives to `medoids`, then an exact
/// weighted PAM medoid update per cluster. Returns the new medoids, the
/// weighted coreset cost of the *input* medoids (the assign pass), and
/// the distance evaluations performed.
///
/// With `incumbent_candidates` the current medoid is prepended to each
/// cluster's candidate list (first-wins ties keep it), which makes the
/// assign/update chain non-increasing even when the incumbent is not one
/// of the representatives — the online-serving refinement needs that
/// guarantee because its incumbents come from a full-data fit. The
/// coreset driver passes `false`: its medoids are always drawn from the
/// representative set, so they are already members of their own cluster.
pub(crate) fn weighted_refine_step(
    backend: &dyn ComputeBackend,
    cands: &[Point],
    weights_f32: &[f32],
    medoids: &[Point],
    metric: Metric,
    incumbent_candidates: bool,
) -> anyhow::Result<RefineStep> {
    let coreset = Weighted::new(cands, weights_f32);
    let assign = assign_weighted(backend, &coreset, medoids, metric)?;
    let mut dist_evals = assign.dist_evals;
    let cost: f64 = assign.cluster_cost.iter().sum();
    let mut new_medoids = medoids.to_vec();
    for (j, slot) in new_medoids.iter_mut().enumerate() {
        let idx: Vec<usize> = (0..cands.len()).filter(|&i| assign.labels[i] == j as u32).collect();
        if idx.is_empty() {
            continue; // empty cluster keeps its medoid
        }
        let member_pts: Vec<Point> = idx.iter().map(|&i| cands[i]).collect();
        let member_ws: Vec<f32> = idx.iter().map(|&i| weights_f32[i]).collect();
        let members = Weighted::new(member_pts.as_slice(), &member_ws);
        if incumbent_candidates {
            let mut cand_pts = Vec::with_capacity(idx.len() + 1);
            cand_pts.push(*slot);
            cand_pts.extend_from_slice(&member_pts);
            let (costs, evals) =
                weighted_pairwise_costs_src(backend, cand_pts.as_slice(), &members, metric)?;
            dist_evals += evals;
            *slot = cand_pts[argmin_f64(&costs)];
        } else {
            let (costs, evals) =
                weighted_pairwise_costs_src(backend, member_pts.as_slice(), &members, metric)?;
            dist_evals += evals;
            *slot = member_pts[argmin_f64(&costs)];
        }
    }
    Ok(RefineStep { medoids: new_medoids, cost, dist_evals })
}

/// Per-split representative budget: splits together land ≈ `target`
/// reps, floored at 2 so even a sliver split contributes a spread pair
/// (the driver-side recluster tops up from the full dataset if the
/// merged pool ever lacks k distinct coordinates). Shared with tests
/// that rebuild the mapper's coreset.
pub(crate) fn per_split_budget(target: usize, n_splits: usize, k: usize) -> usize {
    target.div_ceil(n_splits.max(1)).max(k.min(2))
}

// ---- map side ----------------------------------------------------------------

/// Locally cluster one split to `per_split` weighted representatives.
struct CoresetMapper {
    backend: Arc<dyn ComputeBackend>,
    metric: Metric,
    per_split: usize,
    /// Deterministic per-split stream: the local seeding depends only on
    /// (seed, split start row), not on scheduling or thread count.
    seed: u64,
}

impl Mapper for CoresetMapper {
    fn map_points(&self, ctx: &mut MapCtx, row_start: u64, pts: &[Point]) {
        if pts.is_empty() {
            return;
        }
        let m = self.per_split.min(pts.len());
        // Local ++ seeding picks spread representatives; serial, f64 —
        // the split is small relative to the dataset and runs once.
        let mut rng = Rng::new(self.seed ^ 0xC0_5E7 ^ row_start);
        let (reps, seed_evals) =
            super::seeding::plus_plus_serial(pts, m, &mut rng, self.metric);
        // One kernel pass weights each representative by the split
        // population it captures.
        let (labels, _, assign_evals) =
            min_dists_chunked(self.backend.as_ref(), pts, &reps, self.metric);
        let mut weights = vec![0f32; reps.len()];
        for &l in &labels {
            weights[l as usize] += 1.0;
        }
        let evals = seed_evals + assign_evals;
        ctx.charge_dist_evals(evals);
        ctx.counters.inc("work.dist.evals", evals);
        ctx.counters.inc("coreset.reps", reps.len() as u64);
        // Single shuffle key: every split's coreset meets in one reducer.
        ctx.emit(encode_cluster_key(0), encode_weighted_run(&reps, &weights));
    }
}

// ---- reduce side -------------------------------------------------------------

/// Merge per-split coresets; recompress to `target` weighted
/// representatives when the union is larger.
struct CoresetMergeReducer {
    backend: Arc<dyn ComputeBackend>,
    metric: Metric,
    dims: usize,
    target: usize,
    seed: u64,
}

impl Reducer for CoresetMergeReducer {
    fn reduce(&self, ctx: &mut ReduceCtx, key: &[u8], values: &[Vec<u8>]) {
        // Zero-copy weighted view over the shuffle bytes.
        let merged = PackedPoints::weighted(self.dims, values.iter().map(|v| v.as_slice()));
        let n = merged.len();
        if n == 0 {
            return;
        }
        let mut pts: Vec<Point> = Vec::with_capacity(n);
        let mut ws: Vec<f64> = Vec::with_capacity(n);
        for i in 0..n {
            pts.push(merged.get(i));
            ws.push(merged.weight(i) as f64);
        }
        if n <= self.target {
            let ws32: Vec<f32> = ws.iter().map(|&w| w as f32).collect();
            ctx.emit(key.to_vec(), encode_weighted_run(&pts, &ws32));
            return;
        }
        // Compress: weighted ++ draw of `target` representatives, then one
        // kernel assignment re-weights them by captured mass (labels are
        // weight-independent, so the shared chunked scan applies; the
        // weights only aggregate).
        let mut rng = Rng::new(self.seed ^ 0xC05ED);
        let reps = recluster_candidates(&pts, &ws, self.target, &pts, &mut rng, self.metric);
        let (labels, _, assign_evals) =
            min_dists_chunked(self.backend.as_ref(), &pts, &reps, self.metric);
        let evals = (self.target as u64) * n as u64 + assign_evals;
        ctx.charge_dist_evals(evals);
        ctx.counters.inc("work.dist.evals", evals);
        let mut new_ws = vec![0f32; reps.len()];
        for (i, &l) in labels.iter().enumerate() {
            new_ws[l as usize] += ws[i] as f32;
        }
        ctx.emit(key.to_vec(), encode_weighted_run(&reps, &new_ws));
    }
}

// ---- final pass --------------------------------------------------------------

/// Map-only exact cost (and optional labels) under the final medoids.
struct CostLabelMapper {
    backend: Arc<dyn ComputeBackend>,
    medoids: Arc<[Point]>,
    metric: Metric,
    with_labels: bool,
    /// One-shot pruned lane: bounds start cold, but the shared spatial
    /// index still caps each resolve at the cell's candidate list.
    pruned: Option<Arc<PrunedAssigner>>,
}

impl Mapper for CostLabelMapper {
    fn map_points(&self, ctx: &mut MapCtx, row_start: u64, pts: &[Point]) {
        let res = match &self.pruned {
            Some(pa) => pa.assign_split(self.backend.as_ref(), row_start, pts, &self.medoids),
            None => assign_points(self.backend.as_ref(), pts, &self.medoids, self.metric),
        }
        .expect("assign kernel failed in coreset cost pass");
        ctx.charge_dist_evals(res.dist_evals);
        ctx.counters.inc("work.dist.evals", res.dist_evals);
        let split_cost: f64 = res.cluster_cost.iter().sum();
        let mut enc = Enc::with_capacity(8 + 4 * pts.len()).f64(split_cost);
        if self.with_labels {
            for &l in &res.labels {
                enc = enc.u32(l);
            }
        }
        ctx.emit(Enc::new().u64(row_start).done(), enc.done());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::metrics::{
        adjusted_rand_index, total_cost_metric, weighted_total_cost_metric,
    };
    use crate::config::ClusterConfig;
    use crate::geo::datasets::{generate, SpatialSpec};
    use crate::mapreduce::{SplitMeta, SplitOrigin};
    use crate::runtime::NativeBackend;

    fn backend() -> Arc<dyn ComputeBackend> {
        Arc::new(NativeBackend::new(256, 16))
    }

    fn make_input(points: &Arc<Vec<Point>>, n_splits: usize) -> Input {
        let total = points.len() as u64;
        let splits = (0..n_splits as u64)
            .map(|i| SplitMeta {
                row_start: total * i / n_splits as u64,
                row_end: total * (i + 1) / n_splits as u64,
                bytes: 1 << 20,
                preferred: vec![],
                origin: SplitOrigin::Adhoc,
            })
            .collect();
        Input::Points { points: points.clone(), splits }
    }

    fn run(
        n: usize,
        k: usize,
        seed: u64,
        splits: usize,
        coreset_size: Option<usize>,
        label_pass: bool,
    ) -> (ClusterOutcome, Arc<Vec<Point>>, Vec<Option<u32>>, usize) {
        let mut spec = SpatialSpec::new(n, k, seed);
        spec.outlier_frac = 0.0;
        let d = generate(&spec);
        let points = Arc::new(d.points);
        let input = make_input(&points, splits);
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), seed);
        let mut drv = CoresetKMedoids::new(backend(), IterParams::new(k, seed));
        drv.coreset_size = coreset_size;
        drv.label_pass = label_pass;
        let out = drv
            .run_observed(&mut cluster, &input, &points, &mut ObserverHub::default())
            .expect("coreset fit failed");
        (out, points, d.truth, cluster.jobs_run)
    }

    #[test]
    fn default_coreset_size_is_k_log_n() {
        assert_eq!(default_coreset_size(3, 1024), 3 * 11);
        assert!(default_coreset_size(9, 2) >= 9 || default_coreset_size(9, 2) == 2);
        // Clamped into [k, n].
        assert_eq!(default_coreset_size(5, 4), 4);
        assert!(default_coreset_size(4, 1_000_000) >= 4);
        // Shared per-split budget: ≈ target/n_splits, sliver floor 2.
        assert_eq!(per_split_budget(33, 4, 3), 9);
        assert_eq!(per_split_budget(10, 100, 5), 2);
        assert_eq!(per_split_budget(10, 1, 1), 10);
    }

    #[test]
    fn constant_two_jobs_regardless_of_data_size() {
        let (_, _, _, jobs_small) = run(1500, 4, 7, 3, None, false);
        let (_, _, _, jobs_large) = run(6000, 4, 7, 6, None, false);
        assert_eq!(jobs_small, 2, "coreset job + cost pass");
        assert_eq!(jobs_large, 2, "job count must not grow with n or splits");
    }

    #[test]
    fn recovers_planted_clusters_and_reports_oracle_cost() {
        let (out, points, truth, _) = run(5000, 5, 3, 5, None, true);
        assert_eq!(out.medoids.len(), 5);
        // Medoids are data points (K-Medoids invariant).
        for m in &out.medoids {
            assert!(points.iter().any(|p| p == m), "medoid {m:?} must be an input point");
        }
        let ari = adjusted_rand_index(out.labels.as_ref().unwrap(), &truth);
        assert!(ari > 0.85, "ARI {ari} too low");
        // Reported cost is the exact full-data oracle cost.
        let brute = total_cost_metric(&points, &out.medoids, Metric::SqEuclidean);
        assert!(
            (out.cost - brute).abs() / brute.max(1.0) < 1e-6,
            "cost {} vs brute {brute}",
            out.cost
        );
        assert!(out.sim_seconds > 0.0);
        assert!(out.dist_evals > 0);
    }

    #[test]
    fn deterministic_in_the_seed() {
        let a = run(2500, 4, 11, 4, None, true).0;
        let b = run(2500, 4, 11, 4, None, true).0;
        assert_eq!(a.medoids, b.medoids);
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.sim_seconds, b.sim_seconds);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn quality_tracks_full_mr_within_factor() {
        // The coreset answer must be within a modest factor of the
        // iterative MR driver's on the same data (the conformance
        // harness enforces the cross-algorithm version of this).
        let mut spec = SpatialSpec::new(4000, 5, 13);
        spec.outlier_frac = 0.0;
        let d = generate(&spec);
        let points = Arc::new(d.points);
        let (coreset_out, _, _, _) = run(4000, 5, 13, 5, None, false);
        let input = make_input(&points, 5);
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 13);
        let mut full = super::super::parallel::ParallelKMedoids::new(
            backend(),
            IterParams::new(5, 13),
        );
        full.update = super::super::UpdateStrategy::Exact;
        let full_out = full.run(&mut cluster, &input, &points);
        let c_coreset = total_cost_metric(&points, &coreset_out.medoids, Metric::SqEuclidean);
        let c_full = total_cost_metric(&points, &full_out.medoids, Metric::SqEuclidean);
        assert!(
            c_coreset <= c_full * 2.5,
            "coreset cost {c_coreset} vs full MR {c_full}"
        );
    }

    #[test]
    fn explicit_coreset_size_bounds_the_merged_set() {
        // A tiny explicit budget still yields k medoids; a huge one is
        // clamped to n.
        let (out, _, _, _) = run(1200, 3, 17, 4, Some(6), false);
        assert_eq!(out.medoids.len(), 3);
        let (out, _, _, _) = run(400, 3, 17, 2, Some(10_000), false);
        assert_eq!(out.medoids.len(), 3);
    }

    #[test]
    fn weighted_coreset_cost_approximates_full_cost() {
        // The merged weighted coreset is a faithful proxy: its weighted
        // cost under the final medoids approximates the full-data cost
        // (this is the coreset property the constant-round bound rests
        // on). Checked through the weighted oracle.
        let mut spec = SpatialSpec::new(3000, 4, 19);
        spec.outlier_frac = 0.0;
        let d = generate(&spec);
        let points = Arc::new(d.points);
        let input = make_input(&points, 4);
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(4), 19);
        let drv = CoresetKMedoids::new(backend(), IterParams::new(4, 19));
        let out = drv
            .run_observed(&mut cluster, &input, &points, &mut ObserverHub::default())
            .unwrap();
        // Rebuild the coreset the same way the driver saw it (same
        // shared budget formula, so the rebuilt object cannot drift).
        let job = JobSpec::new(
            "rebuild",
            input.clone(),
            Arc::new(CoresetMapper {
                backend: backend(),
                metric: Metric::SqEuclidean,
                per_split: per_split_budget(default_coreset_size(4, 3000), 4, 4),
                seed: 19,
            }),
        )
        .with_reducer(
            Arc::new(CoresetMergeReducer {
                backend: backend(),
                metric: Metric::SqEuclidean,
                dims: 2,
                target: default_coreset_size(4, 3000),
                seed: 19,
            }),
            1,
        );
        let result = cluster.try_run_job(&job).unwrap();
        let merged = PackedPoints::weighted(2, [result.output[0].1.as_slice()]);
        let (mut cpts, mut cws) = (Vec::new(), Vec::new());
        for i in 0..merged.len() {
            cpts.push(merged.get(i));
            cws.push(merged.weight(i));
        }
        let w_total: f64 = cws.iter().map(|&w| w as f64).sum();
        assert!(
            (w_total - 3000.0).abs() < 1e-3,
            "coreset mass must equal the dataset size, got {w_total}"
        );
        let proxy = weighted_total_cost_metric(&cpts, &cws, &out.medoids, Metric::SqEuclidean);
        let full = total_cost_metric(&points, &out.medoids, Metric::SqEuclidean);
        assert!(
            proxy <= full * 1.75 && proxy >= full * 0.25,
            "weighted proxy {proxy} should track full cost {full}"
        );
    }

    #[test]
    fn events_stream_one_per_refinement_iteration() {
        use crate::clustering::observe::IterationLog;
        let mut spec = SpatialSpec::new(1500, 3, 23);
        spec.outlier_frac = 0.0;
        let d = generate(&spec);
        let points = Arc::new(d.points);
        let input = make_input(&points, 3);
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(3), 23);
        let drv = CoresetKMedoids::new(backend(), IterParams::new(3, 23));
        let log = IterationLog::new();
        let mut hub = ObserverHub::default();
        hub.add(Box::new(log.clone()));
        let out = drv.run_observed(&mut cluster, &input, &points, &mut hub).unwrap();
        let events = log.events();
        assert_eq!(events.len(), out.iterations);
        assert!(events.iter().all(|e| e.algorithm == CORESET_EVENT_NAME));
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.iteration, i + 1);
        }
        // Cumulative clocks are monotone.
        assert!(events.windows(2).all(|w| w[1].sim_seconds >= w[0].sim_seconds));
    }

    #[test]
    fn fixed_iters_controls_refinement_count() {
        let mut spec = SpatialSpec::new(1200, 3, 29);
        spec.outlier_frac = 0.0;
        let d = generate(&spec);
        let points = Arc::new(d.points);
        let input = make_input(&points, 3);
        let mut cluster = Cluster::new(ClusterConfig::test_cluster(3), 29);
        let mut params = IterParams::new(3, 29);
        params.fixed_iters = Some(6);
        let drv = CoresetKMedoids::new(backend(), params);
        let out = drv
            .run_observed(&mut cluster, &input, &points, &mut ObserverHub::default())
            .unwrap();
        assert_eq!(out.iterations, 6);
        assert_eq!(cluster.jobs_run, 2, "fixed refinement must not add MR jobs");
    }
}
