//! The unified solver API: the [`SpatialClusterer`] trait plus fluent
//! builders for every algorithm in this crate.
//!
//! All five evaluation-grid algorithms are interchangeable solvers over a
//! shared [`ClusterSession`]:
//!
//! ```text
//! let mut session = ClusterSession::builder()
//!     .cluster(ClusterConfig::paper_cluster())
//!     .seed(42)
//!     .build()?;
//! let data = session.ingest_spec("city", &SpatialSpec::new(100_000, 9, 42));
//!
//! let solver = KMedoids::mapreduce()
//!     .plus_plus()
//!     .k(9)
//!     .update(UpdateStrategy::paper_scale_default())
//!     .build();
//! let outcome = solver.fit(&mut session, &data)?;
//! // same session, same ingested data, different solver:
//! let km = KMeans::mapreduce().k(9).build();
//! let outcome2 = km.fit(&mut session, &data)?;
//! ```
//!
//! Every builder takes `.metric(Metric)` — squared Euclidean (default),
//! Manhattan, or haversine over `(lat, lon)` clouds — and the solvers are
//! dimension-generic (the dataset's dimensionality threads through
//! automatically; the metric must support it or `fit` refuses).
//!
//! | Builder | Algorithm name | Engine |
//! |---|---|---|
//! | `KMedoids::mapreduce().plus_plus()` | `kmedoids++-mr` | [`super::parallel`] |
//! | `KMedoids::mapreduce().random_init()` | `kmedoids-mr` | [`super::parallel`] |
//! | `KMedoids::mapreduce().oversample(l, r)` | `kmedoids-scalable-mr` | [`super::parallel`] |
//! | `KMedoids::coreset()` | `kmedoids-coreset-mr` | [`super::coreset`] |
//! | `KMedoids::serial()` | `kmedoids-serial` | [`super::pam`] |
//! | `Clarans::serial()` | `clarans` | [`super::clarans`] |
//! | `KMeans::mapreduce()` | `kmeans-mr` | [`super::kmeans`] |
//!
//! The MR builders additionally take `.lane(Lane)` — a per-fit
//! [execution lane](crate::mapreduce::Lane) override that runs the fit
//! on the Hadoop MR scheduler or the in-memory DAG runtime and restores
//! the session's lane afterwards — and `.exec(&ExecConfig)`, which
//! applies the solver-level knobs (`lane`, `pruning`) of the
//! consolidated [`ExecConfig`] group in one call. Outputs are
//! byte-identical across lanes; only simulated time differs. The serial
//! engines never submit MR jobs and refuse a lane override.

use super::clarans::{clarans_observed, ClaransParams};
use super::coreset::CoresetKMedoids;
use super::kmeans::ParallelKMeans;
use super::observe::ObserverHub;
use super::pam::alternating_kmedoids_observed;
use super::parallel::ParallelKMedoids;
use super::{ClusterOutcome, FitResume, Init, IterParams, PruningMode, UpdateStrategy};
use crate::config::ClusterConfig;
use crate::geo::Metric;
use crate::mapreduce::{Cluster, ExecConfig, Lane};
use crate::session::{ClusterSession, DatasetHandle};
use crate::sim::CostModel;
use anyhow::{ensure, Result};

/// Shared MR-fit plumbing: pair `fit_start` with exactly one of
/// `fit_end` / `fit_error` around the engine run.
fn run_mr_fit(
    session: &mut ClusterSession,
    name: &'static str,
    n_points: usize,
    k: usize,
    run: impl FnOnce(&mut Cluster, &mut ObserverHub) -> Result<ClusterOutcome>,
) -> Result<ClusterOutcome> {
    let (cluster, hub) = session.cluster_and_observers();
    hub.fit_start(name, n_points, k);
    match run(cluster, hub) {
        Ok(outcome) => {
            session.observers_mut().fit_end(&outcome);
            Ok(outcome)
        }
        Err(e) => {
            session.observers_mut().fit_error(name, &format!("{e:#}"));
            Err(e)
        }
    }
}

/// Apply a per-fit execution-lane override around `run`, restoring the
/// session's lane afterwards (on error too) so a solver-level override
/// never leaks into later fits on the same session. `None` inherits
/// the session's lane untouched.
fn with_lane_override(
    session: &mut ClusterSession,
    lane: Option<Lane>,
    run: impl FnOnce(&mut ClusterSession) -> Result<ClusterOutcome>,
) -> Result<ClusterOutcome> {
    let Some(lane) = lane else { return run(session) };
    let prev = session.lane();
    session.set_lane(lane)?;
    let outcome = run(session);
    // The previous lane was valid for this session a moment ago and a
    // fit cannot arm a fault plan, so restoration cannot fail.
    session.set_lane(prev).expect("restoring the previous execution lane is always valid");
    outcome
}

/// Shared serial-fit plumbing: same `fit_start`/`fit_end` pairing as
/// [`run_mr_fit`], plus clock accounting for off-cluster work. Serial
/// engines are infallible once started.
fn run_serial_fit(
    session: &mut ClusterSession,
    name: &'static str,
    n_points: usize,
    k: usize,
    run: impl FnOnce(&ClusterConfig, &CostModel, &mut ObserverHub) -> ClusterOutcome,
) -> ClusterOutcome {
    let cfg = session.config().clone();
    let cost = session.cost_model().clone();
    let hub = session.observers_mut();
    hub.fit_start(name, n_points, k);
    let outcome = run(&cfg, &cost, hub);
    session.account_serial_fit(&outcome);
    outcome
}

/// Check the solver's metric against the dataset — refusing up front
/// beats a kernel assert deep inside a map task. Haversine additionally
/// requires (lat, lon) data: a planar map-unit cloud would be silently
/// misread as degrees, so spec-generated planar datasets are refused
/// outright and raw ingests are validated by coordinate range.
fn ensure_metric_ok(
    session: &ClusterSession,
    data: &crate::session::DatasetHandle,
    metric: Metric,
) -> Result<()> {
    let dims = session.dataset_dims(data);
    ensure!(
        metric.supports_dims(dims),
        "metric {} does not support {dims}-dimensional data \
         (haversine needs (lat, lon) pairs, dims <= {})",
        metric.name(),
        crate::geo::MAX_DIMS
    );
    if metric == Metric::Haversine {
        match session.dataset_latlon(data) {
            Some(true) => {}
            Some(false) => anyhow::bail!(
                "haversine needs (lat, lon) data, but dataset {:?} was generated as a \
                 planar map-unit cloud (use SpatialSpec::latlon)",
                data.name()
            ),
            None => {
                let points = session.dataset_points(data);
                ensure!(
                    points.iter().all(|p| {
                        (-90.0..=90.0).contains(&p.x()) && (-180.0..=180.0).contains(&p.y())
                    }),
                    "haversine needs (lat, lon) degree pairs, but dataset {:?} has \
                     coordinates outside [-90, 90] x [-180, 180]",
                    data.name()
                );
            }
        }
    }
    Ok(())
}

/// Guard [`Init`] parameters the fluent builders cannot reject: fail
/// through the `Result` path like every other invalid parameter instead
/// of a seeding-time assertion panic.
fn ensure_init_ok(init: Init) -> Result<()> {
    if let Init::OverSample { l, rounds } = init {
        ensure!(
            l >= 1 && rounds >= 1,
            "oversample seeding needs l >= 1 and rounds >= 1 (got l={l}, rounds={rounds})"
        );
    }
    Ok(())
}

/// A clustering algorithm runnable against a [`ClusterSession`]'s
/// ingested data. Implementations stream [`super::IterationEvent`]s
/// through the session's observers while fitting.
pub trait SpatialClusterer {
    /// Stable algorithm name (the `Algorithm::parse` vocabulary).
    fn name(&self) -> &'static str;
    /// Number of clusters this solver is configured for.
    fn k(&self) -> usize;
    /// Fit on `data` (previously ingested into `session`), returning the
    /// paper-comparable outcome. MR solvers advance the session's
    /// simulated clock by running jobs; serial solvers account their
    /// modeled serial time on the same clock.
    fn fit(&self, session: &mut ClusterSession, data: &DatasetHandle) -> Result<ClusterOutcome>;
}

/// How a `KMedoids` solver executes.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Exec {
    MapReduce,
    /// Constant-round weighted-coreset pipeline ([`super::coreset`]).
    Coreset,
    Serial,
}

// ---- K-Medoids (the paper's family) ----------------------------------------

/// K-Medoids solver: the paper's parallel MR driver (++, random, or
/// k-means||-style oversampled init) or the serial alternating baseline.
/// Build via [`KMedoids::mapreduce`] / [`KMedoids::serial`].
#[derive(Debug, Clone)]
pub struct KMedoids {
    exec: Exec,
    init: Init,
    k: usize,
    seed: u64,
    update: UpdateStrategy,
    metric: Metric,
    max_iters: usize,
    rel_tol: f64,
    fixed_iters: Option<usize>,
    label_pass: bool,
    /// Weighted-representative budget for the coreset exec mode; `None`
    /// uses the O(k·log n) default.
    coreset_size: Option<usize>,
    /// Checkpointed state to continue from instead of seeding fresh
    /// (see [`crate::persist`]); MR exec modes only.
    resume: Option<FitResume>,
    /// Triangle-inequality pruned assignment lane (byte-identical
    /// outputs, fewer distance evaluations). `Auto` defers to the
    /// durability rule in [`PruningMode::enabled`].
    pruning: PruningMode,
    /// Per-fit execution-lane override; `None` inherits the session's
    /// lane. MR exec modes only — the serial baseline refuses it.
    lane: Option<Lane>,
}

/// Fluent builder for [`KMedoids`].
#[derive(Debug, Clone)]
pub struct KMedoidsBuilder {
    inner: KMedoids,
}

impl KMedoids {
    /// The paper's §3 driver: one MR job per iteration on the session's
    /// simulated cluster. Defaults: ++ seeding, k=9, exact update,
    /// squared Euclidean.
    pub fn mapreduce() -> KMedoidsBuilder {
        KMedoidsBuilder {
            inner: KMedoids {
                exec: Exec::MapReduce,
                init: Init::PlusPlus,
                k: 9,
                seed: 42,
                update: UpdateStrategy::Exact,
                metric: Metric::SqEuclidean,
                max_iters: 30,
                rel_tol: 1e-3,
                fixed_iters: None,
                label_pass: false,
                coreset_size: None,
                resume: None,
                pruning: PruningMode::Auto,
                lane: None,
            },
        }
    }

    /// The §2.3 "traditional K-Medoids" baseline: serial alternation on
    /// the master node (random init by default, as in the paper).
    pub fn serial() -> KMedoidsBuilder {
        let mut b = KMedoids::mapreduce();
        b.inner.exec = Exec::Serial;
        b.inner.init = Init::Random;
        b
    }

    /// The constant-round weighted-coreset pipeline
    /// (`kmedoids-coreset-mr`, [`super::coreset`]): two MR jobs total —
    /// per-split weighted coresets merged by one reducer, then a
    /// driver-side weighted recluster and one exact cost/label pass —
    /// instead of one job pair per iteration. Tune the representative
    /// budget with [`KMedoidsBuilder::coreset_size`].
    pub fn coreset() -> KMedoidsBuilder {
        let mut b = KMedoids::mapreduce();
        b.inner.exec = Exec::Coreset;
        b
    }
}

impl KMedoidsBuilder {
    /// K-Medoids++ weighted seeding (§3.1).
    pub fn plus_plus(mut self) -> Self {
        self.inner.init = Init::PlusPlus;
        self
    }
    /// Uniform random init ("traditional").
    pub fn random_init(mut self) -> Self {
        self.inner.init = Init::Random;
        self
    }
    /// k-means||-style oversampled seeding (Bahmani et al.): ℓ expected
    /// candidates per round for `rounds` rounds, then a weighted
    /// recluster to k. O(rounds) seeding jobs instead of k−1.
    pub fn oversample(mut self, l: usize, rounds: usize) -> Self {
        self.inner.init = Init::OverSample { l, rounds };
        self
    }
    pub fn init(mut self, init: Init) -> Self {
        self.inner.init = init;
        self
    }
    pub fn k(mut self, k: usize) -> Self {
        self.inner.k = k;
        self
    }
    pub fn seed(mut self, seed: u64) -> Self {
        self.inner.seed = seed;
        self
    }
    /// Reducer medoid-update strategy (Table 2 flavor).
    pub fn update(mut self, update: UpdateStrategy) -> Self {
        self.inner.update = update;
        self
    }
    /// Dissimilarity to minimize (default: squared Euclidean).
    pub fn metric(mut self, metric: Metric) -> Self {
        self.inner.metric = metric;
        self
    }
    pub fn max_iters(mut self, n: usize) -> Self {
        self.inner.max_iters = n;
        self
    }
    pub fn rel_tol(mut self, tol: f64) -> Self {
        self.inner.rel_tol = tol;
        self
    }
    /// Run exactly `n` outer iterations (the Table 6 controlled-iteration
    /// mode) instead of converging.
    pub fn fixed_iters(mut self, n: usize) -> Self {
        self.inner.fixed_iters = Some(n);
        self
    }
    /// Run the final map-only labeling pass so the outcome carries labels.
    pub fn with_labels(mut self) -> Self {
        self.inner.label_pass = true;
        self
    }
    pub fn label_pass(mut self, on: bool) -> Self {
        self.inner.label_pass = on;
        self
    }
    /// Total weighted-representative budget of the coreset pipeline
    /// (only honored by [`KMedoids::coreset`]; default O(k·log n)).
    pub fn coreset_size(mut self, n: usize) -> Self {
        self.inner.coreset_size = Some(n);
        self
    }
    /// Continue from a checkpoint ([`crate::persist::Checkpoint::to_resume`])
    /// instead of seeding fresh. The engine validates that the checkpoint's
    /// algorithm, metric, seed, and k match this builder's configuration,
    /// so a resumed fit is byte-identical to the uninterrupted run. MR
    /// exec modes only; the serial baseline refuses it.
    pub fn resume(mut self, state: FitResume) -> Self {
        self.inner.resume = Some(state);
        self
    }
    /// Assignment-lane selection: `On` forces the pruned lane, `Off` the
    /// dense kernels, `Auto` (default) prunes unless the fit writes
    /// checkpoints or resumes from one. Outputs are byte-identical
    /// either way.
    pub fn pruning(mut self, mode: PruningMode) -> Self {
        self.inner.pruning = mode;
        self
    }
    /// Execution-lane override for this fit: run on the Hadoop MR
    /// scheduler or the in-memory DAG runtime regardless of the
    /// session's lane, restoring the session's lane afterwards.
    /// Outputs are byte-identical across lanes ([`Lane`]); only
    /// simulated time differs. MR exec modes only.
    pub fn lane(mut self, lane: Lane) -> Self {
        self.inner.lane = Some(lane);
        self
    }
    /// Apply the solver-level knobs of a consolidated [`ExecConfig`]
    /// group — `lane` and `pruning` — in one call. The session-level
    /// knobs (threads, speculation, faults, …) are consumed by
    /// [`crate::session::SessionBuilder::exec`].
    pub fn exec(mut self, exec: &ExecConfig) -> Self {
        self.inner.lane = Some(exec.lane);
        self.inner.pruning = exec.pruning;
        self
    }
    pub fn build(self) -> KMedoids {
        self.inner
    }
}

impl KMedoids {
    fn iter_params(&self) -> IterParams {
        let mut p = IterParams::new(self.k, self.seed);
        p.max_iters = self.max_iters;
        p.rel_tol = self.rel_tol;
        p.fixed_iters = self.fixed_iters;
        p.pruning = self.pruning;
        p
    }
}

impl SpatialClusterer for KMedoids {
    fn name(&self) -> &'static str {
        match (self.exec, self.init) {
            (Exec::MapReduce, Init::PlusPlus) => "kmedoids++-mr",
            (Exec::MapReduce, Init::Random) => "kmedoids-mr",
            (Exec::MapReduce, Init::OverSample { .. }) => "kmedoids-scalable-mr",
            (Exec::Coreset, _) => "kmedoids-coreset-mr",
            (Exec::Serial, _) => "kmedoids-serial",
        }
    }
    fn k(&self) -> usize {
        self.k
    }

    fn fit(&self, session: &mut ClusterSession, data: &DatasetHandle) -> Result<ClusterOutcome> {
        let points = session.dataset_points(data);
        ensure!(
            (1..=points.len()).contains(&self.k),
            "k={} must be in 1..={} (dataset size)",
            self.k,
            points.len()
        );
        ensure_metric_ok(session, data, self.metric)?;
        ensure_init_ok(self.init)?;
        let name = self.name();
        match self.exec {
            Exec::MapReduce => {
                let input = session.dataset_input(data);
                let drv = ParallelKMedoids {
                    backend: session.backend(),
                    init: self.init,
                    update: self.update,
                    params: self.iter_params(),
                    metric: self.metric,
                    label_pass: self.label_pass,
                    event_label: None,
                    resume: self.resume.clone(),
                };
                with_lane_override(session, self.lane, |session| {
                    run_mr_fit(session, name, points.len(), self.k, |cluster, hub| {
                        drv.run_observed(cluster, &input, &points, hub)
                    })
                })
            }
            Exec::Coreset => {
                if let Some(size) = self.coreset_size {
                    ensure!(
                        size >= 1,
                        "coreset_size must be >= 1 (it is clamped into [k, n] at fit time)"
                    );
                }
                let input = session.dataset_input(data);
                let drv = CoresetKMedoids {
                    backend: session.backend(),
                    params: self.iter_params(),
                    metric: self.metric,
                    coreset_size: self.coreset_size,
                    label_pass: self.label_pass,
                    resume: self.resume.clone(),
                };
                with_lane_override(session, self.lane, |session| {
                    run_mr_fit(session, name, points.len(), self.k, |cluster, hub| {
                        drv.run_observed(cluster, &input, &points, hub)
                    })
                })
            }
            Exec::Serial => {
                // Refuse rather than silently converge early: the serial
                // engine has no controlled-iteration mode (same rule the
                // JSON run-spec layer enforces).
                ensure!(
                    self.fixed_iters.is_none(),
                    "kmedoids-serial ignores fixed_iters (only the MR drivers support \
                     controlled iterations)"
                );
                ensure!(
                    self.resume.is_none(),
                    "kmedoids-serial cannot resume from a checkpoint (only the MR drivers \
                     emit and restore checkpoints)"
                );
                ensure!(
                    self.lane.is_none(),
                    "kmedoids-serial runs on the master node and never submits MR jobs; \
                     remove the lane override (only the MR drivers execute on a lane)"
                );
                let backend = session.backend();
                let bytes = session.dataset_bytes(data);
                let mut outcome =
                    run_serial_fit(session, name, points.len(), self.k, |cfg, cost, hub| {
                        alternating_kmedoids_observed(
                            backend.as_ref(),
                            &points,
                            &self.iter_params(),
                            self.init,
                            self.update,
                            self.metric,
                            cfg,
                            cost,
                            bytes,
                            hub,
                        )
                    });
                if !self.label_pass {
                    // The serial engine always labels; drop them unless
                    // asked, matching the MR solver's contract.
                    outcome.labels = None;
                }
                Ok(outcome)
            }
        }
    }
}

// ---- Parallel k-means (robustness ablation) --------------------------------

/// MR k-means (Zhao/Ma/He), the outlier-sensitivity comparator. Build via
/// [`KMeans::mapreduce`]. Under a non-Euclidean metric the mean update is
/// invalid, so the engine falls back to a medoid update (see
/// [`super::kmeans`] module docs).
#[derive(Debug, Clone)]
pub struct KMeans {
    init: Init,
    k: usize,
    seed: u64,
    metric: Metric,
    max_iters: usize,
    rel_tol: f64,
    pruning: PruningMode,
    /// Per-fit execution-lane override; `None` inherits the session's
    /// lane (see [`KMedoids`]'s field of the same name).
    lane: Option<Lane>,
}

/// Fluent builder for [`KMeans`].
#[derive(Debug, Clone)]
pub struct KMeansBuilder {
    inner: KMeans,
}

impl KMeans {
    pub fn mapreduce() -> KMeansBuilder {
        KMeansBuilder {
            inner: KMeans {
                init: Init::PlusPlus,
                k: 9,
                seed: 42,
                metric: Metric::SqEuclidean,
                max_iters: 30,
                rel_tol: 1e-3,
                pruning: PruningMode::Auto,
                lane: None,
            },
        }
    }
}

impl KMeansBuilder {
    pub fn plus_plus(mut self) -> Self {
        self.inner.init = Init::PlusPlus;
        self
    }
    pub fn random_init(mut self) -> Self {
        self.inner.init = Init::Random;
        self
    }
    pub fn init(mut self, init: Init) -> Self {
        self.inner.init = init;
        self
    }
    pub fn k(mut self, k: usize) -> Self {
        self.inner.k = k;
        self
    }
    pub fn seed(mut self, seed: u64) -> Self {
        self.inner.seed = seed;
        self
    }
    /// Dissimilarity of the fit (non-Euclidean metrics run the medoid
    /// fallback — see [`super::kmeans`]).
    pub fn metric(mut self, metric: Metric) -> Self {
        self.inner.metric = metric;
        self
    }
    pub fn max_iters(mut self, n: usize) -> Self {
        self.inner.max_iters = n;
        self
    }
    pub fn rel_tol(mut self, tol: f64) -> Self {
        self.inner.rel_tol = tol;
        self
    }
    /// Assignment-lane selection (see [`KMedoidsBuilder::pruning`]).
    pub fn pruning(mut self, mode: PruningMode) -> Self {
        self.inner.pruning = mode;
        self
    }
    /// Execution-lane override for this fit (see
    /// [`KMedoidsBuilder::lane`]).
    pub fn lane(mut self, lane: Lane) -> Self {
        self.inner.lane = Some(lane);
        self
    }
    /// Apply the solver-level knobs of an [`ExecConfig`] group (see
    /// [`KMedoidsBuilder::exec`]).
    pub fn exec(mut self, exec: &ExecConfig) -> Self {
        self.inner.lane = Some(exec.lane);
        self.inner.pruning = exec.pruning;
        self
    }
    pub fn build(self) -> KMeans {
        self.inner
    }
}

impl SpatialClusterer for KMeans {
    fn name(&self) -> &'static str {
        "kmeans-mr"
    }
    fn k(&self) -> usize {
        self.k
    }

    fn fit(&self, session: &mut ClusterSession, data: &DatasetHandle) -> Result<ClusterOutcome> {
        let points = session.dataset_points(data);
        ensure!(
            (1..=points.len()).contains(&self.k),
            "k={} must be in 1..={} (dataset size)",
            self.k,
            points.len()
        );
        ensure_metric_ok(session, data, self.metric)?;
        ensure_init_ok(self.init)?;
        let input = session.dataset_input(data);
        let mut params = IterParams::new(self.k, self.seed);
        params.max_iters = self.max_iters;
        params.rel_tol = self.rel_tol;
        params.pruning = self.pruning;
        let km = ParallelKMeans {
            backend: session.backend(),
            init: self.init,
            params,
            metric: self.metric,
        };
        with_lane_override(session, self.lane, |session| {
            run_mr_fit(session, self.name(), points.len(), self.k, |cluster, hub| {
                km.run_observed(cluster, &input, &points, hub)
            })
        })
    }
}

// ---- CLARANS (Ng & Han) -----------------------------------------------------

/// CLARANS randomized-search comparator (serial, on the master node).
/// Build via [`Clarans::serial`]. Parameters default to Ng & Han's
/// recommendations derived from the dataset size at fit time, with the
/// paper-scale cost-sampling substitution above 100k points (DESIGN.md).
#[derive(Debug, Clone)]
pub struct Clarans {
    k: usize,
    seed: u64,
    metric: Metric,
    num_local: Option<usize>,
    max_neighbor: Option<usize>,
    cost_sample: Option<usize>,
    paper_scale_sampling: bool,
    /// Accepted for surface uniformity with the MR builders, but
    /// CLARANS is serial — any explicit lane is refused at fit time.
    lane: Option<Lane>,
}

/// Fluent builder for [`Clarans`].
#[derive(Debug, Clone)]
pub struct ClaransBuilder {
    inner: Clarans,
}

impl Clarans {
    pub fn serial() -> ClaransBuilder {
        ClaransBuilder {
            inner: Clarans {
                k: 9,
                seed: 42,
                metric: Metric::SqEuclidean,
                num_local: None,
                max_neighbor: None,
                cost_sample: None,
                paper_scale_sampling: true,
                lane: None,
            },
        }
    }

    /// Resolve the effective parameters for a dataset of `n` points.
    fn params_for(&self, n: usize) -> ClaransParams {
        let mut p = ClaransParams::recommended(self.k, n, self.seed);
        p.metric = self.metric;
        if self.paper_scale_sampling && n > 100_000 {
            // Sampled cost evaluation at paper scale; the sample grows
            // with n so CLARANS keeps its Fig. 5 scaling (DESIGN.md).
            p.cost_sample = (16_000 + n / 100).min(n);
            p.max_neighbor = p.max_neighbor.min(1_500);
        }
        if let Some(v) = self.num_local {
            p.num_local = v;
        }
        if let Some(v) = self.max_neighbor {
            p.max_neighbor = v;
        }
        if let Some(v) = self.cost_sample {
            p.cost_sample = v;
        }
        p
    }
}

impl ClaransBuilder {
    pub fn k(mut self, k: usize) -> Self {
        self.inner.k = k;
        self
    }
    pub fn seed(mut self, seed: u64) -> Self {
        self.inner.seed = seed;
        self
    }
    /// Dissimilarity the search minimizes (default: squared Euclidean).
    pub fn metric(mut self, metric: Metric) -> Self {
        self.inner.metric = metric;
        self
    }
    /// Override the number of restarts (Ng & Han recommend 2).
    pub fn num_local(mut self, n: usize) -> Self {
        self.inner.num_local = Some(n);
        self
    }
    /// Override the neighbors examined before declaring a local minimum.
    pub fn max_neighbor(mut self, n: usize) -> Self {
        self.inner.max_neighbor = Some(n);
        self
    }
    /// Override the cost-evaluation sample size (`usize::MAX` = exact).
    pub fn cost_sample(mut self, n: usize) -> Self {
        self.inner.cost_sample = Some(n);
        self
    }
    /// Disable the automatic >100k-point cost-sampling substitution.
    pub fn exact_cost(mut self) -> Self {
        self.inner.paper_scale_sampling = false;
        self
    }
    /// Present for surface uniformity with the MR builders — CLARANS
    /// runs serially on the master node, so any explicit lane is
    /// refused at fit time (same rule the JSON run-spec layer enforces).
    pub fn lane(mut self, lane: Lane) -> Self {
        self.inner.lane = Some(lane);
        self
    }
    pub fn build(self) -> Clarans {
        self.inner
    }
}

impl SpatialClusterer for Clarans {
    fn name(&self) -> &'static str {
        "clarans"
    }
    fn k(&self) -> usize {
        self.k
    }

    fn fit(&self, session: &mut ClusterSession, data: &DatasetHandle) -> Result<ClusterOutcome> {
        let points = session.dataset_points(data);
        // Strictly k < n (not <= as for the other solvers): CLARANS swaps
        // a medoid for a *non-medoid*, which cannot exist when k == n.
        ensure!(
            (1..points.len()).contains(&self.k),
            "k={} must be in 1..{} (dataset size)",
            self.k,
            points.len()
        );
        ensure_metric_ok(session, data, self.metric)?;
        ensure!(
            self.lane.is_none(),
            "clarans runs serially on the master node and never submits MR jobs; \
             remove the lane override (only the MR drivers execute on a lane)"
        );
        let params = self.params_for(points.len());
        let bytes = session.dataset_bytes(data);
        let outcome = run_serial_fit(session, self.name(), points.len(), self.k, |cfg, cost, hub| {
            clarans_observed(&points, &params, cfg, cost, bytes, hub)
        });
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_are_fluent_and_defaulted() {
        let m = KMedoids::mapreduce()
            .plus_plus()
            .k(9)
            .update(UpdateStrategy::paper_scale_default())
            .build();
        assert_eq!(m.name(), "kmedoids++-mr");
        assert_eq!(m.k(), 9);

        let r = KMedoids::mapreduce().random_init().k(4).build();
        assert_eq!(r.name(), "kmedoids-mr");

        let o = KMedoids::mapreduce().oversample(18, 5).k(9).build();
        assert_eq!(o.name(), "kmedoids-scalable-mr");
        assert_eq!(o.init, Init::OverSample { l: 18, rounds: 5 });

        let s = KMedoids::serial().k(5).seed(7).build();
        assert_eq!(s.name(), "kmedoids-serial");

        let c = KMedoids::coreset().k(6).coreset_size(96).build();
        assert_eq!(c.name(), "kmedoids-coreset-mr");
        assert_eq!(c.k(), 6);
        assert_eq!(c.coreset_size, Some(96));

        let km = KMeans::mapreduce().k(3).build();
        assert_eq!(km.name(), "kmeans-mr");

        let cl = Clarans::serial().k(4).num_local(1).max_neighbor(60).build();
        assert_eq!(cl.name(), "clarans");
        assert_eq!(cl.k(), 4);
    }

    #[test]
    fn metric_threads_through_builders() {
        let m = KMedoids::mapreduce().metric(Metric::Haversine).build();
        assert_eq!(m.metric, Metric::Haversine);
        let km = KMeans::mapreduce().metric(Metric::Manhattan).build();
        assert_eq!(km.metric, Metric::Manhattan);
        let cl = Clarans::serial().metric(Metric::Manhattan).build();
        assert_eq!(cl.params_for(1000).metric, Metric::Manhattan);
    }

    #[test]
    fn clarans_params_scale_with_dataset() {
        let cl = Clarans::serial().k(9).build();
        let small = cl.params_for(10_000);
        assert_eq!(small.cost_sample, usize::MAX, "small n evaluates exactly");
        let big = cl.params_for(1_000_000);
        assert!(big.cost_sample < 1_000_000, "paper scale samples the cost");
        assert!(big.max_neighbor <= 1_500);

        let exact = Clarans::serial().k(9).exact_cost().build().params_for(1_000_000);
        assert_eq!(exact.cost_sample, usize::MAX);

        let overridden = Clarans::serial().k(9).cost_sample(123).build().params_for(1_000_000);
        assert_eq!(overridden.cost_sample, 123, "explicit override wins");
    }

    #[test]
    fn kmedoids_iter_params_carry_through() {
        let m =
            KMedoids::mapreduce().k(5).seed(11).max_iters(12).rel_tol(1e-4).fixed_iters(6).build();
        let p = m.iter_params();
        assert_eq!((p.k, p.seed, p.max_iters), (5, 11, 12));
        assert_eq!(p.fixed_iters, Some(6));
        assert_eq!(p.rel_tol, 1e-4);
        assert_eq!(p.pruning, PruningMode::Auto, "pruning defaults to Auto");
        let off = KMedoids::mapreduce().pruning(PruningMode::Off).build();
        assert_eq!(off.iter_params().pruning, PruningMode::Off);
    }

    #[test]
    fn lane_overrides_thread_through_and_serial_engines_refuse() {
        use crate::geo::datasets::SpatialSpec;
        let m = KMedoids::mapreduce().lane(Lane::InMemoryDag).build();
        assert_eq!(m.lane, Some(Lane::InMemoryDag));
        assert_eq!(KMedoids::mapreduce().build().lane, None, "default inherits the session");

        let grouped = ExecConfig {
            lane: Lane::InMemoryDag,
            pruning: PruningMode::Off,
            ..ExecConfig::default()
        };
        let via = KMedoids::mapreduce().exec(&grouped).build();
        assert_eq!(via.lane, Some(Lane::InMemoryDag));
        assert_eq!(via.pruning, PruningMode::Off);
        let km = KMeans::mapreduce().exec(&grouped).build();
        assert_eq!(km.lane, Some(Lane::InMemoryDag));
        assert_eq!(km.pruning, PruningMode::Off);

        let mut session = ClusterSession::builder().test(3).seed(1).build().unwrap();
        let data = session.ingest_spec("pts", &SpatialSpec::new(400, 3, 1));
        let e = KMedoids::serial()
            .k(3)
            .lane(Lane::HadoopMr)
            .build()
            .fit(&mut session, &data)
            .unwrap_err();
        assert!(format!("{e:#}").contains("lane override"), "{e:#}");
        let e = Clarans::serial()
            .k(3)
            .lane(Lane::InMemoryDag)
            .build()
            .fit(&mut session, &data)
            .unwrap_err();
        assert!(format!("{e:#}").contains("lane override"), "{e:#}");
    }

    #[test]
    fn per_fit_lane_override_restores_the_session_lane() {
        use crate::geo::datasets::SpatialSpec;
        let mut session = ClusterSession::builder().test(3).seed(9).build().unwrap();
        let data = session.ingest_spec("pts", &SpatialSpec::new(600, 3, 9));
        assert_eq!(session.lane(), Lane::HadoopMr);
        let solver = KMedoids::mapreduce().k(3).fixed_iters(2).lane(Lane::InMemoryDag).build();
        solver.fit(&mut session, &data).unwrap();
        assert_eq!(session.lane(), Lane::HadoopMr, "the override must not leak");
    }

    #[test]
    fn haversine_on_planar_dims_is_refused() {
        use crate::geo::datasets::SpatialSpec;
        let mut session = ClusterSession::builder().test(3).seed(1).build().unwrap();
        let data = session.ingest_spec("d3", &SpatialSpec::new(500, 3, 1).with_dims(3));
        let e = KMedoids::mapreduce()
            .k(3)
            .metric(Metric::Haversine)
            .build()
            .fit(&mut session, &data)
            .unwrap_err();
        assert!(format!("{e:#}").contains("haversine"), "{e:#}");
    }

    #[test]
    fn zero_oversample_parameters_are_refused_not_panicked() {
        use crate::geo::datasets::SpatialSpec;
        let mut session = ClusterSession::builder().test(3).seed(1).build().unwrap();
        let data = session.ingest_spec("pts", &SpatialSpec::new(500, 3, 1));
        for (l, rounds) in [(0usize, 4usize), (8, 0)] {
            let e = KMedoids::mapreduce()
                .k(3)
                .oversample(l, rounds)
                .build()
                .fit(&mut session, &data)
                .unwrap_err();
            assert!(format!("{e:#}").contains("oversample"), "(l={l}, rounds={rounds}): {e:#}");
        }
    }

    #[test]
    fn haversine_on_non_latlon_data_is_refused() {
        use crate::geo::datasets::SpatialSpec;
        use crate::geo::Point;
        use std::sync::Arc;
        let mut session = ClusterSession::builder().test(3).seed(1).build().unwrap();
        let hav = KMedoids::mapreduce().k(2).metric(Metric::Haversine).build();

        // A spec-generated planar cloud is refused outright (map units,
        // not degrees — the generator knows).
        let planar = session.ingest_spec("planar", &SpatialSpec::new(500, 3, 1));
        let e = hav.fit(&mut session, &planar).unwrap_err();
        assert!(format!("{e:#}").contains("planar map-unit"), "{e:#}");

        // Raw ingests are range-checked: out-of-range coordinates refuse...
        let bad = Arc::new(vec![Point::new(1000.0, 0.0), Point::new(0.0, 0.0)]);
        let bad = session.ingest_points("bad", bad);
        let e = hav.fit(&mut session, &bad).unwrap_err();
        assert!(format!("{e:#}").contains("[-90, 90]"), "{e:#}");

        // ...while plausible (lat, lon) pairs are accepted.
        let ok = Arc::new(vec![
            Point::new(48.85, 2.35),
            Point::new(51.51, -0.13),
            Point::new(40.71, -74.01),
        ]);
        let ok = session.ingest_points("ok", ok);
        assert!(hav.fit(&mut session, &ok).is_ok());
    }
}
