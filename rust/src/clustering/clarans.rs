//! CLARANS (Clustering Large Applications based on RANdomized Search,
//! Ng & Han) — the second comparator in the paper's Fig. 5.
//!
//! The algorithm walks the graph whose vertices are k-subsets of points
//! and whose edges are single-medoid swaps: from a random current node it
//! examines up to `max_neighbor` random swap neighbors, moving whenever a
//! neighbor is cheaper, and restarts `num_local` times, keeping the best
//! minimum found. Cost evaluation is over all points (exact) or a
//! deterministic sample (`cost_sample`) at paper scale — the sampling knob
//! is documented in DESIGN.md's substitutions. Cost evaluation is
//! metric-generic; the 2-D squared-Euclidean case keeps its hand-inlined
//! f32 fast loop (CLARANS cost evaluation dominates its runtime).
//!
//! CLARANS is serial (master-node only) and never submits MR jobs, so
//! execution lanes ([`crate::mapreduce::Lane`]) do not apply — the
//! fluent API refuses a lane override rather than silently ignoring it.

use super::metrics::total_cost_metric;
use super::observe::{IterationEvent, ObserverHub};
use super::ClusterOutcome;
use crate::config::ClusterConfig;
use crate::geo::{Metric, Point};
use crate::sim::{CostModel, TaskWork};
use crate::util::rng::Rng;

pub struct ClaransParams {
    pub k: usize,
    /// Restarts (Ng & Han recommend 2).
    pub num_local: usize,
    /// Neighbors examined before declaring a local minimum. Ng & Han use
    /// max(250, 1.25% of k(n−k)).
    pub max_neighbor: usize,
    /// Points used per cost evaluation (usize::MAX = exact).
    pub cost_sample: usize,
    /// Dissimilarity the search minimizes.
    pub metric: Metric,
    pub seed: u64,
}

impl ClaransParams {
    pub fn recommended(k: usize, n: usize, seed: u64) -> ClaransParams {
        let max_neighbor = ((0.0125 * (k * (n - k)) as f64) as usize).max(250);
        ClaransParams {
            k,
            num_local: 2,
            max_neighbor,
            cost_sample: usize::MAX,
            metric: Metric::SqEuclidean,
            seed,
        }
    }
}

pub fn clarans(
    points: &[Point],
    params: &ClaransParams,
    cfg: &ClusterConfig,
    cost_model: &CostModel,
    dataset_bytes: u64,
) -> ClusterOutcome {
    clarans_observed(points, params, cfg, cost_model, dataset_bytes, &mut ObserverHub::default())
}

/// [`clarans`] with streaming: one [`IterationEvent`] per *accepted swap
/// move* (CLARANS' outer-iteration unit, matching `outcome.iterations`).
/// Event `cost` is the (possibly sampled) evaluation cost of the accepted
/// node and `sim_seconds` a running serial-cost estimate; the final
/// outcome reports the exact Eq. 1 cost.
pub fn clarans_observed(
    points: &[Point],
    params: &ClaransParams,
    cfg: &ClusterConfig,
    cost_model: &CostModel,
    dataset_bytes: u64,
    hub: &mut ObserverHub,
) -> ClusterOutcome {
    let n = points.len();
    let k = params.k;
    assert!((1..n).contains(&k));
    let metric = params.metric;
    let dims = points.first().map(|p| p.dims()).unwrap_or(2);
    assert!(
        metric.supports_dims(dims),
        "{} does not support dims={dims}",
        metric.name()
    );
    let mut rng = Rng::new(params.seed);
    let mut dist_evals = 0u64;

    // Deterministic evaluation sample (shared by all cost evaluations so
    // comparisons are consistent within a run).
    let eval_idx: Vec<usize> = if params.cost_sample >= n {
        (0..n).collect()
    } else {
        rng.sample_indices(n, params.cost_sample)
    };

    // Gather the evaluation sample once; evaluate in f32 with the medoid
    // coordinates materialized per call (§Perf: ~3x over the naive
    // indexed f64 loop — CLARANS cost evaluation dominates its runtime).
    // The 2-D squared-Euclidean combination keeps the hand-inlined loop;
    // other (dims, metric) pairs go through the generic f32 kernel form.
    let eval_pts: Vec<Point> = eval_idx.iter().map(|&i| points[i]).collect();
    let fast_2d = dims == 2 && metric == Metric::SqEuclidean;
    let eval_cost = |set: &[usize], evals: &mut u64| -> f64 {
        *evals += (eval_pts.len() * set.len()) as u64;
        let meds: Vec<Point> = set.iter().map(|&m| points[m]).collect();
        let mut total = 0f64;
        if fast_2d {
            for p in &eval_pts {
                let mut best = f32::INFINITY;
                for m in &meds {
                    let dx = p.x() - m.x();
                    let dy = p.y() - m.y();
                    let d = dx * dx + dy * dy;
                    if d < best {
                        best = d;
                    }
                }
                total += best as f64;
            }
        } else {
            for p in &eval_pts {
                let mut best = f32::INFINITY;
                for m in &meds {
                    let d = metric.distance_f32(dims, p.coords(), m.coords());
                    if d < best {
                        best = d;
                    }
                }
                total += best as f64;
            }
        }
        total
    };

    let mut best_set: Vec<usize> = Vec::new();
    let mut best_cost = f64::INFINITY;
    let mut moves_total = 0usize;

    for local in 0..params.num_local {
        // Random start node.
        let mut current = rng.sample_indices(n, k);
        let mut current_cost = eval_cost(&current, &mut dist_evals);
        let mut j = 0usize;
        while j < params.max_neighbor {
            // Random neighbor: swap one medoid for one non-medoid.
            let mi = rng.below(k);
            let mut cand = rng.below(n);
            while current.contains(&cand) {
                cand = rng.below(n);
            }
            let mut neighbor = current.clone();
            neighbor[mi] = cand;
            let c = eval_cost(&neighbor, &mut dist_evals);
            if c < current_cost {
                let drift = metric.displacement(&points[current[mi]], &points[cand]);
                current = neighbor;
                current_cost = c;
                moves_total += 1;
                j = 0; // restart neighbor count at the new node
                let work_so_far =
                    TaskWork { rows_parsed: n as u64, dist_evals, ..Default::default() };
                hub.iteration(&IterationEvent {
                    algorithm: "clarans",
                    iteration: moves_total,
                    cost: current_cost,
                    medoid_drift: drift,
                    sim_seconds: super::pam::serial_seconds(
                        cfg,
                        cost_model,
                        &work_so_far,
                        local as u64 + 1,
                        dataset_bytes,
                    ),
                    dist_evals,
                });
            } else {
                j += 1;
            }
        }
        if current_cost < best_cost {
            best_cost = current_cost;
            best_set = current;
        }
    }

    let medoids: Vec<Point> = best_set.iter().map(|&i| points[i]).collect();
    // Report the exact Eq. 1 cost for comparability even when evaluation
    // was sampled.
    let exact_cost = total_cost_metric(points, &medoids, metric);
    dist_evals += (n * k) as u64;

    let work = TaskWork {
        rows_parsed: n as u64, // one materialization of the data
        dist_evals,
        ..Default::default()
    };
    // CLARANS random access pattern: charge one scan per local restart.
    let sim_seconds = super::pam::serial_seconds(
        cfg,
        cost_model,
        &work,
        params.num_local as u64,
        dataset_bytes,
    );
    ClusterOutcome {
        medoids,
        labels: None,
        cost: exact_cost,
        iterations: moves_total,
        sim_seconds,
        dist_evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::metrics::{adjusted_rand_index, brute_labels, brute_labels_metric};
    use crate::geo::datasets::{generate, SpatialSpec};

    fn env() -> (ClusterConfig, CostModel) {
        (ClusterConfig::paper_cluster(), CostModel::default())
    }

    fn params(k: usize, num_local: usize, max_neighbor: usize, seed: u64) -> ClaransParams {
        ClaransParams {
            k,
            num_local,
            max_neighbor,
            cost_sample: usize::MAX,
            metric: Metric::SqEuclidean,
            seed,
        }
    }

    #[test]
    fn finds_planted_clusters() {
        let d = generate(&SpatialSpec::new(1500, 4, 43));
        let (cfg, cm) = env();
        let out = clarans(&d.points, &params(4, 2, 150, 43), &cfg, &cm, 1 << 20);
        let labels = brute_labels(&d.points, &out.medoids);
        let ari = adjusted_rand_index(&labels, &d.truth);
        assert!(ari > 0.75, "ARI {ari}");
    }

    #[test]
    fn sampled_cost_close_to_exact() {
        let d = generate(&SpatialSpec::new(4000, 4, 47));
        let (cfg, cm) = env();
        let exact = clarans(&d.points, &params(4, 1, 80, 5), &cfg, &cm, 1 << 20);
        let mut p = params(4, 1, 80, 5);
        p.cost_sample = 800;
        let sampled = clarans(&d.points, &p, &cfg, &cm, 1 << 20);
        assert!(
            sampled.cost < exact.cost * 1.5,
            "sampled {} vs exact {}",
            sampled.cost,
            exact.cost
        );
        assert!(sampled.dist_evals < exact.dist_evals);
    }

    #[test]
    fn deterministic() {
        let d = generate(&SpatialSpec::new(800, 3, 53));
        let (cfg, cm) = env();
        let a = clarans(&d.points, &params(3, 1, 60, 9), &cfg, &cm, 1 << 20);
        let b = clarans(&d.points, &params(3, 1, 60, 9), &cfg, &cm, 1 << 20);
        assert_eq!(a.medoids, b.medoids);
        assert_eq!(a.dist_evals, b.dist_evals);
    }

    #[test]
    fn manhattan_metric_search_works() {
        let mut spec = SpatialSpec::new(1200, 3, 57).with_dims(3);
        spec.outlier_frac = 0.0;
        let d = generate(&spec);
        let (cfg, cm) = env();
        let mut p = params(3, 1, 120, 57);
        p.metric = Metric::Manhattan;
        let out = clarans(&d.points, &p, &cfg, &cm, 1 << 20);
        assert_eq!(out.medoids.len(), 3);
        assert!(out.medoids.iter().all(|m| m.dims() == 3));
        // Reported cost is the exact L1 objective of the final node.
        let brute = crate::clustering::metrics::total_cost_metric(
            &d.points,
            &out.medoids,
            Metric::Manhattan,
        );
        assert!((out.cost - brute).abs() < 1e-6 * brute.max(1.0));
        let labels = brute_labels_metric(&d.points, &out.medoids, Metric::Manhattan);
        let ari = adjusted_rand_index(&labels, &d.truth);
        assert!(ari > 0.7, "ARI {ari} (L1 clarans)");
    }

    #[test]
    fn recommended_params_scale() {
        let p = ClaransParams::recommended(9, 1_000_000, 1);
        assert!(p.max_neighbor > 250);
        assert_eq!(p.metric, Metric::SqEuclidean);
        let p2 = ClaransParams::recommended(3, 1000, 1);
        assert_eq!(p2.max_neighbor, 250);
    }
}
